//! Integration: rust runtime loads the artifact manifest, executes the
//! lane kernels (emulated by default; real PJRT under `--features
//! xla-pjrt` after `make artifacts`) and the vectorized matcher agrees
//! bit-for-bit with the scalar matchers.

use specdfa::automata::Dfa;
use specdfa::baseline::sequential::SequentialMatcher;
use specdfa::regex::compile::{compile_prosite, compile_search};
use specdfa::runtime::pjrt::{pad_table, VectorUnit};
use specdfa::runtime::simd::SimdMatcher;
use specdfa::speculative::matcher::MatchPlan;
use specdfa::util::rng::Rng;

fn artifacts_dir() -> std::path::PathBuf {
    // tests run from the crate root
    VectorUnit::default_dir()
}

fn require_artifacts() -> std::sync::Arc<VectorUnit> {
    std::sync::Arc::new(VectorUnit::load(artifacts_dir(), "lane8_small")
        .expect(
            "artifacts missing — run `make artifacts` before `cargo test`",
        ))
}

fn random_syms(rng: &mut Rng, dfa: &Dfa, n: usize) -> Vec<u32> {
    (0..n).map(|_| rng.below(dfa.num_symbols as u64) as u32).collect()
}

#[test]
fn vector_unit_loads_and_reports_platform() {
    let vu = require_artifacts();
    assert_eq!(vu.spec.lanes, 8);
    assert_eq!(vu.spec.q, 64);
    let platform = vu.platform();
    assert!(platform.to_lowercase().contains("cpu")
            || platform.to_lowercase().contains("host"),
            "platform {platform}");
}

#[test]
fn lane_match_agrees_with_flat_table() {
    let vu = require_artifacts();
    let dfa = compile_search("(ab|ba)+").unwrap();
    assert!(dfa.num_states as usize <= vu.spec.q);
    let table = pad_table(
        &dfa.table,
        dfa.num_states as usize,
        dfa.num_symbols as usize,
        &vu.spec,
    )
    .unwrap();
    let mut rng = Rng::new(77);
    let syms = random_syms(&mut rng, &dfa, vu.spec.n);
    let inp: Vec<i32> = syms.iter().map(|&s| s as i32).collect();

    // 8 lanes with random (start, len, init)
    let starts: Vec<i32> = (0..8)
        .map(|_| rng.below(vu.spec.n as u64) as i32)
        .collect();
    let lens: Vec<i32> =
        (0..8).map(|_| rng.below(vu.spec.t as u64 + 1) as i32).collect();
    let init: Vec<i32> = (0..8)
        .map(|_| rng.below(dfa.num_states as u64) as i32)
        .collect();
    let out = vu.lane_match(&table, &inp, &starts, &lens, &init).unwrap();

    for l in 0..8 {
        let s0 = starts[l] as usize;
        let mut want = init[l] as u32;
        for i in 0..lens[l] as usize {
            let pos = (s0 + i).min(vu.spec.n - 1);
            want = dfa.step(want, syms[pos]);
        }
        assert_eq!(out[l] as u32, want, "lane {l}");
    }
}

#[test]
fn simd_matcher_equals_scalar_matchers() {
    let vu = require_artifacts();
    let patterns = ["(ab|cd)+e?", "a{2,5}b*c", "hello"];
    let mut rng = Rng::new(123);
    for pat in patterns {
        let dfa = compile_search(pat).unwrap();
        let seq = SequentialMatcher::new(&dfa);
        for r in [0usize, 1, 2] {
            let n = rng.range_usize(0, 20_000);
            let syms = random_syms(&mut rng, &dfa, n);
            let want = seq.run_syms(&syms);
            let simd = SimdMatcher::new(&dfa, &vu).unwrap().lookahead(r);
            let got = simd.run_syms(&syms).unwrap();
            assert_eq!(got.final_state, want.final_state,
                       "pat={pat} r={r} n={n}");
            assert_eq!(got.accepted, want.accepted);
            // and the multicore speculative matcher agrees too
            let mc = MatchPlan::new(&dfa).processors(4).lookahead(r)
                .run_syms(&syms);
            assert_eq!(mc.final_state, want.final_state);
        }
    }
}

#[test]
fn simd_chunk_speedup_grows_with_structure() {
    let vu = require_artifacts();
    // protein-like pattern with small I_max
    let dfa = compile_prosite("D-A-V-I-D.").unwrap();
    assert!(dfa.num_states as usize <= vu.spec.q, "{}", dfa.num_states);
    let mut rng = Rng::new(5);
    let syms = random_syms(&mut rng, &dfa, 50_000);
    let plain = SimdMatcher::new(&dfa, &vu).unwrap().run_syms(&syms).unwrap();
    let opt = SimdMatcher::new(&dfa, &vu)
        .unwrap()
        .lookahead(4)
        .run_syms(&syms)
        .unwrap();
    assert_eq!(plain.final_state, opt.final_state);
    assert!(opt.chunk_speedup() >= plain.chunk_speedup(),
            "opt {} < plain {}", opt.chunk_speedup(), plain.chunk_speedup());
    assert!(opt.chunk_speedup() > 1.0);
}

#[test]
fn compose_kernel_matches_rust_compose() {
    let dir = artifacts_dir();
    let vu = match VectorUnit::load(&dir, "lane8_main") {
        Ok(v) => v,
        Err(_) => return, // main artifact optional for quick test runs
    };
    let qp = vu.compose_width();
    assert_eq!(qp, 1536);
    let mut rng = Rng::new(9);
    let la: Vec<i32> = (0..qp).map(|_| rng.below(qp as u64) as i32).collect();
    let lb: Vec<i32> = (0..qp).map(|_| rng.below(qp as u64) as i32).collect();
    let out = vu.compose(&la, &lb).unwrap();
    for i in 0..qp {
        assert_eq!(out[i], lb[la[i] as usize]);
    }
}

#[test]
fn chained_calls_cross_window_boundaries() {
    // chunk longer than t: SimdMatcher must chain calls correctly
    let vu = require_artifacts();
    let dfa = compile_search("ab").unwrap();
    let seq = SequentialMatcher::new(&dfa);
    let mut rng = Rng::new(31);
    // longer than t=512 and not a multiple of it
    let syms = random_syms(&mut rng, &dfa, 512 * 3 + 129);
    let want = seq.run_syms(&syms);
    let got = SimdMatcher::new(&dfa, &vu)
        .unwrap()
        .lookahead(1)
        .run_syms(&syms)
        .unwrap();
    assert_eq!(got.final_state, want.final_state);
    assert!(got.pjrt_calls >= 4, "calls {}", got.pjrt_calls);
}
