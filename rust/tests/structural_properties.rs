//! Property-test battery over the paper's structural claims, at
//! integration level with larger random instances than the unit suites.

use specdfa::automata::grail;
use specdfa::automata::minimize::{minimize, minimize_moore};
use specdfa::automata::nfa::Nfa;
use specdfa::automata::subset::determinize;
use specdfa::automata::Dfa;
use specdfa::regex::ast::Ast;
use specdfa::automata::byteset::ByteSet;
use specdfa::speculative::lookahead::{i_max_r_naive, Lookahead};
use specdfa::speculative::partition::{partition, total_work};
use specdfa::util::prop;
use specdfa::util::rng::Rng;

fn random_dfa(rng: &mut Rng, max_q: u64, max_s: u64) -> Dfa {
    let q = rng.range_u64(2, max_q) as u32;
    let s = rng.range_u64(2, max_s) as u32;
    let sink = q - 1;
    let mut table = Vec::with_capacity((q * s) as usize);
    for state in 0..q {
        for _ in 0..s {
            table.push(if state == sink {
                sink
            } else if rng.chance(0.05) {
                sink
            } else {
                rng.below(q as u64) as u32
            });
        }
    }
    let accepting = (0..q).map(|st| st != sink && rng.chance(0.25)).collect();
    let mut classes = [0u8; 256];
    for b in 0..256 {
        classes[b] = (b % s as usize) as u8;
    }
    Dfa::new(q, s, 0, accepting, table, classes)
}

#[test]
fn prop_lemma1_and_alg4_agree_at_scale() {
    prop::check("BFS I_max == Algorithm 4, monotone (large DFAs)", 15,
                |rng| {
        let dfa = random_dfa(rng, 120, 8);
        let la = Lookahead::analyze(&dfa, 3);
        for (k, &v) in la.i_max_by_r.iter().enumerate() {
            assert_eq!(v, i_max_r_naive(&dfa, k + 1), "r={}", k + 1);
        }
        for w in la.i_max_by_r.windows(2) {
            assert!(w[0] >= w[1]);
        }
    });
}

#[test]
fn prop_partition_work_formula_eq14() {
    // total work of the basic partition ~ n·|Q|·|P| / (|Q|+|P|-1)
    prop::check("Eq. 14 total work", 60, |rng| {
        let n = rng.range_usize(10_000, 2_000_000);
        let p = rng.range_usize(2, 64);
        let q = rng.range_usize(1, 1024);
        let chunks = partition(n, &vec![1.0; p], q);
        let work = total_work(&chunks, q) as f64;
        let expect = n as f64 * q as f64 * p as f64
            / (q as f64 + p as f64 - 1.0);
        assert!(
            (work - expect).abs() <= expect * 0.01 + (q * p) as f64,
            "work {work} vs Eq.14 {expect} (n={n} p={p} q={q})"
        );
    });
}

#[test]
fn prop_grail_roundtrip_random_dfas() {
    prop::check("grail round-trip identity", 40, |rng| {
        let dfa = random_dfa(rng, 60, 10);
        let text = grail::to_grail(&dfa);
        let back = grail::from_grail(&text).unwrap();
        assert_eq!(back.num_states, dfa.num_states);
        assert_eq!(back.table, dfa.table);
        assert_eq!(back.accepting, dfa.accepting);
        assert_eq!(back.start, dfa.start);
    });
}

#[test]
fn prop_minimize_fixpoint_and_language_large() {
    fn random_ast(rng: &mut Rng, depth: usize) -> Ast {
        if depth == 0 || rng.chance(0.25) {
            return Ast::Class(ByteSet::single(b'a' + rng.below(4) as u8));
        }
        match rng.below(4) {
            0 => Ast::Concat((0..rng.range_usize(1, 4))
                .map(|_| random_ast(rng, depth - 1)).collect()),
            1 => Ast::Alt((0..rng.range_usize(1, 4))
                .map(|_| random_ast(rng, depth - 1)).collect()),
            2 => Ast::star(random_ast(rng, depth - 1)),
            _ => Ast::Repeat {
                node: Box::new(random_ast(rng, depth - 1)),
                min: rng.below(3) as u32,
                max: Some(rng.range_u64(3, 5) as u32),
            },
        }
    }
    prop::check("Hopcroft == Moore == NFA on depth-4 ASTs", 20, |rng| {
        let ast = random_ast(rng, 4);
        if ast.size() > 400 {
            return; // keep runtime sane
        }
        let nfa = Nfa::from_ast(&ast);
        let dfa = determinize(&nfa);
        let h = minimize(&dfa);
        let m = minimize_moore(&dfa);
        assert_eq!(h.num_states, m.num_states);
        for _ in 0..30 {
            let len = rng.below(14) as usize;
            let s: Vec<u8> =
                (0..len).map(|_| b'a' + rng.below(4) as u8).collect();
            assert_eq!(h.accepts_bytes(&s), nfa.accepts(&s));
        }
    });
}

#[test]
fn prop_lookahead_sound_on_minimized_pattern_dfas() {
    // soundness on *real* pattern DFAs (not just random tables)
    let pats = ["(ab|ba)*c", "x[yz]{2,6}w?", "(foo|bar|baz)+"];
    prop::check("initial_set contains reachable state (pattern DFAs)", 30,
                |rng| {
        let pat = pats[rng.usize_below(pats.len())];
        let dfa = specdfa::compile_search(pat).unwrap();
        let la = Lookahead::analyze(&dfa, rng.range_usize(1, 5));
        let len = rng.range_usize(1, 200);
        let syms: Vec<u32> = (0..len)
            .map(|_| rng.below(dfa.num_symbols as u64) as u32)
            .collect();
        let cut = rng.range_usize(1, len);
        let state = dfa.run(dfa.start, &syms[..cut]);
        let set = la.initial_set(&dfa, &syms[..cut]);
        if Some(state) != la.sink {
            assert!(set.contains(state as usize), "pat={pat} cut={cut}");
        }
    });
}
