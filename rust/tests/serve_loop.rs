//! Integration tests for `engine::serve`: the acceptance criteria of the
//! async serving subsystem.
//!
//!  (a) same-pattern coalescing: compile count < request count, and the
//!      cache-hit counter proves repeated batches reused the entry;
//!  (b) capacity calibration: after startup profiling the Auto
//!      thresholds differ from the baked-in ballpark;
//!  (c) streamed outcomes are identical to the synchronous
//!      `match_many` results on the same corpus;
//!  (d) admission control: `Reject` overloads exactly at `max_queue`,
//!      `Block` bounds the depth and unblocks on drain, and submitting
//!      to a shut-down server resolves immediately;
//!  (e) priority scheduling: queued probes jump a queued corpus scan,
//!      and the aging bound keeps a probe flood from starving it;
//!  plus a many-producer concurrency test asserting per-producer
//!  outcome order and a stats-snapshot consistency check.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

use specdfa::engine::{
    Admission, CompiledMatcher, Engine, ExecPolicy, Pattern, ServeConfig,
    ServeError, Server, Ticket,
};
use specdfa::engine::select::AutoThresholds;
use specdfa::workload::InputGen;

fn test_config(workers: usize) -> ServeConfig {
    ServeConfig {
        workers,
        profile_runs: 2,
        profile_sample_syms: 1 << 14,
        recalibrate_every: 0, // deterministic compile counts
        ..ServeConfig::default()
    }
}

/// Config for the admission/priority tests: one deterministic engine,
/// no calibration, no memoization — queue behavior only.
fn bounded_config(workers: usize) -> ServeConfig {
    ServeConfig {
        workers,
        calibrate_on_start: false,
        recalibrate_every: 0,
        cache_outcomes: 0,
        profile_per_worker: false,
        engine: Engine::Sequential,
        ..ServeConfig::default()
    }
}

/// Spin until `cond` holds (30 s hard cap: hitting it means the serving
/// loop wedged, which is itself a failure).
fn wait_until(mut cond: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !cond() {
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "condition timed out"
        );
        std::thread::yield_now();
    }
}

/// Park the only worker on a corpus-scale scan and return its ticket.
/// The pattern is an uppercase literal and `InputGen::ascii_text` emits
/// lowercase only, so the sequential engine can never accept early and
/// must walk the full input — the worker stays busy for milliseconds
/// while the test performs microsecond-scale submissions.
fn wedge(server: &Server, n: usize) -> Ticket {
    let t = server.submit(
        Pattern::Regex("ZQZQZQ".to_string()),
        InputGen::new(0x3ED6E).ascii_text(n),
    );
    wait_until(|| {
        let s = server.stats();
        s.batches >= 1 && s.queue_depth == 0
    });
    t
}

#[test]
fn coalescing_calibration_and_match_many_equivalence() {
    let pattern = Pattern::Regex("(ab|cd)+e?".to_string());
    let mut gen = InputGen::new(0x5EE5);
    let inputs: Vec<Vec<u8>> = (0..64)
        .map(|k| {
            let mut text = gen.ascii_text(200 + 37 * k);
            if k % 2 == 0 {
                gen.plant(&mut text, b"abcde", 1);
            }
            text
        })
        .collect();
    let refs: Vec<&[u8]> = inputs.iter().map(|v| v.as_slice()).collect();

    let server = Server::start(test_config(3)).unwrap();

    // (b) calibrated thresholds differ from the default ballpark
    let thresholds = server.thresholds();
    assert!(thresholds.is_calibrated(), "startup profiling must run");
    assert_ne!(
        thresholds,
        AutoThresholds::default(),
        "calibrated thresholds must differ from the baked-in ballpark"
    );

    // submit the whole corpus under one queue lock: a worker must take
    // it as few coalesced batches, not 64 wake-ups
    let tickets = server.submit_many(&pattern, &refs);
    let streamed: Vec<_> = tickets
        .into_iter()
        .map(|t| t.wait().expect("request must serve"))
        .collect();

    // (c) streamed outcomes equal the synchronous match_many results
    let direct = CompiledMatcher::compile(
        &pattern,
        Engine::Auto,
        ExecPolicy::default(),
    )
    .unwrap()
    .match_many(&refs);
    assert_eq!(direct.error_count(), 0);
    assert_eq!(streamed.len(), direct.outcomes.len());
    for (i, (got, want)) in
        streamed.iter().zip(direct.ok_outcomes()).enumerate()
    {
        assert_eq!(got.accepted, want.accepted, "request {i}");
        assert_eq!(got.final_state, want.final_state, "request {i}");
        assert_eq!(got.n, want.n, "request {i}");
    }

    let stats = server.shutdown();
    // (a) same-pattern coalescing: one compile served all 64 requests
    assert_eq!(stats.submitted, 64);
    assert_eq!(stats.served, 64);
    assert!(
        stats.compiles < stats.served,
        "coalescing failed: {} compiles for {} requests",
        stats.compiles,
        stats.served
    );
    assert!(
        stats.batches < stats.submitted,
        "requests must batch: {} batches for {} requests",
        stats.batches,
        stats.submitted
    );
    assert!(stats.coalesced > 0);
    assert!(stats.requests_per_batch() > 1.0);
    assert!(stats.thresholds.is_calibrated());
}

#[test]
fn many_producers_keep_per_producer_order_and_hit_the_cache() {
    const PRODUCERS: usize = 4;
    const PER_PRODUCER: usize = 25;
    let patterns = [
        Pattern::Regex("(ab|cd)+e?".to_string()),
        Pattern::Regex("needle".to_string()),
    ];
    let server = Server::start(test_config(2)).unwrap();

    let results: Vec<Vec<(usize, bool, Option<u32>)>> =
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for p in 0..PRODUCERS {
                let server = &server;
                let patterns = &patterns;
                handles.push(scope.spawn(move || {
                    let mut gen = InputGen::new(p as u64 + 1);
                    // interleave the two patterns request-by-request
                    let submissions: Vec<_> = (0..PER_PRODUCER)
                        .map(|k| {
                            let mut text = gen.ascii_text(64 + 13 * k);
                            if k % 3 == 0 {
                                gen.plant(&mut text, b"needle", 1);
                                gen.plant(&mut text, b"abcd", 1);
                            }
                            let pat = patterns[k % 2].clone();
                            let ticket = server.submit(pat, text.clone());
                            (k, text, ticket)
                        })
                        .collect();
                    // wait in submission order: the k-th ticket must
                    // stream the k-th request's outcome
                    submissions
                        .into_iter()
                        .map(|(k, text, ticket)| {
                            let out = ticket.wait().expect("serve ok");
                            assert_eq!(
                                out.n,
                                text.len(),
                                "producer {p} request {k}: ticket \
                                 streamed a different request's outcome"
                            );
                            (k, out.accepted, out.final_state)
                        })
                        .collect::<Vec<_>>()
                }));
            }
            handles
                .into_iter()
                .map(|h| h.join().expect("producer panicked"))
                .collect()
        });

    // byte-identical to direct match_many on each producer's corpus
    let matchers: Vec<CompiledMatcher> = patterns
        .iter()
        .map(|p| {
            CompiledMatcher::compile(p, Engine::Auto, ExecPolicy::default())
                .unwrap()
        })
        .collect();
    for (p, outcomes) in results.iter().enumerate() {
        let mut gen = InputGen::new(p as u64 + 1);
        for &(k, accepted, final_state) in outcomes {
            let mut text = gen.ascii_text(64 + 13 * k);
            if k % 3 == 0 {
                gen.plant(&mut text, b"needle", 1);
                gen.plant(&mut text, b"abcd", 1);
            }
            let direct = matchers[k % 2].match_many(&[text.as_slice()]);
            let want = direct.ok_outcomes().next().expect("one outcome");
            assert_eq!(accepted, want.accepted, "producer {p} request {k}");
            assert_eq!(
                final_state, want.final_state,
                "producer {p} request {k}"
            );
        }
    }

    let stats = server.shutdown();
    let total = (PRODUCERS * PER_PRODUCER) as u64;
    assert_eq!(stats.submitted, total);
    assert_eq!(stats.served, total);
    assert_eq!(stats.failed, 0);
    // two patterns, one compile each: everything else came from the cache
    assert!(
        stats.compiles < total,
        "{} compiles for {} requests",
        stats.compiles,
        stats.served
    );
    assert!(
        stats.cache_hits > 0,
        "repeated patterns must hit the compiled-pattern cache"
    );
    assert!(
        stats.cached_patterns <= 2,
        "only two distinct patterns were ever submitted"
    );
}

#[test]
fn outcome_cache_memoizes_repeated_probes() {
    let server = Server::start(test_config(1)).unwrap();
    let pattern = Pattern::Regex("ab+c".to_string());
    let first = server
        .submit(pattern.clone(), &b"xxabbczz"[..])
        .wait()
        .unwrap();
    assert!(first.accepted);
    // the identical probe again: must be a memo hit with the same verdict
    let second = server
        .submit(pattern.clone(), &b"xxabbczz"[..])
        .wait()
        .unwrap();
    assert_eq!(second.accepted, first.accepted);
    assert_eq!(second.final_state, first.final_state);
    assert_eq!(second.n, first.n);
    // a different input must NOT hit
    let other =
        server.submit(pattern, &b"nothing here"[..]).wait().unwrap();
    assert!(!other.accepted);
    let stats = server.shutdown();
    assert_eq!(stats.served, 3);
    assert_eq!(stats.outcome_hits, 1, "exactly the repeated probe hits");
    assert_eq!(stats.cached_outcomes, 2);
}

#[test]
fn outcome_cache_can_be_disabled() {
    let server = Server::start(ServeConfig {
        cache_outcomes: 0,
        ..test_config(1)
    })
    .unwrap();
    let pattern = Pattern::Regex("ab".to_string());
    for _ in 0..3 {
        assert!(server
            .submit(pattern.clone(), &b"ab"[..])
            .wait()
            .unwrap()
            .accepted);
    }
    let stats = server.shutdown();
    assert_eq!(stats.served, 3);
    assert_eq!(stats.outcome_hits, 0);
    assert_eq!(stats.cached_outcomes, 0);
}

#[test]
fn racing_workers_compile_a_new_pattern_once() {
    // many workers, many concurrent submissions of one brand-new
    // pattern: the in-flight marker must dedupe the compile without
    // convoying the other workers
    let server = Server::start(test_config(4)).unwrap();
    let pattern = Pattern::Regex("(ab|cd)+ef".to_string());
    let results: Vec<bool> = std::thread::scope(|scope| {
        (0..16)
            .map(|k| {
                let server = &server;
                let pattern = pattern.clone();
                scope.spawn(move || {
                    let input = if k % 2 == 0 {
                        &b"xxabcdefzz"[..]
                    } else {
                        &b"no match"[..]
                    };
                    server.submit(pattern, input).wait().unwrap().accepted
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    });
    for (k, accepted) in results.iter().enumerate() {
        assert_eq!(*accepted, k % 2 == 0, "request {k}");
    }
    let stats = server.shutdown();
    assert_eq!(stats.served, 16);
    assert_eq!(
        stats.compiles, 1,
        "racing workers must not duplicate the compile"
    );
}

#[test]
fn recalibration_interval_reprofiles_and_bumps_epoch() {
    let server = Server::start(ServeConfig {
        workers: 2,
        profile_runs: 1,
        profile_sample_syms: 1 << 13,
        recalibrate_every: 10,
        ..ServeConfig::default()
    })
    .unwrap();
    let pattern = Pattern::Regex("ab".to_string());
    let inputs: Vec<&[u8]> = vec![b"ab and more"; 35];
    for t in server.submit_many(&pattern, &inputs) {
        assert!(t.wait().unwrap().accepted);
    }
    let stats = server.shutdown();
    assert_eq!(stats.served, 35);
    // startup + one per 10 served requests (3 crossings in 35)
    assert_eq!(
        stats.recalibrations,
        1 + 35 / 10,
        "periodic re-profiling must fire on the request interval"
    );
    assert!(stats.thresholds.is_calibrated());
}

#[test]
fn submit_after_shutdown_resolves_immediately() {
    let server = Server::start(bounded_config(1)).unwrap();
    let handle = server.handle();
    assert!(handle
        .submit(Pattern::Regex("ab".to_string()), &b"xaby"[..])
        .wait()
        .unwrap()
        .accepted);
    let stats = server.shutdown();
    assert_eq!(stats.served, 1);
    // regression: this used to push onto a queue no worker will ever
    // drain, so Ticket::wait blocked forever
    let ticket =
        handle.submit(Pattern::Regex("ab".to_string()), &b"xaby"[..]);
    match ticket.wait_timeout(Duration::from_secs(30)) {
        Ok(res) => {
            assert!(matches!(res, Err(ServeError::ShuttingDown)), "{res:?}")
        }
        Err(_) => panic!("submit-after-shutdown ticket never resolved"),
    }
    let tickets = handle.submit_many(
        &Pattern::Regex("ab".to_string()),
        &[&b"x"[..], &b"y"[..]],
    );
    for t in tickets {
        assert!(matches!(t.wait(), Err(ServeError::ShuttingDown)));
    }
    let s = handle.stats();
    assert_eq!(s.rejected, 3);
    assert_eq!(s.submitted, 1, "refused requests are never 'submitted'");
}

#[test]
fn stats_snapshots_never_show_served_ahead_of_submitted() {
    let server = Server::start(bounded_config(4)).unwrap();
    let done = AtomicBool::new(false);
    std::thread::scope(|scope| {
        let server = &server;
        let done = &done;
        // regression: `submitted` used to be incremented after the
        // queue lock was released, so a snapshot could observe a
        // request served before it was counted as submitted
        let poller = scope.spawn(move || {
            let mut checks = 0u64;
            while !done.load(Ordering::Relaxed) {
                let s = server.stats();
                assert!(
                    s.served + s.failed <= s.submitted,
                    "torn snapshot: served {} + failed {} > submitted {}",
                    s.served,
                    s.failed,
                    s.submitted
                );
                checks += 1;
            }
            checks
        });
        let producers: Vec<_> = (0..3)
            .map(|p| {
                scope.spawn(move || {
                    let pattern = Pattern::Regex(format!("a{p}b"));
                    let inputs: Vec<Vec<u8>> =
                        (0..16).map(|k| vec![b'a'; 8 + k]).collect();
                    let refs: Vec<&[u8]> =
                        inputs.iter().map(|v| v.as_slice()).collect();
                    for _ in 0..40 {
                        for t in server.submit_many(&pattern, &refs) {
                            assert!(t.wait().is_ok());
                        }
                    }
                })
            })
            .collect();
        for h in producers {
            h.join().unwrap();
        }
        done.store(true, Ordering::Relaxed);
        assert!(poller.join().unwrap() > 0, "the poller must have sampled");
    });
    let stats = server.shutdown();
    assert_eq!(stats.submitted, 3 * 40 * 16);
    assert_eq!(stats.served, stats.submitted);
    assert_eq!(stats.failed, 0);
}

#[test]
fn reject_admission_never_exceeds_max_queue() {
    let server = Server::start(ServeConfig {
        max_queue: 4,
        admission: Admission::Reject,
        ..bounded_config(1)
    })
    .unwrap();
    let wedge_ticket = wedge(&server, 8 << 20);
    let probe = Pattern::Regex("ab+c".to_string());
    let accepted: Vec<_> = (0..4)
        .map(|_| server.submit(probe.clone(), &b"xabbcx"[..]))
        .collect();
    // depth is now exactly max_queue: every further submit must stream
    // Overloaded through its ticket immediately
    for _ in 0..4 {
        let t = server.submit(probe.clone(), &b"xabbcx"[..]);
        match t.wait_timeout(Duration::from_secs(30)) {
            Ok(res) => match res {
                Err(ServeError::Overloaded { depth, max_queue }) => {
                    assert_eq!(max_queue, 4);
                    assert_eq!(depth, 4);
                }
                other => panic!(
                    "expected Overloaded, got {:?}",
                    other.map(|o| o.accepted)
                ),
            },
            Err(_) => panic!("rejected ticket never resolved"),
        }
    }
    assert!(wedge_ticket.wait().is_ok());
    for t in accepted {
        assert!(t.wait().unwrap().accepted);
    }
    let stats = server.shutdown();
    assert_eq!(stats.rejected, 4);
    assert_eq!(stats.submitted, 5);
    assert_eq!(stats.served, 5);
    assert!(
        stats.max_queue_depth <= 4,
        "Reject admission let the depth reach {}",
        stats.max_queue_depth
    );
}

#[test]
fn block_admission_bounds_depth_and_unblocks_on_drain() {
    let server = Server::start(ServeConfig {
        max_queue: 2,
        admission: Admission::Block,
        ..bounded_config(1)
    })
    .unwrap();
    let pattern = Pattern::Regex("ab".to_string());
    let tickets: Vec<_> = (0..64)
        .map(|k| server.submit(pattern.clone(), vec![b'a'; 1 + k % 7]))
        .collect();
    for t in tickets {
        assert!(t.wait().is_ok());
    }
    let stats = server.shutdown();
    assert_eq!(stats.submitted, 64);
    assert_eq!(stats.served, 64);
    assert_eq!(stats.rejected, 0);
    assert!(
        stats.max_queue_depth <= 2,
        "Block admission let the depth reach {}",
        stats.max_queue_depth
    );
}

#[test]
fn probe_flood_cannot_starve_a_queued_scan() {
    let server = Server::start(ServeConfig {
        max_queue: 8,
        admission: Admission::Block,
        max_batch: 4,
        age_limit: 2,
        ..bounded_config(1)
    })
    .unwrap();
    // generate the scan corpus BEFORE parking the worker: the wedge
    // window must not race millisecond-scale input generation
    let scan_input = InputGen::new(0x5CA9).ascii_text(4 << 20);
    let wedge_ticket = wedge(&server, 4 << 20);
    let scan_ticket =
        server.submit(Pattern::Regex("ZQZQZQ".to_string()), scan_input);
    let scan_resolved = AtomicBool::new(false);
    std::thread::scope(|scope| {
        let server = &server;
        let scan_resolved = &scan_resolved;
        let flooder = scope.spawn(move || {
            let probe = Pattern::Regex("ab+c".to_string());
            let mut sent = 0u64;
            while !scan_resolved.load(Ordering::Relaxed) {
                // Block admission paces the flood to the service rate,
                // so probes are always queued when the worker picks its
                // next batch — without aging the scan would never run
                drop(server.submit(probe.clone(), &b"xabbcx"[..]));
                sent += 1;
            }
            sent
        });
        match scan_ticket.wait_timeout(Duration::from_secs(60)) {
            Ok(res) => assert!(res.expect("scan serves").n > 0),
            Err(_) => panic!("a probe flood starved the queued scan"),
        }
        scan_resolved.store(true, Ordering::Relaxed);
        assert!(flooder.join().unwrap() > 0);
    });
    assert!(wedge_ticket.wait().is_ok());
    let stats = server.shutdown();
    assert_eq!(stats.scan_wait.taken, 2, "the wedge + the aged scan");
    assert!(stats.probe_wait.taken > 0);
}

#[test]
fn cross_pattern_probe_flood_cannot_starve_a_queued_scan() {
    let server = Server::start(ServeConfig {
        max_queue: 8,
        admission: Admission::Block,
        max_batch: 4,
        age_limit: 2,
        ..bounded_config(1)
    })
    .unwrap();
    let scan_input = InputGen::new(0xF00D).ascii_text(4 << 20);
    let wedge_ticket = wedge(&server, 4 << 20);
    let scan_ticket =
        server.submit(Pattern::Regex("ZQZQZQ".to_string()), scan_input);
    let scan_resolved = AtomicBool::new(false);
    std::thread::scope(|scope| {
        let server = &server;
        let scan_resolved = &scan_resolved;
        let flooder = scope.spawn(move || {
            // two patterns over ONE shared input: every probe batch
            // pulls the other pattern's queued probes into a fused
            // group, so each cycle serves TWO passes — the fused drain
            // must count against the aging bound like the batch it
            // rides behind, or the scan's starvation bound silently
            // stretches to 2 x age_limit
            let pats = [
                Pattern::Regex("ab+c".to_string()),
                Pattern::Regex("xa".to_string()),
            ];
            let mut sent = 0u64;
            while !scan_resolved.load(Ordering::Relaxed) {
                let p = pats[(sent % 2) as usize].clone();
                drop(server.submit(p, &b"xabbcx"[..]));
                sent += 1;
            }
            sent
        });
        match scan_ticket.wait_timeout(Duration::from_secs(60)) {
            Ok(res) => assert!(res.expect("scan serves").n > 0),
            Err(_) => {
                panic!("a cross-pattern probe flood starved the queued scan")
            }
        }
        scan_resolved.store(true, Ordering::Relaxed);
        assert!(flooder.join().unwrap() > 0);
    });
    assert!(wedge_ticket.wait().is_ok());
    let stats = server.shutdown();
    assert_eq!(stats.scan_wait.taken, 2, "the wedge + the aged scan");
    assert!(
        stats.fused_passes + stats.prefilter_clears > 0,
        "the flood must actually exercise cross-pattern fusing"
    );
}

#[test]
fn queued_probes_jump_a_queued_scan() {
    let server = Server::start(ServeConfig {
        max_batch: 1024,
        age_limit: 1000,
        ..bounded_config(1)
    })
    .unwrap();
    // generate every input BEFORE parking the worker: submissions in
    // the wedge window must be microsecond-scale lock operations, not
    // millisecond-scale corpus generation
    let scan_input = InputGen::new(0x77).ascii_text(4 << 20);
    let probe = Pattern::Regex("ab+c".to_string());
    let inputs: Vec<Vec<u8>> = (0..500)
        .map(|k| {
            let mut v = vec![b'x'; 8 + (k % 11)];
            if k % 2 == 0 {
                v.extend_from_slice(b"abbc");
            }
            v
        })
        .collect();
    let refs: Vec<&[u8]> = inputs.iter().map(|v| v.as_slice()).collect();
    let wedge_ticket = wedge(&server, 4 << 20);
    // the scan is submitted BEFORE any probe...
    let scan_ticket =
        server.submit(Pattern::Regex("ZQZQZQ".to_string()), scan_input);
    let tickets = server.submit_many(&probe, &refs);
    for (k, t) in tickets.into_iter().enumerate() {
        assert_eq!(t.wait().unwrap().accepted, k % 2 == 0, "probe {k}");
    }
    assert!(scan_ticket.wait().is_ok());
    assert!(wedge_ticket.wait().is_ok());
    let stats = server.shutdown();
    assert_eq!(stats.probe_wait.taken, 500);
    assert_eq!(stats.scan_wait.taken, 2);
    // ...yet all 500 probes were taken in one batch before it: the
    // scan's take-time wait must dominate every probe's
    assert!(
        stats.scan_wait.max_us > stats.probe_wait.max_us,
        "scan max wait {} us <= probe max wait {} us",
        stats.scan_wait.max_us,
        stats.probe_wait.max_us
    );
}
