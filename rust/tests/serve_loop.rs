//! Integration tests for `engine::serve`: the acceptance criteria of the
//! async serving subsystem.
//!
//!  (a) same-pattern coalescing: compile count < request count, and the
//!      cache-hit counter proves repeated batches reused the entry;
//!  (b) capacity calibration: after startup profiling the Auto
//!      thresholds differ from the baked-in ballpark;
//!  (c) streamed outcomes are identical to the synchronous
//!      `match_many` results on the same corpus;
//!  plus a many-producer concurrency test asserting per-producer
//!  outcome order.

use specdfa::engine::{
    CompiledMatcher, Engine, ExecPolicy, Pattern, ServeConfig, Server,
};
use specdfa::engine::select::AutoThresholds;
use specdfa::workload::InputGen;

fn test_config(workers: usize) -> ServeConfig {
    ServeConfig {
        workers,
        profile_runs: 2,
        profile_sample_syms: 1 << 14,
        recalibrate_every: 0, // deterministic compile counts
        ..ServeConfig::default()
    }
}

#[test]
fn coalescing_calibration_and_match_many_equivalence() {
    let pattern = Pattern::Regex("(ab|cd)+e?".to_string());
    let mut gen = InputGen::new(0x5EE5);
    let inputs: Vec<Vec<u8>> = (0..64)
        .map(|k| {
            let mut text = gen.ascii_text(200 + 37 * k);
            if k % 2 == 0 {
                gen.plant(&mut text, b"abcde", 1);
            }
            text
        })
        .collect();
    let refs: Vec<&[u8]> = inputs.iter().map(|v| v.as_slice()).collect();

    let server = Server::start(test_config(3)).unwrap();

    // (b) calibrated thresholds differ from the default ballpark
    let thresholds = server.thresholds();
    assert!(thresholds.is_calibrated(), "startup profiling must run");
    assert_ne!(
        thresholds,
        AutoThresholds::default(),
        "calibrated thresholds must differ from the baked-in ballpark"
    );

    // submit the whole corpus under one queue lock: a worker must take
    // it as few coalesced batches, not 64 wake-ups
    let tickets = server.submit_many(&pattern, &refs);
    let streamed: Vec<_> = tickets
        .into_iter()
        .map(|t| t.wait().expect("request must serve"))
        .collect();

    // (c) streamed outcomes equal the synchronous match_many results
    let direct = CompiledMatcher::compile(
        &pattern,
        Engine::Auto,
        ExecPolicy::default(),
    )
    .unwrap()
    .match_many(&refs);
    assert_eq!(direct.error_count(), 0);
    assert_eq!(streamed.len(), direct.outcomes.len());
    for (i, (got, want)) in
        streamed.iter().zip(direct.ok_outcomes()).enumerate()
    {
        assert_eq!(got.accepted, want.accepted, "request {i}");
        assert_eq!(got.final_state, want.final_state, "request {i}");
        assert_eq!(got.n, want.n, "request {i}");
    }

    let stats = server.shutdown();
    // (a) same-pattern coalescing: one compile served all 64 requests
    assert_eq!(stats.submitted, 64);
    assert_eq!(stats.served, 64);
    assert!(
        stats.compiles < stats.served,
        "coalescing failed: {} compiles for {} requests",
        stats.compiles,
        stats.served
    );
    assert!(
        stats.batches < stats.submitted,
        "requests must batch: {} batches for {} requests",
        stats.batches,
        stats.submitted
    );
    assert!(stats.coalesced > 0);
    assert!(stats.requests_per_batch() > 1.0);
    assert!(stats.thresholds.is_calibrated());
}

#[test]
fn many_producers_keep_per_producer_order_and_hit_the_cache() {
    const PRODUCERS: usize = 4;
    const PER_PRODUCER: usize = 25;
    let patterns = [
        Pattern::Regex("(ab|cd)+e?".to_string()),
        Pattern::Regex("needle".to_string()),
    ];
    let server = Server::start(test_config(2)).unwrap();

    let results: Vec<Vec<(usize, bool, Option<u32>)>> =
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for p in 0..PRODUCERS {
                let server = &server;
                let patterns = &patterns;
                handles.push(scope.spawn(move || {
                    let mut gen = InputGen::new(p as u64 + 1);
                    // interleave the two patterns request-by-request
                    let submissions: Vec<_> = (0..PER_PRODUCER)
                        .map(|k| {
                            let mut text = gen.ascii_text(64 + 13 * k);
                            if k % 3 == 0 {
                                gen.plant(&mut text, b"needle", 1);
                                gen.plant(&mut text, b"abcd", 1);
                            }
                            let pat = patterns[k % 2].clone();
                            let ticket = server.submit(pat, text.clone());
                            (k, text, ticket)
                        })
                        .collect();
                    // wait in submission order: the k-th ticket must
                    // stream the k-th request's outcome
                    submissions
                        .into_iter()
                        .map(|(k, text, ticket)| {
                            let out = ticket.wait().expect("serve ok");
                            assert_eq!(
                                out.n,
                                text.len(),
                                "producer {p} request {k}: ticket \
                                 streamed a different request's outcome"
                            );
                            (k, out.accepted, out.final_state)
                        })
                        .collect::<Vec<_>>()
                }));
            }
            handles
                .into_iter()
                .map(|h| h.join().expect("producer panicked"))
                .collect()
        });

    // byte-identical to direct match_many on each producer's corpus
    let matchers: Vec<CompiledMatcher> = patterns
        .iter()
        .map(|p| {
            CompiledMatcher::compile(p, Engine::Auto, ExecPolicy::default())
                .unwrap()
        })
        .collect();
    for (p, outcomes) in results.iter().enumerate() {
        let mut gen = InputGen::new(p as u64 + 1);
        for &(k, accepted, final_state) in outcomes {
            let mut text = gen.ascii_text(64 + 13 * k);
            if k % 3 == 0 {
                gen.plant(&mut text, b"needle", 1);
                gen.plant(&mut text, b"abcd", 1);
            }
            let direct = matchers[k % 2].match_many(&[text.as_slice()]);
            let want = direct.ok_outcomes().next().expect("one outcome");
            assert_eq!(accepted, want.accepted, "producer {p} request {k}");
            assert_eq!(
                final_state, want.final_state,
                "producer {p} request {k}"
            );
        }
    }

    let stats = server.shutdown();
    let total = (PRODUCERS * PER_PRODUCER) as u64;
    assert_eq!(stats.submitted, total);
    assert_eq!(stats.served, total);
    assert_eq!(stats.failed, 0);
    // two patterns, one compile each: everything else came from the cache
    assert!(
        stats.compiles < total,
        "{} compiles for {} requests",
        stats.compiles,
        stats.served
    );
    assert!(
        stats.cache_hits > 0,
        "repeated patterns must hit the compiled-pattern cache"
    );
    assert!(
        stats.cached_patterns <= 2,
        "only two distinct patterns were ever submitted"
    );
}

#[test]
fn outcome_cache_memoizes_repeated_probes() {
    let server = Server::start(test_config(1)).unwrap();
    let pattern = Pattern::Regex("ab+c".to_string());
    let first = server
        .submit(pattern.clone(), &b"xxabbczz"[..])
        .wait()
        .unwrap();
    assert!(first.accepted);
    // the identical probe again: must be a memo hit with the same verdict
    let second = server
        .submit(pattern.clone(), &b"xxabbczz"[..])
        .wait()
        .unwrap();
    assert_eq!(second.accepted, first.accepted);
    assert_eq!(second.final_state, first.final_state);
    assert_eq!(second.n, first.n);
    // a different input must NOT hit
    let other =
        server.submit(pattern, &b"nothing here"[..]).wait().unwrap();
    assert!(!other.accepted);
    let stats = server.shutdown();
    assert_eq!(stats.served, 3);
    assert_eq!(stats.outcome_hits, 1, "exactly the repeated probe hits");
    assert_eq!(stats.cached_outcomes, 2);
}

#[test]
fn outcome_cache_can_be_disabled() {
    let server = Server::start(ServeConfig {
        cache_outcomes: 0,
        ..test_config(1)
    })
    .unwrap();
    let pattern = Pattern::Regex("ab".to_string());
    for _ in 0..3 {
        assert!(server
            .submit(pattern.clone(), &b"ab"[..])
            .wait()
            .unwrap()
            .accepted);
    }
    let stats = server.shutdown();
    assert_eq!(stats.served, 3);
    assert_eq!(stats.outcome_hits, 0);
    assert_eq!(stats.cached_outcomes, 0);
}

#[test]
fn racing_workers_compile_a_new_pattern_once() {
    // many workers, many concurrent submissions of one brand-new
    // pattern: the in-flight marker must dedupe the compile without
    // convoying the other workers
    let server = Server::start(test_config(4)).unwrap();
    let pattern = Pattern::Regex("(ab|cd)+ef".to_string());
    let results: Vec<bool> = std::thread::scope(|scope| {
        (0..16)
            .map(|k| {
                let server = &server;
                let pattern = pattern.clone();
                scope.spawn(move || {
                    let input = if k % 2 == 0 {
                        &b"xxabcdefzz"[..]
                    } else {
                        &b"no match"[..]
                    };
                    server.submit(pattern, input).wait().unwrap().accepted
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    });
    for (k, accepted) in results.iter().enumerate() {
        assert_eq!(*accepted, k % 2 == 0, "request {k}");
    }
    let stats = server.shutdown();
    assert_eq!(stats.served, 16);
    assert_eq!(
        stats.compiles, 1,
        "racing workers must not duplicate the compile"
    );
}

#[test]
fn recalibration_interval_reprofiles_and_bumps_epoch() {
    let server = Server::start(ServeConfig {
        workers: 2,
        profile_runs: 1,
        profile_sample_syms: 1 << 13,
        recalibrate_every: 10,
        ..ServeConfig::default()
    })
    .unwrap();
    let pattern = Pattern::Regex("ab".to_string());
    let inputs: Vec<&[u8]> = vec![b"ab and more"; 35];
    for t in server.submit_many(&pattern, &inputs) {
        assert!(t.wait().unwrap().accepted);
    }
    let stats = server.shutdown();
    assert_eq!(stats.served, 35);
    // startup + one per 10 served requests (3 crossings in 35)
    assert_eq!(
        stats.recalibrations,
        1 + 35 / 10,
        "periodic re-profiling must fire on the request interval"
    );
    assert!(stats.thresholds.is_calibrated());
}
