//! Edge-case coverage for degenerate partitions: empty input, inputs
//! shorter than the processor count, lengths exactly at / off-by-one
//! around SIMD lane and window multiples, and single-state /
//! all-accepting DFAs — the places a chunked matcher silently breaks
//! while looking fine on average-sized inputs.

use specdfa::engine::{
    CompiledMatcher, Engine, ExecPolicy, Matcher, Pattern,
};
use specdfa::workload::InputGen;

fn policy(processors: usize) -> ExecPolicy {
    ExecPolicy { processors, lookahead: 2, ..ExecPolicy::default() }
}

/// Every DFA-table engine (the ones that report a final state).
fn dfa_engines() -> Vec<Engine> {
    vec![
        Engine::Sequential,
        Engine::Speculative { adaptive: false },
        Engine::Speculative { adaptive: true },
        Engine::Simd { variant: None },
        Engine::Cloud { nodes: 3 },
        Engine::Shard { nodes: 3 },
        Engine::HolubStekr,
    ]
}

fn assert_agree(pattern: &Pattern, pol: &ExecPolicy, input: &[u8]) {
    let want = CompiledMatcher::compile(pattern, Engine::Sequential, pol.clone())
        .unwrap()
        .run_bytes(input)
        .unwrap();
    for engine in dfa_engines() {
        let cm = CompiledMatcher::compile(pattern, engine.clone(), pol.clone())
            .unwrap();
        let out = cm.run_bytes(input).unwrap();
        assert_eq!(
            out.accepted,
            want.accepted,
            "{engine:?} n={}",
            input.len()
        );
        assert_eq!(
            out.final_state,
            want.final_state,
            "{engine:?} n={}",
            input.len()
        );
    }
}

#[test]
fn empty_input_every_engine() {
    for pat in ["(ab|cd)+e?", "a*", "needle"] {
        let pattern = Pattern::Regex(pat.to_string());
        assert_agree(&pattern, &policy(4), b"");
    }
}

#[test]
fn inputs_shorter_than_processor_count() {
    // 8 processors, inputs of 0..8 symbols: most chunks are empty, and
    // the partitioner must not emit out-of-range offsets
    let pattern = Pattern::Regex("ab".to_string());
    let pol = policy(8);
    for n in 0..8usize {
        let texts: [&[u8]; 2] = [&b"abababab"[..n], &b"xxxxxxxx"[..n]];
        for text in texts {
            assert_agree(&pattern, &pol, text);
        }
    }
}

#[test]
fn lane_width_and_window_multiples() {
    // the emulated vector unit runs 8 lanes with a 4096-symbol window:
    // sweep lengths exactly at and off-by-one around both
    let pattern = Pattern::Regex("needle".to_string());
    let pol = policy(4);
    let mut gen = InputGen::new(0x51D3);
    for n in [7usize, 8, 9, 15, 16, 17, 63, 64, 65, 4095, 4096, 4097] {
        let mut text = gen.ascii_text(n);
        assert_agree(&pattern, &pol, &text);
        if n >= 6 {
            // plant the needle across the midpoint, then at the tail
            gen.plant(&mut text, b"needle", 1);
            assert_agree(&pattern, &pol, &text);
            let pos = n - 6;
            text[pos..].copy_from_slice(b"needle");
            assert_agree(&pattern, &pol, &text);
        }
    }
}

#[test]
fn single_state_all_accepting_dfa() {
    // one state, two symbols, accepting: every input (including empty)
    // is a member and the final state is always 0
    let grail = "(START) |- 0\n0 0 0\n0 1 0\n0 -| (FINAL)\n";
    let pattern = Pattern::Grail(grail.to_string());
    for engine in dfa_engines() {
        let cm =
            CompiledMatcher::compile(&pattern, engine.clone(), policy(4))
                .unwrap();
        for syms in [vec![], vec![0], vec![1, 0, 1, 0, 1]] {
            let out = cm.run_syms(&syms).unwrap();
            assert!(out.accepted, "{engine:?} {syms:?}");
            assert_eq!(out.final_state, Some(0), "{engine:?} {syms:?}");
        }
    }
}

#[test]
fn single_state_all_rejecting_dfa() {
    // same shape without the FINAL marker: nothing is ever a member
    let grail = "(START) |- 0\n0 0 0\n0 1 0\n";
    let pattern = Pattern::Grail(grail.to_string());
    for engine in dfa_engines() {
        let cm =
            CompiledMatcher::compile(&pattern, engine.clone(), policy(4))
                .unwrap();
        for syms in [vec![], vec![0, 1, 1, 0]] {
            let out = cm.run_syms(&syms).unwrap();
            assert!(!out.accepted, "{engine:?} {syms:?}");
            assert_eq!(out.final_state, Some(0), "{engine:?} {syms:?}");
        }
    }
}

#[test]
fn exact_star_language_boundary_lengths() {
    // whole-input semantics for a* — accepts exactly the all-'a' strings,
    // at lengths around the lane width
    let pattern = Pattern::RegexExact("a*".to_string());
    let pol = policy(3);
    for n in [0usize, 1, 7, 8, 9, 64, 257] {
        let all_a = vec![b'a'; n];
        assert_agree(&pattern, &pol, &all_a);
        if n > 0 {
            let mut broken = all_a.clone();
            broken[n / 2] = b'b';
            assert_agree(&pattern, &pol, &broken);
        }
    }
}
