//! Adversarial stress suite: the `util::workload` generators driven
//! against every layer that claims a bound.
//!
//!  * **Differential**: every engine must agree with
//!    `Engine::Sequential` on the pathological corpus — permutation
//!    automata (γ = 1, speculation's structural worst case),
//!    dense-frontier and sink-heavy automata, ReDoS regexes and
//!    anchored patterns.  Backtracking is allowed to answer with its
//!    fuel-budget error on the exponential cases; it is never allowed
//!    to hang or disagree.
//!  * **Serving bounds**: a bursty Zipfian heavy-tail trace replayed
//!    against a live [`Server`] must respect the PR 5 invariants —
//!    the measured starvation bound (`max_bypass_streak ≤ age_limit`
//!    without cross-pattern fusion), the queue-depth bound under
//!    `Admission::Block`, load-shedding accounting under
//!    `Admission::Reject`, and counter reconciliation after drain.
//!  * **Preempt/resume**: a long scan flooded by probes must park on
//!    its checkpoint and resume without changing its verdict.
//!  * **Cache churn**: Zipfian popularity over a pool larger than the
//!    pattern cache — the compile-cache hit rate must grow with skew,
//!    and the outcome memo must fire on repeated (pattern, input)
//!    pairs while epoch recalibration never serves a stale verdict.
//!
//! Every corpus derives from [`test_seed`]: a CI failure prints the
//! seed, and `SPECDFA_TEST_SEED=<value>` replays it exactly.

use specdfa::engine::{
    Admission, CompiledMatcher, Engine, ExecPolicy, Matcher, Pattern,
    PriorityPolicy, ServeConfig,
};
use specdfa::util::rng::{test_seed, Rng};
use specdfa::util::workload::{
    pathological_corpus, replay_trace, trace, AdversarialCase, TraceConfig,
};

/// Processor count for the multicore engines (chunk boundaries at
/// multiples of n/PROCS).
const PROCS: usize = 4;

fn policy() -> ExecPolicy {
    ExecPolicy {
        processors: PROCS,
        lookahead: 2,
        // bounded so exponential backtracking degrades into a skipped
        // comparison instead of a hung suite
        backtrack_fuel: 1 << 22,
        ..ExecPolicy::default()
    }
}

/// Engines comparable on AST-safe patterns (unanchored regex search).
fn engines() -> Vec<(&'static str, Engine)> {
    vec![
        ("seq", Engine::Sequential),
        ("spec", Engine::Speculative { adaptive: false }),
        ("spec-adaptive", Engine::Speculative { adaptive: true }),
        ("simd", Engine::Simd { variant: None }),
        ("cloud", Engine::Cloud { nodes: 3 }),
        ("shard", Engine::Shard { nodes: 3 }),
        ("holub", Engine::HolubStekr),
        ("backtrack", Engine::Backtracking),
        ("grep", Engine::GrepLike),
    ]
}

/// Engines comparable on raw automata and anchored patterns (the AST
/// comparators refuse those).
fn dfa_only_engines() -> Vec<(&'static str, Engine)> {
    engines()
        .into_iter()
        .filter(|(name, _)| *name != "backtrack" && *name != "grep")
        .collect()
}

/// Adversarial input lengths: empty, sub-chunk, chunk-boundary
/// straddling, and large enough that speculation actually partitions.
const LENGTHS: &[usize] = &[0, 1, 3, 4, 17, 256, 1024, 4096];

#[test]
fn pathological_corpus_engines_agree_with_sequential() {
    let seed = test_seed(0xADE5_2026);
    eprintln!(
        "adversarial corpus seed: {seed:#x} \
         (SPECDFA_TEST_SEED={seed:#x} replays this corpus exactly)"
    );
    let corpus = pathological_corpus(seed);
    let mut rng = Rng::new(seed ^ 1);
    for case in &corpus {
        let reference =
            CompiledMatcher::compile(&case.pattern, Engine::Sequential, policy())
                .unwrap_or_else(|e| panic!("{}: reference compile: {e:#}", case.name));
        let list =
            if case.ast_safe { engines() } else { dfa_only_engines() };
        let pool: Vec<(&'static str, CompiledMatcher)> = list
            .into_iter()
            .map(|(name, eng)| {
                    let m = CompiledMatcher::compile(&case.pattern, eng, policy())
                        .unwrap_or_else(|e| {
                            panic!("{}/{name}: compile: {e:#}", case.name)
                        });
                    (name, m)
                })
                .collect();
        let mut inputs: Vec<Vec<u8>> = Vec::new();
        for &n in LENGTHS {
            let mut input: Vec<u8> = (0..n)
                .map(|_| *rng.choose(&case.alphabet))
                .collect();
            inputs.push(input.clone());
            if let Some(w) = &case.witness {
                if w.len() <= n {
                    input[..w.len()].copy_from_slice(w);
                    inputs.push(input);
                }
            }
        }
        if case.ast_safe {
            // the pure-repetition prefix is the exponential
            // backtracking trigger: the budget must fire, not a hang
            inputs.push(vec![b'a'; 48]);
        }
        for input in &inputs {
            let expect = reference
                .run_bytes(input)
                .unwrap_or_else(|e| {
                    panic!("{}: sequential failed: {e:#}", case.name)
                })
                .accepted;
            for (name, matcher) in &pool {
                match matcher.run_bytes(input) {
                    Ok(out) => assert_eq!(
                        out.accepted, expect,
                        "{}/{name} disagrees with sequential on \
                         {}-byte input (seed {seed:#x})",
                        case.name,
                        input.len()
                    ),
                    // the only tolerated failure: an exhausted
                    // backtracking budget on a ReDoS case
                    Err(e) => assert!(
                        format!("{e:#}").contains("fuel"),
                        "{}/{name}: unexpected error: {e:#}",
                        case.name
                    ),
                }
            }
        }
    }
}

#[test]
fn bursty_zipfian_trace_respects_serving_bounds() {
    let seed = test_seed(0xB0B5_2026);
    eprintln!(
        "trace seed: {seed:#x} (SPECDFA_TEST_SEED={seed:#x} replays)"
    );
    let pool = pathological_corpus(seed);
    let probe_max = 1 << 10;
    let events = trace(
        &TraceConfig {
            requests: 300,
            pool: pool.len(),
            skew: 1.2,
            probe_max_bytes: probe_max,
            burst: 12,
            gap_us: 200,
        },
        seed ^ 2,
    );
    let age_limit = 3u64;
    let config = ServeConfig {
        workers: 3,
        max_queue: 24,
        admission: Admission::Block,
        priority: PriorityPolicy::SizeAware,
        probe_max_bytes: probe_max,
        age_limit,
        // fusion's drain credit would raise the streak ceiling to
        // age_limit + 1; keep the clean bound under test here
        fuse_cross_pattern: false,
        calibrate_on_start: false,
        policy: policy(),
        ..ServeConfig::default()
    };
    let report = replay_trace(config, &pool, &events, seed ^ 3, 0).unwrap();
    assert_eq!(report.mismatches, 0, "served verdict diverged (seed {seed:#x})");
    assert_eq!(report.errors, 0);
    assert_eq!(report.rejected, 0, "Block admission never rejects");
    let s = &report.stats;
    assert_eq!(s.submitted, 300);
    assert_eq!(s.served + s.failed, s.submitted, "drain lost a request");
    assert_eq!(s.failed, 0);
    assert!(
        s.max_queue_depth <= 24,
        "queue bound violated: depth {} > 24",
        s.max_queue_depth
    );
    assert!(
        s.max_bypass_streak <= age_limit,
        "starvation bound violated: a scan was bypassed {} consecutive \
         times with age_limit {age_limit} (seed {seed:#x})",
        s.max_bypass_streak
    );
    assert!(
        s.scan_bypasses >= s.max_bypass_streak,
        "total bypasses {} below the observed streak {}",
        s.scan_bypasses,
        s.max_bypass_streak
    );
}

#[test]
fn reject_admission_sheds_load_with_consistent_accounting() {
    let seed = test_seed(0x5EED_2026);
    eprintln!("trace seed: {seed:#x} (SPECDFA_TEST_SEED replays)");
    let pool = pathological_corpus(seed);
    let requests = 400;
    let events = trace(
        &TraceConfig {
            requests,
            pool: pool.len(),
            skew: 1.0,
            probe_max_bytes: 512,
            burst: 32,
            gap_us: 100,
        },
        seed ^ 2,
    );
    let config = ServeConfig {
        workers: 1,
        max_queue: 4,
        admission: Admission::Reject,
        priority: PriorityPolicy::SizeAware,
        probe_max_bytes: 512,
        age_limit: 2,
        calibrate_on_start: false,
        policy: policy(),
        ..ServeConfig::default()
    };
    // pace 0: flood — a single worker behind a depth-4 queue must shed
    let report = replay_trace(config, &pool, &events, seed ^ 3, 0).unwrap();
    assert_eq!(report.mismatches, 0);
    assert_eq!(report.errors, 0);
    assert!(
        report.rejected > 0,
        "depth-4 Reject queue under a {requests}-request flood shed nothing"
    );
    let s = &report.stats;
    assert_eq!(s.rejected as usize, report.rejected);
    assert_eq!(
        s.submitted as usize + report.rejected,
        requests,
        "every request is admitted or rejected, never both"
    );
    assert_eq!(s.served + s.failed, s.submitted);
    assert!(s.max_queue_depth <= 4, "depth {}", s.max_queue_depth);
}

#[test]
fn preempted_scans_resume_with_correct_verdicts_under_flood() {
    use specdfa::util::workload::TraceEvent;
    let seed = test_seed(0xF10D_2026);
    eprintln!("flood seed: {seed:#x} (SPECDFA_TEST_SEED replays)");
    let pool = pathological_corpus(seed);
    // hand-crafted flood: one huge scan first, then a probe storm, so
    // the single worker is mid-scan while probes queue behind it
    let scan_idx = pool
        .iter()
        .position(|c| c.name.starts_with("sink"))
        .expect("corpus always carries a sink-heavy case");
    let mut events = vec![TraceEvent {
        at_us: 0,
        pattern: scan_idx,
        len: 1 << 18,
    }];
    for i in 0..300 {
        events.push(TraceEvent { at_us: 0, pattern: i, len: 64 });
    }
    let config = ServeConfig {
        workers: 1,
        max_queue: 0,
        admission: Admission::Block,
        priority: PriorityPolicy::SizeAware,
        probe_max_bytes: 1 << 12,
        age_limit: 4,
        preempt_scans: true,
        preempt_segment_bytes: 1 << 13,
        calibrate_on_start: false,
        policy: policy(),
        ..ServeConfig::default()
    };
    let report = replay_trace(config, &pool, &events, seed ^ 3, 0).unwrap();
    assert_eq!(report.mismatches, 0, "a resumed scan changed its verdict");
    assert_eq!(report.errors, 0);
    let s = &report.stats;
    assert_eq!(s.served, 301);
    assert!(
        s.preemptions >= 1,
        "a 256 KiB scan behind a 300-probe flood never parked \
         (preempt_segment_bytes 8 KiB)"
    );
    assert!(
        s.resumed_scans >= 1,
        "parked scans were never picked back up"
    );
}

/// Pool of cheap distinct literal patterns for the cache-churn tests —
/// popularity is the variable under test, pattern cost is not.
fn literal_pool(k: usize) -> Vec<AdversarialCase> {
    (0..k)
        .map(|i| AdversarialCase {
            name: format!("lit-{i}"),
            pattern: Pattern::Regex(format!("x{i}y")),
            // single-symbol alphabet: inputs of equal length are
            // *identical*, so the outcome memo sees repeats
            alphabet: b"a".to_vec(),
            witness: None,
            ast_safe: true,
        })
        .collect()
}

#[test]
fn zipfian_churn_hit_rate_grows_with_skew_and_memo_fires() {
    let seed = test_seed(0xCAC4_2026);
    eprintln!("churn seed: {seed:#x} (SPECDFA_TEST_SEED replays)");
    let pool = literal_pool(32);
    let mut run = |skew: f64| {
        let events = trace(
            &TraceConfig {
                requests: 500,
                pool: pool.len(),
                skew,
                probe_max_bytes: 512,
                burst: 8,
                gap_us: 100,
            },
            seed ^ 2,
        );
        let config = ServeConfig {
            workers: 2,
            // cache far smaller than the pool: the tail must churn
            cache_patterns: 8,
            cache_outcomes: 256,
            max_queue: 64,
            admission: Admission::Block,
            probe_max_bytes: 512,
            calibrate_on_start: false,
            policy: policy(),
            ..ServeConfig::default()
        };
        let report = replay_trace(config, &pool, &events, seed ^ 3, 0).unwrap();
        assert_eq!(report.mismatches, 0, "stale verdict at skew {skew}");
        assert_eq!(report.errors, 0);
        let s = report.stats;
        let hit = s.cache_hits as f64 / (s.cache_hits + s.compiles).max(1) as f64;
        (hit, s.outcome_hits, s.evictions)
    };
    let (uniform_hit, _, uniform_evictions) = run(0.0);
    let (mild_hit, _, _) = run(0.8);
    let (steep_hit, steep_memo, _) = run(1.6);
    assert!(
        uniform_evictions > 0,
        "a 32-pattern pool over an 8-entry cache must evict"
    );
    assert!(
        steep_hit > uniform_hit,
        "compile-cache hit rate should grow with skew: \
         uniform {uniform_hit:.3} vs steep {steep_hit:.3} (seed {seed:#x})"
    );
    assert!(
        steep_hit >= mild_hit * 0.9,
        "steep skew {steep_hit:.3} collapsed below mild {mild_hit:.3}"
    );
    assert!(
        steep_memo > 0,
        "identical repeated inputs never hit the outcome memo"
    );
}

#[test]
fn epoch_recalibration_never_serves_stale_verdicts() {
    let seed = test_seed(0xE0C4_2026);
    eprintln!("epoch seed: {seed:#x} (SPECDFA_TEST_SEED replays)");
    let pool = literal_pool(6);
    let events = trace(
        &TraceConfig {
            requests: 200,
            pool: pool.len(),
            skew: 0.9,
            probe_max_bytes: 512,
            burst: 8,
            gap_us: 100,
        },
        seed ^ 2,
    );
    let config = ServeConfig {
        workers: 2,
        // recalibrate every handful of requests: verdicts must be
        // epoch-stable even while thresholds churn underneath
        recalibrate_every: 16,
        profile_runs: 1,
        profile_sample_syms: 1 << 10,
        max_queue: 32,
        admission: Admission::Block,
        probe_max_bytes: 512,
        calibrate_on_start: true,
        policy: policy(),
        ..ServeConfig::default()
    };
    let report = replay_trace(config, &pool, &events, seed ^ 3, 0).unwrap();
    assert_eq!(
        report.mismatches, 0,
        "recalibration churn produced a stale verdict (seed {seed:#x})"
    );
    assert_eq!(report.errors, 0);
    let s = &report.stats;
    assert!(
        s.recalibrations >= 2,
        "recalibrate_every=16 over 200 requests recalibrated only {} times",
        s.recalibrations
    );
    assert_eq!(s.served + s.failed, s.submitted);
}

/// Soak variant of the serving-bounds test: an order of magnitude more
/// load. `cargo test --release --test adversarial -- --ignored` runs it.
#[test]
#[ignore = "soak: ~10x the quick trace; run with -- --ignored"]
fn soak_bursty_trace_bounds_hold_at_scale() {
    let seed = test_seed(0x50AC_2026);
    eprintln!("soak seed: {seed:#x} (SPECDFA_TEST_SEED replays)");
    let pool = pathological_corpus(seed);
    let probe_max = 1 << 10;
    let events = trace(
        &TraceConfig {
            requests: 4000,
            pool: pool.len(),
            skew: 1.1,
            probe_max_bytes: probe_max,
            burst: 24,
            gap_us: 150,
        },
        seed ^ 2,
    );
    let age_limit = 4u64;
    let config = ServeConfig {
        workers: 4,
        max_queue: 64,
        admission: Admission::Block,
        priority: PriorityPolicy::SizeAware,
        probe_max_bytes: probe_max,
        age_limit,
        fuse_cross_pattern: false,
        preempt_scans: true,
        preempt_segment_bytes: 1 << 13,
        calibrate_on_start: false,
        policy: policy(),
        ..ServeConfig::default()
    };
    let report = replay_trace(config, &pool, &events, seed ^ 3, 1000).unwrap();
    assert_eq!(report.mismatches, 0);
    assert_eq!(report.errors, 0);
    let s = &report.stats;
    assert_eq!(s.served + s.failed + s.rejected, 4000);
    assert!(s.max_queue_depth <= 64);
    assert!(
        s.max_bypass_streak <= age_limit,
        "soak starvation bound violated: streak {} (seed {seed:#x})",
        s.max_bypass_streak
    );
}
