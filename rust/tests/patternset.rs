//! Differential test suite for `engine::patternset`: the fused
//! multi-pattern matcher must be **observationally identical** to k
//! independent `Engine::Sequential` runs — per-pattern `accepted`
//! always, per-pattern `final_state` whenever the set matcher reports
//! one (prefilter-cleared slots report `None`) — across:
//!
//!  * overlapping patterns (shared prefixes/infixes, one input);
//!  * duplicate patterns (one compile, one shared verdict);
//!  * the empty set;
//!  * budget spills (`state_budget` from 1 to unbounded);
//!  * prefilter on and off;
//!  * speculative chunk boundaries (witnesses planted at the P-way
//!    split points of the multicore engine);
//!
//! plus the serve-loop acceptance criterion: N different-pattern
//! requests over one shared input complete with `fused_passes == 1`,
//! and the memo-poisoning regression: a prefilter-cleared slot's
//! synthesized verdict must never enter the outcome memo.

use std::time::{Duration, Instant};

use specdfa::engine::{
    CompiledMatcher, CompiledSetMatcher, Engine, ExecPolicy, Pattern,
    PatternSet, ServeConfig, Server, SetConfig, SetTier,
};
use specdfa::util::prop;
use specdfa::util::rng::Rng;

/// The symbols patterns are built from.
const ALPHABET: &[u8] = b"abcd";
/// Input filler: the pattern alphabet plus bytes outside it.
const FILLER: &[u8] = b"abcdex .";

/// One random pattern together with a witness string from its language.
fn gen_pattern(rng: &mut Rng) -> (String, Vec<u8>) {
    let lit = |rng: &mut Rng, len: usize| -> (String, Vec<u8>) {
        let mut p = String::new();
        let mut w = Vec::new();
        for _ in 0..len.max(1) {
            let c = ALPHABET[rng.usize_below(ALPHABET.len())];
            p.push(c as char);
            w.push(c);
        }
        (p, w)
    };
    match rng.usize_below(4) {
        // plain literal: the prefilter tier's best case
        0 => lit(rng, 2 + rng.usize_below(3)),
        // alternation of literals
        1 => {
            let (a, wa) = lit(rng, 1 + rng.usize_below(3));
            let (b, _) = lit(rng, 1 + rng.usize_below(3));
            (format!("({a}|{b})"), wa)
        }
        // literal-class-literal: still has a required literal when the
        // flanks are long enough, otherwise exercises the no-literal path
        2 => {
            let (a, mut w) = lit(rng, 1 + rng.usize_below(2));
            let (b, wb) = lit(rng, 1 + rng.usize_below(2));
            let cls = ALPHABET[rng.usize_below(ALPHABET.len())];
            w.push(cls);
            w.extend(&wb);
            (format!("{a}[{}{}]{b}", cls as char, 'e'), w)
        }
        // plus-repetition over a literal base
        _ => {
            let (a, wa) = lit(rng, 1 + rng.usize_below(2));
            let (b, wb) = lit(rng, 2);
            let mut w = wa.clone();
            w.extend(&wb);
            (format!("({a})+{b}"), w)
        }
    }
}

fn gen_text(rng: &mut Rng, len: usize) -> Vec<u8> {
    (0..len)
        .map(|_| FILLER[rng.usize_below(FILLER.len())])
        .collect()
}

fn plant(text: &mut [u8], witness: &[u8], pos: usize) {
    if witness.is_empty() || witness.len() > text.len() {
        return;
    }
    let pos = pos.min(text.len() - witness.len());
    text[pos..pos + witness.len()].copy_from_slice(witness);
}

/// Compare one compiled set against k independent sequential runs on
/// `input`.  `accepted` must agree on every slot; `final_state` must
/// agree whenever the set matcher reports one.
fn assert_set_matches_sequential(
    csm: &CompiledSetMatcher,
    patterns: &[Pattern],
    input: &[u8],
    label: &str,
) {
    let out = csm.run_bytes(input).expect("set run");
    assert_eq!(out.outcomes.len(), patterns.len(), "{label}: slot count");
    assert_eq!(out.tiers.len(), patterns.len(), "{label}: tier count");
    for (slot, pattern) in patterns.iter().enumerate() {
        let solo = CompiledMatcher::compile(
            pattern,
            Engine::Sequential,
            ExecPolicy::default(),
        )
        .expect("solo compile")
        .run_bytes(input)
        .expect("solo run");
        let got = &out.outcomes[slot];
        assert_eq!(
            got.accepted, solo.accepted,
            "{label}: slot {slot} ({pattern:?}) disagrees on acceptance \
             (tier {:?}, n={})",
            out.tiers[slot],
            input.len()
        );
        if let (Some(g), Some(w)) = (got.final_state, solo.final_state) {
            assert_eq!(
                g, w,
                "{label}: slot {slot} ({pattern:?}) disagrees on final \
                 state (tier {:?})",
                out.tiers[slot]
            );
        }
        // a prefilter clear must never clear an accepting pattern
        if out.tiers[slot] == SetTier::PrefilterCleared {
            assert!(
                !solo.accepted,
                "{label}: slot {slot} cleared but sequential accepts"
            );
        }
    }
}

#[test]
fn random_sets_match_k_sequential_runs() {
    prop::check("set == k sequential runs", 60, |rng| {
        let k = 1 + rng.usize_below(4);
        let mut patterns = Vec::new();
        let mut witnesses = Vec::new();
        for _ in 0..k {
            let (p, w) = gen_pattern(rng);
            patterns.push(Pattern::Regex(p));
            witnesses.push(w);
        }
        // sometimes duplicate a slot to exercise the dedupe path
        if k > 1 && rng.chance(0.3) {
            let dup = rng.usize_below(patterns.len());
            patterns.push(patterns[dup].clone());
            witnesses.push(witnesses[dup].clone());
        }
        let n = 200 + rng.usize_below(1800);
        let mut input = gen_text(rng, n);
        // plant a random subset of witnesses, some at chunk boundaries
        for w in &witnesses {
            if rng.chance(0.5) {
                let pos = if rng.chance(0.5) {
                    rng.usize_below(n)
                } else {
                    // the 4-way split points of the speculative engine
                    (n / 4) * (1 + rng.usize_below(3))
                };
                plant(&mut input, w, pos);
            }
        }
        let config = SetConfig {
            engine: if rng.chance(0.5) {
                Engine::Sequential
            } else {
                Engine::speculative()
            },
            policy: ExecPolicy {
                processors: 4,
                lookahead: 2,
                ..ExecPolicy::default()
            },
            state_budget: match rng.usize_below(3) {
                0 => 1,  // everything spills
                1 => 24, // partial spill on bigger sets
                _ => SetConfig::default().state_budget,
            },
            prefilter: rng.chance(0.7),
        };
        let set = PatternSet::from_patterns(patterns.clone());
        let csm = CompiledSetMatcher::compile(&set, config)
            .expect("set compile");
        assert_set_matches_sequential(&csm, &patterns, &input, "random");
    });
}

#[test]
fn overlapping_patterns_share_one_pass() {
    // shared prefixes and infixes: the product DFA must keep the
    // component verdicts independent
    let patterns: Vec<Pattern> = ["ab+", "ab+c", "(ab|cd)+", "bc"]
        .iter()
        .map(|p| Pattern::Regex(p.to_string()))
        .collect();
    let set = PatternSet::from_patterns(patterns.clone());
    let csm = CompiledSetMatcher::compile(&set, SetConfig::default())
        .expect("set compile");
    for input in [
        &b"xxabbbcyy"[..],
        b"abcdabcd",
        b"no hits here",
        b"ab",
        b"",
        b"cdcdcdab",
    ] {
        assert_set_matches_sequential(&csm, &patterns, input, "overlap");
    }
}

#[test]
fn duplicate_patterns_compile_once_and_share_the_verdict() {
    let patterns: Vec<Pattern> = ["ab+", "cd", "ab+", "cd", "ab+"]
        .iter()
        .map(|p| Pattern::Regex(p.to_string()))
        .collect();
    let set = PatternSet::from_patterns(patterns.clone());
    let csm = CompiledSetMatcher::compile(&set, SetConfig::default())
        .expect("set compile");
    assert_eq!(csm.unique_patterns(), 2, "dedupe must collapse to 2");
    let out = csm.run_bytes(b"xxabbyy").expect("set run");
    assert_eq!(out.accepted(), vec![true, false, true, false, true]);
    for dup in [2usize, 4] {
        assert_eq!(out.outcomes[dup].final_state, out.outcomes[0].final_state);
        assert_eq!(out.tiers[dup], out.tiers[0]);
    }
    assert_set_matches_sequential(&csm, &patterns, b"xxabbyy", "dup");
    assert_set_matches_sequential(&csm, &patterns, b"cd and ab", "dup");
}

#[test]
fn empty_set_yields_empty_outcome() {
    let csm = CompiledSetMatcher::compile(
        &PatternSet::new(),
        SetConfig::default(),
    )
    .expect("empty set compiles");
    let out = csm.run_bytes(b"anything").expect("empty set runs");
    assert!(out.outcomes.is_empty());
    assert!(out.tiers.is_empty());
    assert!(out.fused_pass.is_none());
    assert_eq!(out.prefilter_cleared, 0);
    assert_eq!(csm.unique_patterns(), 0);
}

#[test]
fn budget_spill_tiers_stay_equivalent() {
    let patterns: Vec<Pattern> =
        ["(ab|cd)+e", "ab+c", "cdcd", "a[bc]d", "abcd"]
            .iter()
            .map(|p| Pattern::Regex(p.to_string()))
            .collect();
    let set = PatternSet::from_patterns(patterns.clone());
    let mut gen = Rng::new(0x5B1);
    let mut input = gen_text(&mut gen, 4096);
    plant(&mut input, b"ababcde", 100);
    plant(&mut input, b"cdcd", 2048);
    let mut spilled_at = Vec::new();
    for budget in [1usize, 8, 24, 64, 0 /* unbounded */] {
        let csm = CompiledSetMatcher::compile(
            &set,
            SetConfig { state_budget: budget, ..SetConfig::default() },
        )
        .expect("set compile never fails on size");
        spilled_at.push(csm.spilled_patterns());
        let label = format!("budget={budget}");
        assert_set_matches_sequential(&csm, &patterns, &input, &label);
        assert_set_matches_sequential(&csm, &patterns, b"", &label);
        assert_eq!(
            csm.fused_patterns() + csm.spilled_patterns(),
            csm.unique_patterns(),
            "{label}: every unique pattern lands in exactly one tier"
        );
    }
    // budget 1 spills everything; unbounded spills nothing
    assert_eq!(spilled_at[0], set.len(), "budget 1 must spill all");
    assert_eq!(*spilled_at.last().unwrap(), 0, "unbounded must fuse all");
}

#[test]
fn chunk_boundary_witnesses_survive_fused_speculation() {
    // witnesses planted exactly at the 4-way split points of the
    // speculative kernel, matched through the fused product DFA
    let patterns: Vec<Pattern> = ["abca", "bcab", "cabc"]
        .iter()
        .map(|p| Pattern::Regex(p.to_string()))
        .collect();
    let set = PatternSet::from_patterns(patterns.clone());
    let csm = CompiledSetMatcher::compile(
        &set,
        SetConfig {
            engine: Engine::speculative(),
            policy: ExecPolicy {
                processors: 4,
                lookahead: 2,
                ..ExecPolicy::default()
            },
            // no prefilter: force every verdict through the fused pass
            prefilter: false,
            ..SetConfig::default()
        },
    )
    .expect("set compile");
    assert_eq!(csm.fused_patterns(), 3);
    let n = 8192;
    let mut gen = Rng::new(0xB0B);
    for straddle in 0..3usize {
        let mut input = gen_text(&mut gen, n);
        // straddle the boundary: 2 bytes before, 2 after
        let pos = (n / 4) * (straddle + 1) - 2;
        plant(&mut input, b"abca", pos);
        let label = format!("straddle boundary {straddle}");
        assert_set_matches_sequential(&csm, &patterns, &input, &label);
        let out = csm.run_bytes(&input).expect("set run");
        assert!(out.outcomes[0].accepted, "{label}: witness lost");
        assert!(out.fused_pass.is_some(), "{label}: fused pass must run");
    }
}

/// Spin until `cond` holds (30 s hard cap).
fn wait_until(mut cond: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !cond() {
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "condition timed out"
        );
        std::thread::yield_now();
    }
}

#[test]
fn serve_coalesces_distinct_patterns_over_one_input_into_one_pass() {
    let server = Server::start(ServeConfig {
        workers: 1,
        calibrate_on_start: false,
        recalibrate_every: 0,
        cache_outcomes: 0,
        profile_per_worker: false,
        engine: Engine::Sequential,
        ..ServeConfig::default()
    })
    .expect("server");
    // park the only worker on a corpus scan (uppercase literal never
    // occurs in lowercase ascii_text) so the probes all queue up
    let scan = specdfa::workload::InputGen::new(0x3ED6E).ascii_text(8 << 20);
    let wedge = server.submit(Pattern::Regex("ZQZQZQ".to_string()), scan);
    wait_until(|| {
        let s = server.stats();
        s.batches >= 1 && s.queue_depth == 0
    });
    // N distinct patterns, ONE shared input that contains every
    // pattern's required literal (otherwise the prefilter clears the
    // whole set and no fused pass is needed)
    let shared = b"the cat saw a dog chase a bird past a fish".to_vec();
    let names = ["cat", "dog", "bird", "fish"];
    let tickets: Vec<_> = names
        .iter()
        .map(|p| {
            server.submit(Pattern::Regex(p.to_string()), shared.clone())
        })
        .collect();
    wait_until(|| server.stats().queue_depth == names.len());
    for (k, t) in tickets.into_iter().enumerate() {
        let out = t.wait().expect("probe serves");
        assert!(out.accepted, "pattern {k} must match the shared input");
        assert_eq!(out.n, shared.len());
    }
    assert!(wedge.wait().is_ok());
    let stats = server.shutdown();
    assert_eq!(stats.served, 1 + names.len() as u64);
    assert_eq!(
        stats.fused_passes, 1,
        "{} distinct-pattern requests over one input must collapse \
         into exactly one fused pass",
        names.len()
    );
    assert_eq!(stats.patterns_fused, names.len() as u64);
    assert_eq!(
        stats.prefilter_clears, 0,
        "every literal is present in the shared input"
    );
}

#[test]
fn fused_prefilter_clears_are_not_memoized() {
    // outcome memo ON (the default), one worker
    let server = Server::start(ServeConfig {
        workers: 1,
        calibrate_on_start: false,
        recalibrate_every: 0,
        profile_per_worker: false,
        engine: Engine::Sequential,
        ..ServeConfig::default()
    })
    .expect("server");
    let scan = specdfa::workload::InputGen::new(0x3ED6E).ascii_text(8 << 20);
    let wedge = server.submit(Pattern::Regex("ZQZQZQ".to_string()), scan);
    wait_until(|| {
        let s = server.stats();
        s.batches >= 1 && s.queue_depth == 0
    });
    // one shared input: "cat" is present (a real fused verdict) while
    // "unicorn" is absent, so the Aho–Corasick prefilter clears it and
    // its slot is a synthesized reject without a final state
    let shared = b"the cat sat".to_vec();
    let hit =
        server.submit(Pattern::Regex("cat".to_string()), shared.clone());
    let cleared = server
        .submit(Pattern::Regex("unicorn".to_string()), shared.clone());
    wait_until(|| server.stats().queue_depth == 2);
    assert!(hit.wait().expect("probe serves").accepted);
    let first = cleared.wait().expect("probe serves");
    assert!(!first.accepted);
    assert!(wedge.wait().is_ok());
    // regression: the cleared slot's verdict used to be memoized, so
    // this solo re-submit of the identical (pattern, input) was served
    // the degraded synthesized outcome from the cache instead of a real
    // matcher run reporting the DFA's final state
    let solo = server
        .submit(Pattern::Regex("unicorn".to_string()), shared.clone())
        .wait()
        .expect("probe serves");
    assert!(!solo.accepted);
    assert!(
        solo.final_state.is_some(),
        "memo served a prefilter-cleared verdict back to a solo request"
    );
    // ...while the fused pass's REAL verdict is memoized as before
    let again = server
        .submit(Pattern::Regex("cat".to_string()), shared.clone())
        .wait()
        .expect("probe serves");
    assert!(again.accepted);
    let stats = server.shutdown();
    assert_eq!(stats.fused_passes, 1);
    assert_eq!(stats.prefilter_clears, 1);
    assert_eq!(stats.outcome_hits, 1, "only the real verdict may hit");
}

#[test]
fn serve_cross_pattern_fusing_can_be_disabled() {
    let server = Server::start(ServeConfig {
        workers: 1,
        fuse_cross_pattern: false,
        calibrate_on_start: false,
        recalibrate_every: 0,
        cache_outcomes: 0,
        profile_per_worker: false,
        engine: Engine::Sequential,
        ..ServeConfig::default()
    })
    .expect("server");
    let scan = specdfa::workload::InputGen::new(0x3ED6E).ascii_text(4 << 20);
    let wedge = server.submit(Pattern::Regex("ZQZQZQ".to_string()), scan);
    wait_until(|| {
        let s = server.stats();
        s.batches >= 1 && s.queue_depth == 0
    });
    let shared = b"cat and dog".to_vec();
    let tickets: Vec<_> = ["cat", "dog"]
        .iter()
        .map(|p| {
            server.submit(Pattern::Regex(p.to_string()), shared.clone())
        })
        .collect();
    wait_until(|| server.stats().queue_depth == 2);
    for t in tickets {
        assert!(t.wait().expect("probe serves").accepted);
    }
    assert!(wedge.wait().is_ok());
    let stats = server.shutdown();
    assert_eq!(stats.fused_passes, 0, "fusing disabled");
    assert_eq!(stats.patterns_fused, 0);
}
