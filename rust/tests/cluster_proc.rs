//! Differential fault-tolerance suite for the real multi-process
//! cluster (`cluster::proc`).
//!
//! Every test spawns actual `specdfa worker` processes (this crate's
//! own binary, via `CARGO_BIN_EXE_specdfa`) speaking the framed socket
//! protocol, then asserts the one invariant the paper's failure-freedom
//! argument demands: **whatever is injected — a worker killed
//! mid-chunk, a truncated or dropped `Result` frame, stalled
//! heartbeats, total cluster loss — the verdict equals
//! `Engine::Sequential`'s**, and the recovery telemetry proves the
//! advertised path was taken (failover counted, checkpointed bytes
//! resumed rather than rescanned, degradation to local matching under
//! total loss).
//!
//! Inputs come from the PR 8 pathological corpus where the automaton
//! shape matters, and from seeded ASCII text where only the fault
//! machinery is under test.  `SPECDFA_TEST_SEED=<value>` replays a CI
//! failure exactly.

use std::sync::Arc;
use std::time::Duration;

use specdfa::cluster::{ClusterStats, ProcCluster, ProcConfig};
use specdfa::engine::{
    CompiledMatcher, Engine, ExecPolicy, Matcher, Pattern, ServeConfig,
    Server,
};
use specdfa::util::rng::{test_seed, Rng};
use specdfa::util::workload::pathological_corpus;
use specdfa::workload::InputGen;

/// Base configuration every test builds on: this crate's own binary as
/// the worker, chunk/checkpoint sizes small enough that a ~512 KiB
/// input spans many checkpoints, and timeouts short enough that the
/// timeout-driven failure paths run in test time.
fn config(workers: usize, fault: Option<&str>) -> ProcConfig {
    ProcConfig {
        workers,
        worker_bin: Some(env!("CARGO_BIN_EXE_specdfa").into()),
        min_chunk_bytes: 4 << 10,
        checkpoint_every: 16 << 10,
        request_timeout: Duration::from_millis(2500),
        heartbeat_timeout: Duration::from_millis(500),
        backoff_base: Duration::from_millis(5),
        backoff_cap: Duration::from_millis(50),
        profile_runs: 1,
        profile_sample_syms: 4 << 10,
        fault_spec: fault.map(str::to_string),
        ..ProcConfig::default()
    }
}

fn sequential(pattern: &Pattern) -> CompiledMatcher {
    CompiledMatcher::compile(
        pattern,
        Engine::Sequential,
        ExecPolicy::default(),
    )
    .expect("sequential yardstick compiles")
}

/// Run one pattern/input through a fresh cluster under `fault` and
/// return (cluster verdict == sequential verdict, final stats).
fn differential(
    pattern: &Pattern,
    input: &[u8],
    workers: usize,
    fault: Option<&str>,
) -> (bool, ClusterStats) {
    let cluster =
        ProcCluster::start(config(workers, fault)).expect("cluster starts");
    let out = cluster
        .match_bytes(pattern, input)
        .expect("a verdict is always produced");
    let seq = sequential(pattern).run_bytes(input).expect("seq runs");
    assert_eq!(
        out.accepted, seq.accepted,
        "failure-freedom violated under fault {fault:?}"
    );
    if let Some(fin) = out.final_state {
        assert_eq!(fin, seq.final_state.expect("seq reports a state"));
    }
    (out.accepted == seq.accepted, cluster.shutdown())
}

#[test]
fn no_fault_differential_on_pathological_corpus() {
    let seed = test_seed();
    let mut rng = Rng::new(seed ^ 0xC1D5);
    let corpus = pathological_corpus(seed);
    // a structural cross-section: permutation (γ = 1), dense frontier,
    // sink-heavy, one ReDoS regex — enough shapes to catch a
    // composition bug without spawning dozens of process fleets
    let picks = ["perm-q64", "dense-q128", "sink-q32", "redos-alt"];
    let mut tested = 0;
    for case in corpus.iter().filter(|c| picks.contains(&c.name.as_str())) {
        let n = 200 << 10;
        let mut input: Vec<u8> = (0..n)
            .map(|_| case.alphabet[rng.usize_below(case.alphabet.len())])
            .collect();
        if let Some(w) = &case.witness {
            input[..w.len()].copy_from_slice(w);
        }
        let (ok, stats) = differential(&case.pattern, &input, 2, None);
        assert!(ok, "{}", case.name);
        assert!(
            stats.cluster_serves >= 1,
            "{}: input must go through the workers, not the local \
             fallback: {stats:?}",
            case.name
        );
        assert_eq!(stats.failovers, 0, "{}: no fault, no failover", case.name);
        assert_eq!(stats.degraded, 0, "{}", case.name);
        tested += 1;
    }
    assert!(tested >= 3, "corpus no longer contains the picked cases");
}

#[test]
fn kill_mid_chunk_fails_over_and_resumes_from_checkpoint() {
    let input = InputGen::new(test_seed() ^ 0x417).ascii_text(512 << 10);
    let pattern = Pattern::Regex("ZQZQZQ".to_string());
    // worker 1 dies after matching 64 KiB — several checkpoints into
    // its ~256 KiB chunk
    let (_, stats) =
        differential(&pattern, &input, 2, Some("w1:kill@65536"));
    assert!(stats.failovers >= 1, "{stats:?}");
    assert!(stats.worker_deaths >= 1, "{stats:?}");
    assert!(
        stats.resumed_bytes > 0,
        "failover must resume from the streamed checkpoint, not \
         rescan from zero: {stats:?}"
    );
    assert!(stats.resumed_serves >= 1, "{stats:?}");
    assert_eq!(stats.degraded, 0, "a survivor existed: {stats:?}");
}

#[test]
fn truncated_result_frame_fails_over() {
    let input = InputGen::new(test_seed() ^ 0x7256).ascii_text(256 << 10);
    let pattern = Pattern::Regex("(ab|cd)+e".to_string());
    // worker 1 writes half of its first Result frame, then exits: the
    // frontend sees a corrupt/short read and must fail over
    let (_, stats) =
        differential(&pattern, &input, 2, Some("w1:trunc=result"));
    assert!(stats.failovers >= 1, "{stats:?}");
    assert!(stats.worker_deaths >= 1, "{stats:?}");
    assert_eq!(stats.degraded, 0, "{stats:?}");
}

#[test]
fn dropped_result_frame_times_out_and_retries() {
    let input = InputGen::new(test_seed() ^ 0xD20).ascii_text(128 << 10);
    let pattern = Pattern::Regex("(ab|cd)+e".to_string());
    // worker 1 swallows its first Result frame but stays alive: only
    // the per-request deadline can unstick this serve
    let (_, stats) =
        differential(&pattern, &input, 2, Some("w1:drop=result"));
    assert!(stats.retries >= 1, "{stats:?}");
    assert!(stats.failovers >= 1, "{stats:?}");
}

#[test]
fn heartbeat_stall_is_detected_and_worker_buried() {
    let input = InputGen::new(test_seed() ^ 0x57A1).ascii_text(128 << 10);
    let pattern = Pattern::Regex("(ab|cd)+e".to_string());
    let (_, stats) = differential(&pattern, &input, 2, Some("w1:stall"));
    assert!(stats.heartbeat_failures >= 1, "{stats:?}");
    assert!(stats.worker_deaths >= 1, "{stats:?}");
    assert_eq!(stats.degraded, 0, "the healthy worker serves: {stats:?}");
}

#[test]
fn total_cluster_loss_degrades_to_local_match() {
    let input = InputGen::new(test_seed() ^ 0x1055).ascii_text(256 << 10);
    let pattern = Pattern::Regex("ZQZQZQ".to_string());
    // both workers die almost immediately: the ladder must end at the
    // in-process engine, with a verdict — not an error
    let (_, stats) = differential(
        &pattern,
        &input,
        2,
        Some("w0:kill@4096;w1:kill@4096"),
    );
    assert!(stats.degraded >= 1, "{stats:?}");
    assert_eq!(stats.live_workers, 0, "{stats:?}");
}

#[test]
fn cluster_survives_repeated_serves_after_failover() {
    // the buried worker stays buried; later serves keep working on the
    // survivor with no further retries
    let cluster = ProcCluster::start(config(2, Some("w1:kill@32768")))
        .expect("cluster starts");
    let pattern = Pattern::Regex("ZQZQZQ".to_string());
    let seq = sequential(&pattern);
    let mut gen = InputGen::new(test_seed() ^ 0x2EAF);
    for i in 0..3 {
        let input = gen.ascii_text(128 << 10);
        let out = cluster.match_bytes(&pattern, &input).expect("verdict");
        let want = seq.run_bytes(&input).expect("seq").accepted;
        assert_eq!(out.accepted, want, "serve {i}");
    }
    let stats = cluster.shutdown();
    assert_eq!(stats.serves, 3, "{stats:?}");
    assert!(stats.failovers >= 1, "{stats:?}");
    assert!(stats.live_workers >= 1, "survivor still attached: {stats:?}");
}

#[test]
fn serve_loop_routes_large_scans_through_the_cluster() {
    let cluster =
        Arc::new(ProcCluster::start(config(2, None)).expect("cluster"));
    let server = Server::start(ServeConfig {
        workers: 1,
        profile_runs: 1,
        profile_sample_syms: 4 << 10,
        recalibrate_every: 0,
        calibrate_on_start: false,
        cache_outcomes: 0,
        cluster: Some(Arc::clone(&cluster)),
        cluster_min_bytes: 32 << 10,
        ..ServeConfig::default()
    })
    .expect("server starts");
    let pattern = Pattern::Regex("(ab|cd)+e".to_string());
    let seq = sequential(&pattern);
    let mut gen = InputGen::new(test_seed() ^ 0x5C42);
    let small = gen.ascii_text(1 << 10); // below the routing floor
    let large = gen.ascii_text(128 << 10); // routed to the cluster
    let t_small = server.submit(pattern.clone(), small.clone());
    let t_large = server.submit(pattern.clone(), large.clone());
    let out_small = t_small.wait().expect("small serves");
    let out_large = t_large.wait().expect("large serves");
    assert_eq!(
        out_small.accepted,
        seq.run_bytes(&small).unwrap().accepted
    );
    assert_eq!(
        out_large.accepted,
        seq.run_bytes(&large).unwrap().accepted
    );
    let stats = server.shutdown();
    assert!(
        stats.cluster_routed >= 1,
        "the large scan must route to the cluster: {stats:?}"
    );
    let cstats = cluster.stats();
    assert!(cstats.serves >= 1, "{cstats:?}");
    assert_eq!(cstats.degraded + cstats.local_small, 0, "{cstats:?}");
    // worker processes are reaped by ProcCluster's Drop impl
}
