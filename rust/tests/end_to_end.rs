//! End-to-end integration over the whole pipeline (no PJRT required):
//! pattern text -> NFA -> DFA -> minimize -> analysis -> parallel match
//! -> merge, on realistic workloads; plus Grail+ round-trips and
//! cross-engine agreement (speculative vs backtracking vs grep-like).

use specdfa::automata::grail;
use specdfa::automata::minimize::minimize;
use specdfa::baseline::backtracking::Backtracker;
use specdfa::baseline::greplike::GrepLike;
use specdfa::baseline::holub_stekr::HolubStekr;
use specdfa::baseline::sequential::SequentialMatcher;
use specdfa::regex::compile::{compile_prosite, compile_search};
use specdfa::regex::parser;
use specdfa::speculative::matcher::MatchPlan;
use specdfa::workload::InputGen;

#[test]
fn full_pipeline_on_planted_protein_corpus() {
    let mut gen = InputGen::new(0xE2E_1);
    let mut corpus = gen.protein(1 << 20);
    gen.plant(&mut corpus, b"RGD", 3);
    let dfa = compile_prosite("R-G-D.").unwrap();
    let seq = SequentialMatcher::new(&dfa).run_bytes(&corpus);
    assert!(seq.accepted, "planted signature must be found");
    let out = MatchPlan::new(&dfa).processors(16).lookahead(4).run(&corpus);
    assert!(out.accepted);
    assert_eq!(out.final_state, seq.final_state);
}

#[test]
fn negative_corpus_rejects_everywhere() {
    // build a corpus that cannot contain the pattern (no 'W' characters)
    let dfa = compile_prosite("W-W.").unwrap();
    let mut gen = InputGen::new(0xE2E_2);
    let corpus: Vec<u8> = gen
        .protein(1 << 19)
        .into_iter()
        .map(|b| if b == b'W' { b'A' } else { b })
        .collect();
    let out = MatchPlan::new(&dfa).processors(8).lookahead(2).run(&corpus);
    assert!(!out.accepted);
}

#[test]
fn engines_agree_on_ascii_logs() {
    let pats = ["ERROR", "WARN|ERROR", "[0-9]{4}-[0-9]{2}-[0-9]{2}",
                "fail(ed|ure)?"];
    let mut gen = InputGen::new(0xE2E_3);
    let mut text = gen.ascii_text(200_000);
    gen.plant(&mut text, b"2024-01-31 ERROR something failed", 2);
    for pat in pats {
        let dfa = compile_search(pat).unwrap();
        let want = dfa.accepts_bytes(&text);
        let parsed = parser::parse(pat).unwrap();
        let bt = Backtracker::with_fuel(&parsed.ast, 1_000_000_000)
            .search(&text)
            .expect("fuel");
        assert_eq!(bt.matched, want, "backtracker {pat}");
        let grep = GrepLike::new(&parsed.ast).search(&text);
        assert_eq!(grep.matched, want, "greplike {pat}");
        let spec =
            MatchPlan::new(&dfa).processors(8).lookahead(3).run(&text);
        assert_eq!(spec.accepted, want, "speculative {pat}");
        let hs = HolubStekr::new(&dfa, 8).run_syms(&dfa.map_input(&text));
        assert_eq!(hs.accepted, want, "holub-stekr {pat}");
    }
}

#[test]
fn grail_roundtrip_preserves_parallel_results() {
    let dfa = compile_search("(ab|cd){2,4}").unwrap();
    let text = grail::to_grail(&dfa);
    let back = grail::from_grail(&text).unwrap();
    let mut gen = InputGen::new(0xE2E_4);
    let syms = gen.uniform_syms(&dfa, 100_000);
    let a = MatchPlan::new(&dfa).processors(6).lookahead(2).run_syms(&syms);
    let b = MatchPlan::new(&back).processors(6).lookahead(2).run_syms(&syms);
    assert_eq!(a.final_state, b.final_state);
    assert_eq!(a.accepted, b.accepted);
}

#[test]
fn minimization_does_not_change_match_outcomes() {
    // run the speculative matcher on a deliberately non-minimal DFA and
    // its minimized form; outcomes must agree
    let parsed = parser::parse("(aa|ab|ac|ba|bb|bc)+").unwrap();
    let nfa = specdfa::automata::nfa::Nfa::from_ast(&parsed.ast);
    let big = specdfa::automata::subset::determinize(&nfa);
    let small = minimize(&big);
    assert!(small.num_states <= big.num_states);
    let mut gen = InputGen::new(0xE2E_5);
    let bytes: Vec<u8> = gen
        .ascii_text(50_000)
        .into_iter()
        .map(|b| b"abc"[(b as usize) % 3])
        .collect();
    let a = MatchPlan::new(&big).processors(5).lookahead(2).run(&bytes);
    let b = MatchPlan::new(&small).processors(5).lookahead(2).run(&bytes);
    assert_eq!(a.accepted, b.accepted);
}

#[test]
fn prosite_anchored_patterns_end_to_end() {
    let n_term = compile_prosite("<M-A-x(2)-K.").unwrap();
    assert!(n_term.accepts_bytes(b"MACCKRRRR"));
    // '<' anchored: must start at the N-terminus
    assert!(!n_term.accepts_bytes(b"GMACCKRRR"));
    let c_term = compile_prosite("K-D-E-L>.").unwrap();
    assert!(c_term.accepts_bytes(b"MAAKDEL"));
    assert!(!c_term.accepts_bytes(b"MAAKDELG"));
}

#[test]
fn speculative_overhead_shrinks_with_lookahead_depth() {
    // Lemma 1 materialized: deeper lookahead => less redundant work
    let dfa =
        compile_prosite("C-x(2,4)-C-x(3)-[LIVMFYWC]-x(4)-H-x(3,5)-H.")
            .unwrap();
    let mut gen = InputGen::new(0xE2E_6);
    let syms = gen.uniform_syms(&dfa, 400_000);
    let mut prev = usize::MAX;
    for r in [1usize, 2, 3, 4] {
        let out = MatchPlan::new(&dfa)
            .processors(16)
            .lookahead(r)
            .run_syms(&syms);
        assert!(out.m <= prev, "I_max grew with r: {} > {prev}", out.m);
        prev = out.m;
    }
}
