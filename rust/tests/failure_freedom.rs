//! The paper's headline property, tested at integration level across the
//! whole configuration space: *speculation is failure-free* — every
//! parallel configuration produces exactly the sequential result
//! (sequential semantics), and per-processor work never exceeds the
//! sequential symbol count (no speed-down in the work model).

use specdfa::baseline::sequential::SequentialMatcher;
use specdfa::cluster::{CloudMatcher, ClusterSpec};
use specdfa::engine::{
    select, AutoThresholds, CompiledMatcher, DfaProps, Engine, EngineKind,
    ExecPolicy, Matcher, Pattern,
};
use specdfa::regex::compile::{compile_prosite, compile_search};
use specdfa::speculative::matcher::MatchPlan;
use specdfa::speculative::merge::MergeStrategy;
use specdfa::util::prop;
use specdfa::workload::{
    pcre_suite_cached, prosite_suite_cached, InputGen,
};

#[test]
fn parallel_equals_sequential_across_suite() {
    let mut gen = InputGen::new(0xFF1);
    for p in pcre_suite_cached().iter().step_by(3) {
        let syms = gen.uniform_syms(&p.dfa, 200_000);
        let want = SequentialMatcher::new(&p.dfa).run_syms(&syms);
        for procs in [1, 2, 7, 40] {
            for r in [0, 1, 4] {
                let out = MatchPlan::new(&p.dfa)
                    .processors(procs)
                    .lookahead(r)
                    .run_syms(&syms);
                assert_eq!(out.final_state, want.final_state,
                           "{} P={procs} r={r}", p.name);
            }
        }
    }
}

#[test]
fn no_speeddown_in_work_model() {
    // Eq. (14)/(15): makespan_syms <= n always (failure-freedom), with
    // equality only at P=1.
    let mut gen = InputGen::new(0xFF2);
    for p in pcre_suite_cached().iter().step_by(5) {
        let n = 300_000;
        let syms = gen.uniform_syms(&p.dfa, n);
        for procs in [2, 8, 40] {
            for r in [0, 4] {
                let out = MatchPlan::new(&p.dfa)
                    .processors(procs)
                    .lookahead(r)
                    .run_syms(&syms);
                // +|Q| slack for flooring at chunk boundaries
                assert!(
                    out.makespan_syms() <= n + p.dfa.num_states as usize,
                    "{} P={procs} r={r}: makespan {} > n {n}",
                    p.name,
                    out.makespan_syms()
                );
            }
        }
    }
}

#[test]
fn prop_weights_and_merges_do_not_change_results() {
    prop::check("arbitrary weights/merges keep sequential semantics", 30,
                |rng| {
        let pats = ["a(bc)*d", "[ab]{3,9}", "x+y+z+", "(q|r|s){2,4}t"];
        let pat = pats[rng.usize_below(pats.len())];
        let dfa = compile_search(pat).unwrap();
        let n = rng.range_usize(0, 40_000);
        let syms: Vec<u32> = (0..n)
            .map(|_| rng.below(dfa.num_symbols as u64) as u32)
            .collect();
        let want = SequentialMatcher::new(&dfa).run_syms(&syms);
        let p = rng.range_usize(1, 24);
        let weights: Vec<f64> =
            (0..p).map(|_| 0.3 + rng.f64() * 4.0).collect();
        let strat = match rng.below(3) {
            0 => MergeStrategy::Sequential,
            1 => MergeStrategy::BinaryTree,
            _ => MergeStrategy::Hierarchical {
                cores_per_node: rng.range_usize(1, 8),
            },
        };
        let out = MatchPlan::new(&dfa)
            .processors(p)
            .weights(weights)
            .lookahead(rng.range_usize(0, 5))
            .merge_strategy(strat)
            .run_syms(&syms);
        assert_eq!(out.final_state, want.final_state);
    });
}

#[test]
fn cloud_preserves_sequential_semantics_under_preemption() {
    let dfa = compile_prosite("C-x(2)-C-x(3)-H.").unwrap();
    let mut gen = InputGen::new(0xFF4);
    let syms = gen.uniform_syms(&dfa, 500_000);
    let want = SequentialMatcher::new(&dfa).run_syms(&syms);
    for seed in 0..5u64 {
        let out = CloudMatcher::new(
            &dfa,
            ClusterSpec::fast_slow(2, 2).allocate_all_cores(),
        )
        .lookahead(2)
        .seed(seed)
        .run_syms(&syms);
        // preemption slows the simulated clock, never changes the result
        assert_eq!(out.final_state, want.final_state, "seed {seed}");
    }
}

/// Every engine adapter, one code path: the same (pattern, input) runs
/// through every `Matcher` via the engine facade and must report the same
/// membership verdict — and the same final state where the engine tracks
/// one.  This is the old multicore-only failure-freedom property extended
/// to the SIMD, cloud, Holub–Štekr and AST engines.
#[test]
fn prop_all_engine_adapters_equivalent() {
    let pats = ["ne{2}dle", "(ab|cd)+e?", "a+b", "[0-9]{2}:[0-9]{2}"];
    prop::check("facade adapters equivalent", 10, |rng| {
        let pat = pats[rng.usize_below(pats.len())];
        let pattern = Pattern::Regex(pat.to_string());
        let len = rng.range_usize(0, 800);
        let bytes: Vec<u8> = (0..len)
            .map(|_| b"abcdne 0123:xy"[rng.usize_below(14)])
            .collect();
        let policy = ExecPolicy {
            processors: rng.range_usize(1, 6),
            lookahead: rng.range_usize(0, 4),
            ..ExecPolicy::default()
        };
        let engines = [
            Engine::Sequential,
            Engine::Speculative { adaptive: false },
            Engine::Speculative { adaptive: true },
            Engine::Simd { variant: None },
            Engine::Cloud { nodes: 2 },
            Engine::Shard { nodes: 2 },
            Engine::HolubStekr,
            Engine::Backtracking,
            Engine::GrepLike,
        ];
        let outcomes: Vec<_> = engines
            .iter()
            .map(|e| {
                CompiledMatcher::compile(&pattern, e.clone(), policy.clone())
                    .expect("compile")
                    .run_bytes(&bytes)
                    .expect("run")
            })
            .collect();
        let want = &outcomes[0];
        assert_eq!(want.engine, EngineKind::Sequential);
        for out in &outcomes[1..] {
            assert_eq!(
                out.accepted, want.accepted,
                "{} disagrees on {pat} (len {len})",
                out.engine
            );
            if let (Some(a), Some(b)) = (out.final_state, want.final_state) {
                assert_eq!(a, b, "{} final state, {pat}", out.engine);
            }
            assert_eq!(out.n, want.n);
        }
    });
}

/// Acceptance criterion: `Engine::Auto` demonstrably dispatches to at
/// least 3 different engines across the PCRE-like and PROSITE-like
/// suites, and every selection is consistent with the documented
/// γ/|Q|/n threshold rules.
#[test]
fn auto_dispatches_at_least_three_engines_across_suites() {
    let t = AutoThresholds::default();
    let sizes = [1usize << 10, 1 << 18, 1 << 21, 1 << 24, 1 << 27];
    let mut kinds = std::collections::BTreeSet::new();
    for suite in [pcre_suite_cached(), prosite_suite_cached()] {
        for p in suite {
            let props = DfaProps::analyze(&p.dfa, 4);
            for n in sizes {
                let sel = select(&props, n, &t);
                kinds.insert(sel.kind);
                // re-derive the decision from gamma/|Q|/n: the published
                // threshold contract, not the implementation
                let expected = if n < t.seq_max_n {
                    EngineKind::Sequential
                } else if props.gamma > t.gamma_max {
                    EngineKind::Sequential
                } else if n >= t.shard_min_n {
                    EngineKind::Shard
                } else if n >= t.cloud_min_n {
                    EngineKind::Cloud
                } else if props.i_max <= t.simd_max_i_max
                    && n <= t.simd_max_n
                {
                    EngineKind::Simd
                } else {
                    EngineKind::Speculative
                };
                assert_eq!(
                    sel.kind, expected,
                    "{} n={n}: {sel}",
                    p.name
                );
            }
        }
    }
    assert!(
        kinds.len() >= 3,
        "auto dispatched only {kinds:?} across the suites"
    );
    assert!(kinds.contains(&EngineKind::Sequential));
    assert!(kinds.contains(&EngineKind::Cloud));
}

/// Deterministic dispatch walk on the paper's Fig. 6 DFA (γ = 1/2): the
/// same pattern is served by all five Auto substrates as the request size
/// grows.
#[test]
fn auto_walks_all_substrates_with_input_size() {
    let fig6 = "(START) |- 0\n0 0 1\n0 1 2\n1 0 1\n1 1 3\n2 0 3\n\
                2 1 2\n3 0 3\n3 1 3\n3 -| (FINAL)\n";
    let cm = CompiledMatcher::compile(
        &Pattern::Grail(fig6.to_string()),
        Engine::Auto,
        ExecPolicy::default(),
    )
    .unwrap();
    let props = cm.props();
    assert!(props.i_max <= 2, "Fig. 6 I_max,4 is at most 2");
    assert!(props.gamma <= 0.5);
    assert_eq!(cm.selection_for(1 << 10).kind, EngineKind::Sequential);
    assert_eq!(cm.selection_for(1 << 18).kind, EngineKind::Simd);
    assert_eq!(cm.selection_for(1 << 21).kind, EngineKind::Speculative);
    assert_eq!(cm.selection_for(1 << 24).kind, EngineKind::Cloud);
    assert_eq!(cm.selection_for(1 << 27).kind, EngineKind::Shard);

    // and the dispatched runs stay failure-free at a representative size
    let mut gen = InputGen::new(0xA070);
    let syms = gen.uniform_syms(cm.dfa(), 1 << 18);
    let out = cm.run_syms(&syms).unwrap();
    assert_eq!(out.engine, EngineKind::Simd);
    let want = SequentialMatcher::new(cm.dfa()).run_syms(&syms);
    assert_eq!(out.final_state, Some(want.final_state));
    assert_eq!(out.accepted, want.accepted);
}

#[test]
fn zero_and_tiny_inputs_all_configs() {
    let dfa = compile_search("abc").unwrap();
    for n in [0usize, 1, 2, 3, 5, 17] {
        let syms: Vec<u32> = (0..n)
            .map(|i| (i % dfa.num_symbols as usize) as u32)
            .collect();
        let want = SequentialMatcher::new(&dfa).run_syms(&syms);
        for procs in [1, 2, 13] {
            for r in [0, 1, 3] {
                let out = MatchPlan::new(&dfa)
                    .processors(procs)
                    .lookahead(r)
                    .run_syms(&syms);
                assert_eq!(out.final_state, want.final_state,
                           "n={n} P={procs} r={r}");
            }
        }
    }
}
