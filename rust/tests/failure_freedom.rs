//! The paper's headline property, tested at integration level across the
//! whole configuration space: *speculation is failure-free* — every
//! parallel configuration produces exactly the sequential result
//! (sequential semantics), and per-processor work never exceeds the
//! sequential symbol count (no speed-down in the work model).

use specdfa::baseline::sequential::SequentialMatcher;
use specdfa::cluster::{CloudMatcher, ClusterSpec};
use specdfa::regex::compile::{compile_prosite, compile_search};
use specdfa::speculative::matcher::MatchPlan;
use specdfa::speculative::merge::MergeStrategy;
use specdfa::util::prop;
use specdfa::workload::{pcre_suite_cached, InputGen};

#[test]
fn parallel_equals_sequential_across_suite() {
    let mut gen = InputGen::new(0xFF1);
    for p in pcre_suite_cached().iter().step_by(3) {
        let syms = gen.uniform_syms(&p.dfa, 200_000);
        let want = SequentialMatcher::new(&p.dfa).run_syms(&syms);
        for procs in [1, 2, 7, 40] {
            for r in [0, 1, 4] {
                let out = MatchPlan::new(&p.dfa)
                    .processors(procs)
                    .lookahead(r)
                    .run_syms(&syms);
                assert_eq!(out.final_state, want.final_state,
                           "{} P={procs} r={r}", p.name);
            }
        }
    }
}

#[test]
fn no_speeddown_in_work_model() {
    // Eq. (14)/(15): makespan_syms <= n always (failure-freedom), with
    // equality only at P=1.
    let mut gen = InputGen::new(0xFF2);
    for p in pcre_suite_cached().iter().step_by(5) {
        let n = 300_000;
        let syms = gen.uniform_syms(&p.dfa, n);
        for procs in [2, 8, 40] {
            for r in [0, 4] {
                let out = MatchPlan::new(&p.dfa)
                    .processors(procs)
                    .lookahead(r)
                    .run_syms(&syms);
                // +|Q| slack for flooring at chunk boundaries
                assert!(
                    out.makespan_syms() <= n + p.dfa.num_states as usize,
                    "{} P={procs} r={r}: makespan {} > n {n}",
                    p.name,
                    out.makespan_syms()
                );
            }
        }
    }
}

#[test]
fn prop_weights_and_merges_do_not_change_results() {
    prop::check("arbitrary weights/merges keep sequential semantics", 30,
                |rng| {
        let pats = ["a(bc)*d", "[ab]{3,9}", "x+y+z+", "(q|r|s){2,4}t"];
        let pat = pats[rng.usize_below(pats.len())];
        let dfa = compile_search(pat).unwrap();
        let n = rng.range_usize(0, 40_000);
        let syms: Vec<u32> = (0..n)
            .map(|_| rng.below(dfa.num_symbols as u64) as u32)
            .collect();
        let want = SequentialMatcher::new(&dfa).run_syms(&syms);
        let p = rng.range_usize(1, 24);
        let weights: Vec<f64> =
            (0..p).map(|_| 0.3 + rng.f64() * 4.0).collect();
        let strat = match rng.below(3) {
            0 => MergeStrategy::Sequential,
            1 => MergeStrategy::BinaryTree,
            _ => MergeStrategy::Hierarchical {
                cores_per_node: rng.range_usize(1, 8),
            },
        };
        let out = MatchPlan::new(&dfa)
            .processors(p)
            .weights(weights)
            .lookahead(rng.range_usize(0, 5))
            .merge_strategy(strat)
            .run_syms(&syms);
        assert_eq!(out.final_state, want.final_state);
    });
}

#[test]
fn cloud_preserves_sequential_semantics_under_preemption() {
    let dfa = compile_prosite("C-x(2)-C-x(3)-H.").unwrap();
    let mut gen = InputGen::new(0xFF4);
    let syms = gen.uniform_syms(&dfa, 500_000);
    let want = SequentialMatcher::new(&dfa).run_syms(&syms);
    for seed in 0..5u64 {
        let out = CloudMatcher::new(
            &dfa,
            ClusterSpec::fast_slow(2, 2).allocate_all_cores(),
        )
        .lookahead(2)
        .seed(seed)
        .run_syms(&syms);
        // preemption slows the simulated clock, never changes the result
        assert_eq!(out.final_state, want.final_state, "seed {seed}");
    }
}

#[test]
fn zero_and_tiny_inputs_all_configs() {
    let dfa = compile_search("abc").unwrap();
    for n in [0usize, 1, 2, 3, 5, 17] {
        let syms: Vec<u32> = (0..n)
            .map(|i| (i % dfa.num_symbols as usize) as u32)
            .collect();
        let want = SequentialMatcher::new(&dfa).run_syms(&syms);
        for procs in [1, 2, 13] {
            for r in [0, 1, 3] {
                let out = MatchPlan::new(&dfa)
                    .processors(procs)
                    .lookahead(r)
                    .run_syms(&syms);
                assert_eq!(out.final_state, want.final_state,
                           "n={n} P={procs} r={r}");
            }
        }
    }
}
