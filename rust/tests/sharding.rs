//! Acceptance tests for hierarchical cross-substrate sharding
//! (`engine::shard`): a sharded match over a multi-node simulated
//! cluster with an inhomogeneous capacity vector must return
//! byte-identical outcomes to `Engine::Seq` on every differential case —
//! including matches planted across both node and intra-node chunk
//! boundaries — and skewed capacity vectors must still partition the
//! full input exactly once.

use specdfa::engine::shard::ShardPlan;
use specdfa::engine::{
    CompiledMatcher, Engine, EngineKind, ExecPolicy, Matcher, Pattern,
};
use specdfa::util::rng::Rng;
use specdfa::workload::InputGen;

/// The inhomogeneous topology used throughout: 3 nodes with different
/// worker counts and per-worker rates (a fast node, a mixed node with
/// one very slow worker, and a small slow node).
fn skewed_nodes() -> Vec<Vec<f64>> {
    vec![
        vec![2.0, 2.0, 2.0, 2.0],
        vec![1.0, 1.0, 0.2, 1.0],
        vec![0.5, 0.5],
    ]
}

#[test]
fn sharded_equals_sequential_across_all_boundaries() {
    // plant the witness straddling every node boundary and every
    // intra-node worker boundary (±1 symbol) — the exact positions where
    // a two-level split/merge bug would flip the outcome
    let pattern = Pattern::Regex("(ab|cd)+e".to_string());
    let witness: &[u8] = b"abcde";
    let n = 60_000;

    let seq =
        CompiledMatcher::compile(&pattern, Engine::Sequential, policy())
            .unwrap();
    let dfa = seq.dfa().clone();
    let plan = ShardPlan::new(&dfa)
        .node_capacities(skewed_nodes())
        .lookahead(2);
    let layout = plan.layout(n);

    // collect every level-1 and level-2 boundary
    let mut boundaries: Vec<usize> = Vec::new();
    for c in &layout.node_chunks {
        boundaries.push(c.start);
    }
    for chunks in &layout.worker_chunks {
        for c in chunks {
            boundaries.push(c.start);
        }
    }
    boundaries.retain(|&b| b > 0 && b < n);
    boundaries.sort_unstable();
    boundaries.dedup();
    assert!(
        boundaries.len() >= 8,
        "3 nodes x (4+4+2) workers must give many internal boundaries, \
         got {boundaries:?}"
    );

    let mut rng = Rng::new(0x5117);
    let filler = b"abcdex .";
    for &b in &boundaries {
        for offset in [-1i64, 0, 1] {
            let pos = (b as i64 + offset - (witness.len() / 2) as i64)
                .clamp(0, (n - witness.len()) as i64)
                as usize;
            let mut text: Vec<u8> = (0..n)
                .map(|_| filler[rng.usize_below(filler.len())])
                .collect();
            text[pos..pos + witness.len()].copy_from_slice(witness);

            let want = seq.run_bytes(&text).unwrap();
            assert!(want.accepted, "witness planted at {pos}");
            let out = plan.run(&text);
            assert_eq!(
                out.final_state,
                want.final_state.unwrap(),
                "boundary {b} offset {offset}"
            );
            assert_eq!(out.accepted, want.accepted);
        }
    }
}

fn policy() -> ExecPolicy {
    ExecPolicy { processors: 3, lookahead: 2, ..ExecPolicy::default() }
}

#[test]
fn shard_engine_differential_through_the_facade() {
    // the facade's shard engine vs the sequential reference over a
    // randomized corpus with planted and unplanted cases
    let patterns =
        ["(ab|cd)+e?", "a+b", "needle", "[ab]c[cd]", "(ha|ho)+x"];
    let mut gen = InputGen::new(0x5118);
    for pat in patterns {
        let pattern = Pattern::Regex(pat.to_string());
        let reference =
            CompiledMatcher::compile(&pattern, Engine::Sequential, policy())
                .unwrap();
        let shard = CompiledMatcher::compile(
            &pattern,
            Engine::Shard { nodes: 3 },
            policy(),
        )
        .unwrap();
        for len in [0usize, 1, 7, 1000, 50_000] {
            let text = gen.ascii_text(len);
            let want = reference.run_bytes(&text).unwrap();
            let out = shard.run_bytes(&text).unwrap();
            assert_eq!(out.engine, EngineKind::Shard);
            assert_eq!(
                out.accepted, want.accepted,
                "pattern={pat:?} len={len}"
            );
            assert_eq!(out.final_state, want.final_state);
        }
    }
}

#[test]
fn prop_skewed_vectors_partition_exactly_once() {
    // property: whatever the capacity skew, the two-level layout covers
    // every input symbol exactly once (no gap, no overlap), and the total
    // matched work accounts for every symbol at least once
    let dfa = specdfa::compile_search("(ab|cd)+e").unwrap();
    let mut rng = Rng::new(0x5119);
    for case in 0..60 {
        let n = rng.below(1_000_000) as usize;
        let nodes: Vec<Vec<f64>> = (0..1 + rng.usize_below(5))
            .map(|_| {
                (0..1 + rng.usize_below(8))
                    .map(|_| {
                        // up to 400x skew between workers
                        0.01 + rng.f64() * 4.0
                    })
                    .collect()
            })
            .collect();
        let plan = ShardPlan::new(&dfa)
            .node_capacities(nodes.clone())
            .lookahead(1 + rng.usize_below(3));
        let layout = plan.layout(n);

        // flatten all worker chunks: they must tile [0, n) in order
        let mut covered = 0usize;
        for (node, chunks) in layout.worker_chunks.iter().enumerate() {
            assert_eq!(
                chunks.first().unwrap().start,
                layout.node_chunks[node].start,
                "case {case}"
            );
            for c in chunks {
                assert_eq!(c.start, covered, "case {case}: gap or overlap");
                assert!(c.end >= c.start);
                covered = c.end;
            }
            assert_eq!(covered, layout.node_chunks[node].end);
        }
        assert_eq!(covered, n, "case {case}: input not fully covered");

        // and the executed work agrees with the layout
        let syms: Vec<u32> = (0..n.min(20_000))
            .map(|_| rng.below(dfa.num_symbols as u64) as u32)
            .collect();
        let out = plan.run_syms(&syms);
        let total_chunk_syms: usize =
            out.work.iter().map(|w| w.chunk_len).sum();
        assert_eq!(total_chunk_syms, syms.len(), "case {case}");
        // every worker matched each of its symbols >= 1 time
        for w in &out.work {
            assert!(w.states_matched >= 1, "case {case}");
            assert_eq!(w.syms_matched, w.chunk_len * w.states_matched);
        }
    }
}

#[test]
fn auto_routes_corpus_scale_requests_to_the_shard_engine() {
    // calibrate thresholds so "corpus scale" is cheap to reach in a test,
    // then check Auto both reports and executes the shard selection
    let mut policy = ExecPolicy::default();
    policy.thresholds.shard_min_n = 1 << 16;
    let cm = CompiledMatcher::compile(
        &Pattern::Regex("(ab|cd)+e".to_string()),
        Engine::Auto,
        policy,
    )
    .unwrap();
    let mut gen = InputGen::new(0x511A);
    let mut corpus = gen.ascii_text(1 << 17);
    gen.plant(&mut corpus, b"abcde", 3);
    let out = cm.run_bytes(&corpus).unwrap();
    assert_eq!(out.engine, EngineKind::Shard);
    let sel = out.selection.expect("auto reports the selection");
    assert_eq!(sel.kind, EngineKind::Shard);
    assert!(sel.reason.contains("two-level"), "{}", sel.reason);
    assert!(out.accepted, "planted witness must be found");

    // below the corpus threshold Auto must not shard
    let small = gen.ascii_text(1 << 12);
    let out = cm.run_bytes(&small).unwrap();
    assert_ne!(out.engine, EngineKind::Shard);
}

#[test]
fn measured_capacity_vector_drives_the_shard_partition() {
    // a per-worker capacity vector with one slow worker: the slow
    // worker's chunks must be shorter than its fast peers' in every node
    let dfa = specdfa::compile_search("(ab|cd)+e").unwrap();
    let cv = specdfa::speculative::profile::CapacityVector {
        rates: vec![400.0, 400.0, 100.0, 400.0],
        runs: 3,
        sample_syms: 1 << 16,
    };
    let plan = ShardPlan::new(&dfa).capacity_vector(3, &cv).lookahead(2);
    let layout = plan.layout(10_000_000);
    for (node, chunks) in layout.worker_chunks.iter().enumerate() {
        if node == 0 {
            // node 0's first chunk carries the m x stretch; compare the
            // speculative workers only
            assert!(
                chunks[2].len() < chunks[1].len(),
                "node 0: slow worker chunk {} !< fast {}",
                chunks[2].len(),
                chunks[1].len()
            );
        } else {
            let lens: Vec<usize> = chunks.iter().map(|c| c.len()).collect();
            assert!(
                lens[2] < lens[0] && lens[2] < lens[1] && lens[2] < lens[3],
                "node {node}: slow worker must get the shortest chunk: \
                 {lens:?}"
            );
        }
    }
}
