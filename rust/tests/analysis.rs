//! Ground-truth tests for the static analyzer (`specdfa::analysis`).
//!
//! Three claims have to hold against the repo's own corpora, not
//! hand-picked fixtures:
//!
//! 1. the ReDoS lints flag every pathological-corpus ReDoS entry and
//!    produce zero false positives across the full PCRE-like and
//!    PROSITE-like benchmark suites,
//! 2. the fuse estimator's bounds bracket the *actual* fused product on
//!    every corpus set, and every predicted skip is one `fuse` provably
//!    aborts,
//! 3. the protocol checker passes the protocol as implemented and
//!    catches a seeded mutation.
//!
//! Plus the serving acceptance check: a `HazardPolicy::Reject` server
//! refuses the ReDoS request in a mixed trace while serving the rest
//! verdict-identically to the sequential engine.

use specdfa::analysis::{
    check_model, estimate_fuse, lint_pattern, session_model, SessionState,
};
use specdfa::automata::product::fuse;
use specdfa::cluster::proto::FrameKind;
use specdfa::engine::{
    CompiledMatcher, Engine, ExecPolicy, HazardPolicy, Pattern, ServeConfig,
    Server,
};
use specdfa::util::workload::pathological_corpus;
use specdfa::workload::{pcre_suite_cached, prosite_suite_cached, InputGen};

// ---------------------------------------------------------------------
// 1. ReDoS ground truth
// ---------------------------------------------------------------------

#[test]
fn every_corpus_redos_entry_is_flagged() {
    let corpus = pathological_corpus(0xA11A);
    let redos: Vec<_> = corpus
        .iter()
        .filter(|c| c.name.starts_with("redos-"))
        .collect();
    assert!(redos.len() >= 3, "corpus lost its ReDoS entries");
    for case in redos {
        let report = lint_pattern(&case.pattern)
            .unwrap_or_else(|e| panic!("{}: lint failed: {e:#}", case.name));
        assert!(
            report.is_hazardous(),
            "{}: ReDoS pattern {:?} not flagged",
            case.name,
            report.pattern
        );
    }
}

#[test]
fn zero_false_positives_on_clean_corpus_and_suites() {
    // every non-ReDoS pathological-corpus entry is hazard-free (the
    // raw automata are pathological for *speculation*, not for a
    // backtracker — different hazard class, different pass)
    for case in pathological_corpus(0xA11B) {
        if case.name.starts_with("redos-") {
            continue;
        }
        let report = lint_pattern(&case.pattern)
            .unwrap_or_else(|e| panic!("{}: lint failed: {e:#}", case.name));
        assert!(
            !report.is_hazardous(),
            "{}: false positive: {:?}",
            case.name,
            report.hazards
        );
    }
    // the full curated suites are production-shaped patterns; a single
    // false positive here would make Warn-mode logs useless
    for p in pcre_suite_cached() {
        let report =
            lint_pattern(&Pattern::Regex(p.pattern.clone())).unwrap();
        assert!(
            !report.is_hazardous(),
            "pcre {}: false positive on {:?}: {:?}",
            p.name,
            p.pattern,
            report.hazards
        );
    }
    for p in prosite_suite_cached() {
        let report =
            lint_pattern(&Pattern::Prosite(p.pattern.clone())).unwrap();
        assert!(
            !report.is_hazardous(),
            "prosite {}: false positive on {:?}: {:?}",
            p.name,
            p.pattern,
            report.hazards
        );
    }
}

// ---------------------------------------------------------------------
// 2. fuse estimator soundness against the real product construction
// ---------------------------------------------------------------------

/// Small-DFA subsets of the PCRE suite (pairwise products stay cheap
/// enough for debug-mode test runs).
fn small_suite_dfas() -> Vec<&'static specdfa::Dfa> {
    pcre_suite_cached()
        .iter()
        .filter(|p| p.q() <= 64)
        .take(8)
        .map(|p| &p.dfa)
        .collect()
}

#[test]
fn estimate_brackets_actual_fused_product_on_suite_sets() {
    let dfas = small_suite_dfas();
    assert!(dfas.len() >= 4, "suite lost its small DFAs");
    for set in dfas.windows(2).chain(dfas.windows(3)) {
        let refs: Vec<&specdfa::Dfa> = set.to_vec();
        let est = estimate_fuse(&refs, 0);
        let prod = fuse(&refs, 0, 1).expect("unlimited budget never aborts");
        let actual = prod.dfa.num_states as usize;
        assert!(
            est.certain_min <= actual,
            "certain_min {} > actual {actual}",
            est.certain_min
        );
        assert!(
            est.upper_bound >= actual,
            "upper_bound {} < actual {actual}",
            est.upper_bound
        );
        assert_eq!(
            est.combined_classes, prod.dfa.num_symbols as usize,
            "combined class count is the fused dense symbol count"
        );
    }
}

#[test]
fn every_predicted_skip_is_a_fuse_that_aborts() {
    let dfas = small_suite_dfas();
    let mut predicted = 0usize;
    for set in dfas.windows(2) {
        let refs: Vec<&specdfa::Dfa> = set.to_vec();
        for budget in [1usize, 4, 16, 64, 256] {
            let est = estimate_fuse(&refs, budget);
            if est.predicted_overflow {
                predicted += 1;
                assert!(
                    fuse(&refs, budget, 1).is_none(),
                    "predicted overflow at budget {budget} but fuse \
                     succeeded (certain_min {})",
                    est.certain_min
                );
            }
        }
    }
    assert!(predicted > 0, "budget sweep never triggered a prediction");
}

// ---------------------------------------------------------------------
// 3. protocol checker ground truth
// ---------------------------------------------------------------------

#[test]
fn protocol_as_implemented_passes_and_mutation_fails() {
    let report = check_model(&session_model());
    assert!(report.ok(), "current protocol flagged: {:?}", report.problems);

    // seeded mutation: drop the idle Heartbeat handler — the checker
    // must notice the declared arrival with no transition
    let mut mutated = session_model();
    mutated.transitions.retain(|&(s, f, _)| {
        !(s == SessionState::Idle && f == FrameKind::Heartbeat)
    });
    let report = check_model(&mutated);
    assert!(!report.ok(), "dropped-Heartbeat mutation not caught");
    assert!(
        report
            .problems
            .iter()
            .any(|p| p.contains("unhandled") && p.contains("heartbeat")),
        "wrong diagnosis: {:?}",
        report.problems
    );
}

// ---------------------------------------------------------------------
// acceptance: Reject-policy server refuses the hazard, serves the rest
// ---------------------------------------------------------------------

#[test]
fn reject_policy_refuses_redos_and_serves_rest_verdict_identical() {
    let clean: Vec<(Pattern, usize)> = vec![
        (Pattern::Regex("cat|dog".to_string()), 1 << 12),
        (Pattern::Regex("(ab|cd)+e".to_string()), 1 << 13),
        (Pattern::Regex("needle".to_string()), 1 << 12),
        (Pattern::Prosite("C-x(2)-C.".to_string()), 1 << 12),
    ];
    let server = Server::start(ServeConfig {
        workers: 2,
        hazard_policy: HazardPolicy::Reject,
        engine: Engine::Sequential,
        calibrate_on_start: false,
        profile_runs: 1,
        profile_sample_syms: 1 << 12,
        recalibrate_every: 0,
        ..ServeConfig::default()
    })
    .expect("server starts");

    let mut gen = InputGen::new(0xACCE);
    let mut expected = Vec::new();
    let mut tickets = Vec::new();
    for (i, (pattern, n)) in clean.iter().enumerate() {
        let input = if matches!(pattern, Pattern::Prosite(_)) {
            gen.protein(*n)
        } else {
            gen.ascii_text(*n)
        };
        let seq = CompiledMatcher::compile(
            pattern,
            Engine::Sequential,
            ExecPolicy::default(),
        )
        .expect("clean pattern compiles")
        .run_bytes(&input)
        .expect("sequential yardstick runs");
        expected.push((i, seq.accepted));
        tickets.push((i, server.submit(pattern.clone(), input)));
    }
    // the hazardous request, interleaved with live clean traffic
    let redos = server
        .submit(Pattern::Regex("(a|a)*b".to_string()), b"aaaab".to_vec());

    for ((i, ticket), (j, want)) in tickets.into_iter().zip(expected) {
        assert_eq!(i, j);
        let out = ticket.wait().expect("clean request serves");
        assert_eq!(
            out.accepted, want,
            "request {i}: verdict diverged from Engine::Sequential"
        );
    }
    let err = redos.wait().expect_err("ReDoS request must be refused");
    let msg = err.to_string();
    assert!(
        msg.contains("hazard policy reject")
            && msg.contains("overlapping-alternation"),
        "unexpected refusal message: {msg}"
    );

    let stats = server.shutdown();
    assert_eq!(stats.hazards_flagged, 1, "one hazardous request flagged");
    assert_eq!(stats.hazards_rejected, 1, "acceptance criterion");
    assert_eq!(stats.rejected, 1, "hazard refusals count as rejections");
    assert_eq!(stats.served, 4, "every clean request served");
}
