//! Cross-engine differential test suite: every adapter must agree with
//! the Listing-1 sequential reference on `accepted` (all engines) and
//! `final_state` (the DFA engines) over a seeded randomized corpus of
//! (regex, input) cases — including matches planted to straddle the
//! chunk boundaries where split/combine bugs live.
//!
//! The generator emits a pattern *together with a witness string from
//! its language*, so planted cases are guaranteed accept cases and the
//! suite exercises both verdicts without depending on random luck.

use specdfa::engine::{
    CompiledMatcher, Engine, ExecPolicy, Matcher, Pattern,
};
use specdfa::util::rng::{test_seed, Rng};

/// The symbols patterns are built from.
const ALPHABET: &[u8] = b"abcd";
/// Input filler: the pattern alphabet plus bytes outside it.
const FILLER: &[u8] = b"abcdex .";

/// Seeded (pattern, witness) generator.  Repetition is only ever applied
/// to literal/class/alternation-of-literal bases — never nested — so the
/// backtracking comparator stays polynomial on every generated pattern.
struct PatternGen {
    rng: Rng,
}

impl PatternGen {
    fn literal(&mut self, len: usize) -> (String, Vec<u8>) {
        let mut p = String::new();
        let mut w = Vec::new();
        for _ in 0..len.max(1) {
            let c = ALPHABET[self.rng.usize_below(ALPHABET.len())];
            p.push(c as char);
            w.push(c);
        }
        (p, w)
    }

    fn class(&mut self) -> (String, Vec<u8>) {
        let mut members = ALPHABET.to_vec();
        self.rng.shuffle(&mut members);
        let k = 2 + self.rng.usize_below(ALPHABET.len() - 1);
        members.truncate(k);
        let p = format!("[{}]", String::from_utf8(members.clone()).unwrap());
        let w = vec![members[self.rng.usize_below(k)]];
        (p, w)
    }

    fn alternation(&mut self) -> (String, Vec<u8>) {
        let n = 2 + self.rng.usize_below(2);
        let branches: Vec<(String, Vec<u8>)> = (0..n)
            .map(|_| self.literal(1 + self.rng.usize_below(3)))
            .collect();
        let p = format!(
            "({})",
            branches
                .iter()
                .map(|(s, _)| s.as_str())
                .collect::<Vec<_>>()
                .join("|")
        );
        let w = branches[self.rng.usize_below(n)].1.clone();
        (p, w)
    }

    /// One concatenation element; the bool says whether the piece can
    /// match the empty string.
    fn piece(&mut self) -> (String, Vec<u8>, bool) {
        let (base_p, base_w) = match self.rng.usize_below(3) {
            0 => self.literal(1 + self.rng.usize_below(3)),
            1 => self.class(),
            _ => self.alternation(),
        };
        // the witness of `(x)op` is one copy of x for every op we emit:
        // `+` needs >= 1 copy, `?` and `*` admit exactly one copy
        match self.rng.usize_below(6) {
            0 => (format!("({base_p})+"), base_w, false),
            1 => (format!("({base_p})?"), base_w, true),
            2 => (format!("({base_p})*"), base_w, true),
            _ => (base_p, base_w, false),
        }
    }

    /// A full pattern (2..=4 pieces) that cannot match the empty string,
    /// with a witness from its language.
    fn pattern(&mut self) -> (String, Vec<u8>) {
        let pieces = 2 + self.rng.usize_below(3);
        let mut p = String::new();
        let mut w = Vec::new();
        let mut nonempty = false;
        for _ in 0..pieces {
            let (pp, ww, can_empty) = self.piece();
            p.push_str(&pp);
            w.extend(ww);
            nonempty |= !can_empty;
        }
        if !nonempty {
            // anchor the language away from epsilon so "search accepts
            // everything" never trivializes a case
            let (pp, ww) = self.literal(2);
            p.push_str(&pp);
            w.extend(ww);
        }
        (p, w)
    }

    fn text(&mut self, len: usize) -> Vec<u8> {
        (0..len)
            .map(|_| FILLER[self.rng.usize_below(FILLER.len())])
            .collect()
    }

    /// Nested-repeat piece with its witness, up to `depth` grouping
    /// levels (e.g. `((ab|c)+d){1,3}`).  Only safe for the DFA-only
    /// corpus: the backtracking comparator would go exponential here.
    fn nested(&mut self, depth: usize) -> (String, Vec<u8>) {
        let (p, w) = if depth == 0 {
            match self.rng.usize_below(3) {
                0 => self.literal(1 + self.rng.usize_below(2)),
                1 => self.class(),
                _ => self.alternation(),
            }
        } else {
            let (a, mut wa) = self.nested(depth - 1);
            let (b, wb) = self.nested(depth - 1);
            wa.extend(wb);
            (format!("{a}{b}"), wa)
        };
        // one copy of the body witnesses every quantifier we emit
        match self.rng.usize_below(4) {
            0 => (format!("({p})+"), w),
            1 => (format!("({p}){{1,3}}"), w),
            2 => (format!("({p})*"), w),
            _ => (p, w),
        }
    }
}

fn plant(text: &mut [u8], witness: &[u8], pos: usize) {
    if witness.is_empty() || witness.len() > text.len() {
        return;
    }
    let pos = pos.min(text.len() - witness.len());
    text[pos..pos + witness.len()].copy_from_slice(witness);
}

/// The number of processors every multicore engine runs with — chunk
/// boundaries land at multiples of n/PROCS.
const PROCS: usize = 4;

fn policy() -> ExecPolicy {
    ExecPolicy {
        processors: PROCS,
        lookahead: 2,
        // bounded so a pathological backtracking case degrades into a
        // skipped comparison instead of a hung suite
        backtrack_fuel: 1 << 22,
        ..ExecPolicy::default()
    }
}

/// All 8 adapters under test (the sequential reference is compiled
/// separately).
fn engines() -> Vec<(&'static str, Engine)> {
    vec![
        ("seq", Engine::Sequential),
        ("spec", Engine::Speculative { adaptive: false }),
        ("spec-adaptive", Engine::Speculative { adaptive: true }),
        ("simd", Engine::Simd { variant: None }),
        ("cloud", Engine::Cloud { nodes: 3 }),
        ("shard", Engine::Shard { nodes: 3 }),
        ("holub", Engine::HolubStekr),
        ("backtrack", Engine::Backtracking),
        ("grep", Engine::GrepLike),
    ]
}

/// Run one (pattern, input) case through every engine and compare with
/// the sequential reference.  Returns whether the reference accepted.
fn check_case(
    pattern: &str,
    reference: &CompiledMatcher,
    matchers: &[(&'static str, CompiledMatcher)],
    input: &[u8],
    label: &str,
) -> bool {
    let want = reference
        .run_bytes(input)
        .unwrap_or_else(|e| panic!("sequential failed on {pattern:?}: {e:#}"));
    for (name, cm) in matchers {
        match cm.run_bytes(input) {
            Ok(out) => {
                assert_eq!(
                    out.accepted, want.accepted,
                    "{name} disagrees on acceptance: pattern={pattern:?} \
                     case={label} n={}",
                    input.len()
                );
                if let (Some(got), Some(exp)) =
                    (out.final_state, want.final_state)
                {
                    assert_eq!(
                        got, exp,
                        "{name} disagrees on final state: \
                         pattern={pattern:?} case={label} n={}",
                        input.len()
                    );
                }
            }
            Err(e) => {
                // the only tolerated failure is backtracking running out
                // of its (deliberately small) fuel budget
                let msg = format!("{e:#}");
                assert!(
                    msg.contains("fuel"),
                    "{name} failed on pattern={pattern:?} case={label}: {msg}"
                );
            }
        }
    }
    want.accepted
}

#[test]
fn randomized_corpus_all_engines_agree_with_sequential() {
    let seed = test_seed(0xD1FF_2024);
    eprintln!(
        "differential corpus seed: {seed:#x} \
         (SPECDFA_TEST_SEED={seed:#x} reproduces this corpus exactly)"
    );
    let mut gen = PatternGen { rng: Rng::new(seed) };
    let mut cases = 0usize;
    let mut accepts = 0usize;
    let mut rejects = 0usize;

    // fixed regression patterns with hand-picked witnesses, then the
    // seeded random corpus
    let mut corpus: Vec<(String, Vec<u8>)> = vec![
        ("(ab|cd)+e?".to_string(), b"abcd".to_vec()),
        ("a+b".to_string(), b"aab".to_vec()),
        ("needle".to_string(), b"needle".to_vec()),
        ("[ab]c[cd]".to_string(), b"acd".to_vec()),
    ];
    for _ in 0..36 {
        corpus.push(gen.pattern());
    }

    for (pattern, witness) in &corpus {
        let pat = Pattern::Regex(pattern.clone());
        let reference =
            CompiledMatcher::compile(&pat, Engine::Sequential, policy())
                .unwrap_or_else(|e| {
                    panic!("compile {pattern:?} failed: {e:#}")
                });
        let matchers: Vec<(&'static str, CompiledMatcher)> = engines()
            .into_iter()
            .map(|(name, engine)| {
                let cm = CompiledMatcher::compile(&pat, engine, policy())
                    .unwrap_or_else(|e| {
                        panic!("compile {pattern:?} for {name}: {e:#}")
                    });
                (name, cm)
            })
            .collect();

        // 1. empty input
        // 2. tiny input (shorter than the processor count)
        // 3. mid-size random input, unplanted
        // 4. witness planted straddling the first chunk boundary
        // 5. witness planted at position 0 and at the very end
        // 6. the witness alone
        let tiny_len = 1 + gen.rng.usize_below(PROCS);
        let tiny = gen.text(tiny_len);
        let unplanted_len = 600 + gen.rng.usize_below(600);
        let unplanted = gen.text(unplanted_len);
        let n4 = 1200 + gen.rng.usize_below(400);
        let mut boundary = gen.text(n4);
        plant(
            &mut boundary,
            witness,
            (n4 / PROCS).saturating_sub(witness.len() / 2),
        );
        let n5 = 1400 + gen.rng.usize_below(400);
        let mut ends = gen.text(n5);
        plant(&mut ends, witness, 0);
        plant(&mut ends, witness, n5.saturating_sub(witness.len()));
        let inputs: [(&str, &[u8]); 6] = [
            ("empty", b""),
            ("tiny", &tiny),
            ("unplanted", &unplanted),
            ("boundary-planted", &boundary),
            ("ends-planted", &ends),
            ("witness", witness),
        ];
        for (label, input) in inputs {
            let accepted =
                check_case(pattern, &reference, &matchers, input, label);
            cases += 1;
            if accepted {
                accepts += 1;
            } else {
                rejects += 1;
            }
            if label == "boundary-planted" || label == "witness" {
                assert!(
                    accepted,
                    "planted witness must be found: pattern={pattern:?} \
                     case={label}"
                );
            }
        }
    }

    assert!(cases >= 200, "need >= 200 differential cases, got {cases}");
    assert!(
        accepts >= corpus.len() && rejects > 0,
        "corpus must exercise both verdicts: {accepts} accepts, \
         {rejects} rejects over {cases} cases"
    );
}

/// The DFA-table engines (final-state comparable, no pattern AST
/// needed).  Nested repeats and anchored/exact patterns are fair game
/// here — the AST comparators that constrain the main corpus are out.
fn dfa_only_engines() -> Vec<(&'static str, Engine)> {
    vec![
        ("seq", Engine::Sequential),
        ("spec", Engine::Speculative { adaptive: false }),
        ("spec-adaptive", Engine::Speculative { adaptive: true }),
        ("simd", Engine::Simd { variant: None }),
        ("cloud", Engine::Cloud { nodes: 3 }),
        ("shard", Engine::Shard { nodes: 3 }),
        ("holub", Engine::HolubStekr),
    ]
}

#[test]
fn dfa_only_corpus_nested_repeats_and_anchors() {
    // the deepened fuzz mode: nested repeats, start/end anchors, and
    // whole-input (RegexExact) semantics — checked across every DFA
    // engine, with the serving default convergence collapsing on
    let seed = test_seed(0xD1FF_4202);
    eprintln!(
        "DFA-only corpus seed: {seed:#x} \
         (SPECDFA_TEST_SEED={seed:#x} reproduces this corpus exactly)"
    );
    let mut gen = PatternGen { rng: Rng::new(seed) };
    let mut cases = 0usize;
    for round in 0..24usize {
        let (core, witness) = gen.nested(2);
        let (pattern, assert_planted) = match round % 4 {
            0 => (Pattern::Regex(core.clone()), true),
            1 => (Pattern::Regex(format!("^{core}")), false),
            2 => (Pattern::Regex(format!("{core}$")), false),
            _ => (Pattern::RegexExact(core.clone()), false),
        };
        let reference =
            CompiledMatcher::compile(&pattern, Engine::Sequential, policy())
                .unwrap_or_else(|e| panic!("compile {core:?}: {e:#}"));
        let matchers: Vec<(&'static str, CompiledMatcher)> =
            dfa_only_engines()
                .into_iter()
                .map(|(name, engine)| {
                    let cm =
                        CompiledMatcher::compile(&pattern, engine, policy())
                            .unwrap_or_else(|e| {
                                panic!("compile {core:?} for {name}: {e:#}")
                            });
                    (name, cm)
                })
                .collect();

        let n = 900 + gen.rng.usize_below(600);
        let mut planted = gen.text(n);
        plant(
            &mut planted,
            &witness,
            (n / PROCS).saturating_sub(witness.len() / 2),
        );
        let mut at_start = gen.text(n);
        plant(&mut at_start, &witness, 0);
        let unplanted = gen.text(n);
        let inputs: [(&str, &[u8]); 5] = [
            ("empty", b""),
            ("witness", &witness),
            ("boundary-planted", &planted),
            ("start-planted", &at_start),
            ("unplanted", &unplanted),
        ];
        for (label, input) in inputs {
            let label = format!("{label} (round {round})");
            let accepted = check_case(
                &core,
                &reference,
                &matchers,
                input,
                &label,
            );
            cases += 1;
            if assert_planted
                && !witness.is_empty()
                && (label.starts_with("boundary-planted")
                    || label.starts_with("witness"))
            {
                assert!(
                    accepted,
                    "planted witness must be found: {core:?} {label}"
                );
            }
        }
        // whole-input semantics: the witness itself is in the language
        if matches!(pattern, Pattern::RegexExact(_)) {
            let out = reference.run_bytes(&witness).unwrap();
            assert!(
                out.accepted,
                "witness {witness:?} must satisfy {core:?} exactly"
            );
        }
    }
    assert!(cases >= 100, "need >= 100 DFA-only cases, got {cases}");
}

#[test]
fn boundary_sweep_on_a_structured_pattern() {
    // sweep the planted-match position across every chunk boundary,
    // +/- 1 symbol, at several processor counts — the exact positions
    // where L-vector split/combine errors appear
    let pattern = Pattern::Regex("(ab|cd)+e".to_string());
    let witness: &[u8] = b"abcde";
    let n = 4096;
    for procs in [2, 3, 4, 7, 8] {
        let pol = ExecPolicy { processors: procs, ..policy() };
        let reference = CompiledMatcher::compile(
            &pattern,
            Engine::Sequential,
            pol.clone(),
        )
        .unwrap();
        let spec = CompiledMatcher::compile(
            &pattern,
            Engine::Speculative { adaptive: false },
            pol.clone(),
        )
        .unwrap();
        let holub =
            CompiledMatcher::compile(&pattern, Engine::HolubStekr, pol)
                .unwrap();
        let mut rng = Rng::new(procs as u64);
        for k in 1..procs {
            let boundary = n * k / procs;
            for offset in [-1i64, 0, 1] {
                let pos = (boundary as i64 + offset
                    - (witness.len() / 2) as i64)
                    .clamp(0, (n - witness.len()) as i64)
                    as usize;
                let mut text: Vec<u8> = (0..n)
                    .map(|_| FILLER[rng.usize_below(FILLER.len())])
                    .collect();
                plant(&mut text, witness, pos);
                let want = reference.run_bytes(&text).unwrap();
                assert!(want.accepted, "witness planted at {pos}");
                for cm in [&spec, &holub] {
                    let out = cm.run_bytes(&text).unwrap();
                    assert_eq!(out.accepted, want.accepted);
                    assert_eq!(out.final_state, want.final_state);
                }
            }
        }
    }
}
