//! Differential suite for `engine::stream`: segment-streamed matching
//! must be observationally identical to the one-shot matcher whatever
//! the segmentation.
//!
//!  * random (pattern, input, segmentation) triples — 1-byte and empty
//!    segments included — with checkpoint serialization round-trips
//!    injected at random boundaries mid-stream;
//!  * a deterministic sweep resuming from a `to_bytes`/`from_bytes`
//!    round-trip at EVERY byte boundary of one input;
//!  * preempt/resume under the serve loop: a probe flood preempts a
//!    corpus scan (`ServeConfig::preempt_scans`), the parked checkpoint
//!    is resumed, and the scan's verdict still equals the one-shot run.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

use specdfa::engine::{
    Checkpoint, CompiledMatcher, Engine, EngineKind, ExecPolicy, Matcher,
    Pattern, ServeConfig, Server, StreamMatcher,
};
use specdfa::util::prop;
use specdfa::util::rng::Rng;
use specdfa::workload::InputGen;

/// The symbols patterns are built from.
const ALPHABET: &[u8] = b"abc";
/// Input filler: the pattern alphabet plus bytes outside it.
const FILLER: &[u8] = b"abcx .";

/// One random pattern together with a witness string from its language.
fn gen_pattern(rng: &mut Rng) -> (String, Vec<u8>) {
    let lit = |rng: &mut Rng, len: usize| -> (String, Vec<u8>) {
        let mut p = String::new();
        let mut w = Vec::new();
        for _ in 0..len.max(1) {
            let c = ALPHABET[rng.usize_below(ALPHABET.len())];
            p.push(c as char);
            w.push(c);
        }
        (p, w)
    };
    match rng.usize_below(3) {
        0 => lit(rng, 2 + rng.usize_below(3)),
        1 => {
            let (a, wa) = lit(rng, 1 + rng.usize_below(3));
            let (b, _) = lit(rng, 1 + rng.usize_below(3));
            (format!("({a}|{b})"), wa)
        }
        _ => {
            let (a, wa) = lit(rng, 1 + rng.usize_below(2));
            let (b, wb) = lit(rng, 2);
            let mut w = wa.clone();
            w.extend(&wb);
            (format!("({a})+{b}"), w)
        }
    }
}

fn compile(pattern: &str) -> CompiledMatcher {
    CompiledMatcher::compile(
        &Pattern::Regex(pattern.to_string()),
        Engine::Sequential,
        ExecPolicy::default(),
    )
    .expect("compile")
}

#[test]
fn prop_any_segmentation_equals_one_shot() {
    prop::check("stream == one-shot under any segmentation", 40, |rng| {
        let (pat, witness) = gen_pattern(rng);
        let cm = compile(&pat);
        let n = 1 + rng.usize_below(600);
        let mut input: Vec<u8> = (0..n)
            .map(|_| FILLER[rng.usize_below(FILLER.len())])
            .collect();
        if rng.chance(0.6) && witness.len() < n {
            let pos = rng.usize_below(n - witness.len());
            input[pos..pos + witness.len()].copy_from_slice(&witness);
        }
        let want = cm.run_bytes(&input).expect("one-shot");
        let fold = 1 + rng.usize_below(64);
        let mut sm = StreamMatcher::with_fold_bytes(&cm, fold);
        let mut pos = 0;
        while pos < input.len() {
            if rng.chance(0.15) {
                sm.feed(b""); // empty segments are legal no-ops
            }
            let mut len = 1 + rng.usize_below(48);
            if rng.chance(0.3) {
                len = 1; // 1-byte segments with positive probability
            }
            let end = input.len().min(pos + len);
            let progress = sm.feed(&input[pos..end]);
            pos = end;
            assert_eq!(progress.offset, pos as u64, "{pat} fold={fold}");
            // serialize + resume mid-stream at random boundaries: the
            // wire round-trip must be invisible in the outcome
            if rng.chance(0.25) {
                let bytes = sm.checkpoint().to_bytes();
                let ck = Checkpoint::from_bytes(&bytes).expect("decode");
                assert_eq!(ck.offset(), pos as u64);
                sm = StreamMatcher::from_checkpoint(&cm, ck)
                    .expect("resume");
                sm.set_fold_bytes(fold);
            }
        }
        let out = sm.finish();
        assert_eq!(out.accepted, want.accepted, "{pat} n={n} fold={fold}");
        assert_eq!(out.final_state, want.final_state, "{pat} fold={fold}");
        assert_eq!(out.n, input.len());
        assert_eq!(out.engine, EngineKind::Stream);
    });
}

#[test]
fn checkpoint_roundtrip_resumes_at_every_boundary() {
    let cm = compile("(ab|ca)+bc");
    let mut gen = Rng::new(0xC4);
    let input: Vec<u8> = (0..257)
        .map(|_| FILLER[gen.usize_below(FILLER.len())])
        .collect();
    let want = cm.run_bytes(&input).expect("one-shot");
    for cut in 0..=input.len() {
        let mut head = StreamMatcher::with_fold_bytes(&cm, 16);
        head.feed(&input[..cut]);
        let bytes = head.checkpoint().to_bytes();
        let ckpt = Checkpoint::from_bytes(&bytes).expect("frame decodes");
        assert_eq!(ckpt.offset(), cut as u64, "cut {cut}");
        let mut tail =
            StreamMatcher::from_checkpoint(&cm, ckpt).expect("resume");
        tail.feed(&input[cut..]);
        let out = tail.finish();
        assert_eq!(out.accepted, want.accepted, "cut {cut}");
        assert_eq!(out.final_state, want.final_state, "cut {cut}");
        assert_eq!(out.n, input.len(), "cut {cut}");
    }
}

/// Spin until `cond` holds (30 s hard cap).
fn wait_until(mut cond: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !cond() {
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "condition timed out"
        );
        std::thread::yield_now();
    }
}

#[test]
fn preempted_scan_resumes_and_reports_the_one_shot_verdict() {
    let server = Server::start(ServeConfig {
        workers: 1,
        preempt_scans: true,
        preempt_segment_bytes: 8 << 10,
        probe_max_bytes: 1 << 10,
        age_limit: 1,
        max_queue: 64,
        calibrate_on_start: false,
        recalibrate_every: 0,
        cache_outcomes: 0,
        profile_per_worker: false,
        engine: Engine::Sequential,
        ..ServeConfig::default()
    })
    .expect("server");
    // the scan's only witness sits at the very end of the corpus, so a
    // lost resume is observable as a wrong verdict — ascii_text emits
    // lowercase only, the uppercase witness occurs nowhere else
    let mut scan_input = InputGen::new(0xD1CE).ascii_text(512 << 10);
    let n = scan_input.len();
    scan_input[n - 4..].copy_from_slice(b"ZQZQ");
    let scan_pattern = Pattern::Regex("ZQZQ".to_string());
    let want = CompiledMatcher::compile(
        &scan_pattern,
        Engine::Sequential,
        ExecPolicy::default(),
    )
    .expect("compile")
    .run_bytes(&scan_input)
    .expect("one-shot");
    assert!(want.accepted, "the planted witness must match");

    let stop = AtomicBool::new(false);
    let out = std::thread::scope(|scope| {
        let server = &server;
        let stop = &stop;
        let flooder = scope.spawn(move || {
            let probe = Pattern::Regex("qz".to_string());
            let mut sent = 0u64;
            while !stop.load(Ordering::Relaxed) {
                // Block admission paces the flood to the service rate,
                // so probes are (virtually) always live at the scan's
                // segment boundaries
                drop(server.submit(probe.clone(), &b"aqzb"[..]));
                sent += 1;
            }
            sent
        });
        // let the flood reach steady state before the scan arrives
        wait_until(|| server.stats().served >= 64);
        let out = server
            .submit(scan_pattern.clone(), scan_input.clone())
            .wait()
            .expect("scan serves");
        stop.store(true, Ordering::Relaxed);
        assert!(flooder.join().unwrap() > 0);
        out
    });

    assert_eq!(out.accepted, want.accepted);
    assert_eq!(out.final_state, want.final_state);
    assert_eq!(out.n, want.n);
    assert_eq!(
        out.engine,
        EngineKind::Stream,
        "a preemptible scan is served through the stream wrapper"
    );
    let stats = server.shutdown();
    assert!(
        stats.preemptions >= 1,
        "the probe flood must park the scan at least once"
    );
    assert!(
        stats.resumed_scans >= 1,
        "a parked scan must be resumed from its checkpoint"
    );
    assert_eq!(stats.failed, 0);
}

/// Satellite: the `SDCK` wire format under hostile bytes.  Whatever we
/// do to a serialized frame — cut it anywhere, flip any bit, append
/// garbage — `Checkpoint::from_bytes` must return an error or a
/// well-formed checkpoint; it must never panic.  And a frame that does
/// parse but belongs to a different automaton must be refused at
/// resume time (wrong |Q|), not silently continued.
#[test]
fn checkpoint_frame_survives_corruption_without_panicking() {
    let seed = specdfa::util::rng::test_seed(0x5DC4_2026);
    eprintln!(
        "corruption seed: {seed:#x} (SPECDFA_TEST_SEED={seed:#x} replays)"
    );
    let cm = compile("(ab|ba)+c");
    let mut sm = StreamMatcher::with_fold_bytes(&cm, 8);
    sm.feed(b"abbaabba"); // folds once
    sm.feed(b"abb"); // leaves pending bytes in the frame
    let ckpt = sm.checkpoint();
    let frame = ckpt.to_bytes();

    // the untouched frame round-trips exactly
    let rt = Checkpoint::from_bytes(&frame).expect("valid frame parses");
    assert_eq!(rt, ckpt);

    // (1) truncation at EVERY byte boundary is rejected
    for cut in 0..frame.len() {
        assert!(
            Checkpoint::from_bytes(&frame[..cut]).is_err(),
            "a {cut}-byte prefix of a {}-byte frame parsed",
            frame.len()
        );
    }

    // (2) trailing garbage is rejected — a frame is exact, not a prefix
    for extra in [1usize, 7, 64] {
        let mut long = frame.clone();
        long.extend(std::iter::repeat(0xA5).take(extra));
        assert!(
            Checkpoint::from_bytes(&long).is_err(),
            "{extra} trailing bytes accepted"
        );
    }

    // (3) every single-bit flip either fails to parse or yields a
    // well-formed checkpoint (flips inside pending bytes or counters
    // are legitimately undetectable without a checksum) — never a panic
    let mut parsed_ok = 0usize;
    for bit in 0..frame.len() * 8 {
        let mut bad = frame.clone();
        bad[bit / 8] ^= 1 << (bit % 8);
        if let Ok(c) = Checkpoint::from_bytes(&bad) {
            parsed_ok += 1;
            // whatever parsed is internally consistent
            assert!(c.num_states() > 0);
            assert!(c.offset() >= c.buffered() as u64);
        }
    }
    // structural fields dominate the frame, so most flips must be caught
    assert!(
        parsed_ok < frame.len() * 8 / 2,
        "{parsed_ok} of {} bit flips went unnoticed",
        frame.len() * 8
    );

    // (4) random garbage never parses (the magic gate)
    let mut rng = Rng::new(seed);
    for _ in 0..200 {
        let n = rng.usize_below(96);
        let junk: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
        assert!(Checkpoint::from_bytes(&junk).is_err());
    }

    // (5) a valid frame for a DIFFERENT automaton parses but must be
    // refused at resume: |Q| mismatch is a hard error, not a guess
    // a long literal needs one chain state per character, so its |Q|
    // cannot collide with the small alternation DFA above
    let other = compile("aabbaabbaacc");
    let other_ckpt = StreamMatcher::new(&other).checkpoint();
    assert_ne!(
        other_ckpt.num_states(),
        ckpt.num_states(),
        "test premise: the two DFAs must differ in |Q|"
    );
    let alien = Checkpoint::from_bytes(&other_ckpt.to_bytes()).unwrap();
    assert!(
        StreamMatcher::from_checkpoint(&cm, alien).is_err(),
        "resumed a checkpoint from a different automaton"
    );

    // (6) and the happy path still works end to end after all that:
    // resume from the serialized frame and finish equals one-shot
    let resumed = Checkpoint::from_bytes(&frame).unwrap();
    let mut sm2 = StreamMatcher::from_checkpoint(&cm, resumed).unwrap();
    sm2.feed(b"aabbac");
    let full: Vec<u8> = b"abbaabba".iter().chain(b"abb").chain(b"aabbac").copied().collect();
    let want = cm.run_bytes(&full).unwrap();
    let got = sm2.finish();
    assert_eq!(got.accepted, want.accepted);
    assert_eq!(got.final_state, want.final_state);
}
