//! Vendored, offline subset of the `anyhow` error-handling API.
//!
//! The build environment has no crate registry, so this path dependency
//! provides the pieces of `anyhow` this workspace actually uses with the
//! same names and semantics:
//!
//!  * [`Error`] — a context chain; `Display` prints the outermost
//!    message, `{:#}` prints the whole chain joined by `": "`.
//!  * [`Result<T>`] with the `Error` default type parameter.
//!  * [`anyhow!`], [`bail!`], [`ensure!`] macros.
//!  * [`Context`] for attaching lazy context to `Result`s.
//!  * `From<E: std::error::Error>` so `?` converts concrete errors.
//!
//! If the real crates.io `anyhow` becomes available, deleting this
//! directory and switching the dependency line is a drop-in change.

use std::fmt;

/// Context-chain error. `chain[0]` is the outermost (most recent) message.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message (the `anyhow!` constructor).
    pub fn msg(message: impl fmt::Display) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Prepend a context message (outermost-first ordering).
    pub fn context(mut self, message: impl fmt::Display) -> Error {
        self.chain.insert(0, message.to_string());
        self
    }

    /// The error chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The root (innermost) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full chain, like anyhow
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with `Error` default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to the error branch of a `Result`.
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    /// Wrap the error value with lazily evaluated context.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            return ::std::result::Result::Err($crate::Error::msg(
                ::std::concat!("condition failed: ", ::std::stringify!($cond)),
            ));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_err() -> Result<u32> {
        let n: u32 = "zzz".parse().context("parsing zzz")?;
        Ok(n)
    }

    #[test]
    fn context_chain_formats() {
        let err = parse_err().unwrap_err();
        assert_eq!(format!("{err}"), "parsing zzz");
        let full = format!("{err:#}");
        assert!(full.starts_with("parsing zzz: "), "{full}");
        assert!(full.contains("invalid digit"), "{full}");
    }

    #[test]
    fn macros_build_errors() {
        let x = 3;
        let e = anyhow!("bad value {x}");
        assert_eq!(e.to_string(), "bad value 3");
        let e = anyhow!("bad {} value", "x");
        assert_eq!(e.to_string(), "bad x value");

        fn bails(flag: bool) -> Result<()> {
            ensure!(flag, "flag was {flag}");
            bail!("unreachable {}", 7)
        }
        assert_eq!(bails(false).unwrap_err().to_string(), "flag was false");
        assert_eq!(bails(true).unwrap_err().to_string(), "unreachable 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn read() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(read().is_err());
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: std::result::Result<u32, std::num::ParseIntError> =
            "42".parse();
        let n = ok.with_context(|| -> String {
            panic!("context closure must not run on Ok")
        });
        assert!(matches!(n, Ok(42)));
    }
}
