//! SimdMatcher: the fully vectorized speculative DFA membership test of
//! §5.1 (Listing 2), executed on the PJRT vector unit.
//!
//! The paper packs 8 (chunk × initial-state) speculative matches into one
//! AVX2 register and steps them in lockstep with gather loads.  Here the
//! lanes of the AOT-compiled Pallas kernel play that role:
//!
//!  * the input is split into k uniform chunks with
//!    k = 1 + max(1, ⌊(lanes−1)/I_max⌋) (uniform because lockstep lanes
//!    all advance one symbol per step — unlike the multicore partition,
//!    unequal chunks would idle lanes, §6.1's observed overhead),
//!  * lane slots are (chunk, initial-state) pairs; chunk 0 occupies one
//!    lane, every subsequent chunk up to I_max lanes,
//!  * lanes advance `t` symbols per PJRT call; rust carries the state
//!    vector between calls exactly as Listing 2 carries `States`.
//!
//! Instruction accounting mirrors the paper's SDE methodology (§6.1):
//! speedups are ratios of executed work, with the Listing-1 scalar loop at
//! 5 instructions/symbol and the Listing-2 vector loop at 9
//! instructions/step (their 8-lane ratio 8·5/9 ≈ 4.4 matches the measured
//! 4.45× of Fig. 13).

use std::sync::Arc;

use anyhow::Result;

use crate::automata::Dfa;
use crate::speculative::lookahead::Lookahead;
use crate::speculative::lvector::LVector;
use crate::speculative::merge::{self, MergeStrategy};

use super::pjrt::{pad_table, VectorUnit};

/// Listing 1: two adds, one indexed load, one cmp, one conditional jump.
pub const SCALAR_OPS_PER_SYM: f64 = 5.0;
/// Listing 2: two gathers, two adds, loop decrement + branch, plus loop
/// maintenance — 9 instructions per 8-lane step (§5.1, incl. the saved
/// cmp from counting down).
pub const VECTOR_OPS_PER_STEP: f64 = 9.0;

/// Result of one vector-unit run (the work model of §6.1).
#[derive(Clone, Debug)]
pub struct SimdOutcome {
    /// delta*(q0, input)
    pub final_state: u32,
    /// membership verdict
    pub accepted: bool,
    /// symbols a scalar sequential run would execute (= n)
    pub scalar_syms: u64,
    /// lockstep vector steps under full lane packing (the model the
    /// paper's SIMD evaluation measures): chunk_len × passes
    pub vector_steps: u64,
    /// lane slots used: 1 + Σ |set_i|
    pub lane_slots: usize,
    /// register passes needed: ⌈lane_slots / lanes⌉
    pub passes: usize,
    /// PJRT executions performed
    pub pjrt_calls: u64,
    /// wall time of the PJRT executions, seconds (reference only; the
    /// interpret-mode CPU executable is not a TPU performance proxy)
    pub wall_s: f64,
}

impl SimdOutcome {
    /// Work-ratio speedup over scalar (chunk parallelism only).
    pub fn chunk_speedup(&self) -> f64 {
        self.scalar_syms as f64 / self.vector_steps.max(1) as f64
    }

    /// Instruction-ratio speedup (the Fig. 13 metric): scalar instructions
    /// over vector instructions for the same membership test.
    pub fn instr_speedup(&self) -> f64 {
        (self.scalar_syms as f64 * SCALAR_OPS_PER_SYM)
            / (self.vector_steps.max(1) as f64 * VECTOR_OPS_PER_STEP)
    }
}

/// Owns its DFA and shares the (compile-once) vector unit via `Arc`, so a
/// matcher can be kept hot across requests — the [`crate::engine`] facade
/// builds one per pattern.
pub struct SimdMatcher {
    dfa: Dfa,
    vu: Arc<VectorUnit>,
    lookahead: Option<Lookahead>,
    padded_table: Vec<i32>,
}

impl SimdMatcher {
    /// Build over `dfa`, padding its table to the unit's shape.
    pub fn new(dfa: &Dfa, vu: &Arc<VectorUnit>) -> Result<Self> {
        let padded_table = pad_table(
            &dfa.table,
            dfa.num_states as usize,
            dfa.num_symbols as usize,
            &vu.spec,
        )?;
        Ok(SimdMatcher {
            dfa: dfa.clone(),
            vu: Arc::clone(vu),
            lookahead: None,
            padded_table,
        })
    }

    /// Enable the I_max,r optimization with `r` reverse lookahead symbols.
    pub fn lookahead(mut self, r: usize) -> Self {
        self.lookahead =
            if r > 0 { Some(Lookahead::analyze(&self.dfa, r)) } else { None };
        self
    }

    /// Inject a precomputed lookahead analysis (must come from this DFA);
    /// see [`crate::speculative::matcher::MatchPlan::with_lookahead`].
    pub fn with_lookahead(mut self, la: Option<Lookahead>) -> Self {
        self.lookahead = la;
        self
    }

    /// The speculation parameter m: I_max,r with lookahead, |Q| without.
    pub fn i_max(&self) -> usize {
        self.lookahead
            .as_ref()
            .map(|la| la.i_max)
            .unwrap_or(self.dfa.num_states as usize)
            .max(1)
    }

    /// The compiled DFA.
    pub fn dfa(&self) -> &Dfa {
        &self.dfa
    }

    /// Match raw bytes (applies the IBase class mapping first).
    pub fn run(&self, input: &[u8]) -> Result<SimdOutcome> {
        self.run_syms(&self.dfa.map_input(input))
    }

    /// Match pre-mapped dense symbols on the vector unit.
    pub fn run_syms(&self, syms: &[u32]) -> Result<SimdOutcome> {
        let n = syms.len();
        let lanes = self.vu.spec.lanes;
        let q = self.dfa.num_states as usize;
        let m = self.i_max();
        // uniform chunk count for lockstep lanes
        let k = if m >= lanes { 2 } else { 1 + ((lanes - 1) / m).max(1) };
        let k = k.min(n.max(1));

        let bounds: Vec<(usize, usize)> =
            (0..k).map(|i| (n * i / k, n * (i + 1) / k)).collect();

        // upload the table once per run; per-call traffic is then just
        // the input tile + lane descriptors (§Perf)
        self.vu.set_table(&self.padded_table)?;

        let t0 = std::time::Instant::now();
        let calls0 = self.vu.calls();
        let mut lvecs: Vec<LVector> = Vec::with_capacity(k);
        let mut lane_slots = 0usize;
        for (i, &(start, end)) in bounds.iter().enumerate() {
            let set: Vec<u32> = if i == 0 {
                vec![self.dfa.start]
            } else {
                match &self.lookahead {
                    Some(la) => {
                        let lo = start.saturating_sub(la.r);
                        la.initial_set(&self.dfa, &syms[lo..start])
                            .iter()
                            .map(|s| s as u32)
                            .collect()
                    }
                    None => (0..q as u32).collect(),
                }
            };
            lane_slots += set.len();
            let mut lv = LVector::identity(q);
            for batch in set.chunks(lanes) {
                let finals = self.run_lanes(&syms[start..end], batch)?;
                for (&init, &fin) in batch.iter().zip(&finals) {
                    lv.set(init, fin as u32);
                }
            }
            lvecs.push(lv);
        }
        let (final_state, _) =
            merge::merge(&lvecs, self.dfa.start, MergeStrategy::Sequential);

        let passes = lane_slots.div_ceil(lanes);
        let chunk_len_max =
            bounds.iter().map(|&(s, e)| e - s).max().unwrap_or(0);
        Ok(SimdOutcome {
            final_state,
            accepted: self.dfa.accepting[final_state as usize],
            scalar_syms: n as u64,
            vector_steps: (chunk_len_max * passes) as u64,
            lane_slots,
            passes,
            pjrt_calls: self.vu.calls() - calls0,
            wall_s: t0.elapsed().as_secs_f64(),
        })
    }

    /// Advance one batch of initial states through one chunk, carrying the
    /// state vector across t-symbol PJRT calls (Listing 2's loop).
    fn run_lanes(&self, chunk: &[u32], inits: &[u32]) -> Result<Vec<i32>> {
        let sp = &self.vu.spec;
        let lanes = sp.lanes;
        assert!(inits.len() <= lanes);
        let mut states: Vec<i32> = (0..lanes)
            .map(|l| inits.get(l).copied().unwrap_or(0) as i32)
            .collect();
        let mut inp = vec![0i32; sp.n];
        let starts = vec![0i32; lanes];
        let mut pos = 0usize;
        while pos < chunk.len() {
            let t_eff = (chunk.len() - pos).min(sp.t);
            // the IBase window for this macro step (all lanes share the
            // chunk, so one segment at offset 0 serves every lane)
            for (dst, &sym) in
                inp[..t_eff].iter_mut().zip(&chunk[pos..pos + t_eff])
            {
                *dst = sym as i32;
            }
            let lens: Vec<i32> = (0..lanes)
                .map(|l| if l < inits.len() { t_eff as i32 } else { 0 })
                .collect();
            // pass our table every call: lane_match re-asserts residency
            // atomically (no-op when already resident), so another
            // matcher sharing this unit can never run us against its
            // transition table
            states = self
                .vu
                .lane_match(&self.padded_table, &inp, &starts, &lens, &states)?;
            pos += t_eff;
        }
        Ok(states)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn instruction_constants_give_paper_ratio() {
        // 8 lanes: 8·5/9 = 4.44x — the paper measured 4.45x (Fig. 13)
        let ratio = 8.0 * SCALAR_OPS_PER_SYM / VECTOR_OPS_PER_STEP;
        assert!((ratio - 4.45).abs() < 0.05, "ratio {ratio}");
    }

    // Execution tests live in rust/tests/pjrt_integration.rs (they need
    // the AOT artifacts produced by `make artifacts`).
}
