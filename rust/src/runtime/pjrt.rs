//! The system's **vector unit**: the lane-parallel DFA stepping kernel the
//! 8-wide AVX2 gather loop (Listing 2) plays in the paper.
//!
//! Two interchangeable backends stand behind one [`VectorUnit`] API:
//!
//!  * **Emulated** (default) — a pure-Rust interpreter of the lane_match /
//!    compose kernels with exactly the semantics of the AOT-lowered Pallas
//!    model (python/compile/model.py: per-lane window gather with index
//!    clipping, `lens`-masked stepping, Eq. (9) composition).  Needs no
//!    external crates and no compiled artifacts beyond the shape manifest,
//!    so `cargo test` exercises the full SIMD code path offline.
//!  * **PJRT** (feature `xla-pjrt`) — loads the HLO-text artifacts
//!    produced by `python/compile/aot.py` (jax ≥ 0.5 emits protos with
//!    64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//!    parser reassigns ids), compiles them with the PJRT CPU client and
//!    executes with concrete buffers.  Requires the unvendored `xla`
//!    bindings crate.
//!
//! Both backends share the artifact manifest (shape metadata) and the
//! device-resident-table protocol: `set_table` once, then `lane_match`
//! with an empty table slice (§Perf: re-uploading the padded table per
//! call — q·s·4 B ≈ 393 KiB for lane8_main — dominated the per-call cost).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use crate::automata::dfa::{with_sbase, SBase, SBaseWord, Width};

/// Static shape configuration of one lane_match variant (mirrors
/// python/compile/model.py::VariantSpec).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct VariantSpec {
    /// number of SIMD lanes
    pub lanes: usize,
    /// padded state count of the transition table
    pub q: usize,
    /// padded symbol count (row stride)
    pub s: usize,
    /// max symbols advanced per call
    pub t: usize,
    /// input window length
    pub n: usize,
    /// kernel block size along t
    pub block_t: usize,
}

impl VariantSpec {
    /// A spec sized to one concrete DFA, for the emulated backend: no
    /// padding waste, 8 lanes (the paper's AVX2 width).
    pub fn sized_to(num_states: usize, num_symbols: usize) -> VariantSpec {
        VariantSpec {
            lanes: 8,
            q: num_states.max(1),
            s: num_symbols.max(1),
            t: 4096,
            n: 4096,
            block_t: 512,
        }
    }
}

/// Parsed artifacts/manifest.tsv.
#[derive(Clone, Debug, Default)]
pub struct ArtifactManifest {
    /// lane_match variants by name
    pub lane_match: HashMap<String, VariantSpec>,
    /// padded L-vector width of the compose artifact
    pub compose_qp: Option<usize>,
}

impl ArtifactManifest {
    /// Parse `manifest.tsv` from the artifact directory.
    pub fn load(dir: &Path) -> Result<ArtifactManifest> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let mut m = ArtifactManifest::default();
        for (lineno, line) in text.lines().enumerate() {
            let f: Vec<&str> = line.split('\t').collect();
            let ctx = || format!("{path:?} line {}", lineno + 1);
            match f.as_slice() {
                [name, "lane_match", lanes, q, s, t, n, block_t] => {
                    m.lane_match.insert(
                        name.to_string(),
                        VariantSpec {
                            lanes: lanes.parse().with_context(ctx)?,
                            q: q.parse().with_context(ctx)?,
                            s: s.parse().with_context(ctx)?,
                            t: t.parse().with_context(ctx)?,
                            n: n.parse().with_context(ctx)?,
                            block_t: block_t.parse().with_context(ctx)?,
                        },
                    );
                }
                [_, "compose", qp, ..] => {
                    m.compose_qp = Some(qp.parse().with_context(ctx)?);
                }
                [] | [""] => {}
                _ => bail!("unrecognized manifest line: {line:?}"),
            }
        }
        Ok(m)
    }
}

enum Backend {
    /// Pure-Rust interpreter of the lane_match/compose kernels.
    Emulated,
    #[cfg(feature = "xla-pjrt")]
    Pjrt(xla_backend::PjrtState),
}

/// The unit-resident transition table: the raw padded i32 form (the
/// PJRT upload and residency-equality format) plus a width-compacted
/// *premultiplied* offset table that the emulated backend's in-range
/// fast path steps through — the same compact SBase kernel shape
/// (one clamp, one add, one indexed load per symbol) as the scalar
/// matchers.
struct ResidentTable {
    raw: Vec<i32>,
    fast: SBase,
}

impl ResidentTable {
    /// Premultiply and compact `raw` (entries are state ids, clamped to
    /// [0, q) exactly like the reference kernel does per step).
    fn new(sp: &VariantSpec, raw: Vec<i32>) -> ResidentTable {
        let q = sp.q as u32;
        let s = sp.s as u32;
        let offsets: Vec<u32> = raw
            .iter()
            .map(|&t| (t.max(0) as u32).min(q - 1) * s)
            .collect();
        let fast = SBase::compact(&offsets, Width::for_dfa(q, s));
        ResidentTable { raw, fast }
    }
}

/// A lane_match executable + its shape spec, behind one of two backends.
pub struct VectorUnit {
    backend: Backend,
    /// shape configuration of the loaded variant
    pub spec: VariantSpec,
    /// variant name (manifest key)
    pub name: String,
    /// executions performed (diagnostics / Fig. 13 instruction accounting);
    /// atomic so one unit can serve concurrent matcher threads
    calls: AtomicU64,
    /// unit-resident transition table set by `set_table` (the emulated
    /// analog of a device-resident buffer); a mutex because the serving
    /// path shares one compiled matcher across worker threads
    table: Mutex<Option<ResidentTable>>,
    /// padded L-vector width of the compose kernel; 0 = unavailable
    compose_qp: usize,
}

impl VectorUnit {
    /// Load variant `name` from the artifact directory.
    ///
    /// The manifest (shape metadata) is always required; the `.hlo.txt`
    /// executables are only read under the `xla-pjrt` feature — the
    /// default build interprets the kernel semantics directly.
    pub fn load(dir: impl AsRef<Path>, name: &str) -> Result<VectorUnit> {
        let dir = dir.as_ref();
        let manifest = ArtifactManifest::load(dir)?;
        let spec = *manifest
            .lane_match
            .get(name)
            .ok_or_else(|| anyhow!("variant {name:?} not in manifest"))?;
        let compose_qp = manifest.compose_qp.unwrap_or(0);
        let backend = Self::make_backend(dir, name)?;
        Ok(VectorUnit {
            backend,
            spec,
            name: name.to_string(),
            calls: AtomicU64::new(0),
            table: Mutex::new(None),
            compose_qp,
        })
    }

    #[cfg(not(feature = "xla-pjrt"))]
    fn make_backend(_dir: &Path, _name: &str) -> Result<Backend> {
        Ok(Backend::Emulated)
    }

    #[cfg(feature = "xla-pjrt")]
    fn make_backend(dir: &Path, name: &str) -> Result<Backend> {
        Ok(Backend::Pjrt(xla_backend::PjrtState::load(dir, name)?))
    }

    /// An artifact-free emulated unit with the given shapes — what
    /// [`crate::engine`] uses so the SIMD substrate works out of the box.
    pub fn emulated(name: &str, spec: VariantSpec) -> VectorUnit {
        VectorUnit {
            backend: Backend::Emulated,
            compose_qp: spec.q,
            spec,
            name: name.to_string(),
            calls: AtomicU64::new(0),
            table: Mutex::new(None),
        }
    }

    /// Upload a padded transition table to the unit once; subsequent
    /// `lane_match` calls reuse it (pass `table = &[]`).  Re-uploading an
    /// identical table is a no-op, so per-request callers (the serving
    /// path calls this once per run) pay one copy total, not one per run.
    pub fn set_table(&self, table: &[i32]) -> Result<()> {
        let sp = &self.spec;
        if table.len() != sp.q * sp.s {
            bail!("table len {} != q*s {}", table.len(), sp.q * sp.s);
        }
        let mut resident = self.table.lock().unwrap();
        if resident.as_ref().map(|r| r.raw.as_slice()) == Some(table) {
            return Ok(());
        }
        #[cfg(feature = "xla-pjrt")]
        if let Backend::Pjrt(state) = &self.backend {
            state.set_table(table)?;
        }
        *resident = Some(ResidentTable::new(&self.spec, table.to_vec()));
        Ok(())
    }

    /// Default artifact directory: $SPECDFA_ARTIFACTS, else the first of
    /// ./artifacts and ./rust/artifacts holding a manifest (so the CLI and
    /// examples work from both the workspace root and the crate root).
    pub fn default_dir() -> PathBuf {
        if let Some(dir) = std::env::var_os("SPECDFA_ARTIFACTS") {
            return PathBuf::from(dir);
        }
        for cand in ["artifacts", "rust/artifacts"] {
            let p = PathBuf::from(cand);
            if p.join("manifest.tsv").exists() {
                return p;
            }
        }
        PathBuf::from("artifacts")
    }

    /// Backend platform description ("emulated-cpu" or the PJRT platform).
    pub fn platform(&self) -> String {
        match &self.backend {
            Backend::Emulated => "emulated-cpu".to_string(),
            #[cfg(feature = "xla-pjrt")]
            Backend::Pjrt(state) => state.platform(),
        }
    }

    /// One vector step: advance every lane by up to `spec.t` symbols.
    ///
    /// * `table` — padded flat table, len q*s, entries are *state ids*
    ///   (not premultiplied offsets; the kernel indexes [q, s]).  Pass an
    ///   empty slice to reuse the unit-resident table from `set_table`
    ///   (the fast path — saves ~400 KiB of host->device traffic/call on
    ///   the PJRT backend).
    /// * `inp` — symbol window, len n.
    /// * `starts`/`lens`/`init` — per-lane descriptors, len lanes.
    ///
    /// Kernel semantics (python/compile/model.py): per-lane gather
    /// `inp[clip(start + i, 0, n-1)]`, `lens` clipped to `t`, each lane
    /// stepping `state = table[state, sym]` for `i < len`.
    pub fn lane_match(
        &self,
        table: &[i32],
        inp: &[i32],
        starts: &[i32],
        lens: &[i32],
        init: &[i32],
    ) -> Result<Vec<i32>> {
        let sp = &self.spec;
        if inp.len() != sp.n {
            bail!("input window len {} != n {}", inp.len(), sp.n);
        }
        for (nm, v) in [("starts", starts), ("lens", lens), ("init", init)] {
            if v.len() != sp.lanes {
                bail!("{nm} len {} != lanes {}", v.len(), sp.lanes);
            }
        }
        // residency check + execution under ONE lock acquisition: two
        // matchers for different DFAs may share this unit across threads,
        // and a set_table/lane_match pair that isn't atomic would run one
        // matcher's input against the other's transition table
        let mut resident = self.table.lock().unwrap();
        if !table.is_empty() {
            if table.len() != sp.q * sp.s {
                bail!("table len {} != q*s {}", table.len(), sp.q * sp.s);
            }
            if resident.as_ref().map(|r| r.raw.as_slice()) != Some(table) {
                #[cfg(feature = "xla-pjrt")]
                if let Backend::Pjrt(state) = &self.backend {
                    state.set_table(table)?;
                }
                *resident =
                    Some(ResidentTable::new(&self.spec, table.to_vec()));
            }
        }
        let out = match &self.backend {
            Backend::Emulated => {
                let Some(table) = resident.as_ref() else {
                    bail!("no table uploaded: call set_table first");
                };
                emu_lane_match(sp, table, inp, starts, lens, init)
            }
            #[cfg(feature = "xla-pjrt")]
            Backend::Pjrt(state) => state.lane_match(inp, starts, lens, init)?,
        };
        drop(resident);
        self.calls.fetch_add(1, Ordering::Relaxed);
        Ok(out)
    }

    /// Executions performed so far (diagnostics / Fig. 13 accounting).
    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    /// Eq. (9) composition on the unit: out[q] = lb[la[q]].
    /// Vectors must be padded to the compose kernel's width.
    pub fn compose(&self, la: &[i32], lb: &[i32]) -> Result<Vec<i32>> {
        if self.compose_qp == 0 {
            bail!("compose artifact not loaded");
        }
        if la.len() != self.compose_qp || lb.len() != self.compose_qp {
            bail!(
                "compose args len {}/{} != qp {}",
                la.len(),
                lb.len(),
                self.compose_qp
            );
        }
        match &self.backend {
            Backend::Emulated => Ok(la
                .iter()
                .map(|&i| {
                    let i = (i.max(0) as usize).min(lb.len() - 1);
                    lb[i]
                })
                .collect()),
            #[cfg(feature = "xla-pjrt")]
            Backend::Pjrt(state) => state.compose(la, lb),
        }
    }

    /// Padded L-vector width of the compose kernel (0 = unavailable).
    pub fn compose_width(&self) -> usize {
        self.compose_qp
    }
}

/// The lane_match kernel reference semantics in pure Rust (mirrors
/// python/compile/kernels/ref.py::lane_dfa_match_py plus the window
/// gather + clipping of model.py::lane_match).
///
/// When a lane's window lies fully inside the input (the common case —
/// the matcher always issues in-range windows), the lane runs on the
/// width-compacted premultiplied table instead: the per-step position
/// clip and the state/table clamps disappear, leaving one symbol clamp,
/// one add and one indexed load — the Listing-1 shape on the vector
/// unit.  The out-of-range reference loop is kept byte-identical
/// (clamped entries are premultiplied at [`ResidentTable::new`] time),
/// property-tested below.
fn emu_lane_match(
    sp: &VariantSpec,
    table: &ResidentTable,
    inp: &[i32],
    starts: &[i32],
    lens: &[i32],
    init: &[i32],
) -> Vec<i32> {
    let n = sp.n as i64;
    (0..sp.lanes)
        .map(|l| {
            let mut state = (init[l].max(0) as usize).min(sp.q - 1);
            let len = lens[l].clamp(0, sp.t as i32) as usize;
            let start = starts[l] as i64;
            if len > 0 && start >= 0 && start as usize + len <= sp.n {
                let begin = start as usize;
                let smax = sp.s - 1;
                state = with_sbase!(&table.fast, tab => {
                    let mut off = (state * sp.s) as u32;
                    for &sym in &inp[begin..begin + len] {
                        let sym = (sym.max(0) as usize).min(smax) as u32;
                        // off + sym <= (q-1)*s + s-1 < q*s = tab.len()
                        off = unsafe {
                            tab.get_unchecked((off + sym) as usize)
                        }
                        .to_u32();
                    }
                    off as usize / sp.s
                });
            } else {
                for i in 0..len as i64 {
                    let pos = (start + i).clamp(0, n - 1) as usize;
                    let sym = (inp[pos].max(0) as usize).min(sp.s - 1);
                    state = (table.raw[state * sp.s + sym].max(0) as usize)
                        .min(sp.q - 1);
                }
            }
            state as i32
        })
        .collect()
}

#[cfg(feature = "xla-pjrt")]
mod xla_backend {
    //! The real PJRT path: HLO-text artifacts compiled with the CPU
    //! client.  Only built with `--features xla-pjrt`, which additionally
    //! requires supplying the `xla` bindings crate (not vendored; add it
    //! as a path dependency next to vendor/anyhow).

    use std::path::Path;

    use anyhow::{anyhow, Result};

    pub struct PjrtState {
        client: xla::PjRtClient,
        exe: xla::PjRtLoadedExecutable,
        compose_exe: Option<xla::PjRtLoadedExecutable>,
        table_buf: std::sync::Mutex<Option<xla::PjRtBuffer>>,
    }

    impl PjrtState {
        pub fn load(dir: &Path, name: &str) -> Result<PjrtState> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| anyhow!("PjRtClient::cpu: {e:?}"))?;
            let exe =
                compile_hlo(&client, &dir.join(format!("{name}.hlo.txt")))?;
            let compose_path = dir.join("compose.hlo.txt");
            let compose_exe = if compose_path.exists() {
                Some(compile_hlo(&client, &compose_path)?)
            } else {
                None
            };
            Ok(PjrtState {
                client,
                exe,
                compose_exe,
                table_buf: std::sync::Mutex::new(None),
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        pub fn set_table(&self, table: &[i32]) -> Result<()> {
            let buf = self
                .client
                .buffer_from_host_buffer(table, &[table.len()], None)
                .map_err(|e| anyhow!("table upload: {e:?}"))?;
            *self.table_buf.lock().unwrap() = Some(buf);
            Ok(())
        }

        pub fn lane_match(
            &self,
            inp: &[i32],
            starts: &[i32],
            lens: &[i32],
            init: &[i32],
        ) -> Result<Vec<i32>> {
            let tb = self.table_buf.lock().unwrap();
            let Some(table_dev) = tb.as_ref() else {
                return Err(anyhow!("no table uploaded: call set_table first"));
            };
            // small operands go host->device per call; the table stays put
            let to_dev = |v: &[i32]| -> Result<xla::PjRtBuffer> {
                self.client
                    .buffer_from_host_buffer(v, &[v.len()], None)
                    .map_err(|e| anyhow!("upload: {e:?}"))
            };
            let args = [
                table_dev,
                &to_dev(inp)?,
                &to_dev(starts)?,
                &to_dev(lens)?,
                &to_dev(init)?,
            ];
            let result = self
                .exe
                .execute_b::<&xla::PjRtBuffer>(&args)
                .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("to_literal: {e:?}"))?;
            // aot.py lowers with return_tuple=True: unwrap the 1-tuple
            let out = result
                .to_tuple1()
                .map_err(|e| anyhow!("to_tuple1: {e:?}"))?;
            out.to_vec::<i32>().map_err(|e| anyhow!("to_vec: {e:?}"))
        }

        pub fn compose(&self, la: &[i32], lb: &[i32]) -> Result<Vec<i32>> {
            let exe = self
                .compose_exe
                .as_ref()
                .ok_or_else(|| anyhow!("compose artifact not loaded"))?;
            let args = [xla::Literal::vec1(la), xla::Literal::vec1(lb)];
            let result = exe
                .execute::<xla::Literal>(&args)
                .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("to_literal: {e:?}"))?;
            let out = result
                .to_tuple1()
                .map_err(|e| anyhow!("to_tuple1: {e:?}"))?;
            out.to_vec::<i32>().map_err(|e| anyhow!("to_vec: {e:?}"))
        }
    }

    fn compile_hlo(
        client: &xla::PjRtClient,
        path: &Path,
    ) -> Result<xla::PjRtLoadedExecutable> {
        let path_str = path
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 path {path:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {path:?}: {e:?}"))
    }
}

/// Pad a DFA's transition table to a variant's (q, s) shape.  Entries are
/// state ids; rows beyond the DFA's states self-loop (never reached),
/// symbol columns beyond the DFA's alphabet self-loop (never fed).
pub fn pad_table(
    table: &[u32],
    num_states: usize,
    num_symbols: usize,
    spec: &VariantSpec,
) -> Result<Vec<i32>> {
    if num_states > spec.q {
        bail!("DFA has {num_states} states > artifact q {}", spec.q);
    }
    if num_symbols > spec.s {
        bail!("DFA has {num_symbols} symbols > artifact s {}", spec.s);
    }
    let mut out = vec![0i32; spec.q * spec.s];
    for q in 0..spec.q {
        for s in 0..spec.s {
            out[q * spec.s + s] = if q < num_states && s < num_symbols {
                table[q * num_symbols + s] as i32
            } else {
                q as i32 // self-loop padding
            };
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let dir = tempdir();
        std::fs::write(
            dir.join("manifest.tsv"),
            "lane8_main\tlane_match\t8\t1536\t64\t8192\t65536\t512\n\
             compose\tcompose\t1536\t0\t0\t0\t0\t0\n",
        )
        .unwrap();
        let m = ArtifactManifest::load(&dir).unwrap();
        let spec = m.lane_match["lane8_main"];
        assert_eq!(spec.lanes, 8);
        assert_eq!(spec.q, 1536);
        assert_eq!(spec.n, 65536);
        assert_eq!(m.compose_qp, Some(1536));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_missing_is_error() {
        let dir = tempdir();
        let err = ArtifactManifest::load(&dir).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_rejects_garbage() {
        let dir = tempdir();
        std::fs::write(dir.join("manifest.tsv"), "what is this\n").unwrap();
        assert!(ArtifactManifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pad_table_shapes() {
        let spec = VariantSpec { lanes: 8, q: 4, s: 3, t: 8, n: 16, block_t: 4 };
        // 2-state, 2-symbol DFA
        let table = vec![1, 0, 1, 1];
        let padded = pad_table(&table, 2, 2, &spec).unwrap();
        assert_eq!(padded.len(), 12);
        assert_eq!(padded[0], 1); // (0,0)
        assert_eq!(padded[1], 0); // (0,1)
        assert_eq!(padded[2], 0); // (0,2) pad: self-loop
        assert_eq!(padded[3], 1); // (1,0)
        assert_eq!(padded[5], 1); // (1,2) pad
        assert_eq!(padded[6], 2); // (2,0) pad row
        // too big DFAs are rejected
        assert!(pad_table(&table, 5, 2, &spec).is_err());
        assert!(pad_table(&table, 2, 4, &spec).is_err());
    }

    #[test]
    fn emulated_lane_match_reference_semantics() {
        // 2-state 2-symbol toggle DFA: delta(q, 0) = q, delta(q, 1) = 1-q
        let spec = VariantSpec { lanes: 4, q: 2, s: 2, t: 8, n: 8, block_t: 4 };
        let vu = VectorUnit::emulated("toggle", spec);
        let table = vec![0, 1, 1, 0];
        vu.set_table(&table).unwrap();
        let inp = vec![1, 1, 0, 1, 0, 0, 1, 1];
        // lane 0: full window from 0; lane 1: masked to 0 syms;
        // lane 2: start mid-window; lane 3: start beyond n-1 (clipped)
        let starts = vec![0, 0, 3, 100];
        let lens = vec![8, 0, 2, 3];
        let init = vec![0, 1, 0, 0];
        let out = vu.lane_match(&[], &inp, &starts, &lens, &init).unwrap();
        assert_eq!(out[0], 1); // five 1s from state 0
        assert_eq!(out[1], 1); // untouched
        assert_eq!(out[2], 1); // syms 1, 0
        assert_eq!(out[3], 1); // clipped to inp[7]=1 three times: toggles to 1
        assert_eq!(vu.calls(), 1);
    }

    #[test]
    fn emulated_compose_is_eq9() {
        let spec = VariantSpec { lanes: 2, q: 4, s: 2, t: 4, n: 4, block_t: 2 };
        let vu = VectorUnit::emulated("c", spec);
        let la = vec![2, 0, 3, 1];
        let lb = vec![10, 11, 12, 13];
        assert_eq!(vu.compose(&la, &lb).unwrap(), vec![12, 10, 13, 11]);
    }

    #[test]
    fn prop_fast_path_equals_reference_semantics() {
        // the compact premultiplied fast path must agree with the
        // clip-everything reference loop on every in-range window,
        // including degenerate tables and out-of-range symbols/states
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xFA57);
        for case in 0..40 {
            let q = rng.range_usize(1, 9);
            let s = rng.range_usize(1, 5);
            let n = rng.range_usize(1, 24);
            let spec = VariantSpec { lanes: 4, q, s, t: 16, n, block_t: 4 };
            let table: Vec<i32> = (0..q * s)
                .map(|_| match rng.below(8) {
                    0 => -3,         // clamped to 0
                    1 => q as i32 + 5, // clamped to q-1
                    _ => rng.below(q as u64) as i32,
                })
                .collect();
            let vu = VectorUnit::emulated("prop", spec);
            vu.set_table(&table).unwrap();
            let inp: Vec<i32> = (0..n)
                .map(|_| rng.below(s as u64 + 2) as i32 - 1)
                .collect();
            let starts: Vec<i32> = (0..4)
                .map(|_| rng.below(n as u64 + 6) as i32 - 3)
                .collect();
            let lens: Vec<i32> =
                (0..4).map(|_| rng.below(20) as i32 - 2).collect();
            let init: Vec<i32> =
                (0..4).map(|_| rng.below(q as u64 + 4) as i32 - 2).collect();
            let got =
                vu.lane_match(&[], &inp, &starts, &lens, &init).unwrap();
            // straight reference computation, no fast path
            for l in 0..4usize {
                let mut state = (init[l].max(0) as usize).min(q - 1);
                let len = lens[l].clamp(0, spec.t as i32);
                for i in 0..len as i64 {
                    let pos =
                        (starts[l] as i64 + i).clamp(0, n as i64 - 1) as usize;
                    let sym = (inp[pos].max(0) as usize).min(s - 1);
                    state =
                        (table[state * s + sym].max(0) as usize).min(q - 1);
                }
                assert_eq!(got[l], state as i32, "case {case} lane {l}");
            }
        }
    }

    #[test]
    fn lane_match_requires_table() {
        let spec = VariantSpec { lanes: 1, q: 2, s: 2, t: 4, n: 4, block_t: 2 };
        let vu = VectorUnit::emulated("x", spec);
        let err = vu
            .lane_match(&[], &[0; 4], &[0], &[1], &[0])
            .unwrap_err();
        assert!(format!("{err}").contains("set_table"));
    }

    fn tempdir() -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "specdfa-test-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }
}
