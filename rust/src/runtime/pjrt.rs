//! PJRT wrapper: load HLO-text artifacts, compile once, execute many.
//!
//! Interchange is HLO *text* (see python/compile/aot.py): jax ≥ 0.5 emits
//! protos with 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids.  Pattern follows
//! /opt/xla-example/src/bin/load_hlo.rs.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

/// Static shape configuration of one lane_match artifact (mirrors
/// python/compile/model.py::VariantSpec).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct VariantSpec {
    pub lanes: usize,
    /// padded state count of the transition table
    pub q: usize,
    /// padded symbol count (row stride)
    pub s: usize,
    /// max symbols advanced per call
    pub t: usize,
    /// input window length
    pub n: usize,
    pub block_t: usize,
}

/// Parsed artifacts/manifest.tsv.
#[derive(Clone, Debug, Default)]
pub struct ArtifactManifest {
    pub lane_match: HashMap<String, VariantSpec>,
    /// padded L-vector width of the compose artifact
    pub compose_qp: Option<usize>,
}

impl ArtifactManifest {
    pub fn load(dir: &Path) -> Result<ArtifactManifest> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let mut m = ArtifactManifest::default();
        for (lineno, line) in text.lines().enumerate() {
            let f: Vec<&str> = line.split('\t').collect();
            let ctx = || format!("{path:?} line {}", lineno + 1);
            match f.as_slice() {
                [name, "lane_match", lanes, q, s, t, n, block_t] => {
                    m.lane_match.insert(
                        name.to_string(),
                        VariantSpec {
                            lanes: lanes.parse().with_context(ctx)?,
                            q: q.parse().with_context(ctx)?,
                            s: s.parse().with_context(ctx)?,
                            t: t.parse().with_context(ctx)?,
                            n: n.parse().with_context(ctx)?,
                            block_t: block_t.parse().with_context(ctx)?,
                        },
                    );
                }
                [_, "compose", qp, ..] => {
                    m.compose_qp = Some(qp.parse().with_context(ctx)?);
                }
                [] | [""] => {}
                _ => bail!("unrecognized manifest line: {line:?}"),
            }
        }
        Ok(m)
    }
}

/// A compiled lane_match executable + its shape spec.
pub struct VectorUnit {
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    compose_exe: Option<xla::PjRtLoadedExecutable>,
    compose_qp: usize,
    pub spec: VariantSpec,
    pub name: String,
    /// executions performed (diagnostics / Fig. 13 instruction accounting)
    pub calls: std::cell::Cell<u64>,
    /// device-resident transition table (§Perf: uploading the padded
    /// table per call — q·s·4 B ≈ 393 KiB for lane8_main — dominated the
    /// per-call cost; `set_table` uploads it once, `lane_match` then only
    /// moves the small per-call operands)
    table_buf: std::cell::RefCell<Option<xla::PjRtBuffer>>,
}

impl VectorUnit {
    /// Load variant `name` from the artifact directory.
    pub fn load(dir: impl AsRef<Path>, name: &str) -> Result<VectorUnit> {
        let dir = dir.as_ref();
        let manifest = ArtifactManifest::load(dir)?;
        let spec = *manifest
            .lane_match
            .get(name)
            .ok_or_else(|| anyhow!("variant {name:?} not in manifest"))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PjRtClient::cpu: {e:?}"))?;
        let exe = compile_hlo(&client, &dir.join(format!("{name}.hlo.txt")))?;
        let compose_path = dir.join("compose.hlo.txt");
        let (compose_exe, compose_qp) = if compose_path.exists() {
            (
                Some(compile_hlo(&client, &compose_path)?),
                manifest.compose_qp.unwrap_or(0),
            )
        } else {
            (None, 0)
        };
        Ok(VectorUnit {
            client,
            exe,
            compose_exe,
            compose_qp,
            spec,
            name: name.to_string(),
            calls: std::cell::Cell::new(0),
            table_buf: std::cell::RefCell::new(None),
        })
    }

    /// Upload a padded transition table to the device once; subsequent
    /// `lane_match` calls reuse it (pass `table = &[]`).
    pub fn set_table(&self, table: &[i32]) -> Result<()> {
        let sp = &self.spec;
        if table.len() != sp.q * sp.s {
            bail!("table len {} != q*s {}", table.len(), sp.q * sp.s);
        }
        let buf = self
            .client
            .buffer_from_host_buffer(table, &[sp.q * sp.s], None)
            .map_err(|e| anyhow!("table upload: {e:?}"))?;
        *self.table_buf.borrow_mut() = Some(buf);
        Ok(())
    }

    /// Default artifact directory: $SPECDFA_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("SPECDFA_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// One vector step: advance every lane by up to `spec.t` symbols.
    ///
    /// * `table` — padded flat table, len q*s, entries are *state ids*
    ///   (not premultiplied offsets; the kernel indexes [q, s]).  Pass an
    ///   empty slice to reuse the device-resident table from `set_table`
    ///   (the fast path — saves ~400 KiB of host->device traffic/call).
    /// * `inp` — symbol window, len n.
    /// * `starts`/`lens`/`init` — per-lane descriptors, len lanes.
    pub fn lane_match(
        &self,
        table: &[i32],
        inp: &[i32],
        starts: &[i32],
        lens: &[i32],
        init: &[i32],
    ) -> Result<Vec<i32>> {
        let sp = &self.spec;
        if inp.len() != sp.n {
            bail!("input window len {} != n {}", inp.len(), sp.n);
        }
        for (nm, v) in [("starts", starts), ("lens", lens), ("init", init)] {
            if v.len() != sp.lanes {
                bail!("{nm} len {} != lanes {}", v.len(), sp.lanes);
            }
        }
        if !table.is_empty() {
            if table.len() != sp.q * sp.s {
                bail!("table len {} != q*s {}", table.len(), sp.q * sp.s);
            }
            self.set_table(table)?;
        }
        let tb = self.table_buf.borrow();
        let Some(table_dev) = tb.as_ref() else {
            bail!("no table uploaded: call set_table first");
        };
        // small operands go host->device per call; the table stays put
        let to_dev = |v: &[i32]| -> Result<xla::PjRtBuffer> {
            self.client
                .buffer_from_host_buffer(v, &[v.len()], None)
                .map_err(|e| anyhow!("upload: {e:?}"))
        };
        let args = [
            table_dev,
            &to_dev(inp)?,
            &to_dev(starts)?,
            &to_dev(lens)?,
            &to_dev(init)?,
        ];
        let result = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(&args)
            .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow!("to_tuple1: {e:?}"))?;
        self.calls.set(self.calls.get() + 1);
        out.to_vec::<i32>().map_err(|e| anyhow!("to_vec: {e:?}"))
    }

    /// Eq. (9) composition on the device: out[q] = lb[la[q]].
    /// Vectors must be padded to the compose artifact's width.
    pub fn compose(&self, la: &[i32], lb: &[i32]) -> Result<Vec<i32>> {
        let exe = self
            .compose_exe
            .as_ref()
            .ok_or_else(|| anyhow!("compose artifact not loaded"))?;
        if la.len() != self.compose_qp || lb.len() != self.compose_qp {
            bail!(
                "compose args len {}/{} != qp {}",
                la.len(),
                lb.len(),
                self.compose_qp
            );
        }
        let args = [xla::Literal::vec1(la), xla::Literal::vec1(lb)];
        let result = exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow!("to_tuple1: {e:?}"))?;
        out.to_vec::<i32>().map_err(|e| anyhow!("to_vec: {e:?}"))
    }

    pub fn compose_width(&self) -> usize {
        self.compose_qp
    }
}

fn compile_hlo(
    client: &xla::PjRtClient,
    path: &Path,
) -> Result<xla::PjRtLoadedExecutable> {
    let path_str = path
        .to_str()
        .ok_or_else(|| anyhow!("non-utf8 path {path:?}"))?;
    let proto = xla::HloModuleProto::from_text_file(path_str)
        .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow!("compile {path:?}: {e:?}"))
}

/// Pad a DFA's transition table to a variant's (q, s) shape.  Entries are
/// state ids; rows beyond the DFA's states self-loop (never reached),
/// symbol columns beyond the DFA's alphabet self-loop (never fed).
pub fn pad_table(
    table: &[u32],
    num_states: usize,
    num_symbols: usize,
    spec: &VariantSpec,
) -> Result<Vec<i32>> {
    if num_states > spec.q {
        bail!("DFA has {num_states} states > artifact q {}", spec.q);
    }
    if num_symbols > spec.s {
        bail!("DFA has {num_symbols} symbols > artifact s {}", spec.s);
    }
    let mut out = vec![0i32; spec.q * spec.s];
    for q in 0..spec.q {
        for s in 0..spec.s {
            out[q * spec.s + s] = if q < num_states && s < num_symbols {
                table[q * num_symbols + s] as i32
            } else {
                q as i32 // self-loop padding
            };
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let dir = tempdir();
        std::fs::write(
            dir.join("manifest.tsv"),
            "lane8_main\tlane_match\t8\t1536\t64\t8192\t65536\t512\n\
             compose\tcompose\t1536\t0\t0\t0\t0\t0\n",
        )
        .unwrap();
        let m = ArtifactManifest::load(&dir).unwrap();
        let spec = m.lane_match["lane8_main"];
        assert_eq!(spec.lanes, 8);
        assert_eq!(spec.q, 1536);
        assert_eq!(spec.n, 65536);
        assert_eq!(m.compose_qp, Some(1536));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_missing_is_error() {
        let dir = tempdir();
        let err = ArtifactManifest::load(&dir).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_rejects_garbage() {
        let dir = tempdir();
        std::fs::write(dir.join("manifest.tsv"), "what is this\n").unwrap();
        assert!(ArtifactManifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pad_table_shapes() {
        let spec = VariantSpec { lanes: 8, q: 4, s: 3, t: 8, n: 16, block_t: 4 };
        // 2-state, 2-symbol DFA
        let table = vec![1, 0, 1, 1];
        let padded = pad_table(&table, 2, 2, &spec).unwrap();
        assert_eq!(padded.len(), 12);
        assert_eq!(padded[0], 1); // (0,0)
        assert_eq!(padded[1], 0); // (0,1)
        assert_eq!(padded[2], 0); // (0,2) pad: self-loop
        assert_eq!(padded[3], 1); // (1,0)
        assert_eq!(padded[5], 1); // (1,2) pad
        assert_eq!(padded[6], 2); // (2,0) pad row
        // too big DFAs are rejected
        assert!(pad_table(&table, 5, 2, &spec).is_err());
        assert!(pad_table(&table, 2, 4, &spec).is_err());
    }

    fn tempdir() -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "specdfa-test-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }
}
