//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts (HLO text)
//! and exposes them as the system's **vector unit** — the role the 8-wide
//! AVX2 gather loop (Listing 2) plays in the paper.
//!
//! Python never runs here: `make artifacts` lowered the L2 model once; the
//! rust hot path compiles the HLO with the PJRT CPU client and executes it
//! with concrete buffers.

pub mod pjrt;
pub mod simd;

pub use pjrt::{ArtifactManifest, VariantSpec, VectorUnit};
pub use simd::{SimdMatcher, SimdOutcome};
