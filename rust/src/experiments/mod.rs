//! Experiment regenerators: one entry per table and figure of the paper's
//! evaluation (§6).  Each produces `util::bench::Table`s with the same
//! rows/series the paper reports; the bench targets under rust/benches/
//! and the CLI (`specdfa experiment <name>`) print them.
//!
//! Timing methodology (see DESIGN.md §Substitutions): matching work is
//! executed for real and verified against sequential semantics; parallel
//! speedups are work-ratio speedups on a cost model calibrated with the
//! measured single-core symbol rate of this host — the same methodology
//! the paper itself uses for its SIMD results (instruction ratios on the
//! SDE emulator, §6.1).

pub mod calibrate;
pub mod cloud_exp;
pub mod compare;
pub mod multicore;
pub mod simd_exp;
pub mod structure;

use crate::util::bench::Table;

/// All experiment names, in paper order.
pub const ALL: &[&str] = &[
    "table1", "fig10", "fig11", "fig12", "fig13", "fig14", "table3",
    "fig15", "fig16", "table4", "fig17", "fig18", "fig19",
];

/// Run one experiment by name.
pub fn run(name: &str) -> Option<Vec<Table>> {
    Some(match name {
        "table1" => multicore::table1(),
        "fig10" => multicore::fig10(),
        "fig11" => multicore::fig11(),
        "fig12" => compare::fig12(),
        "fig13" => simd_exp::fig13(),
        "fig14" => cloud_exp::fig14(),
        "table3" => cloud_exp::table3(),
        "fig15" => multicore::fig15(),
        "fig16" => structure::fig16(),
        "table4" => structure::table4(),
        "fig17" => structure::fig17(),
        "fig18" => multicore::fig18(),
        "fig19" => cloud_exp::fig19(),
        _ => return None,
    })
}
