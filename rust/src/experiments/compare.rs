//! Fig. 12: our optimized parallel matcher against the ScanProsite-style
//! backtracking engine (a) and the grep-style engine (b).
//!
//! Both comparators execute for real on this host; our matcher's
//! sequential loop also executes for real, and its parallel factor is the
//! work-ratio model (same anchoring as Fig. 10).  Ratios therefore carry
//! the same structure as the paper's: interpretive-backtracking overhead
//! × per-position restarts vs one table lookup per input symbol, times
//! the parallel speedup.

use std::time::Instant;

use crate::baseline::backtracking::Backtracker;
use crate::baseline::greplike::GrepLike;
use crate::baseline::sequential::SequentialMatcher;
use crate::regex::prosite;
use crate::speculative::matcher::MatchPlan;
use crate::util::bench::Table;
use crate::workload::{prosite_suite_cached, InputGen};

use super::multicore::{model_speedup, spread_by_q, P_MTL};

/// Fig. 12(a,b): speedup of our 40-core r=4 matcher over ScanProsite-like
/// backtracking and grep-like scanning on protein sequences.
pub fn fig12() -> Vec<Table> {
    let n = 1_000_000;
    let mut t = Table::new(
        "Fig. 12 — ours (P=40, r=4) vs ScanProsite-style backtracking (a) \
         and grep-style scan (b)",
        &["pattern", "|Q|", "ours µs", "scanprosite µs", "(a) ratio",
          "grep µs", "(b) ratio"],
    );
    for p in spread_by_q(prosite_suite_cached(), 6) {
        let mut gen = InputGen::new(0xF1612);
        let protein = gen.protein(n);

        // ours: real sequential wall time / modelled parallel factor
        let seq = SequentialMatcher::new(&p.dfa);
        let t0 = Instant::now();
        let seq_out = seq.run_bytes(&protein);
        let seq_us = t0.elapsed().as_secs_f64() * 1e6;
        let plan = MatchPlan::new(&p.dfa)
            .lookahead(4)
            .sequential_execution()
            .processors(P_MTL);
        let outp = plan.run(&protein);
        assert_eq!(outp.accepted, seq_out.accepted);
        let par_factor = model_speedup(
            n,
            outp.makespan_syms(),
            outp.merge_stats.lookup_ops,
        );
        let ours_us = seq_us / par_factor;

        // ScanProsite stand-in: backtracking search over the sequence
        let parsed = prosite::parse(&p.pattern).unwrap();
        let bt = Backtracker::with_fuel(&parsed.ast, 2_000_000_000);
        let t0 = Instant::now();
        let bt_res = bt.search(&protein);
        let bt_us = t0.elapsed().as_secs_f64() * 1e6;
        let (bt_cell, ratio_a) = match bt_res {
            Some(st) => {
                assert_eq!(st.matched, seq_out.accepted,
                           "backtracker disagrees on {}", p.name);
                (format!("{bt_us:.0}"), format!("{:.1}x", bt_us / ours_us))
            }
            None => (format!(">{bt_us:.0} (fuel)"),
                     format!(">{:.1}x", bt_us / ours_us)),
        };

        // grep stand-in
        let grep = GrepLike::new(&parsed.ast);
        let t0 = Instant::now();
        let g = grep.search(&protein);
        let grep_us = t0.elapsed().as_secs_f64() * 1e6;
        assert_eq!(g.matched, seq_out.accepted,
                   "greplike disagrees on {}", p.name);

        t.row(vec![
            p.name.clone(),
            p.q().to_string(),
            format!("{ours_us:.0}"),
            bt_cell,
            ratio_a,
            format!("{grep_us:.0}"),
            format!("{:.1}x", grep_us / ours_us),
        ]);
    }
    vec![t]
}
