//! Host calibration: measure the real single-core matching rate of the
//! Listing-1 loop on this machine.  Every simulated speedup is anchored to
//! this measured number (DESIGN.md §Substitutions).

use std::sync::OnceLock;

use crate::automata::FlatDfa;
use crate::regex::compile::compile_search;
use crate::speculative::profile::measure_capacity;
use crate::workload::InputGen;

/// Measured symbols/µs of the sequential flat-table loop on this host.
pub fn host_syms_per_us() -> f64 {
    static RATE: OnceLock<f64> = OnceLock::new();
    *RATE.get_or_init(|| {
        let dfa = compile_search("(ab|cd)+e?").unwrap();
        let flat = FlatDfa::from_dfa(&dfa);
        let syms = InputGen::new(0xCA11B)
            .uniform_syms(&dfa, 2_000_000);
        measure_capacity(&flat, &syms, 7)
    })
}

/// Convert a symbol count to µs at the calibrated host rate.
pub fn syms_to_us(syms: f64) -> f64 {
    syms / host_syms_per_us()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_is_measured_and_cached() {
        let a = host_syms_per_us();
        let b = host_syms_per_us();
        assert_eq!(a, b);
        assert!(a > 10.0 && a < 100_000.0, "rate {a} syms/us");
    }
}
