//! Fig. 13: vectorized DFA matching on the PJRT vector unit (the AVX2
//! analog).  Reported exactly like the paper's SDE methodology (§6.1):
//! speedup is a ratio of executed work/instructions, not wall-clock —
//! "SDE is not cycle-accurate ... we used the number of executed machine
//! instructions as the basis of our performance comparison."

use crate::runtime::pjrt::VectorUnit;
use crate::runtime::simd::{SimdMatcher, SCALAR_OPS_PER_SYM,
                           VECTOR_OPS_PER_STEP};
use crate::util::bench::{fmt_speedup, Table};
use crate::workload::{pcre_suite_cached, prosite_suite_cached, InputGen};

use super::multicore::spread_by_q;

/// Fig. 13: 8-lane vectorization over the suites.  Columns mirror the
/// paper: scalar chunked speedup (a,c) and vectorized speedup (b,d);
/// the per-step instruction ratio 8·5/9 ≈ 4.45× matches §6.1.
pub fn fig13() -> Vec<Table> {
    let vu = match VectorUnit::load(VectorUnit::default_dir(), "lane8_main")
    {
        Ok(vu) => std::sync::Arc::new(vu),
        Err(e) => {
            let mut t = Table::new("Fig. 13 — SKIPPED", &["reason"]);
            t.row(vec![format!("{e:#}")]);
            return vec![t];
        }
    };
    let n = 1 << 16; // per-pattern input (PJRT interpret-mode throughput)
    let mut out = Vec::new();
    for (title, suite) in [
        ("Fig. 13(a,b) — PROSITE, 8-lane vector unit, r=1",
         prosite_suite_cached()),
        ("Fig. 13(c,d) — PCRE, 8-lane vector unit, r=1",
         pcre_suite_cached()),
    ] {
        let mut t = Table::new(
            title,
            &["pattern", "|Q|", "I_max", "lane slots", "passes",
              "scalar-equiv speedup", "instr speedup", "S@8corex8lane",
              "pjrt calls"],
        );
        for p in spread_by_q(suite, 6) {
            if p.dfa.num_states as usize > vu.spec.q {
                continue;
            }
            let syms = p.input_syms(&mut InputGen::new(0xF1613), n);
            let m = match SimdMatcher::new(&p.dfa, &vu) {
                Ok(m) => m.lookahead(1),
                Err(_) => continue,
            };
            let outcome = match m.run_syms(&syms) {
                Ok(o) => o,
                Err(e) => {
                    eprintln!("fig13 {}: {e:#}", p.name);
                    continue;
                }
            };
            t.row(vec![
                p.name.clone(),
                p.q().to_string(),
                m.i_max().to_string(),
                outcome.lane_slots.to_string(),
                outcome.passes.to_string(),
                fmt_speedup(outcome.chunk_speedup()),
                fmt_speedup(outcome.instr_speedup()),
                // the paper's Fig. 13 testbed: SDE-emulated 8 cores, each
                // with 8 AVX2 lanes = 64 speculative lanes (Eq. 15/18)
                fmt_speedup(
                    crate::speculative::partition::predicted_speedup(
                        64, m.i_max())),
                outcome.pjrt_calls.to_string(),
            ]);
        }
        out.push(t);
    }
    let mut meta = Table::new(
        "Fig. 13 instruction model (Listing 1 vs Listing 2)",
        &["scalar ops/sym", "vector ops/step", "8-lane ratio",
          "paper (measured)"],
    );
    meta.row(vec![
        format!("{SCALAR_OPS_PER_SYM}"),
        format!("{VECTOR_OPS_PER_STEP}"),
        format!("{:.2}x", 8.0 * SCALAR_OPS_PER_SYM / VECTOR_OPS_PER_STEP),
        "4.45x".to_string(),
    ]);
    out.push(meta);
    out
}
