//! Structural-property experiments: Fig. 16 (initial-state reduction
//! rates), Table 4 (average I_max,r / |Q|), Fig. 17 (I_max,r computation
//! overhead).

use std::time::Instant;

use crate::automata::Dfa;
use crate::speculative::lookahead::{i_max_r_naive, Lookahead};
use crate::util::bench::Table;
use crate::util::rng::Rng;
use crate::util::stats;
use crate::workload::{pcre_suite_cached, prosite_suite_cached};

use super::multicore::spread_by_q;

/// Fig. 16: per-DFA |Q| and the reduction rate (1 − I_max,r/|Q|) for
/// r = 1..4.
pub fn fig16() -> Vec<Table> {
    let mut out = Vec::new();
    for (title, suite) in [
        ("Fig. 16(a) — PCRE initial-state reduction", pcre_suite_cached()),
        ("Fig. 16(b) — PROSITE initial-state reduction",
         prosite_suite_cached()),
    ] {
        let mut t = Table::new(
            title,
            &["pattern", "|Q|", "red r=1", "red r=2", "red r=3", "red r=4"],
        );
        for p in spread_by_q(suite, 12) {
            let la = Lookahead::analyze(&p.dfa, 4);
            let mut row = vec![p.name.clone(), p.q().to_string()];
            for k in 0..4 {
                let reduction =
                    1.0 - la.i_max_by_r[k] as f64 / p.q() as f64;
                row.push(format!("{:.0}%", reduction * 100.0));
            }
            t.row(row);
        }
        out.push(t);
    }
    out
}

/// Table 4: average size of I_max,r relative to |Q| over each suite
/// (paper: PCRE 33.7/26.4/23.7/21.7 %, PROSITE 47.2/29.2/20.5/16.0 %).
pub fn table4() -> Vec<Table> {
    let mut t = Table::new(
        "Table 4 — average I_max,r / |Q| (r reverse lookahead symbols)",
        &["suite", "r=0", "r=1", "r=2", "r=3", "r=4"],
    );
    for (name, suite) in [
        ("PCRE", pcre_suite_cached()),
        ("PROSITE", prosite_suite_cached()),
    ] {
        let mut ratios = vec![Vec::new(); 4];
        for p in suite {
            let la = Lookahead::analyze(&p.dfa, 4);
            for k in 0..4 {
                ratios[k].push(la.i_max_by_r[k] as f64 / p.q() as f64);
            }
        }
        let mut row = vec![name.to_string(), "100%".to_string()];
        for k in 0..4 {
            row.push(format!("{:.1}%", stats::mean(&ratios[k]) * 100.0));
        }
        t.row(row);
    }
    vec![t]
}

/// Random complete DFA with the given |Q| and |Σ| (for Fig. 17 scaling).
fn random_dfa_sized(rng: &mut Rng, q: u32, s: u32) -> Dfa {
    let sink = q - 1;
    let mut table = Vec::with_capacity((q * s) as usize);
    for state in 0..q {
        for _ in 0..s {
            table.push(if state == sink {
                sink
            } else {
                rng.below(q as u64) as u32
            });
        }
    }
    let accepting = (0..q).map(|st| st != sink && st % 7 == 3).collect();
    let mut classes = [0u8; 256];
    for b in 0..256 {
        classes[b] = (b % s as usize) as u8;
    }
    Dfa::new(q, s, 0, accepting, table, classes)
}

/// Fig. 17: overhead of computing I_max,r with the paper's Algorithm 4
/// (exponential in r): (a) growing |Σ| at fixed |Q|, (b) growing |Q| at
/// fixed |Σ|.
pub fn fig17() -> Vec<Table> {
    let mut rng = Rng::new(0xF16_17);
    let mut ta = Table::new(
        "Fig. 17(a) — I_max,r cost vs |Sigma| (|Q|=50), Algorithm 4, µs",
        &["|Sigma|", "r=1", "r=2", "r=3"],
    );
    for s in [4u32, 8, 16, 24, 32] {
        let dfa = random_dfa_sized(&mut rng, 50, s);
        let mut row = vec![s.to_string()];
        for r in 1..=3 {
            let t0 = Instant::now();
            let v = i_max_r_naive(&dfa, r);
            std::hint::black_box(v);
            row.push(format!("{:.1}", t0.elapsed().as_secs_f64() * 1e6));
        }
        ta.row(row);
    }
    let mut tb = Table::new(
        "Fig. 17(b) — I_max,r cost vs |Q| (|Sigma|=20), Algorithm 4 vs BFS, µs",
        &["|Q|", "alg4 r=2", "alg4 r=3", "bfs r=3"],
    );
    for q in [50u32, 100, 200, 400, 800] {
        let dfa = random_dfa_sized(&mut rng, q, 20);
        let mut row = vec![q.to_string()];
        for r in 2..=3 {
            let t0 = Instant::now();
            std::hint::black_box(i_max_r_naive(&dfa, r));
            row.push(format!("{:.1}", t0.elapsed().as_secs_f64() * 1e6));
        }
        let t0 = Instant::now();
        std::hint::black_box(Lookahead::analyze(&dfa, 3).i_max);
        row.push(format!("{:.1}", t0.elapsed().as_secs_f64() * 1e6));
        tb.row(row);
    }
    vec![ta, tb]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_ratios_in_range_and_monotone() {
        let t = &table4()[0];
        for row in &t.rows {
            let vals: Vec<f64> = row[2..]
                .iter()
                .map(|s| s.trim_end_matches('%').parse::<f64>().unwrap())
                .collect();
            for v in &vals {
                assert!(*v > 0.0 && *v <= 100.0);
            }
            // Lemma 1: averages non-increasing in r
            for w in vals.windows(2) {
                assert!(w[0] >= w[1] - 1e-9, "{vals:?}");
            }
        }
    }

    #[test]
    fn random_dfa_sized_shapes() {
        let mut rng = Rng::new(1);
        let dfa = random_dfa_sized(&mut rng, 64, 12);
        assert_eq!(dfa.num_states, 64);
        assert_eq!(dfa.num_symbols, 12);
        assert_eq!(dfa.sink(), Some(63));
    }
}
