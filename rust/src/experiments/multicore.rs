//! Shared-memory multicore experiments: Table 1, Fig. 10, Fig. 11,
//! Fig. 15, Fig. 18.

use crate::baseline::holub_stekr::HolubStekr;
use crate::speculative::matcher::MatchPlan;
use crate::speculative::partition::{partition, predicted_speedup};
use crate::util::bench::{fmt_speedup, Table};
use crate::workload::{pcre_suite_cached, prosite_suite_cached, BenchPattern,
                      InputGen};

/// Paper default problem size (§6: "inputs of one million characters").
pub const N_DEFAULT: usize = 1_000_000;
/// The MTL node's core count.
pub const P_MTL: usize = 40;

/// Work-ratio speedup: sequential symbols over parallel makespan symbols
/// (+ the sequential-merge lookups, which are negligible but included).
pub fn model_speedup(n: usize, makespan_syms: usize, merge_lookups: usize) -> f64 {
    n as f64 / (makespan_syms as f64 + merge_lookups as f64).max(1.0)
}

/// Pick `k` patterns spread evenly across the suite's |Q| range.
pub fn spread_by_q(suite: &[BenchPattern], k: usize) -> Vec<&BenchPattern> {
    let mut sorted: Vec<&BenchPattern> = suite.iter().collect();
    sorted.sort_by_key(|p| p.q());
    if sorted.len() <= k {
        return sorted;
    }
    (0..k)
        .map(|i| sorted[i * (sorted.len() - 1) / (k - 1).max(1)])
        .collect()
}

/// Table 1: chunk-size computation for the Fig. 6 DFA on three processors
/// of non-uniform capacity.
pub fn table1() -> Vec<Table> {
    let weights = [1.5, 0.75, 0.75];
    let n = 36;
    let q = 4;
    let chunks = partition(n, &weights, q);
    let l0 = n as f64 * q as f64
        / (weights[0] * q as f64 + weights[1] + weights[2]);
    let mut t = Table::new(
        "Table 1 — chunk sizes, Fig. 6 DFA, 3 processors (m_k = 50/25/25)",
        &["Processor", "m_k", "w_k", "L_0*w_k", "Input character range"],
    );
    for (k, c) in chunks.iter().enumerate() {
        let wk = weights[k];
        let expected = if k == 0 { l0 * wk } else { l0 * wk / q as f64 };
        t.row(vec![
            format!("p{k}"),
            format!("{}", [50, 25, 25][k]),
            format!("{wk}"),
            format!("{expected:.1}"),
            format!("{}-{}", c.start, c.end.saturating_sub(1)),
        ]);
    }
    vec![t]
}

/// Fig. 10: speedups on the 40-core MTL node for PROSITE (a) and PCRE (c)
/// with 4-symbol reverse lookahead, plus the I_max-optimization gain over
/// matching |Q| states (b, d).
pub fn fig10() -> Vec<Table> {
    let mut out = Vec::new();
    for (title, suite) in [
        ("Fig. 10(a,b) — PROSITE on 40-core node, r=4",
         prosite_suite_cached()),
        ("Fig. 10(c,d) — PCRE on 40-core node, r=4", pcre_suite_cached()),
    ] {
        let mut t = Table::new(
            title,
            &["pattern", "|Q|", "I_max,4", "gamma",
              "P=10", "P=20", "P=30", "P=40", "Imax-gain@40"],
        );
        for p in spread_by_q(suite, 12) {
            let n = N_DEFAULT;
            let syms = p.input_syms(&mut InputGen::new(0xF1610), n);
            let base = MatchPlan::new(&p.dfa).lookahead(4)
                .sequential_execution();
            let mut row = vec![
                p.name.clone(),
                p.q().to_string(),
                base.i_max().to_string(),
                format!("{:.3}", base.gamma()),
            ];
            let mut makespan40_opt = 0usize;
            for procs in [10, 20, 30, 40] {
                let outp = base.clone().processors(procs).run_syms(&syms);
                if procs == 40 {
                    makespan40_opt = outp.makespan_syms();
                }
                row.push(fmt_speedup(model_speedup(
                    n,
                    outp.makespan_syms(),
                    outp.merge_stats.lookup_ops,
                )));
            }
            // Fig. 10(b,d): optimized vs matching all |Q| states
            let basic = MatchPlan::new(&p.dfa)
                .sequential_execution()
                .processors(40)
                .run_syms(&syms);
            let gain =
                basic.makespan_syms() as f64 / makespan40_opt.max(1) as f64;
            row.push(format!("{gain:.1}x"));
            t.row(row);
        }
        out.push(t);
    }
    out
}

/// Fig. 11: the Holub–Štekr algorithm [19] on the same workloads —
/// speed-downs whenever |Q| > |P| (paper: up to −390×).
pub fn fig11() -> Vec<Table> {
    let mut out = Vec::new();
    for (title, suite) in [
        ("Fig. 11(a) — Holub-Stekr, PROSITE", prosite_suite_cached()),
        ("Fig. 11(b) — Holub-Stekr, PCRE", pcre_suite_cached()),
    ] {
        let mut t = Table::new(
            title,
            &["pattern", "|Q|", "P=10", "P=40", "ours P=40 (r=4)"],
        );
        for p in spread_by_q(suite, 10) {
            let n = N_DEFAULT;
            let syms = p.input_syms(&mut InputGen::new(0xF1611), n);
            let mut row = vec![p.name.clone(), p.q().to_string()];
            for procs in [10, 40] {
                let hs = HolubStekr::new(&p.dfa, procs).run_syms(&syms);
                row.push(fmt_speedup(model_speedup(
                    n,
                    hs.makespan_syms(),
                    hs.merge_stats.lookup_ops,
                )));
            }
            let ours = MatchPlan::new(&p.dfa)
                .lookahead(4)
                .sequential_execution()
                .processors(40)
                .run_syms(&syms);
            row.push(fmt_speedup(model_speedup(
                n,
                ours.makespan_syms(),
                ours.merge_stats.lookup_ops,
            )));
            t.row(row);
        }
        out.push(t);
    }
    out
}

/// Fig. 15: basic algorithm (no I_max optimization) against the Eq. (15)
/// prediction 1 + (|P|−1)/|Q|.
pub fn fig15() -> Vec<Table> {
    let mut t = Table::new(
        "Fig. 15 — speedups without I_max optimization vs Eq. (15), P=40",
        &["pattern", "|Q|", "observed", "P=40 (predicted)"],
    );
    let mut all: Vec<&BenchPattern> = Vec::new();
    all.extend(spread_by_q(pcre_suite_cached(), 8));
    all.extend(spread_by_q(prosite_suite_cached(), 8));
    all.sort_by_key(|p| p.q());
    for p in all {
        let n = N_DEFAULT;
        let syms = p.input_syms(&mut InputGen::new(0xF1615), n);
        let outp = MatchPlan::new(&p.dfa)
            .sequential_execution()
            .processors(P_MTL)
            .run_syms(&syms);
        t.row(vec![
            p.name.clone(),
            p.q().to_string(),
            fmt_speedup(model_speedup(
                n,
                outp.makespan_syms(),
                outp.merge_stats.lookup_ops,
            )),
            format!("{:.2}x", predicted_speedup(P_MTL, p.q())),
        ]);
    }
    vec![t]
}

/// Fig. 18: speedups for varying input sizes (1 MB / 16 MB / 128 MB here;
/// the paper's 10 GB point follows the same O(n)-invariance — set
/// SPECDFA_BIG=1 to add a 1 GB row).
pub fn fig18() -> Vec<Table> {
    let mut sizes: Vec<usize> =
        vec![1 << 20, 16 << 20, 128 << 20];
    if std::env::var("SPECDFA_BIG").is_ok() {
        sizes.push(1 << 30);
    }
    let mut t = Table::new(
        "Fig. 18 — speedup invariance over input size, P=40, r=4",
        &["pattern", "|Q|", "1MB", "16MB", "128MB", "(+1GB w/ SPECDFA_BIG)"],
    );
    let mut pats: Vec<&BenchPattern> = Vec::new();
    pats.extend(spread_by_q(pcre_suite_cached(), 2));
    pats.extend(spread_by_q(prosite_suite_cached(), 2));
    for p in pats {
        let mut row = vec![p.name.clone(), p.q().to_string()];
        let base =
            MatchPlan::new(&p.dfa).lookahead(4).sequential_execution()
                .processors(P_MTL);
        for (i, &n) in sizes.iter().enumerate() {
            let syms =
                p.input_syms(&mut InputGen::new(0xF1618 + i as u64), n);
            let outp = base.clone().run_syms(&syms);
            row.push(fmt_speedup(model_speedup(
                n,
                outp.makespan_syms(),
                outp.merge_stats.lookup_ops,
            )));
        }
        while row.len() < 6 {
            row.push("-".to_string());
        }
        t.row(row);
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper() {
        let t = &table1()[0];
        assert_eq!(t.rows.len(), 3);
        assert_eq!(t.rows[0][4], "0-27");
        assert_eq!(t.rows[1][4], "28-31");
        assert_eq!(t.rows[2][4], "32-35");
        assert_eq!(t.rows[0][3], "28.8");
    }

    #[test]
    fn spread_by_q_covers_range() {
        let suite = pcre_suite_cached();
        let picked = spread_by_q(suite, 5);
        assert_eq!(picked.len(), 5);
        let qs: Vec<usize> = picked.iter().map(|p| p.q()).collect();
        assert!(qs.windows(2).all(|w| w[0] <= w[1]));
        let min_q = suite.iter().map(|p| p.q()).min().unwrap();
        let max_q = suite.iter().map(|p| p.q()).max().unwrap();
        assert_eq!(qs[0], min_q);
        assert_eq!(*qs.last().unwrap(), max_q);
    }

    #[test]
    fn model_speedup_bounds() {
        assert!((model_speedup(100, 100, 0) - 1.0).abs() < 1e-12);
        assert!(model_speedup(100, 50, 0) > 1.9);
        assert!(model_speedup(100, 200, 0) < 1.0); // speed-down representable
    }
}
