//! Cloud experiments on the simulated EC2 cluster: Fig. 14 (speedups +
//! communication ratio), Table 3 (load balancing on inhomogeneous
//! clusters), Fig. 19 (input-size scaling).

use crate::cluster::{CloudMatcher, ClusterSpec};
use crate::util::bench::{fmt_speedup, Table};
use crate::util::stats;
use crate::workload::{prosite_suite_cached, pcre_suite_cached, InputGen};

use super::calibrate::host_syms_per_us;
use super::multicore::spread_by_q;

/// §6.2: inputs of 8 million characters on EC2.
pub const N_CLOUD: usize = 8_000_000;

/// Fig. 14: speedups (a, c) and proportional communication cost (b, d)
/// on cc2.8xlarge clusters of 32..288 cores.
pub fn fig14() -> Vec<Table> {
    let mut out = Vec::new();
    let core_cfgs: &[(usize, &str)] =
        &[(3, "32"), (5, "64"), (9, "128"), (14, "192"), (20, "288")];
    for (title, suite) in [
        ("Fig. 14(a,b) — EC2 PROSITE, r=4", prosite_suite_cached()),
        ("Fig. 14(c,d) — EC2 PCRE, r=4", pcre_suite_cached()),
    ] {
        let mut t = Table::new(
            title,
            &["pattern", "|Q|", "S@32", "S@64", "S@128", "S@192", "S@288",
              "comm%@288"],
        );
        for p in spread_by_q(suite, 8) {
            let syms = p.input_syms(&mut InputGen::new(0xC10D), N_CLOUD);
            let mut row = vec![p.name.clone(), p.q().to_string()];
            let mut last_comm = 0.0;
            for &(nodes, _) in core_cfgs {
                let out_c = CloudMatcher::new(
                    &p.dfa,
                    ClusterSpec::homogeneous(nodes),
                )
                .lookahead(4)
                .base_rate(host_syms_per_us())
                .seed(0xEC2 + nodes as u64)
                .run_syms(&syms);
                row.push(fmt_speedup(out_c.speedup()));
                last_comm = out_c.comm_ratio();
            }
            row.push(format!("{:.2}%", last_comm * 100.0));
            t.row(row);
        }
        out.push(t);
    }
    out
}

/// Table 3: load-balance effectiveness (proportional stddev of matching
/// times) on six fast/slow EC2 instance mixes.
pub fn table3() -> Vec<Table> {
    let mixes: &[(usize, usize)] =
        &[(0, 5), (1, 4), (2, 3), (3, 2), (4, 1), (5, 0)];
    let mut t = Table::new(
        "Table 3 — load balancing on inhomogeneous clusters (CV of \
         matching times)",
        &["Fast", "Slow", "PROSITE min", "PROSITE avg", "PROSITE max",
          "PCRE min", "PCRE avg", "PCRE max"],
    );
    for &(fast, slow) in mixes {
        let mut row = vec![fast.to_string(), slow.to_string()];
        for suite in [prosite_suite_cached(), pcre_suite_cached()] {
            let mut cvs = Vec::new();
            for p in spread_by_q(suite, 6) {
                let syms = p.input_syms(&mut InputGen::new(0x7AB3), N_CLOUD / 4);
                let out_c = CloudMatcher::new(
                    &p.dfa,
                    ClusterSpec::fast_slow(fast, slow),
                )
                .lookahead(4)
                .adaptive_partition(true)
                .base_rate(host_syms_per_us())
                .seed(0x7AB3 + fast as u64 * 10 + slow as u64)
                .run_syms(&syms);
                cvs.push(out_c.balance_cv());
            }
            row.push(format!("{:.4}", stats::min(&cvs)));
            row.push(format!("{:.4}", stats::mean(&cvs)));
            row.push(format!("{:.4}", stats::max(&cvs)));
        }
        t.row(row);
    }

    // Ablation: the paper-faithful worst-case (I_max) partition vs this
    // repo's adaptive fixed-point partition, on the 4-fast/1-slow mix.
    let mut ta = Table::new(
        "Table 3 ablation — worst-case I_max partition vs adaptive (CV, \
         4 fast / 1 slow)",
        &["pattern", "|Q|", "CV fixed", "CV adaptive"],
    );
    for p in spread_by_q(prosite_suite_cached(), 6) {
        let syms = p.input_syms(&mut InputGen::new(0x7AB4), N_CLOUD / 4);
        let run = |adaptive: bool| {
            CloudMatcher::new(&p.dfa, ClusterSpec::fast_slow(4, 1))
                .lookahead(4)
                .adaptive_partition(adaptive)
                .base_rate(host_syms_per_us())
                .seed(0x7AB4)
                .run_syms(&syms)
                .balance_cv()
        };
        ta.row(vec![
            p.name.clone(),
            p.q().to_string(),
            format!("{:.4}", run(false)),
            format!("{:.4}", run(true)),
        ]);
    }
    vec![t, ta]
}

/// Fig. 19: cloud performance (a) and communication ratio (b) for input
/// sizes 10 MB..1 GB on 288 cores (PROSITE).
pub fn fig19() -> Vec<Table> {
    let mut sizes: Vec<(usize, &str)> =
        vec![(10 << 20, "10MB"), (100 << 20, "100MB")];
    if std::env::var("SPECDFA_BIG").is_ok() {
        sizes.push((1 << 30, "1GB"));
    }
    let mut t = Table::new(
        "Fig. 19 — EC2 input-size scaling, 20 nodes (288 cores), PROSITE, r=4",
        &["pattern", "|Q|", "size", "speedup", "comm%"],
    );
    for p in spread_by_q(prosite_suite_cached(), 3) {
        for &(n, label) in &sizes {
            let syms = p.input_syms(&mut InputGen::new(0xF1619), n);
            let out_c =
                CloudMatcher::new(&p.dfa, ClusterSpec::homogeneous(20))
                    .lookahead(4)
                    .base_rate(host_syms_per_us())
                    .seed(0xF19)
                    .run_syms(&syms);
            t.row(vec![
                p.name.clone(),
                p.q().to_string(),
                label.to_string(),
                fmt_speedup(out_c.speedup()),
                format!("{:.2}%", out_c.comm_ratio() * 100.0),
            ]);
        }
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn core_configs_match_paper_multiples_of_32() {
        // §6.2: "cluster sizes that are a multiple of 32 cores" up to 288
        for (nodes, label) in
            [(3usize, "32"), (5, "64"), (9, "128"), (14, "192"), (20, "288")]
        {
            let c = ClusterSpec::homogeneous(nodes);
            let cores = c.total_workers();
            let labelled: usize = label.parse().unwrap();
            assert!(cores >= labelled, "{nodes} nodes -> {cores} cores");
        }
    }
}
