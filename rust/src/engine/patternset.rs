//! Multi-pattern matching: one input pass answers k membership queries.
//!
//! Scanning one corpus against k patterns naively costs k full passes.
//! This module compiles a [`PatternSet`] into a [`CompiledSetMatcher`]
//! that answers all k queries with (at most) one prefilter pass plus one
//! fused-DFA pass, organised as three tiers:
//!
//! 1. **Prefilter** — every pattern with a *required literal*
//!    ([`crate::baseline::greplike::required_literal`]) registers it in
//!    one Aho–Corasick automaton ([`crate::automata::AhoCorasick`]); a
//!    single cheap pass clears each pattern whose literal is absent
//!    (verdict: reject) before any DFA runs.
//! 2. **Fused** — the surviving patterns' DFAs are fused into one
//!    product automaton ([`crate::automata::product::fuse`], the
//!    Simultaneous-FA construction of arXiv 1405.0562, built with the
//!    frontier-parallel scheme of arXiv 1512.09228) carrying a
//!    per-pattern accept bitmask ([`crate::util::bitset::BitSet`]).  The
//!    product is just another [`Dfa`](crate::automata::Dfa), so it runs
//!    through the existing [`CompiledMatcher`] stack — including
//!    [`Engine::Auto`] dispatch on the *fused* γ/|Q| and the speculative
//!    `FlatDfa`/`match_chunk_states` chunk kernel — and one traversal
//!    yields every pattern's final state by projection.
//! 3. **Spill** — fusing can blow up (reachable product ≤ ∏|Qᵢ|), so a
//!    `state_budget` caps it; patterns that don't fit are *spilled* back
//!    to ordinary per-pattern matchers, largest DFA first, until the
//!    rest fits.  Compilation therefore never fails on size — the same
//!    failure-freedom discipline as the speculative kernel (never wrong,
//!    only slower).
//!
//! Duplicate patterns in the set compile once and share an accept bit;
//! the per-slot outcomes are fanned back out in input order.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::analysis::fuse::estimate_fuse;
use crate::automata::acorasick::AhoCorasick;
use crate::automata::product::fuse;
use crate::automata::Dfa;
use crate::baseline::greplike::{required_literal, GrepStats};
use crate::regex::ast::Ast;
use crate::util::bitset::BitSet;

use super::outcome::{Detail, EngineKind, Outcome};
use super::select::DfaProps;
use super::{CompiledMatcher, Engine, ExecPolicy, Matcher, Pattern};

/// Default [`SetConfig::state_budget`]: comfortably holds every fused
/// set the bench suites produce while bounding worst-case construction
/// to a few MB of product table.
pub const DEFAULT_STATE_BUDGET: usize = 1 << 14;

/// An ordered collection of patterns matched together against one input.
///
/// Duplicates are allowed (each slot gets its own verdict) but compile
/// only once.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PatternSet {
    patterns: Vec<Pattern>,
}

impl PatternSet {
    /// An empty set.
    pub fn new() -> PatternSet {
        PatternSet::default()
    }

    /// Build from a list of patterns (order = verdict order).
    pub fn from_patterns(patterns: Vec<Pattern>) -> PatternSet {
        PatternSet { patterns }
    }

    /// Append a pattern (its verdict slot is the current length).
    pub fn push(&mut self, pattern: Pattern) {
        self.patterns.push(pattern);
    }

    /// Number of pattern slots (duplicates counted).
    pub fn len(&self) -> usize {
        self.patterns.len()
    }

    /// Whether the set has no patterns.
    pub fn is_empty(&self) -> bool {
        self.patterns.is_empty()
    }

    /// The pattern slots in verdict order.
    pub fn patterns(&self) -> &[Pattern] {
        &self.patterns
    }
}

/// Knobs for compiling a [`PatternSet`].
#[derive(Clone, Debug)]
pub struct SetConfig {
    /// Engine for the fused pass and the spilled per-pattern matchers.
    /// `Engine::Auto` dispatches on the *fused* DFA's γ/|Q|, so a fused
    /// set can route to a different substrate than its members would
    /// alone.  The AST engines (backtracking, grep-like) are rejected:
    /// a product DFA has no pattern AST.
    pub engine: Engine,
    /// Shared execution knobs; `policy.processors` also bounds the
    /// threads used for parallel product construction.
    pub policy: ExecPolicy,
    /// Product-state cap for the fused tier (0 = unlimited).  Overflow
    /// spills patterns instead of failing.
    pub state_budget: usize,
    /// γ cap for the *fused product* (the ROADMAP fused-γ policy): when
    /// set, a product that fuses within `state_budget` but whose
    /// γ = I_max,r/|Q| ([`DfaProps`]) exceeds the cap spills its largest
    /// component and retries — size alone no longer decides, because a
    /// speculation-hostile product (γ→1, e.g. fused permutation DFAs)
    /// would force every parallel substrate back to sequential cost.
    /// `None` (the default) keeps the size-only behavior.
    pub fuse_gamma_max: Option<f64>,
    /// Whether to build the Aho–Corasick literal prefilter tier.
    pub prefilter: bool,
}

impl Default for SetConfig {
    fn default() -> SetConfig {
        SetConfig {
            engine: Engine::Auto,
            policy: ExecPolicy::default(),
            state_budget: DEFAULT_STATE_BUDGET,
            fuse_gamma_max: None,
            prefilter: true,
        }
    }
}

/// Which tier decided a pattern's verdict on one run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SetTier {
    /// Required literal absent — rejected by the prefilter, no DFA ran.
    PrefilterCleared,
    /// Decided by the fused product pass.
    Fused,
    /// Decided by a per-pattern matcher (over `state_budget`).
    Spilled,
}

/// Per-pattern verdicts from one set run, plus set-level telemetry.
#[derive(Clone, Debug)]
pub struct SetOutcome {
    /// One [`Outcome`] per pattern slot, in [`PatternSet`] order
    /// (duplicate slots share the underlying result).
    pub outcomes: Vec<Outcome>,
    /// Which tier decided each slot.
    pub tiers: Vec<SetTier>,
    /// The raw fused-pass outcome, when the fused tier ran.
    pub fused_pass: Option<Outcome>,
    /// Unique patterns cleared by the prefilter on this input.
    pub prefilter_cleared: usize,
    /// Fuse attempts the pre-fuse estimator
    /// ([`crate::analysis::fuse::estimate_fuse`]) skipped at *compile*
    /// time because even the certain lower bound busted `state_budget`
    /// (each skip avoided a full-cost `fuse` abort; constant across
    /// runs of one compiled set).
    pub fuse_skipped_predicted: usize,
    /// Input length in bytes.
    pub n: usize,
    /// Wall time of the whole set run, seconds.
    pub wall_s: f64,
}

impl SetOutcome {
    /// Membership verdicts only, in slot order.
    pub fn accepted(&self) -> Vec<bool> {
        self.outcomes.iter().map(|o| o.accepted).collect()
    }
}

/// How a unique pattern is matched after compilation.
enum UniqTier {
    /// component `comp` of the fused product
    Fused { comp: usize },
    /// standalone matcher (over budget, or fusing was impossible)
    Spilled { cm: Box<CompiledMatcher> },
}

/// One deduplicated pattern with its tier assignment.
struct UniqPattern {
    pattern: Pattern,
    literal: Option<Vec<u8>>,
    tier: UniqTier,
}

/// The fused product tier: one matcher whose outcome projects back to
/// every fused component.
struct FusedTier {
    cm: CompiledMatcher,
    /// per product state: which components accept
    masks: Vec<BitSet>,
    /// per product state: component-state tuple
    proj: Vec<Vec<u32>>,
    /// component index -> unique-pattern index
    comps: Vec<usize>,
}

/// A [`PatternSet`] compiled for serving: prefilter + fused product +
/// spilled matchers, built once and reused across inputs.
///
/// ```
/// use specdfa::engine::{Matcher, Pattern};
/// use specdfa::engine::patternset::{CompiledSetMatcher, PatternSet, SetConfig};
///
/// let set = PatternSet::from_patterns(vec![
///     Pattern::Regex("cat".into()),
///     Pattern::Regex("d[ou]g".into()),
/// ]);
/// let csm = CompiledSetMatcher::compile(&set, SetConfig::default())?;
/// let out = csm.run_bytes(b"hot dog stand")?;
/// assert_eq!(out.accepted(), vec![false, true]);
/// # anyhow::Result::<()>::Ok(())
/// ```
pub struct CompiledSetMatcher {
    /// pattern slot -> unique-pattern index
    slot_of: Vec<usize>,
    uniq: Vec<UniqPattern>,
    prefilter: Option<AhoCorasick>,
    /// Aho–Corasick literal id -> unique-pattern index
    lit_uniq: Vec<usize>,
    fused: Option<FusedTier>,
    /// fuse attempts the pre-fuse estimator skipped at compile time
    fuse_skipped_predicted: usize,
    config: SetConfig,
}

impl CompiledSetMatcher {
    /// Compile a pattern set under the given configuration.  Never fails
    /// on product size (overflow spills); fails only on invalid patterns
    /// or an AST-engine request.
    pub fn compile(set: &PatternSet, config: SetConfig) -> Result<CompiledSetMatcher> {
        if matches!(config.engine, Engine::Backtracking | Engine::GrepLike) {
            bail!(
                "pattern-set matching needs a DFA engine; the AST engines \
                 (backtrack, grep) cannot run a fused product DFA"
            );
        }

        // 1. Dedupe: identical patterns share one compile + accept bit.
        let mut uniq_of: HashMap<&Pattern, usize> = HashMap::new();
        let mut slot_of = Vec::with_capacity(set.len());
        let mut sources: Vec<&Pattern> = Vec::new();
        for p in set.patterns() {
            let u = *uniq_of.entry(p).or_insert_with(|| {
                sources.push(p);
                sources.len() - 1
            });
            slot_of.push(u);
        }

        // 2. Per-unique compile: minimal DFA + optional AST + required
        //    literal.  The literal is a *necessary* condition only for
        //    unanchored search patterns (exactly when the AST survives
        //    `Pattern::compile`), so clearing on its absence is sound.
        struct Working {
            pattern: Pattern,
            dfa: Option<Dfa>,
            ast: Option<Ast>,
            literal: Option<Vec<u8>>,
        }
        let mut work: Vec<Working> = Vec::with_capacity(sources.len());
        for p in &sources {
            let parts = p.compile()?;
            let literal =
                parts.ast.as_ref().and_then(|ast| required_literal(ast));
            work.push(Working {
                pattern: (*p).clone(),
                dfa: Some(parts.dfa),
                ast: parts.ast,
                literal,
            });
        }

        // 3. Fuse with spill-retry: try the whole set; on budget
        //    overflow spill the largest DFA and retry.  Terminates (the
        //    candidate list shrinks every round) and never fails.  Two
        //    static checks run before/after each attempt: the pre-fuse
        //    size estimate skips attempts that are *certain* to bust the
        //    budget (the abort would otherwise be discovered at full
        //    construction cost), and the fused-γ policy spills out of a
        //    product that fused within budget but came out
        //    speculation-hostile.
        let threads = config.policy.processors.max(1);
        let mut fuse_order: Vec<usize> = (0..work.len()).collect();
        fuse_order.sort_by_key(|&u| {
            (work[u].dfa.as_ref().expect("dfa present").num_states, u)
        });
        let mut spilled_idx: Vec<usize> = Vec::new();
        let mut product = None;
        let mut fuse_skipped_predicted = 0usize;
        while !fuse_order.is_empty() {
            let dfas: Vec<&Dfa> = fuse_order
                .iter()
                .map(|&u| work[u].dfa.as_ref().expect("dfa present"))
                .collect();
            let est = estimate_fuse(&dfas, config.state_budget);
            if est.predicted_overflow {
                // sound skip: certain_min > budget means fuse() would
                // provably return None (all components read every byte,
                // so the largest trimmed component lower-bounds the
                // reachable product)
                fuse_skipped_predicted += 1;
                spilled_idx.push(
                    fuse_order.pop().expect("non-empty fuse candidates"),
                );
                continue;
            }
            match fuse(&dfas, config.state_budget, threads) {
                Some(p) => {
                    if let Some(limit) = config.fuse_gamma_max {
                        if fuse_order.len() >= 2 {
                            let props = DfaProps::analyze(
                                &p.dfa,
                                config.policy.lookahead.max(1),
                            );
                            if props.gamma > limit {
                                spilled_idx.push(
                                    fuse_order
                                        .pop()
                                        .expect("non-empty fuse candidates"),
                                );
                                continue;
                            }
                        }
                    }
                    product = Some(p);
                    break;
                }
                None => spilled_idx.push(
                    fuse_order.pop().expect("non-empty fuse candidates"),
                ),
            }
        }

        // 4. Assemble the tiers.
        let fused = match product {
            Some(p) => {
                let cm = CompiledMatcher::from_dfa(
                    p.dfa,
                    config.engine.clone(),
                    config.policy.clone(),
                )?;
                Some(FusedTier {
                    cm,
                    masks: p.accept_masks,
                    proj: p.proj,
                    comps: fuse_order.clone(),
                })
            }
            None => None,
        };
        let mut tier_of: Vec<Option<UniqTier>> =
            (0..work.len()).map(|_| None).collect();
        if let Some(f) = &fused {
            for (comp, &u) in f.comps.iter().enumerate() {
                tier_of[u] = Some(UniqTier::Fused { comp });
            }
        }
        for &u in &spilled_idx {
            let dfa = work[u].dfa.take().expect("spilled dfa");
            let ast = work[u].ast.take();
            let cm = CompiledMatcher::from_parts(
                dfa,
                ast,
                config.engine.clone(),
                config.policy.clone(),
            )?;
            tier_of[u] = Some(UniqTier::Spilled { cm: Box::new(cm) });
        }
        let uniq: Vec<UniqPattern> = work
            .into_iter()
            .zip(tier_of)
            .map(|(w, t)| UniqPattern {
                pattern: w.pattern,
                literal: w.literal,
                tier: t.expect("every unique pattern got a tier"),
            })
            .collect();

        // 5. Prefilter over every unique pattern that has a literal.
        let mut lit_uniq = Vec::new();
        let prefilter = if config.prefilter {
            let mut pairs: Vec<(&[u8], u32)> = Vec::new();
            for (u, up) in uniq.iter().enumerate() {
                if let Some(lit) = &up.literal {
                    if !lit.is_empty() {
                        pairs.push((lit.as_slice(), lit_uniq.len() as u32));
                        lit_uniq.push(u);
                    }
                }
            }
            if pairs.is_empty() {
                None
            } else {
                Some(AhoCorasick::new(&pairs, lit_uniq.len()))
            }
        } else {
            None
        };

        Ok(CompiledSetMatcher {
            slot_of,
            uniq,
            prefilter,
            lit_uniq,
            fused,
            fuse_skipped_predicted,
            config,
        })
    }

    /// Run every pattern against `input` in one coordinated pass:
    /// prefilter scan, at most one fused traversal, then the spilled
    /// stragglers.
    pub fn run_bytes(&self, input: &[u8]) -> Result<SetOutcome> {
        let t0 = Instant::now();

        // Tier 1: literal presence clears patterns outright.
        let mut cleared = vec![false; self.uniq.len()];
        let mut prefilter_cleared = 0usize;
        if let Some(ac) = &self.prefilter {
            let present = ac.presence(input);
            for (id, &u) in self.lit_uniq.iter().enumerate() {
                if !present[id] {
                    cleared[u] = true;
                    prefilter_cleared += 1;
                }
            }
        }

        // Tier 2: one fused traversal, skipped when the prefilter
        // already cleared every fused component.
        let fused_pass = match &self.fused {
            Some(f) if f.comps.iter().any(|&u| !cleared[u]) => {
                Some(f.cm.run_bytes(input)?)
            }
            _ => None,
        };
        let fused_state = match &fused_pass {
            Some(out) => match out.final_state {
                Some(q) => Some(q as usize),
                None => bail!(
                    "fused pass reported no final state (engine {})",
                    out.engine
                ),
            },
            None => None,
        };

        // Tier 3 + assembly: per-unique outcomes.
        let mut per_uniq: Vec<(Outcome, SetTier)> =
            Vec::with_capacity(self.uniq.len());
        for (u, up) in self.uniq.iter().enumerate() {
            if cleared[u] {
                per_uniq.push((
                    cleared_outcome(input.len()),
                    SetTier::PrefilterCleared,
                ));
                continue;
            }
            match &up.tier {
                UniqTier::Fused { comp } => {
                    let f = self.fused.as_ref().expect("fused tier present");
                    let q = fused_state.expect("fused pass ran");
                    let mut out = fused_pass
                        .as_ref()
                        .expect("fused pass ran")
                        .clone();
                    out.accepted = f.masks[q].contains(*comp);
                    out.final_state = Some(f.proj[q][*comp]);
                    per_uniq.push((out, SetTier::Fused));
                }
                UniqTier::Spilled { cm } => {
                    per_uniq.push((cm.run_bytes(input)?, SetTier::Spilled));
                }
            }
        }

        // Fan unique results back out to the original slots.
        let mut outcomes = Vec::with_capacity(self.slot_of.len());
        let mut tiers = Vec::with_capacity(self.slot_of.len());
        for &u in &self.slot_of {
            outcomes.push(per_uniq[u].0.clone());
            tiers.push(per_uniq[u].1);
        }
        Ok(SetOutcome {
            outcomes,
            tiers,
            fused_pass,
            prefilter_cleared,
            fuse_skipped_predicted: self.fuse_skipped_predicted,
            n: input.len(),
            wall_s: t0.elapsed().as_secs_f64(),
        })
    }

    /// Human-readable description of the compiled tiers.
    pub fn describe(&self) -> String {
        let fused_q = self
            .fused
            .as_ref()
            .map(|f| f.cm.dfa().num_states)
            .unwrap_or(0);
        format!(
            "patternset: {} slots, {} unique ({} fused over |Q|={} product, \
             {} spilled), {} prefilter literals, budget {}",
            self.slot_of.len(),
            self.uniq.len(),
            self.fused_patterns(),
            fused_q,
            self.spilled_patterns(),
            self.lit_uniq.len(),
            self.config.state_budget,
        )
    }

    /// Number of unique patterns after dedupe.
    pub fn unique_patterns(&self) -> usize {
        self.uniq.len()
    }

    /// Unique patterns matched by the fused product tier.
    pub fn fused_patterns(&self) -> usize {
        self.fused.as_ref().map_or(0, |f| f.comps.len())
    }

    /// Unique patterns spilled to per-pattern matchers.
    pub fn spilled_patterns(&self) -> usize {
        self.uniq
            .iter()
            .filter(|u| matches!(u.tier, UniqTier::Spilled { .. }))
            .count()
    }

    /// Unique patterns guarded by a prefilter literal.
    pub fn prefiltered_patterns(&self) -> usize {
        self.lit_uniq.len()
    }

    /// Fuse attempts the pre-fuse size estimator skipped at compile
    /// time (each one a `fuse` run that would have aborted at full
    /// construction cost).
    pub fn fuse_skips_predicted(&self) -> usize {
        self.fuse_skipped_predicted
    }

    /// |Q| of the fused product DFA, when the fused tier exists.
    pub fn product_states(&self) -> Option<usize> {
        self.fused.as_ref().map(|f| f.cm.dfa().num_states as usize)
    }

    /// Structural properties of the fused product (γ, |Q|, I_max,r) —
    /// what `Engine::Auto` dispatches on for the fused pass.
    pub fn fused_props(&self) -> Option<&DfaProps> {
        self.fused.as_ref().map(|f| f.cm.props())
    }

    /// The unique patterns in first-appearance order.
    pub fn uniq_patterns(&self) -> impl Iterator<Item = &Pattern> {
        self.uniq.iter().map(|u| &u.pattern)
    }

    /// The configuration this set was compiled under.
    pub fn config(&self) -> &SetConfig {
        &self.config
    }
}

/// The synthesized reject verdict for a prefilter-cleared pattern: the
/// prefilter *is* a grep-like engine (literal scan, no DFA), so the
/// outcome reports [`EngineKind::GrepLike`] with the scan length as its
/// work, and no final state (the DFA never ran).
fn cleared_outcome(n: usize) -> Outcome {
    Outcome {
        engine: EngineKind::GrepLike,
        n,
        accepted: false,
        final_state: None,
        makespan: n,
        overhead_syms: 0,
        per_worker_syms: Vec::new(),
        wall_s: 0.0,
        selection: None,
        detail: Detail::GrepLike(GrepStats {
            matched: false,
            work: n as u64,
            candidates: 0,
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn regexes(pats: &[&str]) -> PatternSet {
        PatternSet::from_patterns(
            pats.iter().map(|p| Pattern::Regex(p.to_string())).collect(),
        )
    }

    fn quick() -> SetConfig {
        SetConfig {
            policy: ExecPolicy { processors: 2, ..ExecPolicy::default() },
            ..SetConfig::default()
        }
    }

    #[test]
    fn empty_set_runs_and_returns_nothing() {
        let csm =
            CompiledSetMatcher::compile(&PatternSet::new(), quick()).unwrap();
        let out = csm.run_bytes(b"anything").unwrap();
        assert!(out.outcomes.is_empty());
        assert!(out.fused_pass.is_none());
        assert_eq!(out.prefilter_cleared, 0);
    }

    #[test]
    fn fused_set_reports_per_pattern_verdicts() {
        let set = regexes(&["cat", "dog", "bird"]);
        let csm = CompiledSetMatcher::compile(&set, quick()).unwrap();
        assert_eq!(csm.fused_patterns(), 3);
        assert_eq!(csm.spilled_patterns(), 0);
        let out = csm.run_bytes(b"the dog chased the bird").unwrap();
        assert_eq!(out.accepted(), vec![false, true, true]);
        // "cat" was cleared by the prefilter (literal absent)
        assert_eq!(out.tiers[0], SetTier::PrefilterCleared);
        assert_eq!(out.tiers[1], SetTier::Fused);
        assert!(out.fused_pass.is_some());
        assert_eq!(out.prefilter_cleared, 1);
    }

    #[test]
    fn duplicates_share_a_compile_and_a_verdict() {
        let set = regexes(&["ab+", "cd", "ab+"]);
        let csm = CompiledSetMatcher::compile(&set, quick()).unwrap();
        assert_eq!(csm.unique_patterns(), 2);
        let out = csm.run_bytes(b"xxabbxx").unwrap();
        assert_eq!(out.accepted(), vec![true, false, true]);
        assert_eq!(out.outcomes.len(), 3);
        assert_eq!(out.tiers[0], out.tiers[2]);
    }

    #[test]
    fn tiny_budget_spills_everything_but_still_answers() {
        let set = regexes(&["cat", "dog"]);
        let cfg = SetConfig { state_budget: 1, ..quick() };
        let csm = CompiledSetMatcher::compile(&set, cfg).unwrap();
        assert_eq!(csm.fused_patterns(), 0);
        assert_eq!(csm.spilled_patterns(), 2);
        assert!(csm.product_states().is_none());
        // the estimator predicted every round's overflow statically —
        // each component alone already exceeds a budget of 1 — so no
        // fuse() construction was ever paid for
        assert_eq!(csm.fuse_skips_predicted(), 2);
        let out = csm.run_bytes(b"hot dog").unwrap();
        assert_eq!(out.accepted(), vec![false, true]);
        assert_eq!(out.tiers[1], SetTier::Spilled);
        assert_eq!(out.fuse_skipped_predicted, 2);
    }

    #[test]
    fn fused_gamma_policy_spills_hostile_products() {
        use crate::automata::grail::to_grail;
        use crate::util::workload::permutation_dfa;

        // Each component is a permutation DFA (γ = 1 at every r), and a
        // product of permutations is a permutation, so the fused product
        // is speculation-hostile however small it is.
        let set = PatternSet::from_patterns(vec![
            Pattern::Grail(to_grail(&permutation_dfa(8, 4, 11))),
            Pattern::Grail(to_grail(&permutation_dfa(8, 4, 12))),
        ]);

        // size-only policy (default): the 64-state product fits the
        // budget comfortably, so both patterns fuse
        let csm =
            CompiledSetMatcher::compile(&set, quick()).unwrap();
        assert_eq!(csm.fused_patterns(), 2);
        let props = csm.fused_props().expect("fused tier exists");
        assert!(props.gamma > 0.5, "product not hostile: {props:?}");

        // fused-γ policy: the same set spills because the product's γ
        // exceeds the cap — size alone no longer decides
        let cfg = SetConfig { fuse_gamma_max: Some(0.5), ..quick() };
        let csm = CompiledSetMatcher::compile(&set, cfg).unwrap();
        assert!(csm.fused_patterns() <= 1, "{}", csm.describe());
        assert!(csm.spilled_patterns() >= 1, "{}", csm.describe());
        // verdicts are unchanged by the tier split
        let input: Vec<u8> = (0u8..64).collect();
        let a = CompiledSetMatcher::compile(&set, quick())
            .unwrap()
            .run_bytes(&input)
            .unwrap();
        let b = csm.run_bytes(&input).unwrap();
        assert_eq!(a.accepted(), b.accepted());
    }

    #[test]
    fn prefilter_can_be_disabled() {
        let set = regexes(&["cat"]);
        let cfg = SetConfig { prefilter: false, ..quick() };
        let csm = CompiledSetMatcher::compile(&set, cfg).unwrap();
        assert_eq!(csm.prefiltered_patterns(), 0);
        let out = csm.run_bytes(b"no felines here").unwrap();
        assert_eq!(out.accepted(), vec![false]);
        assert_eq!(out.tiers[0], SetTier::Fused); // DFA decided, not prefilter
    }

    #[test]
    fn ast_engines_are_rejected() {
        let set = regexes(&["cat"]);
        for engine in [Engine::Backtracking, Engine::GrepLike] {
            let cfg = SetConfig { engine, ..SetConfig::default() };
            assert!(CompiledSetMatcher::compile(&set, cfg).is_err());
        }
    }

    #[test]
    fn fused_final_states_project_to_sequential_runs() {
        let set = regexes(&["ab|ba", "a+b", "(ab)+"]);
        let cfg = SetConfig {
            engine: Engine::Sequential,
            prefilter: false, // force every pattern through the product
            ..quick()
        };
        let csm = CompiledSetMatcher::compile(&set, cfg).unwrap();
        for input in [&b""[..], b"ab", b"aab", b"abab", b"bbba"] {
            let out = csm.run_bytes(input).unwrap();
            for (slot, p) in set.patterns().iter().enumerate() {
                let solo = CompiledMatcher::compile(
                    p,
                    Engine::Sequential,
                    ExecPolicy::default(),
                )
                .unwrap();
                let want = solo.run_bytes(input).unwrap();
                assert_eq!(out.outcomes[slot].accepted, want.accepted);
                assert_eq!(out.outcomes[slot].final_state, want.final_state);
            }
        }
    }
}
