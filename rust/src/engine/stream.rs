//! Streaming, checkpoint-resumable matching: [`StreamMatcher`] wraps a
//! [`CompiledMatcher`] and accepts the input in **segments** instead of
//! demanding the whole corpus in memory.
//!
//! ```text
//!   feed(seg) ──▶ pending buffer ──fold──▶ chunk kernel ──▶ LVector
//!                     │                       (Eq. 9 compose per fold)
//!              checkpoint() ⇄ to_bytes/from_bytes ⇄ another worker
//!                     │
//!   finish() ──▶ Outcome (EngineKind::Stream, Detail::Stream)
//! ```
//!
//! The carried state is exactly the paper's combine operand: a composed
//! L-vector (Fig. 9 / Eq. 9).  The stream seeds it as the *constant map
//! to the start state* — every entry maps to `q0` — so after folding
//! bytes `w` every entry equals `δ*(q0, w)`.  Composition preserves the
//! singleton image, which keeps per-segment work sequential-scale: the
//! stream pays one chain per fold, not |Q|.
//!
//! Three capabilities fall out of that state being small and explicit:
//!
//! * **Unbounded tailing** — memory is `O(|Q| + fold threshold)`
//!   regardless of how many bytes have streamed through.
//! * **Preempt / resume** — [`StreamMatcher::checkpoint`] snapshots the
//!   stream; [`StreamMatcher::from_checkpoint`] continues it, on any
//!   worker.  The serve loop uses this to park long scans when probes
//!   arrive ([`super::serve::ServeConfig::preempt_scans`]).
//! * **Migration framing** — [`Checkpoint::to_bytes`] /
//!   [`Checkpoint::from_bytes`] give the future multi-process cluster a
//!   versioned wire format for moving a scan between processes.
//!
//! Byte-to-symbol mapping is stateless per byte (`Dfa::class_of`), so a
//! segment boundary can land anywhere; the `pending` buffer only
//! coalesces small feeds up to the fold threshold so kernel entry cost
//! is amortized, and [`StreamMatcher::finish`] flushes the remainder.

// Checkpoints cross process boundaries (the serve loop parks scans on
// them; the cluster migrates them): decode failures must be `Err`, not
// panics.  Enforced by clippy.toml `disallowed-methods`.
#![deny(clippy::disallowed_methods)]

use std::time::Instant;

use anyhow::{bail, Result};

use crate::automata::FlatDfa;
use crate::speculative::chunk::match_chunk_states_resume;
use crate::speculative::lvector::LVector;

use super::outcome::{Detail, EngineKind, Outcome};
use super::CompiledMatcher;

/// Default fold threshold in bytes: `feed` buffers until this many
/// bytes are pending, then folds them through the chunk kernel in one
/// call.  Large enough to amortize kernel entry, small enough that a
/// tailing stream stays constant-memory.
pub const DEFAULT_FOLD_BYTES: usize = 1 << 16;

const CKPT_MAGIC: &[u8; 4] = b"SDCK";
const CKPT_VERSION: u16 = 1;

/// Work/progress counters of one streamed run, carried inside the
/// [`Checkpoint`] and reported as [`Detail::Stream`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StreamStats {
    /// `feed` calls accepted by the stream (across resumes).
    pub segments: u64,
    /// Kernel folds executed (each flushes the pending buffer).
    pub folds: u64,
    /// Symbol steps executed across all folds.
    pub syms: u64,
    /// Chains merged by convergence collapsing inside folds.
    pub collapses: u64,
    /// Whether this run was resumed from a serialized checkpoint at
    /// least once.
    pub resumed: bool,
}

/// The compact resumable state of a [`StreamMatcher`]: the composed
/// L-vector, how many bytes it covers, the not-yet-folded pending
/// bytes, and the work counters.  Complete by construction — a stream
/// rebuilt from a checkpoint continues byte-identically, on any worker.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Checkpoint {
    /// composed state map: entry `q` equals `δ*(q0, folded bytes)` for
    /// every `q` (constant image — see the module docs)
    lv: LVector,
    /// bytes already folded through the chunk kernel
    folded: u64,
    /// bytes accepted by `feed` but not yet folded
    pending: Vec<u8>,
    stats: StreamStats,
}

impl Checkpoint {
    /// Total bytes this checkpoint covers (folded + buffered).
    pub fn offset(&self) -> u64 {
        self.folded + self.pending.len() as u64
    }

    /// Bytes buffered but not yet folded through the kernel.
    pub fn buffered(&self) -> usize {
        self.pending.len()
    }

    /// |Q| of the DFA this checkpoint belongs to (resume validates it).
    pub fn num_states(&self) -> usize {
        self.lv.len()
    }

    /// The carried work counters.
    pub fn stats(&self) -> StreamStats {
        self.stats
    }

    /// The composed L-vector itself — the Fig. 9 merge operand.  A
    /// chunk-mode stream ([`StreamMatcher::for_chunk`]) finishes with
    /// entry `q` equal to `δ*(q, chunk)`, so a cluster frontend
    /// composes per-chunk checkpoints with
    /// [`LVector::compose`] (Eq. 9) instead of rescanning anything.
    pub fn lvector(&self) -> &LVector {
        &self.lv
    }

    /// Serialize to the versioned `SDCK` wire format (little-endian):
    /// magic, version, flags, |Q|, the counters, the state map, the
    /// grounded-entry bitset, and the pending bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let q = self.lv.len();
        let mut out =
            Vec::with_capacity(64 + 4 * q + q / 8 + self.pending.len());
        out.extend_from_slice(CKPT_MAGIC);
        out.extend_from_slice(&CKPT_VERSION.to_le_bytes());
        let flags: u16 = u16::from(self.stats.resumed);
        out.extend_from_slice(&flags.to_le_bytes());
        out.extend_from_slice(&(q as u32).to_le_bytes());
        for v in [
            self.folded,
            self.stats.segments,
            self.stats.folds,
            self.stats.syms,
            self.stats.collapses,
        ] {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for i in 0..q as u32 {
            out.extend_from_slice(&self.lv.get(i).to_le_bytes());
        }
        // grounded-entry bitset, LSB-first within each byte
        let mut acc = 0u8;
        for i in 0..q {
            if self.lv.was_matched(i as u32) {
                acc |= 1 << (i % 8);
            }
            if i % 8 == 7 {
                out.push(acc);
                acc = 0;
            }
        }
        if q % 8 != 0 {
            out.push(acc);
        }
        out.extend_from_slice(&(self.pending.len() as u64).to_le_bytes());
        out.extend_from_slice(&self.pending);
        out
    }

    /// Deserialize a checkpoint written by [`Checkpoint::to_bytes`].
    /// Every field is validated (magic, version, lengths, state-map
    /// range) so a corrupt or truncated frame fails loudly instead of
    /// resuming a scan from garbage.
    pub fn from_bytes(bytes: &[u8]) -> Result<Checkpoint> {
        let mut cur = Cursor { buf: bytes, pos: 0 };
        if cur.take(4)? != CKPT_MAGIC {
            bail!("not a specdfa checkpoint (bad magic)");
        }
        let version = cur.u16()?;
        if version != CKPT_VERSION {
            bail!(
                "unsupported checkpoint version {version} \
                 (this build reads v{CKPT_VERSION})"
            );
        }
        let flags = cur.u16()?;
        if flags > 1 {
            bail!("unknown checkpoint flags {flags:#06x}");
        }
        let q = cur.u32()? as usize;
        if q == 0 {
            bail!("checkpoint carries an empty state map");
        }
        let folded = cur.u64()?;
        let stats = StreamStats {
            segments: cur.u64()?,
            folds: cur.u64()?,
            syms: cur.u64()?,
            collapses: cur.u64()?,
            resumed: flags & 1 != 0,
        };
        let mut map = Vec::with_capacity(q);
        for _ in 0..q {
            let entry = cur.u32()?;
            if entry as usize >= q {
                bail!("checkpoint state-map entry {entry} out of range");
            }
            map.push(entry);
        }
        let bits = cur.take(q.div_ceil(8))?;
        let matched: Vec<bool> =
            (0..q).map(|i| (bits[i / 8] >> (i % 8)) & 1 != 0).collect();
        let pending_len = cur.u64()?;
        let pending_len = usize::try_from(pending_len)
            .map_err(|_| anyhow::anyhow!("absurd pending length"))?;
        let pending = cur.take(pending_len)?.to_vec();
        if cur.pos != bytes.len() {
            bail!(
                "{} trailing bytes after the checkpoint frame",
                bytes.len() - cur.pos
            );
        }
        Ok(Checkpoint {
            lv: LVector::from_raw(map, matched),
            folded,
            pending,
            stats,
        })
    }
}

/// Bounds-checked little-endian reader for [`Checkpoint::from_bytes`].
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() - self.pos < n {
            bail!("checkpoint truncated at byte {}", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }
}

/// Progress report returned by [`StreamMatcher::feed`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FeedProgress {
    /// Total bytes accepted so far (folded + buffered).
    pub offset: u64,
    /// Bytes already folded through the chunk kernel.
    pub folded: u64,
    /// Bytes buffered, awaiting the next fold (`finish` flushes them).
    pub buffered: usize,
}

/// Segment-streamed matching over any [`CompiledMatcher`]: `feed`
/// segments as they arrive, `checkpoint`/resume at will, `finish` for
/// the [`Outcome`].  See the [module docs](self) for the state model.
///
/// ```
/// use specdfa::engine::{CompiledMatcher, Engine, ExecPolicy, Pattern};
/// use specdfa::engine::stream::StreamMatcher;
///
/// let cm = CompiledMatcher::compile(
///     &Pattern::Regex("ab+c".to_string()),
///     Engine::Auto,
///     ExecPolicy::default(),
/// )?;
/// let mut sm = StreamMatcher::new(&cm);
/// sm.feed(b"xx ab");
/// sm.feed(b"bbc yy");          // match straddles the boundary
/// let out = sm.finish();
/// assert!(out.accepted);
/// assert_eq!(out.n, 11);
/// # anyhow::Result::<()>::Ok(())
/// ```
pub struct StreamMatcher<'m> {
    matcher: &'m CompiledMatcher,
    flat: &'m FlatDfa,
    ckpt: Checkpoint,
    fold_bytes: usize,
    wall_s: f64,
}

impl<'m> StreamMatcher<'m> {
    /// Start a fresh stream with the default fold threshold.
    pub fn new(matcher: &'m CompiledMatcher) -> StreamMatcher<'m> {
        Self::with_fold_bytes(matcher, DEFAULT_FOLD_BYTES)
    }

    /// Start a fresh stream folding every `fold_bytes` pending bytes
    /// (clamped to at least 1; 1 folds on every feed).
    pub fn with_fold_bytes(
        matcher: &'m CompiledMatcher,
        fold_bytes: usize,
    ) -> StreamMatcher<'m> {
        let dfa = matcher.dfa();
        let q = dfa.num_states as usize;
        // the constant map to q0: after folding bytes w, every entry
        // equals delta*(q0, w) — the streaming seed (module docs)
        let lv = LVector::from_raw(vec![dfa.start; q], vec![true; q]);
        StreamMatcher {
            matcher,
            flat: matcher.seq.flat(),
            ckpt: Checkpoint {
                lv,
                folded: 0,
                pending: Vec::new(),
                stats: StreamStats::default(),
            },
            fold_bytes: fold_bytes.max(1),
            wall_s: 0.0,
        }
    }

    /// Start a **chunk-mode** stream: the L-vector is seeded with the
    /// *identity map* (entry `q` starts at `q`) instead of the constant
    /// map to `q0`, so after streaming a chunk through it, entry `q`
    /// equals `δ*(q, chunk)` — the chunk's full per-state L-vector.
    /// This is the worker side of the multi-process cluster
    /// ([`crate::cluster::proc`]): every worker folds its own chunk
    /// blind to the others' final states, and the frontend composes
    /// the finished maps in chunk order (Fig. 9 / Eq. 9) to recover the
    /// sequential verdict.  Costs up to |Q| chains per fold where the
    /// constant seed pays one — the paper's price for speculation.
    pub fn for_chunk(matcher: &'m CompiledMatcher) -> StreamMatcher<'m> {
        let q = matcher.dfa().num_states as usize;
        let lv =
            LVector::from_raw((0..q as u32).collect(), vec![true; q]);
        StreamMatcher {
            matcher,
            flat: matcher.seq.flat(),
            ckpt: Checkpoint {
                lv,
                folded: 0,
                pending: Vec::new(),
                stats: StreamStats::default(),
            },
            fold_bytes: DEFAULT_FOLD_BYTES,
            wall_s: 0.0,
        }
    }

    /// Continue a stream from a checkpoint — possibly taken by another
    /// `StreamMatcher` on another worker (or deserialized from another
    /// process).  Fails when the checkpoint's |Q| does not match this
    /// matcher's DFA: resuming under a different pattern is undefined
    /// and must be refused.
    pub fn from_checkpoint(
        matcher: &'m CompiledMatcher,
        ckpt: Checkpoint,
    ) -> Result<StreamMatcher<'m>> {
        let q = matcher.dfa().num_states as usize;
        if ckpt.lv.len() != q {
            bail!(
                "checkpoint is for a {}-state DFA, matcher has {} states",
                ckpt.lv.len(),
                q
            );
        }
        let mut ckpt = ckpt;
        ckpt.stats.resumed = true;
        Ok(StreamMatcher {
            matcher,
            flat: matcher.seq.flat(),
            ckpt,
            fold_bytes: DEFAULT_FOLD_BYTES,
            wall_s: 0.0,
        })
    }

    /// Change the fold threshold (clamped to at least 1).
    pub fn set_fold_bytes(&mut self, fold_bytes: usize) {
        self.fold_bytes = fold_bytes.max(1);
    }

    /// Accept one input segment.  The segment may split anywhere —
    /// byte-to-symbol mapping is stateless — and is folded through the
    /// kernel once the pending buffer reaches the fold threshold.
    pub fn feed(&mut self, segment: &[u8]) -> FeedProgress {
        self.ckpt.stats.segments += 1;
        self.ckpt.pending.extend_from_slice(segment);
        if self.ckpt.pending.len() >= self.fold_bytes {
            self.fold();
        }
        FeedProgress {
            offset: self.ckpt.offset(),
            folded: self.ckpt.folded,
            buffered: self.ckpt.pending.len(),
        }
    }

    /// Fold any buffered bytes through the kernel right now, leaving
    /// the pending buffer empty.  A cluster worker flushes before
    /// taking the final [`StreamMatcher::checkpoint`] of a chunk so the
    /// shipped L-vector covers every byte ([`Checkpoint::buffered`]
    /// is 0 and [`Checkpoint::offset`] equals the fold count).
    pub fn flush(&mut self) {
        self.fold();
    }

    /// Snapshot the resumable state (pending bytes included).
    pub fn checkpoint(&self) -> Checkpoint {
        self.ckpt.clone()
    }

    /// Total bytes accepted so far.
    pub fn offset(&self) -> u64 {
        self.ckpt.offset()
    }

    /// Flush the pending buffer and report the outcome of everything
    /// streamed so far, as [`EngineKind::Stream`] with the run's
    /// [`StreamStats`] in [`Detail::Stream`].
    pub fn finish(mut self) -> Outcome {
        self.fold();
        let dfa = self.matcher.dfa();
        let fin = self.ckpt.lv.get(dfa.start);
        let n = self.ckpt.folded as usize;
        let stats = self.ckpt.stats;
        let syms = stats.syms as usize;
        Outcome {
            engine: EngineKind::Stream,
            n,
            accepted: dfa.accepting[fin as usize],
            final_state: Some(fin),
            makespan: syms,
            overhead_syms: syms.saturating_sub(n),
            per_worker_syms: vec![syms],
            wall_s: self.wall_s,
            selection: None,
            detail: Detail::Stream(stats),
        }
    }

    /// Fold the pending bytes through the chunk kernel and compose the
    /// segment's map into the carried L-vector (Eq. 9).
    fn fold(&mut self) {
        if self.ckpt.pending.is_empty() {
            return;
        }
        let t0 = Instant::now();
        let pending = std::mem::take(&mut self.ckpt.pending);
        let syms = self.matcher.dfa().map_input(&pending);
        let chunk = self.flat.validate(&syms);
        let work = match_chunk_states_resume(
            self.flat,
            &mut self.ckpt.lv,
            chunk,
            self.matcher.policy.collapse_every,
        );
        self.ckpt.folded += pending.len() as u64;
        self.ckpt.stats.folds += 1;
        self.ckpt.stats.syms += work.syms_matched as u64;
        self.ckpt.stats.collapses += work.collapses as u64;
        self.wall_s += t0.elapsed().as_secs_f64();
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // unwrap in tests is a test failure
mod tests {
    use super::super::{Engine, ExecPolicy, Matcher, Pattern};
    use super::*;

    fn compile(pattern: &str) -> CompiledMatcher {
        CompiledMatcher::compile(
            &Pattern::Regex(pattern.to_string()),
            Engine::Sequential,
            ExecPolicy::default(),
        )
        .unwrap()
    }

    #[test]
    fn streamed_equals_one_shot_across_boundaries() {
        let cm = compile("ab+c");
        let input = b"xx abbbbc yy";
        let want = cm.run_bytes(input).unwrap();
        for cut in 0..=input.len() {
            let mut sm = StreamMatcher::with_fold_bytes(&cm, 4);
            sm.feed(&input[..cut]);
            sm.feed(&input[cut..]);
            let out = sm.finish();
            assert_eq!(out.accepted, want.accepted, "cut {cut}");
            assert_eq!(out.final_state, want.final_state, "cut {cut}");
            assert_eq!(out.n, input.len());
            assert_eq!(out.engine, EngineKind::Stream);
        }
    }

    #[test]
    fn empty_stream_reports_the_start_state() {
        let cm = compile("a*");
        let out = StreamMatcher::new(&cm).finish();
        assert_eq!(out.n, 0);
        assert_eq!(out.final_state, Some(cm.dfa().start));
        // "a*" matches the empty input under search semantics
        assert!(out.accepted);
    }

    #[test]
    fn checkpoint_roundtrips_through_bytes() {
        let cm = compile("needle");
        let mut sm = StreamMatcher::with_fold_bytes(&cm, 8);
        sm.feed(b"hay hay "); // reaches the threshold: folds
        sm.feed(b"hay nee"); // below it: stays buffered
        let ckpt = sm.checkpoint();
        assert!(ckpt.buffered() > 0, "fold threshold leaves a remainder");
        let decoded = Checkpoint::from_bytes(&ckpt.to_bytes()).unwrap();
        assert_eq!(decoded, ckpt);
        // resume from the decoded frame and finish both ways
        let mut resumed =
            StreamMatcher::from_checkpoint(&cm, decoded).unwrap();
        resumed.feed(b"dle hay");
        sm.feed(b"dle hay");
        let a = resumed.finish();
        let b = sm.finish();
        assert!(a.accepted && b.accepted);
        assert_eq!(a.final_state, b.final_state);
        assert_eq!(a.n, b.n);
        match &a.detail {
            Detail::Stream(stats) => assert!(stats.resumed),
            other => panic!("expected stream detail, got {other:?}"),
        }
    }

    #[test]
    fn chunk_mode_composes_to_the_one_shot_verdict() {
        let cm = compile("(ab|cd)+e");
        let input: Vec<u8> = (0..4096u32)
            .map(|i| b"abcde"[(i.wrapping_mul(2654435761) % 5) as usize])
            .collect();
        let want = cm.run_bytes(&input).unwrap();
        for split in [1usize, 7, 1000, 2048, 4095] {
            let (left, right) = input.split_at(split);
            let mut a = StreamMatcher::for_chunk(&cm);
            a.set_fold_bytes(64);
            a.feed(left);
            a.flush();
            let ca = a.checkpoint();
            assert_eq!(ca.buffered(), 0, "flush must empty the buffer");
            assert_eq!(ca.offset(), split as u64);
            let mut b = StreamMatcher::for_chunk(&cm);
            b.feed(right);
            b.flush();
            let cb = b.checkpoint();
            // Fig. 9 / Eq. 9: compose the chunk maps in order, then
            // read the start-state entry
            let lv = ca.lvector().compose(cb.lvector());
            let fin = lv.get(cm.dfa().start);
            assert_eq!(Some(fin), want.final_state, "split {split}");
            assert_eq!(
                cm.dfa().accepting[fin as usize],
                want.accepted,
                "split {split}"
            );
        }
    }

    #[test]
    fn chunk_mode_resume_continues_midway() {
        let cm = compile("needle");
        let input = vec![b'x'; 3000]
            .into_iter()
            .chain(b"needle".iter().copied())
            .chain(vec![b'y'; 1000])
            .collect::<Vec<u8>>();
        // a "worker" dies after folding the first 2000 bytes; its last
        // checkpoint resumes on a fresh stream that feeds the rest
        let mut victim = StreamMatcher::for_chunk(&cm);
        victim.set_fold_bytes(500);
        victim.feed(&input[..2000]);
        let ckpt = victim.checkpoint();
        let wire = ckpt.to_bytes();
        let restored = Checkpoint::from_bytes(&wire).unwrap();
        let offset = restored.offset() as usize;
        let mut survivor =
            StreamMatcher::from_checkpoint(&cm, restored).unwrap();
        survivor.feed(&input[offset..]);
        survivor.flush();
        let lv = survivor.checkpoint();
        assert_eq!(lv.offset() as usize, input.len());
        assert!(lv.stats().resumed);
        let fin = lv.lvector().get(cm.dfa().start);
        let want = cm.run_bytes(&input).unwrap();
        assert_eq!(Some(fin), want.final_state);
        assert!(cm.dfa().accepting[fin as usize]);
    }

    #[test]
    fn from_bytes_rejects_corrupt_frames() {
        let cm = compile("abc");
        let mut sm = StreamMatcher::new(&cm);
        sm.feed(b"ab");
        let good = sm.checkpoint().to_bytes();
        assert!(Checkpoint::from_bytes(b"nope").is_err());
        assert!(Checkpoint::from_bytes(&good[..good.len() - 1]).is_err());
        let mut bad_magic = good.clone();
        bad_magic[0] = b'X';
        assert!(Checkpoint::from_bytes(&bad_magic).is_err());
        let mut bad_version = good.clone();
        bad_version[4] = 99;
        assert!(Checkpoint::from_bytes(&bad_version).is_err());
        let mut trailing = good.clone();
        trailing.push(0);
        assert!(Checkpoint::from_bytes(&trailing).is_err());
        assert!(Checkpoint::from_bytes(&good).is_ok());
    }

    #[test]
    fn resume_refuses_a_mismatched_matcher() {
        let small = compile("a");
        let big = compile("(abc|def)+ghi");
        let ckpt = StreamMatcher::new(&big).checkpoint();
        let err = StreamMatcher::from_checkpoint(&small, ckpt)
            .err()
            .expect("|Q| mismatch must be refused");
        assert!(format!("{err}").contains("state"), "{err}");
    }
}
