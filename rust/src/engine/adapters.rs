//! [`Matcher`](super::Matcher) adapters over every existing engine.
//!
//! Each adapter owns everything it needs (DFA, flattened tables, shared
//! lookahead analysis, vector unit), is built once per pattern by
//! [`super::CompiledMatcher`], and converts its engine's native outcome
//! into the unified [`Outcome`].

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::automata::Dfa;
use crate::baseline::backtracking::Backtracker;
use crate::baseline::greplike::GrepLike;
use crate::baseline::holub_stekr::HolubStekr;
use crate::baseline::sequential::SequentialMatcher;
use crate::cluster::{CloudMatcher, ClusterSpec};
use crate::regex::ast::Ast;
use crate::runtime::pjrt::{VariantSpec, VectorUnit};
use crate::runtime::simd::SimdMatcher;
use crate::speculative::lookahead::Lookahead;
use crate::speculative::matcher::MatchPlan;
use crate::speculative::merge::MergeStrategy;

use super::outcome::{Detail, EngineKind, Outcome};
use super::shard::ShardPlan;
use super::Matcher;

/// Representative byte per dense symbol class, so engines that consume
/// raw bytes (backtracking, grep-like) can serve `run_syms` requests.
/// Sound because two bytes in one IBase class are members of exactly the
/// same pattern character classes (automata::dfa::byte_classes).
fn class_representatives(dfa: &Dfa) -> Vec<u8> {
    let mut reps = vec![b'?'; dfa.num_symbols as usize];
    for b in (0..=255u8).rev() {
        reps[dfa.class_of(b) as usize] = b;
    }
    reps
}

fn syms_to_bytes(reps: &[u8], syms: &[u32]) -> Vec<u8> {
    syms.iter().map(|&s| reps[s as usize]).collect()
}

// ---------------------------------------------------------------- seq --

/// Listing-1 scalar loop behind the [`Matcher`] shape.
pub struct SequentialAdapter {
    m: SequentialMatcher,
}

impl SequentialAdapter {
    /// Build from a compiled DFA.
    pub fn new(dfa: &Dfa) -> SequentialAdapter {
        SequentialAdapter { m: SequentialMatcher::new(dfa) }
    }

    /// The flattened transition table, shared with the streaming
    /// wrapper ([`super::stream::StreamMatcher`]) so segment folds
    /// reuse the table this adapter already built.
    pub(crate) fn flat(&self) -> &crate::automata::FlatDfa {
        self.m.flat()
    }
}

impl Matcher for SequentialAdapter {
    fn describe(&self) -> String {
        "sequential: Listing-1 scalar loop over the flattened SBase table"
            .to_string()
    }

    fn run_syms(&self, syms: &[u32]) -> Result<Outcome> {
        let t0 = Instant::now();
        let out = self.m.run_syms(syms);
        Ok(Outcome {
            engine: EngineKind::Sequential,
            n: syms.len(),
            accepted: out.accepted,
            final_state: Some(out.final_state),
            makespan: syms.len(),
            overhead_syms: 0,
            per_worker_syms: vec![syms.len()],
            wall_s: t0.elapsed().as_secs_f64(),
            selection: None,
            detail: Detail::Sequential(out),
        })
    }

    fn run_bytes(&self, bytes: &[u8]) -> Result<Outcome> {
        let t0 = Instant::now();
        let out = self.m.run_bytes(bytes);
        Ok(Outcome {
            engine: EngineKind::Sequential,
            n: bytes.len(),
            accepted: out.accepted,
            final_state: Some(out.final_state),
            makespan: bytes.len(),
            overhead_syms: 0,
            per_worker_syms: vec![bytes.len()],
            wall_s: t0.elapsed().as_secs_f64(),
            selection: None,
            detail: Detail::Sequential(out),
        })
    }
}

// --------------------------------------------------------------- spec --

/// The paper's multicore speculative matcher (Algorithms 2/3).
pub struct SpeculativeAdapter {
    plan: MatchPlan,
}

impl SpeculativeAdapter {
    /// Build a plan sharing the facade's lookahead analysis; `weights`
    /// are Eq. (1) per-worker weights (len must equal `processors`),
    /// `collapse_every` the convergence-collapse interval (0 = off).
    pub fn new(
        dfa: &Dfa,
        processors: usize,
        lookahead: Option<&Lookahead>,
        weights: Option<Vec<f64>>,
        merge: Option<MergeStrategy>,
        adaptive: bool,
        collapse_every: usize,
    ) -> Result<SpeculativeAdapter> {
        let mut plan = MatchPlan::new(dfa)
            .processors(processors)
            .adaptive_partition(adaptive)
            .collapse_every(collapse_every);
        if let Some(la) = lookahead {
            plan = plan.with_lookahead(la.clone());
        }
        if let Some(w) = weights {
            anyhow::ensure!(
                w.len() == processors,
                "weights len {} != processors {processors}",
                w.len()
            );
            plan = plan.weights(w);
        }
        if let Some(m) = merge {
            plan = plan.merge_strategy(m);
        }
        Ok(SpeculativeAdapter { plan })
    }

    fn convert(&self, n: usize, t0: Instant, out: crate::speculative::matcher::MatchOutcome) -> Outcome {
        Outcome {
            engine: EngineKind::Speculative,
            n,
            accepted: out.accepted,
            final_state: Some(out.final_state),
            makespan: out.makespan_syms(),
            overhead_syms: out.speculative_overhead_syms(n),
            per_worker_syms: out.work.iter().map(|w| w.syms_matched).collect(),
            wall_s: t0.elapsed().as_secs_f64(),
            selection: None,
            detail: Detail::Speculative(out),
        }
    }
}

impl Matcher for SpeculativeAdapter {
    fn describe(&self) -> String {
        format!(
            "speculative multicore: Algorithm 3, m={}, gamma={:.3}",
            self.plan.i_max(),
            self.plan.gamma()
        )
    }

    fn run_syms(&self, syms: &[u32]) -> Result<Outcome> {
        let t0 = Instant::now();
        let out = self.plan.run_syms(syms);
        Ok(self.convert(syms.len(), t0, out))
    }

    fn run_bytes(&self, bytes: &[u8]) -> Result<Outcome> {
        let t0 = Instant::now();
        let out = self.plan.run(bytes);
        Ok(self.convert(bytes.len(), t0, out))
    }
}

// --------------------------------------------------------------- simd --

/// Lane-parallel vector-unit matcher (Listing 2).
pub struct SimdAdapter {
    m: SimdMatcher,
}

impl SimdAdapter {
    /// `variant = None` builds an artifact-free emulated vector unit
    /// sized to this DFA; `Some(name)` loads the named AOT artifact.
    pub fn new(
        dfa: &Dfa,
        variant: Option<&str>,
        lookahead: Option<&Lookahead>,
    ) -> Result<SimdAdapter> {
        let vu = match variant {
            Some(name) => VectorUnit::load(VectorUnit::default_dir(), name)?,
            None => VectorUnit::emulated(
                "engine_emulated",
                VariantSpec::sized_to(
                    dfa.num_states as usize,
                    dfa.num_symbols as usize,
                ),
            ),
        };
        let m = SimdMatcher::new(dfa, &Arc::new(vu))?
            .with_lookahead(lookahead.cloned());
        Ok(SimdAdapter { m })
    }

    fn convert(&self, n: usize, t0: Instant, out: crate::runtime::simd::SimdOutcome) -> Outcome {
        Outcome {
            engine: EngineKind::Simd,
            n,
            accepted: out.accepted,
            final_state: Some(out.final_state),
            // lockstep lanes: the busiest "worker" is the full vector
            // pipeline, vector_steps deep
            makespan: out.vector_steps as usize,
            overhead_syms: (out.vector_steps as usize).saturating_sub(n),
            per_worker_syms: Vec::new(),
            wall_s: t0.elapsed().as_secs_f64(),
            selection: None,
            detail: Detail::Simd(out),
        }
    }
}

impl Matcher for SimdAdapter {
    fn describe(&self) -> String {
        format!(
            "vector unit: Listing-2 lane-parallel matching, I_max={}",
            self.m.i_max()
        )
    }

    fn run_syms(&self, syms: &[u32]) -> Result<Outcome> {
        let t0 = Instant::now();
        let out = self.m.run_syms(syms)?;
        Ok(self.convert(syms.len(), t0, out))
    }

    fn run_bytes(&self, bytes: &[u8]) -> Result<Outcome> {
        self.run_syms(&self.m.dfa().map_input(bytes))
    }
}

// -------------------------------------------------------------- cloud --

/// Simulated-EC2 cluster matcher (§5.2).
pub struct CloudAdapter {
    m: CloudMatcher,
}

impl CloudAdapter {
    /// A homogeneous `nodes`-node cluster sharing the facade's analysis.
    pub fn new(
        dfa: &Dfa,
        nodes: usize,
        lookahead: Option<&Lookahead>,
        merge: Option<MergeStrategy>,
        adaptive: bool,
    ) -> Result<CloudAdapter> {
        anyhow::ensure!(nodes >= 1, "cloud engine needs >= 1 node");
        let mut m = CloudMatcher::new(dfa, ClusterSpec::homogeneous(nodes))
            .adaptive_partition(adaptive);
        if let Some(la) = lookahead {
            m = m.with_lookahead(la.clone());
        }
        if let Some(s) = merge {
            m = m.merge_strategy(s);
        }
        Ok(CloudAdapter { m })
    }
}

impl Matcher for CloudAdapter {
    fn describe(&self) -> String {
        "cloud: weighted partitioning + 2-tier merge on the simulated EC2 \
         cluster"
            .to_string()
    }

    fn run_syms(&self, syms: &[u32]) -> Result<Outcome> {
        let t0 = Instant::now();
        let out = self.m.run_syms(syms);
        let n = syms.len();
        Ok(Outcome {
            engine: EngineKind::Cloud,
            n,
            accepted: out.accepted,
            final_state: Some(out.final_state),
            makespan: out.per_worker_syms.iter().copied().max().unwrap_or(0),
            overhead_syms: out
                .per_worker_syms
                .iter()
                .sum::<usize>()
                .saturating_sub(n),
            per_worker_syms: out.per_worker_syms.clone(),
            wall_s: t0.elapsed().as_secs_f64(),
            selection: None,
            detail: Detail::Cloud(out),
        })
    }

    fn run_bytes(&self, bytes: &[u8]) -> Result<Outcome> {
        self.run_syms(&self.m.dfa().map_input(bytes))
    }
}

// -------------------------------------------------------------- shard --

/// Hierarchical two-level shard matcher ([`ShardPlan`]).
pub struct ShardAdapter {
    plan: ShardPlan,
    nodes: usize,
    workers_per_node: usize,
}

impl ShardAdapter {
    /// `nodes` simulated cluster nodes × `workers_per_node` cores each.
    /// `weights` is the per-worker capacity vector measured by
    /// [`crate::speculative::profile::profile_workers`] (len =
    /// `workers_per_node`); `None` assumes homogeneous workers.
    /// `collapse_every` is the convergence-collapse interval (0 = off).
    pub fn new(
        dfa: &Dfa,
        nodes: usize,
        workers_per_node: usize,
        lookahead: Option<&Lookahead>,
        weights: Option<&[f64]>,
        collapse_every: usize,
    ) -> Result<ShardAdapter> {
        anyhow::ensure!(nodes >= 1, "shard engine needs >= 1 node");
        anyhow::ensure!(
            workers_per_node >= 1,
            "shard engine needs >= 1 worker per node"
        );
        let per_node: Vec<f64> = match weights {
            Some(w) => {
                anyhow::ensure!(
                    w.len() == workers_per_node,
                    "capacity vector len {} != workers per node \
                     {workers_per_node}",
                    w.len()
                );
                w.to_vec()
            }
            None => vec![1.0; workers_per_node],
        };
        let mut plan = ShardPlan::new(dfa)
            .node_capacities(vec![per_node; nodes])
            .collapse_every(collapse_every);
        if let Some(la) = lookahead {
            plan = plan.with_lookahead(la.clone());
        }
        Ok(ShardAdapter { plan, nodes, workers_per_node })
    }

    fn convert(
        &self,
        n: usize,
        t0: Instant,
        out: crate::engine::shard::ShardOutcome,
    ) -> Outcome {
        Outcome {
            engine: EngineKind::Shard,
            n,
            accepted: out.accepted,
            final_state: Some(out.final_state),
            makespan: out.makespan_syms(),
            overhead_syms: out.speculative_overhead_syms(n),
            per_worker_syms: out.work.iter().map(|w| w.syms_matched).collect(),
            wall_s: t0.elapsed().as_secs_f64(),
            selection: None,
            detail: Detail::Shard(out),
        }
    }
}

impl Matcher for ShardAdapter {
    fn describe(&self) -> String {
        format!(
            "hierarchical shard: {} node(s) x {} worker(s), two-level \
             Eq. (1) partition, m={}",
            self.nodes,
            self.workers_per_node,
            self.plan.i_max()
        )
    }

    fn run_syms(&self, syms: &[u32]) -> Result<Outcome> {
        let t0 = Instant::now();
        let out = self.plan.run_syms(syms);
        Ok(self.convert(syms.len(), t0, out))
    }

    fn run_bytes(&self, bytes: &[u8]) -> Result<Outcome> {
        let t0 = Instant::now();
        let out = self.plan.run(bytes);
        Ok(self.convert(bytes.len(), t0, out))
    }
}

// -------------------------------------------------------------- holub --

/// Holub–Štekr prior-work comparator.
pub struct HolubStekrAdapter {
    m: HolubStekr,
}

impl HolubStekrAdapter {
    /// Uniform chunks across `processors` workers, all |Q| states each.
    pub fn new(dfa: &Dfa, processors: usize) -> HolubStekrAdapter {
        HolubStekrAdapter { m: HolubStekr::new(dfa, processors) }
    }
}

impl Matcher for HolubStekrAdapter {
    fn describe(&self) -> String {
        "Holub-Stekr: uniform chunks, all |Q| states per chunk (prior work \
         comparator)"
            .to_string()
    }

    fn run_syms(&self, syms: &[u32]) -> Result<Outcome> {
        let t0 = Instant::now();
        let out = self.m.run_syms(syms);
        let n = syms.len();
        Ok(Outcome {
            engine: EngineKind::HolubStekr,
            n,
            accepted: out.accepted,
            final_state: Some(out.final_state),
            makespan: out.makespan_syms(),
            overhead_syms: out.work.iter().sum::<usize>().saturating_sub(n),
            per_worker_syms: out.work.clone(),
            wall_s: t0.elapsed().as_secs_f64(),
            selection: None,
            detail: Detail::HolubStekr(out),
        })
    }

    fn run_bytes(&self, bytes: &[u8]) -> Result<Outcome> {
        self.run_syms(&self.m.dfa().map_input(bytes))
    }
}

// ---------------------------------------------------------- backtrack --

/// Perl-style backtracking engine (ScanProsite stand-in).
pub struct BacktrackingAdapter {
    ast: Ast,
    fuel: u64,
    reps: Vec<u8>,
}

impl BacktrackingAdapter {
    /// Build over the pattern AST with a step-fuel bound.
    pub fn new(dfa: &Dfa, ast: &Ast, fuel: u64) -> BacktrackingAdapter {
        BacktrackingAdapter {
            ast: ast.clone(),
            fuel,
            reps: class_representatives(dfa),
        }
    }
}

impl Matcher for BacktrackingAdapter {
    fn describe(&self) -> String {
        "backtracking: Perl-style recursive engine (ScanProsite stand-in), \
         unanchored search"
            .to_string()
    }

    fn run_syms(&self, syms: &[u32]) -> Result<Outcome> {
        self.run_bytes(&syms_to_bytes(&self.reps, syms))
    }

    fn run_bytes(&self, bytes: &[u8]) -> Result<Outcome> {
        let t0 = Instant::now();
        let bt = Backtracker::with_fuel(&self.ast, self.fuel);
        let stats = bt.search(bytes).ok_or_else(|| {
            anyhow!("backtracking engine ran out of fuel ({})", self.fuel)
        })?;
        Ok(Outcome {
            engine: EngineKind::Backtracking,
            n: bytes.len(),
            accepted: stats.matched,
            final_state: None,
            makespan: stats.steps as usize,
            overhead_syms: 0,
            per_worker_syms: Vec::new(),
            wall_s: t0.elapsed().as_secs_f64(),
            selection: None,
            detail: Detail::Backtracking(stats),
        })
    }
}

// --------------------------------------------------------------- grep --

/// grep-style literal-prefilter engine.
pub struct GrepLikeAdapter {
    ast: Ast,
    reps: Vec<u8>,
}

impl GrepLikeAdapter {
    /// Build over the pattern AST.
    pub fn new(dfa: &Dfa, ast: &Ast) -> GrepLikeAdapter {
        GrepLikeAdapter { ast: ast.clone(), reps: class_representatives(dfa) }
    }
}

impl Matcher for GrepLikeAdapter {
    fn describe(&self) -> String {
        "grep-like: Boyer-Moore literal prefilter + bounded verification"
            .to_string()
    }

    fn run_syms(&self, syms: &[u32]) -> Result<Outcome> {
        self.run_bytes(&syms_to_bytes(&self.reps, syms))
    }

    fn run_bytes(&self, bytes: &[u8]) -> Result<Outcome> {
        let t0 = Instant::now();
        let engine = GrepLike::new(&self.ast);
        let stats = engine.search(bytes);
        Ok(Outcome {
            engine: EngineKind::GrepLike,
            n: bytes.len(),
            accepted: stats.matched,
            final_state: None,
            makespan: stats.work as usize,
            overhead_syms: 0,
            per_worker_syms: Vec::new(),
            wall_s: t0.elapsed().as_secs_f64(),
            selection: None,
            detail: Detail::GrepLike(stats),
        })
    }
}
