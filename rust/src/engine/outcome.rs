//! The unified result type every engine adapter returns.
//!
//! Before this module, each substrate reported its own struct
//! (`SeqOutcome`, `MatchOutcome`, `SimdOutcome`, `CloudOutcome`,
//! `HolubStekrOutcome`, `BacktrackStats`, `GrepStats`) with four
//! incompatible field sets.  [`Outcome`] carries the telemetry they all
//! share — membership verdict, work model, wall time — while the
//! [`Detail`] enum keeps every engine-specific record intact for callers
//! that need substrate depth (experiment regenerators, benches).

use std::fmt;

use crate::baseline::backtracking::BacktrackStats;
use crate::baseline::greplike::GrepStats;
use crate::baseline::holub_stekr::HolubStekrOutcome;
use crate::baseline::sequential::SeqOutcome;
use crate::cluster::{CloudOutcome, ProcOutcome};
use crate::runtime::simd::SimdOutcome;
use crate::speculative::matcher::MatchOutcome;

use super::select::Selection;
use super::shard::ShardOutcome;
use super::stream::StreamStats;

/// Which substrate executed a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum EngineKind {
    /// Listing-1 scalar loop (Algorithm 1).
    Sequential,
    /// The paper's speculative multicore matcher (Algorithms 2/3).
    Speculative,
    /// Lane-parallel vector unit (Listing 2 / §5.1).
    Simd,
    /// Simulated-EC2 distributed matcher (§5.2).
    Cloud,
    /// Hierarchical cross-substrate sharding: cloud nodes × per-node
    /// multicore, both levels Eq. (1)-weighted
    /// ([`crate::engine::shard`]).
    Shard,
    /// Holub–Štekr prior-work comparator.
    HolubStekr,
    /// Perl-style backtracking (ScanProsite stand-in).
    Backtracking,
    /// grep-style literal-prefilter engine.
    GrepLike,
    /// Segment-streamed, checkpoint-resumable matching
    /// ([`crate::engine::stream::StreamMatcher`]).
    Stream,
    /// Real multi-process cluster over the framed socket protocol
    /// ([`crate::cluster::proc::ProcCluster`]).
    Cluster,
}

impl EngineKind {
    /// Stable short name (CLI `--engine` vocabulary).
    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Sequential => "seq",
            EngineKind::Speculative => "spec",
            EngineKind::Simd => "simd",
            EngineKind::Cloud => "cloud",
            EngineKind::Shard => "shard",
            EngineKind::HolubStekr => "holub",
            EngineKind::Backtracking => "backtrack",
            EngineKind::GrepLike => "grep",
            EngineKind::Stream => "stream",
            EngineKind::Cluster => "cluster",
        }
    }
}

impl fmt::Display for EngineKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Engine-specific result record, preserved verbatim.
#[derive(Clone, Debug)]
#[allow(missing_docs)] // variant payloads are the engines' native records
pub enum Detail {
    Sequential(SeqOutcome),
    Speculative(MatchOutcome),
    Simd(SimdOutcome),
    Cloud(CloudOutcome),
    Shard(ShardOutcome),
    HolubStekr(HolubStekrOutcome),
    Backtracking(BacktrackStats),
    GrepLike(GrepStats),
    Stream(StreamStats),
    Cluster(ProcOutcome),
}

/// Unified outcome of one membership test, whichever engine ran it.
#[derive(Clone, Debug)]
pub struct Outcome {
    /// Engine that actually executed (for `Engine::Auto`, the selected
    /// substrate — see [`Outcome::selection`]).
    pub engine: EngineKind,
    /// Input length in symbols.
    pub n: usize,
    /// Membership verdict: final state ∈ F.
    pub accepted: bool,
    /// `delta*(q0, input)`; `None` for the AST engines (backtracking,
    /// grep-like), which decide membership without running the DFA.
    pub final_state: Option<u32>,
    /// Parallel makespan in work units — symbols stepped by the busiest
    /// worker for the DFA engines (`n` exactly for sequential), engine
    /// work units (match steps / inspected bytes) for the AST engines.
    pub makespan: usize,
    /// Redundant work introduced by speculation, in symbols (0 for the
    /// non-speculative engines).
    pub overhead_syms: usize,
    /// Per-worker symbols of real matching work, where the engine tracks
    /// it (speculative, cloud, Holub–Štekr; single entry for sequential;
    /// empty for the lockstep-lane and AST engines — see `detail`).
    pub per_worker_syms: Vec<usize>,
    /// Measured wall time of this run, seconds.
    pub wall_s: f64,
    /// For `Engine::Auto` runs: why this engine was selected.
    pub selection: Option<Selection>,
    /// The engine's native result record.
    pub detail: Detail,
}

impl Outcome {
    /// Work-model speedup over the sequential yardstick:
    /// `n / makespan` (1.0 for sequential by construction).
    pub fn model_speedup(&self) -> f64 {
        self.n as f64 / self.makespan.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_names_are_cli_vocabulary() {
        let all = [
            EngineKind::Sequential,
            EngineKind::Speculative,
            EngineKind::Simd,
            EngineKind::Cloud,
            EngineKind::Shard,
            EngineKind::HolubStekr,
            EngineKind::Backtracking,
            EngineKind::GrepLike,
            EngineKind::Stream,
            EngineKind::Cluster,
        ];
        let names: Vec<&str> = all.iter().map(|k| k.name()).collect();
        assert_eq!(
            names,
            ["seq", "spec", "simd", "cloud", "shard", "holub", "backtrack",
             "grep", "stream", "cluster"]
        );
        // names are distinct and Display matches name()
        for k in all {
            assert_eq!(format!("{k}"), k.name());
        }
    }
}
