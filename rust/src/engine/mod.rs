//! The unified engine API: **one facade over every matching substrate**.
//!
//! The paper's contribution is a single algorithm deployed across three
//! substrates (multicore, SIMD, cloud); this module gives the repo the
//! matching shape — one request path that picks the right substrate per
//! request instead of four bespoke APIs:
//!
//! ```text
//!   Pattern ──compile──▶ CompiledMatcher ──run/match_many──▶ Outcome
//!                            │
//!              Engine::Auto ─┤ γ = I_max,r/|Q|, |Q|, n  (select.rs)
//!                            ├─▶ SequentialAdapter   (Listing 1)
//!                            ├─▶ SpeculativeAdapter  (Algorithms 2/3)
//!                            ├─▶ SimdAdapter         (Listing 2 lanes)
//!                            ├─▶ CloudAdapter        (simulated EC2)
//!                            ├─▶ ShardAdapter        (nodes × cores,
//!                            │                        two-level Eq. 1)
//!                            └─▶ Holub-Stekr / backtracking / grep-like
//! ```
//!
//! * [`Matcher`] — the object-safe trait every adapter implements
//!   (`run_bytes` / `run_syms` / `describe`).
//! * [`Outcome`] — unified telemetry with an engine-specific
//!   [`Detail`](outcome::Detail) payload.
//! * [`Engine`] + [`ExecPolicy`] — which substrate, and the shared
//!   execution knobs (processors, lookahead depth, weights, merge).
//! * [`CompiledMatcher`] — pattern compiled once (DFA + lookahead
//!   analysis + adapters), served many times; [`CompiledMatcher::match_many`]
//!   amortizes plan construction across a batch of requests, with a
//!   per-request error slot ([`batch::RequestError`]) so one failed
//!   request never drops the rest of the batch.
//! * [`select`] — the `Engine::Auto` dispatch rule over (γ, |Q|, n),
//!   with thresholds calibrated from measured host capacity
//!   ([`AutoThresholds::from_profile`]).
//! * [`serve`] — the asynchronous serving loop: worker threads, a
//!   coalescing request queue, an LRU compiled-pattern cache, and live
//!   capacity re-calibration ([`serve::Server`]).
//! * [`patternset`] — multi-pattern matching: a [`PatternSet`] compiles
//!   to a literal prefilter + fused product DFA + spill tiers
//!   ([`CompiledSetMatcher`]) so one input pass answers k membership
//!   queries; the serve loop coalesces different-pattern requests over
//!   one input into a single fused pass.
//! * [`stream`] — segment-streamed, checkpoint-resumable matching
//!   ([`StreamMatcher`]): feed the input in pieces with constant
//!   memory, snapshot a [`Checkpoint`] mid-scan, resume it on any
//!   worker (the serve loop's scan preemption), or serialize it for
//!   migration ([`Checkpoint::to_bytes`]).

pub mod adapters;
pub mod batch;
pub mod outcome;
pub mod patternset;
pub mod select;
pub mod serve;
pub mod shard;
pub mod stream;

use anyhow::{bail, Result};

use crate::automata::Dfa;
use crate::regex::ast::Ast;
use crate::regex::{compile, parser, prosite};
use crate::speculative::lookahead::Lookahead;
use crate::speculative::merge::MergeStrategy;

pub use batch::{BatchOutcome, RequestError};
pub use outcome::{Detail, EngineKind, Outcome};
pub use patternset::{
    CompiledSetMatcher, PatternSet, SetConfig, SetOutcome, SetTier,
};
pub use select::{select, AutoThresholds, DfaProps, Selection};
pub use serve::{
    Admission, HazardPolicy, PriorityPolicy, ServeConfig, ServeError,
    ServeStats, Server, ServerHandle, Ticket, WaitStats,
};
pub use shard::{ShardLayout, ShardOutcome, ShardPlan, ShardWork};
pub use stream::{Checkpoint, FeedProgress, StreamMatcher, StreamStats};

use adapters::{
    BacktrackingAdapter, CloudAdapter, GrepLikeAdapter, HolubStekrAdapter,
    SequentialAdapter, ShardAdapter, SimdAdapter, SpeculativeAdapter,
};

/// An engine adapter: one substrate behind the unified request shape.
pub trait Matcher {
    /// Human-readable description of the engine and its configuration.
    fn describe(&self) -> String;
    /// Membership test over pre-mapped dense symbols (IBase form).
    fn run_syms(&self, syms: &[u32]) -> Result<Outcome>;
    /// Membership test over raw bytes.
    fn run_bytes(&self, bytes: &[u8]) -> Result<Outcome>;
}

/// Which substrate to run, with engine-specific knobs inline.
///
/// [`Engine::Auto`] routes per request; every explicit variant pins one
/// substrate.  All variants produce identical membership verdicts
/// (failure-freedom):
///
/// ```
/// use specdfa::engine::{CompiledMatcher, Engine, ExecPolicy, Matcher, Pattern};
///
/// let pattern = Pattern::Regex("(ab|cd)+e".to_string());
/// let policy = ExecPolicy { processors: 3, ..ExecPolicy::default() };
/// let mut verdicts = Vec::new();
/// for engine in [
///     Engine::Sequential,
///     Engine::speculative(),
///     Engine::Shard { nodes: 2 },
/// ] {
///     let cm = CompiledMatcher::compile(&pattern, engine, policy.clone())?;
///     verdicts.push(cm.run_bytes(b"xxabcdezz")?.accepted);
/// }
/// assert_eq!(verdicts, vec![true, true, true]);
/// # anyhow::Result::<()>::Ok(())
/// ```
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Engine {
    /// Pick per request from DFA structure + input size ([`select`]).
    Auto,
    /// Listing-1 scalar loop.
    Sequential,
    /// The paper's multicore speculative matcher.
    Speculative {
        /// fixed-point adaptive partition (repo extension) instead of the
        /// worst-case I_max sizing
        adaptive: bool,
    },
    /// Lane-parallel vector unit.  `variant` names an AOT artifact from
    /// the manifest; `None` uses the emulated unit sized to the DFA.
    Simd { variant: Option<String> },
    /// Simulated-EC2 cluster with this many nodes.
    Cloud { nodes: usize },
    /// Hierarchical sharding: this many cluster nodes, each re-split
    /// across `ExecPolicy::processors` workers — both levels Eq. (1)
    /// capacity-weighted ([`shard::ShardPlan`]).
    Shard { nodes: usize },
    /// Prior-work comparator (uniform chunks × all |Q| states).
    HolubStekr,
    /// Perl-style backtracking (needs the pattern AST; search semantics).
    Backtracking,
    /// grep-style prefilter engine (needs the pattern AST; search
    /// semantics).
    GrepLike,
}

impl Engine {
    /// Default-configured speculative engine.
    pub fn speculative() -> Engine {
        Engine::Speculative { adaptive: false }
    }

    /// Default-configured (emulated) SIMD engine.
    pub fn simd() -> Engine {
        Engine::Simd { variant: None }
    }

    /// Default-configured cloud engine.
    pub fn cloud() -> Engine {
        Engine::Cloud { nodes: DEFAULT_CLOUD_NODES }
    }

    /// Default-configured hierarchical shard engine.
    pub fn shard() -> Engine {
        Engine::Shard { nodes: DEFAULT_CLOUD_NODES }
    }

    /// Parse a CLI engine name:
    /// auto|seq|spec|simd|cloud|shard|holub|backtrack|grep.
    pub fn parse(name: &str) -> Result<Engine> {
        Ok(match name {
            "auto" => Engine::Auto,
            "seq" | "sequential" => Engine::Sequential,
            "spec" | "speculative" => Engine::speculative(),
            "simd" => Engine::simd(),
            "cloud" => Engine::cloud(),
            "shard" => Engine::shard(),
            "holub" => Engine::HolubStekr,
            "backtrack" | "backtracking" => Engine::Backtracking,
            "grep" => Engine::GrepLike,
            other => bail!(
                "unknown engine {other:?} (expected \
                 auto|seq|spec|simd|cloud|shard|holub|backtrack|grep)"
            ),
        })
    }
}

/// Default cluster size for the cloud adapter (`ExecPolicy::cloud_nodes`
/// and `Engine::cloud()`).
pub const DEFAULT_CLOUD_NODES: usize = 4;

/// Shared execution knobs, applied to whichever engines get built.
#[derive(Clone, Debug)]
pub struct ExecPolicy {
    /// |P| for the multicore engines (speculative, Holub–Štekr).
    pub processors: usize,
    /// Reverse lookahead depth r (Algorithm 3); 0 = basic Algorithm 2.
    /// `Engine::Auto` clamps this to ≥ 1 so the dispatch decision (which
    /// uses the r-analysis) matches what the adapters actually execute.
    pub lookahead: usize,
    /// Cluster size for the cloud adapter `Engine::Auto` builds;
    /// `Engine::Cloud { nodes }` overrides this when chosen explicitly.
    pub cloud_nodes: usize,
    /// Per-processor weights (Eq. 1); `None` = uniform.  Must match
    /// `processors` in length when set.
    pub weights: Option<Vec<f64>>,
    /// Merge strategy override; `None` keeps each engine's paper-correct
    /// default (sequential Eq. 8 on shared memory, hierarchical Fig. 9 on
    /// the cluster).
    pub merge: Option<MergeStrategy>,
    /// Fuel bound for the backtracking engine.  Clamped to the
    /// engine's hard step cap
    /// ([`crate::baseline::backtracking::MAX_FUEL`]), so no policy can
    /// configure an effectively unbounded ReDoS-vulnerable run.
    pub backtrack_fuel: u64,
    /// Convergence-collapse check interval for the speculative chunk
    /// kernels, in symbols: chains that have converged are merged and
    /// drop out of the inner loop (outcome unchanged, work reduced).
    /// 0 disables collapsing.
    pub collapse_every: usize,
    /// `Engine::Auto` dispatch thresholds.
    pub thresholds: AutoThresholds,
}

/// Default [`ExecPolicy::collapse_every`]: frequent enough that a
/// high-γ DFA's chains die within a few blocks, rare enough that the
/// dedupe scan is noise next to the matching loop.
pub const DEFAULT_COLLAPSE_EVERY: usize = 256;

impl Default for ExecPolicy {
    fn default() -> ExecPolicy {
        ExecPolicy {
            processors: 8,
            lookahead: 4,
            cloud_nodes: DEFAULT_CLOUD_NODES,
            weights: None,
            merge: None,
            backtrack_fuel: 1 << 34,
            collapse_every: DEFAULT_COLLAPSE_EVERY,
            thresholds: AutoThresholds::default(),
        }
    }
}

/// A pattern in one of the supported frontends.
///
/// ```
/// use specdfa::engine::{CompiledMatcher, Engine, ExecPolicy, Matcher, Pattern};
///
/// // search semantics: "the input contains a match"
/// let re = CompiledMatcher::compile(
///     &Pattern::Regex("ab+c".to_string()),
///     Engine::Sequential,
///     ExecPolicy::default(),
/// )?;
/// assert!(re.run_bytes(b"xx abbbc yy")?.accepted);
///
/// // PROSITE protein signatures compile through the same facade
/// let sig = CompiledMatcher::compile(
///     &Pattern::Prosite("C-x(2)-C.".to_string()),
///     Engine::Sequential,
///     ExecPolicy::default(),
/// )?;
/// assert!(sig.run_bytes(b"AACKLCAA")?.accepted);
/// # anyhow::Result::<()>::Ok(())
/// ```
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum Pattern {
    /// PCRE-style regex, search ("input contains a match") semantics.
    Regex(String),
    /// PCRE-style regex, whole-input semantics.
    RegexExact(String),
    /// PROSITE protein signature (ScanProsite semantics).
    Prosite(String),
    /// A DFA in Grail+ text format (no AST: the backtracking and
    /// grep-like engines are unavailable).
    Grail(String),
}

pub(crate) struct CompiledPattern {
    pub(crate) dfa: Dfa,
    /// raw pattern AST for the AST engines; only present when unanchored
    /// search semantics make their scan loops equivalent to the DFA
    pub(crate) ast: Option<Ast>,
}

impl Pattern {
    pub(crate) fn compile(&self) -> Result<CompiledPattern> {
        Ok(match self {
            Pattern::Regex(p) => {
                let parsed = parser::parse(p)?;
                let ast = if parsed.anchored_start || parsed.anchored_end {
                    None // the AST engines' scan loop ignores anchors
                } else {
                    Some(parsed.ast)
                };
                CompiledPattern { dfa: compile::compile_search(p)?, ast }
            }
            Pattern::RegexExact(p) => CompiledPattern {
                dfa: compile::compile_exact(p)?,
                ast: None, // exact semantics: search engines don't apply
            },
            Pattern::Prosite(p) => {
                let parsed = prosite::parse(p)?;
                let ast = if parsed.anchored_start || parsed.anchored_end {
                    None
                } else {
                    Some(parsed.ast)
                };
                CompiledPattern { dfa: compile::compile_prosite(p)?, ast }
            }
            Pattern::Grail(text) => CompiledPattern {
                dfa: crate::automata::grail::from_grail(text)?,
                ast: None,
            },
        })
    }
}

/// One pattern compiled for serving: minimal DFA, shared structural
/// analysis, and every adapter the chosen [`Engine`] needs — built once,
/// reused for every request and across [`CompiledMatcher::match_many`]
/// batches.
pub struct CompiledMatcher {
    dfa: Dfa,
    engine: Engine,
    policy: ExecPolicy,
    props: DfaProps,
    seq: SequentialAdapter,
    spec: Option<SpeculativeAdapter>,
    simd: Option<SimdAdapter>,
    cloud: Option<CloudAdapter>,
    shard: Option<ShardAdapter>,
    holub: Option<HolubStekrAdapter>,
    backtrack: Option<BacktrackingAdapter>,
    grep: Option<GrepLikeAdapter>,
}

impl CompiledMatcher {
    /// Compile a pattern for the given engine under the given policy.
    pub fn compile(
        pattern: &Pattern,
        engine: Engine,
        policy: ExecPolicy,
    ) -> Result<CompiledMatcher> {
        let parts = pattern.compile()?;
        Self::from_parts(parts.dfa, parts.ast, engine, policy)
    }

    /// Build directly from a DFA (no AST: the backtracking and grep-like
    /// engines are unavailable).
    pub fn from_dfa(
        dfa: Dfa,
        engine: Engine,
        policy: ExecPolicy,
    ) -> Result<CompiledMatcher> {
        Self::from_parts(dfa, None, engine, policy)
    }

    fn from_parts(
        dfa: Dfa,
        ast: Option<Ast>,
        engine: Engine,
        policy: ExecPolicy,
    ) -> Result<CompiledMatcher> {
        let auto = engine == Engine::Auto;
        // one structural analysis shared by every adapter and by Auto.
        // Auto clamps r to >= 1: the dispatch rules reason about the
        // r-lookahead structure, so the adapters must run with it too.
        let r = if auto { policy.lookahead.max(1) } else { policy.lookahead };
        let la = if r > 0 {
            Some(Lookahead::analyze(&dfa, r))
        } else {
            None
        };
        let props = match &la {
            Some(la) => DfaProps::from_lookahead(&dfa, la),
            None => DfaProps::analyze(&dfa, 1),
        };
        // Static feasibility verdict (analysis::dfa): a speculation-
        // hostile DFA (gamma past the threshold) makes Auto's rule 2
        // route every request sequential, and rule 2 fires before any
        // rule that could pick a parallel substrate — so skip building
        // the parallel adapters entirely instead of paying their plan
        // construction for adapters that can never serve.
        let hostile = auto
            && crate::analysis::dfa::speculation_hostile(
                &props,
                &policy.thresholds,
            );
        let mut cm = CompiledMatcher {
            seq: SequentialAdapter::new(&dfa),
            spec: None,
            simd: None,
            cloud: None,
            shard: None,
            holub: None,
            backtrack: None,
            grep: None,
            props,
            engine,
            policy,
            dfa,
        };

        if (auto && !hostile) || matches!(cm.engine, Engine::Speculative { .. }) {
            let adaptive =
                matches!(cm.engine, Engine::Speculative { adaptive: true });
            cm.spec = Some(SpeculativeAdapter::new(
                &cm.dfa,
                cm.policy.processors,
                la.as_ref(),
                cm.policy.weights.clone(),
                cm.policy.merge,
                adaptive,
                cm.policy.collapse_every,
            )?);
        }
        if (auto && !hostile) || matches!(cm.engine, Engine::Simd { .. }) {
            let variant = match &cm.engine {
                Engine::Simd { variant } => variant.as_deref(),
                _ => None,
            };
            cm.simd = Some(SimdAdapter::new(&cm.dfa, variant, la.as_ref())?);
        }
        if (auto && !hostile) || matches!(cm.engine, Engine::Cloud { .. }) {
            let nodes = match cm.engine {
                Engine::Cloud { nodes } => nodes,
                _ => cm.policy.cloud_nodes,
            };
            cm.cloud = Some(CloudAdapter::new(
                &cm.dfa,
                nodes,
                la.as_ref(),
                cm.policy.merge,
                false,
            )?);
        }
        if (auto && !hostile) || matches!(cm.engine, Engine::Shard { .. }) {
            let nodes = match cm.engine {
                Engine::Shard { nodes } => nodes,
                _ => cm.policy.cloud_nodes,
            };
            cm.shard = Some(ShardAdapter::new(
                &cm.dfa,
                nodes,
                cm.policy.processors,
                la.as_ref(),
                cm.policy.weights.as_deref(),
                cm.policy.collapse_every,
            )?);
        }
        if cm.engine == Engine::HolubStekr {
            cm.holub = Some(HolubStekrAdapter::new(
                &cm.dfa,
                cm.policy.processors,
            ));
        }
        if cm.engine == Engine::Backtracking {
            match &ast {
                Some(ast) => {
                    cm.backtrack = Some(BacktrackingAdapter::new(
                        &cm.dfa,
                        ast,
                        cm.policy.backtrack_fuel,
                    ));
                }
                None => bail!(
                    "backtracking engine needs an unanchored search \
                     pattern AST (Regex/Prosite without ^/$/</> anchors)"
                ),
            }
        }
        if cm.engine == Engine::GrepLike {
            match &ast {
                Some(ast) => {
                    cm.grep = Some(GrepLikeAdapter::new(&cm.dfa, ast));
                }
                None => bail!(
                    "grep-like engine needs an unanchored search pattern \
                     AST (Regex/Prosite without ^/$/</> anchors)"
                ),
            }
        }
        Ok(cm)
    }

    /// The compiled minimal DFA.
    pub fn dfa(&self) -> &Dfa {
        &self.dfa
    }

    /// Structural properties (γ, |Q|, I_max,r) computed at compile time.
    pub fn props(&self) -> &DfaProps {
        &self.props
    }

    /// The engine this matcher was compiled for.
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// What `Engine::Auto` would pick for an input of `n` symbols.
    pub fn selection_for(&self, n: usize) -> Selection {
        select(&self.props, n, &self.policy.thresholds)
    }

    /// The adapter serving requests of `n` symbols (resolves Auto).
    fn adapter_for(&self, n: usize) -> Result<(&dyn Matcher, Option<Selection>)> {
        let missing = |what: &str| {
            anyhow::anyhow!("{what} adapter not built for engine {:?}", self.engine)
        };
        Ok(match &self.engine {
            Engine::Auto => {
                let sel = self.selection_for(n);
                let m: &dyn Matcher = match sel.kind {
                    EngineKind::Sequential => &self.seq,
                    EngineKind::Speculative => {
                        self.spec.as_ref().ok_or_else(|| missing("spec"))?
                    }
                    EngineKind::Simd => {
                        self.simd.as_ref().ok_or_else(|| missing("simd"))?
                    }
                    EngineKind::Cloud => {
                        self.cloud.as_ref().ok_or_else(|| missing("cloud"))?
                    }
                    EngineKind::Shard => {
                        self.shard.as_ref().ok_or_else(|| missing("shard"))?
                    }
                    // Auto never picks the comparator engines
                    _ => &self.seq,
                };
                (m, Some(sel))
            }
            Engine::Sequential => (&self.seq, None),
            Engine::Speculative { .. } => {
                (self.spec.as_ref().ok_or_else(|| missing("spec"))?, None)
            }
            Engine::Simd { .. } => {
                (self.simd.as_ref().ok_or_else(|| missing("simd"))?, None)
            }
            Engine::Cloud { .. } => {
                (self.cloud.as_ref().ok_or_else(|| missing("cloud"))?, None)
            }
            Engine::Shard { .. } => {
                (self.shard.as_ref().ok_or_else(|| missing("shard"))?, None)
            }
            Engine::HolubStekr => {
                (self.holub.as_ref().ok_or_else(|| missing("holub"))?, None)
            }
            Engine::Backtracking => (
                self.backtrack.as_ref().ok_or_else(|| missing("backtrack"))?,
                None,
            ),
            Engine::GrepLike => {
                (self.grep.as_ref().ok_or_else(|| missing("grep"))?, None)
            }
        })
    }
}

impl Matcher for CompiledMatcher {
    fn describe(&self) -> String {
        let engine = match &self.engine {
            Engine::Auto => format!(
                "auto (thresholds: seq<{}, gamma<={:.2}, cloud>={}, \
                 shard>={}, simd I_max<={})",
                self.policy.thresholds.seq_max_n,
                self.policy.thresholds.gamma_max,
                self.policy.thresholds.cloud_min_n,
                self.policy.thresholds.shard_min_n,
                self.policy.thresholds.simd_max_i_max,
            ),
            other => format!("{other:?}"),
        };
        format!(
            "engine {engine} over DFA |Q|={} |Sigma|={} I_max,{}={} \
             gamma={:.3}",
            self.props.q, self.props.sigma, self.props.r, self.props.i_max,
            self.props.gamma
        )
    }

    fn run_syms(&self, syms: &[u32]) -> Result<Outcome> {
        let (m, sel) = self.adapter_for(syms.len())?;
        let mut out = m.run_syms(syms)?;
        out.selection = sel;
        Ok(out)
    }

    fn run_bytes(&self, bytes: &[u8]) -> Result<Outcome> {
        let (m, sel) = self.adapter_for(bytes.len())?;
        let mut out = m.run_bytes(bytes)?;
        out.selection = sel;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> ExecPolicy {
        ExecPolicy { processors: 4, lookahead: 2, ..ExecPolicy::default() }
    }

    #[test]
    fn explicit_engines_agree_on_membership() {
        let pattern = Pattern::Regex("(ab|cd)+e?".to_string());
        let inputs: [&[u8]; 4] =
            [b"", b"abcd", b"xxabcdezz", b"cdabcdabe"];
        let engines = [
            Engine::Sequential,
            Engine::speculative(),
            Engine::simd(),
            Engine::Cloud { nodes: 2 },
            Engine::Shard { nodes: 2 },
            Engine::HolubStekr,
            Engine::Backtracking,
            Engine::GrepLike,
        ];
        for input in inputs {
            let want = CompiledMatcher::compile(
                &pattern,
                Engine::Sequential,
                policy(),
            )
            .unwrap()
            .run_bytes(input)
            .unwrap();
            for e in &engines {
                let cm =
                    CompiledMatcher::compile(&pattern, e.clone(), policy())
                        .unwrap();
                let out = cm.run_bytes(input).unwrap();
                assert_eq!(out.accepted, want.accepted, "{e:?} {input:?}");
                if let (Some(a), Some(b)) = (out.final_state, want.final_state)
                {
                    assert_eq!(a, b, "{e:?} {input:?}");
                }
            }
        }
    }

    #[test]
    fn auto_runs_and_reports_selection() {
        let cm = CompiledMatcher::compile(
            &Pattern::Regex("needle".to_string()),
            Engine::Auto,
            ExecPolicy::default(),
        )
        .unwrap();
        let out = cm.run_bytes(b"hay needle hay").unwrap();
        assert!(out.accepted);
        assert_eq!(out.engine, EngineKind::Sequential); // tiny input
        let sel = out.selection.expect("auto must report a selection");
        assert_eq!(sel.kind, EngineKind::Sequential);
        assert_eq!(sel.n, 14);
        assert!(!sel.reason.is_empty());
    }

    #[test]
    fn auto_skips_parallel_adapters_for_hostile_dfas() {
        // gamma = 1 permutation DFA: Auto's rule 2 routes every request
        // sequential, so compile must not build the parallel adapters.
        let dfa = crate::util::workload::permutation_dfa(16, 4, 3);
        let cm = CompiledMatcher::from_dfa(
            dfa.clone(),
            Engine::Auto,
            ExecPolicy::default(),
        )
        .unwrap();
        assert!(cm.props().gamma > cm.policy.thresholds.gamma_max);
        assert!(cm.spec.is_none() && cm.simd.is_none());
        assert!(cm.cloud.is_none() && cm.shard.is_none());
        // every input size still serves, sequentially
        for n in [8usize, 1 << 17, 1 << 21] {
            let syms: Vec<u32> = (0..n).map(|i| (i % 4) as u32).collect();
            let out = cm.run_syms(&syms).unwrap();
            assert_eq!(out.engine, EngineKind::Sequential, "n={n}");
        }
        // a friendly DFA under the same policy still builds them
        let friendly = CompiledMatcher::compile(
            &Pattern::Regex("needle".to_string()),
            Engine::Auto,
            ExecPolicy::default(),
        )
        .unwrap();
        assert!(friendly.spec.is_some() && friendly.shard.is_some());
        // explicit engine choice is never second-guessed by the verdict
        let pinned = CompiledMatcher::from_dfa(
            dfa,
            Engine::speculative(),
            ExecPolicy::default(),
        )
        .unwrap();
        assert!(pinned.spec.is_some());
    }

    #[test]
    fn run_syms_equals_run_bytes_through_the_facade() {
        let pattern = Pattern::Regex("a+b".to_string());
        for e in [Engine::Sequential, Engine::speculative(), Engine::simd()] {
            let cm =
                CompiledMatcher::compile(&pattern, e, policy()).unwrap();
            let bytes = b"xxaaabyy";
            let syms = cm.dfa().map_input(bytes);
            let a = cm.run_bytes(bytes).unwrap();
            let b = cm.run_syms(&syms).unwrap();
            assert_eq!(a.accepted, b.accepted);
            assert_eq!(a.final_state, b.final_state);
        }
    }

    #[test]
    fn anchored_patterns_reject_ast_engines() {
        let pattern = Pattern::Regex("^abc$".to_string());
        for e in [Engine::Backtracking, Engine::GrepLike] {
            let err = CompiledMatcher::compile(&pattern, e, policy())
                .err()
                .expect("anchored pattern must reject AST engines");
            assert!(format!("{err}").contains("unanchored"), "{err}");
        }
        // exact semantics likewise
        let exact = Pattern::RegexExact("abc".to_string());
        assert!(CompiledMatcher::compile(
            &exact,
            Engine::Backtracking,
            policy()
        )
        .is_err());
    }

    #[test]
    fn grail_pattern_compiles_without_ast() {
        let fig6 = "(START) |- 0\n0 0 1\n0 1 2\n1 0 1\n1 1 3\n2 0 3\n\
                    2 1 2\n3 0 3\n3 1 3\n3 -| (FINAL)\n";
        let cm = CompiledMatcher::compile(
            &Pattern::Grail(fig6.to_string()),
            Engine::speculative(),
            policy(),
        )
        .unwrap();
        let out = cm.run_syms(&[1, 0, 1, 0]).unwrap();
        assert!(out.final_state.is_some());
        assert!(
            CompiledMatcher::compile(
                &Pattern::Grail(fig6.to_string()),
                Engine::GrepLike,
                policy()
            )
            .is_err()
        );
    }

    #[test]
    fn engine_parse_roundtrip() {
        assert_eq!(Engine::parse("auto").unwrap(), Engine::Auto);
        assert_eq!(Engine::parse("seq").unwrap(), Engine::Sequential);
        assert_eq!(Engine::parse("spec").unwrap(), Engine::speculative());
        assert_eq!(Engine::parse("simd").unwrap(), Engine::simd());
        assert_eq!(Engine::parse("cloud").unwrap(), Engine::cloud());
        assert_eq!(Engine::parse("shard").unwrap(), Engine::shard());
        assert_eq!(Engine::parse("holub").unwrap(), Engine::HolubStekr);
        assert_eq!(
            Engine::parse("backtrack").unwrap(),
            Engine::Backtracking
        );
        assert_eq!(Engine::parse("grep").unwrap(), Engine::GrepLike);
        assert!(Engine::parse("warp-drive").is_err());
    }

    #[test]
    fn policy_weights_must_match_processors() {
        let pattern = Pattern::Regex("abc".to_string());
        let bad = ExecPolicy {
            processors: 4,
            weights: Some(vec![1.0, 1.0]),
            ..ExecPolicy::default()
        };
        assert!(CompiledMatcher::compile(
            &pattern,
            Engine::speculative(),
            bad
        )
        .is_err());
    }
}
