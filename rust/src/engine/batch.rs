//! Batched serving: run many inputs through one [`CompiledMatcher`],
//! amortizing pattern compilation, lookahead analysis and plan/adapter
//! construction across the batch — the request shape of a matching
//! service (many inputs per pattern, mixed sizes).
//!
//! With [`Engine::Auto`](super::Engine::Auto), each request in the batch
//! is dispatched independently: a 4 KB probe goes to the scalar loop
//! while the 16 MB corpus scan behind it goes to the cluster.
//!
//! A failed request (out-of-fuel backtracking run, missing adapter) does
//! **not** abort the batch: its slot records a [`RequestError`] and every
//! other request still completes — a server must never drop finished work
//! because an unrelated request in the same batch failed.

use std::collections::HashMap;
use std::fmt;

use super::outcome::{EngineKind, Outcome};
use super::{CompiledMatcher, Matcher};

/// One request's failure inside a batch.  The batch keeps going; the slot
/// records what went wrong and at which position.
#[derive(Clone, Debug)]
pub struct RequestError {
    /// Index of the failed request within the batch.
    pub index: usize,
    /// The full error chain, `{:#}`-formatted.
    pub message: String,
}

impl fmt::Display for RequestError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "request {}: {}", self.index, self.message)
    }
}

impl std::error::Error for RequestError {}

/// Results of one batch, plus aggregate serving telemetry.
#[derive(Clone, Debug)]
pub struct BatchOutcome {
    /// Per-request result slots, in input order: `Ok` outcomes for the
    /// requests that completed, a [`RequestError`] for each that failed.
    pub outcomes: Vec<Result<Outcome, RequestError>>,
    /// Total input symbols across the batch (failed slots included).
    pub total_syms: usize,
    /// Wall time of the whole batch, seconds.
    pub wall_s: f64,
}

impl BatchOutcome {
    /// The completed outcomes, in input order.
    pub fn ok_outcomes(&self) -> impl Iterator<Item = &Outcome> + '_ {
        self.outcomes.iter().filter_map(|r| r.as_ref().ok())
    }

    /// The failed slots, in input order.
    pub fn errors(&self) -> impl Iterator<Item = &RequestError> + '_ {
        self.outcomes.iter().filter_map(|r| r.as_ref().err())
    }

    /// How many requests failed.
    pub fn error_count(&self) -> usize {
        self.errors().count()
    }

    /// How many requests completed.
    pub fn ok_count(&self) -> usize {
        self.outcomes.len() - self.error_count()
    }

    /// How many requests each engine served (insertion-ordered; failed
    /// slots excluded).
    pub fn by_engine(&self) -> Vec<(EngineKind, usize)> {
        let mut tally: Vec<(EngineKind, usize)> = Vec::new();
        for o in self.ok_outcomes() {
            match tally.iter_mut().find(|(k, _)| *k == o.engine) {
                Some((_, c)) => *c += 1,
                None => tally.push((o.engine, 1)),
            }
        }
        tally
    }

    /// How many requests accepted.
    pub fn accepted_count(&self) -> usize {
        self.ok_outcomes().filter(|o| o.accepted).count()
    }

    /// Total makespan work units across the completed requests — the
    /// critical-path cost the batch paid after parallel dispatch.
    pub fn total_makespan(&self) -> usize {
        self.ok_outcomes().map(|o| o.makespan).sum()
    }

    /// Aggregate throughput over the wall time, symbols per second.
    pub fn syms_per_sec(&self) -> f64 {
        self.total_syms as f64 / self.wall_s.max(1e-12)
    }
}

impl CompiledMatcher {
    /// Serve a batch of byte inputs through the compiled pattern.
    /// Infallible at the batch level: per-request failures land in their
    /// own [`RequestError`] slot.
    ///
    /// Slots with byte-identical inputs run **once**: later duplicates
    /// clone the first slot's result (the matcher is deterministic, so
    /// the outcome is too).  [`BatchOutcome::total_syms`] counts only the
    /// work actually executed — duplicate slots add nothing.
    pub fn match_many(&self, inputs: &[&[u8]]) -> BatchOutcome {
        let t0 = std::time::Instant::now();
        let mut outcomes: Vec<Result<Outcome, RequestError>> =
            Vec::with_capacity(inputs.len());
        let mut total_syms = 0usize;
        let mut first_of: HashMap<&[u8], usize> = HashMap::new();
        for (index, input) in inputs.iter().enumerate() {
            if let Some(&prev) = first_of.get(*input) {
                outcomes.push(reuse_slot(&outcomes[prev], index));
                continue;
            }
            first_of.insert(input, index);
            total_syms += input.len();
            outcomes.push(self.run_bytes(input).map_err(|e| RequestError {
                index,
                message: format!("{e:#}"),
            }));
        }
        BatchOutcome {
            outcomes,
            total_syms,
            wall_s: t0.elapsed().as_secs_f64(),
        }
    }

    /// Serve a batch of pre-mapped symbol inputs.  Duplicate inputs are
    /// matched once and share the result, as in [`Self::match_many`].
    pub fn match_many_syms(&self, inputs: &[Vec<u32>]) -> BatchOutcome {
        let t0 = std::time::Instant::now();
        let mut outcomes: Vec<Result<Outcome, RequestError>> =
            Vec::with_capacity(inputs.len());
        let mut total_syms = 0usize;
        let mut first_of: HashMap<&[u32], usize> = HashMap::new();
        for (index, input) in inputs.iter().enumerate() {
            if let Some(&prev) = first_of.get(input.as_slice()) {
                outcomes.push(reuse_slot(&outcomes[prev], index));
                continue;
            }
            first_of.insert(input.as_slice(), index);
            total_syms += input.len();
            outcomes.push(self.run_syms(input).map_err(|e| RequestError {
                index,
                message: format!("{e:#}"),
            }));
        }
        BatchOutcome {
            outcomes,
            total_syms,
            wall_s: t0.elapsed().as_secs_f64(),
        }
    }
}

/// Clone an earlier slot's result for a duplicate input, re-indexed so a
/// cloned [`RequestError`] still points at its own slot.
fn reuse_slot(
    prev: &Result<Outcome, RequestError>,
    index: usize,
) -> Result<Outcome, RequestError> {
    match prev {
        Ok(o) => Ok(o.clone()),
        Err(e) => Err(RequestError { index, message: e.message.clone() }),
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Engine, ExecPolicy, Pattern};
    use super::*;
    use crate::workload::InputGen;

    #[test]
    fn batch_preserves_order_and_tallies_engines() {
        let cm = CompiledMatcher::compile(
            &Pattern::Regex("needle".to_string()),
            Engine::Auto,
            ExecPolicy::default(),
        )
        .unwrap();
        let mut gen = InputGen::new(0xBA7C);
        let small = gen.ascii_text(512);
        let mut large = gen.ascii_text(300_000);
        gen.plant(&mut large, b"needle", 1);
        let inputs: Vec<&[u8]> = vec![&small, &large, b"needle", b""];
        let batch = cm.match_many(&inputs);
        assert_eq!(batch.outcomes.len(), 4);
        assert_eq!(batch.error_count(), 0);
        assert_eq!(batch.ok_count(), 4);
        assert_eq!(batch.total_syms, 512 + 300_000 + 6);
        let out: Vec<&Outcome> = batch.ok_outcomes().collect();
        // small inputs stay on the scalar loop; the large scan leaves it
        assert_eq!(out[0].engine, EngineKind::Sequential);
        assert_ne!(out[1].engine, EngineKind::Sequential);
        assert!(out[1].accepted, "planted needle must be found");
        assert!(out[2].accepted);
        assert!(!out[3].accepted);
        let total: usize = batch.by_engine().iter().map(|(_, c)| c).sum();
        assert_eq!(total, 4);
        assert!(batch.by_engine().len() >= 2, "{:?}", batch.by_engine());
        assert_eq!(batch.accepted_count(), 2);
        assert!(batch.total_makespan() > 0);
        assert!(batch.syms_per_sec() > 0.0);
    }

    #[test]
    fn batch_syms_matches_batch_bytes() {
        let cm = CompiledMatcher::compile(
            &Pattern::Regex("ab+c".to_string()),
            Engine::speculative(),
            ExecPolicy { processors: 3, ..ExecPolicy::default() },
        )
        .unwrap();
        let byte_inputs: Vec<&[u8]> = vec![b"xxabbbc", b"nope", b""];
        let sym_inputs: Vec<Vec<u32>> = byte_inputs
            .iter()
            .map(|b| cm.dfa().map_input(b))
            .collect();
        let a = cm.match_many(&byte_inputs);
        let b = cm.match_many_syms(&sym_inputs);
        for (x, y) in a.ok_outcomes().zip(b.ok_outcomes()) {
            assert_eq!(x.accepted, y.accepted);
            assert_eq!(x.final_state, y.final_state);
        }
        assert_eq!(a.ok_count(), 3);
        assert_eq!(b.ok_count(), 3);
    }

    #[test]
    fn duplicate_inputs_run_once_and_share_the_result() {
        let cm = CompiledMatcher::compile(
            &Pattern::Regex("needle".to_string()),
            Engine::Sequential,
            ExecPolicy::default(),
        )
        .unwrap();
        let mut gen = InputGen::new(0xD0D0);
        let mut hay = gen.ascii_text(4096);
        gen.plant(&mut hay, b"needle", 1);
        let inputs: Vec<&[u8]> = vec![&hay, b"miss", &hay, &hay, b"miss"];
        let batch = cm.match_many(&inputs);
        assert_eq!(batch.outcomes.len(), 5);
        assert_eq!(batch.error_count(), 0);
        // only the two distinct inputs contribute work
        assert_eq!(batch.total_syms, 4096 + 4);
        let out: Vec<&Outcome> = batch.ok_outcomes().collect();
        for dup in [2usize, 3] {
            assert_eq!(out[dup].accepted, out[0].accepted);
            assert_eq!(out[dup].final_state, out[0].final_state);
            assert_eq!(out[dup].makespan, out[0].makespan);
        }
        assert!(out[0].accepted);
        assert!(!out[1].accepted);
        assert_eq!(out[4].accepted, out[1].accepted);

        // the syms path dedupes the same way
        let sym_inputs: Vec<Vec<u32>> = inputs
            .iter()
            .map(|b| cm.dfa().map_input(b))
            .collect();
        let sb = cm.match_many_syms(&sym_inputs);
        assert_eq!(sb.total_syms, 4096 + 4);
        for (x, y) in batch.ok_outcomes().zip(sb.ok_outcomes()) {
            assert_eq!(x.accepted, y.accepted);
        }
    }

    #[test]
    fn duplicate_of_a_failed_input_clones_the_error_with_its_own_index() {
        let cm = CompiledMatcher::compile(
            &Pattern::Regex("a+b".to_string()),
            Engine::Backtracking,
            ExecPolicy { backtrack_fuel: 200, ..ExecPolicy::default() },
        )
        .unwrap();
        let pathological = vec![b'a'; 4096];
        let inputs: Vec<&[u8]> = vec![&pathological, b"ab", &pathological];
        let batch = cm.match_many(&inputs);
        assert_eq!(batch.error_count(), 2);
        let errs: Vec<&RequestError> = batch.errors().collect();
        assert_eq!(errs[0].index, 0);
        assert_eq!(errs[1].index, 2, "cloned error must carry its slot");
        assert_eq!(errs[0].message, errs[1].message);
        // the failed run still paid for its symbols exactly once
        assert_eq!(batch.total_syms, 4096 + 2);
    }

    #[test]
    fn failed_request_keeps_the_rest_of_the_batch() {
        // a backtracking engine with almost no fuel: the long all-'a'
        // input exhausts it, the trivial inputs don't
        let cm = CompiledMatcher::compile(
            &Pattern::Regex("a+b".to_string()),
            Engine::Backtracking,
            ExecPolicy { backtrack_fuel: 200, ..ExecPolicy::default() },
        )
        .unwrap();
        let pathological = vec![b'a'; 4096]; // a+ with no b: O(n^2) retries
        let inputs: Vec<&[u8]> = vec![b"ab", &pathological, b"aab"];
        let batch = cm.match_many(&inputs);
        assert_eq!(batch.outcomes.len(), 3, "no slot may be dropped");
        assert!(batch.outcomes[0].is_ok(), "{:?}", batch.outcomes[0]);
        assert!(batch.outcomes[2].is_ok(), "{:?}", batch.outcomes[2]);
        let err = batch.outcomes[1]
            .as_ref()
            .err()
            .expect("fuel-starved request must fail alone");
        assert_eq!(err.index, 1);
        assert!(err.message.contains("fuel"), "{}", err.message);
        assert_eq!(batch.error_count(), 1);
        assert_eq!(batch.ok_count(), 2);
        assert_eq!(batch.accepted_count(), 2);
        let errs: Vec<&RequestError> = batch.errors().collect();
        assert_eq!(errs.len(), 1);
        assert!(format!("{}", errs[0]).starts_with("request 1:"));
    }
}
