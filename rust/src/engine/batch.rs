//! Batched serving: run many inputs through one [`CompiledMatcher`],
//! amortizing pattern compilation, lookahead analysis and plan/adapter
//! construction across the batch — the request shape of a matching
//! service (many inputs per pattern, mixed sizes).
//!
//! With [`Engine::Auto`](super::Engine::Auto), each request in the batch
//! is dispatched independently: a 4 KB probe goes to the scalar loop
//! while the 16 MB corpus scan behind it goes to the cluster.

use anyhow::Result;

use super::outcome::{EngineKind, Outcome};
use super::{CompiledMatcher, Matcher};

/// Results of one batch, plus aggregate serving telemetry.
#[derive(Clone, Debug)]
pub struct BatchOutcome {
    /// Per-request outcomes, in input order.
    pub outcomes: Vec<Outcome>,
    /// Total input symbols across the batch.
    pub total_syms: usize,
    /// Wall time of the whole batch, seconds.
    pub wall_s: f64,
}

impl BatchOutcome {
    /// How many requests each engine served (insertion-ordered).
    pub fn by_engine(&self) -> Vec<(EngineKind, usize)> {
        let mut tally: Vec<(EngineKind, usize)> = Vec::new();
        for o in &self.outcomes {
            match tally.iter_mut().find(|(k, _)| *k == o.engine) {
                Some((_, c)) => *c += 1,
                None => tally.push((o.engine, 1)),
            }
        }
        tally
    }

    /// How many requests accepted.
    pub fn accepted_count(&self) -> usize {
        self.outcomes.iter().filter(|o| o.accepted).count()
    }
}

impl CompiledMatcher {
    /// Serve a batch of byte inputs through the compiled pattern.
    pub fn match_many(&self, inputs: &[&[u8]]) -> Result<BatchOutcome> {
        let t0 = std::time::Instant::now();
        let mut outcomes = Vec::with_capacity(inputs.len());
        let mut total_syms = 0usize;
        for input in inputs {
            total_syms += input.len();
            outcomes.push(self.run_bytes(input)?);
        }
        Ok(BatchOutcome {
            outcomes,
            total_syms,
            wall_s: t0.elapsed().as_secs_f64(),
        })
    }

    /// Serve a batch of pre-mapped symbol inputs.
    pub fn match_many_syms(&self, inputs: &[Vec<u32>]) -> Result<BatchOutcome> {
        let t0 = std::time::Instant::now();
        let mut outcomes = Vec::with_capacity(inputs.len());
        let mut total_syms = 0usize;
        for input in inputs {
            total_syms += input.len();
            outcomes.push(self.run_syms(input)?);
        }
        Ok(BatchOutcome {
            outcomes,
            total_syms,
            wall_s: t0.elapsed().as_secs_f64(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Engine, ExecPolicy, Pattern};
    use super::*;
    use crate::workload::InputGen;

    #[test]
    fn batch_preserves_order_and_tallies_engines() {
        let cm = CompiledMatcher::compile(
            &Pattern::Regex("needle".to_string()),
            Engine::Auto,
            ExecPolicy::default(),
        )
        .unwrap();
        let mut gen = InputGen::new(0xBA7C);
        let small = gen.ascii_text(512);
        let mut large = gen.ascii_text(300_000);
        gen.plant(&mut large, b"needle", 1);
        let inputs: Vec<&[u8]> = vec![&small, &large, b"needle", b""];
        let batch = cm.match_many(&inputs).unwrap();
        assert_eq!(batch.outcomes.len(), 4);
        assert_eq!(batch.total_syms, 512 + 300_000 + 6);
        // small inputs stay on the scalar loop; the large scan leaves it
        assert_eq!(batch.outcomes[0].engine, EngineKind::Sequential);
        assert_ne!(batch.outcomes[1].engine, EngineKind::Sequential);
        assert!(batch.outcomes[1].accepted, "planted needle must be found");
        assert!(batch.outcomes[2].accepted);
        assert!(!batch.outcomes[3].accepted);
        let total: usize = batch.by_engine().iter().map(|(_, c)| c).sum();
        assert_eq!(total, 4);
        assert!(batch.by_engine().len() >= 2, "{:?}", batch.by_engine());
        assert_eq!(batch.accepted_count(), 2);
    }

    #[test]
    fn batch_syms_matches_batch_bytes() {
        let cm = CompiledMatcher::compile(
            &Pattern::Regex("ab+c".to_string()),
            Engine::speculative(),
            ExecPolicy { processors: 3, ..ExecPolicy::default() },
        )
        .unwrap();
        let byte_inputs: Vec<&[u8]> = vec![b"xxabbbc", b"nope", b""];
        let sym_inputs: Vec<Vec<u32>> = byte_inputs
            .iter()
            .map(|b| cm.dfa().map_input(b))
            .collect();
        let a = cm.match_many(&byte_inputs).unwrap();
        let b = cm.match_many_syms(&sym_inputs).unwrap();
        for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
            assert_eq!(x.accepted, y.accepted);
            assert_eq!(x.final_state, y.final_state);
        }
    }
}
