//! Asynchronous batched serving on top of [`CompiledMatcher`]: the
//! request loop the ROADMAP north-star asks for.
//!
//! ```text
//!   producers ──submit(pattern, input)──▶ queue ──▶ worker threads
//!      ▲                                              │
//!      │            same-pattern coalescing           │
//!   Ticket ◀──────── streamed Outcome ◀── LRU compiled-pattern cache
//!                                              │
//!                       speculative::profile ──▶ AutoThresholds
//!                       (startup + periodic re-calibration)
//! ```
//!
//! * Many producer threads [`Server::submit`] `(pattern, input)` requests;
//!   each gets a [`Ticket`] that streams its own `Result<Outcome, _>` back
//!   over a channel — no caller ever blocks another.
//! * Worker threads pop the queue and **coalesce**: a worker taking a
//!   request also takes every other queued request for the same pattern
//!   (up to [`ServeConfig::max_batch`]), so one cache lookup and one hot
//!   transition table serve the whole run — the `match_many` amortization,
//!   made concurrent.
//! * Compiled patterns live in an **LRU cache** keyed by the pattern, so
//!   repeated patterns never recompile (DFA construction + lookahead
//!   analysis dominate small-request latency).  A miss marks the pattern
//!   **in-flight** and compiles outside the cache mutex, so cache hits
//!   (and unrelated compiles) proceed while a new pattern is compiling;
//!   concurrent requests for the same new pattern wait instead of
//!   compiling twice.
//! * Results are memoized in a small **(pattern, input) → Outcome LRU**
//!   ([`ServeConfig::cache_outcomes`]): repeated probes — health checks,
//!   retried requests, hot keys — skip the matching loop entirely
//!   ([`ServeStats::outcome_hits`] counts the wins).
//! * At startup — and again every [`ServeConfig::recalibrate_every`]
//!   requests — the server runs the paper's §4.1 offline profiling step
//!   ([`crate::speculative::profile::profile_host`]) and installs
//!   [`AutoThresholds::from_profile`], so `Engine::Auto` routing reflects
//!   the machine it is on instead of the baked-in 500 sym/µs ballpark.
//!   Re-calibration bumps an epoch; cached matchers compiled under stale
//!   thresholds are recompiled on next use.
//! * The same profiling step also measures a **per-worker capacity
//!   vector** ([`crate::speculative::profile::profile_workers`]): one
//!   rate per matcher thread, timed concurrently.  Its Eq. (1) weights
//!   flow into [`ExecPolicy::weights`], so on inhomogeneous machines the
//!   multicore and hierarchical-shard partitions follow what each worker
//!   can actually do instead of assuming uniform cores.
//!
//! Everything is `std` threads and channels — no new dependencies.

use std::collections::VecDeque;
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use anyhow::Result;

use crate::speculative::profile;

use super::select::AutoThresholds;
use super::{CompiledMatcher, Engine, ExecPolicy, Matcher, Outcome, Pattern};

/// Serving configuration.  The defaults serve `Engine::Auto` with
/// calibration on and a cache sized for a medium pattern working set.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Worker threads draining the request queue.
    pub workers: usize,
    /// Capacity of the compiled-pattern LRU cache (patterns, not bytes).
    pub cache_patterns: usize,
    /// Capacity of the result-level `(pattern, input) -> Outcome` memo
    /// cache (entries); 0 disables outcome memoization.  Hits are
    /// decided by exact input equality (an FNV-1a hash pre-filters) and
    /// invalidated by each re-calibration epoch.
    pub cache_outcomes: usize,
    /// Largest input (bytes) the outcome memo will retain — entries
    /// store the input for exact comparison, so this bounds the memo's
    /// memory at `cache_outcomes × cache_outcome_max_bytes`.
    pub cache_outcome_max_bytes: usize,
    /// Maximum requests one worker coalesces into a single batch.
    pub max_batch: usize,
    /// Re-run the §4.1 profiling step after this many served requests;
    /// 0 disables periodic re-calibration.
    pub recalibrate_every: u64,
    /// Run the profiling step before accepting requests, so the very
    /// first dispatch already uses measured thresholds.
    pub calibrate_on_start: bool,
    /// Timed runs per profiling step (median taken, §4.1).
    pub profile_runs: usize,
    /// Symbols per timed profiling run.
    pub profile_sample_syms: usize,
    /// Also measure a per-worker capacity vector at each calibration
    /// (one rate per `policy.processors` worker thread, timed
    /// concurrently) and feed its Eq. (1) weights into
    /// [`ExecPolicy::weights`] for every compiled matcher.
    pub profile_per_worker: bool,
    /// Engine every request is served with (normally `Engine::Auto`).
    pub engine: Engine,
    /// Execution policy template; its `thresholds` field is replaced by
    /// the live calibrated thresholds at each compile.
    pub policy: ExecPolicy,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            workers: 4,
            cache_patterns: 64,
            cache_outcomes: 256,
            cache_outcome_max_bytes: 1 << 16,
            max_batch: 64,
            recalibrate_every: 4096,
            calibrate_on_start: true,
            profile_runs: 5,
            profile_sample_syms: 1 << 18,
            profile_per_worker: true,
            engine: Engine::Auto,
            policy: ExecPolicy::default(),
        }
    }
}

/// A request failure delivered through a [`Ticket`].  Cloneable so one
/// compile failure can be streamed to every request of a coalesced batch.
#[derive(Clone, Debug)]
pub struct ServeError {
    /// human-readable failure description (the full error chain)
    pub message: String,
}

impl ServeError {
    fn new(message: impl Into<String>) -> ServeError {
        ServeError { message: message.into() }
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.message)
    }
}

impl std::error::Error for ServeError {}

/// The per-request result stream: one [`Outcome`] (or error) per submit.
pub type ServeResult = Result<Outcome, ServeError>;

/// Handle to one submitted request.  Dropping it discards the result;
/// the server keeps running.
pub struct Ticket {
    rx: Receiver<ServeResult>,
}

impl Ticket {
    /// Block until this request's outcome is streamed back.
    pub fn wait(self) -> ServeResult {
        match self.rx.recv() {
            Ok(res) => res,
            Err(_) => Err(ServeError::new(
                "server shut down before serving the request",
            )),
        }
    }
}

/// Aggregate serving telemetry (monotonic counters since startup).
#[derive(Clone, Debug)]
pub struct ServeStats {
    /// Requests accepted into the queue.
    pub submitted: u64,
    /// Requests served with an `Ok` outcome.
    pub served: u64,
    /// Requests that streamed an error back.
    pub failed: u64,
    /// Coalesced batches executed.
    pub batches: u64,
    /// Requests that rode along in a batch after the first (coalescing
    /// wins: each saved a queue wake-up and a cache lookup).
    pub coalesced: u64,
    /// Pattern compilations performed (cache misses + stale recompiles).
    pub compiles: u64,
    /// Batches served from an already-compiled cache entry.
    pub cache_hits: u64,
    /// Requests answered straight from the outcome memo cache (the
    /// matching loop never ran).
    pub outcome_hits: u64,
    /// LRU evictions.
    pub evictions: u64,
    /// Profiling runs performed (startup calibration included).
    pub recalibrations: u64,
    /// Patterns currently resident in the cache.
    pub cached_patterns: usize,
    /// Outcomes currently resident in the memo cache.
    pub cached_outcomes: usize,
    /// Requests currently queued, not yet taken by a worker.
    pub queue_depth: usize,
    /// The thresholds `Engine::Auto` dispatch currently uses.
    pub thresholds: AutoThresholds,
    /// The measured per-worker capacity vector (symbols/µs) the current
    /// Eq. (1) weights derive from; `None` until the first per-worker
    /// calibration (or when [`ServeConfig::profile_per_worker`] is off).
    pub worker_rates: Option<Vec<f64>>,
}

impl ServeStats {
    /// Mean requests per executed batch (1.0 = no coalescing happened).
    pub fn requests_per_batch(&self) -> f64 {
        let done = self.served + self.failed;
        done as f64 / self.batches.max(1) as f64
    }
}

struct Request {
    pattern: Pattern,
    input: Vec<u8>,
    reply: Sender<ServeResult>,
}

struct CacheEntry {
    pattern: Pattern,
    /// calibration epoch the matcher was compiled under; stale entries
    /// are recompiled so Auto routing uses the fresh thresholds
    epoch: u64,
    matcher: Arc<CompiledMatcher>,
    last_used: u64,
}

/// Tiny LRU keyed by `Pattern` equality.  Linear scan: serving caches
/// hold tens-to-hundreds of patterns, where a scan beats hashing the
/// whole pattern string per lookup.  `inflight` marks patterns some
/// worker is currently compiling *outside* this cache's mutex.
struct PatternCache {
    entries: Vec<CacheEntry>,
    inflight: Vec<Pattern>,
    tick: u64,
}

/// One memoized `(pattern, input) -> Outcome` result.  The input bytes
/// are retained so a hit requires exact equality — the hash only
/// pre-filters (FNV-1a is non-cryptographic; a collision must not
/// return another request's outcome).
struct OutcomeEntry {
    pattern: Pattern,
    input: Vec<u8>,
    input_hash: u64,
    /// calibration epoch the outcome was produced under; stale entries
    /// never hit (routing may differ after re-calibration)
    epoch: u64,
    outcome: Outcome,
    last_used: u64,
}

impl OutcomeEntry {
    /// The memo key predicate: epoch + hash pre-filter, then exact
    /// input and pattern equality.
    fn matches(&self, epoch: u64, hash: u64, req: &Request) -> bool {
        self.epoch == epoch
            && self.input_hash == hash
            && self.input == req.input
            && self.pattern == req.pattern
    }
}

/// Result-level memo cache, same linear-scan LRU idiom as
/// [`PatternCache`]: the hash comparison rejects almost every non-match
/// before the `Pattern` equality check runs.
struct OutcomeCache {
    entries: Vec<OutcomeEntry>,
    tick: u64,
}

struct Counters {
    submitted: AtomicU64,
    served: AtomicU64,
    failed: AtomicU64,
    batches: AtomicU64,
    coalesced: AtomicU64,
    compiles: AtomicU64,
    cache_hits: AtomicU64,
    outcome_hits: AtomicU64,
    evictions: AtomicU64,
    recalibrations: AtomicU64,
}

impl Counters {
    fn new() -> Counters {
        Counters {
            submitted: AtomicU64::new(0),
            served: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            compiles: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            outcome_hits: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            recalibrations: AtomicU64::new(0),
        }
    }
}

struct Shared {
    config: ServeConfig,
    queue: Mutex<VecDeque<Request>>,
    ready: Condvar,
    shutdown: AtomicBool,
    /// live dispatch thresholds, replaced by each calibration
    thresholds: Mutex<AutoThresholds>,
    /// live per-worker capacity vector, replaced by each calibration
    /// (None until measured or when profile_per_worker is off)
    capacity: Mutex<Option<profile::CapacityVector>>,
    /// bumped by each calibration; cache entries from older epochs are
    /// recompiled on next use
    epoch: AtomicU64,
    /// requests finished (served + failed), drives periodic re-calibration
    done: AtomicU64,
    cache: Mutex<PatternCache>,
    /// signalled when an in-flight compile finishes, waking workers that
    /// queued behind the same new pattern
    compiled: Condvar,
    outcomes: Mutex<OutcomeCache>,
    counters: Counters,
}

/// The serving loop: worker threads, request queue, pattern cache and
/// capacity calibration behind a submit/stream API.
///
/// ```
/// use specdfa::engine::{Pattern, ServeConfig, Server};
///
/// let server = Server::start(ServeConfig {
///     workers: 2,
///     profile_runs: 1,          // keep the doctest's calibration cheap
///     profile_sample_syms: 4096,
///     ..ServeConfig::default()
/// })?;
/// let hit = server.submit(Pattern::Regex("ab+c".into()), &b"xabbcx"[..]);
/// let miss = server.submit(Pattern::Regex("ab+c".into()), &b"nope"[..]);
/// assert!(hit.wait().unwrap().accepted);
/// assert!(!miss.wait().unwrap().accepted);
/// let stats = server.shutdown();
/// assert_eq!(stats.served, 2);
/// assert!(stats.thresholds.is_calibrated());
/// # anyhow::Result::<()>::Ok(())
/// ```
pub struct Server {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl Server {
    /// Start the worker threads (and, by default, run the startup
    /// calibration) and begin accepting requests.
    pub fn start(config: ServeConfig) -> Result<Server> {
        anyhow::ensure!(config.workers >= 1, "serve needs >= 1 worker");
        anyhow::ensure!(
            config.cache_patterns >= 1,
            "serve needs a >= 1 pattern cache"
        );
        anyhow::ensure!(config.max_batch >= 1, "serve needs max_batch >= 1");
        let calibrate = config.calibrate_on_start;
        let workers = config.workers;
        let shared = Arc::new(Shared {
            thresholds: Mutex::new(config.policy.thresholds.clone()),
            capacity: Mutex::new(None),
            queue: Mutex::new(VecDeque::new()),
            ready: Condvar::new(),
            shutdown: AtomicBool::new(false),
            epoch: AtomicU64::new(0),
            done: AtomicU64::new(0),
            cache: Mutex::new(PatternCache {
                entries: Vec::new(),
                inflight: Vec::new(),
                tick: 0,
            }),
            compiled: Condvar::new(),
            outcomes: Mutex::new(OutcomeCache {
                entries: Vec::new(),
                tick: 0,
            }),
            counters: Counters::new(),
            config,
        });
        if calibrate {
            recalibrate(&shared);
        }
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let worker_shared = Arc::clone(&shared);
            let spawned = std::thread::Builder::new()
                .name(format!("specdfa-serve-{i}"))
                .spawn(move || worker_loop(&worker_shared));
            match spawned {
                Ok(handle) => handles.push(handle),
                Err(e) => {
                    // unwind: don't leak the already-spawned workers
                    // parked forever on the condvar
                    {
                        let _queue = shared.queue.lock().unwrap();
                        shared.shutdown.store(true, Ordering::SeqCst);
                        shared.ready.notify_all();
                    }
                    for handle in handles {
                        let _ = handle.join();
                    }
                    return Err(e.into());
                }
            }
        }
        Ok(Server { shared, workers: handles })
    }

    /// Queue one request; the returned [`Ticket`] streams its outcome.
    pub fn submit(&self, pattern: Pattern, input: impl Into<Vec<u8>>) -> Ticket {
        let (tx, rx) = channel();
        let req = Request { pattern, input: input.into(), reply: tx };
        self.shared.counters.submitted.fetch_add(1, Ordering::Relaxed);
        self.shared.queue.lock().unwrap().push_back(req);
        self.shared.ready.notify_one();
        Ticket { rx }
    }

    /// Queue many same-pattern requests under one queue lock, maximizing
    /// the coalescing a single worker can do.
    pub fn submit_many(
        &self,
        pattern: &Pattern,
        inputs: &[&[u8]],
    ) -> Vec<Ticket> {
        let mut tickets = Vec::with_capacity(inputs.len());
        {
            let mut q = self.shared.queue.lock().unwrap();
            for input in inputs {
                let (tx, rx) = channel();
                q.push_back(Request {
                    pattern: pattern.clone(),
                    input: input.to_vec(),
                    reply: tx,
                });
                tickets.push(Ticket { rx });
            }
        }
        self.shared
            .counters
            .submitted
            .fetch_add(inputs.len() as u64, Ordering::Relaxed);
        self.shared.ready.notify_all();
        tickets
    }

    /// Snapshot of the serving telemetry.
    pub fn stats(&self) -> ServeStats {
        // one lock at a time: a snapshot must never stall the workers
        let cached_patterns = self.shared.cache.lock().unwrap().entries.len();
        let cached_outcomes =
            self.shared.outcomes.lock().unwrap().entries.len();
        let queue_depth = self.shared.queue.lock().unwrap().len();
        let thresholds = self.shared.thresholds.lock().unwrap().clone();
        let worker_rates = self
            .shared
            .capacity
            .lock()
            .unwrap()
            .as_ref()
            .map(|cv| cv.rates.clone());
        let c = &self.shared.counters;
        ServeStats {
            submitted: c.submitted.load(Ordering::Relaxed),
            served: c.served.load(Ordering::Relaxed),
            failed: c.failed.load(Ordering::Relaxed),
            batches: c.batches.load(Ordering::Relaxed),
            coalesced: c.coalesced.load(Ordering::Relaxed),
            compiles: c.compiles.load(Ordering::Relaxed),
            cache_hits: c.cache_hits.load(Ordering::Relaxed),
            outcome_hits: c.outcome_hits.load(Ordering::Relaxed),
            evictions: c.evictions.load(Ordering::Relaxed),
            recalibrations: c.recalibrations.load(Ordering::Relaxed),
            cached_patterns,
            cached_outcomes,
            queue_depth,
            thresholds,
            worker_rates,
        }
    }

    /// The thresholds `Engine::Auto` dispatch currently uses (calibrated
    /// after startup profiling unless disabled).
    pub fn thresholds(&self) -> AutoThresholds {
        self.shared.thresholds.lock().unwrap().clone()
    }

    /// Drain the queue, stop the workers, and return the final stats.
    pub fn shutdown(mut self) -> ServeStats {
        self.finish();
        self.stats()
    }

    fn finish(&mut self) {
        {
            // flag + notify under the queue lock: a worker between its
            // shutdown check and Condvar::wait holds this mutex, so the
            // wakeup can never race into the gap and get lost
            let _queue = self.shared.queue.lock().unwrap();
            self.shared.shutdown.store(true, Ordering::SeqCst);
            self.shared.ready.notify_all();
        }
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.finish();
    }
}

/// Worker: take a coalesced batch, serve it, repeat until shutdown with
/// an empty queue (shutdown drains — queued work is never dropped).
fn worker_loop(shared: &Shared) {
    while let Some(batch) = next_batch(shared) {
        serve_batch(shared, batch);
    }
}

fn next_batch(shared: &Shared) -> Option<Vec<Request>> {
    let mut q = shared.queue.lock().unwrap();
    loop {
        if let Some(first) = q.pop_front() {
            let mut batch = vec![first];
            // coalesce: take every queued request for the same pattern.
            // One scan records the matching indices; the removals then go
            // back-to-front via swap_remove_back, which is O(1) per hit
            // (VecDeque::remove would shift O(queue) elements each time).
            // Removing the largest index first keeps the smaller recorded
            // indices valid: a swap only disturbs positions at or beyond
            // the removed index.  Unmatched requests may change relative
            // order — each request streams to its own ticket, so no
            // caller can observe the queue's internal order.
            let mut hits: Vec<usize> = Vec::new();
            for i in 0..q.len() {
                if batch.len() + hits.len() >= shared.config.max_batch {
                    break;
                }
                if q[i].pattern == batch[0].pattern {
                    hits.push(i);
                }
            }
            for &i in hits.iter().rev() {
                batch.push(q.swap_remove_back(i).expect("index checked"));
            }
            // the back-to-front removals reversed the hits: restore
            // submission order within the batch
            batch[1..].reverse();
            return Some(batch);
        }
        if shared.shutdown.load(Ordering::SeqCst) {
            return None;
        }
        q = shared.ready.wait(q).unwrap();
    }
}

fn serve_batch(shared: &Shared, batch: Vec<Request>) {
    let c = &shared.counters;
    c.batches.fetch_add(1, Ordering::Relaxed);
    c.coalesced.fetch_add((batch.len() - 1) as u64, Ordering::Relaxed);
    // memo pre-pass: hits answer without touching the pattern cache, so
    // a memoized probe never pays a recompile after pattern eviction.
    // The hash is computed once per request and reused below.
    let mut misses: Vec<(Request, Option<u64>)> =
        Vec::with_capacity(batch.len());
    for req in batch {
        let hash = memo_hash(shared, &req);
        match hash.and_then(|h| cached_outcome(shared, &req, h)) {
            Some(out) => {
                c.served.fetch_add(1, Ordering::Relaxed);
                // a dropped Ticket just discards its result
                let _ = req.reply.send(Ok(out));
                finish_request(shared);
            }
            None => misses.push((req, hash)),
        }
    }
    if misses.is_empty() {
        return;
    }
    // lock-free duplicate detection: a memo re-check under the outcomes
    // mutex is only worth it when an *earlier miss in this batch* will
    // have memoized the identical request by the time we reach this one
    let dup_of_earlier: Vec<bool> = misses
        .iter()
        .enumerate()
        .map(|(i, (req, hash))| {
            hash.is_some()
                && misses[..i].iter().any(|(prev, prev_hash)| {
                    prev_hash == hash && prev.input == req.input
                })
        })
        .collect();
    match matcher_for(shared, &misses[0].0.pattern) {
        Ok(cm) => {
            for ((req, hash), dup) in misses.into_iter().zip(dup_of_earlier)
            {
                let memo = if dup {
                    hash.and_then(|h| cached_outcome(shared, &req, h))
                } else {
                    None
                };
                let res = match memo {
                    Some(out) => Ok(out),
                    None => {
                        // capture the epoch BEFORE matching: if a
                        // re-calibration lands mid-run, the stale-epoch
                        // insert below can never hit (preserving the
                        // purge-on-recalibrate invariant)
                        let epoch = shared.epoch.load(Ordering::SeqCst);
                        let res = cm
                            .run_bytes(&req.input)
                            .map_err(|e| ServeError::new(format!("{e:#}")));
                        if let (Ok(out), Some(h)) = (&res, hash) {
                            remember_outcome(shared, &req, h, epoch, out);
                        }
                        res
                    }
                };
                match &res {
                    Ok(_) => c.served.fetch_add(1, Ordering::Relaxed),
                    Err(_) => c.failed.fetch_add(1, Ordering::Relaxed),
                };
                let _ = req.reply.send(res);
                finish_request(shared);
            }
        }
        Err(e) => {
            for (req, _) in misses {
                c.failed.fetch_add(1, Ordering::Relaxed);
                let _ = req.reply.send(Err(e.clone()));
                finish_request(shared);
            }
        }
    }
}

/// The memo hash for a request, or `None` when the request is not
/// memoizable (memoization off, or the input exceeds the size cap).
fn memo_hash(shared: &Shared, req: &Request) -> Option<u64> {
    if shared.config.cache_outcomes == 0
        || req.input.len() > shared.config.cache_outcome_max_bytes
    {
        return None;
    }
    Some(crate::util::fnv1a(&req.input))
}

/// Outcome memo lookup under the current calibration epoch: the hash
/// pre-filters, the stored input bytes decide (exact equality — a hash
/// collision must never return another request's outcome).  The
/// returned outcome is a clone of the memoized run (its `wall_s` etc.
/// describe the original run).
fn cached_outcome(
    shared: &Shared,
    req: &Request,
    hash: u64,
) -> Option<Outcome> {
    let epoch = shared.epoch.load(Ordering::SeqCst);
    let mut cache = shared.outcomes.lock().unwrap();
    cache.tick += 1;
    let tick = cache.tick;
    let hit = cache
        .entries
        .iter_mut()
        .find(|e| e.matches(epoch, hash, req))?;
    hit.last_used = tick;
    shared.counters.outcome_hits.fetch_add(1, Ordering::Relaxed);
    Some(hit.outcome.clone())
}

/// Insert a freshly computed outcome into the memo LRU.  `epoch` is the
/// calibration epoch read *before* the match ran — an insert that raced
/// a re-calibration lands stale and can never hit.
fn remember_outcome(
    shared: &Shared,
    req: &Request,
    hash: u64,
    epoch: u64,
    out: &Outcome,
) {
    let cap = shared.config.cache_outcomes;
    let mut cache = shared.outcomes.lock().unwrap();
    cache.tick += 1;
    let tick = cache.tick;
    if let Some(e) =
        cache.entries.iter_mut().find(|e| e.matches(epoch, hash, req))
    {
        // a concurrent worker memoized the same request first
        e.last_used = tick;
        return;
    }
    if cache.entries.len() >= cap {
        if let Some(lru) = cache
            .entries
            .iter()
            .enumerate()
            .min_by_key(|(_, e)| e.last_used)
            .map(|(i, _)| i)
        {
            cache.entries.swap_remove(lru);
        }
    }
    cache.entries.push(OutcomeEntry {
        pattern: req.pattern.clone(),
        input: req.input.clone(),
        input_hash: hash,
        epoch,
        outcome: out.clone(),
        last_used: tick,
    });
}

/// Removes this worker's in-flight compile marker and wakes the waiters
/// on every exit path — including an unwind out of the compile itself,
/// which would otherwise strand waiters on the condvar forever.
struct InflightGuard<'a> {
    shared: &'a Shared,
    pattern: &'a Pattern,
}

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        let mut cache = match self.shared.cache.lock() {
            Ok(cache) => cache,
            // a poisoned cache just means some holder panicked; the
            // marker still has to go so waiters can make progress
            Err(poisoned) => poisoned.into_inner(),
        };
        if let Some(pos) =
            cache.inflight.iter().position(|p| p == self.pattern)
        {
            cache.inflight.swap_remove(pos);
        }
        drop(cache);
        self.shared.compiled.notify_all();
    }
}

/// Cache lookup / compile.  A miss marks the pattern in-flight and
/// compiles *outside* the cache mutex, so hits (and compiles of other
/// patterns) proceed while the DFA construction runs; workers racing on
/// the same new pattern wait on the condvar instead of duplicating the
/// compile.
fn matcher_for(
    shared: &Shared,
    pattern: &Pattern,
) -> std::result::Result<Arc<CompiledMatcher>, ServeError> {
    let epoch = loop {
        let epoch = shared.epoch.load(Ordering::SeqCst);
        let mut cache = shared.cache.lock().unwrap();
        cache.tick += 1;
        let tick = cache.tick;
        if let Some(pos) =
            cache.entries.iter().position(|e| &e.pattern == pattern)
        {
            if cache.entries[pos].epoch == epoch {
                let entry = &mut cache.entries[pos];
                entry.last_used = tick;
                shared.counters.cache_hits.fetch_add(1, Ordering::Relaxed);
                return Ok(Arc::clone(&entry.matcher));
            }
            // compiled under stale thresholds: drop and recompile below
            cache.entries.swap_remove(pos);
        }
        if cache.inflight.contains(pattern) {
            // another worker is compiling this exact pattern: wait for
            // its insert (or failure) and re-check.  On failure there is
            // neither entry nor marker, so this worker becomes the
            // compiler, fails the same way, and reports its own error —
            // no retry loop.
            let woken = shared.compiled.wait(cache).unwrap();
            drop(woken);
            continue;
        }
        cache.inflight.push(pattern.clone());
        break epoch;
    };
    // from here the marker is cleaned up on EVERY exit — normal return,
    // compile error, or an unwind out of the compile
    let _inflight = InflightGuard { shared, pattern };
    // compile with NO cache lock held.  Measured per-worker Eq. (1)
    // weights (when available) override the template's; the multicore
    // and shard partitions then track the machine's real per-worker
    // capacities.
    let weights = shared
        .capacity
        .lock()
        .unwrap()
        .as_ref()
        .map(|cv| cv.weights())
        .or_else(|| shared.config.policy.weights.clone());
    let policy = ExecPolicy {
        thresholds: shared.thresholds.lock().unwrap().clone(),
        weights,
        ..shared.config.policy.clone()
    };
    let compiled =
        CompiledMatcher::compile(pattern, shared.config.engine.clone(), policy)
            .map_err(|e| ServeError::new(format!("compile failed: {e:#}")));
    let cm = Arc::new(compiled?);
    shared.counters.compiles.fetch_add(1, Ordering::Relaxed);
    let mut cache = shared.cache.lock().unwrap();
    cache.tick += 1;
    let tick = cache.tick;
    if cache.entries.len() >= shared.config.cache_patterns {
        if let Some(lru) = cache
            .entries
            .iter()
            .enumerate()
            .min_by_key(|(_, e)| e.last_used)
            .map(|(i, _)| i)
        {
            cache.entries.swap_remove(lru);
            shared.counters.evictions.fetch_add(1, Ordering::Relaxed);
        }
    }
    cache.entries.push(CacheEntry {
        pattern: pattern.clone(),
        epoch,
        matcher: Arc::clone(&cm),
        last_used: tick,
    });
    drop(cache);
    Ok(cm)
}

fn finish_request(shared: &Shared) {
    let every = shared.config.recalibrate_every;
    let done = shared.done.fetch_add(1, Ordering::SeqCst) + 1;
    // `done` values are unique per request, so exactly one worker crosses
    // each multiple of `every` and triggers the re-calibration
    if every != 0 && done % every == 0 {
        recalibrate(shared);
    }
}

/// The §4.1 offline profiling step, applied live: measure this host's
/// matching capacity (and, unless disabled, the per-worker capacity
/// vector) and install thresholds + Eq. (1) weights derived from them.
fn recalibrate(shared: &Shared) {
    let p = profile::profile_host(
        shared.config.profile_runs,
        shared.config.profile_sample_syms,
    );
    *shared.thresholds.lock().unwrap() = AutoThresholds::from_profile(&p);
    if shared.config.profile_per_worker {
        let cv = profile::profile_workers(
            shared.config.policy.processors,
            shared.config.profile_runs,
            shared.config.profile_sample_syms,
        );
        *shared.capacity.lock().unwrap() = Some(cv);
    }
    shared.epoch.fetch_add(1, Ordering::SeqCst);
    // every memoized outcome is now stale (routing may differ under the
    // fresh thresholds); purge instead of letting dead entries linger in
    // the scan until LRU pressure displaces them
    shared.outcomes.lock().unwrap().entries.clear();
    shared.counters.recalibrations.fetch_add(1, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_config() -> ServeConfig {
        ServeConfig {
            workers: 2,
            profile_runs: 1,
            profile_sample_syms: 4096,
            recalibrate_every: 0,
            ..ServeConfig::default()
        }
    }

    #[test]
    fn serves_and_streams_outcomes() {
        let server = Server::start(quick_config()).unwrap();
        let pattern = Pattern::Regex("ab+c".to_string());
        let t1 = server.submit(pattern.clone(), &b"xxabbbcyy"[..]);
        let t2 = server.submit(pattern.clone(), &b"nothing"[..]);
        let t3 = server.submit(pattern, &b""[..]);
        assert!(t1.wait().unwrap().accepted);
        assert!(!t2.wait().unwrap().accepted);
        assert!(!t3.wait().unwrap().accepted);
        let stats = server.shutdown();
        assert_eq!(stats.submitted, 3);
        assert_eq!(stats.served, 3);
        assert_eq!(stats.failed, 0);
        assert!(stats.compiles >= 1);
        assert!(stats.compiles < 3, "same pattern must not recompile");
        assert!(stats.thresholds.is_calibrated());
        assert_eq!(stats.recalibrations, 1); // the startup profiling
    }

    #[test]
    fn bad_pattern_streams_an_error_and_keeps_serving() {
        let server = Server::start(quick_config()).unwrap();
        let bad = server.submit(
            Pattern::Regex("ab[".to_string()),
            &b"whatever"[..],
        );
        let good =
            server.submit(Pattern::Regex("ok".to_string()), &b"ok"[..]);
        let err = bad.wait().expect_err("unterminated class must fail");
        assert!(err.message.contains("compile failed"), "{err}");
        assert!(good.wait().unwrap().accepted);
        let stats = server.shutdown();
        assert_eq!(stats.failed, 1);
        assert_eq!(stats.served, 1);
    }

    #[test]
    fn per_worker_calibration_feeds_eq1_weights() {
        let server = Server::start(quick_config()).unwrap();
        let t = server.submit(Pattern::Regex("ab".to_string()), &b"ab"[..]);
        assert!(t.wait().unwrap().accepted);
        let stats = server.shutdown();
        let rates = stats
            .worker_rates
            .expect("per-worker profiling is on by default");
        assert_eq!(rates.len(), ServeConfig::default().policy.processors);
        assert!(rates.iter().all(|&r| r > 0.0), "{rates:?}");

        // and it can be disabled
        let server = Server::start(ServeConfig {
            profile_per_worker: false,
            ..quick_config()
        })
        .unwrap();
        let t = server.submit(Pattern::Regex("ab".to_string()), &b"ab"[..]);
        assert!(t.wait().unwrap().accepted);
        assert!(server.shutdown().worker_rates.is_none());
    }

    #[test]
    fn shutdown_drains_queued_requests() {
        let server = Server::start(ServeConfig {
            workers: 1,
            ..quick_config()
        })
        .unwrap();
        let pattern = Pattern::Regex("x".to_string());
        let inputs: Vec<&[u8]> = vec![b"x"; 32];
        let tickets = server.submit_many(&pattern, &inputs);
        let stats = server.shutdown();
        assert_eq!(stats.served, 32, "shutdown must not drop queued work");
        for t in tickets {
            assert!(t.wait().unwrap().accepted);
        }
        assert!(stats.batches <= 32);
        assert!(stats.requests_per_batch() >= 1.0);
    }
}
