//! Asynchronous batched serving on top of [`CompiledMatcher`]: the
//! request loop the ROADMAP north-star asks for.
//!
//! ```text
//!   producers ──submit(pattern, input)──▶ bounded queue ──▶ workers
//!      ▲            admission at max_queue:  │ per-pattern sub-queues
//!      │            Block | Reject           │ probe ≺ scan + aging
//!   Ticket ◀──────── streamed Outcome ◀── LRU compiled-pattern cache
//!                                              │
//!                       speculative::profile ──▶ AutoThresholds
//!                       (startup + periodic re-calibration)
//! ```
//!
//! * Many producer threads [`Server::submit`] `(pattern, input)` requests;
//!   each gets a [`Ticket`] that streams its own `Result<Outcome, _>` back
//!   over a channel — no caller ever blocks another.
//! * The queue is **bounded** ([`ServeConfig::max_queue`]).  At the bound
//!   [`Admission::Block`] parks the producer until a worker drains space;
//!   [`Admission::Reject`] resolves the ticket immediately with
//!   [`ServeError::Overloaded`] — either way producers can never grow
//!   server memory without bound.  Submitting to a shut-down server
//!   resolves the ticket with [`ServeError::ShuttingDown`] instead of
//!   queueing work no worker will ever drain.
//! * Queued requests are **priority-scheduled by size**
//!   ([`PriorityPolicy::SizeAware`], the default): inputs of at most
//!   [`ServeConfig::probe_max_bytes`] form the *probe* class, larger
//!   inputs the *scan* class.  Workers prefer probes — one corpus scan
//!   can no longer convoy a thousand health checks behind it — but a
//!   waiting scan is bypassed by at most [`ServeConfig::age_limit`]
//!   probe batches before it is forced (the starvation bound).
//! * The queue is a **per-pattern sub-queue index**: one FIFO lane per
//!   (pattern, class) plus a per-class arrival list, so a worker's
//!   coalescing take is O(batch) — pop the oldest request of the
//!   scheduled class, drain its pattern's lane.  Arrival order within
//!   every (class, pattern) is preserved exactly (the old O(queue) scan
//!   and its `swap_remove_back` FIFO perturbation are gone), and one
//!   cache lookup plus one hot transition table still serve the whole
//!   batch — the `match_many` amortization, made concurrent.
//! * Compiled patterns live in an **LRU cache** keyed by the pattern, so
//!   repeated patterns never recompile (DFA construction + lookahead
//!   analysis dominate small-request latency).  A miss marks the pattern
//!   **in-flight** and compiles outside the cache mutex, so cache hits
//!   (and unrelated compiles) proceed while a new pattern is compiling;
//!   concurrent requests for the same new pattern wait instead of
//!   compiling twice.
//! * Results are memoized in a small **(pattern, input) → Outcome LRU**
//!   ([`ServeConfig::cache_outcomes`]): repeated probes — health checks,
//!   retried requests, hot keys — skip the matching loop entirely
//!   ([`ServeStats::outcome_hits`] counts the wins).
//! * **Cross-pattern coalescing**: when a worker takes a batch, other
//!   queued requests over the *same input* — whatever their pattern —
//!   are drained along with it and served by one fused
//!   [`CompiledSetMatcher`](super::patternset::CompiledSetMatcher) pass:
//!   prefilter + product DFA + spill, the inverse of same-pattern
//!   coalescing (k patterns × 1 input instead of 1 pattern × k inputs).
//!   [`ServeStats::fused_passes`], [`ServeStats::patterns_fused`] and
//!   [`ServeStats::prefilter_clears`] count the wins;
//!   [`ServeConfig::fuse_cross_pattern`] turns the path off.  Compiled
//!   set matchers live in their own LRU keyed by the distinct-pattern
//!   list, so a recurring fused group recompiles nothing
//!   ([`ServeStats::set_cache_hits`]); entries are epoch-invalidated by
//!   re-calibration exactly like the per-pattern cache.
//! * **Cluster routing** ([`ServeConfig::cluster`]): scans of at least
//!   [`ServeConfig::cluster_min_bytes`] are handed to a
//!   [`ProcCluster`](crate::cluster::ProcCluster) of worker processes;
//!   its own degradation ladder guarantees the sequential verdict comes
//!   back even when every worker is dead, so routing never weakens the
//!   serve loop's failure-freedom.
//! * **Preemptible scans** ([`ServeConfig::preempt_scans`]): scan-class
//!   requests are served through the streaming wrapper
//!   ([`super::stream::StreamMatcher`]) one
//!   [`ServeConfig::preempt_segment_bytes`] segment at a time; when a
//!   probe-class request is waiting at a segment boundary the scan is
//!   **parked** — its [`Checkpoint`] is serialized onto the request and
//!   the request re-queued at scan priority — so probes stop waiting
//!   behind corpus scans without the PR 5 aging bypass being the only
//!   fairness lever.  Any worker can resume a parked scan (the
//!   checkpoint rides the queue, not the worker), the aging bound
//!   limits how long it stays parked, and shutdown still drains every
//!   parked scan to completion.  [`ServeStats::preemptions`] /
//!   [`ServeStats::resumed_scans`] count the park/resume events.
//! * At startup — and again every [`ServeConfig::recalibrate_every`]
//!   requests — the server runs the paper's §4.1 offline profiling step
//!   ([`crate::speculative::profile::profile_host`]) and installs
//!   [`AutoThresholds::from_profile`], so `Engine::Auto` routing reflects
//!   the machine it is on instead of the baked-in 500 sym/µs ballpark.
//!   Re-calibration bumps an epoch; cached matchers compiled under stale
//!   thresholds are recompiled on next use.
//! * The same profiling step also measures a **per-worker capacity
//!   vector** ([`crate::speculative::profile::profile_workers`]): one
//!   rate per matcher thread, timed concurrently.  Its Eq. (1) weights
//!   flow into [`ExecPolicy::weights`], so on inhomogeneous machines the
//!   multicore and hierarchical-shard partitions follow what each worker
//!   can actually do instead of assuming uniform cores.
//!
//! Everything is `std` threads and channels — no new dependencies.

// Always-on serving path: panics on `unwrap`/`expect` are outages, not
// bugs-in-tests.  The ban is enforced by clippy.toml `disallowed-methods`
// (poisoned locks are recovered with `unwrap_or_else(PoisonError::
// into_inner)` — every guarded structure is counter- or cache-shaped and
// stays valid across an unwinding holder).
#![deny(clippy::disallowed_methods)]

use std::collections::{HashMap, VecDeque};
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::cluster::ProcCluster;
use crate::speculative::profile;

use super::patternset::{
    CompiledSetMatcher, PatternSet, SetConfig, SetTier, DEFAULT_STATE_BUDGET,
};
use super::select::AutoThresholds;
use super::stream::{Checkpoint, StreamMatcher};
use super::{CompiledMatcher, Engine, ExecPolicy, Matcher, Outcome, Pattern};

/// Index of the *probe* class (inputs of at most
/// [`ServeConfig::probe_max_bytes`]) in per-class telemetry.
pub const CLASS_PROBE: usize = 0;
/// Index of the *scan* class (inputs larger than
/// [`ServeConfig::probe_max_bytes`]) in per-class telemetry.
pub const CLASS_SCAN: usize = 1;
/// Number of request classes.
const CLASSES: usize = 2;

/// What [`Server::submit`] does when the queue already holds
/// [`ServeConfig::max_queue`] requests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// Park the producer until a worker drains space (backpressure
    /// propagates to the caller; nothing is ever dropped).
    Block,
    /// Resolve the ticket immediately with [`ServeError::Overloaded`]
    /// (load shedding; the producer decides whether to retry).
    Reject,
}

impl Admission {
    /// Parse a CLI admission name: `block|reject`.
    pub fn parse(name: &str) -> Result<Admission> {
        Ok(match name {
            "block" => Admission::Block,
            "reject" => Admission::Reject,
            other => anyhow::bail!(
                "unknown admission {other:?} (expected block|reject)"
            ),
        })
    }
}

/// How queued requests are ordered for the workers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PriorityPolicy {
    /// Strict arrival order (plus same-pattern coalescing), the
    /// pre-priority behavior: a corpus scan convoys every probe behind
    /// it.
    Fifo,
    /// Size-derived priorities: probe-class requests are taken before
    /// scan-class requests, bounded by [`ServeConfig::age_limit`] so
    /// scans cannot starve.
    SizeAware,
}

impl PriorityPolicy {
    /// Parse a CLI priority name: `fifo|size`.
    pub fn parse(name: &str) -> Result<PriorityPolicy> {
        Ok(match name {
            "fifo" => PriorityPolicy::Fifo,
            "size" | "size-aware" => PriorityPolicy::SizeAware,
            other => anyhow::bail!(
                "unknown priority {other:?} (expected fifo|size)"
            ),
        })
    }
}

/// What admission does with a pattern the static analyzer
/// ([`crate::analysis::regex::lint_pattern`]) flags as ReDoS-hazardous
/// (nested unbounded quantifiers, overlapping alternation under an
/// unbounded repeat).
///
/// The lint runs on the pattern AST at submit time — parse-only, no DFA
/// construction — and only on pattern kinds that have an AST to lint
/// (`Grail` tables are exempt).  The DFA engines themselves are immune
/// to ReDoS blowup at *match* time (no backtracking), so the gate
/// protects the *compile* path — subset construction on an ambiguous
/// regex is exactly where the exponential lives — and downstream
/// consumers the served verdicts may be forwarded to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HazardPolicy {
    /// Do not lint submitted patterns at all.
    Off,
    /// Lint and count ([`ServeStats::hazards_flagged`]) but still serve.
    /// The default: observability without behavior change.
    Warn,
    /// Refuse hazardous patterns at admission: the ticket resolves with
    /// [`ServeError::Hazard`] and nothing is queued or compiled.
    Reject,
}

impl HazardPolicy {
    /// Parse a CLI hazard-policy name: `off|warn|reject`.
    pub fn parse(name: &str) -> Result<HazardPolicy> {
        Ok(match name {
            "off" => HazardPolicy::Off,
            "warn" => HazardPolicy::Warn,
            "reject" => HazardPolicy::Reject,
            other => anyhow::bail!(
                "unknown hazard policy {other:?} (expected off|warn|reject)"
            ),
        })
    }
}

/// Serving configuration.  The defaults serve `Engine::Auto` with
/// calibration on, an unbounded queue, size-aware priorities and a cache
/// sized for a medium pattern working set.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Worker threads draining the request queue.
    pub workers: usize,
    /// Capacity of the compiled-pattern LRU cache (patterns, not bytes).
    pub cache_patterns: usize,
    /// Capacity of the result-level `(pattern, input) -> Outcome` memo
    /// cache (entries); 0 disables outcome memoization.  Hits are
    /// decided by exact input equality (an FNV-1a hash pre-filters) and
    /// invalidated by each re-calibration epoch.
    pub cache_outcomes: usize,
    /// Largest input (bytes) the outcome memo will retain — entries
    /// store the input for exact comparison, so this bounds the memo's
    /// memory at `cache_outcomes × cache_outcome_max_bytes`.
    pub cache_outcome_max_bytes: usize,
    /// Maximum requests one worker coalesces into a single batch.
    pub max_batch: usize,
    /// Queue depth bound; 0 = unbounded.  At the bound, `admission`
    /// decides between producer backpressure and load shedding.
    pub max_queue: usize,
    /// Admission policy applied when the queue is at `max_queue`.
    pub admission: Admission,
    /// Scheduling policy for queued requests.
    pub priority: PriorityPolicy,
    /// Largest input (bytes) classified as a *probe*; larger inputs are
    /// *scans*.  Under [`PriorityPolicy::SizeAware`] probes are served
    /// first; the split also keys the per-class wait telemetry.
    pub probe_max_bytes: usize,
    /// Starvation bound: how many probe batches may be taken while a
    /// scan-class request waits before the scan is forced.  0 = scans
    /// are never bypassed; `u64::MAX` = pure (starvable) priority.
    pub age_limit: u64,
    /// Re-run the §4.1 profiling step after this many served requests;
    /// 0 disables periodic re-calibration.
    pub recalibrate_every: u64,
    /// Run the profiling step before accepting requests, so the very
    /// first dispatch already uses measured thresholds.
    pub calibrate_on_start: bool,
    /// Timed runs per profiling step (median taken, §4.1).
    pub profile_runs: usize,
    /// Symbols per timed profiling run.
    pub profile_sample_syms: usize,
    /// Also measure a per-worker capacity vector at each calibration
    /// (one rate per `policy.processors` worker thread, timed
    /// concurrently) and feed its Eq. (1) weights into
    /// [`ExecPolicy::weights`] for every compiled matcher.
    pub profile_per_worker: bool,
    /// Coalesce different-pattern requests over one identical input into
    /// a single fused pattern-set pass
    /// ([`super::patternset::CompiledSetMatcher`]).
    pub fuse_cross_pattern: bool,
    /// Product-state budget for the fused pass; overflowing patterns
    /// spill to per-pattern matching (0 = unlimited).
    pub fuse_state_budget: usize,
    /// Serve scan-class requests preemptibly through the streaming
    /// wrapper ([`super::stream::StreamMatcher`]): at every
    /// `preempt_segment_bytes` boundary, a scan parks itself (checkpoint
    /// serialized onto the request, request re-queued at scan priority)
    /// whenever a probe-class request is waiting.  Only meaningful under
    /// [`PriorityPolicy::SizeAware`]; off by default.
    pub preempt_scans: bool,
    /// Segment size (bytes) a preemptible scan is fed between park
    /// checks; clamped to at least 1.
    pub preempt_segment_bytes: usize,
    /// Optional multi-process cluster: requests of at least
    /// `cluster_min_bytes` (that are not parked scans) are served by
    /// [`ProcCluster::match_bytes`] instead of an in-process matcher.
    /// The cluster's degradation ladder still produces the sequential
    /// verdict under any worker failure, so routing cannot change
    /// results.
    pub cluster: Option<Arc<ProcCluster>>,
    /// Smallest input (bytes) routed to `cluster` when one is attached.
    pub cluster_min_bytes: usize,
    /// What admission does with patterns the static ReDoS lint flags
    /// ([`crate::analysis::regex::lint_pattern`]); see [`HazardPolicy`].
    pub hazard_policy: HazardPolicy,
    /// Engine every request is served with (normally `Engine::Auto`).
    pub engine: Engine,
    /// Execution policy template; its `thresholds` field is replaced by
    /// the live calibrated thresholds at each compile.
    pub policy: ExecPolicy,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            workers: 4,
            cache_patterns: 64,
            cache_outcomes: 256,
            cache_outcome_max_bytes: 1 << 16,
            max_batch: 64,
            max_queue: 0,
            admission: Admission::Block,
            priority: PriorityPolicy::SizeAware,
            probe_max_bytes: 1 << 16,
            age_limit: 4,
            recalibrate_every: 4096,
            calibrate_on_start: true,
            profile_runs: 5,
            profile_sample_syms: 1 << 18,
            profile_per_worker: true,
            fuse_cross_pattern: true,
            fuse_state_budget: DEFAULT_STATE_BUDGET,
            preempt_scans: false,
            preempt_segment_bytes: 1 << 20,
            cluster: None,
            cluster_min_bytes: 1 << 20,
            hazard_policy: HazardPolicy::Warn,
            engine: Engine::Auto,
            policy: ExecPolicy::default(),
        }
    }
}

/// A request failure delivered through a [`Ticket`].  Cloneable so one
/// compile failure can be streamed to every request of a coalesced batch.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The queue was at [`ServeConfig::max_queue`] under
    /// [`Admission::Reject`]; the request was never admitted.
    Overloaded {
        /// queue depth observed at the admission decision
        depth: usize,
        /// the configured bound the depth had reached
        max_queue: usize,
    },
    /// The server had begun shutting down (or already shut down) when
    /// the request was submitted or while it waited; it was not served.
    ShuttingDown,
    /// Compiling or running the request failed.
    Failed {
        /// human-readable failure description (the full error chain)
        message: String,
    },
    /// The pattern was refused at admission under
    /// [`HazardPolicy::Reject`]: the static analyzer flagged it as
    /// ReDoS-hazardous.  Nothing was queued or compiled.
    Hazard {
        /// the hazards found, `kind (severity)` comma-joined
        detail: String,
    },
}

impl ServeError {
    fn failed(message: impl Into<String>) -> ServeError {
        ServeError::Failed { message: message.into() }
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Overloaded { depth, max_queue } => write!(
                f,
                "server overloaded: {depth} queued at max_queue \
                 {max_queue} (Reject admission)"
            ),
            ServeError::ShuttingDown => f.write_str(
                "server is shutting down; the request was not served",
            ),
            ServeError::Failed { message } => f.write_str(message),
            ServeError::Hazard { detail } => write!(
                f,
                "pattern refused at admission (hazard policy reject): \
                 {detail}"
            ),
        }
    }
}

impl std::error::Error for ServeError {}

/// The per-request result stream: one [`Outcome`] (or error) per submit.
pub type ServeResult = Result<Outcome, ServeError>;

/// Handle to one submitted request.  Dropping it discards the result;
/// the server keeps running.
pub struct Ticket {
    rx: Receiver<ServeResult>,
}

impl Ticket {
    /// Block until this request's outcome is streamed back.
    pub fn wait(self) -> ServeResult {
        match self.rx.recv() {
            Ok(res) => res,
            Err(_) => Err(ServeError::ShuttingDown),
        }
    }

    /// Like [`Ticket::wait`], but give up after `timeout` — the
    /// deadline-aware client shape.  Returns the ticket back on timeout
    /// so the caller can keep waiting (or drop it to abandon the
    /// result).
    pub fn wait_timeout(
        self,
        timeout: Duration,
    ) -> std::result::Result<ServeResult, Ticket> {
        match self.rx.recv_timeout(timeout) {
            Ok(res) => Ok(res),
            Err(RecvTimeoutError::Timeout) => Err(self),
            Err(RecvTimeoutError::Disconnected) => {
                Ok(Err(ServeError::ShuttingDown))
            }
        }
    }
}

/// Queue-wait telemetry for one request class (probe or scan): time
/// between admission and a worker taking the request.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WaitStats {
    /// Requests of this class taken by a worker.
    pub taken: u64,
    /// Total queue wait across those requests, microseconds.
    pub total_us: u64,
    /// Largest single queue wait observed, microseconds.
    pub max_us: u64,
}

impl WaitStats {
    /// Mean queue wait in microseconds (0.0 before any take).
    pub fn mean_us(&self) -> f64 {
        if self.taken == 0 {
            0.0
        } else {
            self.total_us as f64 / self.taken as f64
        }
    }
}

/// Aggregate serving telemetry (monotonic counters since startup).
#[derive(Clone, Debug)]
pub struct ServeStats {
    /// Requests accepted into the queue (admission refusals are counted
    /// in `rejected` instead, never here).
    pub submitted: u64,
    /// Requests served with an `Ok` outcome.
    pub served: u64,
    /// Requests that streamed an error back after being admitted.
    pub failed: u64,
    /// Requests refused at admission: `Overloaded` rejects,
    /// submit-after-shutdown refusals, and [`HazardPolicy::Reject`]
    /// hazard refusals (the latter also counted in `hazards_rejected`).
    pub rejected: u64,
    /// Submitted patterns the static ReDoS lint flagged as hazardous
    /// (counted under both [`HazardPolicy::Warn`] and
    /// [`HazardPolicy::Reject`]; once per *request*, not per pattern).
    pub hazards_flagged: u64,
    /// Requests refused with [`ServeError::Hazard`] under
    /// [`HazardPolicy::Reject`]; a subset of `rejected`.
    pub hazards_rejected: u64,
    /// Coalesced batches executed.
    pub batches: u64,
    /// Requests that rode along in a batch after the first (coalescing
    /// wins: each saved a queue wake-up and a cache lookup).
    pub coalesced: u64,
    /// Pattern compilations performed (cache misses + stale recompiles).
    pub compiles: u64,
    /// Batches served from an already-compiled cache entry.
    pub cache_hits: u64,
    /// Requests answered straight from the outcome memo cache (the
    /// matching loop never ran).
    pub outcome_hits: u64,
    /// Fused product-DFA passes executed for cross-pattern coalesced
    /// groups (each replaced k per-pattern traversals with one).
    pub fused_passes: u64,
    /// Unique patterns answered by fused product passes, summed across
    /// groups (the k's behind `fused_passes`).
    pub patterns_fused: u64,
    /// Unique patterns rejected by the Aho–Corasick literal prefilter
    /// during cross-pattern groups (no DFA ran for them at all).
    pub prefilter_clears: u64,
    /// Fused groups answered by an already-compiled set matcher from
    /// the set-level LRU (each hit skipped a product-DFA construction).
    pub set_cache_hits: u64,
    /// Requests handed to the attached [`ServeConfig::cluster`]
    /// (0 when no cluster is configured).
    pub cluster_routed: u64,
    /// Scan-class requests parked mid-input because a probe was waiting
    /// (the checkpoint re-queued; counted once per park, so one scan can
    /// contribute many).
    pub preemptions: u64,
    /// Parked scans picked back up from their serialized checkpoint
    /// (possibly by a different worker).
    pub resumed_scans: u64,
    /// LRU evictions.
    pub evictions: u64,
    /// Profiling runs performed (startup calibration included).
    pub recalibrations: u64,
    /// Patterns currently resident in the cache.
    pub cached_patterns: usize,
    /// Outcomes currently resident in the memo cache.
    pub cached_outcomes: usize,
    /// Requests currently queued, not yet taken by a worker.
    pub queue_depth: usize,
    /// High-water mark of `queue_depth` since startup; never exceeds
    /// [`ServeConfig::max_queue`] when a bound is configured.
    pub max_queue_depth: usize,
    /// Serving passes (probe batches, plus fused same-input drains
    /// riding behind them) executed while a scan-class request was
    /// waiting — the total aging pressure since startup.
    pub scan_bypasses: u64,
    /// Highest consecutive-bypass count any waiting scan experienced
    /// before a worker was forced to serve the scan class: the
    /// *measured* PR 5 starvation bound.  Never exceeds
    /// [`ServeConfig::age_limit`] under size-aware scheduling alone;
    /// a fused cross-pattern drain riding behind the final bypassing
    /// probe batch can add one more (see
    /// [`ServeConfig::fuse_cross_pattern`]), so `age_limit + 1` is the
    /// ceiling with fusion enabled.
    pub max_bypass_streak: u64,
    /// Queue-wait telemetry for probe-class requests.
    pub probe_wait: WaitStats,
    /// Queue-wait telemetry for scan-class requests.
    pub scan_wait: WaitStats,
    /// The thresholds `Engine::Auto` dispatch currently uses.
    pub thresholds: AutoThresholds,
    /// The measured per-worker capacity vector (symbols/µs) the current
    /// Eq. (1) weights derive from; `None` until the first per-worker
    /// calibration (or when [`ServeConfig::profile_per_worker`] is off).
    pub worker_rates: Option<Vec<f64>>,
}

impl ServeStats {
    /// Mean requests per executed batch (1.0 = no coalescing happened).
    pub fn requests_per_batch(&self) -> f64 {
        let done = self.served + self.failed;
        done as f64 / self.batches.max(1) as f64
    }
}

struct Request {
    pattern: Pattern,
    input: Vec<u8>,
    reply: Sender<ServeResult>,
    /// Serialized [`Checkpoint`] of a preempted scan: progress already
    /// made over `input`.  `Some` only while a parked scan waits to be
    /// resumed; such a request never rides a fused group.
    ckpt: Option<Vec<u8>>,
}

/// One admitted request with its scheduling metadata.
struct Queued {
    /// admission sequence number (per-queue, monotonic)
    seq: u64,
    /// size class ([`CLASS_PROBE`] / [`CLASS_SCAN`]) for wait telemetry
    class: usize,
    /// when admission pushed the request (queue-wait telemetry)
    enqueued: Instant,
    req: Request,
}

/// Per-pattern sub-queues: one FIFO lane per scheduling class, each in
/// admission order.  A worker's take drains one lane, so coalescing no
/// longer scans the whole queue.
#[derive(Default)]
struct Lane {
    by_class: [VecDeque<Queued>; CLASSES],
}

/// The request queue: a per-pattern sub-queue index plus per-class
/// arrival lists.
///
/// `arrivals[class]` records `(seq, pattern)` in admission order.  An
/// entry whose request already rode an earlier coalesced batch is
/// *stale* and skipped when popped (detected in O(1): the lane head's
/// seq no longer matches).  Every entry is pushed once and popped once,
/// so a take is O(batch) amortized — the ROADMAP's "per-pattern
/// sub-queue index" item.
struct ReqQueue {
    lanes: HashMap<Pattern, Lane>,
    arrivals: [VecDeque<(u64, Pattern)>; CLASSES],
    /// live (not yet taken) requests per scheduling class
    live: [usize; CLASSES],
    /// live requests total — the admission depth
    len: usize,
    /// high-water mark of `len`
    max_depth: usize,
    next_seq: u64,
    /// probe batches taken while a scan-class request waited (aging)
    bypassed: u64,
    /// total bypass increments since startup (telemetry)
    bypass_total: u64,
    /// high-water mark of `bypassed`: the measured starvation bound
    max_streak: u64,
}

impl ReqQueue {
    fn new() -> ReqQueue {
        ReqQueue {
            lanes: HashMap::new(),
            arrivals: [VecDeque::new(), VecDeque::new()],
            live: [0; CLASSES],
            len: 0,
            max_depth: 0,
            next_seq: 0,
            bypassed: 0,
            bypass_total: 0,
            max_streak: 0,
        }
    }

    /// One more serving pass went ahead of a waiting scan: bump the
    /// aging counter and the telemetry that makes the bound observable.
    fn note_bypass(&mut self) {
        self.bypassed += 1;
        self.bypass_total += 1;
        self.max_streak = self.max_streak.max(self.bypassed);
    }

    /// Admit one request into class `sched` (its telemetry size class is
    /// `class`; the two differ only under [`PriorityPolicy::Fifo`]).
    fn push(&mut self, req: Request, class: usize, sched: usize) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.arrivals[sched].push_back((seq, req.pattern.clone()));
        self.lanes
            .entry(req.pattern.clone())
            .or_default()
            .by_class[sched]
            .push_back(Queued {
                seq,
                class,
                enqueued: Instant::now(),
                req,
            });
        self.live[sched] += 1;
        self.len += 1;
        self.max_depth = self.max_depth.max(self.len);
    }

    /// Which class the next batch comes from: probes first, but a
    /// waiting scan is bypassed at most `age_limit` times.  `None` when
    /// the queue is empty.
    fn pick_class(&mut self, age_limit: u64) -> Option<usize> {
        if self.live[CLASS_SCAN] == 0 {
            // nothing is waiting to age
            self.bypassed = 0;
        }
        match (self.live[CLASS_PROBE] > 0, self.live[CLASS_SCAN] > 0) {
            (false, false) => None,
            (true, false) => Some(CLASS_PROBE),
            (false, true) => {
                self.bypassed = 0;
                Some(CLASS_SCAN)
            }
            (true, true) => {
                if self.bypassed >= age_limit {
                    self.bypassed = 0;
                    Some(CLASS_SCAN)
                } else {
                    self.note_bypass();
                    Some(CLASS_PROBE)
                }
            }
        }
    }

    /// Take the next coalesced batch: the oldest live request of the
    /// scheduled class plus up to `max_batch - 1` same-pattern
    /// same-class followers, in admission order.
    fn take_batch(
        &mut self,
        age_limit: u64,
        max_batch: usize,
    ) -> Option<Vec<Queued>> {
        loop {
            let class = self.pick_class(age_limit)?;
            let batch = self.take(class, max_batch);
            if !batch.is_empty() {
                return Some(batch);
            }
            // the live counter for `class` was stale (take zeroed it);
            // re-pick from what actually remains
        }
    }

    /// Remove up to `max` live requests whose input equals `input` —
    /// any pattern, any class, oldest first — for cross-pattern fused
    /// serving.  Returned in admission order.  Arrival-list entries of
    /// drained requests go stale and are skipped by [`ReqQueue::take`]'s
    /// head-seq check, exactly like entries that rode an earlier
    /// coalesced batch.  Parked scans (`ckpt.is_some()`) never ride: a
    /// fused product pass cannot resume from a checkpoint.
    ///
    /// A non-empty drain is an extra serving pass executed ahead of any
    /// still-waiting scan, so it **counts against the aging bound**
    /// exactly like the probe batch it rides behind — without this
    /// credit, a probe flood whose inputs coalesce cross-pattern would
    /// serve two passes per `bypassed` increment and stretch the PR 5
    /// starvation bound to `2 × age_limit`.
    fn drain_same_input(&mut self, input: &[u8], max: usize) -> Vec<Queued> {
        if max == 0 || self.len == 0 {
            return Vec::new();
        }
        // pass 1: the admission seqs of the oldest `max` matches (lane
        // hash order must not decide who rides the fused pass)
        let mut seqs: Vec<u64> = self
            .lanes
            .values()
            .flat_map(|lane| lane.by_class.iter())
            .flatten()
            .filter(|item| {
                item.req.ckpt.is_none()
                    && item.req.input.as_slice() == input
            })
            .map(|item| item.seq)
            .collect();
        if seqs.is_empty() {
            return Vec::new();
        }
        seqs.sort_unstable();
        seqs.truncate(max);
        let Some(&cutoff) = seqs.last() else {
            return Vec::new();
        };
        // pass 2: remove exactly those requests
        let mut taken: Vec<Queued> = Vec::new();
        let mut emptied: Vec<Pattern> = Vec::new();
        for (pattern, lane) in self.lanes.iter_mut() {
            for class in 0..CLASSES {
                let sub = &mut lane.by_class[class];
                if sub.is_empty() {
                    continue;
                }
                let mut kept = VecDeque::with_capacity(sub.len());
                while let Some(item) = sub.pop_front() {
                    if item.seq <= cutoff
                        && item.req.ckpt.is_none()
                        && item.req.input.as_slice() == input
                    {
                        self.live[class] = self.live[class].saturating_sub(1);
                        self.len = self.len.saturating_sub(1);
                        taken.push(item);
                    } else {
                        kept.push_back(item);
                    }
                }
                *sub = kept;
            }
            if lane.by_class.iter().all(|d| d.is_empty()) {
                emptied.push(pattern.clone());
            }
        }
        for p in emptied {
            self.lanes.remove(&p);
        }
        if !taken.is_empty() && self.live[CLASS_SCAN] > 0 {
            self.note_bypass();
        }
        taken.sort_by_key(|t| t.seq);
        taken
    }

    fn take(&mut self, class: usize, max_batch: usize) -> Vec<Queued> {
        while let Some((seq, pattern)) = self.arrivals[class].pop_front() {
            let (batch, lane_empty) = {
                let Some(lane) = self.lanes.get_mut(&pattern) else {
                    continue; // stale: the whole lane was drained
                };
                let sub = &mut lane.by_class[class];
                if sub.front().is_none_or(|head| head.seq != seq) {
                    // stale: this request rode an earlier batch
                    continue;
                }
                let n = sub.len().min(max_batch);
                let batch: Vec<Queued> = sub.drain(..n).collect();
                (batch, lane.by_class.iter().all(|d| d.is_empty()))
            };
            if lane_empty {
                self.lanes.remove(&pattern);
            }
            self.len = self.len.saturating_sub(batch.len());
            self.live[class] =
                self.live[class].saturating_sub(batch.len());
            return batch;
        }
        // no live entry found: the counter was stale, repair it
        self.live[class] = 0;
        Vec::new()
    }
}

struct CacheEntry {
    pattern: Pattern,
    /// calibration epoch the matcher was compiled under; stale entries
    /// are recompiled so Auto routing uses the fresh thresholds
    epoch: u64,
    matcher: Arc<CompiledMatcher>,
    last_used: u64,
}

/// Tiny LRU keyed by `Pattern` equality.  Linear scan: serving caches
/// hold tens-to-hundreds of patterns, where a scan beats hashing the
/// whole pattern string per lookup.  `inflight` marks patterns some
/// worker is currently compiling *outside* this cache's mutex.
struct PatternCache {
    entries: Vec<CacheEntry>,
    inflight: Vec<Pattern>,
    tick: u64,
}

/// One memoized `(pattern, input) -> Outcome` result.  The input bytes
/// are retained so a hit requires exact equality — the hash only
/// pre-filters (FNV-1a is non-cryptographic; a collision must not
/// return another request's outcome).
struct OutcomeEntry {
    pattern: Pattern,
    input: Vec<u8>,
    input_hash: u64,
    /// calibration epoch the outcome was produced under; stale entries
    /// never hit (routing may differ after re-calibration)
    epoch: u64,
    outcome: Outcome,
    last_used: u64,
}

impl OutcomeEntry {
    /// The memo key predicate: epoch + hash pre-filter, then exact
    /// input and pattern equality.
    fn matches(&self, epoch: u64, hash: u64, req: &Request) -> bool {
        self.epoch == epoch
            && self.input_hash == hash
            && self.input == req.input
            && self.pattern == req.pattern
    }
}

/// Result-level memo cache, same linear-scan LRU idiom as
/// [`PatternCache`]: the hash comparison rejects almost every non-match
/// before the `Pattern` equality check runs.
struct OutcomeCache {
    entries: Vec<OutcomeEntry>,
    tick: u64,
}

/// One cached fused set matcher, keyed by the distinct-pattern list in
/// first-appearance order (the [`serve_fused_group`] group identity).
struct SetCacheEntry {
    patterns: Vec<Pattern>,
    /// calibration epoch the set was compiled under; stale entries are
    /// recompiled so fused routing uses the fresh thresholds
    epoch: u64,
    matcher: Arc<CompiledSetMatcher>,
    last_used: u64,
}

/// Set-matcher LRU, the fused-group analog of [`PatternCache`]: a
/// recurring cross-pattern group (the same distinct patterns hammering
/// one server) pays the product-DFA construction once.
struct SetCache {
    entries: Vec<SetCacheEntry>,
    tick: u64,
}

struct Counters {
    submitted: AtomicU64,
    served: AtomicU64,
    failed: AtomicU64,
    rejected: AtomicU64,
    hazards_flagged: AtomicU64,
    hazards_rejected: AtomicU64,
    batches: AtomicU64,
    coalesced: AtomicU64,
    compiles: AtomicU64,
    cache_hits: AtomicU64,
    outcome_hits: AtomicU64,
    fused_passes: AtomicU64,
    patterns_fused: AtomicU64,
    prefilter_clears: AtomicU64,
    set_cache_hits: AtomicU64,
    cluster_routed: AtomicU64,
    preemptions: AtomicU64,
    resumed_scans: AtomicU64,
    evictions: AtomicU64,
    recalibrations: AtomicU64,
    wait_taken: [AtomicU64; CLASSES],
    wait_total_us: [AtomicU64; CLASSES],
    wait_max_us: [AtomicU64; CLASSES],
}

impl Counters {
    fn new() -> Counters {
        Counters {
            submitted: AtomicU64::new(0),
            served: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            hazards_flagged: AtomicU64::new(0),
            hazards_rejected: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            compiles: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            outcome_hits: AtomicU64::new(0),
            fused_passes: AtomicU64::new(0),
            patterns_fused: AtomicU64::new(0),
            prefilter_clears: AtomicU64::new(0),
            set_cache_hits: AtomicU64::new(0),
            cluster_routed: AtomicU64::new(0),
            preemptions: AtomicU64::new(0),
            resumed_scans: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            recalibrations: AtomicU64::new(0),
            wait_taken: [AtomicU64::new(0), AtomicU64::new(0)],
            wait_total_us: [AtomicU64::new(0), AtomicU64::new(0)],
            wait_max_us: [AtomicU64::new(0), AtomicU64::new(0)],
        }
    }
}

struct Shared {
    config: ServeConfig,
    queue: Mutex<ReqQueue>,
    ready: Condvar,
    /// signalled when a worker drains queue space, waking producers
    /// parked by `Admission::Block`
    space: Condvar,
    shutdown: AtomicBool,
    /// live dispatch thresholds, replaced by each calibration
    thresholds: Mutex<AutoThresholds>,
    /// live per-worker capacity vector, replaced by each calibration
    /// (None until measured or when profile_per_worker is off)
    capacity: Mutex<Option<profile::CapacityVector>>,
    /// bumped by each calibration; cache entries from older epochs are
    /// recompiled on next use
    epoch: AtomicU64,
    /// requests finished (served + failed), drives periodic re-calibration
    done: AtomicU64,
    cache: Mutex<PatternCache>,
    /// signalled when an in-flight compile finishes, waking workers that
    /// queued behind the same new pattern
    compiled: Condvar,
    outcomes: Mutex<OutcomeCache>,
    set_cache: Mutex<SetCache>,
    counters: Counters,
}

/// The serving loop: worker threads, a bounded priority request queue,
/// pattern cache and capacity calibration behind a submit/stream API.
///
/// ```
/// use specdfa::engine::{Pattern, ServeConfig, Server};
///
/// let server = Server::start(ServeConfig {
///     workers: 2,
///     profile_runs: 1,          // keep the doctest's calibration cheap
///     profile_sample_syms: 4096,
///     ..ServeConfig::default()
/// })?;
/// let hit = server.submit(Pattern::Regex("ab+c".into()), &b"xabbcx"[..]);
/// let miss = server.submit(Pattern::Regex("ab+c".into()), &b"nope"[..]);
/// assert!(hit.wait().unwrap().accepted);
/// assert!(!miss.wait().unwrap().accepted);
/// let stats = server.shutdown();
/// assert_eq!(stats.served, 2);
/// assert!(stats.thresholds.is_calibrated());
/// # anyhow::Result::<()>::Ok(())
/// ```
pub struct Server {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

/// A cloneable submission handle onto a running [`Server`] — hand these
/// to producer threads that outlive (or must not own) the server.  A
/// handle kept past [`Server::shutdown`] stays safe: submissions resolve
/// immediately with [`ServeError::ShuttingDown`] instead of queueing
/// work no worker will ever drain.
///
/// ```
/// use specdfa::engine::{Pattern, ServeConfig, ServeError, Server};
///
/// let server = Server::start(ServeConfig {
///     workers: 1,
///     profile_runs: 1,
///     profile_sample_syms: 4096,
///     ..ServeConfig::default()
/// })?;
/// let handle = server.handle();
/// server.shutdown();
/// let err = handle
///     .submit(Pattern::Regex("ab".into()), &b"ab"[..])
///     .wait()
///     .unwrap_err();
/// assert_eq!(err, ServeError::ShuttingDown);
/// # anyhow::Result::<()>::Ok(())
/// ```
#[derive(Clone)]
pub struct ServerHandle {
    shared: Arc<Shared>,
}

impl ServerHandle {
    /// Queue one request; the returned [`Ticket`] streams its outcome.
    /// See [`Server::submit`].
    pub fn submit(
        &self,
        pattern: Pattern,
        input: impl Into<Vec<u8>>,
    ) -> Ticket {
        do_submit(&self.shared, pattern, input.into())
    }

    /// Queue many same-pattern requests under one queue lock.  See
    /// [`Server::submit_many`].
    pub fn submit_many(
        &self,
        pattern: &Pattern,
        inputs: &[&[u8]],
    ) -> Vec<Ticket> {
        do_submit_many(&self.shared, pattern, inputs)
    }

    /// Snapshot of the serving telemetry.
    pub fn stats(&self) -> ServeStats {
        stats_of(&self.shared)
    }

    /// The thresholds `Engine::Auto` dispatch currently uses.
    pub fn thresholds(&self) -> AutoThresholds {
        self.shared.thresholds.lock().unwrap_or_else(std::sync::PoisonError::into_inner).clone()
    }
}

impl Server {
    /// Start the worker threads (and, by default, run the startup
    /// calibration) and begin accepting requests.
    pub fn start(config: ServeConfig) -> Result<Server> {
        anyhow::ensure!(config.workers >= 1, "serve needs >= 1 worker");
        anyhow::ensure!(
            config.cache_patterns >= 1,
            "serve needs a >= 1 pattern cache"
        );
        anyhow::ensure!(config.max_batch >= 1, "serve needs max_batch >= 1");
        let calibrate = config.calibrate_on_start;
        let workers = config.workers;
        let shared = Arc::new(Shared {
            thresholds: Mutex::new(config.policy.thresholds.clone()),
            capacity: Mutex::new(None),
            queue: Mutex::new(ReqQueue::new()),
            ready: Condvar::new(),
            space: Condvar::new(),
            shutdown: AtomicBool::new(false),
            epoch: AtomicU64::new(0),
            done: AtomicU64::new(0),
            cache: Mutex::new(PatternCache {
                entries: Vec::new(),
                inflight: Vec::new(),
                tick: 0,
            }),
            compiled: Condvar::new(),
            outcomes: Mutex::new(OutcomeCache {
                entries: Vec::new(),
                tick: 0,
            }),
            set_cache: Mutex::new(SetCache {
                entries: Vec::new(),
                tick: 0,
            }),
            counters: Counters::new(),
            config,
        });
        if calibrate {
            recalibrate(&shared);
        }
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let worker_shared = Arc::clone(&shared);
            let spawned = std::thread::Builder::new()
                .name(format!("specdfa-serve-{i}"))
                .spawn(move || worker_loop(&worker_shared));
            match spawned {
                Ok(handle) => handles.push(handle),
                Err(e) => {
                    // unwind: don't leak the already-spawned workers
                    // parked forever on the condvar
                    {
                        let _queue = shared.queue.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
                        shared.shutdown.store(true, Ordering::SeqCst);
                        shared.ready.notify_all();
                    }
                    for handle in handles {
                        let _ = handle.join();
                    }
                    return Err(e.into());
                }
            }
        }
        Ok(Server { shared, workers: handles })
    }

    /// A cloneable [`ServerHandle`] for producer threads.
    pub fn handle(&self) -> ServerHandle {
        ServerHandle { shared: Arc::clone(&self.shared) }
    }

    /// Queue one request; the returned [`Ticket`] streams its outcome.
    ///
    /// When the queue is at [`ServeConfig::max_queue`] this applies the
    /// configured [`Admission`] policy: `Block` parks the caller until a
    /// worker drains space, `Reject` resolves the ticket immediately
    /// with [`ServeError::Overloaded`].  After shutdown has begun the
    /// ticket resolves immediately with [`ServeError::ShuttingDown`].
    pub fn submit(&self, pattern: Pattern, input: impl Into<Vec<u8>>) -> Ticket {
        do_submit(&self.shared, pattern, input.into())
    }

    /// Queue many same-pattern requests under one queue lock, maximizing
    /// the coalescing a single worker can do.  Admission applies per
    /// request, exactly as in [`Server::submit`].
    pub fn submit_many(
        &self,
        pattern: &Pattern,
        inputs: &[&[u8]],
    ) -> Vec<Ticket> {
        do_submit_many(&self.shared, pattern, inputs)
    }

    /// Snapshot of the serving telemetry.
    pub fn stats(&self) -> ServeStats {
        stats_of(&self.shared)
    }

    /// The thresholds `Engine::Auto` dispatch currently uses (calibrated
    /// after startup profiling unless disabled).
    pub fn thresholds(&self) -> AutoThresholds {
        self.shared.thresholds.lock().unwrap_or_else(std::sync::PoisonError::into_inner).clone()
    }

    /// Drain the queue, stop the workers, and return the final stats.
    pub fn shutdown(mut self) -> ServeStats {
        self.finish();
        self.stats()
    }

    fn finish(&mut self) {
        {
            // flag + notify under the queue lock: a worker between its
            // shutdown check and Condvar::wait holds this mutex, so the
            // wakeup can never race into the gap and get lost
            let _queue = self.shared.queue.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            self.shared.shutdown.store(true, Ordering::SeqCst);
            self.shared.ready.notify_all();
            // producers parked by Block admission re-check the shutdown
            // flag and resolve their tickets with ShuttingDown
            self.shared.space.notify_all();
        }
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.finish();
    }
}

/// The static ReDoS gate ([`ServeConfig::hazard_policy`]), evaluated at
/// admission, before the queue lock.  Returns the refusal error under
/// [`HazardPolicy::Reject`]; `None` means admit (clean pattern, policy
/// `Off`/`Warn`, a `Grail` table with no AST to lint, or a pattern that
/// does not even parse — the compile path reports parse errors with
/// full context, so the gate stays out of the way).
fn hazard_gate(shared: &Shared, pattern: &Pattern) -> Option<ServeError> {
    if shared.config.hazard_policy == HazardPolicy::Off
        || matches!(pattern, Pattern::Grail(_))
    {
        return None;
    }
    let report = crate::analysis::regex::lint_pattern(pattern).ok()?;
    if !report.is_hazardous() {
        return None;
    }
    let c = &shared.counters;
    c.hazards_flagged.fetch_add(1, Ordering::SeqCst);
    if shared.config.hazard_policy != HazardPolicy::Reject {
        return None;
    }
    c.hazards_rejected.fetch_add(1, Ordering::SeqCst);
    let detail = report
        .hazards
        .iter()
        .map(|h| format!("{} ({})", h.kind.name(), h.kind.severity()))
        .collect::<Vec<_>>()
        .join(", ");
    Some(ServeError::Hazard { detail })
}

/// The admission + enqueue path shared by [`Server`] and
/// [`ServerHandle`].
fn do_submit(shared: &Shared, pattern: Pattern, input: Vec<u8>) -> Ticket {
    let (tx, rx) = channel();
    let req = Request { pattern, input, reply: tx, ckpt: None };
    if let Some(err) = hazard_gate(shared, &req.pattern) {
        refuse(shared, req, err);
        return Ticket { rx };
    }
    let mut q = shared.queue.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            drop(q);
            refuse(shared, req, ServeError::ShuttingDown);
            return Ticket { rx };
        }
        let max = shared.config.max_queue;
        if max == 0 || q.len < max {
            break;
        }
        match shared.config.admission {
            Admission::Reject => {
                let depth = q.len;
                drop(q);
                refuse(
                    shared,
                    req,
                    ServeError::Overloaded { depth, max_queue: max },
                );
                return Ticket { rx };
            }
            Admission::Block => q = shared
                    .space
                    .wait(q)
                    .unwrap_or_else(std::sync::PoisonError::into_inner),
        }
    }
    enqueue_locked(shared, &mut q, req);
    drop(q);
    shared.ready.notify_one();
    Ticket { rx }
}

fn do_submit_many(
    shared: &Shared,
    pattern: &Pattern,
    inputs: &[&[u8]],
) -> Vec<Ticket> {
    let mut tickets = Vec::with_capacity(inputs.len());
    let mut q = shared.queue.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    'requests: for input in inputs {
        let (tx, rx) = channel();
        tickets.push(Ticket { rx });
        let req = Request {
            pattern: pattern.clone(),
            input: input.to_vec(),
            reply: tx,
            ckpt: None,
        };
        // per request, not once per batch: every refused request must
        // carry its own Hazard error and count in the stats, matching
        // the do_submit path exactly
        if let Some(err) = hazard_gate(shared, &req.pattern) {
            refuse(shared, req, err);
            continue 'requests;
        }
        loop {
            if shared.shutdown.load(Ordering::SeqCst) {
                refuse(shared, req, ServeError::ShuttingDown);
                continue 'requests;
            }
            let max = shared.config.max_queue;
            if max == 0 || q.len < max {
                break;
            }
            match shared.config.admission {
                Admission::Reject => {
                    let depth = q.len;
                    refuse(
                        shared,
                        req,
                        ServeError::Overloaded { depth, max_queue: max },
                    );
                    continue 'requests;
                }
                // waiting releases the queue mutex, so workers drain
                // (and other producers run) while this batch is parked
                Admission::Block => q = shared
                    .space
                    .wait(q)
                    .unwrap_or_else(std::sync::PoisonError::into_inner),
            }
        }
        enqueue_locked(shared, &mut q, req);
        // wake a worker per admitted request: with Block admission the
        // rest of this batch may park, and the workers must be able to
        // drain what is already queued meanwhile
        shared.ready.notify_one();
    }
    drop(q);
    tickets
}

/// Resolve a refused request's ticket immediately (admission reject or
/// submit-after-shutdown) — the request is never queued.
fn refuse(shared: &Shared, req: Request, err: ServeError) {
    shared.counters.rejected.fetch_add(1, Ordering::SeqCst);
    // a dropped Ticket just discards its result
    let _ = req.reply.send(Err(err));
}

/// Classify + push one admitted request.  Runs under the queue lock so
/// a `stats()` snapshot that has seen this request `served` has also
/// seen it `submitted` (the increment happens-before the worker's take
/// through this mutex; `SeqCst` orders it against the snapshot loads).
fn enqueue_locked(shared: &Shared, q: &mut ReqQueue, req: Request) {
    let class = if req.input.len() <= shared.config.probe_max_bytes {
        CLASS_PROBE
    } else {
        CLASS_SCAN
    };
    let sched = match shared.config.priority {
        PriorityPolicy::Fifo => CLASS_PROBE,
        PriorityPolicy::SizeAware => class,
    };
    q.push(req, class, sched);
    shared.counters.submitted.fetch_add(1, Ordering::SeqCst);
}

fn stats_of(shared: &Shared) -> ServeStats {
    // one lock at a time: a snapshot must never stall the workers
    let cached_patterns = shared.cache.lock().unwrap_or_else(std::sync::PoisonError::into_inner).entries.len();
    let cached_outcomes = shared.outcomes.lock().unwrap_or_else(std::sync::PoisonError::into_inner).entries.len();
    let (queue_depth, max_queue_depth, scan_bypasses, max_bypass_streak) = {
        let q = shared.queue.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        (q.len, q.max_depth, q.bypass_total, q.max_streak)
    };
    let thresholds = shared.thresholds.lock().unwrap_or_else(std::sync::PoisonError::into_inner).clone();
    let worker_rates = shared
        .capacity
        .lock()
        .unwrap_or_else(std::sync::PoisonError::into_inner)
        .as_ref()
        .map(|cv| cv.rates.clone());
    let c = &shared.counters;
    let wait = |class: usize| WaitStats {
        taken: c.wait_taken[class].load(Ordering::Relaxed),
        total_us: c.wait_total_us[class].load(Ordering::Relaxed),
        max_us: c.wait_max_us[class].load(Ordering::Relaxed),
    };
    // completion counters are loaded BEFORE `submitted`: `submitted`
    // only grows, and each request's submit increment is SeqCst-ordered
    // before its serve/fail increment, so no snapshot can ever show
    // served + failed > submitted
    let served = c.served.load(Ordering::SeqCst);
    let failed = c.failed.load(Ordering::SeqCst);
    let rejected = c.rejected.load(Ordering::SeqCst);
    let submitted = c.submitted.load(Ordering::SeqCst);
    ServeStats {
        submitted,
        served,
        failed,
        rejected,
        hazards_flagged: c.hazards_flagged.load(Ordering::SeqCst),
        hazards_rejected: c.hazards_rejected.load(Ordering::SeqCst),
        batches: c.batches.load(Ordering::Relaxed),
        coalesced: c.coalesced.load(Ordering::Relaxed),
        compiles: c.compiles.load(Ordering::Relaxed),
        cache_hits: c.cache_hits.load(Ordering::Relaxed),
        outcome_hits: c.outcome_hits.load(Ordering::Relaxed),
        fused_passes: c.fused_passes.load(Ordering::Relaxed),
        patterns_fused: c.patterns_fused.load(Ordering::Relaxed),
        prefilter_clears: c.prefilter_clears.load(Ordering::Relaxed),
        set_cache_hits: c.set_cache_hits.load(Ordering::Relaxed),
        cluster_routed: c.cluster_routed.load(Ordering::Relaxed),
        preemptions: c.preemptions.load(Ordering::Relaxed),
        resumed_scans: c.resumed_scans.load(Ordering::Relaxed),
        evictions: c.evictions.load(Ordering::Relaxed),
        recalibrations: c.recalibrations.load(Ordering::Relaxed),
        cached_patterns,
        cached_outcomes,
        queue_depth,
        max_queue_depth,
        scan_bypasses,
        max_bypass_streak,
        probe_wait: wait(CLASS_PROBE),
        scan_wait: wait(CLASS_SCAN),
        thresholds,
        worker_rates,
    }
}

/// Worker: take a coalesced batch, serve it, repeat until shutdown with
/// an empty queue (shutdown drains — queued work is never dropped).
/// When the take picked up a cross-pattern same-input group, the group
/// runs through one fused pattern-set pass and the rest of the batch is
/// served normally.
fn worker_loop(shared: &Shared) {
    while let Some((batch, fused)) = next_batch(shared) {
        if !batch.is_empty() {
            serve_batch(shared, batch);
        }
        if !fused.is_empty() {
            serve_fused_group(shared, fused);
        }
    }
}

/// Take the next unit of work: `(same_pattern_batch, same_input_group)`.
/// The group is non-empty only when cross-pattern fusion found other
/// queued requests over the batch head's exact input; it then contains
/// every taken request with that input (whatever its pattern) and the
/// batch keeps the rest.
fn next_batch(shared: &Shared) -> Option<(Vec<Request>, Vec<Request>)> {
    let mut q = shared.queue.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    loop {
        if let Some(taken) =
            q.take_batch(shared.config.age_limit, shared.config.max_batch)
        {
            // cross-pattern coalescing: drain other queued requests over
            // this exact input so one fused pass can answer all of them
            let extras = if shared.config.fuse_cross_pattern && q.len > 0 {
                q.drain_same_input(
                    &taken[0].req.input,
                    shared.config.max_batch,
                )
            } else {
                Vec::new()
            };
            drop(q);
            // queue space freed: wake producers parked by Block admission
            shared.space.notify_all();
            let now = Instant::now();
            let same_input: Vec<bool> = taken
                .iter()
                .map(|t| t.req.input == taken[0].req.input)
                .collect();
            let mut batch = Vec::new();
            let mut group = Vec::new();
            for (item, same) in taken.into_iter().zip(same_input) {
                record_wait(
                    shared,
                    item.class,
                    now.saturating_duration_since(item.enqueued),
                );
                // a parked scan stays on the per-pattern path: a fused
                // product pass cannot resume its checkpoint
                if !extras.is_empty() && same && item.req.ckpt.is_none() {
                    group.push(item.req);
                } else {
                    batch.push(item.req);
                }
            }
            for item in extras {
                record_wait(
                    shared,
                    item.class,
                    now.saturating_duration_since(item.enqueued),
                );
                group.push(item.req);
            }
            return Some((batch, group));
        }
        if shared.shutdown.load(Ordering::SeqCst) {
            return None;
        }
        q = shared
            .ready
            .wait(q)
            .unwrap_or_else(std::sync::PoisonError::into_inner);
    }
}

/// Fold one request's queue wait into the per-class telemetry.
fn record_wait(shared: &Shared, class: usize, waited: Duration) {
    let us = u64::try_from(waited.as_micros()).unwrap_or(u64::MAX);
    let c = &shared.counters;
    c.wait_taken[class].fetch_add(1, Ordering::Relaxed);
    c.wait_total_us[class].fetch_add(us, Ordering::Relaxed);
    c.wait_max_us[class].fetch_max(us, Ordering::Relaxed);
}

fn serve_batch(shared: &Shared, batch: Vec<Request>) {
    let c = &shared.counters;
    c.batches.fetch_add(1, Ordering::Relaxed);
    c.coalesced.fetch_add((batch.len() - 1) as u64, Ordering::Relaxed);
    let misses = memo_prepass(shared, batch);
    if misses.is_empty() {
        return;
    }
    serve_same_pattern(shared, misses);
}

/// Memo pre-pass shared by the same-pattern and fused paths: hits answer
/// without touching the pattern cache, so a memoized probe never pays a
/// recompile after pattern eviction.  Returns the misses with their
/// memo hashes (computed once per request and reused downstream).
fn memo_prepass(
    shared: &Shared,
    batch: Vec<Request>,
) -> Vec<(Request, Option<u64>)> {
    let c = &shared.counters;
    let mut misses: Vec<(Request, Option<u64>)> =
        Vec::with_capacity(batch.len());
    for req in batch {
        let hash = memo_hash(shared, &req);
        match hash.and_then(|h| cached_outcome(shared, &req, h)) {
            Some(out) => {
                c.served.fetch_add(1, Ordering::SeqCst);
                // a dropped Ticket just discards its result
                let _ = req.reply.send(Ok(out));
                finish_request(shared);
            }
            None => misses.push((req, hash)),
        }
    }
    misses
}

/// Serve a non-empty list of same-pattern memo misses through one
/// compiled matcher (the original coalesced-batch path).
fn serve_same_pattern(shared: &Shared, misses: Vec<(Request, Option<u64>)>) {
    let c = &shared.counters;
    // lock-free duplicate detection: a memo re-check under the outcomes
    // mutex is only worth it when an *earlier miss in this batch* will
    // have memoized the identical request by the time we reach this one
    let dup_of_earlier: Vec<bool> = misses
        .iter()
        .enumerate()
        .map(|(i, (req, hash))| {
            hash.is_some()
                && misses[..i].iter().any(|(prev, prev_hash)| {
                    prev_hash == hash && prev.input == req.input
                })
        })
        .collect();
    match matcher_for(shared, &misses[0].0.pattern) {
        Ok(cm) => {
            for ((req, hash), dup) in misses.into_iter().zip(dup_of_earlier)
            {
                let memo = if dup {
                    hash.and_then(|h| cached_outcome(shared, &req, h))
                } else {
                    None
                };
                if memo.is_none() {
                    if let Some(res) = serve_via_cluster(shared, &req) {
                        match &res {
                            Ok(_) => c.served.fetch_add(1, Ordering::SeqCst),
                            Err(_) => c.failed.fetch_add(1, Ordering::SeqCst),
                        };
                        let _ = req.reply.send(res);
                        finish_request(shared);
                        continue;
                    }
                    if preemptible(shared, &req) {
                        serve_preemptible(shared, &cm, req);
                        continue;
                    }
                }
                let res = match memo {
                    Some(out) => Ok(out),
                    None => {
                        // capture the epoch BEFORE matching: if a
                        // re-calibration lands mid-run, the stale-epoch
                        // insert below can never hit (preserving the
                        // purge-on-recalibrate invariant)
                        let epoch = shared.epoch.load(Ordering::SeqCst);
                        let res = cm
                            .run_bytes(&req.input)
                            .map_err(|e| ServeError::failed(format!("{e:#}")));
                        if let (Ok(out), Some(h)) = (&res, hash) {
                            remember_outcome(shared, &req, h, epoch, out);
                        }
                        res
                    }
                };
                match &res {
                    Ok(_) => c.served.fetch_add(1, Ordering::SeqCst),
                    Err(_) => c.failed.fetch_add(1, Ordering::SeqCst),
                };
                let _ = req.reply.send(res);
                finish_request(shared);
            }
        }
        Err(e) => {
            for (req, _) in misses {
                c.failed.fetch_add(1, Ordering::SeqCst);
                let _ = req.reply.send(Err(e.clone()));
                finish_request(shared);
            }
        }
    }
}

/// Route one request to the attached process cluster, when configured
/// and the input is large enough.  `None` means "serve locally"; parked
/// scans always stay local (their checkpoint belongs to the in-process
/// stream).  The cluster's own degradation ladder guarantees the
/// verdict matches `Engine::Sequential` even with every worker dead, so
/// this routing decision can never change a result.
fn serve_via_cluster(shared: &Shared, req: &Request) -> Option<ServeResult> {
    if req.ckpt.is_some() {
        return None;
    }
    let cluster = shared.config.cluster.as_ref()?;
    if req.input.len() < shared.config.cluster_min_bytes {
        return None;
    }
    shared.counters.cluster_routed.fetch_add(1, Ordering::Relaxed);
    Some(
        cluster
            .match_bytes(&req.pattern, &req.input)
            .map_err(|e| ServeError::failed(format!("{e:#}"))),
    )
}

/// Whether a request takes the preemptible streaming path: a scan-class
/// input (or a parked scan carrying a checkpoint) under size-aware
/// priority with [`ServeConfig::preempt_scans`] on.
fn preemptible(shared: &Shared, req: &Request) -> bool {
    shared.config.preempt_scans
        && shared.config.priority == PriorityPolicy::SizeAware
        && (req.ckpt.is_some()
            || req.input.len() > shared.config.probe_max_bytes)
}

/// Serve one scan through the streaming wrapper, one
/// [`ServeConfig::preempt_segment_bytes`] segment per park check.  At an
/// interior segment boundary with a probe-class request waiting, the
/// scan *parks*: its [`Checkpoint`] is serialized onto the request and
/// the request re-queued at scan priority, so the probes run now and
/// the aging bound limits how many probe batches pass before some
/// worker — any worker, the checkpoint rides the queue — resumes it.
/// Each service turn makes at least one segment of progress, and
/// shutdown never parks (queued work drains to completion), so a parked
/// scan always terminates.  The re-queue bypasses admission on purpose:
/// the request was admitted once already, and a worker blocking on its
/// own queue's backpressure would deadlock.
fn serve_preemptible(shared: &Shared, cm: &CompiledMatcher, mut req: Request) {
    let c = &shared.counters;
    let mut sm = match req.ckpt.take() {
        Some(bytes) => {
            let resumed = Checkpoint::from_bytes(&bytes)
                .and_then(|ck| StreamMatcher::from_checkpoint(cm, ck));
            match resumed {
                Ok(sm) => {
                    c.resumed_scans.fetch_add(1, Ordering::Relaxed);
                    sm
                }
                Err(e) => {
                    // a checkpoint this server serialized always
                    // round-trips unless the pattern recompiled to a
                    // different DFA mid-flight; surface the failure
                    c.failed.fetch_add(1, Ordering::SeqCst);
                    let _ = req
                        .reply
                        .send(Err(ServeError::failed(format!("{e:#}"))));
                    finish_request(shared);
                    return;
                }
            }
        }
        None => StreamMatcher::new(cm),
    };
    let seg = shared.config.preempt_segment_bytes.max(1);
    let mut pos = usize::try_from(sm.offset()).unwrap_or(req.input.len());
    while pos < req.input.len() {
        let end = req.input.len().min(pos + seg);
        sm.feed(&req.input[pos..end]);
        pos = end;
        // park only at an interior boundary: a finished scan replies
        // below, and shutdown drains scans to completion instead of
        // re-queueing them forever
        if pos >= req.input.len() || shared.shutdown.load(Ordering::SeqCst) {
            continue;
        }
        let mut q = shared.queue.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        if q.live[CLASS_PROBE] > 0 {
            req.ckpt = Some(sm.checkpoint().to_bytes());
            c.preemptions.fetch_add(1, Ordering::Relaxed);
            q.push(req, CLASS_SCAN, CLASS_SCAN);
            drop(q);
            shared.ready.notify_one();
            return;
        }
    }
    let out = sm.finish();
    c.served.fetch_add(1, Ordering::SeqCst);
    let _ = req.reply.send(Ok(out));
    finish_request(shared);
}

/// Serve a cross-pattern same-input group: one fused pattern-set pass
/// answers every distinct pattern's membership query over the shared
/// input (the inverse of same-pattern coalescing).  Falls back to the
/// per-pattern path when fewer than two distinct patterns miss the memo
/// or when the set fails to compile (e.g. one invalid pattern must not
/// fail the others).
fn serve_fused_group(shared: &Shared, group: Vec<Request>) {
    let c = &shared.counters;
    c.batches.fetch_add(1, Ordering::Relaxed);
    c.coalesced.fetch_add((group.len() - 1) as u64, Ordering::Relaxed);
    let misses = memo_prepass(shared, group);
    if misses.is_empty() {
        return;
    }
    // distinct patterns in first-appearance order; duplicate requests of
    // one pattern share its verdict slot
    let mut distinct: Vec<Pattern> = Vec::new();
    for (req, _) in &misses {
        if !distinct.contains(&req.pattern) {
            distinct.push(req.pattern.clone());
        }
    }
    if distinct.len() < 2 {
        serve_same_pattern(shared, misses);
        return;
    }
    let csm = match set_matcher_for(shared, &distinct) {
        Ok(csm) => csm,
        Err(_) => {
            // one bad pattern (or an AST-engine config) must not fail
            // the whole group: serve each pattern's requests through the
            // ordinary cached-matcher path instead
            for (pattern, misses) in by_pattern(misses, &distinct) {
                debug_assert!(!misses.is_empty(), "{pattern:?}");
                serve_same_pattern(shared, misses);
            }
            return;
        }
    };
    // capture the epoch BEFORE matching, same invariant as the
    // per-pattern path: a mid-run re-calibration makes the memo inserts
    // below stale instead of wrong
    let epoch = shared.epoch.load(Ordering::SeqCst);
    match csm.run_bytes(&misses[0].0.input) {
        Ok(setout) => {
            if setout.fused_pass.is_some() {
                c.fused_passes.fetch_add(1, Ordering::Relaxed);
            }
            c.patterns_fused
                .fetch_add(csm.fused_patterns() as u64, Ordering::Relaxed);
            c.prefilter_clears.fetch_add(
                setout.prefilter_cleared as u64,
                Ordering::Relaxed,
            );
            for (req, hash) in misses {
                let Some(slot) =
                    distinct.iter().position(|p| *p == req.pattern)
                else {
                    c.failed.fetch_add(1, Ordering::SeqCst);
                    let _ = req.reply.send(Err(ServeError::failed(
                        "internal: fused group slot missing for pattern",
                    )));
                    finish_request(shared);
                    continue;
                };
                let out = setout.outcomes[slot].clone();
                // memoize only verdicts a matcher actually computed: a
                // prefilter-cleared slot is a synthesized reject
                // (`final_state: None`), and memoizing it would poison
                // later solo hits for this (pattern, input) with the
                // degraded outcome
                let real_verdict =
                    setout.tiers[slot] != SetTier::PrefilterCleared;
                if let (true, Some(h)) = (real_verdict, hash) {
                    remember_outcome(shared, &req, h, epoch, &out);
                }
                c.served.fetch_add(1, Ordering::SeqCst);
                let _ = req.reply.send(Ok(out));
                finish_request(shared);
            }
        }
        Err(e) => {
            let err = ServeError::failed(format!("{e:#}"));
            for (req, _) in misses {
                c.failed.fetch_add(1, Ordering::SeqCst);
                let _ = req.reply.send(Err(err.clone()));
                finish_request(shared);
            }
        }
    }
}

/// Set-matcher lookup / compile for a fused group, the
/// [`matcher_for`] idiom generalized to a distinct-pattern-list key.
/// Hits must be from the current calibration epoch; a stale entry is
/// dropped and recompiled.  Unlike the per-pattern cache there is no
/// in-flight marker: fused groups are far rarer than single patterns,
/// so two workers racing on the same new group at worst compile it
/// twice (the second insert wins the LRU slot) — never a wrong result.
fn set_matcher_for(
    shared: &Shared,
    distinct: &[Pattern],
) -> std::result::Result<Arc<CompiledSetMatcher>, ServeError> {
    let epoch = shared.epoch.load(Ordering::SeqCst);
    {
        let mut cache = shared.set_cache.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        cache.tick += 1;
        let tick = cache.tick;
        if let Some(pos) = cache
            .entries
            .iter()
            .position(|e| e.patterns.as_slice() == distinct)
        {
            if cache.entries[pos].epoch == epoch {
                let entry = &mut cache.entries[pos];
                entry.last_used = tick;
                shared
                    .counters
                    .set_cache_hits
                    .fetch_add(1, Ordering::Relaxed);
                return Ok(Arc::clone(&entry.matcher));
            }
            // compiled under stale thresholds: drop and recompile below
            cache.entries.swap_remove(pos);
        }
    }
    // compile with NO cache lock held (product DFAs can be large)
    let set = PatternSet::from_patterns(distinct.to_vec());
    let set_config = SetConfig {
        engine: shared.config.engine.clone(),
        policy: live_policy(shared),
        state_budget: shared.config.fuse_state_budget,
        prefilter: true,
    };
    let csm = Arc::new(
        CompiledSetMatcher::compile(&set, set_config)
            .map_err(|e| ServeError::failed(format!("{e:#}")))?,
    );
    let mut cache = shared.set_cache.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    cache.tick += 1;
    let tick = cache.tick;
    if cache.entries.len() >= shared.config.cache_patterns {
        if let Some(lru) = cache
            .entries
            .iter()
            .enumerate()
            .min_by_key(|(_, e)| e.last_used)
            .map(|(i, _)| i)
        {
            cache.entries.swap_remove(lru);
            shared.counters.evictions.fetch_add(1, Ordering::Relaxed);
        }
    }
    cache.entries.push(SetCacheEntry {
        patterns: distinct.to_vec(),
        epoch,
        matcher: Arc::clone(&csm),
        last_used: tick,
    });
    Ok(csm)
}

/// Split misses into per-pattern lists, preserving request order within
/// each pattern (the fused path's fallback shape).
fn by_pattern(
    misses: Vec<(Request, Option<u64>)>,
    distinct: &[Pattern],
) -> Vec<(Pattern, Vec<(Request, Option<u64>)>)> {
    let mut split: Vec<(Pattern, Vec<(Request, Option<u64>)>)> =
        distinct.iter().map(|p| (p.clone(), Vec::new())).collect();
    for (req, hash) in misses {
        if let Some((_, list)) =
            split.iter_mut().find(|(p, _)| *p == req.pattern)
        {
            list.push((req, hash));
        }
    }
    split.retain(|(_, list)| !list.is_empty());
    split
}

/// The memo hash for a request, or `None` when the request is not
/// memoizable (memoization off, or the input exceeds the size cap).
fn memo_hash(shared: &Shared, req: &Request) -> Option<u64> {
    if shared.config.cache_outcomes == 0
        || req.input.len() > shared.config.cache_outcome_max_bytes
    {
        return None;
    }
    Some(crate::util::fnv1a(&req.input))
}

/// Outcome memo lookup under the current calibration epoch: the hash
/// pre-filters, the stored input bytes decide (exact equality — a hash
/// collision must never return another request's outcome).  The
/// returned outcome is a clone of the memoized run (its `wall_s` etc.
/// describe the original run).
fn cached_outcome(
    shared: &Shared,
    req: &Request,
    hash: u64,
) -> Option<Outcome> {
    let epoch = shared.epoch.load(Ordering::SeqCst);
    let mut cache = shared.outcomes.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    cache.tick += 1;
    let tick = cache.tick;
    let hit = cache
        .entries
        .iter_mut()
        .find(|e| e.matches(epoch, hash, req))?;
    hit.last_used = tick;
    shared.counters.outcome_hits.fetch_add(1, Ordering::Relaxed);
    Some(hit.outcome.clone())
}

/// Insert a freshly computed outcome into the memo LRU.  `epoch` is the
/// calibration epoch read *before* the match ran — an insert that raced
/// a re-calibration lands stale and can never hit.
fn remember_outcome(
    shared: &Shared,
    req: &Request,
    hash: u64,
    epoch: u64,
    out: &Outcome,
) {
    let cap = shared.config.cache_outcomes;
    let mut cache = shared.outcomes.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    cache.tick += 1;
    let tick = cache.tick;
    if let Some(e) =
        cache.entries.iter_mut().find(|e| e.matches(epoch, hash, req))
    {
        // a concurrent worker memoized the same request first
        e.last_used = tick;
        return;
    }
    if cache.entries.len() >= cap {
        if let Some(lru) = cache
            .entries
            .iter()
            .enumerate()
            .min_by_key(|(_, e)| e.last_used)
            .map(|(i, _)| i)
        {
            cache.entries.swap_remove(lru);
        }
    }
    cache.entries.push(OutcomeEntry {
        pattern: req.pattern.clone(),
        input: req.input.clone(),
        input_hash: hash,
        epoch,
        outcome: out.clone(),
        last_used: tick,
    });
}

/// Removes this worker's in-flight compile marker and wakes the waiters
/// on every exit path — including an unwind out of the compile itself,
/// which would otherwise strand waiters on the condvar forever.
struct InflightGuard<'a> {
    shared: &'a Shared,
    pattern: &'a Pattern,
}

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        let mut cache = match self.shared.cache.lock() {
            Ok(cache) => cache,
            // a poisoned cache just means some holder panicked; the
            // marker still has to go so waiters can make progress
            Err(poisoned) => poisoned.into_inner(),
        };
        if let Some(pos) =
            cache.inflight.iter().position(|p| p == self.pattern)
        {
            cache.inflight.swap_remove(pos);
        }
        drop(cache);
        self.shared.compiled.notify_all();
    }
}

/// Cache lookup / compile.  A miss marks the pattern in-flight and
/// compiles *outside* the cache mutex, so hits (and compiles of other
/// patterns) proceed while the DFA construction runs; workers racing on
/// the same new pattern wait on the condvar instead of duplicating the
/// compile.
fn matcher_for(
    shared: &Shared,
    pattern: &Pattern,
) -> std::result::Result<Arc<CompiledMatcher>, ServeError> {
    let epoch = loop {
        let epoch = shared.epoch.load(Ordering::SeqCst);
        let mut cache = shared.cache.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        cache.tick += 1;
        let tick = cache.tick;
        if let Some(pos) =
            cache.entries.iter().position(|e| &e.pattern == pattern)
        {
            if cache.entries[pos].epoch == epoch {
                let entry = &mut cache.entries[pos];
                entry.last_used = tick;
                shared.counters.cache_hits.fetch_add(1, Ordering::Relaxed);
                return Ok(Arc::clone(&entry.matcher));
            }
            // compiled under stale thresholds: drop and recompile below
            cache.entries.swap_remove(pos);
        }
        if cache.inflight.contains(pattern) {
            // another worker is compiling this exact pattern: wait for
            // its insert (or failure) and re-check.  On failure there is
            // neither entry nor marker, so this worker becomes the
            // compiler, fails the same way, and reports its own error —
            // no retry loop.
            let woken = shared
                .compiled
                .wait(cache)
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            drop(woken);
            continue;
        }
        cache.inflight.push(pattern.clone());
        break epoch;
    };
    // from here the marker is cleaned up on EVERY exit — normal return,
    // compile error, or an unwind out of the compile
    let _inflight = InflightGuard { shared, pattern };
    // compile with NO cache lock held
    let policy = live_policy(shared);
    let compiled =
        CompiledMatcher::compile(pattern, shared.config.engine.clone(), policy)
            .map_err(|e| ServeError::failed(format!("compile failed: {e:#}")));
    let cm = Arc::new(compiled?);
    shared.counters.compiles.fetch_add(1, Ordering::Relaxed);
    let mut cache = shared.cache.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    cache.tick += 1;
    let tick = cache.tick;
    if cache.entries.len() >= shared.config.cache_patterns {
        if let Some(lru) = cache
            .entries
            .iter()
            .enumerate()
            .min_by_key(|(_, e)| e.last_used)
            .map(|(i, _)| i)
        {
            cache.entries.swap_remove(lru);
            shared.counters.evictions.fetch_add(1, Ordering::Relaxed);
        }
    }
    cache.entries.push(CacheEntry {
        pattern: pattern.clone(),
        epoch,
        matcher: Arc::clone(&cm),
        last_used: tick,
    });
    drop(cache);
    Ok(cm)
}

/// The execution-policy template with the *live* calibrated state
/// substituted in: current thresholds, plus measured per-worker Eq. (1)
/// weights (when available) overriding the template's — the multicore
/// and shard partitions then track the machine's real per-worker
/// capacities.  Used for every compile, per-pattern and fused alike.
fn live_policy(shared: &Shared) -> ExecPolicy {
    let weights = shared
        .capacity
        .lock()
        .unwrap_or_else(std::sync::PoisonError::into_inner)
        .as_ref()
        .map(|cv| cv.weights())
        .or_else(|| shared.config.policy.weights.clone());
    ExecPolicy {
        thresholds: shared.thresholds.lock().unwrap_or_else(std::sync::PoisonError::into_inner).clone(),
        weights,
        ..shared.config.policy.clone()
    }
}

fn finish_request(shared: &Shared) {
    let every = shared.config.recalibrate_every;
    let done = shared.done.fetch_add(1, Ordering::SeqCst) + 1;
    // `done` values are unique per request, so exactly one worker crosses
    // each multiple of `every` and triggers the re-calibration
    if every != 0 && done % every == 0 {
        recalibrate(shared);
    }
}

/// The §4.1 offline profiling step, applied live: measure this host's
/// matching capacity (and, unless disabled, the per-worker capacity
/// vector) and install thresholds + Eq. (1) weights derived from them.
fn recalibrate(shared: &Shared) {
    let p = profile::profile_host(
        shared.config.profile_runs,
        shared.config.profile_sample_syms,
    );
    *shared.thresholds.lock().unwrap_or_else(std::sync::PoisonError::into_inner) = AutoThresholds::from_profile(&p);
    if shared.config.profile_per_worker {
        let cv = profile::profile_workers(
            shared.config.policy.processors,
            shared.config.profile_runs,
            shared.config.profile_sample_syms,
        );
        *shared.capacity.lock().unwrap_or_else(std::sync::PoisonError::into_inner) = Some(cv);
    }
    shared.epoch.fetch_add(1, Ordering::SeqCst);
    // every memoized outcome is now stale (routing may differ under the
    // fresh thresholds); purge instead of letting dead entries linger in
    // the scan until LRU pressure displaces them
    shared.outcomes.lock().unwrap_or_else(std::sync::PoisonError::into_inner).entries.clear();
    shared.counters.recalibrations.fetch_add(1, Ordering::Relaxed);
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // unwrap in tests is a test failure
mod tests {
    use super::*;

    fn quick_config() -> ServeConfig {
        ServeConfig {
            workers: 2,
            profile_runs: 1,
            profile_sample_syms: 4096,
            recalibrate_every: 0,
            ..ServeConfig::default()
        }
    }

    #[test]
    fn serves_and_streams_outcomes() {
        let server = Server::start(quick_config()).unwrap();
        let pattern = Pattern::Regex("ab+c".to_string());
        let t1 = server.submit(pattern.clone(), &b"xxabbbcyy"[..]);
        let t2 = server.submit(pattern.clone(), &b"nothing"[..]);
        let t3 = server.submit(pattern, &b""[..]);
        assert!(t1.wait().unwrap().accepted);
        assert!(!t2.wait().unwrap().accepted);
        assert!(!t3.wait().unwrap().accepted);
        let stats = server.shutdown();
        assert_eq!(stats.submitted, 3);
        assert_eq!(stats.served, 3);
        assert_eq!(stats.failed, 0);
        assert_eq!(stats.rejected, 0);
        assert!(stats.compiles >= 1);
        assert!(stats.compiles < 3, "same pattern must not recompile");
        assert!(stats.thresholds.is_calibrated());
        assert_eq!(stats.recalibrations, 1); // the startup profiling
        // every request was probe-sized; all three waits were recorded
        assert_eq!(stats.probe_wait.taken, 3);
        assert_eq!(stats.scan_wait.taken, 0);
        assert!(stats.max_queue_depth >= 1);
    }

    #[test]
    fn bad_pattern_streams_an_error_and_keeps_serving() {
        let server = Server::start(quick_config()).unwrap();
        let bad = server.submit(
            Pattern::Regex("ab[".to_string()),
            &b"whatever"[..],
        );
        let good =
            server.submit(Pattern::Regex("ok".to_string()), &b"ok"[..]);
        let err = bad.wait().expect_err("unterminated class must fail");
        assert!(matches!(err, ServeError::Failed { .. }), "{err:?}");
        assert!(err.to_string().contains("compile failed"), "{err}");
        assert!(good.wait().unwrap().accepted);
        let stats = server.shutdown();
        assert_eq!(stats.failed, 1);
        assert_eq!(stats.served, 1);
    }

    #[test]
    fn per_worker_calibration_feeds_eq1_weights() {
        let server = Server::start(quick_config()).unwrap();
        let t = server.submit(Pattern::Regex("ab".to_string()), &b"ab"[..]);
        assert!(t.wait().unwrap().accepted);
        let stats = server.shutdown();
        let rates = stats
            .worker_rates
            .expect("per-worker profiling is on by default");
        assert_eq!(rates.len(), ServeConfig::default().policy.processors);
        assert!(rates.iter().all(|&r| r > 0.0), "{rates:?}");

        // and it can be disabled
        let server = Server::start(ServeConfig {
            profile_per_worker: false,
            ..quick_config()
        })
        .unwrap();
        let t = server.submit(Pattern::Regex("ab".to_string()), &b"ab"[..]);
        assert!(t.wait().unwrap().accepted);
        assert!(server.shutdown().worker_rates.is_none());
    }

    #[test]
    fn shutdown_drains_queued_requests() {
        let server = Server::start(ServeConfig {
            workers: 1,
            ..quick_config()
        })
        .unwrap();
        let pattern = Pattern::Regex("x".to_string());
        let inputs: Vec<&[u8]> = vec![b"x"; 32];
        let tickets = server.submit_many(&pattern, &inputs);
        let stats = server.shutdown();
        assert_eq!(stats.served, 32, "shutdown must not drop queued work");
        for t in tickets {
            assert!(t.wait().unwrap().accepted);
        }
        assert!(stats.batches <= 32);
        assert!(stats.requests_per_batch() >= 1.0);
    }

    #[test]
    fn hazard_policy_warn_counts_and_reject_refuses() {
        // Warn (the default): the hazardous pattern is still served
        let server = Server::start(quick_config()).unwrap();
        let t = server
            .submit(Pattern::Regex("(a|a)*b".to_string()), &b"aaab"[..]);
        assert!(t.wait().unwrap().accepted);
        let stats = server.shutdown();
        assert_eq!(stats.hazards_flagged, 1);
        assert_eq!(stats.hazards_rejected, 0);
        assert_eq!(stats.served, 1);

        // Reject: the ticket resolves with ServeError::Hazard and the
        // request never reaches the queue; clean patterns still serve
        let server = Server::start(ServeConfig {
            hazard_policy: HazardPolicy::Reject,
            ..quick_config()
        })
        .unwrap();
        let bad = server
            .submit(Pattern::Regex("(a+)+b".to_string()), &b"aaab"[..]);
        let good =
            server.submit(Pattern::Regex("a+b".to_string()), &b"aaab"[..]);
        let err = bad.wait().expect_err("nested quantifier must refuse");
        assert!(matches!(err, ServeError::Hazard { .. }), "{err:?}");
        assert!(err.to_string().contains("nested-quantifier"), "{err}");
        assert!(good.wait().unwrap().accepted);
        let stats = server.shutdown();
        assert_eq!(stats.hazards_flagged, 1);
        assert_eq!(stats.hazards_rejected, 1);
        assert_eq!(stats.rejected, 1);
        assert_eq!(stats.served, 1);
        assert_eq!(stats.submitted, 1, "refused request never queued");

        assert_eq!(
            HazardPolicy::parse("reject").unwrap(),
            HazardPolicy::Reject
        );
        assert!(HazardPolicy::parse("panic").is_err());
    }

    #[test]
    fn admission_and_priority_parse() {
        assert_eq!(Admission::parse("block").unwrap(), Admission::Block);
        assert_eq!(Admission::parse("reject").unwrap(), Admission::Reject);
        assert!(Admission::parse("drop").is_err());
        assert_eq!(
            PriorityPolicy::parse("fifo").unwrap(),
            PriorityPolicy::Fifo
        );
        assert_eq!(
            PriorityPolicy::parse("size").unwrap(),
            PriorityPolicy::SizeAware
        );
        assert!(PriorityPolicy::parse("deadline").is_err());
    }

    #[test]
    fn serve_error_display_names_the_bound() {
        let e = ServeError::Overloaded { depth: 8, max_queue: 8 };
        let msg = e.to_string();
        assert!(msg.contains("overloaded"), "{msg}");
        assert!(msg.contains("max_queue 8"), "{msg}");
        assert!(ServeError::ShuttingDown
            .to_string()
            .contains("shutting down"));
    }

    // ---- ReqQueue unit tests: scheduling is a pure data-structure
    // property, tested without threads or timing ----

    fn test_req(pattern: &Pattern) -> Request {
        let (tx, _rx) = channel();
        Request {
            pattern: pattern.clone(),
            input: Vec::new(),
            reply: tx,
            ckpt: None,
        }
    }

    fn push_class(q: &mut ReqQueue, pattern: &Pattern, class: usize) -> u64 {
        let seq = q.next_seq;
        q.push(test_req(pattern), class, class);
        seq
    }

    #[test]
    fn drain_same_input_takes_across_lanes_and_leaves_stale_arrivals() {
        let pats: Vec<Pattern> = ["a", "b", "c"]
            .iter()
            .map(|p| Pattern::Regex(p.to_string()))
            .collect();
        let mut q = ReqQueue::new();
        let mut push = |q: &mut ReqQueue, p: &Pattern, input: &[u8]| {
            let (tx, _rx) = channel();
            let seq = q.next_seq;
            q.push(
                Request {
                    pattern: p.clone(),
                    input: input.to_vec(),
                    reply: tx,
                    ckpt: None,
                },
                CLASS_PROBE,
                CLASS_PROBE,
            );
            seq
        };
        // same input under three patterns, one other input in the middle
        let s0 = push(&mut q, &pats[0], b"shared");
        let s1 = push(&mut q, &pats[1], b"shared");
        let other = push(&mut q, &pats[1], b"other");
        let s3 = push(&mut q, &pats[2], b"shared");
        assert_eq!(q.len, 4);
        let drained = q.drain_same_input(b"shared", 64);
        let seqs: Vec<u64> = drained.iter().map(|t| t.seq).collect();
        assert_eq!(seqs, vec![s0, s1, s3], "admission order across lanes");
        assert_eq!(q.len, 1);
        // the survivor is still takeable despite its stale lane-mates
        let batch = q.take_batch(4, 64).unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].seq, other);
        assert_eq!(q.len, 0);
        assert!(q.take_batch(4, 64).is_none());
        // a capped drain takes only the oldest matches
        let t0 = push(&mut q, &pats[0], b"x");
        let _t1 = push(&mut q, &pats[1], b"x");
        let drained = q.drain_same_input(b"x", 1);
        assert_eq!(drained.len(), 1);
        assert_eq!(drained[0].seq, t0);
        assert_eq!(q.len, 1);
    }

    #[test]
    fn subqueue_coalesces_per_pattern_in_arrival_order() {
        let a = Pattern::Regex("a".to_string());
        let b = Pattern::Regex("b".to_string());
        let mut q = ReqQueue::new();
        // interleaved: a0 b1 a2 b3 a4
        for (i, p) in [&a, &b, &a, &b, &a].into_iter().enumerate() {
            assert_eq!(push_class(&mut q, p, CLASS_PROBE), i as u64);
        }
        assert_eq!(q.len, 5);
        // first take: oldest is a0, coalesces a0 a2 a4
        let batch = q.take_batch(4, 64).unwrap();
        let seqs: Vec<u64> = batch.iter().map(|t| t.seq).collect();
        assert_eq!(seqs, vec![0, 2, 4]);
        assert!(batch.iter().all(|t| t.req.pattern == a));
        // second take: b1 b3, still in arrival order
        let batch = q.take_batch(4, 64).unwrap();
        let seqs: Vec<u64> = batch.iter().map(|t| t.seq).collect();
        assert_eq!(seqs, vec![1, 3]);
        assert_eq!(q.len, 0);
        assert!(q.take_batch(4, 64).is_none());
        assert_eq!(q.max_depth, 5);
    }

    #[test]
    fn aging_bound_is_deterministic() {
        let scan = Pattern::Regex("scan".to_string());
        let probe = Pattern::Regex("probe".to_string());
        let mut q = ReqQueue::new();
        let s0 = push_class(&mut q, &scan, CLASS_SCAN);
        let probes: Vec<u64> = (0..10)
            .map(|_| push_class(&mut q, &probe, CLASS_PROBE))
            .collect();
        // age_limit 2, max_batch 2: two probe batches bypass the scan,
        // then the scan is forced, then the probes drain
        let order: Vec<Vec<u64>> = std::iter::from_fn(|| {
            q.take_batch(2, 2)
                .map(|b| b.iter().map(|t| t.seq).collect())
        })
        .collect();
        assert_eq!(
            order,
            vec![
                vec![probes[0], probes[1]],
                vec![probes[2], probes[3]],
                vec![s0],
                vec![probes[4], probes[5]],
                vec![probes[6], probes[7]],
                vec![probes[8], probes[9]],
            ]
        );
    }

    #[test]
    fn bypass_telemetry_tracks_the_aging_bound() {
        let scan = Pattern::Regex("scan".to_string());
        let probe = Pattern::Regex("probe".to_string());
        let mut q = ReqQueue::new();
        push_class(&mut q, &scan, CLASS_SCAN);
        for _ in 0..12 {
            push_class(&mut q, &probe, CLASS_PROBE);
        }
        // drain everything under age_limit 3, max_batch 1: the scan is
        // bypassed exactly three times, then forced; afterwards only
        // probes remain so the streak never grows again
        while q.take_batch(3, 1).is_some() {}
        assert_eq!(q.bypass_total, 3);
        assert_eq!(q.max_streak, 3);
        // a second wave with a waiting scan resumes the total but the
        // streak high-water mark still respects the bound
        push_class(&mut q, &scan, CLASS_SCAN);
        for _ in 0..8 {
            push_class(&mut q, &probe, CLASS_PROBE);
        }
        while q.take_batch(3, 1).is_some() {}
        assert_eq!(q.bypass_total, 6);
        assert_eq!(q.max_streak, 3, "streak resets when the scan serves");
    }

    #[test]
    fn fused_drains_credit_the_aging_counter() {
        let a = Pattern::Regex("a".to_string());
        let b = Pattern::Regex("b".to_string());
        let scan = Pattern::Regex("scan".to_string());
        let req = |p: &Pattern, input: &[u8]| {
            let (tx, _rx) = channel();
            Request {
                pattern: p.clone(),
                input: input.to_vec(),
                reply: tx,
                ckpt: None,
            }
        };
        let mut q = ReqQueue::new();
        // a scan waits (seq 0) while four cross-pattern probe pairs —
        // each pair sharing one input — flood in (seqs 1..=8)
        q.push(req(&scan, b"corpus"), CLASS_SCAN, CLASS_SCAN);
        for i in 0..4u8 {
            q.push(req(&a, &[b'x', i]), CLASS_PROBE, CLASS_PROBE);
            q.push(req(&b, &[b'x', i]), CLASS_PROBE, CLASS_PROBE);
        }
        // emulate the worker cycle with age_limit 2, max_batch 1: take
        // a batch, then (as next_batch does) drain the head input's
        // cross-pattern riders into a fused group
        let mut order: Vec<u64> = Vec::new();
        while let Some(batch) = q.take_batch(2, 1) {
            order.push(batch[0].seq);
            for rider in q.drain_same_input(&batch[0].req.input, 64) {
                order.push(rider.seq);
            }
        }
        // each probe cycle serves TWO passes (batch + fused group), so
        // both count against the aging bound and the scan is forced
        // after one cycle — not after two, which would stretch the
        // starvation bound to 2 x age_limit
        assert_eq!(order, vec![1, 2, 0, 3, 4, 5, 6, 7, 8]);
    }

    #[test]
    fn parked_scans_never_ride_a_fused_drain() {
        let a = Pattern::Regex("a".to_string());
        let b = Pattern::Regex("b".to_string());
        let req = |p: &Pattern, ckpt: Option<Vec<u8>>| {
            let (tx, _rx) = channel();
            Request {
                pattern: p.clone(),
                input: b"shared".to_vec(),
                reply: tx,
                ckpt,
            }
        };
        let mut q = ReqQueue::new();
        q.push(req(&a, None), CLASS_PROBE, CLASS_PROBE);
        let parked = q.next_seq;
        q.push(req(&b, Some(vec![1, 2, 3])), CLASS_SCAN, CLASS_SCAN);
        let drained = q.drain_same_input(b"shared", 64);
        assert_eq!(drained.len(), 1, "the checkpointed request stays");
        assert_eq!(q.len, 1);
        let batch = q.take_batch(0, 64).unwrap();
        assert_eq!(batch[0].seq, parked);
    }

    #[test]
    fn age_limit_zero_never_bypasses_a_scan() {
        let scan = Pattern::Regex("scan".to_string());
        let probe = Pattern::Regex("probe".to_string());
        let mut q = ReqQueue::new();
        push_class(&mut q, &probe, CLASS_PROBE);
        let s = push_class(&mut q, &scan, CLASS_SCAN);
        // both classes live: age_limit 0 forces the scan first
        let batch = q.take_batch(0, 64).unwrap();
        assert_eq!(batch[0].seq, s);
    }

    #[test]
    fn prop_take_is_oldest_of_class_and_class_pattern_fifo() {
        use crate::util::rng::Rng;
        let patterns = [
            Pattern::Regex("a".to_string()),
            Pattern::Regex("b".to_string()),
            Pattern::Regex("c".to_string()),
        ];
        let mut rng = Rng::new(0x5EED_F1F0);
        let mut q = ReqQueue::new();
        // mirror of the live queue: (seq, class, pattern index)
        let mut mirror: Vec<(u64, usize, usize)> = Vec::new();
        for _ in 0..600 {
            if mirror.is_empty() || rng.below(10) < 7 {
                let p = rng.usize_below(patterns.len());
                let class = if rng.below(4) == 0 {
                    CLASS_SCAN
                } else {
                    CLASS_PROBE
                };
                let seq = push_class(&mut q, &patterns[p], class);
                mirror.push((seq, class, p));
            } else {
                let max_batch = 1 + rng.usize_below(5);
                let age_limit = rng.below(4);
                let batch = q
                    .take_batch(age_limit, max_batch)
                    .expect("mirror is non-empty");
                let class = batch[0].class;
                let pat = batch[0].req.pattern.clone();
                // invariant 1: the batch head is the OLDEST live
                // request of its class — no within-class queue jumping
                let oldest = mirror
                    .iter()
                    .filter(|&&(_, c, _)| c == class)
                    .map(|&(s, _, _)| s)
                    .min()
                    .expect("class had a live request");
                assert_eq!(batch[0].seq, oldest);
                // invariant 2: the batch is exactly the first
                // min(max_batch, k) live (class, pattern) requests in
                // arrival order — per-class FIFO is never violated
                let want: Vec<u64> = mirror
                    .iter()
                    .filter(|&&(_, c, p)| {
                        c == class && patterns[p] == pat
                    })
                    .map(|&(s, _, _)| s)
                    .take(max_batch)
                    .collect();
                let got: Vec<u64> =
                    batch.iter().map(|t| t.seq).collect();
                assert_eq!(got, want);
                mirror.retain(|(s, _, _)| !got.contains(s));
                assert_eq!(q.len, mirror.len());
            }
        }
    }

    /// Build a fused group of one request per pattern, all sharing one
    /// input, keeping the receivers alive so verdicts can be checked.
    fn fused_group(
        patterns: &[Pattern],
        input: &[u8],
    ) -> (Vec<Request>, Vec<Receiver<ServeResult>>) {
        let mut group = Vec::new();
        let mut rxs = Vec::new();
        for p in patterns {
            let (tx, rx) = channel();
            group.push(Request {
                pattern: p.clone(),
                input: input.to_vec(),
                reply: tx,
                ckpt: None,
            });
            rxs.push(rx);
        }
        (group, rxs)
    }

    #[test]
    fn fused_set_matcher_is_cached_and_epoch_invalidated() {
        // memoization off so every repeat group reaches the set path
        let server = Server::start(ServeConfig {
            calibrate_on_start: false,
            cache_outcomes: 0,
            ..quick_config()
        })
        .unwrap();
        let shared = &server.shared;
        let patterns = [
            Pattern::Regex("ab+c".to_string()),
            Pattern::Regex("xyz".to_string()),
        ];
        let check = |rxs: Vec<Receiver<ServeResult>>| {
            let o1 = rxs[0].recv().unwrap().unwrap();
            let o2 = rxs[1].recv().unwrap().unwrap();
            assert!(o1.accepted, "ab+c matches");
            assert!(!o2.accepted, "xyz does not");
        };

        let (g1, rx1) = fused_group(&patterns, b"zzabbbczz");
        serve_fused_group(shared, g1);
        check(rx1);
        assert_eq!(stats_of(shared).set_cache_hits, 0, "first group compiles");

        let (g2, rx2) = fused_group(&patterns, b"zzabbbczz");
        serve_fused_group(shared, g2);
        check(rx2);
        assert_eq!(stats_of(shared).set_cache_hits, 1, "repeat group hits");

        // recalibration bumps the epoch: the cached set matcher was
        // compiled under stale thresholds and must not be reused
        recalibrate(shared);
        let (g3, rx3) = fused_group(&patterns, b"zzabbbczz");
        serve_fused_group(shared, g3);
        check(rx3);
        assert_eq!(
            stats_of(shared).set_cache_hits,
            1,
            "post-epoch group recompiles"
        );

        let (g4, rx4) = fused_group(&patterns, b"zzabbbczz");
        serve_fused_group(shared, g4);
        check(rx4);
        assert_eq!(stats_of(shared).set_cache_hits, 2);
        server.shutdown();
    }

    #[test]
    fn abandoned_tickets_do_not_strand_the_queue() {
        // Satellite audit: a Ticket dropped after wait_timeout (or
        // dropped outright) must not wedge the serve loop — the worker's
        // reply send is `let _ =`, so a gone receiver only discards the
        // outcome.  Regression test for the abandonment leak.
        let server = Server::start(ServeConfig {
            workers: 1,
            ..quick_config()
        })
        .unwrap();
        let pattern = Pattern::Regex("ab+c".to_string());
        let mut abandoned = 0usize;
        for _ in 0..8 {
            let t = server.submit(pattern.clone(), &b"xxabbbcyy"[..]);
            match t.wait_timeout(Duration::from_nanos(1)) {
                Ok(out) => assert!(out.unwrap().accepted),
                Err(ticket) => {
                    drop(ticket); // abandon while possibly in flight
                    abandoned += 1;
                }
            }
        }
        // dropped without any wait at all
        let t = server.submit(pattern.clone(), &b"xxabbbcyy"[..]);
        drop(t);
        // the loop is still alive and serving
        let t = server.submit(pattern, &b"xxabbbcyy"[..]);
        assert!(t.wait().unwrap().accepted, "server survived abandonment");
        let stats = server.shutdown();
        assert_eq!(stats.submitted, 10);
        assert_eq!(
            stats.served + stats.failed + stats.rejected,
            stats.submitted,
            "every submission resolved: {stats:?} ({abandoned} abandoned)"
        );
        assert_eq!(stats.queue_depth, 0, "nothing stranded in the queue");
    }
}
