//! Hierarchical cross-substrate sharding: one corpus-scale input split
//! across simulated cloud nodes **and**, within each node, across that
//! node's multicore speculative matcher.
//!
//! `Engine::Auto` used to pick exactly one substrate per request: a
//! 100 MB scan went either to the cluster (leaving each node's cores
//! under one chunk) or to the multicore matcher (leaving the other nodes
//! idle).  [`ShardPlan`] composes both: a **two-level partition** where
//! each level is the paper's Eq. (1) capacity-weighted split —
//!
//! ```text
//!   input [0, n)                          m = I_max,r
//!     │  level 1: node spans of the Eq. (1) worker partition —
//!     │  node shares follow total node capacity
//!     ├──────────── node 0 ────────────┬──── node 1 ────┬─ node 2 ─┐
//!     │  level 2: Eq. (1) over the     │                │          │
//!     │  per-worker capacity vectors   │                │          │
//!     │  (profile_workers)             │                │          │
//!     ├── w0 ──┬─ w1 ─┬─ w2 ─┬─ w3 ─┤  ├─ w0 ─┬─ w1 ─┤  ├─ ... ─┤  │
//! ```
//!
//! — and a **bottom-up merge** mirroring the paper's 2-tier scheme
//! (Fig. 9): each node composes its workers' L-vectors (Eq. 9) into one
//! node map, then the master threads the start state through the node
//! maps in order (Eq. 8).  Failure-freedom is inherited from the
//! single-level matcher: the state entering any chunk is always inside
//! that chunk's speculated initial-state set (lookahead soundness), so
//! the sharded outcome is byte-identical to the sequential run —
//! verified by the differential suite in `tests/sharding.rs`.
//!
//! Capacity vectors come from [`crate::speculative::profile`]: node
//! weights from per-node *total* capacity, intra-node weights from the
//! node's per-worker rates ([`profile_workers`](
//! crate::speculative::profile::profile_workers) measures a real one for
//! the serving path).

use std::time::Instant;

use crate::automata::{Dfa, FlatDfa};
use crate::cluster::ClusterSpec;
use crate::speculative::chunk::match_chunk_states;
use crate::speculative::lookahead::Lookahead;
use crate::speculative::lvector::LVector;
use crate::speculative::merge::MergeStats;
use crate::speculative::partition::{partition_with_sizes, Chunk};
use crate::speculative::profile::{weights_from_capacities, CapacityVector};

/// The two-level chunk layout of one sharded run: which byte range each
/// (node, worker) pair matches.
#[derive(Clone, Debug)]
pub struct ShardLayout {
    /// Level-1 chunks, one per node (`proc` = node id), tiling `[0, n)`.
    pub node_chunks: Vec<Chunk>,
    /// Level-2 chunks per node (`proc` = worker index within the node),
    /// in **global** input offsets, tiling the node's level-1 chunk.
    pub worker_chunks: Vec<Vec<Chunk>>,
}

impl ShardLayout {
    /// Total worker chunks across all nodes.
    pub fn total_workers(&self) -> usize {
        self.worker_chunks.iter().map(Vec::len).sum()
    }
}

/// One worker's execution record in a sharded run.
#[derive(Clone, Debug)]
pub struct ShardWork {
    /// node (level-1 shard) this worker belongs to
    pub node: usize,
    /// worker index within the node
    pub worker: usize,
    /// global start offset of the worker's chunk
    pub chunk_start: usize,
    /// chunk length in symbols
    pub chunk_len: usize,
    /// initial states matched for this chunk (1 for the very first chunk)
    pub states_matched: usize,
    /// the worker's real matching work in symbol steps:
    /// `chunk_len × states_matched` minus what convergence collapsing
    /// removed
    pub syms_matched: usize,
    /// speculative chains merged by convergence collapsing (0 when the
    /// plan runs without it)
    pub collapses: usize,
    /// measured wall time of this worker's matching loop, seconds
    pub elapsed_s: f64,
}

/// Result of one hierarchical sharded run.
#[derive(Clone, Debug)]
pub struct ShardOutcome {
    /// `delta*(q0, input)` — identical to the sequential run
    pub final_state: u32,
    /// membership verdict: `final_state ∈ F`
    pub accepted: bool,
    /// partitioning parameter m (I_max,r, or |Q| without lookahead)
    pub m: usize,
    /// per-worker execution records, node-major order
    pub work: Vec<ShardWork>,
    /// tier-1 composed L-vector of each node's full chunk
    pub node_lvectors: Vec<LVector>,
    /// op/message counts of the bottom-up merge (Fig. 9 accounting)
    pub merge_stats: MergeStats,
}

impl ShardOutcome {
    /// Max symbols matched by any worker — the parallel makespan in
    /// symbol units.
    pub fn makespan_syms(&self) -> usize {
        self.work.iter().map(|w| w.syms_matched).max().unwrap_or(0)
    }

    /// Total redundant work introduced by speculation, in symbols.
    pub fn speculative_overhead_syms(&self, n: usize) -> usize {
        let total: usize = self.work.iter().map(|w| w.syms_matched).sum();
        total.saturating_sub(n)
    }

    /// Total chains merged by convergence collapsing across all workers.
    pub fn collapses(&self) -> usize {
        self.work.iter().map(|w| w.collapses).sum()
    }

    /// Symbols of real matching work done by each node (level-1 shard).
    pub fn per_node_syms(&self) -> Vec<usize> {
        let nodes = self
            .work
            .iter()
            .map(|w| w.node)
            .max()
            .map(|m| m + 1)
            .unwrap_or(0);
        let mut out = vec![0usize; nodes];
        for w in &self.work {
            out[w.node] += w.syms_matched;
        }
        out
    }
}

/// Configuration builder for hierarchical sharded matching: a cluster of
/// nodes, each with a per-worker capacity vector, sharing one DFA.
///
/// ```
/// use specdfa::engine::shard::ShardPlan;
/// use specdfa::{compile_search, SequentialMatcher};
///
/// let dfa = compile_search("(ab|cd)+e").unwrap();
/// let input = b"xxabcde".repeat(40_000);
/// // 2 nodes: a 4-worker node with one slow worker, a 2-worker node
/// let plan = ShardPlan::new(&dfa)
///     .node_capacities(vec![vec![1.0, 1.0, 1.0, 0.25], vec![1.5, 1.5]])
///     .lookahead(2);
/// let out = plan.run(&input);
/// let seq = SequentialMatcher::new(&dfa).run_bytes(&input);
/// assert_eq!(out.final_state, seq.final_state); // failure-free
/// assert!(out.accepted);
/// ```
#[derive(Clone, Debug)]
pub struct ShardPlan {
    dfa: Dfa,
    flat: FlatDfa,
    /// per-node per-worker capacity vectors (rates; any positive unit)
    nodes: Vec<Vec<f64>>,
    r: usize,
    lookahead: Option<Lookahead>,
    use_threads: bool,
    collapse_every: usize,
}

impl ShardPlan {
    /// A plan over `dfa` with the default topology: 2 nodes × 4 uniform
    /// workers.  Use the builder methods to shape the cluster.
    pub fn new(dfa: &Dfa) -> ShardPlan {
        ShardPlan {
            dfa: dfa.clone(),
            flat: FlatDfa::from_dfa(dfa),
            nodes: vec![vec![1.0; 4]; 2],
            r: 0,
            lookahead: None,
            use_threads: true,
            collapse_every: 0,
        }
    }

    /// Enable convergence collapsing with the given check interval in
    /// symbols (merged chains drop out of the inner loop; the outcome is
    /// byte-identical).  0 (the default) disables it — see
    /// [`crate::speculative::matcher::MatchPlan::collapse_every`].
    pub fn collapse_every(mut self, every: usize) -> ShardPlan {
        self.collapse_every = every;
        self
    }

    /// Explicit per-node per-worker capacity vectors.  Vector lengths may
    /// differ per node (heterogeneous clusters); every rate must be > 0.
    pub fn node_capacities(mut self, nodes: Vec<Vec<f64>>) -> ShardPlan {
        assert!(!nodes.is_empty(), "need at least one node");
        for caps in &nodes {
            assert!(!caps.is_empty(), "every node needs >= 1 worker");
            assert!(
                caps.iter().all(|&c| c > 0.0),
                "capacities must be positive"
            );
        }
        self.nodes = nodes;
        self
    }

    /// `nodes` identical nodes, each using the same measured per-worker
    /// capacity vector — the serving-path shape, where
    /// [`profile_workers`](crate::speculative::profile::profile_workers)
    /// measured the local host once.
    pub fn capacity_vector(self, nodes: usize, cv: &CapacityVector) -> ShardPlan {
        assert!(nodes >= 1);
        self.node_capacities(vec![cv.rates.clone(); nodes])
    }

    /// Derive the topology from a simulated-cluster spec: one worker per
    /// allocated core, each at the node's per-core capacity.
    pub fn cluster(self, spec: &ClusterSpec) -> ShardPlan {
        let mut nodes = Vec::with_capacity(spec.nodes.len());
        for node in &spec.nodes {
            let cores = if spec.leave_one_core_idle {
                node.cores.saturating_sub(1).max(1)
            } else {
                node.cores
            };
            nodes.push(vec![node.capacity; cores]);
        }
        self.node_capacities(nodes)
    }

    /// Enable the I_max,r optimization (Algorithm 3) with `r` reverse
    /// lookahead symbols; r = 0 reverts to basic all-|Q| speculation.
    pub fn lookahead(mut self, r: usize) -> ShardPlan {
        self.r = r;
        self.lookahead =
            if r > 0 { Some(Lookahead::analyze(&self.dfa, r)) } else { None };
        self
    }

    /// Inject a precomputed lookahead analysis (must come from this DFA),
    /// sharing one BFS across adapters like
    /// [`MatchPlan::with_lookahead`](crate::speculative::matcher::MatchPlan::with_lookahead).
    pub fn with_lookahead(mut self, la: Lookahead) -> ShardPlan {
        self.r = la.r;
        self.lookahead = Some(la);
        self
    }

    /// Run workers inline on the calling thread (deterministic for the
    /// simulation harness) instead of spawning OS threads.
    pub fn sequential_execution(mut self) -> ShardPlan {
        self.use_threads = false;
        self
    }

    /// The partitioning parameter m: I_max,r with lookahead, |Q| without.
    pub fn i_max(&self) -> usize {
        self.lookahead
            .as_ref()
            .map(|la| la.i_max)
            .unwrap_or(self.dfa.num_states as usize)
    }

    /// The compiled DFA the plan matches with.
    pub fn dfa(&self) -> &Dfa {
        &self.dfa
    }

    /// Total workers across all nodes.
    pub fn total_workers(&self) -> usize {
        self.nodes.iter().map(Vec::len).sum()
    }

    /// Compute the two-level chunk layout for an `n`-symbol input.
    ///
    /// One Eq. (1) weighting over the **full worker population** (node-
    /// major) drives both levels: `partition_with_sizes` balances
    /// `len·states/weight` across every worker — the very first worker
    /// matches one state (the known start, Eq. 5's m× stretch), every
    /// other worker speculates over up to `m` states — and the level-1
    /// node chunks are the node-major spans of their workers' chunks.
    /// Node shares therefore follow total node capacity automatically
    /// (Eq. (1) is normalization-invariant), without the naive
    /// two-pass scheme's flaw of re-applying the chunk-0 stretch
    /// per level, which would systematically overload node 0's workers
    /// for any m > 1.
    ///
    /// Invariants (property-tested): worker chunks tile each node chunk
    /// exactly; node chunks tile `[0, n)` exactly — every symbol is
    /// matched exactly once per speculated state, whatever the skew of
    /// the capacity vectors.
    pub fn layout(&self, n: usize) -> ShardLayout {
        let m = self.i_max().max(1);
        let all: Vec<f64> =
            self.nodes.iter().flatten().copied().collect();
        let weights = weights_from_capacities(&all);
        let sizes: Vec<usize> = (0..all.len())
            .map(|i| if i == 0 { 1 } else { m })
            .collect();
        let flat = partition_with_sizes(n, &weights, &sizes);

        let mut node_chunks = Vec::with_capacity(self.nodes.len());
        let mut worker_chunks = Vec::with_capacity(self.nodes.len());
        let mut next = 0usize;
        for (node, caps) in self.nodes.iter().enumerate() {
            let group: Vec<Chunk> = flat[next..next + caps.len()]
                .iter()
                .enumerate()
                .map(|(worker, c)| Chunk {
                    proc: worker,
                    start: c.start,
                    end: c.end,
                })
                .collect();
            next += caps.len();
            node_chunks.push(Chunk {
                proc: node,
                start: group.first().expect(">=1 worker per node").start,
                end: group.last().expect(">=1 worker per node").end,
            });
            worker_chunks.push(group);
        }
        ShardLayout { node_chunks, worker_chunks }
    }

    /// The speculated initial-state set for a chunk starting at global
    /// offset `b`: `{q0}` at the input start, the reverse-lookahead set
    /// of Eq. (13) with lookahead, all live states without.
    fn initial_set(&self, syms: &[u32], b: usize) -> Vec<u32> {
        if b == 0 {
            return vec![self.dfa.start];
        }
        match &self.lookahead {
            Some(la) => {
                let lo = b.saturating_sub(la.r);
                la.initial_set(&self.dfa, &syms[lo..b])
                    .iter()
                    .map(|s| s as u32)
                    .collect()
            }
            None => (0..self.dfa.num_states).collect(),
        }
    }

    /// Match raw bytes (applies the IBase class mapping first).
    pub fn run(&self, input: &[u8]) -> ShardOutcome {
        self.run_syms(&self.dfa.map_input(input))
    }

    /// Match pre-mapped dense symbols: plan the two-level layout, match
    /// every (node, worker) chunk in parallel, merge bottom-up.
    pub fn run_syms(&self, syms: &[u32]) -> ShardOutcome {
        let q = self.dfa.num_states as usize;
        let m = self.i_max().max(1);
        let layout = self.layout(syms.len());

        // flatten (node, worker) tasks with their initial-state sets
        let mut tasks: Vec<(usize, &Chunk, Vec<u32>)> = Vec::new();
        for (node, chunks) in layout.worker_chunks.iter().enumerate() {
            for chunk in chunks {
                tasks.push((node, chunk, self.initial_set(syms, chunk.start)));
            }
        }

        let collapse = self.collapse_every;
        let mut results: Vec<(LVector, ShardWork)> =
            Vec::with_capacity(tasks.len());
        if self.use_threads {
            let mut slots: Vec<Option<(LVector, ShardWork)>> =
                vec![None; tasks.len()];
            std::thread::scope(|scope| {
                let flat = &self.flat;
                for (slot, (node, chunk, set)) in
                    slots.iter_mut().zip(&tasks)
                {
                    scope.spawn(move || {
                        *slot = Some(match_chunk(
                            flat, q, *node, chunk, set, syms, collapse,
                        ));
                    });
                }
            });
            results.extend(slots.into_iter().map(Option::unwrap));
        } else {
            for (node, chunk, set) in &tasks {
                results.push(match_chunk(
                    &self.flat, q, *node, chunk, set, syms, collapse,
                ));
            }
        }

        // ---- bottom-up merge (Fig. 9, generalized to ragged nodes) ----
        // tier 1: each node composes its workers' L-vectors (Eq. 9)
        let mut stats = MergeStats::default();
        let mut node_lvectors: Vec<LVector> = Vec::new();
        let mut work: Vec<ShardWork> = Vec::with_capacity(results.len());
        let mut it = results.into_iter();
        for chunks in &layout.worker_chunks {
            let (first_lv, first_work) =
                it.next().expect("one result per planned chunk");
            work.push(first_work);
            let mut acc = first_lv;
            for _ in 1..chunks.len() {
                let (lv, w) = it.next().expect("one result per chunk");
                work.push(w);
                acc = acc.compose(&lv);
                stats.compose_ops += 1;
            }
            stats.intra_node_msgs += chunks.len().saturating_sub(1);
            node_lvectors.push(acc);
        }
        stats.depth += 1;
        // tier 2: the master threads the start state through the node
        // maps in chunk order (Eq. 8)
        let mut state = self.dfa.start;
        for (i, lv) in node_lvectors.iter().enumerate() {
            state = lv.get(state);
            stats.lookup_ops += 1;
            if i > 0 {
                stats.inter_node_msgs += 1;
            }
        }
        stats.depth += 1;

        ShardOutcome {
            final_state: state,
            accepted: self.dfa.accepting[state as usize],
            m,
            work,
            node_lvectors,
            merge_stats: stats,
        }
    }
}

/// Match one worker chunk for each speculated initial state — the same
/// shared 8-wide interleaved kernel (with optional convergence
/// collapsing) as the multicore matcher, validated once per chunk.
fn match_chunk(
    flat: &FlatDfa,
    q: usize,
    node: usize,
    chunk: &Chunk,
    set: &[u32],
    syms: &[u32],
    collapse_every: usize,
) -> (LVector, ShardWork) {
    let t0 = Instant::now();
    let mut lv = LVector::identity(q);
    let chunk_syms = flat.validate(&syms[chunk.start..chunk.end]);
    let work =
        match_chunk_states(flat, &mut lv, set, chunk_syms, collapse_every);
    (
        lv,
        ShardWork {
            node,
            worker: chunk.proc,
            chunk_start: chunk.start,
            chunk_len: chunk.len(),
            states_matched: set.len(),
            syms_matched: work.syms_matched,
            collapses: work.collapses,
            elapsed_s: t0.elapsed().as_secs_f64(),
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::sequential::SequentialMatcher;
    use crate::regex::compile::{compile_prosite, compile_search};
    use crate::speculative::lookahead::tests::{fig6_dfa, random_dfa};
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn random_syms(rng: &mut Rng, dfa: &Dfa, len: usize) -> Vec<u32> {
        (0..len).map(|_| rng.below(dfa.num_symbols as u64) as u32).collect()
    }

    #[test]
    fn sharded_equals_sequential_on_fig6() {
        let dfa = fig6_dfa();
        let mut rng = Rng::new(0x5A4D);
        let syms = random_syms(&mut rng, &dfa, 20_000);
        let want = SequentialMatcher::new(&dfa).run_syms(&syms);
        for nodes in [
            vec![vec![1.0; 2]; 2],
            vec![vec![1.5, 0.5], vec![1.0; 3], vec![2.0]],
            vec![vec![1.0]],
        ] {
            for r in [0, 1, 2] {
                let out = ShardPlan::new(&dfa)
                    .node_capacities(nodes.clone())
                    .lookahead(r)
                    .run_syms(&syms);
                assert_eq!(out.final_state, want.final_state, "r={r}");
                assert_eq!(out.accepted, want.accepted);
            }
        }
    }

    #[test]
    fn prop_sharded_failure_freedom_random_dfas() {
        prop::check("sharded == sequential (random DFAs)", 40, |rng| {
            let dfa = random_dfa(rng);
            let len = rng.range_usize(0, 1500);
            let syms = random_syms(rng, &dfa, len);
            let want = SequentialMatcher::new(&dfa).run_syms(&syms);
            let nodes: Vec<Vec<f64>> = (0..rng.range_usize(1, 5))
                .map(|_| {
                    (0..rng.range_usize(1, 5))
                        .map(|_| 0.25 + rng.f64() * 3.0)
                        .collect()
                })
                .collect();
            let out = ShardPlan::new(&dfa)
                .node_capacities(nodes)
                .lookahead(rng.range_usize(0, 4))
                .run_syms(&syms);
            assert_eq!(out.final_state, want.final_state, "len={len}");
            assert_eq!(out.accepted, want.accepted);
        });
    }

    #[test]
    fn prop_layout_tiles_input_exactly_once() {
        // skewed capacity vectors must still partition [0, n) exactly:
        // node chunks tile the input, worker chunks tile each node chunk
        prop::check("shard layout tiles input", 80, |rng| {
            let dfa = fig6_dfa();
            let n = rng.below(3_000_000) as usize;
            let nodes: Vec<Vec<f64>> = (0..rng.range_usize(1, 6))
                .map(|_| {
                    (0..rng.range_usize(1, 9))
                        .map(|_| if rng.chance(0.3) {
                            0.01 + rng.f64() * 0.1 // heavily skewed worker
                        } else {
                            0.5 + rng.f64() * 4.0
                        })
                        .collect()
                })
                .collect();
            let plan = ShardPlan::new(&dfa)
                .node_capacities(nodes.clone())
                .lookahead(rng.range_usize(0, 3));
            let layout = plan.layout(n);
            assert_eq!(layout.node_chunks.len(), nodes.len());
            assert_eq!(layout.node_chunks[0].start, 0);
            assert_eq!(layout.node_chunks.last().unwrap().end, n);
            for w in layout.node_chunks.windows(2) {
                assert_eq!(w[0].end, w[1].start);
            }
            for (node, chunks) in layout.worker_chunks.iter().enumerate() {
                let top = &layout.node_chunks[node];
                assert_eq!(chunks.len(), nodes[node].len());
                assert_eq!(chunks[0].start, top.start);
                assert_eq!(chunks.last().unwrap().end, top.end);
                for w in chunks.windows(2) {
                    assert_eq!(w[0].end, w[1].start);
                    assert!(w[0].start <= w[0].end);
                }
            }
        });
    }

    #[test]
    fn capacity_weights_shift_work_toward_fast_workers() {
        let dfa = compile_prosite("C-x(2)-C-x(3)-[LIVMFYWC].").unwrap();
        let mut gen = crate::workload::InputGen::new(0x5A4E);
        let syms = dfa.map_input(&gen.protein(400_000));
        // node 1 is 3x the capacity of node 0: it must get more symbols
        let out = ShardPlan::new(&dfa)
            .node_capacities(vec![vec![1.0; 2], vec![3.0; 2]])
            .lookahead(4)
            .run_syms(&syms);
        let per_node = out.per_node_syms();
        assert_eq!(per_node.len(), 2);
        assert!(
            per_node[1] > per_node[0],
            "fast node must do more work: {per_node:?}"
        );
        // and the sharded result still equals sequential
        let want = SequentialMatcher::new(&dfa).run_syms(&syms);
        assert_eq!(out.final_state, want.final_state);
    }

    #[test]
    fn uniform_cluster_balances_work_across_nodes() {
        // regression: a naive two-pass layout re-applies the chunk-0 m×
        // stretch inside node 0 and systematically overloads its workers
        // for m > 1.  With the single Eq. (1) partition, per-worker work
        // (len × states) must be near-equal on a uniform cluster.  r=1 on
        // the Fig. 6 DFA pins every speculative set at I_max = 2 exactly,
        // so the worst-case sizing matches the runtime sets.
        let dfa = fig6_dfa();
        let mut rng = Rng::new(0x5A51);
        let syms = random_syms(&mut rng, &dfa, 1_000_000);
        let out = ShardPlan::new(&dfa)
            .node_capacities(vec![vec![1.0; 4]; 2])
            .lookahead(1)
            .run_syms(&syms);
        let works: Vec<usize> =
            out.work.iter().map(|w| w.syms_matched).collect();
        let max = *works.iter().max().unwrap() as f64;
        let min = *works.iter().min().unwrap() as f64;
        assert!(
            max / min < 1.05,
            "unbalanced shard work: {works:?}"
        );
    }

    #[test]
    fn inline_execution_equals_threads() {
        let dfa = fig6_dfa();
        let mut rng = Rng::new(0x5A4F);
        let syms = random_syms(&mut rng, &dfa, 8_000);
        let plan = ShardPlan::new(&dfa)
            .node_capacities(vec![vec![1.0, 2.0], vec![1.0; 3]])
            .lookahead(2);
        let threaded = plan.clone().run_syms(&syms);
        let inline = plan.sequential_execution().run_syms(&syms);
        assert_eq!(threaded.final_state, inline.final_state);
        assert_eq!(threaded.makespan_syms(), inline.makespan_syms());
        assert_eq!(threaded.work.len(), inline.work.len());
    }

    #[test]
    fn collapsing_preserves_outcome_and_reduces_work() {
        // gamma = 1 (no lookahead) on an exact-match DFA: all chains
        // sink quickly, so collapsing strictly cuts the executed work
        let dfa = crate::regex::compile::compile_exact("abcd").unwrap();
        let mut rng = Rng::new(0x5A52);
        let syms = random_syms(&mut rng, &dfa, 300_000);
        let nodes = vec![vec![1.0; 3]; 2];
        let plain = ShardPlan::new(&dfa)
            .node_capacities(nodes.clone())
            .run_syms(&syms);
        let collapsed = ShardPlan::new(&dfa)
            .node_capacities(nodes)
            .collapse_every(128)
            .run_syms(&syms);
        assert_eq!(plain.final_state, collapsed.final_state);
        assert_eq!(plain.accepted, collapsed.accepted);
        let total = |o: &ShardOutcome| -> usize {
            o.work.iter().map(|w| w.syms_matched).sum()
        };
        assert!(
            total(&collapsed) < total(&plain),
            "{} !< {}",
            total(&collapsed),
            total(&plain)
        );
        assert!(collapsed.collapses() > 0);
        assert_eq!(plain.collapses(), 0);
    }

    #[test]
    fn empty_input_and_single_worker() {
        let dfa = fig6_dfa();
        let out = ShardPlan::new(&dfa)
            .node_capacities(vec![vec![1.0]])
            .run_syms(&[]);
        assert_eq!(out.final_state, dfa.start);
        let out =
            ShardPlan::new(&dfa).lookahead(1).run_syms(&[]);
        assert_eq!(out.final_state, dfa.start);
    }

    #[test]
    fn merge_stats_follow_fig9_shape() {
        let dfa = fig6_dfa();
        let mut rng = Rng::new(0x5A50);
        let syms = random_syms(&mut rng, &dfa, 50_000);
        // 3 nodes x 4 workers
        let out = ShardPlan::new(&dfa)
            .node_capacities(vec![vec![1.0; 4]; 3])
            .lookahead(1)
            .run_syms(&syms);
        assert_eq!(out.node_lvectors.len(), 3);
        assert_eq!(out.work.len(), 12);
        assert_eq!(out.merge_stats.compose_ops, 3 * 3);
        assert_eq!(out.merge_stats.intra_node_msgs, 3 * 3);
        assert_eq!(out.merge_stats.inter_node_msgs, 2);
        assert_eq!(out.merge_stats.lookup_ops, 3);
        assert_eq!(out.merge_stats.depth, 2);
    }

    #[test]
    fn cluster_spec_derives_topology() {
        let dfa = fig6_dfa();
        let plan = ShardPlan::new(&dfa)
            .cluster(&ClusterSpec::fast_slow(1, 1));
        // cc2.8xlarge: 15 allocated cores; m2.4xlarge: 7
        assert_eq!(plan.total_workers(), 15 + 7);
        let cv = CapacityVector::uniform(3, 100.0);
        let plan = ShardPlan::new(&dfa).capacity_vector(4, &cv);
        assert_eq!(plan.total_workers(), 12);
    }
}
