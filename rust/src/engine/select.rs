//! `Engine::Auto` substrate selection from DFA structure and input size.
//!
//! The paper frames engine choice as a function of two quantities this
//! codebase already computes but the bespoke APIs never used for
//! dispatch:
//!
//!  * **γ = I_max,r / |Q|** (Eq. 18) — the structural speculation-
//!    friendliness of the DFA.  Speedup is bounded by 1 + (|P|−1)/I_max,
//!    so γ near 1 means no parallel substrate can beat Listing 1.
//!  * **n** — the input length, which decides whether the per-run
//!    parallel overhead (thread spawn + merge, or network round trips)
//!    amortizes.
//!
//! The thresholds are calibrated against the host symbol rate measured by
//! `speculative::profile` / `experiments::calibrate` (see
//! [`AutoThresholds::calibrated`]); the defaults bake in the 500 sym/µs
//! ballpark of the paper-era hardware.

use crate::automata::Dfa;
use crate::speculative::lookahead::Lookahead;
use crate::speculative::profile::CapacityProfile;

use super::outcome::EngineKind;

/// Structural properties of a compiled pattern's DFA, computed once at
/// `CompiledMatcher::compile` time and reused for every dispatch.
#[derive(Clone, Debug)]
pub struct DfaProps {
    /// |Q|
    pub q: usize,
    /// |Σ| (dense symbol classes)
    pub sigma: usize,
    /// lookahead depth the analysis used (≥ 1)
    pub r: usize,
    /// I_max,r (Eq. 12)
    pub i_max: usize,
    /// γ = I_max,r / |Q| (Eq. 18)
    pub gamma: f64,
}

impl DfaProps {
    /// Analyze a DFA with `r` reverse-lookahead symbols (clamped to ≥ 1;
    /// r = 0 callers still need γ for the *decision*, and Lemma 1 makes
    /// the r = 1 value a sound conservative stand-in).
    pub fn analyze(dfa: &Dfa, r: usize) -> DfaProps {
        let la = Lookahead::analyze(dfa, r.max(1));
        DfaProps::from_lookahead(dfa, &la)
    }

    /// Build from an existing analysis (avoids re-running the BFS).
    pub fn from_lookahead(dfa: &Dfa, la: &Lookahead) -> DfaProps {
        let q = dfa.num_states as usize;
        DfaProps {
            q,
            sigma: dfa.num_symbols as usize,
            r: la.r,
            i_max: la.i_max,
            gamma: la.i_max as f64 / q.max(1) as f64,
        }
    }
}

/// Dispatch thresholds for [`select`].  All comparisons are documented on
/// the fields; [`select`] applies them in rule order.
#[derive(Clone, Debug, PartialEq)]
pub struct AutoThresholds {
    /// Rule 1 — below this input length the run is served sequentially:
    /// the parallel plan costs ~120 µs (thread spawn + L-vector merge),
    /// which at the calibrated symbol rate equals this many symbols.
    pub seq_max_n: usize,
    /// Rule 2 — above this γ the run is served sequentially: speculative
    /// speedup is bounded by 1 + (|P|−1)/I_max (Eq. 18), which for
    /// γ > 1/2 cannot reach 2× on same-|Q|-scale processor counts.
    pub gamma_max: f64,
    /// Rule 3 — at or above this input length the cloud substrate wins:
    /// the ~362 µs inter-node hops (×nodes) stay under ~2 % of the
    /// sequential matching time.
    pub cloud_min_n: usize,
    /// Rule 3a — at or above this *corpus-scale* input length the
    /// hierarchical shard engine wins over single-substrate cloud
    /// dispatch: per-node chunks are long enough that splitting each of
    /// them again across the node's cores (the two-level Eq. (1)
    /// partition of [`crate::engine::shard`]) amortizes the extra
    /// tier-1 merge work.  Checked before the plain cloud rule.
    pub shard_min_n: usize,
    /// Rule 4 — the vector unit is preferred when every speculative chunk
    /// fits its initial states into one 8-lane register pass
    /// (I_max ≤ lanes − 1, chunk 0 taking the remaining lane) ...
    pub simd_max_i_max: usize,
    /// ... and the input is small enough that a single vector unit beats
    /// fanning out to |P| cores.
    pub simd_max_n: usize,
    /// The measured host rate (symbols/µs) these thresholds were derived
    /// from, or `None` for the baked-in 500 sym/µs paper-era ballpark.
    /// Provenance only — [`select`] never reads it — but it lets serving
    /// telemetry distinguish calibrated routing from the default guess.
    pub calibrated_rate: Option<f64>,
}

impl Default for AutoThresholds {
    fn default() -> AutoThresholds {
        AutoThresholds {
            seq_max_n: 1 << 16,
            gamma_max: 0.5,
            cloud_min_n: 1 << 23,
            shard_min_n: 1 << 26,
            simd_max_i_max: 7,
            simd_max_n: 1 << 20,
            calibrated_rate: None,
        }
    }
}

impl AutoThresholds {
    /// Scale the input-size thresholds to a measured host symbol rate
    /// (`experiments::calibrate::host_syms_per_us`).  The defaults equal
    /// `calibrated(500.0)` rounded to powers of two.
    pub fn calibrated(syms_per_us: f64) -> AutoThresholds {
        let rate = syms_per_us.max(1.0);
        AutoThresholds {
            // ~120 µs of parallel plan overhead
            seq_max_n: (rate * 120.0) as usize,
            // ~16 ms of sequential work before ~20 × 362 µs of network
            // hops drop under a few percent
            cloud_min_n: (rate * 16_000.0) as usize,
            // ~128 ms of sequential work: each node chunk is then long
            // enough to re-split across the node's cores profitably
            shard_min_n: (rate * 128_000.0) as usize,
            calibrated_rate: Some(rate),
            ..AutoThresholds::default()
        }
    }

    /// Thresholds from a live §4.1 profiling run
    /// ([`crate::speculative::profile::profile_host`]) — what
    /// [`crate::engine::serve`] feeds in at startup and on re-calibration.
    pub fn from_profile(p: &CapacityProfile) -> AutoThresholds {
        AutoThresholds::calibrated(p.syms_per_us)
    }

    /// Whether these thresholds came from a measurement rather than the
    /// baked-in ballpark.
    pub fn is_calibrated(&self) -> bool {
        self.calibrated_rate.is_some()
    }
}

/// Why `Engine::Auto` picked a substrate for one request.
#[derive(Clone, Debug)]
pub struct Selection {
    /// the substrate Auto picked
    pub kind: EngineKind,
    /// the quantities the decision used
    pub q: usize,
    /// I_max,r used by the decision
    pub i_max: usize,
    /// γ = I_max,r / |Q|
    pub gamma: f64,
    /// input length in symbols
    pub n: usize,
    /// human-readable rule that fired
    pub reason: String,
}

impl std::fmt::Display for Selection {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} (gamma={:.3}, |Q|={}, I_max={}, n={}): {}",
            self.kind, self.gamma, self.q, self.i_max, self.n, self.reason
        )
    }
}

/// Pick the substrate for one request.  Rules, in order:
///
/// 1. `n < seq_max_n`                      → Sequential (overhead dominates)
/// 2. `gamma > gamma_max`                  → Sequential (structure hostile)
/// 3. `n >= shard_min_n`                   → Shard (two-level node × core
///                                           partition, corpus scale)
/// 4. `n >= cloud_min_n`                   → Cloud (network cost amortized)
/// 5. `i_max <= simd_max_i_max && n <= simd_max_n`
///                                         → Simd (one register pass/chunk)
/// 6. otherwise                            → Speculative multicore
pub fn select(props: &DfaProps, n: usize, t: &AutoThresholds) -> Selection {
    let mk = |kind: EngineKind, reason: String| Selection {
        kind,
        q: props.q,
        i_max: props.i_max,
        gamma: props.gamma,
        n,
        reason,
    };
    if n < t.seq_max_n {
        return mk(
            EngineKind::Sequential,
            format!(
                "n={n} < {} — parallel plan overhead would dominate",
                t.seq_max_n
            ),
        );
    }
    if props.gamma > t.gamma_max {
        return mk(
            EngineKind::Sequential,
            format!(
                "gamma={:.3} > {:.3} — Eq. 18 bounds parallel speedup \
                 below break-even",
                props.gamma, t.gamma_max
            ),
        );
    }
    if n >= t.shard_min_n {
        return mk(
            EngineKind::Shard,
            format!(
                "n={n} >= {} — corpus scale: two-level Eq. (1) partition \
                 across nodes and each node's cores",
                t.shard_min_n
            ),
        );
    }
    if n >= t.cloud_min_n {
        return mk(
            EngineKind::Cloud,
            format!(
                "n={n} >= {} — inter-node latency amortized, cluster \
                 capacity wins",
                t.cloud_min_n
            ),
        );
    }
    if props.i_max <= t.simd_max_i_max && n <= t.simd_max_n {
        return mk(
            EngineKind::Simd,
            format!(
                "I_max={} <= {} and n={n} <= {} — every chunk's initial \
                 states fit one vector register pass",
                props.i_max, t.simd_max_i_max, t.simd_max_n
            ),
        );
    }
    mk(
        EngineKind::Speculative,
        format!(
            "gamma={:.3} <= {:.3} at multicore scale — speculative \
             chunk-parallel matching",
            props.gamma, t.gamma_max
        ),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::regex::compile::{compile_prosite, compile_search};

    #[test]
    fn rules_fire_in_order_on_a_structured_dfa() {
        // literal search DFA: tiny I_max, gamma well under 1/2
        let dfa = compile_search("needle").unwrap();
        let props = DfaProps::analyze(&dfa, 4);
        assert!(props.i_max <= 4, "I_max {}", props.i_max);
        assert!(props.gamma <= 0.5, "gamma {}", props.gamma);
        let t = AutoThresholds::default();

        assert_eq!(select(&props, 1 << 10, &t).kind, EngineKind::Sequential);
        assert_eq!(select(&props, 1 << 18, &t).kind, EngineKind::Simd);
        assert_eq!(select(&props, 1 << 21, &t).kind, EngineKind::Speculative);
        assert_eq!(select(&props, 1 << 24, &t).kind, EngineKind::Cloud);
        assert_eq!(select(&props, 1 << 27, &t).kind, EngineKind::Shard);
    }

    #[test]
    fn corpus_scale_prefers_the_hierarchical_shard() {
        let dfa = compile_search("needle").unwrap();
        let props = DfaProps::analyze(&dfa, 4);
        let t = AutoThresholds::default();
        let sel = select(&props, t.shard_min_n, &t);
        assert_eq!(sel.kind, EngineKind::Shard, "{sel}");
        assert!(sel.reason.contains("two-level"), "{}", sel.reason);
        // just below the corpus threshold the flat cloud engine wins
        let sel = select(&props, t.shard_min_n - 1, &t);
        assert_eq!(sel.kind, EngineKind::Cloud, "{sel}");
    }

    #[test]
    fn hostile_structure_stays_sequential_at_any_size() {
        // force gamma = 1 by disabling lookahead benefits: a DFA where the
        // analysis keeps I_max = |Q| is hard to construct portably, so
        // emulate with explicit props.
        let props = DfaProps {
            q: 100,
            sigma: 4,
            r: 4,
            i_max: 80,
            gamma: 0.8,
        };
        let t = AutoThresholds::default();
        for n in [1 << 12, 1 << 18, 1 << 24, 1 << 27] {
            assert_eq!(select(&props, n, &t).kind, EngineKind::Sequential);
        }
    }

    #[test]
    fn prosite_signatures_are_speculation_friendly() {
        // the paper's headline workload: PROSITE DFAs have I_max << |Q|
        let dfa = compile_prosite("C-x(2)-C-x(3)-[LIVMFYWC].").unwrap();
        let props = DfaProps::analyze(&dfa, 4);
        assert!(
            props.i_max < props.q,
            "lookahead found no structure: I_max {} |Q| {}",
            props.i_max,
            props.q
        );
        let t = AutoThresholds::default();
        let sel = select(&props, 1 << 22, &t);
        if props.gamma <= t.gamma_max {
            assert_eq!(sel.kind, EngineKind::Speculative, "{sel}");
        } else {
            assert_eq!(sel.kind, EngineKind::Sequential, "{sel}");
        }
    }

    #[test]
    fn calibration_scales_input_thresholds() {
        let slow = AutoThresholds::calibrated(50.0);
        let fast = AutoThresholds::calibrated(5000.0);
        assert!(slow.seq_max_n < fast.seq_max_n);
        assert!(slow.cloud_min_n < fast.cloud_min_n);
        assert!(slow.shard_min_n < fast.shard_min_n);
        assert!(slow.cloud_min_n < slow.shard_min_n);
        assert_eq!(slow.gamma_max, fast.gamma_max);
    }

    #[test]
    fn calibration_records_provenance() {
        assert!(!AutoThresholds::default().is_calibrated());
        let t = AutoThresholds::calibrated(123.0);
        assert!(t.is_calibrated());
        assert_eq!(t.calibrated_rate, Some(123.0));
        assert_ne!(t, AutoThresholds::default());
        let p = CapacityProfile { syms_per_us: 123.0, runs: 3, sample_syms: 4096 };
        assert_eq!(AutoThresholds::from_profile(&p), t);
    }

    #[test]
    fn selection_reports_the_decision_inputs() {
        let dfa = compile_search("abc").unwrap();
        let props = DfaProps::analyze(&dfa, 2);
        let sel = select(&props, 10, &AutoThresholds::default());
        assert_eq!(sel.n, 10);
        assert_eq!(sel.q, props.q);
        let line = format!("{sel}");
        assert!(line.contains("gamma="), "{line}");
        assert!(line.contains("seq"), "{line}");
    }
}
