//! The speculative parallel matcher: Algorithm 2 (basic) and Algorithm 3
//! (initial-state-set optimized), executed over OS threads.
//!
//! `MatchPlan` is the configuration builder; `run`/`run_syms` perform the
//! four steps of §4.1: (1) weights from offline profiling are supplied by
//! the caller, (2) partition the input (partition.rs), (3) match chunks in
//! parallel, each chunk for its set of possible initial states, and
//! (4) merge the per-chunk L-vectors (merge.rs).
//!
//! Failure-freedom (the paper's headline property) is enforced by
//! construction and verified by property tests: the outcome is *always*
//! identical to sequential matching, and the per-processor work is bounded
//! so no configuration can be slower than the sequential run by more than
//! the merge cost.

use std::time::Instant;

use crate::automata::{Dfa, FlatDfa};
use crate::speculative::chunk::match_chunk_states;
use crate::speculative::lookahead::Lookahead;
use crate::speculative::lvector::LVector;
use crate::speculative::merge::{self, MergeStats, MergeStrategy};
use crate::speculative::partition::{partition, partition_with_sizes, Chunk};

/// Compute the chunk layout and per-chunk initial-state sets for one run.
///
/// `adaptive = false` is the paper's Algorithm 3: size every subsequent
/// chunk for the worst case (`m` = I_max,r or |Q|), then look up the
/// actual set at each boundary.  `adaptive = true` is this repo's
/// extension: iterate partition ↔ actual set sizes to a fixed point, so
/// chunk lengths match the work each chunk really has (see
/// partition_with_sizes; ablation in the table3 bench).
pub(crate) fn plan_chunks(
    dfa: &Dfa,
    lookahead: Option<&Lookahead>,
    syms: &[u32],
    weights: &[f64],
    m: usize,
    adaptive: bool,
) -> (Vec<Chunk>, Vec<Vec<u32>>) {
    let n = syms.len();
    let p = weights.len();
    let sets_for = |chunks: &[Chunk]| -> Vec<Vec<u32>> {
        chunks
            .iter()
            .map(|c| {
                if c.proc == 0 {
                    vec![dfa.start]
                } else {
                    match lookahead {
                        Some(la) => {
                            let lo = c.start.saturating_sub(la.r);
                            la.initial_set(dfa, &syms[lo..c.start])
                                .iter()
                                .map(|s| s as u32)
                                .collect()
                        }
                        None => (0..dfa.num_states).collect(),
                    }
                }
            })
            .collect()
    };

    if !adaptive || lookahead.is_none() {
        let chunks = partition(n, weights, m);
        let sets = sets_for(&chunks);
        return (chunks, sets);
    }

    // Adaptive: the set size at any candidate boundary is an exact,
    // cheaply computable function of the r-symbol suffix there, so build
    // chunks left-to-right against a per-processor work target T
    // (work_k = len_k · |I_suffix(start_k)| / w_k ≤ T) and binary-search
    // the smallest feasible T.  Boundaries and sets stay consistent by
    // construction.  T = n/w_min is always feasible (chunk 0 covers
    // everything), so the makespan never exceeds the sequential work —
    // the extension stays failure-free.
    let la = lookahead.unwrap();
    let size_at = |start: usize| -> usize {
        if start == 0 {
            1
        } else {
            let lo = start.saturating_sub(la.r);
            la.initial_set(dfa, &syms[lo..start]).len().max(1)
        }
    };
    let build = |t: f64| -> Option<Vec<Chunk>> {
        let mut chunks = Vec::with_capacity(p);
        let mut start = 0usize;
        for (k, &w) in weights.iter().enumerate() {
            let s = if k == 0 { 1 } else { size_at(start) };
            let len = ((t * w / s as f64).floor() as usize).max(1);
            let end = if k == p - 1 { n } else { (start + len).min(n) };
            if k == p - 1 && start + len < n {
                return None; // T too small: last chunk overflows target
            }
            chunks.push(Chunk { proc: k, start, end });
            start = end;
        }
        Some(chunks)
    };
    let w_min = weights.iter().cloned().fold(f64::INFINITY, f64::min);
    let mut lo = 0.0f64;
    let mut hi = (n as f64 / w_min).max(1.0);
    let mut best = build(hi).expect("T = n/w_min must be feasible");
    for _ in 0..50 {
        let mid = 0.5 * (lo + hi);
        match build(mid) {
            Some(c) => {
                best = c;
                hi = mid;
            }
            None => lo = mid,
        }
    }
    let sets = sets_for(&best);
    (best, sets)
}

/// Per-worker execution record: the inputs to every cost/speedup model.
#[derive(Clone, Debug)]
pub struct WorkerWork {
    /// worker/processor index
    pub proc: usize,
    /// chunk start offset in the input
    pub chunk_start: usize,
    /// chunk length in symbols
    pub chunk_len: usize,
    /// initial states matched for this chunk (1 for chunk 0)
    pub states_matched: usize,
    /// symbol steps actually executed: `chunk_len * states_matched`
    /// minus the work convergence collapsing removed
    pub syms_matched: usize,
    /// speculative chains merged by convergence collapsing (0 when the
    /// plan runs without it)
    pub collapses: usize,
    /// measured wall time of this worker's matching loop, seconds
    pub elapsed_s: f64,
}

/// Result of a speculative parallel run.
#[derive(Clone, Debug)]
pub struct MatchOutcome {
    /// delta*(q0, input) — identical to the sequential run
    pub final_state: u32,
    /// membership verdict
    pub accepted: bool,
    /// partitioning parameter m used (|Q| or I_max,r)
    pub m: usize,
    /// per-worker execution records
    pub work: Vec<WorkerWork>,
    /// merge op counts
    pub merge_stats: MergeStats,
    /// per-chunk L-vectors (kept for inspection; small: |P| × |Q|)
    pub lvectors: Vec<LVector>,
}

impl MatchOutcome {
    /// Max symbols matched by any worker — the parallel makespan in
    /// symbol units (the quantity Eq. (14) bounds).
    pub fn makespan_syms(&self) -> usize {
        self.work.iter().map(|w| w.syms_matched).max().unwrap_or(0)
    }

    /// Total redundant work introduced by speculation, in symbols.
    pub fn speculative_overhead_syms(&self, n: usize) -> usize {
        let total: usize = self.work.iter().map(|w| w.syms_matched).sum();
        total.saturating_sub(n)
    }

    /// Total chains merged by convergence collapsing across all workers.
    pub fn collapses(&self) -> usize {
        self.work.iter().map(|w| w.collapses).sum()
    }
}

/// Configuration builder for speculative parallel matching.
///
/// Owns its (cheaply cloned) DFA plus the flattened table, so a plan can
/// be built once per pattern and reused across requests — the contract
/// the [`crate::engine`] facade's batched serving path relies on.
#[derive(Clone, Debug)]
pub struct MatchPlan {
    dfa: Dfa,
    flat: FlatDfa,
    processors: usize,
    /// reverse lookahead depth r; 0 = basic Algorithm 2 (match all |Q|)
    r: usize,
    lookahead: Option<Lookahead>,
    weights: Vec<f64>,
    merge: MergeStrategy,
    use_threads: bool,
    adaptive: bool,
    collapse_every: usize,
}

impl MatchPlan {
    /// A single-processor plan over `dfa`; shape it with the builders.
    pub fn new(dfa: &Dfa) -> Self {
        MatchPlan {
            dfa: dfa.clone(),
            flat: FlatDfa::from_dfa(dfa),
            processors: 1,
            r: 0,
            lookahead: None,
            weights: vec![1.0],
            merge: MergeStrategy::Sequential,
            use_threads: true,
            adaptive: false,
            collapse_every: 0,
        }
    }

    /// Enable convergence collapsing: every `every` symbols, chains that
    /// have reached the same state are merged (a DFA is deterministic,
    /// so converged chains stay identical forever) and drop out of the
    /// inner loop.  The outcome is byte-identical; only `syms_matched`
    /// shrinks.  0 (the default) disables the check.
    pub fn collapse_every(mut self, every: usize) -> Self {
        self.collapse_every = every;
        self
    }

    /// Enable the adaptive (fixed-point) partition extension: chunk
    /// lengths follow the *actual* per-boundary initial-state counts
    /// instead of the worst-case I_max,r.  Requires lookahead(r >= 1).
    pub fn adaptive_partition(mut self, on: bool) -> Self {
        self.adaptive = on;
        self
    }

    /// Number of processors |P| (uniform weights unless `weights` is set).
    pub fn processors(mut self, p: usize) -> Self {
        assert!(p >= 1);
        self.processors = p;
        if self.weights.len() != p {
            self.weights = vec![1.0; p];
        }
        self
    }

    /// Enable the I_max,r optimization (Algorithm 3) with r reverse
    /// lookahead symbols; r = 0 reverts to basic Algorithm 2.
    pub fn lookahead(mut self, r: usize) -> Self {
        self.r = r;
        self.lookahead =
            if r > 0 { Some(Lookahead::analyze(&self.dfa, r)) } else { None };
        self
    }

    /// Inject a precomputed lookahead analysis (must come from this DFA),
    /// skipping the redundant `Lookahead::analyze` when the caller — e.g.
    /// the [`crate::engine`] facade — shares one analysis across engines.
    pub fn with_lookahead(mut self, la: Lookahead) -> Self {
        self.r = la.r;
        self.lookahead = Some(la);
        self
    }

    /// Per-processor weights (Eq. 1; from profile::weights_from_capacities).
    pub fn weights(mut self, w: Vec<f64>) -> Self {
        assert_eq!(w.len(), self.processors, "one weight per processor");
        self.weights = w;
        self
    }

    /// Override the merge strategy (default: sequential Eq. 8).
    pub fn merge_strategy(mut self, s: MergeStrategy) -> Self {
        self.merge = s;
        self
    }

    /// Run workers inline on the calling thread (deterministic timing for
    /// the simulation harness) instead of spawning OS threads.
    pub fn sequential_execution(mut self) -> Self {
        self.use_threads = false;
        self
    }

    /// The partitioning parameter m: I_max,r with lookahead, |Q| without.
    pub fn i_max(&self) -> usize {
        self.lookahead
            .as_ref()
            .map(|la| la.i_max)
            .unwrap_or(self.dfa.num_states as usize)
    }

    /// γ = I_max,r / |Q| (Eq. 18).
    pub fn gamma(&self) -> f64 {
        self.i_max() as f64 / self.dfa.num_states as f64
    }

    /// Match raw bytes (applies the IBase class mapping first).
    pub fn run(&self, input: &[u8]) -> MatchOutcome {
        self.run_syms(&self.dfa.map_input(input))
    }

    /// Match pre-mapped dense symbols — the paper's measured configuration
    /// (its framework also pre-converts input to the IBase form, Fig. 8d).
    pub fn run_syms(&self, syms: &[u32]) -> MatchOutcome {
        let q = self.dfa.num_states as usize;
        let m = self.i_max().max(1);

        // chunk layout + per-chunk initial-state sets (Algorithm 3
        // lines 1–7 at plan construction; runtime lookup here)
        let (chunks, sets) = plan_chunks(
            &self.dfa,
            self.lookahead.as_ref(),
            syms,
            &self.weights,
            m,
            self.adaptive,
        );

        let collapse = self.collapse_every;
        let mut results: Vec<(LVector, WorkerWork)> =
            Vec::with_capacity(chunks.len());
        if self.use_threads {
            let mut slots: Vec<Option<(LVector, WorkerWork)>> =
                vec![None; chunks.len()];
            std::thread::scope(|scope| {
                let flat = &self.flat;
                for (slot, (chunk, set)) in
                    slots.iter_mut().zip(chunks.iter().zip(&sets))
                {
                    scope.spawn(move || {
                        *slot = Some(match_chunk(
                            flat, q, chunk, set, syms, collapse,
                        ));
                    });
                }
            });
            results.extend(slots.into_iter().map(Option::unwrap));
        } else {
            for (chunk, set) in chunks.iter().zip(&sets) {
                results.push(match_chunk(
                    &self.flat, q, chunk, set, syms, collapse,
                ));
            }
        }

        let (lvectors, work): (Vec<LVector>, Vec<WorkerWork>) =
            results.into_iter().unzip();
        let (final_state, merge_stats) =
            merge::merge(&lvectors, self.dfa.start, self.merge);
        MatchOutcome {
            final_state,
            accepted: self.dfa.accepting[final_state as usize],
            m,
            work,
            merge_stats,
            lvectors,
        }
    }

}

/// Match one chunk for each possible initial state (Algorithm 2/3 inner
/// loops) and record the work done.  The chunk is validated once here
/// (not once per state group) and handed to the shared 8-wide kernel
/// with optional convergence collapsing.
fn match_chunk(
    flat: &FlatDfa,
    q: usize,
    chunk: &Chunk,
    set: &[u32],
    syms: &[u32],
    collapse_every: usize,
) -> (LVector, WorkerWork) {
    let t0 = Instant::now();
    let mut lv = LVector::identity(q);
    let chunk_syms = flat.validate(&syms[chunk.start..chunk.end]);
    let work =
        match_chunk_states(flat, &mut lv, set, chunk_syms, collapse_every);
    let elapsed_s = t0.elapsed().as_secs_f64();
    (
        lv,
        WorkerWork {
            proc: chunk.proc,
            chunk_start: chunk.start,
            chunk_len: chunk.len(),
            states_matched: set.len(),
            syms_matched: work.syms_matched,
            collapses: work.collapses,
            elapsed_s,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::sequential::SequentialMatcher;
    use crate::regex::compile::{compile_prosite, compile_search};
    use crate::speculative::lookahead::tests::{fig6_dfa, random_dfa};
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn random_syms(rng: &mut Rng, dfa: &Dfa, len: usize) -> Vec<u32> {
        (0..len).map(|_| rng.below(dfa.num_symbols as u64) as u32).collect()
    }

    #[test]
    fn matches_sequential_on_fig6() {
        let dfa = fig6_dfa();
        // the paper's 36-symbol input (Fig. 6b): a=0, b=1
        let input: Vec<u32> = "bababbababbaabbaabbaaabbaabbaaabaa"
            .bytes()
            .map(|b| if b == b'a' { 0 } else { 1 })
            .collect();
        let seq = SequentialMatcher::new(&dfa);
        let want = seq.run_syms(&input);
        for p in [1, 2, 3, 5] {
            for r in [0, 1, 2] {
                let plan = MatchPlan::new(&dfa).processors(p).lookahead(r);
                let out = plan.run_syms(&input);
                assert_eq!(out.final_state, want.final_state, "p={p} r={r}");
                assert_eq!(out.accepted, want.accepted);
            }
        }
    }

    #[test]
    fn prop_failure_freedom_random_dfas() {
        // THE core property: every parallel configuration returns exactly
        // the sequential result.
        prop::check("parallel == sequential (random DFAs)", 60, |rng| {
            let dfa = random_dfa(rng);
            let len = rng.range_usize(0, 500);
            let syms = random_syms(rng, &dfa, len);
            let seq = SequentialMatcher::new(&dfa);
            let want = seq.run_syms(&syms);
            let p = rng.range_usize(1, 12);
            let r = rng.range_usize(0, 4);
            let weights: Vec<f64> =
                (0..p).map(|_| 0.5 + rng.f64() * 2.0).collect();
            let strat = match rng.below(3) {
                0 => MergeStrategy::Sequential,
                1 => MergeStrategy::BinaryTree,
                _ => MergeStrategy::Hierarchical {
                    cores_per_node: rng.range_usize(1, 5),
                },
            };
            let plan = MatchPlan::new(&dfa)
                .processors(p)
                .lookahead(r)
                .weights(weights)
                .merge_strategy(strat);
            let out = plan.run_syms(&syms);
            assert_eq!(out.final_state, want.final_state,
                       "p={p} r={r} strat={strat:?} len={len}");
            assert_eq!(out.accepted, want.accepted);
        });
    }

    #[test]
    fn prop_failure_freedom_real_patterns() {
        let patterns = ["(ab|cd)+", "a*b?c{2,4}", "hello|world",
                        r"[0-9]{1,3}(\.[0-9]{1,3}){3}"];
        prop::check("parallel == sequential (regex DFAs)", 20, |rng| {
            let pat = patterns[rng.usize_below(patterns.len())];
            let dfa = compile_search(pat).unwrap();
            let len = rng.range_usize(0, 2000);
            let bytes: Vec<u8> = (0..len)
                .map(|_| b"abcdhello world.0123456789"[rng.usize_below(26)])
                .collect();
            let seq = SequentialMatcher::new(&dfa);
            let want = seq.run_bytes(&bytes);
            let plan = MatchPlan::new(&dfa)
                .processors(rng.range_usize(1, 8))
                .lookahead(rng.range_usize(0, 3));
            let out = plan.run(&bytes);
            assert_eq!(out.accepted, want.accepted, "pat={pat}");
            assert_eq!(out.final_state, want.final_state);
        });
    }

    #[test]
    fn lookahead_reduces_work() {
        // PROSITE-style DFA with structure: I_max < |Q| must cut overhead
        let dfa = compile_prosite("C-x(2)-C-x(3)-[LIVMFYWC].").unwrap();
        let mut rng = Rng::new(42);
        let syms: Vec<u32> = (0..100_000)
            .map(|_| rng.below(dfa.num_symbols as u64) as u32)
            .collect();
        let basic = MatchPlan::new(&dfa).processors(8).run_syms(&syms);
        let opt =
            MatchPlan::new(&dfa).processors(8).lookahead(4).run_syms(&syms);
        assert!(opt.m < basic.m, "I_max {} !< |Q| {}", opt.m, basic.m);
        assert!(
            opt.speculative_overhead_syms(syms.len())
                < basic.speculative_overhead_syms(syms.len())
        );
        assert!(opt.makespan_syms() < basic.makespan_syms());
        assert_eq!(opt.final_state, basic.final_state);
    }

    #[test]
    fn makespan_bounded_by_eq14() {
        // Eq. (14): parallel time ~ n·m/(m+|P|-1) symbols per processor
        let dfa = fig6_dfa();
        let mut rng = Rng::new(7);
        let n = 120_000;
        let syms = random_syms(&mut rng, &dfa, n);
        for p in [2, 4, 8] {
            let out = MatchPlan::new(&dfa).processors(p).run_syms(&syms);
            let m = out.m as f64;
            let bound = (n as f64) * m / (m + p as f64 - 1.0);
            let makespan = out.makespan_syms() as f64;
            assert!(
                makespan <= bound * 1.02 + 64.0,
                "p={p}: makespan {makespan} > bound {bound}"
            );
        }
    }

    #[test]
    fn chunk0_matched_once() {
        let dfa = fig6_dfa();
        let mut rng = Rng::new(8);
        let syms = random_syms(&mut rng, &dfa, 10_000);
        let out = MatchPlan::new(&dfa).processors(4).run_syms(&syms);
        assert_eq!(out.work[0].states_matched, 1);
        for w in &out.work[1..] {
            assert!(w.states_matched >= 1);
        }
    }

    #[test]
    fn empty_input() {
        let dfa = fig6_dfa();
        for p in [1, 3] {
            let out = MatchPlan::new(&dfa).processors(p).run_syms(&[]);
            assert_eq!(out.final_state, dfa.start);
        }
    }

    #[test]
    fn inline_execution_equals_threads() {
        let dfa = fig6_dfa();
        let mut rng = Rng::new(9);
        let syms = random_syms(&mut rng, &dfa, 5000);
        let threaded =
            MatchPlan::new(&dfa).processors(6).lookahead(2).run_syms(&syms);
        let inline = MatchPlan::new(&dfa)
            .processors(6)
            .lookahead(2)
            .sequential_execution()
            .run_syms(&syms);
        assert_eq!(threaded.final_state, inline.final_state);
        assert_eq!(threaded.makespan_syms(), inline.makespan_syms());
    }

    #[test]
    fn prop_collapsing_is_failure_free() {
        // collapsing must never change the outcome, only the work
        prop::check("collapse == sequential (random DFAs)", 40, |rng| {
            let dfa = random_dfa(rng);
            let len = rng.range_usize(0, 1200);
            let syms = random_syms(rng, &dfa, len);
            let want = SequentialMatcher::new(&dfa).run_syms(&syms);
            let plan = MatchPlan::new(&dfa)
                .processors(rng.range_usize(1, 8))
                .lookahead(rng.range_usize(0, 3))
                .collapse_every(rng.range_usize(1, 200));
            let out = plan.run_syms(&syms);
            assert_eq!(out.final_state, want.final_state, "len={len}");
            assert_eq!(out.accepted, want.accepted);
        });
    }

    #[test]
    fn collapsing_reduces_work_on_high_gamma_dfa() {
        // exact-match DFA without lookahead: every chunk speculates over
        // all |Q| states (gamma = 1) and every chain falls into the sink
        // within a few symbols, so collapsing must strictly cut the work
        let dfa = crate::regex::compile::compile_exact("abcde").unwrap();
        let mut rng = Rng::new(0xC011);
        let syms = random_syms(&mut rng, &dfa, 200_000);
        let plain = MatchPlan::new(&dfa).processors(8).run_syms(&syms);
        let collapsed = MatchPlan::new(&dfa)
            .processors(8)
            .collapse_every(128)
            .run_syms(&syms);
        assert_eq!(plain.final_state, collapsed.final_state);
        let total = |o: &MatchOutcome| -> usize {
            o.work.iter().map(|w| w.syms_matched).sum()
        };
        assert!(
            total(&collapsed) < total(&plain),
            "collapsing must reduce syms_matched: {} !< {}",
            total(&collapsed),
            total(&plain)
        );
        assert!(collapsed.collapses() > 0);
        assert_eq!(plain.collapses(), 0);
    }
}

#[cfg(test)]
mod adaptive_tests {
    use super::*;
    use crate::baseline::sequential::SequentialMatcher;
    use crate::regex::compile::compile_prosite;
    use crate::speculative::lookahead::tests::random_dfa;
    use crate::util::prop;
    use crate::util::rng::Rng;
    use crate::util::stats;

    #[test]
    fn prop_adaptive_is_failure_free() {
        prop::check("adaptive partition == sequential", 40, |rng| {
            let dfa = random_dfa(rng);
            let len = rng.range_usize(0, 2000);
            let syms: Vec<u32> = (0..len)
                .map(|_| rng.below(dfa.num_symbols as u64) as u32)
                .collect();
            let want = SequentialMatcher::new(&dfa).run_syms(&syms);
            let out = MatchPlan::new(&dfa)
                .processors(rng.range_usize(1, 10))
                .lookahead(rng.range_usize(1, 4))
                .adaptive_partition(true)
                .run_syms(&syms);
            assert_eq!(out.final_state, want.final_state);
        });
    }

    #[test]
    fn adaptive_balances_better_than_worst_case() {
        // gap-heavy PROSITE DFA: per-suffix set sizes vary well below
        // I_max, so the worst-case partition leaves slack that the
        // adaptive fixed-point removes.
        let dfa = compile_prosite(
            "C-x(2,4)-C-x(3)-[LIVMFYWC]-x(4)-H-x(3,5)-H.",
        )
        .unwrap();
        // realistic protein stream (uniform class streams constantly hit
        // the non-amino catch-all class, which no protein input contains)
        let mut gen = crate::workload::InputGen::new(0xADA);
        let syms = dfa.map_input(&gen.protein(400_000));
        let cv = |out: &MatchOutcome| {
            let times: Vec<f64> = out
                .work
                .iter()
                .map(|w| w.syms_matched as f64)
                .collect();
            stats::cv(&times)
        };
        let fixed = MatchPlan::new(&dfa)
            .processors(16)
            .lookahead(4)
            .run_syms(&syms);
        let adapt = MatchPlan::new(&dfa)
            .processors(16)
            .lookahead(4)
            .adaptive_partition(true)
            .run_syms(&syms);
        assert_eq!(fixed.final_state, adapt.final_state);
        // the adaptive partition's guarantees: strictly better balance
        // and a substantially shorter makespan (the worst-case partition
        // oversizes chunk 0 whenever typical |I_suffix| < I_max)
        assert!(cv(&adapt) < cv(&fixed),
                "adaptive CV {} !< fixed CV {}", cv(&adapt), cv(&fixed));
        assert!(adapt.makespan_syms() as f64
                    <= fixed.makespan_syms() as f64 * 0.8,
                "adaptive makespan {} not <20% better than fixed {}",
                adapt.makespan_syms(), fixed.makespan_syms());
    }

    #[test]
    fn adaptive_never_exceeds_sequential_work_per_proc() {
        let mut rng = Rng::new(0xADB);
        for _ in 0..10 {
            let dfa = random_dfa(&mut rng);
            let n = 100_000;
            let syms: Vec<u32> = (0..n)
                .map(|_| rng.below(dfa.num_symbols as u64) as u32)
                .collect();
            let out = MatchPlan::new(&dfa)
                .processors(8)
                .lookahead(2)
                .adaptive_partition(true)
                .run_syms(&syms);
            assert!(out.makespan_syms() <= n + dfa.num_states as usize);
        }
    }
}
