//! L-vectors: a chunk's mapping from possible initial states to last
//! active states (§4.1, notation of [19]).
//!
//! `L_i = [l_0 .. l_{|Q|-1}]` with `l_j = delta*(q_j, c_i)`.  When the
//! I_max optimization restricts the initial-state set, the unmatched
//! entries keep the identity mapping — they are never consulted (lookahead
//! soundness, verified by property tests), and identity keeps absorbing
//! states (e.g. the sink) correct for free.

/// Dense chunk state map.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LVector {
    map: Vec<u32>,
    /// which entries were actually matched (diagnostics/tests)
    matched: Vec<bool>,
}

impl LVector {
    /// Identity map over |Q| states.
    pub fn identity(q: usize) -> LVector {
        LVector {
            map: (0..q as u32).collect(),
            matched: vec![false; q],
        }
    }

    /// |Q| — the number of entries.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Whether the map has zero entries.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Record delta*(init, chunk) = fin.
    #[inline]
    pub fn set(&mut self, init: u32, fin: u32) {
        self.map[init as usize] = fin;
        self.matched[init as usize] = true;
    }

    /// The mapped final state for `init` (identity if never set).
    #[inline]
    pub fn get(&self, init: u32) -> u32 {
        self.map[init as usize]
    }

    /// Whether `init` was actually matched (vs the identity default).
    pub fn was_matched(&self, init: u32) -> bool {
        self.matched[init as usize]
    }

    /// Number of grounded (matched) entries.
    pub fn matched_count(&self) -> usize {
        self.matched.iter().filter(|&&m| m).count()
    }

    /// Eq. (9): combined map `L_{i,j}[q] = L_j[L_i[q]]`.
    pub fn compose(&self, next: &LVector) -> LVector {
        debug_assert_eq!(self.len(), next.len());
        LVector {
            map: self.map.iter().map(|&m| next.map[m as usize]).collect(),
            // entry q of the composition is grounded iff this chunk matched
            // q (the next chunk's entry for map[q] is then sound by the
            // lookahead-soundness invariant)
            matched: self.matched.clone(),
        }
    }

    /// Raw map access (padded upload to the PJRT compose kernel).
    pub fn as_slice(&self) -> &[u32] {
        &self.map
    }

    /// Rebuild an L-vector from raw parts — the checkpoint
    /// deserialization path (`engine::stream`).  Panics when the two
    /// vectors disagree in length or a map entry is out of range: a
    /// checkpoint that fails these invariants is corrupt and must not
    /// silently produce an out-of-bounds compose.
    pub fn from_raw(map: Vec<u32>, matched: Vec<bool>) -> LVector {
        assert_eq!(map.len(), matched.len(), "map/matched length mismatch");
        let q = map.len() as u32;
        assert!(
            map.iter().all(|&m| m < q),
            "map entry out of range for |Q| = {q}"
        );
        LVector { map, matched }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_maps_self() {
        let l = LVector::identity(5);
        for q in 0..5 {
            assert_eq!(l.get(q), q);
            assert!(!l.was_matched(q));
        }
    }

    #[test]
    fn set_get_roundtrip() {
        let mut l = LVector::identity(4);
        l.set(2, 3);
        assert_eq!(l.get(2), 3);
        assert!(l.was_matched(2));
        assert_eq!(l.matched_count(), 1);
        assert_eq!(l.get(1), 1);
    }

    #[test]
    fn compose_is_function_composition() {
        // paper example: L2 = [qe, q1] over {q0,q1(,qe)} — use 3 states
        let mut l1 = LVector::identity(3);
        l1.set(0, 1); // q0 -> q1
        l1.set(1, 2);
        let mut l2 = LVector::identity(3);
        l2.set(0, 2);
        l2.set(1, 1);
        l2.set(2, 2);
        let c = l1.compose(&l2);
        assert_eq!(c.get(0), l2.get(l1.get(0)));
        assert_eq!(c.get(0), 1);
        assert_eq!(c.get(1), 2);
    }

    #[test]
    fn compose_associative() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(9);
        for _ in 0..50 {
            let q = rng.range_usize(1, 20);
            let mk = |rng: &mut Rng| {
                let mut l = LVector::identity(q);
                for i in 0..q {
                    l.set(i as u32, rng.below(q as u64) as u32);
                }
                l
            };
            let (a, b, c) = (mk(&mut rng), mk(&mut rng), mk(&mut rng));
            let left = a.compose(&b).compose(&c);
            let right = a.compose(&b.compose(&c));
            assert_eq!(left.as_slice(), right.as_slice());
        }
    }

    #[test]
    fn identity_neutral_for_compose() {
        let mut a = LVector::identity(6);
        for i in 0..6 {
            a.set(i, (i + 1) % 6);
        }
        let id = LVector::identity(6);
        assert_eq!(a.compose(&id).as_slice(), a.as_slice());
        assert_eq!(id.compose(&a).as_slice(), a.as_slice());
    }
}
