//! Structural DFA analysis: initial-state sets and I_max,r (§4.2/§4.3).
//!
//! * Eq. (11): I_σ = { s : δ(x, σ) = s for some x } ∖ {q_e}
//! * Eq. (13): I_{σ1..σr} = { s : δ*(x, σ1..σr) = s } ∖ {q_e}
//! * Eq. (12): I_max,r = max over all r-grams of |I_{σ1..σr}|
//!
//! Two computations of I_max,r are provided:
//!  * [`Lookahead::analyze`] — image-set BFS with deduplication: level k
//!    holds the distinct sets {image(S, σ)}; far cheaper than enumerating
//!    all |Σ|^r suffixes while computing the exact same maximum.
//!  * [`i_max_r_naive`] — the paper's Algorithm 4 (exponential in r),
//!    kept verbatim as the overhead-measurement subject of Fig. 17.
//!
//! The error state is excluded everywhere (§3: "for these considerations
//! the error state q_e can be ignored"), which is sound because q_e is
//! absorbing and the identity L-vector entry is already correct for it.

use std::collections::HashSet;

use crate::automata::Dfa;
use crate::util::bitset::BitSet;

/// Precomputed lookahead structure for a DFA.
#[derive(Clone, Debug)]
pub struct Lookahead {
    /// r used for the analysis (≥ 1)
    pub r: usize,
    /// I_max,r (Eq. 12) — the partitioning parameter
    pub i_max: usize,
    /// I_max,k for k = 1..=r (Lemma 1 monotonicity; diagnostics)
    pub i_max_by_r: Vec<usize>,
    /// per-symbol one-step sets I_σ (Eq. 11)
    pub sets1: Vec<BitSet>,
    /// sink state if present (excluded from sets)
    pub sink: Option<u32>,
}

impl Lookahead {
    /// Analyze a DFA for up to `r` reverse lookahead symbols.
    pub fn analyze(dfa: &Dfa, r: usize) -> Lookahead {
        assert!(r >= 1, "lookahead requires r >= 1");
        let q = dfa.num_states as usize;
        let s = dfa.num_symbols as usize;
        let sink = dfa.sink();

        // level 1: I_σ per symbol
        let mut sets1: Vec<BitSet> = vec![BitSet::new(q); s];
        for state in 0..q as u32 {
            for sym in 0..s as u32 {
                let t = dfa.step(state, sym);
                if Some(t) != sink {
                    sets1[sym as usize].insert(t as usize);
                }
            }
        }

        // Distinct-image BFS with a level-size cap: if the set of distinct
        // suffix images explodes (pathological DFAs), stop refining and
        // keep the last completed level's maximum — a sound upper bound by
        // Lemma 1 (I_max,r is non-increasing in r), so partitioning stays
        // failure-free, merely slightly conservative.
        const LEVEL_CAP: usize = 50_000;
        let mut i_max_by_r = Vec::with_capacity(r);
        let mut level: HashSet<BitSet> = sets1.iter().cloned().collect();
        i_max_by_r.push(level.iter().map(|b| b.len()).max().unwrap_or(0));
        for _ in 1..r {
            if level.len() * s > LEVEL_CAP {
                i_max_by_r.push(*i_max_by_r.last().unwrap());
                continue;
            }
            let mut next: HashSet<BitSet> = HashSet::new();
            for set in &level {
                for sym in 0..s as u32 {
                    next.insert(image(dfa, set, sym, sink));
                }
            }
            i_max_by_r.push(next.iter().map(|b| b.len()).max().unwrap_or(0));
            level = next;
        }

        let i_max = *i_max_by_r.last().unwrap();
        Lookahead { r, i_max: i_max.max(1), i_max_by_r, sets1, sink }
    }

    /// Runtime per-chunk set: the possible initial states given the
    /// observed reverse-lookahead suffix (dense symbols, matched order —
    /// `suffix.last()` is the symbol adjacent to the chunk).
    ///
    /// Uses min(r, suffix.len()) symbols.  Empty suffix (chunk at input
    /// start) returns all live states.
    pub fn initial_set(&self, dfa: &Dfa, suffix: &[u32]) -> BitSet {
        let q = dfa.num_states as usize;
        let take = suffix.len().min(self.r);
        if take == 0 {
            let mut all = BitSet::new(q);
            for st in 0..q {
                if Some(st as u32) != self.sink {
                    all.insert(st);
                }
            }
            return all;
        }
        let used = &suffix[suffix.len() - take..];
        // first symbol: precomputed I_σ; subsequent: image chaining
        let mut set = self.sets1[used[0] as usize].clone();
        for &sym in &used[1..] {
            set = image(dfa, &set, sym, self.sink);
        }
        set
    }

    /// γ = I_max,r / |Q| — the structural property of Eq. (18).
    pub fn gamma(&self, dfa: &Dfa) -> f64 {
        self.i_max as f64 / dfa.num_states as f64
    }
}

/// image(S, σ) = { δ(x, σ) : x ∈ S } ∖ {sink}
fn image(dfa: &Dfa, set: &BitSet, sym: u32, sink: Option<u32>) -> BitSet {
    let mut out = BitSet::new(set.capacity());
    for st in set.iter() {
        let t = dfa.step(st as u32, sym);
        if Some(t) != sink {
            out.insert(t as usize);
        }
    }
    out
}

/// Algorithm 4 generalized to r symbols: enumerate all |Σ|^r suffixes and
/// take the maximum target-set cardinality.  Exponential in r — used by
/// the Fig. 17 overhead experiment; `Lookahead::analyze` is the fast path.
pub fn i_max_r_naive(dfa: &Dfa, r: usize) -> usize {
    assert!(r >= 1);
    let q = dfa.num_states as usize;
    let s = dfa.num_symbols as usize;
    let sink = dfa.sink();
    let mut suffix = vec![0u32; r];
    let mut best = 0usize;
    loop {
        // compute I_{σ1..σr} for the current suffix
        let mut set = BitSet::new(q);
        for st in 0..q as u32 {
            let mut cur = st;
            for &sym in &suffix {
                cur = dfa.step(cur, sym);
            }
            if Some(cur) != sink {
                set.insert(cur as usize);
            }
        }
        best = best.max(set.len());
        // next suffix (odometer)
        let mut i = 0;
        loop {
            if i == r {
                return best.max(1);
            }
            suffix[i] += 1;
            if (suffix[i] as usize) < s {
                break;
            }
            suffix[i] = 0;
            i += 1;
        }
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::automata::dfa::tests::fig1_dfa;
    use crate::automata::grail::from_grail;
    use crate::regex::compile::{compile_prosite, compile_search};
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// The paper's Fig. 6 DFA: states q0..q3, symbols a=0, b=1; complete
    /// (no sink — every state is live).
    pub fn fig6_dfa() -> Dfa {
        from_grail(
            "(START) |- 0\n\
             0 0 1\n0 1 2\n\
             1 0 1\n1 1 3\n\
             2 0 3\n2 1 2\n\
             3 0 3\n3 1 3\n\
             3 -| (FINAL)\n",
        )
        .unwrap()
    }

    #[test]
    fn fig1_imax_is_one() {
        // motivating example: one target state per symbol => I_max = 1
        let dfa = fig1_dfa();
        let la = Lookahead::analyze(&dfa, 1);
        assert_eq!(la.i_max, 1);
        assert_eq!(la.sink, Some(2));
    }

    #[test]
    fn fig6_sets_match_paper() {
        // §4.2: I_a = {q1, q3}, I_b = {q2, q3}, I_max = 2
        let dfa = fig6_dfa();
        let la = Lookahead::analyze(&dfa, 1);
        assert_eq!(la.sets1[0].iter().collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(la.sets1[1].iter().collect::<Vec<_>>(), vec![2, 3]);
        assert_eq!(la.i_max, 2);
    }

    #[test]
    fn naive_equals_bfs() {
        for dfa in [fig1_dfa(), fig6_dfa(),
                    compile_search("(ab|ba)+c?").unwrap(),
                    compile_prosite("R-G-D.").unwrap()] {
            for r in 1..=3 {
                let la = Lookahead::analyze(&dfa, r);
                assert_eq!(la.i_max, i_max_r_naive(&dfa, r),
                           "r={r} |Q|={}", dfa.num_states);
            }
        }
    }

    #[test]
    fn lemma1_monotone_on_fixtures() {
        for dfa in [fig6_dfa(), compile_prosite("C-x(2)-C-x(3)-H.").unwrap()]
        {
            let la = Lookahead::analyze(&dfa, 4);
            for w in la.i_max_by_r.windows(2) {
                assert!(w[0] >= w[1], "Lemma 1 violated: {:?}", la.i_max_by_r);
            }
        }
    }

    #[test]
    fn prop_lemma1_monotone_random_dfas() {
        prop::check("I_max,r non-increasing in r", 40, |rng: &mut Rng| {
            let dfa = random_dfa(rng);
            let la = Lookahead::analyze(&dfa, 4);
            for w in la.i_max_by_r.windows(2) {
                assert!(w[0] >= w[1], "{:?}", la.i_max_by_r);
            }
        });
    }

    #[test]
    fn prop_lookahead_soundness() {
        // For any input, the true state after reading a prefix ending in
        // suffix σ1..σr must be inside initial_set(suffix) (or the sink).
        prop::check("initial_set contains the true state", 60, |rng| {
            let dfa = random_dfa(rng);
            let la = Lookahead::analyze(&dfa, rng.range_usize(1, 4));
            let len = rng.range_usize(1, 60);
            let syms: Vec<u32> = (0..len)
                .map(|_| rng.below(dfa.num_symbols as u64) as u32)
                .collect();
            let cut = rng.range_usize(1, len);
            let state = dfa.run(dfa.start, &syms[..cut]);
            let set = la.initial_set(&dfa, &syms[..cut]);
            if Some(state) != la.sink {
                assert!(
                    set.contains(state as usize),
                    "state {state} not in set {:?} (cut={cut})",
                    set.iter().collect::<Vec<_>>()
                );
            }
        });
    }

    #[test]
    fn prop_runtime_set_bounded_by_imax() {
        prop::check("per-chunk set <= I_max,r", 40, |rng| {
            let dfa = random_dfa(rng);
            let r = rng.range_usize(1, 3);
            let la = Lookahead::analyze(&dfa, r);
            let len = rng.range_usize(r, 40);
            let syms: Vec<u32> = (0..len)
                .map(|_| rng.below(dfa.num_symbols as u64) as u32)
                .collect();
            let set = la.initial_set(&dfa, &syms);
            assert!(set.len() <= la.i_max.max(1),
                    "set {} > imax {}", set.len(), la.i_max);
        });
    }

    #[test]
    fn gamma_in_unit_interval() {
        let dfa = fig6_dfa();
        let la = Lookahead::analyze(&dfa, 1);
        let g = la.gamma(&dfa);
        assert!(g > 0.0 && g <= 1.0);
        assert!((g - 0.5).abs() < 1e-12); // 2 / 4
    }

    /// Random complete DFA with an absorbing sink (like real pattern DFAs).
    pub fn random_dfa(rng: &mut Rng) -> Dfa {
        let q = rng.range_u64(2, 24) as u32;
        let s = rng.range_u64(2, 6) as u32;
        let sink = q - 1;
        let mut table = Vec::with_capacity((q * s) as usize);
        for state in 0..q {
            for _ in 0..s {
                if state == sink {
                    table.push(sink);
                } else if rng.chance(0.1) {
                    table.push(sink);
                } else {
                    table.push(rng.below(q as u64 - 1) as u32);
                }
            }
        }
        let accepting: Vec<bool> =
            (0..q).map(|st| st != sink && rng.chance(0.3)).collect();
        let mut classes = [0u8; 256];
        for b in 0..256 {
            classes[b] = (b % s as usize) as u8;
        }
        Dfa::new(q, s, 0, accepting, table, classes)
    }
}
