//! The shared per-chunk matching kernel: 8-wide interleaved Listing-1
//! chains with periodic **convergence collapsing**.
//!
//! Every speculative engine matches one chunk for a set of possible
//! initial states.  Two structural facts make that cheaper than
//! `|set| × chunk_len` symbol steps:
//!
//! * the chains are independent serial dependent-load chains, so eight
//!   of them interleave in one pass over the input with the loads
//!   overlapped ([`FlatDfa::run_valid_x8`]) — the scalar analog of the
//!   paper's 8 SIMD lanes;
//! * a DFA is deterministic, so once two chains occupy the same state
//!   after the same prefix they are **provably identical forever**
//!   (δ*(q, w) is a function of q and w).  Checking every
//!   `collapse_every` symbols, merged chains record an alias for their
//!   initial states and drop out of the inner loop — a pure win that
//!   preserves failure-freedom by construction, exploiting the same
//!   §4.2–4.3 structural properties that keep I_max,r small.
//!
//! High-γ DFAs (many live initial states) benefit the most: on
//! synchronizing inputs all chains collapse to one and the remaining
//! work is a single sequential scan.

use std::collections::HashMap;

use crate::automata::{FlatDfa, ValidSyms};
use crate::speculative::lvector::LVector;

/// Work accounting of one chunk-match call.
#[derive(Clone, Copy, Debug, Default)]
pub struct ChunkWork {
    /// symbol steps actually executed (= `chunk_len × |set|` when no
    /// chains collapse)
    pub syms_matched: usize,
    /// chains merged into an already-live identical chain
    pub collapses: usize,
}

/// Advance every live chain offset over one validated block.
fn step_all(flat: &FlatDfa, offs: &mut [u32], block: ValidSyms<'_>) {
    let mut groups = offs.chunks_exact_mut(8);
    for g in &mut groups {
        let starts = [g[0], g[1], g[2], g[3], g[4], g[5], g[6], g[7]];
        let fins = flat.run_valid_x8(starts, block);
        g.copy_from_slice(&fins);
    }
    let rem = groups.into_remainder();
    match rem.len() {
        0 => {}
        1 => rem[0] = flat.run_valid(rem[0], block),
        k => {
            // 2..=7 chains: pad the x8 kernel with copies of the last
            // chain — duplicate lanes load the same table entries, so
            // the interleave (the ILP win) is kept at ~no extra cost
            let mut starts = [rem[k - 1]; 8];
            starts[..k].copy_from_slice(rem);
            let fins = flat.run_valid_x8(starts, block);
            rem.copy_from_slice(&fins[..k]);
        }
    }
}

/// Merge chains that have converged onto the same row offset, keeping
/// first-occurrence order.  Survivors inherit the merged chains' initial
/// states.
fn collapse_converged(
    offs: &mut Vec<u32>,
    members: &mut Vec<Vec<u32>>,
    collapses: &mut usize,
) {
    let mut seen: HashMap<u32, usize> = HashMap::with_capacity(offs.len());
    let mut w = 0usize;
    for i in 0..offs.len() {
        match seen.entry(offs[i]) {
            std::collections::hash_map::Entry::Occupied(e) => {
                let keep = *e.get();
                let merged = std::mem::take(&mut members[i]);
                members[keep].extend(merged);
                *collapses += 1;
            }
            std::collections::hash_map::Entry::Vacant(e) => {
                e.insert(w);
                offs.swap(w, i);
                members.swap(w, i);
                w += 1;
            }
        }
    }
    offs.truncate(w);
    members.truncate(w);
}

/// Match one chunk for each initial state in `set`, recording
/// `δ*(init, chunk)` into `lv`.  `collapse_every` is the convergence
/// check interval in symbols; 0 disables collapsing (the result is
/// byte-identical either way — property-tested).
pub fn match_chunk_states(
    flat: &FlatDfa,
    lv: &mut LVector,
    set: &[u32],
    chunk: ValidSyms<'_>,
    collapse_every: usize,
) -> ChunkWork {
    let n = chunk.len();
    let mut offs: Vec<u32> = set.iter().map(|&q| flat.offset_of(q)).collect();
    if collapse_every == 0 || set.len() < 2 {
        // no collapsing possible: one pass of 8-wide interleaved chains
        step_all(flat, &mut offs, chunk);
        for (&init, &off) in set.iter().zip(&offs) {
            lv.set(init, flat.state_of(off));
        }
        return ChunkWork { syms_matched: n * set.len(), collapses: 0 };
    }

    let mut members: Vec<Vec<u32>> = set.iter().map(|&q| vec![q]).collect();
    let mut work = ChunkWork::default();
    let mut pos = 0usize;
    // distinct states may still alias at pos 0 if the caller passed a
    // set with duplicates; collapse up front so the invariant "live
    // offsets are pairwise distinct" holds from the start.  An empty
    // chunk has no future work a merge could save, so it collapses
    // nothing — `collapses` counts only merges that removed work.
    if n > 0 {
        collapse_converged(&mut offs, &mut members, &mut work.collapses);
    }
    while pos < n {
        if offs.len() == 1 {
            // fully converged: one sequential scan finishes the chunk
            offs[0] = flat.run_valid(offs[0], chunk.slice(pos..n));
            work.syms_matched += n - pos;
            pos = n;
            break;
        }
        let end = (pos + collapse_every).min(n);
        step_all(flat, &mut offs, chunk.slice(pos..end));
        work.syms_matched += (end - pos) * offs.len();
        pos = end;
        // only an interior boundary can save future work; a merge at
        // the terminal boundary (pos == n) is not a collapse, so the
        // work model is identical whether convergence lands exactly on
        // the chunk end or mid-block (the fast path above never counts
        // it either)
        if pos < n {
            collapse_converged(&mut offs, &mut members, &mut work.collapses);
        }
    }
    for (chain, &off) in members.iter().zip(&offs) {
        let fin = flat.state_of(off);
        for &init in chain {
            lv.set(init, fin);
        }
    }
    work
}

/// Match one chunk *continuing from* a previously composed L-vector —
/// the [`engine::stream`](crate::engine::stream) resume entry point.
///
/// The live frontier is the distinct image of `prior` (for a stream
/// seeded from one known state that is a single chain, so per-segment
/// work stays sequential-scale); the segment's own map is computed at
/// identity and folded into `prior` by Eq. (9) composition.  Collapsing
/// applies within the segment exactly as in [`match_chunk_states`].
pub fn match_chunk_states_resume(
    flat: &FlatDfa,
    prior: &mut LVector,
    chunk: ValidSyms<'_>,
    collapse_every: usize,
) -> ChunkWork {
    let q = prior.len();
    // distinct image states of the composed map: sorted + deduped so
    // the frontier size tracks real convergence, not entry count
    let mut set: Vec<u32> = prior.as_slice().to_vec();
    set.sort_unstable();
    set.dedup();
    let mut seg = LVector::identity(q);
    let work = match_chunk_states(flat, &mut seg, &set, chunk, collapse_every);
    // every state `prior` maps into is in `set`, so each entry the
    // composition consults is grounded
    *prior = prior.compose(&seg);
    work
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::automata::Dfa;
    use crate::speculative::lookahead::tests::random_dfa;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn run_both(
        dfa: &Dfa,
        set: &[u32],
        syms: &[u32],
        every: usize,
    ) -> (LVector, ChunkWork, LVector, ChunkWork) {
        let flat = FlatDfa::from_dfa(dfa);
        let q = dfa.num_states as usize;
        let chunk = flat.validate(syms);
        let mut plain = LVector::identity(q);
        let w_plain = match_chunk_states(&flat, &mut plain, set, chunk, 0);
        let mut coll = LVector::identity(q);
        let w_coll = match_chunk_states(&flat, &mut coll, set, chunk, every);
        (plain, w_plain, coll, w_coll)
    }

    /// Independent reference work model: replay the collapse cadence
    /// with naive per-chain scalar scans and first-occurrence dedupe.
    /// `match_chunk_states` must charge exactly this — not "at most".
    fn reference_work(
        flat: &FlatDfa,
        set: &[u32],
        chunk: ValidSyms<'_>,
        every: usize,
    ) -> ChunkWork {
        let n = chunk.len();
        if every == 0 || set.len() < 2 {
            return ChunkWork { syms_matched: n * set.len(), collapses: 0 };
        }
        let mut offs: Vec<u32> =
            set.iter().map(|&q| flat.offset_of(q)).collect();
        let mut work = ChunkWork::default();
        let dedupe = |offs: &mut Vec<u32>, collapses: &mut usize| {
            let mut kept: Vec<u32> = Vec::with_capacity(offs.len());
            for &o in offs.iter() {
                if kept.contains(&o) {
                    *collapses += 1;
                } else {
                    kept.push(o);
                }
            }
            *offs = kept;
        };
        let mut pos = 0usize;
        if n > 0 {
            dedupe(&mut offs, &mut work.collapses);
        }
        while pos < n {
            if offs.len() == 1 {
                work.syms_matched += n - pos;
                break;
            }
            let end = (pos + every).min(n);
            for off in offs.iter_mut() {
                *off = flat.run_valid(*off, chunk.slice(pos..end));
            }
            work.syms_matched += (end - pos) * offs.len();
            pos = end;
            if pos < n {
                dedupe(&mut offs, &mut work.collapses);
            }
        }
        work
    }

    #[test]
    fn prop_collapsing_is_byte_identical_to_plain() {
        // THE collapsing property: same L-vector entries, and the work
        // accounting is an EXACT function of the convergence trace —
        // the reference model must agree step for step, whichever of
        // the block path and the fully-converged fast path ran
        prop::check("collapse == no-collapse (random DFAs)", 60, |rng| {
            let dfa = random_dfa(rng);
            let len = rng.range_usize(0, 800);
            let syms: Vec<u32> = (0..len)
                .map(|_| rng.below(dfa.num_symbols as u64) as u32)
                .collect();
            let all: Vec<u32> = (0..dfa.num_states).collect();
            let set = &all[..rng.range_usize(1, all.len())];
            let every = rng.range_usize(1, 300);
            let (plain, w_plain, coll, w_coll) =
                run_both(&dfa, set, &syms, every);
            for &init in set {
                assert_eq!(coll.get(init), plain.get(init), "init {init}");
                assert!(coll.was_matched(init));
            }
            assert!(
                w_coll.syms_matched <= w_plain.syms_matched,
                "collapsing must never add work: {} > {}",
                w_coll.syms_matched,
                w_plain.syms_matched
            );
            let flat = FlatDfa::from_dfa(&dfa);
            let want =
                reference_work(&flat, set, flat.validate(&syms), every);
            assert_eq!(
                w_coll.syms_matched, want.syms_matched,
                "work charge must match the reference model exactly"
            );
            assert_eq!(
                w_coll.collapses, want.collapses,
                "collapse count must match the reference model exactly"
            );
        });
    }

    #[test]
    fn terminal_boundary_collapse_is_not_counted() {
        // chains that converge exactly at the end of the chunk save no
        // future work, so the terminal boundary must not count a
        // collapse: pre-fix the block path counted it while the
        // fully-converged fast path never did, making `ChunkWork`
        // depend on where the last block happened to end
        let dfa = crate::regex::compile::compile_exact("abc").unwrap();
        let flat = FlatDfa::from_dfa(&dfa);
        let set: Vec<u32> = (0..dfa.num_states).collect();
        let sink = dfa.sink().expect("exact-match DFA has a sink");
        // one mismatching symbol sends every chain into the sink — all
        // convergence lands on the terminal boundary
        let syms = vec![dfa.class_of(b'z')];
        let chunk = flat.validate(&syms);
        let mut lv = LVector::identity(dfa.num_states as usize);
        let work = match_chunk_states(&flat, &mut lv, &set, chunk, 64);
        assert_eq!(work.syms_matched, set.len());
        assert_eq!(
            work.collapses, 0,
            "a merge at pos == n saved nothing and must not be counted"
        );
        for &q in &set {
            assert_eq!(lv.get(q), sink);
        }
    }

    #[test]
    fn prop_resume_composes_identically_to_one_shot() {
        // the streaming entry point: split a chunk at a random cut,
        // match the head from identity, resume the tail from the
        // composed map — the final L-vector equals the one-shot run
        prop::check("resume == one-shot (random DFAs)", 40, |rng| {
            let dfa = random_dfa(rng);
            let flat = FlatDfa::from_dfa(&dfa);
            let q = dfa.num_states as usize;
            let len = rng.range_usize(0, 400);
            let syms: Vec<u32> = (0..len)
                .map(|_| rng.below(dfa.num_symbols as u64) as u32)
                .collect();
            let cut = rng.range_usize(0, len + 1);
            let every = rng.range_usize(0, 64);
            let all: Vec<u32> = (0..dfa.num_states).collect();
            let mut oneshot = LVector::identity(q);
            match_chunk_states(
                &flat,
                &mut oneshot,
                &all,
                flat.validate(&syms),
                every,
            );
            let mut lv = LVector::identity(q);
            match_chunk_states(
                &flat,
                &mut lv,
                &all,
                flat.validate(&syms[..cut]),
                every,
            );
            match_chunk_states_resume(
                &flat,
                &mut lv,
                flat.validate(&syms[cut..]),
                every,
            );
            for init in 0..q as u32 {
                assert_eq!(
                    lv.get(init),
                    oneshot.get(init),
                    "init {init} cut {cut}"
                );
            }
        });
    }

    #[test]
    fn sink_dfa_collapses_to_one_chain() {
        // exact-match DFA: every state falls into the sink on mismatch,
        // so all chains converge and the work drops to ~chunk_len
        let dfa = crate::regex::compile::compile_exact("abc").unwrap();
        let mut rng = Rng::new(0xC0);
        let syms: Vec<u32> = (0..50_000)
            .map(|_| rng.below(dfa.num_symbols as u64) as u32)
            .collect();
        let set: Vec<u32> = (0..dfa.num_states).collect();
        let (_, w_plain, _, w_coll) = run_both(&dfa, &set, &syms, 64);
        assert_eq!(w_plain.syms_matched, syms.len() * set.len());
        assert!(
            w_coll.syms_matched < w_plain.syms_matched,
            "high-gamma DFA must collapse: {} !< {}",
            w_coll.syms_matched,
            w_plain.syms_matched
        );
        assert!(w_coll.collapses >= set.len() - 1);
        // all chains dead within a few blocks: near-sequential work
        assert!(
            w_coll.syms_matched < syms.len() + 64 * set.len() * set.len(),
            "work {} not near-sequential",
            w_coll.syms_matched
        );
    }

    #[test]
    fn duplicate_initial_states_collapse_up_front() {
        let dfa = crate::regex::compile::compile_search("ab").unwrap();
        let flat = FlatDfa::from_dfa(&dfa);
        let syms: Vec<u32> = vec![0; 100];
        let chunk = flat.validate(&syms);
        let mut lv = LVector::identity(dfa.num_states as usize);
        let work =
            match_chunk_states(&flat, &mut lv, &[0, 0, 0], chunk, 10);
        assert_eq!(work.collapses, 2);
        assert_eq!(work.syms_matched, 100);
    }

    #[test]
    fn empty_chunk_is_identity() {
        let dfa = crate::regex::compile::compile_search("ab").unwrap();
        let flat = FlatDfa::from_dfa(&dfa);
        let chunk = flat.validate(&[]);
        let set: Vec<u32> = (0..dfa.num_states).collect();
        for every in [0usize, 16] {
            let mut lv = LVector::identity(dfa.num_states as usize);
            let work =
                match_chunk_states(&flat, &mut lv, &set, chunk, every);
            assert_eq!(work.syms_matched, 0);
            for &q in &set {
                assert_eq!(lv.get(q), q);
            }
        }
    }
}
