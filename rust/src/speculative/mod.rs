//! The paper's contribution: failure-free speculative parallel DFA
//! matching.
//!
//! * [`partition`]  — weighted input partitioning, Eqs. (1)–(7)/(10)
//! * [`lookahead`]  — initial-state sets and I_max,r, Eqs. (11)–(13),
//!   Algorithm 4, Lemma 1
//! * [`lvector`]    — L-vectors (chunk state maps) and Eq. (9) composition
//! * [`chunk`]      — the shared per-chunk kernel: 8-wide interleaved
//!   Listing-1 chains with periodic convergence collapsing
//! * [`matcher`]    — Algorithms 2 and 3 over a thread pool
//! * [`merge`]      — sequential (Eq. 8), binary-tree, and the paper's
//!   2-tier hierarchical merging (Fig. 9)
//! * [`profile`]    — offline capacity profiling, Eq. (1)

pub mod chunk;
pub mod lookahead;
pub mod lvector;
pub mod matcher;
pub mod merge;
pub mod partition;
pub mod profile;

pub use lookahead::Lookahead;
pub use lvector::LVector;
pub use matcher::{MatchOutcome, MatchPlan};
pub use merge::MergeStrategy;
