//! Offline profiling (§4.1, "Offline Profiling"): measure each
//! processor's DFA matching capacity m_k (symbols per microsecond) and
//! derive load-balancing weights w_k by Eq. (1):
//!
//!   w_k = m_k · ( (1/|P|) · Σ m_i )^{-1}
//!
//! On real hardware the profiler times the Listing-1 loop on a sample of
//! the benchmark DFA ("several partial sequential DFA matching runs ...
//! from the median of the obtained execution times").  For the simulated
//! cluster, capacities come from the node model but flow through the same
//! Eq. (1) weighting.

use std::time::Instant;

use crate::automata::FlatDfa;
use crate::util::stats;

/// Measure matching capacity of the *calling* processor: median symbols
/// per microsecond over `runs` timed runs of `sample` symbols each.
pub fn measure_capacity(flat: &FlatDfa, sample: &[u32], runs: usize) -> f64 {
    assert!(!sample.is_empty());
    let mut rates = Vec::with_capacity(runs.max(1));
    for _ in 0..runs.max(1) {
        let t0 = Instant::now();
        let off = flat.run_syms(flat.start_off, sample);
        let dt = t0.elapsed().as_secs_f64();
        std::hint::black_box(off);
        // symbols per microsecond
        rates.push(sample.len() as f64 / (dt * 1e6).max(1e-9));
    }
    stats::median(&rates)
}

/// One fresh capacity measurement of the calling host, the §4.1 offline
/// profiling step packaged for the serving path: unlike
/// `experiments::calibrate::host_syms_per_us` (measured once, cached for
/// the process), every call re-times the Listing-1 loop, so a server can
/// re-calibrate periodically as machine load shifts.
#[derive(Clone, Copy, Debug)]
pub struct CapacityProfile {
    /// median matching rate over the timed runs, symbols per microsecond
    pub syms_per_us: f64,
    /// timed runs the median was taken over
    pub runs: usize,
    /// symbols per timed run
    pub sample_syms: usize,
}

/// The shared §4.1 calibration workload: the standard calibration DFA
/// (the same `(ab|cd)+e?` shape `experiments::calibrate` uses) and a
/// seeded symbol sample, clamped to ≥ 4096 symbols so the timer
/// resolution doesn't swamp the rate.
fn calibration_workload(sample_syms: usize) -> (FlatDfa, Vec<u32>) {
    let dfa = crate::regex::compile::compile_search("(ab|cd)+e?")
        .expect("calibration pattern compiles");
    let flat = FlatDfa::from_dfa(&dfa);
    let n = sample_syms.max(4096);
    let mut rng = crate::util::rng::Rng::new(0xCA11B);
    let sample: Vec<u32> = (0..n)
        .map(|_| rng.below(dfa.num_symbols as u64) as u32)
        .collect();
    (flat, sample)
}

/// Profile the calling host with the standard calibration workload
/// ([`calibration_workload`]).
pub fn profile_host(runs: usize, sample_syms: usize) -> CapacityProfile {
    let (flat, sample) = calibration_workload(sample_syms);
    let runs = runs.max(1);
    CapacityProfile {
        syms_per_us: measure_capacity(&flat, &sample, runs),
        runs,
        sample_syms: sample.len(),
    }
}

/// A **per-worker capacity vector**: one measured matching rate per
/// worker thread, not one host-wide rate (ROADMAP: "Per-processor
/// capacity vectors in serving").
///
/// On an inhomogeneous machine (big.LITTLE cores, SMT siblings, noisy
/// neighbours) the workers of one multicore matcher do not match at the
/// same speed; Eq. (1) weights derived from this vector let
/// [`crate::speculative::matcher::MatchPlan::weights`] and the two-level
/// [`crate::engine::shard::ShardPlan`] partition proportionally to what
/// each worker can actually do.
#[derive(Clone, Debug, PartialEq)]
pub struct CapacityVector {
    /// median matching rate of each worker, symbols per microsecond
    pub rates: Vec<f64>,
    /// timed runs each worker's median was taken over
    pub runs: usize,
    /// symbols per timed run
    pub sample_syms: usize,
}

impl CapacityVector {
    /// A synthetic vector of `workers` equal rates (simulation harnesses
    /// and tests; a real vector comes from [`profile_workers`]).
    pub fn uniform(workers: usize, syms_per_us: f64) -> CapacityVector {
        assert!(workers >= 1 && syms_per_us > 0.0);
        CapacityVector {
            rates: vec![syms_per_us; workers],
            runs: 0,
            sample_syms: 0,
        }
    }

    /// Number of workers the vector was measured over.
    pub fn workers(&self) -> usize {
        self.rates.len()
    }

    /// Eq. (1) load-balancing weights: each worker's rate normalized by
    /// the mean rate (`w_k = m_k / mean(m)`), averaging to 1.
    pub fn weights(&self) -> Vec<f64> {
        weights_from_capacities(&self.rates)
    }

    /// Aggregate capacity of all workers, symbols per microsecond.
    pub fn total(&self) -> f64 {
        self.rates.iter().sum()
    }

    /// Mean per-worker rate, symbols per microsecond.
    pub fn mean(&self) -> f64 {
        stats::mean(&self.rates)
    }

    /// Proportional spread of the vector (coefficient of variation): 0
    /// on a perfectly homogeneous machine; large when some workers are
    /// much slower than others and weighting matters.
    pub fn skew(&self) -> f64 {
        stats::cv(&self.rates)
    }
}

/// Measure a per-worker capacity vector: `workers` OS threads each time
/// the Listing-1 loop **concurrently**, so cache contention and shared
/// functional units show up in the measured rates exactly as they will
/// during a real parallel matching run.  Median over `runs` per worker.
///
/// `sample_syms` is clamped to ≥ 4096 per worker (timer resolution), and
/// `runs`/`workers` to ≥ 1.
pub fn profile_workers(
    workers: usize,
    runs: usize,
    sample_syms: usize,
) -> CapacityVector {
    let workers = workers.max(1);
    let runs = runs.max(1);
    let (flat, sample) = calibration_workload(sample_syms);
    let mut rates = vec![0.0f64; workers];
    std::thread::scope(|scope| {
        for slot in rates.iter_mut() {
            let flat = &flat;
            let sample = &sample;
            scope.spawn(move || {
                *slot = measure_capacity(flat, sample, runs);
            });
        }
    });
    let sample_syms = sample.len();
    CapacityVector { rates, runs, sample_syms }
}

/// Eq. (1): normalize capacities by the mean capacity.
pub fn weights_from_capacities(caps: &[f64]) -> Vec<f64> {
    assert!(!caps.is_empty());
    assert!(caps.iter().all(|&c| c > 0.0), "capacities must be positive");
    let avg = stats::mean(caps);
    caps.iter().map(|&c| c / avg).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::automata::FlatDfa;
    use crate::regex::compile::compile_search;
    use crate::util::rng::Rng;

    #[test]
    fn eq1_table1_weights() {
        // Table 1: capacities 50, 25, 25 -> weights 1.5, 0.75, 0.75
        let w = weights_from_capacities(&[50.0, 25.0, 25.0]);
        assert!((w[0] - 1.5).abs() < 1e-12);
        assert!((w[1] - 0.75).abs() < 1e-12);
        assert!((w[2] - 0.75).abs() < 1e-12);
    }

    #[test]
    fn weights_average_to_one() {
        let w = weights_from_capacities(&[10.0, 20.0, 40.0, 70.0]);
        let avg = w.iter().sum::<f64>() / w.len() as f64;
        assert!((avg - 1.0).abs() < 1e-12);
    }

    #[test]
    fn profile_host_measures_fresh_each_call() {
        let a = profile_host(3, 50_000);
        let b = profile_host(3, 50_000);
        for p in [a, b] {
            assert!(
                p.syms_per_us > 1.0 && p.syms_per_us < 100_000.0,
                "rate {}",
                p.syms_per_us
            );
            assert_eq!(p.runs, 3);
            assert_eq!(p.sample_syms, 50_000);
        }
        // clamps degenerate arguments instead of panicking
        let c = profile_host(0, 0);
        assert_eq!(c.runs, 1);
        assert_eq!(c.sample_syms, 4096);
        assert!(c.syms_per_us > 0.0);
    }

    #[test]
    fn per_worker_vector_measures_every_worker() {
        let cv = profile_workers(4, 2, 8192);
        assert_eq!(cv.workers(), 4);
        for &r in &cv.rates {
            assert!(r > 1.0 && r < 100_000.0, "rate {r}");
        }
        assert!(cv.total() > cv.mean());
        assert!(cv.skew() >= 0.0);
        // Eq. (1) over the vector: weights average to 1
        let w = cv.weights();
        assert_eq!(w.len(), 4);
        let avg = w.iter().sum::<f64>() / 4.0;
        assert!((avg - 1.0).abs() < 1e-12, "avg weight {avg}");
        // degenerate arguments clamp instead of panicking
        let one = profile_workers(0, 0, 0);
        assert_eq!(one.workers(), 1);
        assert_eq!(one.runs, 1);
        assert_eq!(one.sample_syms, 4096);
    }

    #[test]
    fn uniform_vector_is_flat() {
        let cv = CapacityVector::uniform(3, 250.0);
        assert_eq!(cv.rates, vec![250.0; 3]);
        assert_eq!(cv.weights(), vec![1.0; 3]);
        assert!(cv.skew().abs() < 1e-12);
        assert!((cv.total() - 750.0).abs() < 1e-9);
    }

    #[test]
    fn measured_capacity_positive_and_sane() {
        let dfa = compile_search("abc").unwrap();
        let flat = FlatDfa::from_dfa(&dfa);
        let mut rng = Rng::new(5);
        let sample: Vec<u32> = (0..200_000)
            .map(|_| rng.below(dfa.num_symbols as u64) as u32)
            .collect();
        let cap = measure_capacity(&flat, &sample, 5);
        // any machine should match between 1 and 100k symbols/us
        assert!(cap > 1.0 && cap < 100_000.0, "capacity {cap}");
    }
}
