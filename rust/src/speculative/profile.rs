//! Offline profiling (§4.1, "Offline Profiling"): measure each
//! processor's DFA matching capacity m_k (symbols per microsecond) and
//! derive load-balancing weights w_k by Eq. (1):
//!
//!   w_k = m_k · ( (1/|P|) · Σ m_i )^{-1}
//!
//! On real hardware the profiler times the Listing-1 loop on a sample of
//! the benchmark DFA ("several partial sequential DFA matching runs ...
//! from the median of the obtained execution times").  For the simulated
//! cluster, capacities come from the node model but flow through the same
//! Eq. (1) weighting.

use std::time::Instant;

use crate::automata::FlatDfa;
use crate::util::stats;

/// Measure matching capacity of the *calling* processor: median symbols
/// per microsecond over `runs` timed runs of `sample` symbols each.
pub fn measure_capacity(flat: &FlatDfa, sample: &[u32], runs: usize) -> f64 {
    assert!(!sample.is_empty());
    let mut rates = Vec::with_capacity(runs.max(1));
    for _ in 0..runs.max(1) {
        let t0 = Instant::now();
        let off = flat.run_syms(flat.start_off, sample);
        let dt = t0.elapsed().as_secs_f64();
        std::hint::black_box(off);
        // symbols per microsecond
        rates.push(sample.len() as f64 / (dt * 1e6).max(1e-9));
    }
    stats::median(&rates)
}

/// Eq. (1): normalize capacities by the mean capacity.
pub fn weights_from_capacities(caps: &[f64]) -> Vec<f64> {
    assert!(!caps.is_empty());
    assert!(caps.iter().all(|&c| c > 0.0), "capacities must be positive");
    let avg = stats::mean(caps);
    caps.iter().map(|&c| c / avg).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::automata::FlatDfa;
    use crate::regex::compile::compile_search;
    use crate::util::rng::Rng;

    #[test]
    fn eq1_table1_weights() {
        // Table 1: capacities 50, 25, 25 -> weights 1.5, 0.75, 0.75
        let w = weights_from_capacities(&[50.0, 25.0, 25.0]);
        assert!((w[0] - 1.5).abs() < 1e-12);
        assert!((w[1] - 0.75).abs() < 1e-12);
        assert!((w[2] - 0.75).abs() < 1e-12);
    }

    #[test]
    fn weights_average_to_one() {
        let w = weights_from_capacities(&[10.0, 20.0, 40.0, 70.0]);
        let avg = w.iter().sum::<f64>() / w.len() as f64;
        assert!((avg - 1.0).abs() < 1e-12);
    }

    #[test]
    fn measured_capacity_positive_and_sane() {
        let dfa = compile_search("abc").unwrap();
        let flat = FlatDfa::from_dfa(&dfa);
        let mut rng = Rng::new(5);
        let sample: Vec<u32> = (0..200_000)
            .map(|_| rng.below(dfa.num_symbols as u64) as u32)
            .collect();
        let cap = measure_capacity(&flat, &sample, 5);
        // any machine should match between 1 and 100k symbols/us
        assert!(cap > 1.0 && cap < 100_000.0, "capacity {cap}");
    }
}
