//! Weighted input partitioning: Eqs. (1)–(7) and (10) of §4.1/§4.2.
//!
//! Chunk c_0 is matched once (initial state known), subsequent chunks are
//! matched for up to `m` states (m = |Q| basic, m = I_max,r optimized), so
//! c_0 is m× longer; processor weights w_k scale every chunk.  The solved
//! closed form:
//!
//!   L_0 = n·m / (w_0·m + Σ_{1≤i<|P|} w_i)                       (5)/(10)
//!   StartPos(c_k) = ⌊L_0 w_0 + (1/m) Σ_{1≤i<k} L_0 w_i⌋            (6)
//!   EndPos(c_k)   = ⌊L_0 w_0 + (1/m) Σ_{1≤i≤k} L_0 w_i⌋ − 1        (7)

/// One chunk assignment: processor `proc` matches input[start..end]
/// (end exclusive) for `states_to_match` initial states.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Chunk {
    /// processor that matches this chunk
    pub proc: usize,
    /// start offset (inclusive)
    pub start: usize,
    /// end offset (exclusive)
    pub end: usize,
}

impl Chunk {
    /// Chunk length in symbols.
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    /// Whether the chunk is empty.
    pub fn is_empty(&self) -> bool {
        self.start >= self.end
    }
}

/// Partition `n` input symbols into |weights| chunks, where all chunks but
/// the first will be matched for `m` initial states.
///
/// Invariants (tested): chunks tile [0, n) exactly, in order; with uniform
/// weights and m=1 all chunks are within 1 symbol of n/|P|.
pub fn partition(n: usize, weights: &[f64], m: usize) -> Vec<Chunk> {
    let p = weights.len();
    assert!(p > 0, "need at least one processor");
    assert!(m > 0, "need at least one state to match");
    assert!(weights.iter().all(|&w| w > 0.0), "weights must be positive");
    if p == 1 {
        return vec![Chunk { proc: 0, start: 0, end: n }];
    }

    let nf = n as f64;
    let mf = m as f64;
    let wsum_rest: f64 = weights[1..].iter().sum();
    // Eq. (5)/(10)
    let l0 = nf * mf / (weights[0] * mf + wsum_rest);

    // prefix[k] = L0·w0 + (1/m)·Σ_{1<=i<k} L0·w_i  (the StartPos argument)
    let mut chunks = Vec::with_capacity(p);
    let mut acc = l0 * weights[0];
    let mut prev_end = (acc.floor() as usize).min(n);
    chunks.push(Chunk { proc: 0, start: 0, end: prev_end });
    for (k, &wk) in weights.iter().enumerate().skip(1) {
        let end = if k == p - 1 {
            n
        } else {
            acc += l0 * wk / mf;
            (acc.floor() as usize).clamp(prev_end, n)
        };
        chunks.push(Chunk { proc: k, start: prev_end, end });
        prev_end = end;
    }
    chunks
}

/// Generalized partition: per-chunk initial-state counts `sizes[i]`
/// (sizes[0] is chunk 0's count, normally 1).  Balancing
/// `len_i · sizes_i / w_i = const` gives `len_i ∝ w_i / sizes_i`.
///
/// This powers the *adaptive* (two-pass) partitioning extension: instead
/// of sizing every subsequent chunk for the worst case I_max,r, the
/// matcher measures the actual |I_suffix| at each boundary and re-solves.
/// The paper discusses (and rejects as potentially failure-violating)
/// *searching* for low-cardinality boundaries (§4.2); fixed-point
/// re-weighting needs no search and stays failure-free: per-processor
/// work remains ≤ n because Σ len_i = n and every chunk is matched for
/// exactly sizes_i states with len_i ≤ n.
pub fn partition_with_sizes(
    n: usize,
    weights: &[f64],
    sizes: &[usize],
) -> Vec<Chunk> {
    let p = weights.len();
    assert_eq!(sizes.len(), p);
    assert!(p > 0);
    assert!(weights.iter().all(|&w| w > 0.0));
    assert!(sizes.iter().all(|&s| s > 0));
    if p == 1 {
        return vec![Chunk { proc: 0, start: 0, end: n }];
    }
    let shares: Vec<f64> =
        weights.iter().zip(sizes).map(|(&w, &s)| w / s as f64).collect();
    let total: f64 = shares.iter().sum();
    let mut chunks = Vec::with_capacity(p);
    let mut acc = 0.0f64;
    let mut prev_end = 0usize;
    for (k, &sh) in shares.iter().enumerate() {
        let end = if k == p - 1 {
            n
        } else {
            acc += n as f64 * sh / total;
            (acc.floor() as usize).clamp(prev_end, n)
        };
        chunks.push(Chunk { proc: k, start: prev_end, end });
        prev_end = end;
    }
    chunks
}

/// Total number of symbol-match operations the partition implies
/// (chunk 0 once, the rest m times) — the speculation overhead metric.
pub fn total_work(chunks: &[Chunk], m: usize) -> usize {
    chunks
        .iter()
        .map(|c| if c.proc == 0 { c.len() } else { c.len() * m })
        .sum()
}

/// Theoretical speedup bound of Eq. (15)/(18):
/// 1 + (|P|-1) / m, with m = |Q|·γ = I_max,r.
pub fn predicted_speedup(p: usize, m: usize) -> f64 {
    1.0 + (p as f64 - 1.0) / m as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn table1_paper_numbers() {
        // Fig. 6 DFA: |Q| = 4; n = 36; weights 1.5, 0.75, 0.75 (Table 1)
        let chunks = partition(36, &[1.5, 0.75, 0.75], 4);
        // Table 1: ranges 0–27, 28–31, 32–35
        assert_eq!(chunks[0], Chunk { proc: 0, start: 0, end: 28 });
        assert_eq!(chunks[1], Chunk { proc: 1, start: 28, end: 32 });
        assert_eq!(chunks[2], Chunk { proc: 2, start: 32, end: 36 });
    }

    #[test]
    fn fig7_equal_capacity_with_imax() {
        // §4.2: n=36, I_max=2, |Q|=4, w=1: L0 = 36*2/(2+1+1) = 18
        let chunks = partition(36, &[1.0, 1.0, 1.0], 2);
        assert_eq!(chunks[0].len(), 18);
        assert_eq!(chunks[1].len(), 9);
        assert_eq!(chunks[2].len(), 9);
    }

    #[test]
    fn fig3_uniform_naive() {
        // motivating example: 12 symbols, 3 procs, m=1 -> 4 each (Fig. 3)
        let chunks = partition(12, &[1.0; 3], 1);
        assert!(chunks.iter().all(|c| c.len() == 4));
    }

    #[test]
    fn fig4_balanced_two_state() {
        // Fig. 4: m = 2 -> chunk0 = 6, chunk1 = chunk2 = 3
        let chunks = partition(12, &[1.0; 3], 2);
        assert_eq!(chunks[0].len(), 6);
        assert_eq!(chunks[1].len(), 3);
        assert_eq!(chunks[2].len(), 3);
    }

    #[test]
    fn single_processor_whole_input() {
        let chunks = partition(100, &[1.0], 7);
        assert_eq!(chunks, vec![Chunk { proc: 0, start: 0, end: 100 }]);
    }

    #[test]
    fn prop_chunks_tile_input() {
        prop::check("partition tiles [0,n)", 100, |rng| {
            let n = rng.below(100_000) as usize;
            let p = rng.range_usize(1, 16);
            let m = rng.range_usize(1, 600);
            let weights: Vec<f64> =
                (0..p).map(|_| 0.25 + rng.f64() * 3.0).collect();
            let chunks = partition(n, &weights, m);
            assert_eq!(chunks.len(), p);
            assert_eq!(chunks[0].start, 0);
            assert_eq!(chunks.last().unwrap().end, n);
            for w in chunks.windows(2) {
                assert_eq!(w[0].end, w[1].start);
                assert!(w[0].start <= w[0].end);
            }
        });
    }

    #[test]
    fn prop_balanced_work_per_processor() {
        // weighted per-proc work (len·m for i>0, len for i=0, divided by
        // weight) should be near-equal for non-degenerate chunk sizes
        prop::check("partition balances weighted work", 50, |rng| {
            let n = 1_000_000;
            let p = rng.range_usize(2, 12);
            let m = rng.range_usize(1, 64);
            let weights: Vec<f64> =
                (0..p).map(|_| 0.5 + rng.f64() * 2.0).collect();
            let chunks = partition(n, &weights, m);
            let times: Vec<f64> = chunks
                .iter()
                .map(|c| {
                    let work = if c.proc == 0 {
                        c.len() as f64
                    } else {
                        (c.len() * m) as f64
                    };
                    work / weights[c.proc]
                })
                .collect();
            let t0 = times[0];
            for t in &times {
                assert!(
                    (t - t0).abs() / t0 < 0.02,
                    "unbalanced: {times:?} (p={p} m={m})"
                );
            }
        });
    }

    #[test]
    fn total_work_reflects_speculation() {
        let chunks = partition(12, &[1.0; 3], 1);
        assert_eq!(total_work(&chunks, 1), 12);
        let chunks = partition(12, &[1.0; 3], 2);
        // Fig. 4: every processor does 6 units
        assert_eq!(total_work(&chunks, 2), 18);
    }

    #[test]
    fn predicted_speedup_formula() {
        assert!((predicted_speedup(40, 1) - 40.0).abs() < 1e-12);
        assert!((predicted_speedup(3, 2) - 2.0).abs() < 1e-12);
        assert!((predicted_speedup(1, 10) - 1.0).abs() < 1e-12);
    }
}
