//! Merging of partial results (L-vectors) across chunks.
//!
//! * [`MergeStrategy::Sequential`] — Eq. (8): propagate the state through
//!   the chunk maps left to right.  The paper's choice for shared memory
//!   (the parallel reduction "is not large enough to justify the
//!   overhead").
//! * [`MergeStrategy::BinaryTree`] — Eq. (9) pairwise composition in
//!   ⌈log₂|P|⌉ rounds, the [19] scheme the paper evaluated and rejected.
//! * [`MergeStrategy::Hierarchical`] — the paper's 2-tier cloud scheme
//!   (Fig. 9): node leaders compose their local chunk maps, the master
//!   applies leader maps; only one step crosses the (high-variance)
//!   inter-node network.
//!
//! Each merge returns [`MergeStats`] — the op/message counts the cluster
//! simulation (cluster/) prices with its latency model.

use super::lvector::LVector;

/// Which merge schedule combines the per-chunk L-vectors.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MergeStrategy {
    /// Eq. (8): left-to-right state propagation.
    Sequential,
    /// Eq. (9): pairwise composition in ⌈log₂|P|⌉ rounds.
    BinaryTree,
    /// cores_per_node = |C| of Fig. 9 (chunks per node leader)
    Hierarchical { cores_per_node: usize },
}

/// Operation/message counts of one merge (priced by `cluster/`).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct MergeStats {
    /// Eq. (9) full-map compositions performed
    pub compose_ops: usize,
    /// single-state map lookups (Eq. 8 steps)
    pub lookup_ops: usize,
    /// longest dependency chain of composition rounds
    pub depth: usize,
    /// L-vector messages crossing nodes (priced at inter-node latency)
    pub inter_node_msgs: usize,
    /// L-vector messages within a node (priced at intra-node latency)
    pub intra_node_msgs: usize,
}

/// Merge chunk maps; `start` is the DFA start state (index 0 of the
/// L-mapping chain in Eq. 8).  Returns the last active state.
pub fn merge(
    lvecs: &[LVector],
    start: u32,
    strategy: MergeStrategy,
) -> (u32, MergeStats) {
    assert!(!lvecs.is_empty());
    match strategy {
        MergeStrategy::Sequential => merge_sequential(lvecs, start),
        MergeStrategy::BinaryTree => merge_tree(lvecs, start),
        MergeStrategy::Hierarchical { cores_per_node } => {
            merge_hierarchical(lvecs, start, cores_per_node)
        }
    }
}

fn merge_sequential(lvecs: &[LVector], start: u32) -> (u32, MergeStats) {
    let mut state = start;
    for lv in lvecs {
        state = lv.get(state);
    }
    (
        state,
        MergeStats {
            lookup_ops: lvecs.len(),
            depth: lvecs.len(),
            // workers hand their L-vector to the master on the same node
            intra_node_msgs: lvecs.len().saturating_sub(1),
            ..Default::default()
        },
    )
}

fn merge_tree(lvecs: &[LVector], start: u32) -> (u32, MergeStats) {
    let mut stats = MergeStats::default();
    let mut layer: Vec<LVector> = lvecs.to_vec();
    while layer.len() > 1 {
        stats.depth += 1;
        let mut next = Vec::with_capacity(layer.len().div_ceil(2));
        let mut it = layer.chunks(2);
        for pair in &mut it {
            if pair.len() == 2 {
                next.push(pair[0].compose(&pair[1]));
                stats.compose_ops += 1;
                // one operand always travels to the combiner
                stats.intra_node_msgs += 1;
            } else {
                next.push(pair[0].clone());
            }
        }
        layer = next;
    }
    stats.lookup_ops = 1;
    (layer[0].get(start), stats)
}

fn merge_hierarchical(
    lvecs: &[LVector],
    start: u32,
    cores_per_node: usize,
) -> (u32, MergeStats) {
    assert!(cores_per_node >= 1);
    let mut stats = MergeStats::default();
    // tier 1: each node leader composes its node's chunk maps (Eq. 9)
    let mut leader_maps: Vec<LVector> = Vec::new();
    for group in lvecs.chunks(cores_per_node) {
        let mut acc = group[0].clone();
        for lv in &group[1..] {
            acc = acc.compose(lv);
            stats.compose_ops += 1;
        }
        // workers -> leader messages stay on the node
        stats.intra_node_msgs += group.len().saturating_sub(1);
        leader_maps.push(acc);
    }
    stats.depth += 1;
    // tier 2: master (leader of node 0) applies leader maps sequentially
    // (Eq. 8 over the composed per-node maps)
    let mut state = start;
    for (i, lm) in leader_maps.iter().enumerate() {
        state = lm.get(state);
        stats.lookup_ops += 1;
        if i > 0 {
            // leader i ships its composed map across the network once
            stats.inter_node_msgs += 1;
        }
    }
    stats.depth += 1;
    (state, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn random_lvecs(rng: &mut Rng, p: usize, q: usize) -> Vec<LVector> {
        (0..p)
            .map(|_| {
                let mut lv = LVector::identity(q);
                for i in 0..q {
                    lv.set(i as u32, rng.below(q as u64) as u32);
                }
                lv
            })
            .collect()
    }

    #[test]
    fn prop_all_strategies_agree() {
        prop::check("merge strategies compute the same state", 80, |rng| {
            let p = rng.range_usize(1, 24);
            let q = rng.range_usize(1, 16);
            let start = rng.below(q as u64) as u32;
            let lvecs = random_lvecs(rng, p, q);
            let (s_seq, _) = merge(&lvecs, start, MergeStrategy::Sequential);
            let (s_tree, _) = merge(&lvecs, start, MergeStrategy::BinaryTree);
            for c in [1, 2, 3, 8, 15, 16] {
                let (s_h, _) = merge(
                    &lvecs,
                    start,
                    MergeStrategy::Hierarchical { cores_per_node: c },
                );
                assert_eq!(s_seq, s_h, "hierarchical({c})");
            }
            assert_eq!(s_seq, s_tree);
        });
    }

    #[test]
    fn tree_depth_logarithmic() {
        let mut rng = Rng::new(1);
        let lvecs = random_lvecs(&mut rng, 16, 4);
        let (_, stats) = merge(&lvecs, 0, MergeStrategy::BinaryTree);
        assert_eq!(stats.depth, 4);
        assert_eq!(stats.compose_ops, 15);
    }

    #[test]
    fn sequential_stats() {
        let mut rng = Rng::new(2);
        let lvecs = random_lvecs(&mut rng, 10, 4);
        let (_, stats) = merge(&lvecs, 0, MergeStrategy::Sequential);
        assert_eq!(stats.lookup_ops, 10);
        assert_eq!(stats.compose_ops, 0);
        assert_eq!(stats.inter_node_msgs, 0);
    }

    #[test]
    fn hierarchical_message_counts_fig9() {
        // 20 nodes x 15 cores = 300 chunks: 19 inter-node messages only
        let mut rng = Rng::new(3);
        let lvecs = random_lvecs(&mut rng, 300, 8);
        let (_, stats) = merge(
            &lvecs,
            0,
            MergeStrategy::Hierarchical { cores_per_node: 15 },
        );
        assert_eq!(stats.inter_node_msgs, 19);
        assert_eq!(stats.intra_node_msgs, 20 * 14);
        assert_eq!(stats.depth, 2);
    }

    #[test]
    fn single_chunk_trivial() {
        let mut rng = Rng::new(4);
        let lvecs = random_lvecs(&mut rng, 1, 5);
        for strat in [
            MergeStrategy::Sequential,
            MergeStrategy::BinaryTree,
            MergeStrategy::Hierarchical { cores_per_node: 4 },
        ] {
            let (s, _) = merge(&lvecs, 3, strat);
            assert_eq!(s, lvecs[0].get(3));
        }
    }
}
