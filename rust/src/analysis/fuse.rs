//! Pre-fuse product-size estimation: predict `automata::product::fuse`
//! blowup before paying for it.
//!
//! The product construction (the arXiv 1405.0562 / 1512.09228 lineage)
//! interns reachable state *tuples* by BFS and aborts once it has
//! interned more than `state_budget` of them — discovering a doomed fuse
//! only after burning the whole budget.  This pass bounds the reachable
//! tuple count from component structure alone:
//!
//! * **Upper bound** — ∏ trimmed |Qᵢ| (saturating): the product can
//!   never intern more tuples than the full cross product.
//! * **Certain lower bound** — max trimmed |Qᵢ|: every component all
//!   read the *same* word, so each state reachable in component *i* via
//!   some word appears in a reachable tuple — the product has at least
//!   as many reachable tuples as its largest component has reachable
//!   states.
//!
//! `predicted_overflow` fires only off the *certain* bound, so a skip
//! decision ([`crate::engine::patternset`]'s
//! `SetOutcome::fuse_skipped_predicted`) is provably one `fuse` would
//! have aborted anyway: reachable ≥ certain_min > budget means the BFS
//! must intern more than `budget` tuples before finishing.
//!
//! Also reported: the combined byte-class signature — the number of
//! distinct `(class₁(b), …, classₖ(b))` tuples over all 256 byte values,
//! which is exactly the fused product's dense symbol count (its table
//! width), and a measure of how much the component alphabets overlap.

use std::collections::HashSet;

use crate::automata::Dfa;

/// The fuse pass report for one component list.
#[derive(Clone, Debug)]
pub struct FuseEstimate {
    /// number of component DFAs
    pub components: usize,
    /// trimmed (start-reachable) |Q| per component
    pub component_states: Vec<usize>,
    /// ∏ trimmed |Qᵢ|, saturating — the product can never exceed this
    pub upper_bound: usize,
    /// max trimmed |Qᵢ| — the product provably reaches at least this
    /// many tuples (all components read the same word)
    pub certain_min: usize,
    /// distinct combined byte-class tuples over 0..=255 — the fused
    /// product's dense symbol count
    pub combined_classes: usize,
    /// the state budget the prediction was made against (0 = unlimited)
    pub budget: usize,
    /// `budget != 0 && certain_min > budget`: `fuse` is guaranteed to
    /// abort, skip it
    pub predicted_overflow: bool,
}

/// Bound the fused product size for `dfas` against `budget` (0 =
/// unlimited, matching [`crate::automata::product::fuse`]'s convention).
pub fn estimate_fuse(dfas: &[&Dfa], budget: usize) -> FuseEstimate {
    let component_states: Vec<usize> = dfas
        .iter()
        .map(|d| d.trim_unreachable().num_states as usize)
        .collect();
    let upper_bound = component_states
        .iter()
        .fold(1usize, |acc, &q| acc.saturating_mul(q.max(1)));
    let certain_min = component_states.iter().copied().max().unwrap_or(0);
    let combined_classes = combined_class_count(dfas);
    FuseEstimate {
        components: dfas.len(),
        component_states,
        upper_bound,
        certain_min,
        combined_classes,
        budget,
        predicted_overflow: budget != 0 && certain_min > budget,
    }
}

/// Number of distinct `(class₁(b), …, classₖ(b))` tuples over all 256
/// byte values — the fused product's dense symbol count.
fn combined_class_count(dfas: &[&Dfa]) -> usize {
    if dfas.is_empty() {
        return 0;
    }
    let mut seen: HashSet<Vec<u32>> = HashSet::new();
    for b in 0..=255u8 {
        seen.insert(dfas.iter().map(|d| d.class_of(b)).collect());
    }
    seen.len()
}

/// Whether every pair of required literals is disjoint (no literal a
/// substring of another, no overlap prefix/suffix sharing needed — the
/// simple "no pattern's literal contains another's" check).  `None` when
/// any component lacks a required literal.  Disjoint literals mean the
/// prefilter can attribute candidates to single patterns, a fact the
/// report surfaces for routing quality.
pub fn literals_disjoint(literals: &[Option<Vec<u8>>]) -> Option<bool> {
    let lits: Option<Vec<&Vec<u8>>> =
        literals.iter().map(|l| l.as_ref()).collect();
    let lits = lits?;
    for i in 0..lits.len() {
        for j in 0..lits.len() {
            if i != j && contains_sub(lits[i], lits[j]) {
                return Some(false);
            }
        }
    }
    Some(true)
}

fn contains_sub(hay: &[u8], needle: &[u8]) -> bool {
    needle.is_empty()
        || hay.windows(needle.len()).any(|w| w == needle)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::automata::product::fuse;
    use crate::regex::compile::compile_search;

    #[test]
    fn bounds_bracket_the_actual_product() {
        let a = compile_search("cat").unwrap();
        let b = compile_search("dog").unwrap();
        let est = estimate_fuse(&[&a, &b], 0);
        let prod = fuse(&[&a, &b], 0, 1).expect("unlimited budget");
        let actual = prod.dfa.num_states as usize;
        assert!(est.certain_min <= actual, "{} > {actual}", est.certain_min);
        assert!(est.upper_bound >= actual, "{} < {actual}", est.upper_bound);
        assert_eq!(est.combined_classes, prod.dfa.num_symbols as usize);
        assert!(!est.predicted_overflow);
    }

    #[test]
    fn certain_overflow_means_fuse_aborts() {
        let a = compile_search("cat").unwrap();
        let b = compile_search("dog").unwrap();
        let est = estimate_fuse(&[&a, &b], 1);
        assert!(est.predicted_overflow, "certain_min {}", est.certain_min);
        assert!(fuse(&[&a, &b], 1, 1).is_none(), "prediction must be sound");
    }

    #[test]
    fn literal_disjointness() {
        let l = |s: &str| Some(s.as_bytes().to_vec());
        assert_eq!(literals_disjoint(&[l("cat"), l("dog")]), Some(true));
        assert_eq!(literals_disjoint(&[l("cat"), l("concatenate")]), Some(false));
        assert_eq!(literals_disjoint(&[l("cat"), None]), None);
    }
}
