//! Static hazard analysis: every pass runs *before* anything executes.
//!
//! The paper's levers are structural DFA properties (Eq. 11–18: I_σ,
//! I_max,r, γ); this subsystem turns them — plus the regex-pathology
//! catalog of arXiv 1110.1716 and the product-size predictability
//! observation of arXiv 1512.09228 — into a pre-execution analyzer with
//! four passes and one versioned machine-readable record:
//!
//! | pass | subject | hazard / fact |
//! |------|---------|----------------|
//! | [`regex`] | pattern AST | ReDoS ambiguity (nested quantifiers, overlapping alternation), anchors, required literal |
//! | [`dfa`] | compiled DFA | γ / I_max,r curve (Eq. 12/18), minimality gap, dead states, speculation-feasibility verdict |
//! | [`fuse`] | pattern set | product-size bounds: skip `fuse` attempts guaranteed to bust `state_budget` |
//! | [`proto`] | `cluster::proto` | session-FSM safety: every arrival handled, no dead ends |
//!
//! Consumers:
//!
//! * `specdfa analyze` (CLI) emits the [`report::ANALYSIS_SCHEMA`] JSON
//!   record.
//! * [`crate::engine::serve`] gates admission on the regex pass
//!   ([`crate::engine::serve::HazardPolicy`]).
//! * [`crate::engine::patternset`] consults the fuse estimate before
//!   paying for a doomed product construction.
//! * `Engine::Auto` ([`crate::engine::CompiledMatcher`]) skips building
//!   parallel adapters for speculation-hostile DFAs.

pub mod dfa;
pub mod fuse;
pub mod proto;
pub mod regex;
pub mod report;

pub use dfa::{analyze_dfa, speculation_hostile, DfaReport, Feasibility};
pub use fuse::{estimate_fuse, literals_disjoint, FuseEstimate};
pub use proto::{check_model, session_model, ProtoReport, SessionModel, SessionState};
pub use regex::{lint_ast, lint_pattern, Hazard, HazardKind, PatternFacts, PatternReport};
pub use report::{
    analyze_patterns, render_analysis_json, AnalysisReport, PatternAnalysis,
    ANALYSIS_SCHEMA,
};
