//! The machine-readable analysis record: `specdfa-analysis-v1`.
//!
//! [`analyze_patterns`] runs every pass over a pattern list —
//! [`super::regex`] lints, per-DFA [`super::dfa`] structure reports, the
//! [`super::fuse`] product-size estimate when more than one pattern is
//! given, and the [`super::proto`] session-FSM check — and
//! [`render_analysis_json`] serializes the result as a versioned JSON
//! document, the same hand-rolled emission style as the
//! `specdfa-bench-v1` records ([`crate::util::bench`]).  CI
//! schema-validates the document alongside the bench records.

use anyhow::Result;

use crate::engine::Pattern;
use crate::util::bench::json_escape;

use super::dfa::{analyze_dfa, DfaReport};
use super::fuse::{estimate_fuse, literals_disjoint, FuseEstimate};
use super::proto::{check_model, session_model, ProtoReport};
use super::regex::{lint_pattern, PatternReport};

/// Schema identifier stamped into every analysis JSON document.
pub const ANALYSIS_SCHEMA: &str = "specdfa-analysis-v1";

/// All passes' results for one pattern.
#[derive(Clone, Debug)]
pub struct PatternAnalysis {
    /// the regex pass (AST lints + facts)
    pub regex: PatternReport,
    /// the DFA pass (structure + feasibility verdict)
    pub dfa: DfaReport,
}

/// The full analysis record for one `specdfa analyze` invocation.
#[derive(Clone, Debug)]
pub struct AnalysisReport {
    /// per-pattern pass results, in input order
    pub patterns: Vec<PatternAnalysis>,
    /// the fuse estimate (present when ≥ 2 patterns were analyzed)
    pub fuse: Option<FuseEstimate>,
    /// whether the patterns' required literals are pairwise disjoint
    /// (`None` when any pattern lacks one, or with < 2 patterns)
    pub literals_disjoint: Option<bool>,
    /// the protocol session-FSM check (pattern-independent)
    pub proto: ProtoReport,
    /// lookahead depth the DFA pass used
    pub r: usize,
    /// processor count the Eq. 18 cost model used
    pub processors: usize,
    /// γ threshold the feasibility verdicts used
    pub gamma_max: f64,
}

impl AnalysisReport {
    /// Number of patterns with at least one ReDoS-family hazard.
    pub fn hazardous(&self) -> usize {
        self.patterns.iter().filter(|p| p.regex.is_hazardous()).count()
    }
}

/// Run every pass over `patterns`.  `state_budget` parameterizes the
/// fuse estimate (0 = unlimited, the `fuse` convention); `r`,
/// `processors` and `gamma_max` parameterize the DFA pass.  Fails only
/// if a pattern does not parse/compile.
pub fn analyze_patterns(
    patterns: &[Pattern],
    r: usize,
    processors: usize,
    gamma_max: f64,
    state_budget: usize,
) -> Result<AnalysisReport> {
    let mut reports = Vec::with_capacity(patterns.len());
    let mut dfas = Vec::with_capacity(patterns.len());
    for p in patterns {
        let regex = lint_pattern(p)?;
        let parts = p.compile()?;
        dfas.push(parts.dfa);
        reports.push(regex);
    }
    let analyses: Vec<PatternAnalysis> = reports
        .into_iter()
        .zip(&dfas)
        .map(|(regex, dfa)| PatternAnalysis {
            dfa: analyze_dfa(dfa, r, processors, gamma_max),
            regex,
        })
        .collect();
    let (fuse, lits) = if dfas.len() >= 2 {
        let refs: Vec<&crate::automata::Dfa> = dfas.iter().collect();
        let literals: Vec<Option<Vec<u8>>> = analyses
            .iter()
            .map(|a| a.regex.facts.required_literal.clone())
            .collect();
        (
            Some(estimate_fuse(&refs, state_budget)),
            literals_disjoint(&literals),
        )
    } else {
        (None, None)
    };
    Ok(AnalysisReport {
        patterns: analyses,
        fuse,
        literals_disjoint: lits,
        proto: check_model(&session_model()),
        r: r.max(1),
        processors: processors.max(1),
        gamma_max,
    })
}

/// Serialize the report as a `specdfa-analysis-v1` JSON document.
pub fn render_analysis_json(report: &AnalysisReport) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str(&format!("  \"schema\": \"{ANALYSIS_SCHEMA}\",\n"));
    out.push_str(&format!(
        "  \"params\": {{\"r\": {}, \"processors\": {}, \"gamma_max\": {}}},\n",
        report.r,
        report.processors,
        json_f64(report.gamma_max)
    ));
    out.push_str(&format!(
        "  \"hazardous_patterns\": {},\n",
        report.hazardous()
    ));
    out.push_str("  \"patterns\": [\n");
    for (i, p) in report.patterns.iter().enumerate() {
        out.push_str("    ");
        out.push_str(&pattern_json(p));
        out.push_str(if i + 1 < report.patterns.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ],\n");
    match &report.fuse {
        Some(f) => out.push_str(&format!("  \"fuse\": {},\n", fuse_json(f))),
        None => out.push_str("  \"fuse\": null,\n"),
    }
    out.push_str(&format!(
        "  \"literals_disjoint\": {},\n",
        opt_bool(report.literals_disjoint)
    ));
    out.push_str(&format!("  \"proto\": {}\n", proto_json(&report.proto)));
    out.push_str("}\n");
    out
}

fn pattern_json(p: &PatternAnalysis) -> String {
    let hazards: Vec<String> = p
        .regex
        .hazards
        .iter()
        .map(|h| {
            format!(
                "{{\"kind\": \"{}\", \"severity\": \"{}\", \
                 \"detail\": \"{}\"}}",
                h.kind.name(),
                h.kind.severity(),
                json_escape(&h.detail)
            )
        })
        .collect();
    let f = &p.regex.facts;
    let literal = match &f.required_literal {
        Some(bytes) => {
            format!("\"{}\"", json_escape(&String::from_utf8_lossy(bytes)))
        }
        None => "null".to_string(),
    };
    let d = &p.dfa;
    let curve: Vec<String> =
        d.i_max_by_r.iter().map(|v| v.to_string()).collect();
    format!(
        "{{\"pattern\": \"{}\", \"kind\": \"{}\", \
         \"hazards\": [{}], \
         \"facts\": {{\"ast_size\": {}, \"repeat_depth\": {}, \
         \"unbounded_repeats\": {}, \"alternations\": {}, \
         \"anchored_start\": {}, \"anchored_end\": {}, \
         \"required_literal\": {}}}, \
         \"dfa\": {{\"q\": {}, \"sigma\": {}, \"r\": {}, \"i_max\": {}, \
         \"i_max_by_r\": [{}], \"gamma\": {}, \"minimal_q\": {}, \
         \"minimality_gap\": {}, \"unreachable_states\": {}, \
         \"dead_states\": {}, \"sink_state\": {}, \
         \"accepting_states\": {}, \"predicted_speedup\": {}, \
         \"chunk_overhead\": {}, \"feasibility\": \"{}\"}}}}",
        json_escape(&p.regex.pattern),
        p.regex.kind,
        hazards.join(", "),
        f.ast_size,
        f.repeat_depth,
        f.unbounded_repeats,
        f.alternations,
        f.anchored_start,
        f.anchored_end,
        literal,
        d.q,
        d.sigma,
        d.r,
        d.i_max,
        curve.join(", "),
        json_f64(d.gamma),
        d.minimal_q,
        d.minimality_gap,
        d.unreachable_states,
        d.dead_states,
        match d.sink_state {
            Some(s) => s.to_string(),
            None => "null".to_string(),
        },
        d.accepting_states,
        json_f64(d.predicted_speedup),
        json_f64(d.chunk_overhead),
        d.feasibility.name(),
    )
}

fn fuse_json(f: &FuseEstimate) -> String {
    let comps: Vec<String> =
        f.component_states.iter().map(|q| q.to_string()).collect();
    format!(
        "{{\"components\": {}, \"component_states\": [{}], \
         \"upper_bound\": {}, \"certain_min\": {}, \
         \"combined_classes\": {}, \"budget\": {}, \
         \"predicted_overflow\": {}}}",
        f.components,
        comps.join(", "),
        f.upper_bound,
        f.certain_min,
        f.combined_classes,
        f.budget,
        f.predicted_overflow
    )
}

fn proto_json(p: &ProtoReport) -> String {
    let problems: Vec<String> = p
        .problems
        .iter()
        .map(|m| format!("\"{}\"", json_escape(m)))
        .collect();
    format!(
        "{{\"states\": {}, \"transitions\": {}, \"arrivals\": {}, \
         \"ok\": {}, \"problems\": [{}]}}",
        p.states,
        p.transitions,
        p.arrivals,
        p.ok(),
        problems.join(", ")
    )
}

fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

fn opt_bool(v: Option<bool>) -> String {
    match v {
        Some(b) => b.to_string(),
        None => "null".to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_report_over_a_mixed_set() {
        let patterns = [
            Pattern::Regex("(a|a)*b".to_string()),
            Pattern::Regex("needle".to_string()),
        ];
        let rep = analyze_patterns(&patterns, 4, 8, 0.5, 1 << 14).unwrap();
        assert_eq!(rep.patterns.len(), 2);
        assert_eq!(rep.hazardous(), 1);
        assert!(rep.fuse.is_some());
        assert!(rep.proto.ok());
        let doc = render_analysis_json(&rep);
        assert!(doc.contains("\"schema\": \"specdfa-analysis-v1\""));
        assert!(doc.contains("overlapping-alternation"));
        assert!(doc.contains("\"required_literal\": \"needle\""));
        assert!(doc.contains("\"ok\": true"));
        // crude balance check on the hand-rolled emission
        let opens = doc.matches('{').count();
        let closes = doc.matches('}').count();
        assert_eq!(opens, closes, "unbalanced JSON:\n{doc}");
    }

    #[test]
    fn single_pattern_skips_fuse() {
        let rep = analyze_patterns(
            &[Pattern::Regex("abc".to_string())],
            2,
            4,
            0.5,
            0,
        )
        .unwrap();
        assert!(rep.fuse.is_none());
        assert!(rep.literals_disjoint.is_none());
        let doc = render_analysis_json(&rep);
        assert!(doc.contains("\"fuse\": null"));
    }

    #[test]
    fn unparsable_pattern_is_an_error() {
        assert!(analyze_patterns(
            &[Pattern::Regex("(a".to_string())],
            2,
            4,
            0.5,
            0
        )
        .is_err());
    }
}
