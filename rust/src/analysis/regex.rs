//! AST lints over [`Pattern`]: the pre-execution regex hazard pass.
//!
//! The detector targets the matching pathologies cataloged by Quesada
//! et al. (arXiv 1110.1716) — the ambiguity families that blow up a
//! backtracking matcher even though the DFA engines are immune:
//!
//! * **Nested unbounded quantifiers** (`(a+)+`, `(a*b*)*`) — the number
//!   of ways to split the input among the repeat levels is exponential
//!   in its length ("exponential ReDoS").
//! * **Overlapping alternation under an unbounded repeat** (`(a|a)*b`,
//!   `(ab|a)*c`) — two branches can consume the same prefix, so a
//!   backtracker explores polynomially many branch interleavings
//!   ("polynomial ReDoS").
//!
//! The repo keeps a backtracking comparator engine whose fuel cap
//! ([`crate::baseline::backtracking::MAX_FUEL`]) exists precisely for
//! these inputs; this pass flags them *before* anything runs, so the
//! serving stack can warn or refuse at admission
//! ([`crate::engine::ServeConfig::hazard_policy`]) instead of burning
//! the fuel budget.
//!
//! Besides hazards the pass reports routing-quality **facts**: anchors,
//! the required literal (the grep-like prefilter key), AST size and
//! quantifier-nesting depth, and feature-use counts.

use anyhow::Result;

use crate::automata::byteset::ByteSet;
use crate::baseline::greplike::required_literal;
use crate::engine::Pattern;
use crate::regex::ast::Ast;
use crate::regex::{parser, prosite};

/// The hazard family a lint found.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HazardKind {
    /// Nested unbounded quantifiers: exponential backtracking blowup.
    NestedQuantifier,
    /// Overlapping alternation branches under an unbounded repeat:
    /// polynomial backtracking blowup.
    OverlappingAlternation,
}

impl HazardKind {
    /// Blowup class of this hazard family ("exponential"/"polynomial").
    pub fn severity(&self) -> &'static str {
        match self {
            HazardKind::NestedQuantifier => "exponential",
            HazardKind::OverlappingAlternation => "polynomial",
        }
    }

    /// Stable lowercase identifier (used in the JSON report).
    pub fn name(&self) -> &'static str {
        match self {
            HazardKind::NestedQuantifier => "nested-quantifier",
            HazardKind::OverlappingAlternation => "overlapping-alternation",
        }
    }
}

/// One hazard found by the AST lints.
#[derive(Clone, Debug)]
pub struct Hazard {
    /// the hazard family
    pub kind: HazardKind,
    /// human-readable description of the offending construct
    pub detail: String,
}

/// Routing-quality facts about a pattern (no hazard implied).
#[derive(Clone, Debug, Default)]
pub struct PatternFacts {
    /// AST node count ([`Ast::size`])
    pub ast_size: usize,
    /// maximum quantifier-nesting depth (repeats inside repeats)
    pub repeat_depth: usize,
    /// number of unbounded (`max = None`) repeats
    pub unbounded_repeats: usize,
    /// number of alternation nodes
    pub alternations: usize,
    /// pattern is anchored at the start (`^` / `<`)
    pub anchored_start: bool,
    /// pattern is anchored at the end (`$` / `>`)
    pub anchored_end: bool,
    /// the required literal every match must contain, when one exists
    /// (the grep-like / Aho–Corasick prefilter key)
    pub required_literal: Option<Vec<u8>>,
}

/// The regex pass report for one pattern.
#[derive(Clone, Debug)]
pub struct PatternReport {
    /// source pattern text
    pub pattern: String,
    /// pattern frontend ("regex" / "regex-exact" / "prosite" / "grail")
    pub kind: &'static str,
    /// hazards found (empty = clean)
    pub hazards: Vec<Hazard>,
    /// routing-quality facts
    pub facts: PatternFacts,
}

impl PatternReport {
    /// Whether any ReDoS-family hazard was found.
    pub fn is_hazardous(&self) -> bool {
        !self.hazards.is_empty()
    }
}

/// Run the regex pass on one [`Pattern`].  Parse-only — no NFA, subset
/// construction or minimization runs — so this is cheap enough to gate
/// serve admission on.  Fails only if the pattern does not parse.
pub fn lint_pattern(pattern: &Pattern) -> Result<PatternReport> {
    let (text, kind, ast, anchored_start, anchored_end) = match pattern {
        Pattern::Regex(p) => {
            let parsed = parser::parse(p)?;
            (p.as_str(), "regex", Some(parsed.ast), parsed.anchored_start, parsed.anchored_end)
        }
        Pattern::RegexExact(p) => {
            let parsed = parser::parse(p)?;
            // whole-input semantics: effectively anchored at both ends
            (p.as_str(), "regex-exact", Some(parsed.ast), true, true)
        }
        Pattern::Prosite(p) => {
            let parsed = prosite::parse(p)?;
            (p.as_str(), "prosite", Some(parsed.ast), parsed.anchored_start, parsed.anchored_end)
        }
        Pattern::Grail(text) => {
            // no AST: validate the text parses, report facts-only
            crate::automata::grail::from_grail(text)?;
            (text.as_str(), "grail", None, true, true)
        }
    };
    let (hazards, facts) = match &ast {
        Some(ast) => {
            let mut facts = PatternFacts {
                ast_size: ast.size(),
                required_literal: required_literal(ast),
                anchored_start,
                anchored_end,
                ..PatternFacts::default()
            };
            collect_facts(ast, 0, &mut facts);
            (lint_ast(ast), facts)
        }
        None => (
            Vec::new(),
            PatternFacts { anchored_start, anchored_end, ..PatternFacts::default() },
        ),
    };
    Ok(PatternReport { pattern: text.to_string(), kind, hazards, facts })
}

/// Run the ReDoS lints over a raw AST.  Returns every hazard found
/// (deduplicated by construct, not by family — a pattern with two
/// independent nests reports two hazards).
pub fn lint_ast(ast: &Ast) -> Vec<Hazard> {
    let mut out = Vec::new();
    walk(ast, &mut out);
    out
}

fn walk(ast: &Ast, out: &mut Vec<Hazard>) {
    if let Ast::Repeat { node, max: None, .. } = ast {
        if matches_nonempty(node) {
            if directly_unbounded(node) {
                out.push(Hazard {
                    kind: HazardKind::NestedQuantifier,
                    detail: "unbounded repeat whose body is itself \
                             unbounded (e.g. (a+)+): exponential \
                             backtracking ambiguity"
                        .to_string(),
                });
            }
            for alt in body_alternations(node) {
                if let Some((i, j)) = overlapping_branches(alt) {
                    out.push(Hazard {
                        kind: HazardKind::OverlappingAlternation,
                        detail: format!(
                            "alternation branches {i} and {j} share \
                             first bytes under an unbounded repeat \
                             (e.g. (a|a)* / (ab|a)*): polynomial \
                             backtracking ambiguity"
                        ),
                    });
                }
            }
        }
    }
    match ast {
        Ast::Concat(parts) | Ast::Alt(parts) => {
            for p in parts {
                walk(p, out);
            }
        }
        Ast::Repeat { node, .. } => walk(node, out),
        Ast::Empty | Ast::Epsilon | Ast::Class(_) => {}
    }
}

/// Whether the repeat body can absorb input through a nested unbounded
/// repeat with every other element skippable — the shape where the
/// outer and inner repeat compete for the same characters.
fn directly_unbounded(body: &Ast) -> bool {
    match body {
        Ast::Repeat { node, max: None, .. } => matches_nonempty(node),
        Ast::Concat(parts) => {
            parts.iter().any(directly_unbounded)
                && parts
                    .iter()
                    .all(|p| nullable(p) || directly_unbounded(p))
        }
        Ast::Alt(parts) => parts.iter().any(directly_unbounded),
        _ => false,
    }
}

/// The alternation nodes that sit at the "top" of a repeat body: the
/// body itself, or an element of a concat whose other elements are all
/// nullable (so the alternation competes with the repeat directly).
fn body_alternations(body: &Ast) -> Vec<&Ast> {
    match body {
        Ast::Alt(_) => vec![body],
        Ast::Concat(parts) if parts.iter().all(nullable_or_alt) => parts
            .iter()
            .filter(|p| matches!(p, Ast::Alt(_)))
            .collect(),
        _ => Vec::new(),
    }
}

fn nullable_or_alt(ast: &Ast) -> bool {
    nullable(ast) || matches!(ast, Ast::Alt(_))
}

/// First pair of alternation branches that both match non-empty input
/// and share a possible first byte (the local-ambiguity witness).
fn overlapping_branches(alt: &Ast) -> Option<(usize, usize)> {
    let Ast::Alt(branches) = alt else { return None };
    for i in 0..branches.len() {
        if !matches_nonempty(&branches[i]) {
            continue;
        }
        let fi = first_set(&branches[i]);
        for (jo, bj) in branches.iter().enumerate().skip(i + 1) {
            if !matches_nonempty(bj) {
                continue;
            }
            if !fi.intersect(&first_set(bj)).is_empty() {
                return Some((i, jo));
            }
        }
    }
    None
}

/// Whether the node's language contains the empty string.
fn nullable(ast: &Ast) -> bool {
    match ast {
        Ast::Empty => false,
        Ast::Epsilon => true,
        Ast::Class(_) => false,
        Ast::Concat(parts) => parts.iter().all(nullable),
        Ast::Alt(parts) => parts.iter().any(nullable),
        Ast::Repeat { node, min, .. } => *min == 0 || nullable(node),
    }
}

/// Whether the node's language contains a non-empty string.
fn matches_nonempty(ast: &Ast) -> bool {
    match ast {
        Ast::Empty | Ast::Epsilon => false,
        Ast::Class(s) => !s.is_empty(),
        Ast::Concat(parts) => {
            parts.iter().all(can_match) && parts.iter().any(matches_nonempty)
        }
        Ast::Alt(parts) => parts.iter().any(matches_nonempty),
        Ast::Repeat { node, max, .. } => {
            *max != Some(0) && matches_nonempty(node)
        }
    }
}

/// Whether the node's language is non-empty at all.
fn can_match(ast: &Ast) -> bool {
    match ast {
        Ast::Empty => false,
        Ast::Epsilon => true,
        Ast::Class(s) => !s.is_empty(),
        Ast::Concat(parts) => parts.iter().all(can_match),
        Ast::Alt(parts) => parts.iter().any(can_match),
        Ast::Repeat { node, min, .. } => *min == 0 || can_match(node),
    }
}

/// Possible first bytes of the non-empty strings in the node's language
/// (conservative over-approximation).
fn first_set(ast: &Ast) -> ByteSet {
    match ast {
        Ast::Empty | Ast::Epsilon => ByteSet::EMPTY,
        Ast::Class(s) => *s,
        Ast::Concat(parts) => {
            let mut fs = ByteSet::EMPTY;
            for p in parts {
                fs = fs.union(&first_set(p));
                if !nullable(p) {
                    break;
                }
            }
            fs
        }
        Ast::Alt(parts) => {
            let mut fs = ByteSet::EMPTY;
            for p in parts {
                fs = fs.union(&first_set(p));
            }
            fs
        }
        Ast::Repeat { node, max, .. } => {
            if *max == Some(0) {
                ByteSet::EMPTY
            } else {
                first_set(node)
            }
        }
    }
}

fn collect_facts(ast: &Ast, depth: usize, facts: &mut PatternFacts) {
    match ast {
        Ast::Alt(parts) => {
            facts.alternations += 1;
            for p in parts {
                collect_facts(p, depth, facts);
            }
        }
        Ast::Concat(parts) => {
            for p in parts {
                collect_facts(p, depth, facts);
            }
        }
        Ast::Repeat { node, max, .. } => {
            let depth = depth + 1;
            facts.repeat_depth = facts.repeat_depth.max(depth);
            if max.is_none() {
                facts.unbounded_repeats += 1;
            }
            collect_facts(node, depth, facts);
        }
        Ast::Empty | Ast::Epsilon | Ast::Class(_) => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint(p: &str) -> Vec<Hazard> {
        lint_pattern(&Pattern::Regex(p.to_string())).unwrap().hazards
    }

    #[test]
    fn flags_the_redos_families() {
        // polynomial: overlapping alternation under a star
        let h = lint("(a|a)*b");
        assert!(h.iter().any(|h| h.kind == HazardKind::OverlappingAlternation), "{h:?}");
        let h = lint("(ab|a)*c");
        assert!(h.iter().any(|h| h.kind == HazardKind::OverlappingAlternation), "{h:?}");
        // exponential: nested unbounded quantifiers
        let h = lint("(a+)+b");
        assert!(h.iter().any(|h| h.kind == HazardKind::NestedQuantifier), "{h:?}");
        let h = lint("(a*b*)*c");
        assert!(h.iter().any(|h| h.kind == HazardKind::NestedQuantifier), "{h:?}");
    }

    #[test]
    fn clean_patterns_stay_clean() {
        for p in [
            "abc",
            "[0-9]+",
            "(ab|cd)+e",
            "a{2,5}b",
            "(a+b)+",      // inner repeat guarded by a mandatory 'b'
            "(ab|cd|ef)*", // disjoint first bytes
            "colou?r",
            "(a|b)(a|b)",  // overlap, but not under a repeat
        ] {
            assert!(lint(p).is_empty(), "false positive on {p:?}: {:?}", lint(p));
        }
    }

    #[test]
    fn severity_classes() {
        assert_eq!(HazardKind::NestedQuantifier.severity(), "exponential");
        assert_eq!(
            HazardKind::OverlappingAlternation.severity(),
            "polynomial"
        );
    }

    #[test]
    fn facts_capture_structure() {
        let r = lint_pattern(&Pattern::Regex("^(ab|cd)+e$".to_string()))
            .unwrap();
        assert!(r.facts.anchored_start && r.facts.anchored_end);
        assert_eq!(r.facts.alternations, 1);
        assert_eq!(r.facts.unbounded_repeats, 1);
        assert_eq!(r.facts.repeat_depth, 1);
        assert!(r.hazards.is_empty());
        let r = lint_pattern(&Pattern::Regex("needle".to_string())).unwrap();
        assert_eq!(r.facts.required_literal.as_deref(), Some(&b"needle"[..]));
    }

    #[test]
    fn prosite_and_grail_frontends_lint() {
        let r = lint_pattern(&Pattern::Prosite("C-x(2)-C.".to_string()))
            .unwrap();
        assert_eq!(r.kind, "prosite");
        assert!(r.hazards.is_empty());
        let fig6 = "(START) |- 0\n0 0 1\n0 1 2\n1 0 1\n1 1 3\n2 0 3\n\
                    2 1 2\n3 0 3\n3 1 3\n3 -| (FINAL)\n";
        let r = lint_pattern(&Pattern::Grail(fig6.to_string())).unwrap();
        assert_eq!(r.kind, "grail");
        assert!(r.hazards.is_empty());
        assert!(lint_pattern(&Pattern::Regex("(a".to_string())).is_err());
    }
}
