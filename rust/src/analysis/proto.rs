//! Session-FSM model and checker for the `cluster::proto` conversation.
//!
//! The frontend/worker protocol is enforced today by integration tests
//! (`tests/cluster_proc.rs`) and fault-injection fuzzing — both
//! *trajectory* checks.  This pass is the static complement: the
//! conversation is written down as an explicit finite state machine over
//! the [`FrameKind`] alphabet, and an exhaustive-exploration checker
//! asserts the safety properties a trajectory suite can only sample:
//!
//! 1. every declared (state, frame) arrival has a handler transition,
//! 2. every state is reachable from the start state,
//! 3. every non-terminal state can still reach a terminal (no live-lock
//!    dead ends),
//! 4. terminal states have no outgoing transitions,
//! 5. every transition's (state, frame) pair is declared as a possible
//!    arrival (the model can't handle frames it claims can't arrive).
//!
//! [`session_model`] is the model of the protocol *as implemented* in
//! [`crate::cluster::proc`]; the ground-truth test seeds a mutation
//! (dropping the idle Heartbeat handler) and asserts the checker
//! catches it.

use crate::cluster::proto::FrameKind;

/// The frontend's view of one worker conversation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SessionState {
    /// socket connected, Hello not yet received
    Connecting,
    /// attached, no outstanding request
    Idle,
    /// a Compile is outstanding
    Compiling,
    /// a Match is outstanding (checkpoints may stream)
    Matching,
    /// Shutdown sent; the conversation is over
    Closed,
}

impl SessionState {
    /// Stable lowercase identifier (used in the JSON report).
    pub fn name(&self) -> &'static str {
        match self {
            SessionState::Connecting => "connecting",
            SessionState::Idle => "idle",
            SessionState::Compiling => "compiling",
            SessionState::Matching => "matching",
            SessionState::Closed => "closed",
        }
    }
}

/// A declarative session FSM: states, the frame alphabet, which frames
/// may arrive in which states, and the handler transitions.
#[derive(Clone, Debug)]
pub struct SessionModel {
    /// every session state
    pub states: Vec<SessionState>,
    /// initial state
    pub start: SessionState,
    /// terminal states (conversation over)
    pub terminals: Vec<SessionState>,
    /// (state, frame) pairs that can arrive per the protocol contract
    pub may_arrive: Vec<(SessionState, FrameKind)>,
    /// handler transitions: in `state`, on `frame`, go to `next`
    pub transitions: Vec<(SessionState, FrameKind, SessionState)>,
}

/// The proto pass report.
#[derive(Clone, Debug)]
pub struct ProtoReport {
    /// number of states in the model
    pub states: usize,
    /// number of handler transitions
    pub transitions: usize,
    /// number of declared (state, frame) arrivals
    pub arrivals: usize,
    /// every safety violation found (empty = the model checks out)
    pub problems: Vec<String>,
}

impl ProtoReport {
    /// Whether the model passed every check.
    pub fn ok(&self) -> bool {
        self.problems.is_empty()
    }
}

/// The SDPF conversation as implemented by [`crate::cluster::proc`]:
/// attach (`Hello`), compile round-trips, match with streamed
/// checkpoints, heartbeats in any quiescent or matching state, and
/// explicit shutdown.  Errors abort the outstanding request back to
/// idle (the retry/failover ladder runs above this layer).
pub fn session_model() -> SessionModel {
    use FrameKind::*;
    use SessionState::*;
    SessionModel {
        states: vec![Connecting, Idle, Compiling, Matching, Closed],
        start: Connecting,
        terminals: vec![Closed],
        may_arrive: vec![
            (Connecting, Hello),
            (Idle, Compile),
            (Compiling, CompileOk),
            (Compiling, Error),
            (Idle, Match),
            (Matching, Checkpoint),
            (Matching, Result),
            (Matching, Error),
            (Matching, Heartbeat),
            (Idle, Heartbeat),
            (Idle, Shutdown),
        ],
        transitions: vec![
            (Connecting, Hello, Idle),
            (Idle, Compile, Compiling),
            (Compiling, CompileOk, Idle),
            (Compiling, Error, Idle),
            (Idle, Match, Matching),
            (Matching, Checkpoint, Matching),
            (Matching, Result, Idle),
            (Matching, Error, Idle),
            (Matching, Heartbeat, Matching),
            (Idle, Heartbeat, Idle),
            (Idle, Shutdown, Closed),
        ],
    }
}

/// Exhaustively check a session model (the five safety properties in
/// the module docs).  Every violation is reported, not just the first.
pub fn check_model(model: &SessionModel) -> ProtoReport {
    let mut problems = Vec::new();

    // 1. every declared arrival has a handler
    for &(state, frame) in &model.may_arrive {
        let handled = model
            .transitions
            .iter()
            .any(|&(s, f, _)| s == state && f == frame);
        if !handled {
            problems.push(format!(
                "unhandled arrival: frame {} in state {} has no transition",
                frame.name(),
                state.name()
            ));
        }
    }

    // 5. no transition for an undeclared arrival
    for &(state, frame, _) in &model.transitions {
        let declared = model
            .may_arrive
            .iter()
            .any(|&(s, f)| s == state && f == frame);
        if !declared {
            problems.push(format!(
                "phantom transition: frame {} handled in state {} but \
                 not declared as a possible arrival",
                frame.name(),
                state.name()
            ));
        }
    }

    // reachability from start over handler transitions
    let mut reachable = vec![model.start];
    let mut frontier = vec![model.start];
    while let Some(state) = frontier.pop() {
        for &(s, _, next) in &model.transitions {
            if s == state && !reachable.contains(&next) {
                reachable.push(next);
                frontier.push(next);
            }
        }
    }

    // 2. every state reachable
    for &state in &model.states {
        if !reachable.contains(&state) {
            problems.push(format!(
                "unreachable state: {} cannot be entered from {}",
                state.name(),
                model.start.name()
            ));
        }
    }

    // 3. every reachable non-terminal can reach a terminal — backward
    // sweep from the terminals
    let mut can_finish: Vec<SessionState> = model.terminals.clone();
    loop {
        let mut grew = false;
        for &(s, _, next) in &model.transitions {
            if can_finish.contains(&next) && !can_finish.contains(&s) {
                can_finish.push(s);
                grew = true;
            }
        }
        if !grew {
            break;
        }
    }
    for &state in &reachable {
        if !model.terminals.contains(&state) && !can_finish.contains(&state)
        {
            problems.push(format!(
                "dead end: non-terminal state {} cannot reach any \
                 terminal state",
                state.name()
            ));
        }
    }

    // 4. terminals have no outgoing transitions
    for &term in &model.terminals {
        for &(s, frame, _) in &model.transitions {
            if s == term {
                problems.push(format!(
                    "terminal state {} has an outgoing transition on {}",
                    term.name(),
                    frame.name()
                ));
            }
        }
    }

    ProtoReport {
        states: model.states.len(),
        transitions: model.transitions.len(),
        arrivals: model.may_arrive.len(),
        problems,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn current_protocol_checks_out() {
        let report = check_model(&session_model());
        assert!(report.ok(), "problems: {:?}", report.problems);
        assert_eq!(report.states, 5);
    }

    #[test]
    fn dropped_handler_is_caught() {
        let mut model = session_model();
        model.transitions.retain(|&(s, f, _)| {
            !(s == SessionState::Idle && f == FrameKind::Heartbeat)
        });
        let report = check_model(&model);
        assert!(!report.ok());
        assert!(
            report.problems.iter().any(|p| p.contains("unhandled")
                && p.contains("heartbeat")
                && p.contains("idle")),
            "{:?}",
            report.problems
        );
    }

    #[test]
    fn dead_end_is_caught() {
        let mut model = session_model();
        // sever Idle's path to Closed
        model.transitions.retain(|&(s, f, _)| {
            !(s == SessionState::Idle && f == FrameKind::Shutdown)
        });
        model
            .may_arrive
            .retain(|&(s, f)| !(s == SessionState::Idle && f == FrameKind::Shutdown));
        let report = check_model(&model);
        assert!(
            report.problems.iter().any(|p| p.contains("dead end")),
            "{:?}",
            report.problems
        );
    }

    #[test]
    fn phantom_transition_is_caught() {
        let mut model = session_model();
        model.transitions.push((
            SessionState::Connecting,
            FrameKind::Shutdown,
            SessionState::Closed,
        ));
        let report = check_model(&model);
        assert!(
            report.problems.iter().any(|p| p.contains("phantom")),
            "{:?}",
            report.problems
        );
    }
}
