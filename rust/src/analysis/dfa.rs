//! Per-DFA structural report: the speculation-feasibility pass.
//!
//! Everything `Engine::Auto` decides from at match time — γ = I_max,r/|Q|
//! (Eq. 18), the I_max,r curve (Eq. 12, Lemma 1) — is surfaced here
//! *before* anything runs, together with facts only a static pass has
//! time to compute: the minimality gap against a Hopcroft re-minimized
//! copy, dead/unreachable state counts, and sink absorption.  The verdict
//! is binary: **speculation-friendly** (parallel substrates can win) or
//! **speculation-hostile** (γ past the threshold — e.g. permutation DFAs
//! where every r-gram image keeps |Q| candidates, so Eq. 18 bounds the
//! speedup below break-even and Listing 1 is optimal).
//!
//! [`speculation_hostile`] is the same predicate `Engine::Auto` rule 2
//! applies at dispatch; `engine::mod` consults it at *compile* time to
//! skip building the parallel adapters a hostile DFA can never route to.

use crate::automata::minimize::minimize;
use crate::automata::Dfa;
use crate::engine::select::{AutoThresholds, DfaProps};
use crate::speculative::lookahead::Lookahead;

/// The speculation-feasibility verdict for one DFA.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Feasibility {
    /// γ ≤ threshold: parallel substrates can beat Listing 1.
    Friendly,
    /// γ > threshold: Eq. 18 bounds every parallel substrate below
    /// break-even; route sequential.
    Hostile,
}

impl Feasibility {
    /// Stable lowercase identifier (used in the JSON report).
    pub fn name(&self) -> &'static str {
        match self {
            Feasibility::Friendly => "speculation-friendly",
            Feasibility::Hostile => "speculation-hostile",
        }
    }
}

/// The DFA pass report.
#[derive(Clone, Debug)]
pub struct DfaReport {
    /// |Q|
    pub q: usize,
    /// |Σ| (dense symbol classes)
    pub sigma: usize,
    /// lookahead depth used (≥ 1)
    pub r: usize,
    /// I_max,r (Eq. 12)
    pub i_max: usize,
    /// the I_max,k curve for k = 1..=r (Lemma 1: non-increasing)
    pub i_max_by_r: Vec<usize>,
    /// γ = I_max,r / |Q| (Eq. 18)
    pub gamma: f64,
    /// |Q| of the Hopcroft-minimized copy
    pub minimal_q: usize,
    /// |Q| − minimal |Q| (0 = the DFA is already minimal)
    pub minimality_gap: usize,
    /// states unreachable from the start state
    pub unreachable_states: usize,
    /// live states from which no accepting state is reachable
    /// (beyond the designated sink)
    pub dead_states: usize,
    /// the absorbing non-accepting sink, if one exists
    pub sink_state: Option<u32>,
    /// number of accepting states
    pub accepting_states: usize,
    /// processor count the cost model was evaluated for
    pub processors: usize,
    /// γ threshold the verdict used
    pub gamma_max: f64,
    /// Eq. 18 cost model: predicted speculative speedup on `processors`
    /// cores — min(P, 1 + (P−1)/I_max,r)
    pub predicted_speedup: f64,
    /// Eq. 18 cost model: per-chunk overhead factor — each non-first
    /// chunk must run I_max,r chains instead of 1
    pub chunk_overhead: f64,
    /// the verdict
    pub feasibility: Feasibility,
}

/// The same predicate [`crate::engine::select::select`] rule 2 applies at
/// dispatch time: γ past the threshold means every parallel substrate is
/// bounded below break-even, so Auto always routes sequential.
pub fn speculation_hostile(props: &DfaProps, t: &AutoThresholds) -> bool {
    props.gamma > t.gamma_max
}

/// Run the DFA pass: Lookahead BFS for the I_max,r curve, a Hopcroft
/// re-minimization for the minimality gap, and reachability sweeps for
/// dead/unreachable states.  `r` is clamped to ≥ 1; `gamma_max` is the
/// verdict threshold (use [`AutoThresholds::default`]'s 0.5 to match
/// Auto routing).
pub fn analyze_dfa(
    dfa: &Dfa,
    r: usize,
    processors: usize,
    gamma_max: f64,
) -> DfaReport {
    let q = dfa.num_states as usize;
    let la = Lookahead::analyze(dfa, r.max(1));
    let gamma = la.gamma(dfa);
    let minimal_q = minimize(dfa).num_states as usize;
    let unreachable = q - dfa.trim_unreachable().num_states as usize;
    let sink = dfa.sink();
    let dead = dead_states(dfa, sink);
    let p = processors.max(1) as f64;
    let i_max = la.i_max.max(1) as f64;
    let predicted_speedup = (1.0 + (p - 1.0) / i_max).min(p);
    let feasibility = if gamma > gamma_max {
        Feasibility::Hostile
    } else {
        Feasibility::Friendly
    };
    DfaReport {
        q,
        sigma: dfa.num_symbols as usize,
        r: la.r,
        i_max: la.i_max,
        i_max_by_r: la.i_max_by_r.clone(),
        gamma,
        minimal_q,
        minimality_gap: q.saturating_sub(minimal_q),
        unreachable_states: unreachable,
        dead_states: dead,
        sink_state: sink,
        accepting_states: dfa.num_accepting(),
        processors: processors.max(1),
        gamma_max,
        predicted_speedup,
        chunk_overhead: i_max,
        feasibility,
    }
}

/// Count live (start-reachable) non-sink states from which no accepting
/// state is reachable — work the matcher does that can never change the
/// verdict, i.e. states a trimming pass could absorb into the sink.
fn dead_states(dfa: &Dfa, sink: Option<u32>) -> usize {
    let q = dfa.num_states as usize;
    let s = dfa.num_symbols as usize;
    // reverse edges
    let mut preds: Vec<Vec<u32>> = vec![Vec::new(); q];
    for state in 0..q as u32 {
        for sym in 0..s as u32 {
            let t = dfa.step(state, sym) as usize;
            preds[t].push(state);
        }
    }
    // backward BFS from accepting states
    let mut productive = vec![false; q];
    let mut stack: Vec<u32> = (0..q as u32)
        .filter(|&st| dfa.accepting[st as usize])
        .collect();
    for &st in &stack {
        productive[st as usize] = true;
    }
    while let Some(st) = stack.pop() {
        for &p in &preds[st as usize] {
            if !productive[p as usize] {
                productive[p as usize] = true;
                stack.push(p);
            }
        }
    }
    // forward reachability from start
    let mut reachable = vec![false; q];
    reachable[dfa.start as usize] = true;
    let mut stack = vec![dfa.start];
    while let Some(st) = stack.pop() {
        for sym in 0..s as u32 {
            let t = dfa.step(st, sym);
            if !reachable[t as usize] {
                reachable[t as usize] = true;
                stack.push(t);
            }
        }
    }
    (0..q)
        .filter(|&st| {
            reachable[st] && !productive[st] && Some(st as u32) != sink
        })
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::regex::compile::compile_search;
    use crate::util::workload::permutation_dfa;

    #[test]
    fn literal_dfa_is_friendly_and_minimal() {
        let dfa = compile_search("needle").unwrap();
        let rep = analyze_dfa(&dfa, 4, 8, 0.5);
        assert_eq!(rep.feasibility, Feasibility::Friendly);
        assert_eq!(rep.minimality_gap, 0, "compile pipeline minimizes");
        assert_eq!(rep.unreachable_states, 0);
        assert!(rep.gamma <= 0.5, "gamma {}", rep.gamma);
        assert!(rep.predicted_speedup > 1.0);
        // Lemma 1: the curve is non-increasing
        for w in rep.i_max_by_r.windows(2) {
            assert!(w[0] >= w[1], "{:?}", rep.i_max_by_r);
        }
    }

    #[test]
    fn permutation_dfa_is_hostile() {
        // γ = 1: every symbol permutes Q, so every r-gram image keeps
        // all |Q| candidates — the paper's worst case.
        let dfa = permutation_dfa(16, 4, 7);
        let rep = analyze_dfa(&dfa, 4, 8, 0.5);
        assert_eq!(rep.i_max, rep.q);
        assert!((rep.gamma - 1.0).abs() < 1e-12);
        assert_eq!(rep.feasibility, Feasibility::Hostile);
        assert_eq!(rep.feasibility.name(), "speculation-hostile");
        // Eq. 18: 8 cores buy < 1.5x on a permutation DFA
        assert!(rep.predicted_speedup < 1.5, "{}", rep.predicted_speedup);
        let props = DfaProps::analyze(&dfa, 4);
        assert!(speculation_hostile(&props, &AutoThresholds::default()));
    }

    #[test]
    fn dead_state_detection() {
        // a(b) with an explicit dead branch: build via Grail text —
        // state 2 is live-reachable but can never accept, and is not
        // the all-self-loop sink (it steps to the sink 3).
        let dfa = crate::automata::grail::from_grail(
            "(START) |- 0\n0 0 1\n0 1 2\n1 0 1\n1 1 1\n\
             2 0 3\n2 1 3\n3 0 3\n3 1 3\n1 -| (FINAL)\n",
        )
        .unwrap();
        let rep = analyze_dfa(&dfa, 2, 4, 0.5);
        assert_eq!(rep.sink_state, Some(3));
        assert_eq!(rep.dead_states, 1, "state 2 is dead but not the sink");
    }
}
