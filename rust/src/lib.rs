//! # specdfa — Speculative Parallel DFA Membership Test
//!
//! Production-quality reproduction of *"A Speculative Parallel DFA
//! Membership Test for Multicore, SIMD and Cloud Computing Environments"*
//! (Ko, Jung, Han, Burgstaller; Int. J. Parallel Programming, 2012).
//!
//! ## The engine facade
//!
//! The public API is the [`engine`] module: compile a [`engine::Pattern`]
//! once into a [`engine::CompiledMatcher`], then serve membership tests
//! through one request path whatever substrate runs them:
//!
//! ```no_run
//! use specdfa::engine::{CompiledMatcher, Engine, ExecPolicy, Matcher, Pattern};
//!
//! let cm = CompiledMatcher::compile(
//!     &Pattern::Regex("GET /[a-z]+ HTTP/1\\.[01]".into()),
//!     Engine::Auto,
//!     ExecPolicy::default(),
//! )?;
//! let out = cm.run_bytes(b"GET /index HTTP/1.1")?;
//! println!("accepted={} via {}", out.accepted, out.engine);
//! # anyhow::Result::<()>::Ok(())
//! ```
//!
//! * [`engine::Engine::Auto`] picks the substrate per request from the
//!   DFA's structural properties (γ = I_max,r/|Q|, Eq. 18) and the input
//!   length — small probes stay on the Listing-1 scalar loop, structured
//!   patterns go to the vector unit or the multicore speculative matcher,
//!   large scans go to the cluster, and corpus-scale scans go to the
//!   hierarchical shard engine ([`engine::shard`]): a two-level Eq. (1)
//!   partition across cluster nodes *and* each node's cores, driven by
//!   measured per-worker capacity vectors
//!   ([`speculative::profile::profile_workers`]).
//! * [`engine::CompiledMatcher::match_many`] serves batches, amortizing
//!   compilation and plan construction across requests; failed requests
//!   get their own error slot instead of aborting the batch.
//! * [`engine::PatternSet`] compiles k patterns into one
//!   [`engine::CompiledSetMatcher`] — an Aho–Corasick literal prefilter,
//!   a fused product DFA with per-pattern accept bitmasks, and a
//!   budget-bounded spill tier — so one input pass answers every
//!   pattern's membership query ([`engine::patternset`]).
//! * [`engine::serve::Server`] is the asynchronous serving loop: many
//!   producers submit `(pattern, input)` requests, worker threads
//!   coalesce same-pattern requests behind an LRU compiled-pattern
//!   cache, and `Engine::Auto` routing uses thresholds calibrated from
//!   the §4.1 offline profiling step (re-run periodically), not the
//!   baked-in paper-era ballpark.
//! * [`engine::StreamMatcher`] accepts the input in segments with a
//!   serializable [`engine::Checkpoint`] ([`engine::stream`]):
//!   constant-memory tailing of unbounded streams, preempt/resume of
//!   long scans (the serve loop parks scans when probes arrive), and a
//!   wire format for migrating a scan between workers or processes.
//! * Every adapter implements [`engine::Matcher`] and returns the unified
//!   [`engine::Outcome`]; failure-freedom (identical results to
//!   sequential matching) is enforced by construction and property tests.
//!
//! * [`analysis`] is the static hazard analyzer (`specdfa analyze`):
//!   ReDoS ambiguity lints over pattern ASTs, per-DFA
//!   speculation-feasibility reports (γ, the I_max,r curve, minimality
//!   gap), pre-fuse product-size prediction consumed by
//!   [`engine::patternset`], and a session-FSM checker for the
//!   [`cluster::proto`] conversation — all wired into serving:
//!   [`engine::ServeConfig::hazard_policy`] warns on or rejects
//!   hazardous patterns at admission.
//!
//! ## The substrates underneath
//!
//! * [`regex`] / [`automata`] — pattern frontends and the Grail+-substitute
//!   toolchain (Thompson NFA, subset construction, Hopcroft minimization,
//!   flattened SBase/IBase tables).
//! * [`baseline`] — sequential matcher (Listing 1), Holub–Štekr comparator,
//!   backtracking (ScanProsite analog) and grep-like engines.
//! * [`speculative`] — the paper's contribution: failure-free speculative
//!   parallel matching with I_max,r reverse-lookahead optimization,
//!   weighted partitioning and L-vector merging.
//! * [`cluster`] — the cloud environment twice over: the simulated EC2
//!   timing model ([`cluster::cloud`]) and a **real multi-process
//!   cluster** ([`cluster::proc`]): `specdfa worker` processes speaking
//!   a length-framed protocol over Unix/TCP sockets, Eq. (1)
//!   capacity-weighted chunking, retry/backoff/heartbeat failure
//!   handling with checkpointed failover, deterministic fault injection
//!   ([`cluster::fault`]), and degradation to in-process matching under
//!   total loss — every rung returning the sequential verdict.
//! * [`runtime`] — the vector unit (the AVX2-gather analog): an emulated
//!   lane kernel by default, the AOT-compiled Pallas artifact on PJRT
//!   under the `xla-pjrt` feature.
//! * [`workload`] — PCRE-like and PROSITE-like benchmark suites and input
//!   generators.
//! * [`experiments`] — regenerators for every table and figure in §6.
//!
//! `docs/ARCHITECTURE.md` (repo root) maps every paper section, figure
//! and equation to the module and bench that implement it.

#![warn(missing_docs)]

pub mod analysis;
pub mod automata;
pub mod baseline;
pub mod cluster;
pub mod engine;
pub mod experiments;
pub mod regex;
pub mod workload;
pub mod runtime;
pub mod speculative;
pub mod util;

pub use automata::{Dfa, FlatDfa};
pub use baseline::sequential::SequentialMatcher;
pub use engine::{
    Admission, Checkpoint, CompiledMatcher, CompiledSetMatcher, Engine,
    EngineKind, ExecPolicy, FeedProgress, HazardPolicy, Matcher, Outcome,
    Pattern, PatternSet, PriorityPolicy, Selection, ServeConfig, ServeError,
    ServeStats, Server, ServerHandle, SetConfig, SetOutcome, SetTier,
    ShardPlan, StreamMatcher, StreamStats, Ticket, WaitStats,
};
pub use regex::compile::{compile_exact, compile_prosite, compile_search};
pub use speculative::matcher::{MatchOutcome, MatchPlan};
pub use speculative::merge::MergeStrategy;
