//! # specdfa — Speculative Parallel DFA Membership Test
//!
//! Production-quality reproduction of *"A Speculative Parallel DFA
//! Membership Test for Multicore, SIMD and Cloud Computing Environments"*
//! (Ko, Jung, Han, Burgstaller; Int. J. Parallel Programming, 2012).
//!
//! The library is organized as the paper's system plus every substrate it
//! depends on (see DESIGN.md):
//!
//! * [`regex`] / [`automata`] — pattern frontends and the Grail+-substitute
//!   toolchain (Thompson NFA, subset construction, Hopcroft minimization,
//!   flattened SBase/IBase tables).
//! * [`baseline`] — sequential matcher (Listing 1), Holub–Štekr comparator,
//!   backtracking (ScanProsite analog) and grep-like engines.
//! * [`speculative`] — the paper's contribution: failure-free speculative
//!   parallel matching with I_max,r reverse-lookahead optimization,
//!   weighted partitioning and L-vector merging.
//! * [`cluster`] — simulated cloud computing environment (EC2 analog).
//! * [`runtime`] — PJRT vector unit: loads the AOT-compiled Pallas lane
//!   matcher (the AVX2-gather analog) and drives it from the match path.
//! * [`workload`] — PCRE-like and PROSITE-like benchmark suites and input
//!   generators.
//! * [`experiments`] — regenerators for every table and figure in §6.

pub mod automata;
pub mod baseline;
pub mod cluster;
pub mod experiments;
pub mod regex;
pub mod workload;
pub mod runtime;
pub mod speculative;
pub mod util;

pub use automata::{Dfa, FlatDfa};
pub use baseline::sequential::SequentialMatcher;
pub use regex::compile::{compile_exact, compile_prosite, compile_search};
pub use speculative::matcher::{MatchOutcome, MatchPlan};
pub use speculative::merge::MergeStrategy;
