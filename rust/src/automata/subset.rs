//! Subset construction: Thompson NFA -> complete dense-alphabet DFA.
//!
//! The 256-byte alphabet is first compressed into equivalence classes
//! against every ByteSet used by the NFA (dfa.rs::byte_classes) — the IBase
//! symbol mapping of Fig. 8(d) — then the classic worklist construction
//! runs over the dense class alphabet.  The resulting DFA is complete: a
//! sink is materialized for dead transitions (the paper's unique q_e).

use std::collections::HashMap;

use super::dfa::{byte_classes, Dfa};
use super::nfa::Nfa;

/// Determinize an NFA.  Returns a complete DFA (with sink if needed).
pub fn determinize(nfa: &Nfa) -> Dfa {
    // 1. byte classes from the NFA's edge sets
    let sets = nfa.edge_sets();
    let (classes, num_classes) = byte_classes(&sets);
    // representative byte per class
    let mut reps: Vec<u8> = vec![0; num_classes as usize];
    for b in (0..=255u8).rev() {
        reps[classes[b as usize] as usize] = b;
    }

    // 2. worklist subset construction over class alphabet
    let mut state_ids: HashMap<Vec<u32>, u32> = HashMap::new();
    let mut subsets: Vec<Vec<u32>> = Vec::new();
    let mut table: Vec<u32> = Vec::new();

    let start_set = nfa.eps_closure(&[nfa.start]);
    state_ids.insert(start_set.clone(), 0);
    subsets.push(start_set);
    let mut worklist = vec![0u32];
    // reserve row for state 0
    table.resize(num_classes as usize, u32::MAX);

    while let Some(q) = worklist.pop() {
        let subset = subsets[q as usize].clone();
        for c in 0..num_classes {
            let rep = reps[c as usize];
            let mut targets: Vec<u32> = Vec::new();
            for &s in &subset {
                for &(set, t) in &nfa.trans[s as usize] {
                    if set.contains(rep) && !targets.contains(&t) {
                        targets.push(t);
                    }
                }
            }
            let closure = nfa.eps_closure(&targets);
            let id = match state_ids.get(&closure) {
                Some(&id) => id,
                None => {
                    let id = subsets.len() as u32;
                    state_ids.insert(closure.clone(), id);
                    subsets.push(closure);
                    table.extend(std::iter::repeat(u32::MAX)
                        .take(num_classes as usize));
                    worklist.push(id);
                    id
                }
            };
            table[(q * num_classes + c) as usize] = id;
        }
    }

    let num_states = subsets.len() as u32;
    let accepting: Vec<bool> = subsets
        .iter()
        .map(|sub| sub.contains(&nfa.accept))
        .collect();
    debug_assert!(table.iter().all(|&t| t != u32::MAX));
    Dfa::new(num_states, num_classes, 0, accepting, table, classes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::automata::byteset::ByteSet;
    use crate::regex::ast::Ast;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn lit(s: &str) -> Ast {
        Ast::Concat(s.bytes().map(|b| Ast::Class(ByteSet::single(b))).collect())
    }

    #[test]
    fn determinize_literal() {
        let nfa = Nfa::from_ast(&lit("ab"));
        let dfa = determinize(&nfa);
        assert!(dfa.accepts_bytes(b"ab"));
        assert!(!dfa.accepts_bytes(b"a"));
        assert!(!dfa.accepts_bytes(b"abc"));
        // complete: every entry valid
        assert_eq!(dfa.table.len(),
                   (dfa.num_states * dfa.num_symbols) as usize);
    }

    #[test]
    fn determinize_has_sink_for_dead_input() {
        let nfa = Nfa::from_ast(&lit("ab"));
        let dfa = determinize(&nfa).trim_unreachable();
        assert!(dfa.sink().is_some());
    }

    /// Random ASTs: DFA must agree with direct NFA simulation.
    fn random_ast(rng: &mut Rng, depth: usize) -> Ast {
        if depth == 0 || rng.chance(0.3) {
            let b = b'a' + rng.below(3) as u8; // small alphabet {a,b,c}
            return Ast::Class(ByteSet::single(b));
        }
        match rng.below(4) {
            0 => Ast::Concat((0..rng.range_usize(1, 3))
                .map(|_| random_ast(rng, depth - 1)).collect()),
            1 => Ast::Alt((0..rng.range_usize(1, 3))
                .map(|_| random_ast(rng, depth - 1)).collect()),
            2 => Ast::Repeat {
                node: Box::new(random_ast(rng, depth - 1)),
                min: 0,
                max: None,
            },
            _ => {
                let min = rng.below(3) as u32;
                let max = min + rng.below(3) as u32;
                Ast::Repeat {
                    node: Box::new(random_ast(rng, depth - 1)),
                    min,
                    max: Some(max),
                }
            }
        }
    }

    #[test]
    fn prop_dfa_equals_nfa_on_random_strings() {
        prop::check("determinize preserves language", 60, |rng| {
            let ast = random_ast(rng, 3);
            let nfa = Nfa::from_ast(&ast);
            let dfa = determinize(&nfa);
            for _ in 0..20 {
                let len = rng.below(12) as usize;
                let s: Vec<u8> =
                    (0..len).map(|_| b'a' + rng.below(3) as u8).collect();
                assert_eq!(
                    nfa.accepts(&s),
                    dfa.accepts_bytes(&s),
                    "ast={ast:?} input={s:?}"
                );
            }
        });
    }
}
