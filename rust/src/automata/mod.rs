//! Automata substrate: everything the paper delegates to Grail+ [37,15],
//! built from scratch — Thompson NFA construction, subset construction,
//! Hopcroft minimization, the flattened SBase/IBase DFA representation of
//! Fig. 8, and Grail+-style text I/O.

pub mod acorasick;
pub mod byteset;
pub mod dfa;
pub mod grail;
pub mod minimize;
pub mod nfa;
pub mod product;
pub mod subset;

pub use acorasick::AhoCorasick;
pub use byteset::ByteSet;
pub use dfa::{Dfa, FlatDfa, SBase, ValidSyms, Width};
pub use nfa::Nfa;
pub use product::{fuse, ProductDfa};
