//! Product ("fused") DFA construction for multi-pattern matching.
//!
//! A set of k DFAs is fused into one automaton whose states are k-tuples
//! of component states, reachable from the tuple of start states — the
//! Simultaneous Finite Automata idea (Sin'ya et al., arXiv 1405.0562):
//! one pass over the input advances *all* patterns at once, and the
//! final product state projects back to every component's final state.
//! Construction is a BFS over reachable tuples; following Jung &
//! Burgstaller (arXiv 1512.09228) the successor computation of each BFS
//! frontier is embarrassingly parallel (their Rabin fingerprints play
//! the role our tuple hash map plays here), while interning stays
//! sequential so state ids are deterministic.
//!
//! Reachable product size is usually far below the |Q₁|·…·|Qₖ| worst
//! case but *can* blow up, so [`fuse`] takes a `state_budget` and
//! returns `None` instead of thrashing — the caller spills patterns back
//! to per-pattern matching, keeping the engine failure-free (the same
//! "never wrong, only slower" discipline as the speculative kernel).

use std::collections::HashMap;

use super::dfa::Dfa;
use crate::util::bitset::BitSet;

/// A fused product DFA plus the bookkeeping to project verdicts back to
/// the component automata.
#[derive(Clone, Debug)]
pub struct ProductDfa {
    /// the fused automaton (accepting = "some component accepts")
    pub dfa: Dfa,
    /// per product state: which components accept there (bit i ↔ dfas[i])
    pub accept_masks: Vec<BitSet>,
    /// per product state: the component-state tuple (`proj[p][i]` is
    /// component i's state when the product is in state p)
    pub proj: Vec<Vec<u32>>,
}

impl ProductDfa {
    /// Number of fused components.
    pub fn components(&self) -> usize {
        self.proj.first().map_or(0, |t| t.len())
    }
}

/// Fuse `dfas` into a reachable product DFA.
///
/// `state_budget` caps the number of product states (0 = unlimited);
/// when the reachable product exceeds it the construction aborts and
/// returns `None` so the caller can spill patterns instead of failing.
/// `threads` bounds the worker threads used for frontier expansion;
/// results are identical for any thread count (state ids are assigned
/// by a sequential interning pass in frontier order).
pub fn fuse(dfas: &[&Dfa], state_budget: usize, threads: usize) -> Option<ProductDfa> {
    assert!(!dfas.is_empty(), "fuse of an empty DFA set");
    let k = dfas.len();
    let budget = if state_budget == 0 { usize::MAX } else { state_budget };

    // 1. Combined byte classes: two bytes share a class iff every
    //    component classes them identically.  At most 256 classes, so
    //    the signature interning always fits the u8 class table.
    let mut sig_ids: HashMap<Vec<u8>, u8> = HashMap::new();
    let mut classes = [0u8; 256];
    let mut reps: Vec<u8> = Vec::new();
    for b in 0..=255u8 {
        let sig: Vec<u8> = dfas.iter().map(|d| d.classes[b as usize]).collect();
        let id = *sig_ids.entry(sig).or_insert_with(|| {
            reps.push(b);
            (reps.len() - 1) as u8
        });
        classes[b as usize] = id;
    }
    let sigma = reps.len() as u32;
    // per-component view of each combined class (via its representative)
    let comp_sym: Vec<Vec<u32>> = dfas
        .iter()
        .map(|d| reps.iter().map(|&r| d.class_of(r)).collect())
        .collect();

    // 2. BFS over reachable tuples.  Frontier successor tuples are
    //    computed in parallel; interning is sequential in (frontier,
    //    symbol) order so discovery order — hence state ids and the
    //    row-major table layout — is deterministic.
    let start: Vec<u32> = dfas.iter().map(|d| d.start).collect();
    let mut ids: HashMap<Vec<u32>, u32> = HashMap::new();
    let mut tuples: Vec<Vec<u32>> = vec![start.clone()];
    ids.insert(start, 0);
    let mut table: Vec<u32> = Vec::new();
    let mut explored = 0usize;
    let succ_of = |tuple: &[u32]| -> Vec<Vec<u32>> {
        (0..sigma as usize)
            .map(|c| {
                tuple
                    .iter()
                    .enumerate()
                    .map(|(i, &q)| dfas[i].step(q, comp_sym[i][c]))
                    .collect()
            })
            .collect()
    };
    while explored < tuples.len() {
        let frontier: Vec<Vec<u32>> = tuples[explored..].to_vec();
        explored = tuples.len();
        let workers = threads.max(1).min(frontier.len());
        let succs: Vec<Vec<Vec<u32>>> = if workers <= 1 || frontier.len() < 64 {
            frontier.iter().map(|t| succ_of(t)).collect()
        } else {
            let chunk = frontier.len().div_ceil(workers);
            let succ_of = &succ_of;
            std::thread::scope(|scope| {
                let handles: Vec<_> = frontier
                    .chunks(chunk)
                    .map(|ch| {
                        scope.spawn(move || {
                            ch.iter().map(|t| succ_of(t)).collect::<Vec<_>>()
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .flat_map(|h| h.join().expect("fuse worker panicked"))
                    .collect()
            })
        };
        for row in succs {
            for tuple in row {
                let next_id = match ids.get(&tuple) {
                    Some(&id) => id,
                    None => {
                        if tuples.len() >= budget {
                            return None; // over budget: caller spills
                        }
                        let id = tuples.len() as u32;
                        ids.insert(tuple.clone(), id);
                        tuples.push(tuple);
                        id
                    }
                };
                table.push(next_id);
            }
        }
    }

    // 3. Accepting structure: the fused DFA accepts where any component
    //    does; the per-state mask records exactly which ones.
    let mut accepting = Vec::with_capacity(tuples.len());
    let mut accept_masks = Vec::with_capacity(tuples.len());
    for t in &tuples {
        let mask = BitSet::from_iter_cap(
            k,
            t.iter()
                .enumerate()
                .filter(|&(i, &q)| dfas[i].accepting[q as usize])
                .map(|(i, _)| i),
        );
        accepting.push(!mask.is_empty());
        accept_masks.push(mask);
    }
    let dfa =
        Dfa::new(tuples.len() as u32, sigma, 0, accepting, table, classes);
    Some(ProductDfa { dfa, accept_masks, proj: tuples })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Accepts strings containing the byte `b` (2 states).
    fn contains_byte(b: u8) -> Dfa {
        let mut classes = [0u8; 256];
        classes[b as usize] = 1;
        Dfa::new(2, 2, 0, vec![false, true], vec![0, 1, 1, 1], classes)
    }

    /// Accepts strings of even length (2 states, 1 symbol).
    fn even_length() -> Dfa {
        Dfa::new(2, 1, 0, vec![true, false], vec![1, 0], [0u8; 256])
    }

    #[test]
    fn fused_pair_tracks_both_components() {
        let d1 = contains_byte(b'a');
        let d2 = even_length();
        let p = fuse(&[&d1, &d2], 0, 1).unwrap();
        assert_eq!(p.components(), 2);
        for input in [&b""[..], b"a", b"xx", b"xa", b"aaa", b"bbbb"] {
            let fs = p.dfa.run_bytes(p.dfa.start, input);
            let mask = &p.accept_masks[fs as usize];
            assert_eq!(mask.contains(0), d1.accepts_bytes(input));
            assert_eq!(mask.contains(1), d2.accepts_bytes(input));
            // projection agrees with the standalone runs
            assert_eq!(p.proj[fs as usize][0], d1.run_bytes(d1.start, input));
            assert_eq!(p.proj[fs as usize][1], d2.run_bytes(d2.start, input));
        }
    }

    #[test]
    fn budget_overflow_returns_none() {
        let d1 = contains_byte(b'a');
        let d2 = even_length();
        // reachable product has 4 states; a budget of 2 must abort
        assert!(fuse(&[&d1, &d2], 2, 1).is_none());
        assert!(fuse(&[&d1, &d2], 4, 1).is_some());
    }

    #[test]
    fn parallel_construction_is_deterministic() {
        let ds: Vec<Dfa> =
            [b'a', b'b', b'c', b'd'].iter().map(|&b| contains_byte(b)).collect();
        let refs: Vec<&Dfa> = ds.iter().collect();
        let serial = fuse(&refs, 0, 1).unwrap();
        let parallel = fuse(&refs, 0, 4).unwrap();
        assert_eq!(serial.dfa, parallel.dfa);
        assert_eq!(serial.proj, parallel.proj);
        assert_eq!(serial.accept_masks, parallel.accept_masks);
    }

    #[test]
    fn fused_matches_lockstep_on_random_dfas() {
        crate::util::prop::check("product == lockstep", 30, |rng| {
            // random complete 2-symbol DFAs over bytes a/b
            let k = rng.range_usize(1, 3);
            let ds: Vec<Dfa> = (0..k)
                .map(|_| {
                    let n = rng.range_u64(1, 4) as u32;
                    let table: Vec<u32> =
                        (0..n * 2).map(|_| rng.below(n as u64) as u32).collect();
                    let accepting: Vec<bool> =
                        (0..n).map(|_| rng.chance(0.4)).collect();
                    let mut classes = [0u8; 256];
                    classes[b'b' as usize] = 1;
                    Dfa::new(n, 2, rng.below(n as u64) as u32, accepting,
                             table, classes)
                })
                .collect();
            let refs: Vec<&Dfa> = ds.iter().collect();
            let p = fuse(&refs, 0, 2).unwrap();
            let input: Vec<u8> = (0..rng.range_usize(0, 40))
                .map(|_| if rng.chance(0.5) { b'a' } else { b'b' })
                .collect();
            let fs = p.dfa.run_bytes(p.dfa.start, &input);
            for (i, d) in ds.iter().enumerate() {
                let qi = d.run_bytes(d.start, &input);
                assert_eq!(p.proj[fs as usize][i], qi);
                assert_eq!(
                    p.accept_masks[fs as usize].contains(i),
                    d.accepting[qi as usize]
                );
            }
        });
    }
}
