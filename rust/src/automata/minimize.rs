//! DFA minimization: Hopcroft's O(n·s·log n) partition refinement, plus a
//! naive Moore refinement used as a cross-checking oracle in tests.
//!
//! The paper runs Grail+ to produce "unique minimum DFAs" for all 299 PCRE
//! and 110 PROSITE patterns; this module is that step.  Input DFAs must be
//! complete (ours always are — subset construction materializes the sink).

use super::dfa::Dfa;

/// Hopcroft's algorithm. Returns an equivalent minimal complete DFA
/// (unreachable states are trimmed first).
pub fn minimize(dfa: &Dfa) -> Dfa {
    let dfa = dfa.trim_unreachable();
    let n = dfa.num_states as usize;
    let s = dfa.num_symbols as usize;
    if n <= 1 {
        return dfa;
    }

    // reverse transitions: rev[c][t] = list of sources q with delta(q,c)=t
    let mut rev: Vec<Vec<Vec<u32>>> = vec![vec![Vec::new(); n]; s];
    for q in 0..n {
        for c in 0..s {
            let t = dfa.table[q * s + c] as usize;
            rev[c][t].push(q as u32);
        }
    }

    // partition as: block id per state + member lists
    let mut block_of: Vec<u32> = dfa
        .accepting
        .iter()
        .map(|&a| if a { 1u32 } else { 0u32 })
        .collect();
    let mut blocks: Vec<Vec<u32>> = vec![Vec::new(), Vec::new()];
    for q in 0..n {
        blocks[block_of[q] as usize].push(q as u32);
    }
    // drop empty initial block (all-accepting or none-accepting DFAs)
    if blocks[0].is_empty() || blocks[1].is_empty() {
        let keep = if blocks[0].is_empty() { 1 } else { 0 };
        let b = std::mem::take(&mut blocks[keep]);
        blocks = vec![b];
        for q in 0..n {
            block_of[q] = 0;
        }
    }

    // worklist of (block, symbol)
    let mut work: Vec<(u32, u32)> = Vec::new();
    let smaller = if blocks.len() == 2 {
        if blocks[0].len() <= blocks[1].len() { 0 } else { 1 }
    } else {
        0
    };
    for c in 0..s as u32 {
        work.push((smaller as u32, c));
        if blocks.len() == 2 {
            // classic optimization: only the smaller block is needed, but
            // pushing both is also correct; push both for simplicity of the
            // invariant, cost is negligible at our sizes.
            work.push((1 - smaller as u32, c));
        }
    }

    let mut in_splitter: Vec<bool> = vec![false; n];
    while let Some((a, c)) = work.pop() {
        // X = preimage of block a under symbol c
        let mut x: Vec<u32> = Vec::new();
        for &t in &blocks[a as usize] {
            for &q in &rev[c as usize][t as usize] {
                x.push(q);
            }
        }
        if x.is_empty() {
            continue;
        }
        for &q in &x {
            in_splitter[q as usize] = true;
        }
        // find blocks intersecting X
        let mut touched: Vec<u32> = Vec::new();
        for &q in &x {
            let b = block_of[q as usize];
            if !touched.contains(&b) {
                touched.push(b);
            }
        }
        for b in touched {
            let members = &blocks[b as usize];
            let hit = members
                .iter()
                .filter(|&&q| in_splitter[q as usize])
                .count();
            if hit == 0 || hit == members.len() {
                continue; // no split
            }
            // split block b into (in X) and (not in X)
            let (inside, outside): (Vec<u32>, Vec<u32>) = members
                .iter()
                .partition(|&&q| in_splitter[q as usize]);
            let new_id = blocks.len() as u32;
            // smaller part becomes the new block (Hopcroft's trick)
            let (keep, new) = if inside.len() <= outside.len() {
                (outside, inside)
            } else {
                (inside, outside)
            };
            for &q in &new {
                block_of[q as usize] = new_id;
            }
            blocks[b as usize] = keep;
            blocks.push(new);
            for c2 in 0..s as u32 {
                work.push((new_id, c2));
            }
        }
        for &q in &x {
            in_splitter[q as usize] = false;
        }
    }

    // build quotient DFA
    let m = blocks.len() as u32;
    let mut table = vec![0u32; (m as usize) * s];
    let mut accepting = vec![false; m as usize];
    for (bid, members) in blocks.iter().enumerate() {
        let q = members[0] as usize;
        accepting[bid] = dfa.accepting[q];
        for c in 0..s {
            table[bid * s + c] = block_of[dfa.table[q * s + c] as usize];
        }
        // sanity in debug: all members agree
        debug_assert!(members.iter().all(|&qq| {
            dfa.accepting[qq as usize] == accepting[bid]
        }));
    }
    let start = block_of[dfa.start as usize];
    Dfa::new(m, s as u32, start, accepting, table, dfa.classes)
        .trim_unreachable()
}

/// Naive Moore partition refinement — O(n^2 s) oracle for tests.
pub fn minimize_moore(dfa: &Dfa) -> Dfa {
    let dfa = dfa.trim_unreachable();
    let n = dfa.num_states as usize;
    let s = dfa.num_symbols as usize;
    let mut class: Vec<u32> = dfa
        .accepting
        .iter()
        .map(|&a| if a { 1 } else { 0 })
        .collect();
    loop {
        // signature = (class, classes of successors)
        let mut sig_map: std::collections::HashMap<Vec<u32>, u32> =
            std::collections::HashMap::new();
        let mut next_class = vec![0u32; n];
        for q in 0..n {
            let mut sig = Vec::with_capacity(s + 1);
            sig.push(class[q]);
            for c in 0..s {
                sig.push(class[dfa.table[q * s + c] as usize]);
            }
            let id = sig_map.len() as u32;
            let e = *sig_map.entry(sig).or_insert(id);
            next_class[q] = e;
        }
        if next_class == class {
            break;
        }
        class = next_class;
    }
    let m = class.iter().max().map(|&c| c + 1).unwrap_or(0);
    let mut table = vec![0u32; (m as usize) * s];
    let mut accepting = vec![false; m as usize];
    for q in 0..n {
        let b = class[q] as usize;
        accepting[b] = dfa.accepting[q];
        for c in 0..s {
            table[b * s + c] = class[dfa.table[q * s + c] as usize];
        }
    }
    Dfa::new(m, s as u32, class[dfa.start as usize], accepting, table,
             dfa.classes)
        .trim_unreachable()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::automata::byteset::ByteSet;
    use crate::automata::nfa::Nfa;
    use crate::automata::subset::determinize;
    use crate::regex::ast::Ast;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn lit(s: &str) -> Ast {
        Ast::Concat(s.bytes().map(|b| Ast::Class(ByteSet::single(b))).collect())
    }

    #[test]
    fn minimize_merges_equivalent_states() {
        // (a|b)(a|b) via two alternatives produces redundant states
        let ab = Ast::Alt(vec![lit("a"), lit("b")]);
        let ast = Ast::Concat(vec![ab.clone(), ab]);
        let dfa = determinize(&Nfa::from_ast(&ast));
        let min = minimize(&dfa);
        assert!(min.num_states <= dfa.num_states);
        // minimal: 4 states: start, after-1, accept, sink
        assert_eq!(min.num_states, 4);
        for input in [&b"aa"[..], b"ab", b"ba", b"bb", b"a", b"abc", b""] {
            assert_eq!(min.accepts_bytes(input), dfa.accepts_bytes(input));
        }
    }

    #[test]
    fn hopcroft_equals_moore_state_count() {
        let asts = [
            Ast::Repeat { node: Box::new(lit("ab")), min: 0, max: None },
            Ast::Alt(vec![lit("cat"), lit("car"), lit("cab")]),
            Ast::Concat(vec![
                Ast::Repeat { node: Box::new(lit("a")), min: 2, max: Some(5) },
                lit("b"),
            ]),
        ];
        for ast in &asts {
            let dfa = determinize(&Nfa::from_ast(ast));
            let h = minimize(&dfa);
            let m = minimize_moore(&dfa);
            assert_eq!(h.num_states, m.num_states, "ast={ast:?}");
        }
    }

    fn random_ast(rng: &mut Rng, depth: usize) -> Ast {
        if depth == 0 || rng.chance(0.3) {
            return Ast::Class(ByteSet::single(b'a' + rng.below(3) as u8));
        }
        match rng.below(3) {
            0 => Ast::Concat((0..rng.range_usize(1, 3))
                .map(|_| random_ast(rng, depth - 1)).collect()),
            1 => Ast::Alt((0..rng.range_usize(1, 3))
                .map(|_| random_ast(rng, depth - 1)).collect()),
            _ => Ast::Repeat {
                node: Box::new(random_ast(rng, depth - 1)),
                min: rng.below(2) as u32,
                max: None,
            },
        }
    }

    #[test]
    fn prop_minimize_preserves_language_and_is_minimal() {
        prop::check("hopcroft == moore == original language", 40, |rng| {
            let ast = random_ast(rng, 3);
            let dfa = determinize(&Nfa::from_ast(&ast));
            let h = minimize(&dfa);
            let m = minimize_moore(&dfa);
            assert_eq!(h.num_states, m.num_states);
            for _ in 0..25 {
                let len = rng.below(10) as usize;
                let s: Vec<u8> =
                    (0..len).map(|_| b'a' + rng.below(3) as u8).collect();
                let want = dfa.accepts_bytes(&s);
                assert_eq!(h.accepts_bytes(&s), want);
                assert_eq!(m.accepts_bytes(&s), want);
            }
        });
    }

    #[test]
    fn prop_minimize_idempotent() {
        prop::check("minimize(minimize(d)) == minimize(d) size", 20, |rng| {
            let ast = random_ast(rng, 3);
            let dfa = determinize(&Nfa::from_ast(&ast));
            let once = minimize(&dfa);
            let twice = minimize(&once);
            assert_eq!(once.num_states, twice.num_states);
        });
    }
}
