//! 256-bit byte sets: the label alphabet of regex ASTs and NFA transitions.
//!
//! DFAs run over *dense symbol classes* (the IBase mapping of Fig. 8d), and
//! classes are computed by partitioning 0..=255 against every ByteSet used
//! in a pattern — so ByteSet is the bridge between "PCRE regexes over
//! bytes" and "DFA over a small dense alphabet".

/// A set of byte values 0..=255 as a 256-bit mask.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct ByteSet(
    /// membership mask: 4 × 64 little-endian words
    pub [u64; 4],
);

impl ByteSet {
    /// The empty set.
    pub const EMPTY: ByteSet = ByteSet([0; 4]);
    /// All 256 byte values.
    pub const ALL: ByteSet = ByteSet([u64::MAX; 4]);

    /// The singleton set {b}.
    pub fn single(b: u8) -> Self {
        let mut s = Self::EMPTY;
        s.insert(b);
        s
    }

    /// The inclusive byte range lo..=hi.
    pub fn range(lo: u8, hi: u8) -> Self {
        let mut s = Self::EMPTY;
        let mut b = lo;
        loop {
            s.insert(b);
            if b == hi {
                break;
            }
            b += 1;
        }
        s
    }

    /// The set of the given bytes.
    pub fn from_bytes(bytes: &[u8]) -> Self {
        let mut s = Self::EMPTY;
        for &b in bytes {
            s.insert(b);
        }
        s
    }

    /// Add `b` to the set.
    #[inline]
    pub fn insert(&mut self, b: u8) {
        self.0[(b >> 6) as usize] |= 1u64 << (b & 63);
    }

    /// Whether `b` is a member.
    #[inline]
    pub fn contains(&self, b: u8) -> bool {
        self.0[(b >> 6) as usize] & (1u64 << (b & 63)) != 0
    }

    /// Set union.
    pub fn union(&self, o: &ByteSet) -> ByteSet {
        ByteSet([
            self.0[0] | o.0[0],
            self.0[1] | o.0[1],
            self.0[2] | o.0[2],
            self.0[3] | o.0[3],
        ])
    }

    /// Set intersection.
    pub fn intersect(&self, o: &ByteSet) -> ByteSet {
        ByteSet([
            self.0[0] & o.0[0],
            self.0[1] & o.0[1],
            self.0[2] & o.0[2],
            self.0[3] & o.0[3],
        ])
    }

    /// Set complement.
    pub fn negate(&self) -> ByteSet {
        ByteSet([!self.0[0], !self.0[1], !self.0[2], !self.0[3]])
    }

    /// Whether no byte is a member.
    pub fn is_empty(&self) -> bool {
        self.0 == [0; 4]
    }

    /// Number of member bytes.
    pub fn len(&self) -> usize {
        self.0.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Iterate members in increasing order.
    pub fn iter(&self) -> impl Iterator<Item = u8> + '_ {
        (0u16..256).filter(|&b| self.contains(b as u8)).map(|b| b as u8)
    }

    /// Smallest member, if any.
    pub fn first(&self) -> Option<u8> {
        (0u16..256).map(|b| b as u8).find(|&b| self.contains(b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_and_range() {
        let a = ByteSet::single(b'a');
        assert!(a.contains(b'a') && !a.contains(b'b'));
        let d = ByteSet::range(b'0', b'9');
        assert_eq!(d.len(), 10);
        assert!(d.contains(b'5') && !d.contains(b'a'));
    }

    #[test]
    fn full_range_boundaries() {
        let all = ByteSet::range(0, 255);
        assert_eq!(all.len(), 256);
        assert_eq!(all, ByteSet::ALL);
    }

    #[test]
    fn negate_partition() {
        let v = ByteSet::from_bytes(b"aeiou");
        let c = v.negate();
        assert_eq!(v.len() + c.len(), 256);
        for b in 0..=255u8 {
            assert_ne!(v.contains(b), c.contains(b));
        }
    }

    #[test]
    fn union_collects() {
        let u = ByteSet::single(b'x').union(&ByteSet::single(b'y'));
        assert_eq!(u.iter().collect::<Vec<_>>(), vec![b'x', b'y']);
    }

    #[test]
    fn intersect_keeps_common() {
        let a = ByteSet::from_bytes(b"abc");
        let b = ByteSet::from_bytes(b"bcd");
        assert_eq!(a.intersect(&b).iter().collect::<Vec<_>>(), vec![b'b', b'c']);
        assert!(a.intersect(&ByteSet::EMPTY).is_empty());
    }
}
