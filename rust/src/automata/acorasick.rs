//! Aho–Corasick multi-literal scanner: the prefilter tier of the
//! pattern-set engine ([`crate::engine::patternset`]).
//!
//! Each pattern with a *required literal* (a byte string every match must
//! contain, [`crate::baseline::greplike::required_literal`]) registers
//! that literal here; one linear pass over the input then decides, for
//! every registered literal at once, whether it occurs.  A pattern whose
//! required literal is absent cannot match — it is *cleared* without any
//! DFA work.  This is the classic grep/Hyperscan architecture: a cheap
//! necessary-condition tier in front of the exact automaton.
//!
//! The automaton is the textbook construction — trie + BFS failure links
//! — collapsed into a dense `state × 256` goto table so the scan is one
//! table load per input byte, the same memory shape as the flattened
//! SBase DFA tables ([`super::dfa::FlatDfa`]).

/// Sentinel for "no trie child".
const NONE: u32 = u32::MAX;

/// A dense-table Aho–Corasick automaton over raw bytes.
///
/// Built once per compiled pattern set from `(literal, id)` pairs; the
/// ids are small dense indices chosen by the caller (the pattern-set
/// compiler uses positions into its unique-pattern table).  Duplicate
/// literals are fine: each occurrence reports every id registered for
/// it.
pub struct AhoCorasick {
    /// dense goto table: `next[state * 256 + byte]`
    next: Vec<u32>,
    /// ids whose literal ends at this state (failure-closure included)
    out: Vec<Vec<u32>>,
    /// number of distinct ids registered
    num_ids: usize,
}

impl AhoCorasick {
    /// Build the automaton from `(literal, id)` pairs.  Empty literals
    /// are rejected (they would "occur" everywhere and clear nothing);
    /// `num_ids` sizes the presence vector returned by
    /// [`AhoCorasick::presence`] and must exceed every registered id.
    pub fn new(literals: &[(&[u8], u32)], num_ids: usize) -> AhoCorasick {
        assert!(
            literals.iter().all(|(lit, _)| !lit.is_empty()),
            "empty prefilter literal"
        );
        assert!(
            literals.iter().all(|&(_, id)| (id as usize) < num_ids),
            "prefilter id out of range"
        );
        // 1. trie
        let mut children: Vec<[u32; 256]> = vec![[NONE; 256]];
        let mut out: Vec<Vec<u32>> = vec![Vec::new()];
        for (lit, id) in literals {
            let mut s = 0usize;
            for &b in *lit {
                let t = children[s][b as usize];
                s = if t == NONE {
                    children.push([NONE; 256]);
                    out.push(Vec::new());
                    let fresh = (children.len() - 1) as u32;
                    children[s][b as usize] = fresh;
                    fresh as usize
                } else {
                    t as usize
                };
            }
            out[s].push(*id);
        }
        // 2. BFS failure links, collapsed into a dense goto function:
        //    next[s][b] = child if present, else next[fail(s)][b].
        let states = children.len();
        let mut next = vec![0u32; states * 256];
        let mut fail = vec![0u32; states];
        let mut queue = std::collections::VecDeque::new();
        for b in 0..256 {
            let c = children[0][b];
            if c != NONE {
                fail[c as usize] = 0;
                queue.push_back(c);
                next[b] = c;
            } // else next[b] stays 0 (root self-loop)
        }
        while let Some(s) = queue.pop_front() {
            let f = fail[s as usize];
            // outputs of the failure state are also outputs here (a
            // suffix of the current match ends another literal)
            let inherited = out[f as usize].clone();
            out[s as usize].extend(inherited);
            for b in 0..256 {
                let c = children[s as usize][b];
                if c != NONE {
                    fail[c as usize] = next[f as usize * 256 + b];
                    queue.push_back(c);
                    next[s as usize * 256 + b] = c;
                } else {
                    next[s as usize * 256 + b] = next[f as usize * 256 + b];
                }
            }
        }
        AhoCorasick { next, out, num_ids }
    }

    /// Number of automaton states (trie nodes).
    pub fn num_states(&self) -> usize {
        self.out.len()
    }

    /// Bytes of the dense goto table (the prefilter's working set).
    pub fn table_bytes(&self) -> usize {
        self.next.len() * std::mem::size_of::<u32>()
    }

    /// One pass over `haystack`: `presence[id]` is true iff some literal
    /// registered under `id` occurs in the input.  Exits early once every
    /// registered id has been seen.
    pub fn presence(&self, haystack: &[u8]) -> Vec<bool> {
        let mut present = vec![false; self.num_ids];
        let mut remaining = self.num_ids;
        let mut s = 0usize;
        for &b in haystack {
            s = self.next[s * 256 + b as usize] as usize;
            if !self.out[s].is_empty() {
                for &id in &self.out[s] {
                    if !present[id as usize] {
                        present[id as usize] = true;
                        remaining -= 1;
                    }
                }
                if remaining == 0 {
                    break;
                }
            }
        }
        present
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finds_each_literal_independently() {
        let ac = AhoCorasick::new(
            &[(b"he", 0), (b"she", 1), (b"his", 2), (b"hers", 3)],
            4,
        );
        assert_eq!(ac.presence(b"ushers"), vec![true, true, false, true]);
        assert_eq!(ac.presence(b"his"), vec![false, false, true, false]);
        assert_eq!(ac.presence(b""), vec![false; 4]);
        assert_eq!(ac.presence(b"xyz"), vec![false; 4]);
    }

    #[test]
    fn overlapping_and_duplicate_literals() {
        // two patterns share one literal; both ids must report
        let ac = AhoCorasick::new(&[(b"abc", 0), (b"abc", 1), (b"bc", 2)], 3);
        assert_eq!(ac.presence(b"zabcz"), vec![true, true, true]);
        assert_eq!(ac.presence(b"zbcz"), vec![false, false, true]);
    }

    #[test]
    fn presence_matches_naive_contains() {
        crate::util::prop::check("ac presence == contains", 40, |rng| {
            let nlits = rng.range_usize(1, 5);
            let lits: Vec<Vec<u8>> = (0..nlits)
                .map(|_| {
                    let len = rng.range_usize(1, 4);
                    (0..len).map(|_| b'a' + rng.below(3) as u8).collect()
                })
                .collect();
            let pairs: Vec<(&[u8], u32)> = lits
                .iter()
                .enumerate()
                .map(|(i, l)| (l.as_slice(), i as u32))
                .collect();
            let ac = AhoCorasick::new(&pairs, nlits);
            let hay: Vec<u8> = (0..rng.range_usize(0, 64))
                .map(|_| b'a' + rng.below(3) as u8)
                .collect();
            let got = ac.presence(&hay);
            for (i, lit) in lits.iter().enumerate() {
                let want = hay.windows(lit.len()).any(|w| w == &lit[..]);
                assert_eq!(got[i], want, "lit {lit:?} hay {hay:?}");
            }
        });
    }

    #[test]
    #[should_panic]
    fn rejects_empty_literal() {
        AhoCorasick::new(&[(b"", 0)], 1);
    }
}
