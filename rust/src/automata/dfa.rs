//! Dense-alphabet DFA + the paper's flattened SBase/IBase representation.
//!
//! A `Dfa` is complete (total transition function) over a small dense
//! symbol alphabet 0..num_symbols; raw input bytes are mapped to symbols by
//! the 256-entry `classes` table (the IBase mapping of Fig. 8d).  `FlatDfa`
//! is the performance representation of Fig. 8(c): states are encoded as
//! *row offsets* into a 1-dimensional transition array so the matching loop
//! is one add + one indexed load per symbol (Listing 1).

use std::collections::HashMap;

/// Complete deterministic finite automaton over a dense symbol alphabet.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Dfa {
    /// |Q|
    pub num_states: u32,
    /// |Σ| (dense symbol classes)
    pub num_symbols: u32,
    /// q0
    pub start: u32,
    /// accepting[q] — final state indicator (F)
    pub accepting: Vec<bool>,
    /// row-major table: table[q * num_symbols + s] = delta(q, s)
    pub table: Vec<u32>,
    /// byte -> dense symbol class (IBase map). classes[b] < num_symbols.
    pub classes: [u8; 256],
}

impl Dfa {
    /// Build directly from parts, checking the invariants.
    pub fn new(
        num_states: u32,
        num_symbols: u32,
        start: u32,
        accepting: Vec<bool>,
        table: Vec<u32>,
        classes: [u8; 256],
    ) -> Dfa {
        assert_eq!(accepting.len(), num_states as usize);
        assert_eq!(table.len(), (num_states * num_symbols) as usize);
        assert!(start < num_states);
        assert!(table.iter().all(|&t| t < num_states), "incomplete DFA");
        assert!(classes.iter().all(|&c| (c as u32) < num_symbols));
        Dfa { num_states, num_symbols, start, accepting, table, classes }
    }

    /// One transition: delta(q, sym).
    #[inline]
    pub fn step(&self, q: u32, sym: u32) -> u32 {
        self.table[(q * self.num_symbols + sym) as usize]
    }

    /// Dense symbol class of a raw input byte (the IBase map).
    #[inline]
    pub fn class_of(&self, byte: u8) -> u32 {
        self.classes[byte as usize] as u32
    }

    /// delta*(q, syms) over dense symbols.
    pub fn run(&self, mut q: u32, syms: &[u32]) -> u32 {
        for &s in syms {
            q = self.step(q, s);
        }
        q
    }

    /// delta*(q, bytes) over raw bytes (classes applied on the fly).
    pub fn run_bytes(&self, mut q: u32, bytes: &[u8]) -> u32 {
        for &b in bytes {
            q = self.step(q, self.class_of(b));
        }
        q
    }

    /// Membership test: delta*(q0, bytes) in F.
    pub fn accepts_bytes(&self, bytes: &[u8]) -> bool {
        self.accepting[self.run_bytes(self.start, bytes) as usize]
    }

    /// Membership over pre-mapped dense symbols.
    pub fn accepts(&self, syms: &[u32]) -> bool {
        self.accepting[self.run(self.start, syms) as usize]
    }

    /// Map a byte string to dense symbols (materialized IBase, Fig. 8d).
    pub fn map_input(&self, bytes: &[u8]) -> Vec<u32> {
        bytes.iter().map(|&b| self.class_of(b)).collect()
    }

    /// Identify the sink (error) state: non-accepting with all self-loops.
    /// The paper assumes a unique error state q_e (§2.1).
    pub fn sink(&self) -> Option<u32> {
        (0..self.num_states).find(|&q| {
            !self.accepting[q as usize]
                && (0..self.num_symbols).all(|s| self.step(q, s) == q)
        })
    }

    /// Number of accepting states.
    pub fn num_accepting(&self) -> usize {
        self.accepting.iter().filter(|&&a| a).count()
    }

    /// Remove states unreachable from the start (preserves language).
    pub fn trim_unreachable(&self) -> Dfa {
        let mut reach = vec![false; self.num_states as usize];
        let mut stack = vec![self.start];
        reach[self.start as usize] = true;
        while let Some(q) = stack.pop() {
            for s in 0..self.num_symbols {
                let t = self.step(q, s);
                if !reach[t as usize] {
                    reach[t as usize] = true;
                    stack.push(t);
                }
            }
        }
        let mut remap = vec![u32::MAX; self.num_states as usize];
        let mut n = 0u32;
        for q in 0..self.num_states {
            if reach[q as usize] {
                remap[q as usize] = n;
                n += 1;
            }
        }
        let mut table = Vec::with_capacity((n * self.num_symbols) as usize);
        let mut accepting = Vec::with_capacity(n as usize);
        for q in 0..self.num_states {
            if reach[q as usize] {
                accepting.push(self.accepting[q as usize]);
                for s in 0..self.num_symbols {
                    table.push(remap[self.step(q, s) as usize]);
                }
            }
        }
        Dfa::new(n, self.num_symbols, remap[self.start as usize], accepting,
                 table, self.classes)
    }

    /// Make every accepting state absorbing.  Used for "contains a match"
    /// (search) semantics: once matched, always matched — this also lets
    /// the sequential matcher early-exit like Algorithm 1 (lines 4–5).
    pub fn with_absorbing_finals(&self) -> Dfa {
        let mut table = self.table.clone();
        for q in 0..self.num_states {
            if self.accepting[q as usize] {
                for s in 0..self.num_symbols {
                    table[(q * self.num_symbols + s) as usize] = q;
                }
            }
        }
        Dfa::new(self.num_states, self.num_symbols, self.start,
                 self.accepting.clone(), table, self.classes)
    }
}

/// The paper's 1-D flattened representation (Fig. 8c): entries are
/// premultiplied row offsets (`state * num_symbols`), so the hot loop is
/// `off = SBase[off + sym]` — one add, one load, no multiply.
#[derive(Clone, Debug)]
pub struct FlatDfa {
    /// SBase: flattened table of *row offsets*
    pub sbase: Vec<u32>,
    /// |Σ| — the row stride
    pub num_symbols: u32,
    /// |Q|
    pub num_states: u32,
    /// row offset of q0
    pub start_off: u32,
    /// accepting_by_offset[off / num_symbols]
    accepting: Vec<bool>,
    /// byte -> dense symbol class (copied from the source Dfa)
    pub classes: [u8; 256],
    /// row offset of the sink, if any (early-exit opportunity)
    pub sink_off: Option<u32>,
}

impl FlatDfa {
    /// Flatten a [`Dfa`] into the premultiplied-offset representation.
    pub fn from_dfa(dfa: &Dfa) -> FlatDfa {
        let s = dfa.num_symbols;
        let sbase: Vec<u32> = dfa.table.iter().map(|&t| t * s).collect();
        FlatDfa {
            sbase,
            num_symbols: s,
            num_states: dfa.num_states,
            start_off: dfa.start * s,
            accepting: dfa.accepting.clone(),
            classes: dfa.classes,
            sink_off: dfa.sink().map(|q| q * s),
        }
    }

    /// State id of a row offset.
    #[inline]
    pub fn state_of(&self, off: u32) -> u32 {
        off / self.num_symbols
    }

    /// Row offset of a state id.
    #[inline]
    pub fn offset_of(&self, state: u32) -> u32 {
        state * self.num_symbols
    }

    /// Whether the state at row offset `off` is accepting.
    #[inline]
    pub fn is_accepting_off(&self, off: u32) -> bool {
        self.accepting[(off / self.num_symbols) as usize]
    }

    /// The Listing-1 hot loop over premapped dense symbols.
    /// Returns the final row offset.
    ///
    /// SAFETY: every entry of `sbase` is `next_state * num_symbols` with
    /// `next_state < num_states` (guaranteed by Dfa::new + from_dfa), so
    /// with `sym < num_symbols` the index `off + sym` stays in bounds.
    /// The symbol slice is validated up front (a separate, vectorizable
    /// pass that stays off the serial dependent-load chain); the loop
    /// body is then the paper's C Listing 1 — 2 adds, 1 indexed load,
    /// 1 cmp, 1 jump — with no bounds-check branch (§Perf: ~2×, 250→500
    /// MB/s on this host).
    #[inline]
    pub fn run_syms(&self, start_off: u32, syms: &[u32]) -> u32 {
        let s = self.num_symbols;
        assert!(
            syms.iter().all(|&sym| sym < s),
            "symbol out of range (not produced by map_input?)"
        );
        assert!(start_off < self.num_states * s && start_off % s == 0);
        let sbase = &self.sbase[..];
        let mut off = start_off;
        for &sym in syms {
            debug_assert!(((off + sym) as usize) < sbase.len());
            // one add + one indexed load (cf. Listing 1 line 8)
            off = unsafe { *sbase.get_unchecked((off + sym) as usize) };
        }
        off
    }

    /// Four interleaved Listing-1 runs over the same symbol stream.
    ///
    /// The speculative matcher matches one chunk for up to I_max initial
    /// states; each run is an independent serial dependent-load chain, so
    /// interleaving four of them in one pass over the input hides the
    /// load latency behind ILP (§Perf: ~2.3× over four separate passes)
    /// — the scalar analog of the paper's 8 SIMD lanes.
    #[inline]
    pub fn run_syms_x4(&self, starts: [u32; 4], syms: &[u32]) -> [u32; 4] {
        let s = self.num_symbols;
        assert!(
            syms.iter().all(|&sym| sym < s),
            "symbol out of range (not produced by map_input?)"
        );
        for &o in &starts {
            assert!(o < self.num_states * s && o % s == 0);
        }
        let sbase = &self.sbase[..];
        let [mut a, mut b, mut c, mut d] = starts;
        for &sym in syms {
            // four independent chains per iteration: the CPU overlaps
            // the four L1/L2 loads
            unsafe {
                a = *sbase.get_unchecked((a + sym) as usize);
                b = *sbase.get_unchecked((b + sym) as usize);
                c = *sbase.get_unchecked((c + sym) as usize);
                d = *sbase.get_unchecked((d + sym) as usize);
            }
        }
        [a, b, c, d]
    }

    /// Hot loop over raw bytes (class mapping fused).  Same safety
    /// invariant as `run_syms`; `classes[b] < num_symbols` by Dfa::new.
    #[inline]
    pub fn run_bytes(&self, start_off: u32, bytes: &[u8]) -> u32 {
        let sbase = &self.sbase[..];
        let classes = &self.classes;
        let mut off = start_off;
        for &b in bytes {
            let sym = classes[b as usize] as u32;
            debug_assert!(((off + sym) as usize) < sbase.len());
            off = unsafe { *sbase.get_unchecked((off + sym) as usize) };
        }
        off
    }
}

/// Compute byte equivalence classes from a collection of ByteSets: two
/// bytes are equivalent iff they are members of exactly the same sets.
/// Returns (classes, num_classes).  This is the IBase symbol mapping.
pub fn byte_classes(sets: &[super::byteset::ByteSet]) -> ([u8; 256], u32) {
    // signature of byte b = bit vector of set membership
    let mut sig_to_class: HashMap<Vec<bool>, u8> = HashMap::new();
    let mut classes = [0u8; 256];
    let mut next = 0u8;
    for b in 0..=255u8 {
        let sig: Vec<bool> = sets.iter().map(|s| s.contains(b)).collect();
        let c = *sig_to_class.entry(sig).or_insert_with(|| {
            let c = next;
            next = next.checked_add(1).expect("more than 256 byte classes");
            c
        });
        classes[b as usize] = c;
    }
    (classes, next as u32)
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::automata::byteset::ByteSet;

    /// The motivating DFA of Fig. 1: a*bc* with explicit sink.
    /// States: 0=q0, 1=q1, 2=qe. Symbols: 0=a, 1=b, 2=c.
    pub fn fig1_dfa() -> Dfa {
        let mut classes = [3u8; 256];
        // map a,b,c; everything else -> class 3 would exceed num_symbols,
        // so use a 4-symbol alphabet where class 3 ("other") also sinks.
        classes[b'a' as usize] = 0;
        classes[b'b' as usize] = 1;
        classes[b'c' as usize] = 2;
        let table = vec![
            // q0: a->q0, b->q1, c->qe, other->qe
            0, 1, 2, 2, //
            // q1: a->qe, b->qe, c->q1, other->qe
            2, 2, 1, 2, //
            // qe: all self
            2, 2, 2, 2,
        ];
        Dfa::new(3, 4, 0, vec![false, true, false], table, classes)
    }

    #[test]
    fn fig1_membership() {
        let dfa = fig1_dfa();
        assert!(dfa.accepts_bytes(b"aaaaaaabcccc")); // Fig. 1(b)
        assert!(dfa.accepts_bytes(b"b"));
        assert!(!dfa.accepts_bytes(b"aa"));
        assert!(!dfa.accepts_bytes(b"abcb"));
        assert!(!dfa.accepts_bytes(b""));
    }

    #[test]
    fn fig1_sink_detected() {
        assert_eq!(fig1_dfa().sink(), Some(2));
    }

    #[test]
    fn flat_matches_dfa() {
        let dfa = fig1_dfa();
        let flat = FlatDfa::from_dfa(&dfa);
        for input in [&b"aaabccc"[..], b"abc", b"", b"ccc", b"aabbcc"] {
            let q = dfa.run_bytes(dfa.start, input);
            let off = flat.run_bytes(flat.start_off, input);
            assert_eq!(flat.state_of(off), q);
            assert_eq!(flat.is_accepting_off(off),
                       dfa.accepting[q as usize]);
        }
        assert_eq!(flat.sink_off, Some(2 * 4));
    }

    #[test]
    fn flat_run_syms_equals_run_bytes() {
        let dfa = fig1_dfa();
        let flat = FlatDfa::from_dfa(&dfa);
        let input = b"aaabcccab";
        let syms = dfa.map_input(input);
        assert_eq!(
            flat.run_syms(flat.start_off, &syms),
            flat.run_bytes(flat.start_off, input)
        );
    }

    #[test]
    fn byte_classes_partition() {
        let sets = vec![
            ByteSet::range(b'a', b'z'),
            ByteSet::single(b'a'),
            ByteSet::range(b'0', b'9'),
        ];
        let (classes, n) = byte_classes(&sets);
        // expected classes: {a}, {b..z}, {0..9}, {rest} = 4
        assert_eq!(n, 4);
        assert_eq!(classes[b'b' as usize], classes[b'z' as usize]);
        assert_ne!(classes[b'a' as usize], classes[b'b' as usize]);
        assert_eq!(classes[b'3' as usize], classes[b'7' as usize]);
        assert_eq!(classes[b' ' as usize], classes[b'!' as usize]);
    }

    #[test]
    fn trim_unreachable_preserves_language() {
        // add an unreachable state to fig1
        let dfa = fig1_dfa();
        let mut table = dfa.table.clone();
        table.extend_from_slice(&[3, 3, 3, 3]); // state 3, unreachable
        let mut acc = dfa.accepting.clone();
        acc.push(true);
        let big = Dfa::new(4, 4, 0, acc, table, dfa.classes);
        let trimmed = big.trim_unreachable();
        assert_eq!(trimmed.num_states, 3);
        for input in [&b"aaabccc"[..], b"abc", b"", b"b"] {
            assert_eq!(trimmed.accepts_bytes(input), dfa.accepts_bytes(input));
        }
    }

    #[test]
    fn absorbing_finals_latch() {
        let dfa = fig1_dfa().with_absorbing_finals();
        // once we've seen a*bc* prefix, stays accepting
        assert!(dfa.accepts_bytes(b"ab"));
        assert!(dfa.accepts_bytes(b"abzzz"));
    }
}

#[cfg(test)]
mod x4_tests {
    use super::tests::fig1_dfa;
    use super::*;
    use crate::util::prop;

    #[test]
    fn prop_x4_equals_four_single_runs() {
        let dfa = fig1_dfa();
        let flat = FlatDfa::from_dfa(&dfa);
        prop::check("run_syms_x4 == 4x run_syms", 40, |rng| {
            let len = rng.below(300) as usize;
            let syms: Vec<u32> = (0..len)
                .map(|_| rng.below(dfa.num_symbols as u64) as u32)
                .collect();
            let starts = [
                flat.offset_of(rng.below(3) as u32),
                flat.offset_of(rng.below(3) as u32),
                flat.offset_of(rng.below(3) as u32),
                flat.offset_of(rng.below(3) as u32),
            ];
            let got = flat.run_syms_x4(starts, &syms);
            for i in 0..4 {
                assert_eq!(got[i], flat.run_syms(starts[i], &syms));
            }
        });
    }

    #[test]
    #[should_panic]
    fn x4_rejects_bad_symbols() {
        let dfa = fig1_dfa();
        let flat = FlatDfa::from_dfa(&dfa);
        flat.run_syms_x4([0; 4], &[99]);
    }
}
