//! Dense-alphabet DFA + the paper's flattened SBase/IBase representation.
//!
//! A `Dfa` is complete (total transition function) over a small dense
//! symbol alphabet 0..num_symbols; raw input bytes are mapped to symbols by
//! the 256-entry `classes` table (the IBase mapping of Fig. 8d).  `FlatDfa`
//! is the performance representation of Fig. 8(c): states are encoded as
//! *row offsets* into a 1-dimensional transition array so the matching loop
//! is one add + one indexed load per symbol (Listing 1).

use std::collections::HashMap;

/// Complete deterministic finite automaton over a dense symbol alphabet.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Dfa {
    /// |Q|
    pub num_states: u32,
    /// |Σ| (dense symbol classes)
    pub num_symbols: u32,
    /// q0
    pub start: u32,
    /// accepting[q] — final state indicator (F)
    pub accepting: Vec<bool>,
    /// row-major table: table[q * num_symbols + s] = delta(q, s)
    pub table: Vec<u32>,
    /// byte -> dense symbol class (IBase map). classes[b] < num_symbols.
    pub classes: [u8; 256],
}

impl Dfa {
    /// Build directly from parts, checking the invariants.
    pub fn new(
        num_states: u32,
        num_symbols: u32,
        start: u32,
        accepting: Vec<bool>,
        table: Vec<u32>,
        classes: [u8; 256],
    ) -> Dfa {
        assert_eq!(accepting.len(), num_states as usize);
        assert_eq!(table.len(), (num_states * num_symbols) as usize);
        assert!(start < num_states);
        assert!(table.iter().all(|&t| t < num_states), "incomplete DFA");
        assert!(classes.iter().all(|&c| (c as u32) < num_symbols));
        Dfa { num_states, num_symbols, start, accepting, table, classes }
    }

    /// One transition: delta(q, sym).
    #[inline]
    pub fn step(&self, q: u32, sym: u32) -> u32 {
        self.table[(q * self.num_symbols + sym) as usize]
    }

    /// Dense symbol class of a raw input byte (the IBase map).
    #[inline]
    pub fn class_of(&self, byte: u8) -> u32 {
        self.classes[byte as usize] as u32
    }

    /// delta*(q, syms) over dense symbols.
    pub fn run(&self, mut q: u32, syms: &[u32]) -> u32 {
        for &s in syms {
            q = self.step(q, s);
        }
        q
    }

    /// delta*(q, bytes) over raw bytes (classes applied on the fly).
    pub fn run_bytes(&self, mut q: u32, bytes: &[u8]) -> u32 {
        for &b in bytes {
            q = self.step(q, self.class_of(b));
        }
        q
    }

    /// Membership test: delta*(q0, bytes) in F.
    pub fn accepts_bytes(&self, bytes: &[u8]) -> bool {
        self.accepting[self.run_bytes(self.start, bytes) as usize]
    }

    /// Membership over pre-mapped dense symbols.
    pub fn accepts(&self, syms: &[u32]) -> bool {
        self.accepting[self.run(self.start, syms) as usize]
    }

    /// Map a byte string to dense symbols (materialized IBase, Fig. 8d).
    pub fn map_input(&self, bytes: &[u8]) -> Vec<u32> {
        bytes.iter().map(|&b| self.class_of(b)).collect()
    }

    /// Identify the sink (error) state: non-accepting with all self-loops.
    /// The paper assumes a unique error state q_e (§2.1).
    pub fn sink(&self) -> Option<u32> {
        (0..self.num_states).find(|&q| {
            !self.accepting[q as usize]
                && (0..self.num_symbols).all(|s| self.step(q, s) == q)
        })
    }

    /// Number of accepting states.
    pub fn num_accepting(&self) -> usize {
        self.accepting.iter().filter(|&&a| a).count()
    }

    /// Remove states unreachable from the start (preserves language).
    pub fn trim_unreachable(&self) -> Dfa {
        let mut reach = vec![false; self.num_states as usize];
        let mut stack = vec![self.start];
        reach[self.start as usize] = true;
        while let Some(q) = stack.pop() {
            for s in 0..self.num_symbols {
                let t = self.step(q, s);
                if !reach[t as usize] {
                    reach[t as usize] = true;
                    stack.push(t);
                }
            }
        }
        let mut remap = vec![u32::MAX; self.num_states as usize];
        let mut n = 0u32;
        for q in 0..self.num_states {
            if reach[q as usize] {
                remap[q as usize] = n;
                n += 1;
            }
        }
        let mut table = Vec::with_capacity((n * self.num_symbols) as usize);
        let mut accepting = Vec::with_capacity(n as usize);
        for q in 0..self.num_states {
            if reach[q as usize] {
                accepting.push(self.accepting[q as usize]);
                for s in 0..self.num_symbols {
                    table.push(remap[self.step(q, s) as usize]);
                }
            }
        }
        Dfa::new(n, self.num_symbols, remap[self.start as usize], accepting,
                 table, self.classes)
    }

    /// Make every accepting state absorbing.  Used for "contains a match"
    /// (search) semantics: once matched, always matched — this also lets
    /// the sequential matcher early-exit like Algorithm 1 (lines 4–5).
    pub fn with_absorbing_finals(&self) -> Dfa {
        let mut table = self.table.clone();
        for q in 0..self.num_states {
            if self.accepting[q as usize] {
                for s in 0..self.num_symbols {
                    table[(q * self.num_symbols + s) as usize] = q;
                }
            }
        }
        Dfa::new(self.num_states, self.num_symbols, self.start,
                 self.accepting.clone(), table, self.classes)
    }
}

/// Storage width of a premultiplied SBase table.  The matching loop only
/// ever loads *row offsets*, whose maximum value is
/// `(num_states - 1) * num_symbols`, so most PCRE/PROSITE DFAs fit u16
/// (and small ones u8) — halving or quartering the table bytes keeps the
/// hot rows L1-resident (the Fig. 8c table is the only data the inner
/// loop touches).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Width {
    /// row offsets fit in one byte (max offset <= 255)
    U8,
    /// row offsets fit in two bytes (max offset <= 65535)
    U16,
    /// full-width offsets
    U32,
}

impl Width {
    /// Narrowest width whose range holds every row offset of a
    /// `num_states` x `num_symbols` table.
    pub fn for_dfa(num_states: u32, num_symbols: u32) -> Width {
        let max_off =
            num_states.saturating_sub(1) as u64 * num_symbols as u64;
        if max_off <= u8::MAX as u64 {
            Width::U8
        } else if max_off <= u16::MAX as u64 {
            Width::U16
        } else {
            Width::U32
        }
    }

    /// Whether this width's range holds `max_off` — the single
    /// authoritative fits-check used by construction, tests and the
    /// bench tiers.
    pub fn holds(&self, max_off: u64) -> bool {
        match self {
            Width::U8 => max_off <= u8::MAX as u64,
            Width::U16 => max_off <= u16::MAX as u64,
            Width::U32 => max_off <= u32::MAX as u64,
        }
    }

    /// Bytes per table entry.
    pub fn bytes(&self) -> usize {
        match self {
            Width::U8 => 1,
            Width::U16 => 2,
            Width::U32 => 4,
        }
    }

    /// Stable lowercase name ("u8" / "u16" / "u32").
    pub fn name(&self) -> &'static str {
        match self {
            Width::U8 => "u8",
            Width::U16 => "u16",
            Width::U32 => "u32",
        }
    }
}

/// One table word: a premultiplied row offset in a compact width.
pub(crate) trait SBaseWord: Copy {
    /// Widen back to the canonical u32 offset.
    fn to_u32(self) -> u32;
}

impl SBaseWord for u8 {
    #[inline(always)]
    fn to_u32(self) -> u32 {
        self as u32
    }
}

impl SBaseWord for u16 {
    #[inline(always)]
    fn to_u32(self) -> u32 {
        self as u32
    }
}

impl SBaseWord for u32 {
    #[inline(always)]
    fn to_u32(self) -> u32 {
        self
    }
}

/// Width-compacted SBase storage: the flattened table of premultiplied
/// row offsets in the narrowest integer type that holds them.
#[derive(Clone, Debug)]
pub enum SBase {
    /// 1-byte entries
    U8(Vec<u8>),
    /// 2-byte entries
    U16(Vec<u16>),
    /// 4-byte entries
    U32(Vec<u32>),
}

/// Run `$body` with `$tab` bound to the concrete `&[T]` table — the
/// single width dispatch per run (never per symbol).
macro_rules! with_sbase {
    ($sb:expr, $tab:ident => $body:expr) => {
        match $sb {
            SBase::U8($tab) => $body,
            SBase::U16($tab) => $body,
            SBase::U32($tab) => $body,
        }
    };
}
pub(crate) use with_sbase;

impl SBase {
    /// Compact a slice of row offsets into `width` storage (every offset
    /// must fit; guaranteed when `width` covers the table's max offset).
    pub(crate) fn compact(offsets: &[u32], width: Width) -> SBase {
        match width {
            Width::U8 => {
                SBase::U8(offsets.iter().map(|&o| o as u8).collect())
            }
            Width::U16 => {
                SBase::U16(offsets.iter().map(|&o| o as u16).collect())
            }
            Width::U32 => SBase::U32(offsets.to_vec()),
        }
    }

    /// Number of table entries.
    pub fn len(&self) -> usize {
        with_sbase!(self, tab => tab.len())
    }

    /// Whether the table has zero entries.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Storage width of the entries.
    pub fn width(&self) -> Width {
        match self {
            SBase::U8(_) => Width::U8,
            SBase::U16(_) => Width::U16,
            SBase::U32(_) => Width::U32,
        }
    }

    /// Checked entry read (cold paths only — the hot loops run the
    /// unchecked generic kernels).
    pub fn get(&self, i: usize) -> u32 {
        with_sbase!(self, tab => tab[i].to_u32())
    }
}

/// A symbol slice proven in-range for a stride of `stride` symbols:
/// constructed only by [`FlatDfa::validate`], which checks every symbol
/// once, so the unchecked inner loops stay sound without re-scanning the
/// same chunk per initial-state group.
#[derive(Clone, Copy, Debug)]
pub struct ValidSyms<'a> {
    syms: &'a [u32],
    stride: u32,
}

impl<'a> ValidSyms<'a> {
    /// The validated symbols.
    pub fn as_slice(&self) -> &'a [u32] {
        self.syms
    }

    /// Number of symbols.
    pub fn len(&self) -> usize {
        self.syms.len()
    }

    /// Whether the slice is empty.
    pub fn is_empty(&self) -> bool {
        self.syms.is_empty()
    }

    /// The alphabet size the symbols were validated against.
    pub fn stride(&self) -> u32 {
        self.stride
    }

    /// A sub-slice (validity is inherited).
    pub fn slice(&self, range: std::ops::Range<usize>) -> ValidSyms<'a> {
        ValidSyms { syms: &self.syms[range], stride: self.stride }
    }
}

/// The Listing-1 inner loop, monomorphized per table width.
///
/// SAFETY (callers): every table entry is `next_state * stride` with
/// `next_state < num_states`, `start` is a valid row offset, and every
/// `sym < stride` — so `off + sym < num_states * stride = tab.len()`.
#[inline(always)]
fn run_generic<T: SBaseWord>(tab: &[T], start: u32, syms: &[u32]) -> u32 {
    let mut off = start;
    for &sym in syms {
        debug_assert!(((off + sym) as usize) < tab.len());
        // one add + one indexed load (cf. Listing 1 line 8)
        off = unsafe { tab.get_unchecked((off + sym) as usize) }.to_u32();
    }
    off
}

/// Eight interleaved Listing-1 chains over one symbol stream,
/// monomorphized per table width.  Same safety contract as
/// [`run_generic`].
#[inline(always)]
fn run_generic_x8<T: SBaseWord>(
    tab: &[T],
    starts: [u32; 8],
    syms: &[u32],
) -> [u32; 8] {
    let mut off = starts;
    for &sym in syms {
        // eight independent serial dependent-load chains per iteration:
        // the CPU overlaps the L1/L2 loads (the scalar analog of the
        // paper's 8 SIMD lanes)
        unsafe {
            off[0] = tab.get_unchecked((off[0] + sym) as usize).to_u32();
            off[1] = tab.get_unchecked((off[1] + sym) as usize).to_u32();
            off[2] = tab.get_unchecked((off[2] + sym) as usize).to_u32();
            off[3] = tab.get_unchecked((off[3] + sym) as usize).to_u32();
            off[4] = tab.get_unchecked((off[4] + sym) as usize).to_u32();
            off[5] = tab.get_unchecked((off[5] + sym) as usize).to_u32();
            off[6] = tab.get_unchecked((off[6] + sym) as usize).to_u32();
            off[7] = tab.get_unchecked((off[7] + sym) as usize).to_u32();
        }
    }
    off
}

/// The paper's 1-D flattened representation (Fig. 8c): entries are
/// premultiplied row offsets (`state * num_symbols`), so the hot loop is
/// `off = SBase[off + sym]` — one add, one load, no multiply.  The table
/// is stored at the narrowest width that holds its offsets ([`Width`]),
/// dispatched once per run.
#[derive(Clone, Debug)]
pub struct FlatDfa {
    /// SBase: width-compacted flattened table of *row offsets*
    sbase: SBase,
    /// |Σ| — the row stride
    pub num_symbols: u32,
    /// |Q|
    pub num_states: u32,
    /// row offset of q0
    pub start_off: u32,
    /// accepting_by_offset[off / num_symbols]
    accepting: Vec<bool>,
    /// byte -> dense symbol class (copied from the source Dfa)
    pub classes: [u8; 256],
    /// row offset of the sink, if any (early-exit opportunity)
    pub sink_off: Option<u32>,
}

impl FlatDfa {
    /// Flatten a [`Dfa`] into the premultiplied-offset representation at
    /// the narrowest width that fits.
    pub fn from_dfa(dfa: &Dfa) -> FlatDfa {
        Self::from_dfa_with_width(
            dfa,
            Width::for_dfa(dfa.num_states, dfa.num_symbols),
        )
    }

    /// Flatten at a forced storage width (bench tiers compare widths on
    /// one DFA).  Panics if the table's offsets don't fit `width`.
    pub fn from_dfa_with_width(dfa: &Dfa, width: Width) -> FlatDfa {
        let s = dfa.num_symbols;
        let max_off = dfa.num_states.saturating_sub(1) as u64 * s as u64;
        assert!(
            width.holds(max_off),
            "max row offset {max_off} exceeds {} storage",
            width.name()
        );
        let offsets: Vec<u32> = dfa.table.iter().map(|&t| t * s).collect();
        FlatDfa {
            sbase: SBase::compact(&offsets, width),
            num_symbols: s,
            num_states: dfa.num_states,
            start_off: dfa.start * s,
            accepting: dfa.accepting.clone(),
            classes: dfa.classes,
            sink_off: dfa.sink().map(|q| q * s),
        }
    }

    /// Storage width of the SBase table.
    pub fn width(&self) -> Width {
        self.sbase.width()
    }

    /// Bytes occupied by the SBase table (the hot loop's working set).
    pub fn table_bytes(&self) -> usize {
        self.sbase.len() * self.width().bytes()
    }

    /// The width-compacted table (checked access for cold paths).
    pub fn sbase(&self) -> &SBase {
        &self.sbase
    }

    /// State id of a row offset.
    #[inline]
    pub fn state_of(&self, off: u32) -> u32 {
        off / self.num_symbols
    }

    /// Row offset of a state id.
    #[inline]
    pub fn offset_of(&self, state: u32) -> u32 {
        state * self.num_symbols
    }

    /// Whether the state at row offset `off` is accepting.
    #[inline]
    pub fn is_accepting_off(&self, off: u32) -> bool {
        self.accepting[(off / self.num_symbols) as usize]
    }

    /// Validate a symbol slice once (a separate, vectorizable pass that
    /// stays off the serial dependent-load chain).  The returned
    /// [`ValidSyms`] proves every symbol < `num_symbols`, so the
    /// unchecked hot loops accept it without re-scanning — callers that
    /// match one chunk for many initial states validate once per chunk,
    /// not once per state group.
    #[inline]
    pub fn validate<'a>(&self, syms: &'a [u32]) -> ValidSyms<'a> {
        let s = self.num_symbols;
        assert!(
            syms.iter().all(|&sym| sym < s),
            "symbol out of range (not produced by map_input?)"
        );
        ValidSyms { syms, stride: s }
    }

    #[inline]
    fn check_start(&self, off: u32) {
        let s = self.num_symbols;
        assert!(off < self.num_states * s && off % s == 0);
    }

    #[inline]
    fn check_valid(&self, syms: &ValidSyms<'_>) {
        assert_eq!(
            syms.stride, self.num_symbols,
            "ValidSyms validated against a different alphabet"
        );
    }

    /// The Listing-1 hot loop over premapped dense symbols.
    /// Returns the final row offset.
    ///
    /// Validates `syms` first; see [`FlatDfa::run_valid`] for the
    /// validate-once entry point.  The loop body is the paper's C
    /// Listing 1 — 2 adds, 1 indexed load, 1 cmp, 1 jump — with no
    /// bounds-check branch (§Perf: ~2×, 250→500 MB/s on this host), over
    /// the width-compacted table.
    #[inline]
    pub fn run_syms(&self, start_off: u32, syms: &[u32]) -> u32 {
        self.run_valid(start_off, self.validate(syms))
    }

    /// [`FlatDfa::run_syms`] over an already-validated slice: the width
    /// dispatch happens here, once per run.
    #[inline]
    pub fn run_valid(&self, start_off: u32, syms: ValidSyms<'_>) -> u32 {
        self.check_valid(&syms);
        self.check_start(start_off);
        with_sbase!(&self.sbase, tab => {
            run_generic(tab, start_off, syms.as_slice())
        })
    }

    /// Eight interleaved Listing-1 runs over the same symbol stream.
    ///
    /// The speculative matcher matches one chunk for up to I_max initial
    /// states; each run is an independent serial dependent-load chain,
    /// so interleaving eight of them in one pass over the input hides
    /// the load latency behind ILP — the scalar analog of the paper's 8
    /// SIMD lanes (Listing 2).
    #[inline]
    pub fn run_syms_x8(&self, starts: [u32; 8], syms: &[u32]) -> [u32; 8] {
        self.run_valid_x8(starts, self.validate(syms))
    }

    /// [`FlatDfa::run_syms_x8`] over an already-validated slice.
    #[inline]
    pub fn run_valid_x8(
        &self,
        starts: [u32; 8],
        syms: ValidSyms<'_>,
    ) -> [u32; 8] {
        self.check_valid(&syms);
        for &o in &starts {
            self.check_start(o);
        }
        with_sbase!(&self.sbase, tab => {
            run_generic_x8(tab, starts, syms.as_slice())
        })
    }

    /// Hot loop over raw bytes (class mapping fused).  Sound without a
    /// validation pass: `classes[b] < num_symbols` by Dfa::new.
    #[inline]
    pub fn run_bytes(&self, start_off: u32, bytes: &[u8]) -> u32 {
        self.check_start(start_off);
        let classes = &self.classes;
        with_sbase!(&self.sbase, tab => {
            let mut off = start_off;
            for &b in bytes {
                let sym = classes[b as usize] as u32;
                debug_assert!(((off + sym) as usize) < tab.len());
                off = unsafe { tab.get_unchecked((off + sym) as usize) }
                    .to_u32();
            }
            off
        })
    }

    /// Byte scan with the Algorithm-1 early exits: stops after the
    /// symbol that reaches an accepting state or the sink.  Returns
    /// `(final row offset, bytes consumed)` — the checked kernel behind
    /// [`crate::baseline::sequential::SequentialMatcher::run_early_exit`].
    pub fn run_bytes_until(
        &self,
        start_off: u32,
        bytes: &[u8],
    ) -> (u32, usize) {
        self.check_start(start_off);
        let sink = self.sink_off.unwrap_or(u32::MAX);
        let classes = &self.classes;
        with_sbase!(&self.sbase, tab => {
            let mut off = start_off;
            for (i, &b) in bytes.iter().enumerate() {
                let sym = classes[b as usize] as u32;
                debug_assert!(((off + sym) as usize) < tab.len());
                off = unsafe { tab.get_unchecked((off + sym) as usize) }
                    .to_u32();
                if self.is_accepting_off(off) || off == sink {
                    return (off, i + 1);
                }
            }
            (off, bytes.len())
        })
    }
}

/// Compute byte equivalence classes from a collection of ByteSets: two
/// bytes are equivalent iff they are members of exactly the same sets.
/// Returns (classes, num_classes).  This is the IBase symbol mapping.
pub fn byte_classes(sets: &[super::byteset::ByteSet]) -> ([u8; 256], u32) {
    // signature of byte b = bit vector of set membership
    let mut sig_to_class: HashMap<Vec<bool>, u8> = HashMap::new();
    let mut classes = [0u8; 256];
    let mut next = 0u8;
    for b in 0..=255u8 {
        let sig: Vec<bool> = sets.iter().map(|s| s.contains(b)).collect();
        let c = *sig_to_class.entry(sig).or_insert_with(|| {
            let c = next;
            next = next.checked_add(1).expect("more than 256 byte classes");
            c
        });
        classes[b as usize] = c;
    }
    (classes, next as u32)
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::automata::byteset::ByteSet;

    /// The motivating DFA of Fig. 1: a*bc* with explicit sink.
    /// States: 0=q0, 1=q1, 2=qe. Symbols: 0=a, 1=b, 2=c.
    pub fn fig1_dfa() -> Dfa {
        let mut classes = [3u8; 256];
        // map a,b,c; everything else -> class 3 would exceed num_symbols,
        // so use a 4-symbol alphabet where class 3 ("other") also sinks.
        classes[b'a' as usize] = 0;
        classes[b'b' as usize] = 1;
        classes[b'c' as usize] = 2;
        let table = vec![
            // q0: a->q0, b->q1, c->qe, other->qe
            0, 1, 2, 2, //
            // q1: a->qe, b->qe, c->q1, other->qe
            2, 2, 1, 2, //
            // qe: all self
            2, 2, 2, 2,
        ];
        Dfa::new(3, 4, 0, vec![false, true, false], table, classes)
    }

    #[test]
    fn fig1_membership() {
        let dfa = fig1_dfa();
        assert!(dfa.accepts_bytes(b"aaaaaaabcccc")); // Fig. 1(b)
        assert!(dfa.accepts_bytes(b"b"));
        assert!(!dfa.accepts_bytes(b"aa"));
        assert!(!dfa.accepts_bytes(b"abcb"));
        assert!(!dfa.accepts_bytes(b""));
    }

    #[test]
    fn fig1_sink_detected() {
        assert_eq!(fig1_dfa().sink(), Some(2));
    }

    #[test]
    fn flat_matches_dfa() {
        let dfa = fig1_dfa();
        let flat = FlatDfa::from_dfa(&dfa);
        for input in [&b"aaabccc"[..], b"abc", b"", b"ccc", b"aabbcc"] {
            let q = dfa.run_bytes(dfa.start, input);
            let off = flat.run_bytes(flat.start_off, input);
            assert_eq!(flat.state_of(off), q);
            assert_eq!(flat.is_accepting_off(off),
                       dfa.accepting[q as usize]);
        }
        assert_eq!(flat.sink_off, Some(2 * 4));
        // 3 states x 4 symbols: max offset 8 -> u8 storage
        assert_eq!(flat.width(), Width::U8);
        assert_eq!(flat.table_bytes(), 12);
    }

    #[test]
    fn flat_run_syms_equals_run_bytes() {
        let dfa = fig1_dfa();
        let flat = FlatDfa::from_dfa(&dfa);
        let input = b"aaabcccab";
        let syms = dfa.map_input(input);
        assert_eq!(
            flat.run_syms(flat.start_off, &syms),
            flat.run_bytes(flat.start_off, input)
        );
    }

    #[test]
    fn byte_classes_partition() {
        let sets = vec![
            ByteSet::range(b'a', b'z'),
            ByteSet::single(b'a'),
            ByteSet::range(b'0', b'9'),
        ];
        let (classes, n) = byte_classes(&sets);
        // expected classes: {a}, {b..z}, {0..9}, {rest} = 4
        assert_eq!(n, 4);
        assert_eq!(classes[b'b' as usize], classes[b'z' as usize]);
        assert_ne!(classes[b'a' as usize], classes[b'b' as usize]);
        assert_eq!(classes[b'3' as usize], classes[b'7' as usize]);
        assert_eq!(classes[b' ' as usize], classes[b'!' as usize]);
    }

    #[test]
    fn trim_unreachable_preserves_language() {
        // add an unreachable state to fig1
        let dfa = fig1_dfa();
        let mut table = dfa.table.clone();
        table.extend_from_slice(&[3, 3, 3, 3]); // state 3, unreachable
        let mut acc = dfa.accepting.clone();
        acc.push(true);
        let big = Dfa::new(4, 4, 0, acc, table, dfa.classes);
        let trimmed = big.trim_unreachable();
        assert_eq!(trimmed.num_states, 3);
        for input in [&b"aaabccc"[..], b"abc", b"", b"b"] {
            assert_eq!(trimmed.accepts_bytes(input), dfa.accepts_bytes(input));
        }
    }

    #[test]
    fn absorbing_finals_latch() {
        let dfa = fig1_dfa().with_absorbing_finals();
        // once we've seen a*bc* prefix, stays accepting
        assert!(dfa.accepts_bytes(b"ab"));
        assert!(dfa.accepts_bytes(b"abzzz"));
    }
}

#[cfg(test)]
mod kernel_tests {
    use super::tests::fig1_dfa;
    use super::*;
    use crate::speculative::lookahead::tests::random_dfa;
    use crate::util::prop;

    #[test]
    fn prop_x8_equals_eight_single_runs() {
        let dfa = fig1_dfa();
        let flat = FlatDfa::from_dfa(&dfa);
        prop::check("run_syms_x8 == 8x run_syms", 40, |rng| {
            let len = rng.below(300) as usize;
            let syms: Vec<u32> = (0..len)
                .map(|_| rng.below(dfa.num_symbols as u64) as u32)
                .collect();
            let mut starts = [0u32; 8];
            for s in &mut starts {
                *s = flat.offset_of(rng.below(3) as u32);
            }
            let got = flat.run_syms_x8(starts, &syms);
            for (i, &g) in got.iter().enumerate() {
                assert_eq!(g, flat.run_syms(starts[i], &syms));
            }
        });
    }

    #[test]
    #[should_panic]
    fn x8_rejects_bad_symbols() {
        let dfa = fig1_dfa();
        let flat = FlatDfa::from_dfa(&dfa);
        flat.run_syms_x8([0; 8], &[99]);
    }

    #[test]
    #[should_panic]
    fn validate_rejects_out_of_range_symbols() {
        let dfa = fig1_dfa();
        let flat = FlatDfa::from_dfa(&dfa);
        flat.validate(&[0, 1, 4]);
    }

    #[test]
    fn valid_syms_slicing_keeps_validity() {
        let dfa = fig1_dfa();
        let flat = FlatDfa::from_dfa(&dfa);
        let syms = [0u32, 1, 2, 3, 0, 1];
        let vs = flat.validate(&syms);
        assert_eq!(vs.len(), 6);
        assert!(!vs.is_empty());
        assert_eq!(vs.stride(), dfa.num_symbols);
        let mid = vs.slice(2..5);
        assert_eq!(mid.as_slice(), &[2, 3, 0]);
        assert_eq!(
            flat.run_valid(flat.start_off, mid),
            flat.run_syms(flat.start_off, &syms[2..5])
        );
    }

    #[test]
    fn width_selection_tracks_max_row_offset() {
        // (num_states - 1) * num_symbols decides the width
        assert_eq!(Width::for_dfa(4, 64), Width::U8); // 192
        assert_eq!(Width::for_dfa(5, 64), Width::U16); // 256
        assert_eq!(Width::for_dfa(1024, 64), Width::U16); // 65472
        assert_eq!(Width::for_dfa(1025, 64), Width::U32); // 65536
        assert_eq!(Width::U8.bytes(), 1);
        assert_eq!(Width::U16.bytes(), 2);
        assert_eq!(Width::U32.bytes(), 4);
        assert_eq!(Width::U16.name(), "u16");
        assert!(Width::U8.holds(255) && !Width::U8.holds(256));
        assert!(Width::U16.holds(65535) && !Width::U16.holds(65536));
        assert!(Width::U32.holds(u32::MAX as u64));
    }

    #[test]
    #[should_panic]
    fn forced_width_too_narrow_is_rejected() {
        // 300 states x 4 symbols: max row offset 1196 cannot fit u8
        let mut table = Vec::new();
        for _ in 0..300 {
            table.extend_from_slice(&[0, 1, 2, 3]);
        }
        let big = Dfa::new(300, 4, 0, vec![false; 300], table, [0u8; 256]);
        FlatDfa::from_dfa_with_width(&big, Width::U8);
    }

    #[test]
    fn prop_forced_widths_are_byte_identical() {
        // THE compaction property: every width that fits returns exactly
        // the same offsets as the canonical u32 table, on random DFAs
        prop::check("u8/u16/u32 kernels agree", 40, |rng| {
            let dfa = random_dfa(rng);
            let len = rng.below(400) as usize;
            let syms: Vec<u32> = (0..len)
                .map(|_| rng.below(dfa.num_symbols as u64) as u32)
                .collect();
            let reference =
                FlatDfa::from_dfa_with_width(&dfa, Width::U32);
            let auto = FlatDfa::from_dfa(&dfa);
            let start = auto.offset_of(rng.below(dfa.num_states as u64) as u32);
            let want = reference.run_syms(start, &syms);
            assert_eq!(auto.run_syms(start, &syms), want);
            for width in [Width::U8, Width::U16] {
                let max_off = (dfa.num_states - 1) as u64
                    * dfa.num_symbols as u64;
                if !width.holds(max_off) {
                    continue;
                }
                let compact = FlatDfa::from_dfa_with_width(&dfa, width);
                assert_eq!(compact.run_syms(start, &syms), want);
                let mut starts = [start; 8];
                for s in &mut starts {
                    *s = compact
                        .offset_of(rng.below(dfa.num_states as u64) as u32);
                }
                assert_eq!(
                    compact.run_syms_x8(starts, &syms),
                    reference.run_syms_x8(starts, &syms)
                );
            }
            // run_bytes goes through the same compacted table
            let bytes: Vec<u8> =
                (0..len).map(|_| rng.below(256) as u8).collect();
            assert_eq!(
                auto.run_bytes(auto.start_off, &bytes),
                reference.run_bytes(reference.start_off, &bytes)
            );
        });
    }

    #[test]
    fn sbase_accessors() {
        let dfa = fig1_dfa();
        let flat = FlatDfa::from_dfa(&dfa);
        let sb = flat.sbase();
        assert_eq!(sb.len(), 12);
        assert!(!sb.is_empty());
        assert_eq!(sb.width(), Width::U8);
        // entry (q0, b) = q1 -> offset 4
        assert_eq!(sb.get(1), 4);
    }
}
