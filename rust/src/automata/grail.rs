//! Grail+-style text format I/O (Fig. 8b).
//!
//! The paper's framework "reads DFAs and input strings in Grail+ format and
//! converts them to our framework's internal representation."  Format:
//!
//! ```text
//! (START) |- 0
//! 0 a 1
//! 1 b 2
//! 2 -| (FINAL)
//! ```
//!
//! Transition labels are single characters (symbol classes are emitted as
//! their representative byte) or bare integers for dense-symbol DFAs.

use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

use super::dfa::Dfa;

/// Serialize a DFA to Grail+ text.  Labels are dense symbol ids.
pub fn to_grail(dfa: &Dfa) -> String {
    let mut out = String::new();
    writeln!(out, "(START) |- {}", dfa.start).unwrap();
    for q in 0..dfa.num_states {
        for s in 0..dfa.num_symbols {
            writeln!(out, "{} {} {}", q, s, dfa.step(q, s)).unwrap();
        }
    }
    for q in 0..dfa.num_states {
        if dfa.accepting[q as usize] {
            writeln!(out, "{} -| (FINAL)", q).unwrap();
        }
    }
    out
}

/// Parse Grail+ text into a DFA over dense symbols.
///
/// The state/symbol spaces are the integers that appear; the transition
/// function must be total over them (we verify and fail otherwise, since
/// every downstream algorithm assumes a complete DFA).
pub fn from_grail(text: &str) -> Result<Dfa> {
    let mut start: Option<u32> = None;
    let mut finals: Vec<u32> = Vec::new();
    let mut triples: Vec<(u32, u32, u32)> = Vec::new();

    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let toks: Vec<&str> = line.split_whitespace().collect();
        match toks.as_slice() {
            ["(START)", "|-", s] => {
                let s: u32 = s.parse()
                    .with_context(|| format!("line {}: bad start", lineno + 1))?;
                if start.replace(s).is_some() {
                    bail!("line {}: duplicate start", lineno + 1);
                }
            }
            [q, "-|", "(FINAL)"] => {
                finals.push(q.parse()
                    .with_context(|| format!("line {}: bad final", lineno + 1))?);
            }
            [q, a, t] => {
                let q: u32 = q.parse()
                    .with_context(|| format!("line {}: bad src", lineno + 1))?;
                let a: u32 = a.parse()
                    .with_context(|| format!("line {}: bad label", lineno + 1))?;
                let t: u32 = t.parse()
                    .with_context(|| format!("line {}: bad dst", lineno + 1))?;
                triples.push((q, a, t));
            }
            _ => bail!("line {}: unrecognized: {line:?}", lineno + 1),
        }
    }

    let start = start.ok_or_else(|| anyhow!("no (START) line"))?;
    let num_states = triples
        .iter()
        .flat_map(|&(q, _, t)| [q, t])
        .chain(finals.iter().copied())
        .chain([start])
        .max()
        .unwrap_or(0)
        + 1;
    let num_symbols = triples.iter().map(|&(_, a, _)| a).max()
        .ok_or_else(|| anyhow!("no transitions"))?
        + 1;

    let mut table = vec![u32::MAX; (num_states * num_symbols) as usize];
    for (q, a, t) in triples {
        let cell = &mut table[(q * num_symbols + a) as usize];
        if *cell != u32::MAX && *cell != t {
            bail!("nondeterministic: state {q} symbol {a}");
        }
        *cell = t;
    }
    if table.iter().any(|&t| t == u32::MAX) {
        bail!("incomplete DFA: missing transitions");
    }

    let mut accepting = vec![false; num_states as usize];
    for f in finals {
        accepting[f as usize] = true;
    }
    // identity-ish byte class map (byte b -> min(b, num_symbols-1)); raw
    // Grail DFAs operate on dense symbols directly.
    let mut classes = [0u8; 256];
    for b in 0..256usize {
        classes[b] = (b as u32).min(num_symbols - 1) as u8;
    }
    Ok(Dfa::new(num_states, num_symbols, start, accepting, table, classes))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::automata::dfa::tests::fig1_dfa;

    #[test]
    fn roundtrip_fig1() {
        let dfa = fig1_dfa();
        let text = to_grail(&dfa);
        let back = from_grail(&text).unwrap();
        assert_eq!(back.num_states, dfa.num_states);
        assert_eq!(back.num_symbols, dfa.num_symbols);
        assert_eq!(back.start, dfa.start);
        assert_eq!(back.accepting, dfa.accepting);
        assert_eq!(back.table, dfa.table);
    }

    #[test]
    fn parse_fig8_example() {
        // the paper's Fig. 8(b) DFA (4 states + sink row added to complete)
        let text = "\
(START) |- 0
0 0 1
0 1 2
1 0 3
1 1 2
2 0 1
2 1 3
3 0 3
3 1 3
2 -| (FINAL)
3 -| (FINAL)
";
        let dfa = from_grail(text).unwrap();
        assert_eq!(dfa.num_states, 4);
        assert_eq!(dfa.num_symbols, 2);
        assert!(dfa.accepting[2] && dfa.accepting[3]);
        assert!(!dfa.accepting[0]);
    }

    #[test]
    fn rejects_incomplete() {
        let text = "(START) |- 0\n0 0 1\n1 -| (FINAL)\n";
        assert!(from_grail(text).is_err());
    }

    #[test]
    fn rejects_nondeterministic() {
        let text = "(START) |- 0\n0 0 1\n0 0 0\n1 0 1\n1 -| (FINAL)\n";
        assert!(from_grail(text).is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(from_grail("hello world foo bar\n").is_err());
        assert!(from_grail("").is_err());
    }
}
