//! Thompson NFA construction from regex ASTs.
//!
//! Each AST node compiles to an (entry, exit) state pair with epsilon and
//! ByteSet-labelled edges; subset construction (subset.rs) then builds the
//! dense-alphabet DFA.  This replaces Grail+'s `retofm`/`fmtodfa` pipeline.

use super::byteset::ByteSet;
use crate::regex::ast::Ast;

/// Nondeterministic finite automaton with epsilon moves.
/// Single start, single accept (Thompson invariant).
#[derive(Clone, Debug)]
pub struct Nfa {
    /// eps[s] = epsilon successors of s
    pub eps: Vec<Vec<u32>>,
    /// trans[s] = labelled edges (set, target)
    pub trans: Vec<Vec<(ByteSet, u32)>>,
    /// Thompson entry state
    pub start: u32,
    /// Thompson accept state
    pub accept: u32,
}

impl Nfa {
    /// Number of states allocated.
    pub fn num_states(&self) -> usize {
        self.eps.len()
    }

    fn new() -> Self {
        Nfa { eps: Vec::new(), trans: Vec::new(), start: 0, accept: 0 }
    }

    fn add_state(&mut self) -> u32 {
        self.eps.push(Vec::new());
        self.trans.push(Vec::new());
        (self.eps.len() - 1) as u32
    }

    fn add_eps(&mut self, from: u32, to: u32) {
        self.eps[from as usize].push(to);
    }

    fn add_edge(&mut self, from: u32, set: ByteSet, to: u32) {
        self.trans[from as usize].push((set, to));
    }

    /// Compile an AST into a Thompson NFA.
    pub fn from_ast(ast: &Ast) -> Nfa {
        let mut nfa = Nfa::new();
        let start = nfa.add_state();
        let accept = nfa.add_state();
        nfa.start = start;
        nfa.accept = accept;
        nfa.build(ast, start, accept);
        nfa
    }

    /// Wire `ast` between states `from` and `to`.
    fn build(&mut self, ast: &Ast, from: u32, to: u32) {
        match ast {
            Ast::Empty => { /* no path: matches nothing */ }
            Ast::Epsilon => self.add_eps(from, to),
            Ast::Class(set) => {
                if set.is_empty() {
                    // empty class matches nothing
                } else {
                    self.add_edge(from, *set, to);
                }
            }
            Ast::Concat(parts) => {
                if parts.is_empty() {
                    self.add_eps(from, to);
                    return;
                }
                let mut cur = from;
                for (i, p) in parts.iter().enumerate() {
                    let nxt = if i + 1 == parts.len() {
                        to
                    } else {
                        self.add_state()
                    };
                    self.build(p, cur, nxt);
                    cur = nxt;
                }
            }
            Ast::Alt(alts) => {
                for a in alts {
                    let s = self.add_state();
                    let e = self.add_state();
                    self.add_eps(from, s);
                    self.build(a, s, e);
                    self.add_eps(e, to);
                }
            }
            Ast::Repeat { node, min, max } => {
                self.build_repeat(node, *min, *max, from, to);
            }
        }
    }

    fn build_repeat(
        &mut self,
        node: &Ast,
        min: u32,
        max: Option<u32>,
        from: u32,
        to: u32,
    ) {
        match max {
            None => {
                // node{min,}: min copies then a star loop
                let mut cur = from;
                for _ in 0..min {
                    let nxt = self.add_state();
                    self.build(node, cur, nxt);
                    cur = nxt;
                }
                // star: cur -e-> loop_in, loop: node loop_in->loop_in, -e-> to
                let hub = self.add_state();
                self.add_eps(cur, hub);
                let s = self.add_state();
                let e = self.add_state();
                self.add_eps(hub, s);
                self.build(node, s, e);
                self.add_eps(e, hub);
                self.add_eps(hub, to);
            }
            Some(max) => {
                assert!(max >= min, "bad repeat bounds");
                // min mandatory copies, then (max-min) optional copies
                let mut cur = from;
                for _ in 0..min {
                    let nxt = self.add_state();
                    self.build(node, cur, nxt);
                    cur = nxt;
                }
                for _ in min..max {
                    let nxt = self.add_state();
                    self.build(node, cur, nxt);
                    self.add_eps(cur, to);
                    cur = nxt;
                }
                self.add_eps(cur, to);
            }
        }
    }

    /// Epsilon-closure of a set of states (sorted, deduped).
    pub fn eps_closure(&self, states: &[u32]) -> Vec<u32> {
        let mut seen = vec![false; self.num_states()];
        let mut stack: Vec<u32> = Vec::new();
        for &s in states {
            if !seen[s as usize] {
                seen[s as usize] = true;
                stack.push(s);
            }
        }
        let mut out = stack.clone();
        while let Some(s) = stack.pop() {
            for &t in &self.eps[s as usize] {
                if !seen[t as usize] {
                    seen[t as usize] = true;
                    stack.push(t);
                    out.push(t);
                }
            }
        }
        out.sort_unstable();
        out
    }

    /// Direct NFA simulation over raw bytes — the slow ground truth used by
    /// tests to validate the whole NFA->DFA->minimize pipeline.
    pub fn accepts(&self, input: &[u8]) -> bool {
        let mut cur = self.eps_closure(&[self.start]);
        for &b in input {
            let mut nxt: Vec<u32> = Vec::new();
            for &s in &cur {
                for &(set, t) in &self.trans[s as usize] {
                    if set.contains(b) && !nxt.contains(&t) {
                        nxt.push(t);
                    }
                }
            }
            cur = self.eps_closure(&nxt);
            if cur.is_empty() {
                return false;
            }
        }
        cur.contains(&self.accept)
    }

    /// All ByteSets appearing on edges (for byte-class computation).
    pub fn edge_sets(&self) -> Vec<ByteSet> {
        let mut v: Vec<ByteSet> = Vec::new();
        for edges in &self.trans {
            for &(set, _) in edges {
                if !v.contains(&set) {
                    v.push(set);
                }
            }
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::regex::ast::Ast;

    fn lit(s: &str) -> Ast {
        Ast::Concat(s.bytes().map(|b| Ast::Class(ByteSet::single(b))).collect())
    }

    #[test]
    fn literal_accepts_exact() {
        let nfa = Nfa::from_ast(&lit("abc"));
        assert!(nfa.accepts(b"abc"));
        assert!(!nfa.accepts(b"ab"));
        assert!(!nfa.accepts(b"abcd"));
        assert!(!nfa.accepts(b""));
    }

    #[test]
    fn alternation() {
        let ast = Ast::Alt(vec![lit("cat"), lit("dog")]);
        let nfa = Nfa::from_ast(&ast);
        assert!(nfa.accepts(b"cat") && nfa.accepts(b"dog"));
        assert!(!nfa.accepts(b"cow"));
    }

    #[test]
    fn star_repeats() {
        // (ab)*
        let ast = Ast::Repeat { node: Box::new(lit("ab")), min: 0, max: None };
        let nfa = Nfa::from_ast(&ast);
        assert!(nfa.accepts(b""));
        assert!(nfa.accepts(b"ab"));
        assert!(nfa.accepts(b"ababab"));
        assert!(!nfa.accepts(b"aba"));
    }

    #[test]
    fn bounded_repeat() {
        // a{2,4}
        let ast = Ast::Repeat {
            node: Box::new(lit("a")),
            min: 2,
            max: Some(4),
        };
        let nfa = Nfa::from_ast(&ast);
        assert!(!nfa.accepts(b"a"));
        assert!(nfa.accepts(b"aa"));
        assert!(nfa.accepts(b"aaa"));
        assert!(nfa.accepts(b"aaaa"));
        assert!(!nfa.accepts(b"aaaaa"));
    }

    #[test]
    fn exact_repeat_and_plus() {
        // a{3}
        let ast = Ast::Repeat { node: Box::new(lit("a")), min: 3, max: Some(3) };
        let nfa = Nfa::from_ast(&ast);
        assert!(nfa.accepts(b"aaa") && !nfa.accepts(b"aa") && !nfa.accepts(b"aaaa"));
        // a+ == a{1,}
        let plus = Ast::Repeat { node: Box::new(lit("a")), min: 1, max: None };
        let nfa = Nfa::from_ast(&plus);
        assert!(!nfa.accepts(b"") && nfa.accepts(b"a") && nfa.accepts(b"aaaa"));
    }

    #[test]
    fn empty_language() {
        let nfa = Nfa::from_ast(&Ast::Empty);
        assert!(!nfa.accepts(b"") && !nfa.accepts(b"a"));
        let nfa = Nfa::from_ast(&Ast::Epsilon);
        assert!(nfa.accepts(b"") && !nfa.accepts(b"a"));
    }

    #[test]
    fn motivating_example_a_star_b_c_star() {
        // a*bc* — the paper's Fig. 1 DFA
        let ast = Ast::Concat(vec![
            Ast::Repeat { node: Box::new(lit("a")), min: 0, max: None },
            lit("b"),
            Ast::Repeat { node: Box::new(lit("c")), min: 0, max: None },
        ]);
        let nfa = Nfa::from_ast(&ast);
        assert!(nfa.accepts(b"aaaaaaabcccc")); // Fig. 1(b) input
        assert!(nfa.accepts(b"b"));
        assert!(!nfa.accepts(b"ab c"[..3].as_ref()));
        assert!(!nfa.accepts(b"aacc"));
        assert!(!nfa.accepts(b"abb"));
    }
}
