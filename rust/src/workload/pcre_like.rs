//! PCRE-like benchmark suite: a curated set of realistic regex patterns
//! in the style of the PCRE library's test corpus (emails, URIs, IPs,
//! dates, identifiers, protocol tokens, virus-signature-ish byte
//! patterns), spanning the paper's DFA size range (§6: up to 512 states
//! for PCRE), plus a generator for arbitrary target sizes.

use crate::regex::compile::compile_search;
use crate::util::rng::Rng;

use super::{BenchPattern, SuiteKind};

/// Curated PCRE-style suite.  Names are stable identifiers used in
/// EXPERIMENTS.md.  Compilation is `compile_search` (contains-a-match),
/// matching the paper's membership-test usage.
pub fn pcre_suite() -> Vec<BenchPattern> {
    let patterns: &[(&str, &str)] = &[
        ("lit-short", "error"),
        ("lit-long", "segmentation fault detected"),
        ("alt-2", "cat|dog"),
        ("alt-keywords", "while|for|if|else|return|break|continue"),
        ("hex-color", "#[0-9a-fA-F]{6}"),
        ("integer", "[0-9]+"),
        ("signed-float", "[-+]?[0-9]+\\.[0-9]{1,8}"),
        ("identifier", "[A-Za-z_][A-Za-z0-9_]{2,16}"),
        ("ipv4", r"[0-9]{1,3}\.[0-9]{1,3}\.[0-9]{1,3}\.[0-9]{1,3}"),
        ("date-iso", "[0-9]{4}-[0-9]{2}-[0-9]{2}"),
        ("time-hms", "[0-2][0-9]:[0-5][0-9]:[0-5][0-9]"),
        ("email", r"[a-z0-9._]{1,16}@[a-z0-9]{1,12}(\.[a-z]{2,4}){1,2}"),
        ("uri-scheme", "(http|https|ftp)://[a-z0-9./-]{4,24}"),
        ("html-tag", "<(div|span|p|a|li)( [a-z]{2,8}=\"[^\"]{0,12}\")?>"),
        ("c-comment", r"/\*([^*]|\*[^/]){0,20}\*/"),
        ("quoted", "\"[^\"]{0,24}\""),
        ("word-pair", r"[a-z]{3,10} [a-z]{3,10}ing"),
        ("phone", r"\+?[0-9]{1,3}[- ][0-9]{3}[- ][0-9]{4}"),
        ("mac-addr", "[0-9a-f]{2}(:[0-9a-f]{2}){5}"),
        ("uuid", "[0-9a-f]{8}-[0-9a-f]{4}-[0-9a-f]{4}-[0-9a-f]{4}-[0-9a-f]{12}"),
        ("b64-run", "[A-Za-z0-9+/]{16,24}={0,2}"),
        ("sig-bytes", r"\x4d\x5a[\x00-\xff]{2}\x50\x45"),
        ("log-level", "(TRACE|DEBUG|INFO|WARN|ERROR|FATAL)"),
        ("semver", "[0-9]{1,2}\\.[0-9]{1,2}\\.[0-9]{1,2}(-alpha|-beta|-rc)?"),
        ("repeat-deep", "(ab){8,12}"),
        ("class-heavy", "[aeiou][bcdfg][hjkl][mnpq][rstv]{2,5}"),
        ("nested-alt", "((red|green|blue) (fox|dog)|(small|large) (cat|bird))"),
        ("spaced-hex", "0x[0-9a-f]{4}( 0x[0-9a-f]{4}){3}"),
        ("csv-row", "[a-z]{1,8}(,[a-z]{1,8}){4}"),
        ("path-unix", "(/[a-z0-9_.-]{1,12}){2,5}"),
    ];
    patterns
        .iter()
        .map(|(name, pat)| BenchPattern {
            name: (*name).to_string(),
            pattern: (*pat).to_string(),
            dfa: compile_search(pat)
                .unwrap_or_else(|e| panic!("pattern {name}: {e}")),
            kind: SuiteKind::Pcre,
        })
        .collect()
}

/// Generate a pattern whose minimal search DFA has roughly `target`
/// states: an alternation of distinct random literals (each literal
/// contributes ~its length in states to the trie-shaped DFA).
pub fn generate_sized(rng: &mut Rng, target: usize) -> BenchPattern {
    let alpha = b"abcdefghijklmnopqrstuvwxyz";
    let mut lits: Vec<String> = Vec::new();
    let mut budget = target.max(4);
    while budget > 0 {
        let len = rng.range_usize(4, 12).min(budget.max(4));
        let lit: String = (0..len)
            .map(|_| alpha[rng.usize_below(26)] as char)
            .collect();
        budget = budget.saturating_sub(len + 1);
        lits.push(lit);
    }
    let pattern = lits.join("|");
    let name = format!("gen-q{target}");
    BenchPattern {
        name,
        pattern: pattern.clone(),
        dfa: compile_search(&pattern).unwrap(),
            kind: SuiteKind::Pcre,
    }
}

/// A graded suite of generated DFAs covering the paper's PCRE size range
/// (|Q| up to ~512).
pub fn scaled_suite(rng: &mut Rng) -> Vec<BenchPattern> {
    [8, 16, 32, 64, 128, 256, 512]
        .iter()
        .map(|&t| generate_sized(rng, t))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_compiles_and_spans_sizes() {
        let suite = pcre_suite();
        assert!(suite.len() >= 25);
        let qs: Vec<usize> = suite.iter().map(|p| p.q()).collect();
        let max = *qs.iter().max().unwrap();
        let min = *qs.iter().min().unwrap();
        assert!(min >= 2);
        assert!(max >= 60, "largest DFA only {max} states");
        // names unique
        let mut names: Vec<&str> =
            suite.iter().map(|p| p.name.as_str()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), suite.len());
    }

    #[test]
    fn suite_dfas_behave() {
        let suite = pcre_suite();
        let by_name = |n: &str| {
            suite.iter().find(|p| p.name == n).unwrap()
        };
        assert!(by_name("ipv4").dfa.accepts_bytes(b"ping 192.168.0.1 ok"));
        assert!(!by_name("ipv4").dfa.accepts_bytes(b"ping one.two ok"));
        assert!(by_name("email").dfa.accepts_bytes(b"mail bob@example.com x"));
        assert!(by_name("log-level").dfa.accepts_bytes(b"2024 ERROR boom"));
        assert!(!by_name("log-level").dfa.accepts_bytes(b"all quiet"));
    }

    #[test]
    fn generated_sizes_track_targets() {
        let mut rng = Rng::new(1234);
        for target in [16, 64, 256, 512] {
            let p = generate_sized(&mut rng, target);
            let q = p.q();
            assert!(
                q >= target / 2 && q <= target * 3 + 16,
                "target {target} got {q}"
            );
        }
    }
}
