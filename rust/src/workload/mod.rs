//! Benchmark workloads: the PCRE-like and PROSITE-like pattern suites and
//! input generators standing in for the paper's 299 PCRE patterns, 110
//! PROSITE signatures, and multi-GB inputs (§6).

pub mod input_gen;
pub mod pcre_like;
pub mod prosite_like;

use std::sync::OnceLock;

pub use input_gen::InputGen;
pub use pcre_like::pcre_suite;
pub use prosite_like::prosite_suite;

/// Cached suites (subset construction + Hopcroft on the full PROSITE
/// suite costs ~10 s; experiments and tests share one compilation).
pub fn pcre_suite_cached() -> &'static [BenchPattern] {
    static SUITE: OnceLock<Vec<BenchPattern>> = OnceLock::new();
    SUITE.get_or_init(pcre_suite)
}

/// Cached PROSITE-like suite (see [`pcre_suite_cached`]).
pub fn prosite_suite_cached() -> &'static [BenchPattern] {
    static SUITE: OnceLock<Vec<BenchPattern>> = OnceLock::new();
    SUITE.get_or_init(prosite_suite)
}

/// Which suite a benchmark pattern belongs to (decides the realistic
/// input distribution: protein residues vs ASCII text).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SuiteKind {
    /// PCRE-like text patterns
    Pcre,
    /// PROSITE protein signatures
    Prosite,
}

/// A named benchmark pattern compiled to its minimal search DFA.
#[derive(Clone, Debug)]
pub struct BenchPattern {
    /// suite-unique name
    pub name: String,
    /// source pattern text
    pub pattern: String,
    /// compiled minimal search DFA
    pub dfa: crate::automata::Dfa,
    /// which suite it belongs to
    pub kind: SuiteKind,
}

impl BenchPattern {
    /// |Q| of the compiled DFA.
    pub fn q(&self) -> usize {
        self.dfa.num_states as usize
    }

    /// A realistic dense-symbol input stream for this pattern: protein
    /// residues for PROSITE signatures, log-like ASCII for PCRE.  (A
    /// uniform stream over *all* symbol classes would constantly hit the
    /// catch-all class that kills protein matches — input the real
    /// workloads never contain.)
    pub fn input_syms(&self, gen: &mut InputGen, n: usize) -> Vec<u32> {
        let bytes = match self.kind {
            SuiteKind::Prosite => gen.protein(n),
            SuiteKind::Pcre => gen.ascii_text(n),
        };
        self.dfa.map_input(&bytes)
    }
}
