//! PROSITE-like benchmark suite: real protein signatures from the PROSITE
//! database (public patterns, PA lines) plus a generator for the large
//! gap-heavy patterns that drive DFA sizes to the paper's |Q| ≈ 1288.

use crate::regex::compile::compile_prosite;
use crate::regex::prosite::AMINO_ACIDS;
use crate::util::rng::Rng;

use super::{BenchPattern, SuiteKind};

/// Real PROSITE signatures (PA lines of well-known entries).
pub fn prosite_suite() -> Vec<BenchPattern> {
    let patterns: &[(&str, &str)] = &[
        // classic short signatures
        ("PS00016-RGD", "R-G-D."),
        ("PS00001-ASN-GLYC", "N-{P}-[ST]-{P}."),
        ("PS00004-CAMP-PHOSPHO", "[RK](2)-x-[ST]."),
        ("PS00005-PKC-PHOSPHO", "[ST]-x-[RK]."),
        ("PS00006-CK2-PHOSPHO", "[ST]-x(2)-[DE]."),
        ("PS00008-MYRISTYL", "G-{EDRKHPFYW}-x(2)-[STAGCN]-{P}."),
        // Gap widths of the largest signatures are reduced so search-DFA
        // sizes stay inside the paper's observed range (max 1288 states;
        // full-width x(6)/x(8) gaps explode the Sigma*-wrapped DFA to
        // >12k states, which the paper's Grail+ pipeline never produced).
        // The structural character (bounded gaps between anchors) is
        // preserved.  See DESIGN.md §Substitutions.
        ("PS00029-LEUCINE-ZIPPER", "L-x(4)-L-x(4)-L-x(4)-L."),
        ("PS00017-ATP-GTP-A", "[AG]-x(4)-G-K-[ST]."),
        // zinc fingers / metal binding
        ("PS00028-ZINC-FINGER-C2H2",
         "C-x(2,4)-C-x(3)-[LIVMFYWC]-x(4)-H-x(3,5)-H."),
        ("PS00190-CYTOCHROME-C", "C-{CPWHF}-{CPWR}-C-H-{CFYW}."),
        // enzyme active sites
        ("PS00102-PROT-KINASE-TYR",
         "[LIVMFYC]-{A}-[HY]-x-D-[LIVMFY]-[RSTAC]-{D}-{PF}-N-[LIVMFYC](3)."),
        ("PS00107-PROT-KINASE-ATP",
         "[LIV]-G-{P}-G-{P}-[FYWMGSTNH]-[SGA]-{PW}-[LIVCAT]-{PD}-x-[GSTACLIVMFY]-x(5,9)-[LIVMFYWCSTAR]-[AIVP]-[LIVMFAGCKR]-K."),
        ("PS00134-TRYPSIN-HIS", "[LIVM]-[ST]-A-[STAG]-H-C."),
        ("PS00135-TRYPSIN-SER",
         "[DNSTAGC]-[GSTAPIMVQH]-x(2)-G-[DE]-S-G-[GS]-[SAPHV]-[LIVMFYWH]-[LIVMFYSTANQH]."),
        ("PS00136-SUBTILASE-ASP",
         "[STAIV]-{ERDL}-[LIVMF]-[LIVM]-D-[DSTA]-G-[LIVMFC]-x(2,3)-[DNH]."),
        // structural / binding motifs
        ("PS00018-EF-HAND",
         "D-x-[DNS]-{ILVFYW}-[DENSTG]-[DNQGHRK]-{GP}-[LIVMC]-[DENQSTAGC]-x(2)-[DE]-[LIVMFYW]."),
        ("PS00022-EGF-1",
         "C-x-C-x(2)-[GP]-[FYW]-x(4,8)-C."),
        ("PS01186-EGF-2",
         "C-x-C-x(5)-G-x(2)-C."),
        ("PS00211-ABC-TRANSPORTER",
         "[LIVMFYC]-[SA]-[SAPGLVFYKQH]-G-[DENQMW]-[KRQASPCLIMFW]-[KRNQSTAVM]-[KRACLVM]-[LIVMFYPAN]-{PHY}-[LIVMFW]-[SAGCLIVP]-{FYWHP}-{KRHP}-[LIVMFYWSTA]."),
        ("PS00213-LIPOCALIN",
         "[DENG]-{A}-[DENQGSTARK]-x(0,2)-[DENQARK]-[LIVFY]-{CP}-G-{C}-W-[FYWLRH]-x-[LIVMTA]."),
        // longer, gap-heavy signatures (drive |Q| up)
        ("PS00079-MULTICOPPER-OXIDASE",
         "G-x-[FYW]-x-[LIVMFYW]-x-[CST]-x(8)-G-[LM]-x(3)-[LIVMFYW]."),
        ("PS00198-4FE4S-FERREDOXIN",
         "C-x(2)-C-x(2)-C-x(3)-C-[PEG]."),
        ("PS00298-HSP70",
         "[IV]-D-L-G-T-[ST]-x-[SC]."),
        ("PS00301-G-PROTEIN-RECEP-F1",
         "[GSTALIVMFYWC]-[GSTANCPDE]-{EDPKRH}-x(2)-[LIVMNQGA]-x(2)-[LIVMFT]-[GSTANC]-[LIVMFYWSTAC]-[DENH]-R-[FYWCSH]-x(2)-[LIVM]."),
        ("PS00338-GH-FAMILY",
         "C-x-[STAGV]-x(2)-[LIVMFYWS]-x(2)-[LIVMSTA]-x(2,3)-[LIVMFYW]-x(2)-[STACV]-W."),
        ("PS00675-SIGMA54-INTERACT",
         "[LIVMFY]-x-[LIVMFYC]-[DE]-E-[LIVMFYWGAT]-[GH]-x(2)-[SGDE]."),
        ("PS00716-DEAD-BOX", "[LIVMF](2)-D-E-A-D-[RKEN]-x-[LIVMFYGSTN]."),
        ("PS00761-CLP-PROTEASE",
         "[LIVM]-x-[FL]-[LIVM](2)-[DEQSTHKNA]-[QEK]-[LIVMFYT]-[DENTAS]-[RHSGNKQ]."),
        ("PS01030-ABC-TAP-LIKE",
         "C-x(2,3)-C-x(3)-[LIVMFYWC]-x(4,6)-H-x(3,4)-[HC]."),
        ("PS00870-LACTALBUMIN",
         "K-x(2)-[FYWHI]-x(2)-[SGAEQKDV]-x(3)-[LIVMFSTC]-x(2)-[LIVMFYW]-x(2)-[DENQKRHS]."),
    ];
    patterns
        .iter()
        .map(|(name, pat)| BenchPattern {
            name: (*name).to_string(),
            pattern: (*pat).to_string(),
            dfa: compile_prosite(pat)
                .unwrap_or_else(|e| panic!("pattern {name}: {e}")),
            kind: SuiteKind::Prosite,
        })
        .collect()
}

/// Generate a PROSITE-style pattern targeting large DFAs: alternating
/// residue sets and bounded x-gaps (gaps multiply subset-construction
/// state counts — the mechanism behind the paper's 1288-state PROSITE
/// DFAs).
pub fn generate_gapped(rng: &mut Rng, elements: usize) -> BenchPattern {
    let mut parts: Vec<String> = Vec::new();
    for _ in 0..elements {
        match rng.below(4) {
            0 => {
                let aa = AMINO_ACIDS[rng.usize_below(20)] as char;
                parts.push(aa.to_string());
            }
            1 => {
                let k = rng.range_usize(2, 5);
                let set: String = (0..k)
                    .map(|_| AMINO_ACIDS[rng.usize_below(20)] as char)
                    .collect();
                parts.push(format!("[{set}]"));
            }
            2 => {
                let lo = rng.range_usize(1, 4);
                let hi = lo + rng.range_usize(1, 4);
                parts.push(format!("x({lo},{hi})"));
            }
            _ => {
                let n = rng.range_usize(2, 6);
                parts.push(format!("x({n})"));
            }
        }
    }
    let pattern = format!("{}.", parts.join("-"));
    BenchPattern {
        name: format!("gen-prosite-{elements}"),
        pattern: pattern.clone(),
        dfa: compile_prosite(&pattern).unwrap(),
            kind: SuiteKind::Prosite,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_compiles_with_paper_size_range() {
        let suite = prosite_suite();
        assert!(suite.len() >= 25);
        let max = suite.iter().map(|p| p.q()).max().unwrap();
        // the paper reports PROSITE DFAs up to 1288 states
        assert!(max >= 1000, "largest PROSITE DFA only {max} states");
        // the vector-unit artifact pads tables to 1536 states
        assert!(max <= 1536, "PROSITE DFA too large: {max}");
    }

    #[test]
    fn rgd_and_nglyc_semantics() {
        let suite = prosite_suite();
        let rgd = suite.iter().find(|p| p.name == "PS00016-RGD").unwrap();
        assert!(rgd.dfa.accepts_bytes(b"MKLRGDSTV"));
        assert!(!rgd.dfa.accepts_bytes(b"MKLRGESTV"));
        let ng = suite.iter().find(|p| p.name == "PS00001-ASN-GLYC").unwrap();
        assert!(ng.dfa.accepts_bytes(b"AANCSAA"));
        assert!(!ng.dfa.accepts_bytes(b"AANPSAA"));
    }

    #[test]
    fn generated_gapped_grows() {
        let mut rng = Rng::new(55);
        let small = generate_gapped(&mut rng, 4);
        let large = generate_gapped(&mut rng, 16);
        assert!(large.q() > small.q());
    }
}
