//! Input corpus generators: random text over a DFA's alphabet, protein
//! sequences with realistic residue frequencies, and planted-match
//! inputs.  Deterministic (seeded) so experiments replay exactly.

use crate::automata::Dfa;
use crate::util::rng::Rng;

/// Seeded input corpus generator.
pub struct InputGen {
    rng: Rng,
}

/// SwissProt-ish amino-acid frequencies (percent ×10, summing to ~1000).
const AA_FREQ: [(u8, u32); 20] = [
    (b'A', 83), (b'C', 14), (b'D', 55), (b'E', 67), (b'F', 39),
    (b'G', 71), (b'H', 23), (b'I', 59), (b'K', 58), (b'L', 97),
    (b'M', 24), (b'N', 41), (b'P', 47), (b'Q', 39), (b'R', 55),
    (b'S', 67), (b'T', 54), (b'V', 69), (b'W', 11), (b'Y', 29),
];

impl InputGen {
    /// A generator with the given seed.
    pub fn new(seed: u64) -> InputGen {
        InputGen { rng: Rng::new(seed) }
    }

    /// Uniform random dense symbols for a given DFA.
    pub fn uniform_syms(&mut self, dfa: &Dfa, n: usize) -> Vec<u32> {
        (0..n)
            .map(|_| self.rng.below(dfa.num_symbols as u64) as u32)
            .collect()
    }

    /// Random ASCII text over a printable alphabet (log-file-ish).
    pub fn ascii_text(&mut self, n: usize) -> Vec<u8> {
        const CHARS: &[u8] =
            b"abcdefghijklmnopqrstuvwxyz0123456789 .,:-_/\n";
        (0..n).map(|_| CHARS[self.rng.usize_below(CHARS.len())]).collect()
    }

    /// Protein sequence with SwissProt-like residue frequencies.
    pub fn protein(&mut self, n: usize) -> Vec<u8> {
        let total: u32 = AA_FREQ.iter().map(|&(_, f)| f).sum();
        (0..n)
            .map(|_| {
                let mut pick = self.rng.below(total as u64) as u32;
                for &(aa, f) in &AA_FREQ {
                    if pick < f {
                        return aa;
                    }
                    pick -= f;
                }
                b'L'
            })
            .collect()
    }

    /// Plant `occurrences` of `needle` at random positions in `base`.
    pub fn plant(&mut self, base: &mut [u8], needle: &[u8], occurrences: usize) {
        if needle.len() > base.len() {
            return;
        }
        for _ in 0..occurrences {
            let pos = self.rng.usize_below(base.len() - needle.len() + 1);
            base[pos..pos + needle.len()].copy_from_slice(needle);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::regex::prosite::AMINO_ACIDS;
    use crate::regex::compile::compile_search;

    #[test]
    fn deterministic_by_seed() {
        let a = InputGen::new(7).ascii_text(100);
        let b = InputGen::new(7).ascii_text(100);
        assert_eq!(a, b);
        let c = InputGen::new(8).ascii_text(100);
        assert_ne!(a, c);
    }

    #[test]
    fn uniform_syms_in_range() {
        let dfa = compile_search("abc").unwrap();
        let syms = InputGen::new(1).uniform_syms(&dfa, 1000);
        assert!(syms.iter().all(|&s| s < dfa.num_symbols));
    }

    #[test]
    fn protein_uses_amino_alphabet() {
        let seq = InputGen::new(2).protein(5000);
        assert!(seq.iter().all(|b| AMINO_ACIDS.contains(b)));
        // leucine should be the most common residue
        let count = |aa: u8| seq.iter().filter(|&&b| b == aa).count();
        assert!(count(b'L') > count(b'W'));
    }

    #[test]
    fn planting_makes_matches() {
        let dfa = compile_search("needle").unwrap();
        let mut gen = InputGen::new(3);
        let mut text = gen.ascii_text(10_000);
        assert!(!dfa.accepts_bytes(&text) || true); // may match by chance
        gen.plant(&mut text, b"needle", 3);
        assert!(dfa.accepts_bytes(&text));
    }
}
