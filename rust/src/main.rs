//! specdfa CLI — leader entrypoint for the speculative parallel DFA
//! membership test.
//!
//! Subcommands (hand-rolled parser; the build is offline, no clap):
//!   match       run a membership test on a file or generated input
//!   analyze     static hazard analysis (ReDoS lints, speculation
//!               feasibility, fuse-blowup prediction, protocol FSM check)
//!   serve       run the async batched serving loop on a request stream
//!   bench       time the kernel tiers / engines, emit BENCH JSON
//!   experiment  regenerate a paper table/figure (or `all`)
//!   suite       show the benchmark suites with structural properties
//!   profile     print host calibration (measured symbol rate)
//!   grail       run a DFA given in Grail+ format
//!   simd        run the PJRT vector-unit matcher
//!   cloud       run the simulated-EC2 matcher
//!   cluster     run the real multi-process cluster (with fault injection)
//!   worker      cluster worker process (spawned by `cluster`, not by hand)

use std::process::ExitCode;
use std::sync::Arc;

use specdfa::analysis::{analyze_patterns, render_analysis_json};
use specdfa::automata::{grail, FlatDfa, Width};
use specdfa::cluster::proc::{run_worker, Transport, WorkerConfig};
use specdfa::cluster::{
    CloudMatcher, ClusterSpec, FaultPlan, ProcCluster, ProcConfig,
};
use specdfa::engine::{
    Admission, CompiledMatcher, CompiledSetMatcher, Engine, ExecPolicy,
    Matcher, Pattern, PatternSet, PriorityPolicy, ServeConfig, Server,
    SetConfig, SetTier, StreamMatcher,
};
use specdfa::experiments;
use specdfa::regex::compile::{
    compile_exact, compile_prosite, compile_search,
};
use specdfa::runtime::pjrt::VectorUnit;
use specdfa::runtime::simd::SimdMatcher;
use specdfa::speculative::lookahead::Lookahead;
use specdfa::speculative::matcher::MatchPlan;
use specdfa::engine::select::{AutoThresholds, DfaProps};
use specdfa::util::bench::{
    percentile, render_bench_json, time_median, time_once, BenchRecord,
    Table,
};
use specdfa::util::rng::Rng;
use specdfa::util::workload;
use specdfa::workload::{pcre_suite_cached, prosite_suite_cached, InputGen};
use specdfa::{Dfa, SequentialMatcher};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let result = match args.first().map(String::as_str) {
        Some("match") => cmd_match(&args[1..]),
        Some("analyze") => cmd_analyze(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("bench") => cmd_bench(&args[1..]),
        Some("experiment") => cmd_experiment(&args[1..]),
        Some("suite") => cmd_suite(&args[1..]),
        Some("profile") => cmd_profile(),
        Some("grail") => cmd_grail(&args[1..]),
        Some("simd") => cmd_simd(&args[1..]),
        Some("cloud") => cmd_cloud(&args[1..]),
        Some("cluster") => cmd_cluster(&args[1..]),
        Some("worker") => cmd_worker(&args[1..]),
        Some("help") | None => {
            print_usage();
            Ok(())
        }
        Some(other) => {
            eprintln!("unknown command {other:?}");
            print_usage();
            return ExitCode::FAILURE;
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn print_usage() {
    println!(
        "specdfa — speculative parallel DFA membership test\n\
         \n\
         USAGE:\n\
         \x20 specdfa match   (--regex PAT | --prosite PAT | \
         --patterns FILE) [--file F | --gen N]\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 \
         [--engine auto|seq|spec|simd|cloud|shard|holub|backtrack|grep]\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 \
         [--procs P] [--lookahead R] [--nodes K] [--batch B]\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 \
         (--patterns: one regex per line, '-' for stdin; fused \
         multi-pattern\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 \
         \x20matching with [--state-budget Q] [--no-prefilter])\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 \
         [--stream [--segment-bytes S]]   (feed stdin / --file \
         incrementally\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 \
         \x20through the checkpointable segment matcher)\n\
         \x20 specdfa analyze (--pattern PAT)* (--prosite PAT)* \
         [--patterns FILE|-]\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 \
         [--lookahead R] [--procs P] [--gamma-max G] \
         [--state-budget Q]\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 \
         [--json PATH]   (static hazard report; JSON schema \
         specdfa-analysis-v1)\n\
         \x20 specdfa serve   [--workers N] [--cache M] [--batch B] \
         [--recalibrate K]\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 \
         [--max-queue D] [--admission block|reject] \
         [--priority fifo|size]\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 \
         [--age-limit A] [--probe-bytes P]\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 \
         [--requests FILE|-]   (TAB-separated lines: \
         KIND PATTERN INPUT;\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 \
         \x20KIND: regex|regex-exact|prosite; INPUT: text, @file, or \
         gen:N)\n\
         \x20 specdfa bench   [--suite \
         kernels|engines|serve|patternset|stream|adversarial|\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 \
         \x20cluster|all]\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 \
         [--quick] [--json PATH]\n\
         \x20 specdfa experiment <name>|all      names: {}\n\
         \x20 specdfa suite   [pcre|prosite]\n\
         \x20 specdfa profile\n\
         \x20 specdfa grail   <dfa-file> [--gen N]\n\
         \x20 specdfa simd    (--regex PAT | --prosite PAT) [--gen N] \
         [--variant V] [--lookahead R]\n\
         \x20 specdfa cloud   (--regex PAT | --prosite PAT) [--gen N] \
         [--nodes K] [--lookahead R]\n\
         \x20 specdfa cluster [--workers N] [--regex PAT] [--n BYTES] \
         [--requests K]\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 \
         [--fault-plan SPEC] [--tcp]   (SPEC: `wK:PLAN;...`, PLAN e.g.\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 \
         \x20kill@BYTES, drop=KIND[:N], trunc=KIND[:N], delay=MS, stall)\n\
         \x20 specdfa worker  --connect ADDR --id K [--fault PLAN] \
         (internal)",
        experiments::ALL.join(" ")
    );
}

/// Flags that take no value (presence == true); everything else is a
/// --key value pair.
const BOOL_FLAGS: &[&str] = &["quick", "no-prefilter", "stream", "tcp"];

/// Minimal flag parser: --key value pairs, plus valueless [`BOOL_FLAGS`].
fn flags(args: &[String]) -> anyhow::Result<Vec<(String, String)>> {
    let mut out = Vec::new();
    let mut it = args.iter();
    while let Some(k) = it.next() {
        let Some(key) = k.strip_prefix("--") else {
            anyhow::bail!("expected --flag, got {k:?}");
        };
        if BOOL_FLAGS.contains(&key) {
            out.push((key.to_string(), "true".to_string()));
            continue;
        }
        let v = it
            .next()
            .ok_or_else(|| anyhow::anyhow!("--{key} needs a value"))?;
        out.push((key.to_string(), v.clone()));
    }
    Ok(out)
}

fn get<'a>(fl: &'a [(String, String)], key: &str) -> Option<&'a str> {
    fl.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
}

fn has_flag(fl: &[(String, String)], key: &str) -> bool {
    get(fl, key).is_some()
}

/// All values of a repeatable flag, in command-line order.
fn get_all<'a>(fl: &'a [(String, String)], key: &str) -> Vec<&'a str> {
    fl.iter().filter(|(k, _)| k == key).map(|(_, v)| v.as_str()).collect()
}

fn compile_from_flags(
    fl: &[(String, String)],
) -> anyhow::Result<specdfa::Dfa> {
    match (get(fl, "regex"), get(fl, "prosite")) {
        (Some(pat), None) => compile_search(pat),
        (None, Some(pat)) => compile_prosite(pat),
        _ => anyhow::bail!("need exactly one of --regex / --prosite"),
    }
}

fn input_from_flags(
    fl: &[(String, String)],
    dfa: &specdfa::Dfa,
    protein: bool,
) -> anyhow::Result<Vec<u8>> {
    if let Some(path) = get(fl, "file") {
        return Ok(std::fs::read(path)?);
    }
    let n: usize = get(fl, "gen").unwrap_or("1000000").parse()?;
    let mut gen = InputGen::new(0xC11);
    Ok(if protein {
        gen.protein(n)
    } else {
        let syms = gen.uniform_syms(dfa, n);
        // map symbols back through representative bytes
        let mut reps = vec![b'?'; dfa.num_symbols as usize];
        for b in (0..=255u8).rev() {
            reps[dfa.class_of(b) as usize] = b;
        }
        syms.into_iter().map(|s| reps[s as usize]).collect()
    })
}

fn cmd_match(args: &[String]) -> anyhow::Result<()> {
    let fl = flags(args)?;
    if let Some(source) = get(&fl, "patterns") {
        anyhow::ensure!(
            get(&fl, "regex").is_none() && get(&fl, "prosite").is_none(),
            "--patterns replaces --regex / --prosite"
        );
        return cmd_match_patterns(&fl, source);
    }
    let pattern = match (get(&fl, "regex"), get(&fl, "prosite")) {
        (Some(p), None) => Pattern::Regex(p.to_string()),
        (None, Some(p)) => Pattern::Prosite(p.to_string()),
        _ => anyhow::bail!("need exactly one of --regex / --prosite"),
    };
    let procs: usize = get(&fl, "procs").unwrap_or("8").parse()?;
    let r: usize = get(&fl, "lookahead").unwrap_or("4").parse()?;
    let nodes: usize = get(&fl, "nodes").unwrap_or("4").parse()?;
    let batch: usize = get(&fl, "batch").unwrap_or("1").parse()?;
    anyhow::ensure!(batch >= 1, "--batch must be >= 1");
    let mut engine = Engine::parse(get(&fl, "engine").unwrap_or("auto"))?;
    if let Engine::Cloud { nodes: n } | Engine::Shard { nodes: n } =
        &mut engine
    {
        *n = nodes;
    }

    let policy = ExecPolicy {
        processors: procs,
        lookahead: r,
        cloud_nodes: nodes,
        ..ExecPolicy::default()
    };
    let cm = CompiledMatcher::compile(&pattern, engine.clone(), policy)?;
    println!("{}", cm.describe());

    if has_flag(&fl, "stream") {
        anyhow::ensure!(batch == 1, "--stream and --batch are exclusive");
        return cmd_match_stream(&fl, &cm);
    }

    let dfa = cm.dfa().clone();
    let input = input_from_flags(&fl, &dfa, get(&fl, "prosite").is_some())?;

    if batch > 1 {
        // split the input into `batch` requests through match_many — the
        // serving path (plan construction amortized across the batch)
        let chunk = input.len().div_ceil(batch).max(1);
        let inputs: Vec<&[u8]> = input.chunks(chunk).collect();
        let out = cm.match_many(&inputs);
        println!(
            "batch: {} requests, {} total symbols, {:.1} ms wall \
             ({:.0} syms/s)",
            out.outcomes.len(),
            out.total_syms,
            out.wall_s * 1e3,
            out.syms_per_sec()
        );
        for (kind, count) in out.by_engine() {
            println!("  {count:>4} request(s) -> {kind}");
        }
        for err in out.errors() {
            println!("  failed: {err}");
        }
        println!(
            "accepted: {} of {} ({} failed)",
            out.accepted_count(),
            out.outcomes.len(),
            out.error_count()
        );
        return Ok(());
    }

    let out = cm.run_bytes(&input)?;
    if let Some(sel) = &out.selection {
        println!("auto selected {sel}");
    }

    // failure-freedom check against the sequential yardstick
    let seq = SequentialMatcher::new(&dfa).run_bytes(&input);
    anyhow::ensure!(out.accepted == seq.accepted, "failure-freedom violated!");
    if let Some(fs) = out.final_state {
        anyhow::ensure!(
            fs == seq.final_state,
            "failure-freedom violated: state {fs} != {}",
            seq.final_state
        );
    }
    println!(
        "match: {} via {} (n={}, P={procs}, r={r})",
        out.accepted,
        out.engine,
        input.len()
    );
    println!(
        "work: makespan {} vs sequential {} syms -> model speedup {:.2}x \
         (overhead {} syms, wall {:.1} ms)",
        out.makespan,
        input.len(),
        out.model_speedup(),
        out.overhead_syms,
        out.wall_s * 1e3
    );
    Ok(())
}

/// `specdfa match --stream`: feed the input through the checkpointable
/// segment matcher ([`StreamMatcher`]) — stdin by default, `--file F`
/// to stream a file — in `--segment-bytes` reads.  Memory stays
/// constant whatever the stream length: each segment folds into the
/// composed L-vector and is dropped.
fn cmd_match_stream(
    fl: &[(String, String)],
    cm: &CompiledMatcher,
) -> anyhow::Result<()> {
    use std::io::Read;
    anyhow::ensure!(
        get(fl, "gen").is_none(),
        "--stream reads stdin or --file, not --gen"
    );
    let seg: usize = get(fl, "segment-bytes").unwrap_or("65536").parse()?;
    anyhow::ensure!(seg >= 1, "--segment-bytes must be >= 1");
    let mut src: Box<dyn Read> = match get(fl, "file") {
        Some(path) => Box::new(std::fs::File::open(path)?),
        None => Box::new(std::io::stdin()),
    };
    let mut sm = StreamMatcher::new(cm);
    let mut buf = vec![0u8; seg];
    let mut segments = 0u64;
    loop {
        // fill a whole segment per feed (short reads are common on
        // pipes); a short fill means end of stream
        let mut filled = 0;
        while filled < seg {
            let k = src.read(&mut buf[filled..])?;
            if k == 0 {
                break;
            }
            filled += k;
        }
        if filled == 0 {
            break;
        }
        sm.feed(&buf[..filled]);
        segments += 1;
        if filled < seg {
            break;
        }
    }
    let ckpt_bytes = sm.checkpoint().to_bytes().len();
    let out = sm.finish();
    println!(
        "stream match: {} via {} (n={}, {segments} segment(s) of \
         <= {seg} B, checkpoint {ckpt_bytes} B, wall {:.1} ms)",
        out.accepted,
        out.engine,
        out.n,
        out.wall_s * 1e3
    );
    Ok(())
}

/// `specdfa match --patterns FILE`: fused multi-pattern matching through
/// the set engine.  FILE holds one regex per line (`-` = stdin); blank
/// lines and `#` comments are skipped.  One input pass answers every
/// pattern, with per-pattern verdicts and tier/counter telemetry.
fn cmd_match_patterns(
    fl: &[(String, String)],
    source: &str,
) -> anyhow::Result<()> {
    let text = if source == "-" {
        let mut buf = String::new();
        std::io::Read::read_to_string(&mut std::io::stdin(), &mut buf)?;
        buf
    } else {
        std::fs::read_to_string(source)?
    };
    let mut set = PatternSet::new();
    let mut sources: Vec<String> = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        set.push(Pattern::Regex(line.to_string()));
        sources.push(line.to_string());
    }
    anyhow::ensure!(!set.is_empty(), "{source}: no patterns found");

    let procs: usize = get(fl, "procs").unwrap_or("8").parse()?;
    let r: usize = get(fl, "lookahead").unwrap_or("4").parse()?;
    let engine = Engine::parse(get(fl, "engine").unwrap_or("auto"))?;
    let defaults = SetConfig::default();
    let state_budget: usize = match get(fl, "state-budget") {
        Some(v) => v.parse()?,
        None => defaults.state_budget,
    };
    let config = SetConfig {
        engine,
        policy: ExecPolicy {
            processors: procs,
            lookahead: r,
            ..ExecPolicy::default()
        },
        state_budget,
        prefilter: !has_flag(fl, "no-prefilter"),
    };
    let csm = CompiledSetMatcher::compile(&set, config)?;
    println!("{}", csm.describe());

    let input = if let Some(path) = get(fl, "file") {
        std::fs::read(path)?
    } else {
        let n: usize = get(fl, "gen").unwrap_or("1000000").parse()?;
        InputGen::new(0xC11).ascii_text(n)
    };
    let out = csm.run_bytes(&input)?;

    for (slot, (o, tier)) in
        out.outcomes.iter().zip(out.tiers.iter()).enumerate()
    {
        let tier = match tier {
            SetTier::PrefilterCleared => "prefilter",
            SetTier::Fused => "fused",
            SetTier::Spilled => "spilled",
        };
        println!(
            "pattern {slot}: accepted={} [{tier}] {}",
            o.accepted, sources[slot]
        );
    }
    println!(
        "set: {} pattern(s) ({} unique, {} fused, {} spilled, \
         {} prefiltered); fused passes {}, prefilter cleared {}; \
         n={}, wall {:.1} ms",
        out.n,
        csm.unique_patterns(),
        csm.fused_patterns(),
        csm.spilled_patterns(),
        csm.prefiltered_patterns(),
        usize::from(out.fused_pass.is_some()),
        out.prefilter_cleared,
        input.len(),
        out.wall_s * 1e3
    );
    if let Some(q) = csm.product_states() {
        println!("fused product DFA: |Q| = {q} (budget {state_budget})");
    }
    Ok(())
}

/// `specdfa analyze`: the static hazard analyzer — every pass runs
/// before anything executes.  Lints each pattern's AST for the ReDoS
/// ambiguity family, reports the compiled DFA's structure and
/// speculation feasibility (γ and the Eq. 18 chunk-overhead model),
/// bounds the fused product size for multi-pattern sets, and checks the
/// cluster session FSM.  `--json PATH` writes the versioned
/// `specdfa-analysis-v1` record that CI schema-validates.
fn cmd_analyze(args: &[String]) -> anyhow::Result<()> {
    let fl = flags(args)?;
    let mut patterns: Vec<Pattern> = Vec::new();
    for p in get_all(&fl, "pattern") {
        patterns.push(Pattern::Regex(p.to_string()));
    }
    for p in get_all(&fl, "prosite") {
        patterns.push(Pattern::Prosite(p.to_string()));
    }
    if let Some(source) = get(&fl, "patterns") {
        let text = if source == "-" {
            let mut buf = String::new();
            std::io::Read::read_to_string(&mut std::io::stdin(), &mut buf)?;
            buf
        } else {
            std::fs::read_to_string(source)?
        };
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            patterns.push(Pattern::Regex(line.to_string()));
        }
    }
    anyhow::ensure!(
        !patterns.is_empty(),
        "nothing to analyze: need --pattern, --prosite or --patterns FILE"
    );

    let r: usize = get(&fl, "lookahead").unwrap_or("4").parse()?;
    let procs: usize = get(&fl, "procs").unwrap_or("8").parse()?;
    let gamma_max: f64 = match get(&fl, "gamma-max") {
        Some(v) => v.parse()?,
        None => AutoThresholds::default().gamma_max,
    };
    let state_budget: usize = match get(&fl, "state-budget") {
        Some(v) => v.parse()?,
        None => SetConfig::default().state_budget,
    };

    let report =
        analyze_patterns(&patterns, r, procs, gamma_max, state_budget)?;

    for (i, p) in report.patterns.iter().enumerate() {
        println!("pattern {i} ({}): {}", p.regex.kind, p.regex.pattern);
        if p.regex.hazards.is_empty() {
            println!("  hazards: none");
        }
        for h in &p.regex.hazards {
            println!(
                "  hazard: {} [{} blowup] {}",
                h.kind.name(),
                h.kind.severity(),
                h.detail
            );
        }
        let f = &p.regex.facts;
        println!(
            "  facts: ast {} node(s), repeat depth {}, {} unbounded \
             repeat(s), {} alternation(s), anchors {}{}, literal {}",
            f.ast_size,
            f.repeat_depth,
            f.unbounded_repeats,
            f.alternations,
            if f.anchored_start { "^" } else { "-" },
            if f.anchored_end { "$" } else { "-" },
            match &f.required_literal {
                Some(l) => format!("{:?}", String::from_utf8_lossy(l)),
                None => "none".to_string(),
            }
        );
        let d = &p.dfa;
        println!(
            "  dfa: |Q|={} |Sigma|={} I_max,{}={} gamma={:.3} \
             minimal |Q|={} (gap {}), {} dead, {} unreachable, sink {}",
            d.q,
            d.sigma,
            d.r,
            d.i_max,
            d.gamma,
            d.minimal_q,
            d.minimality_gap,
            d.dead_states,
            d.unreachable_states,
            match d.sink_state {
                Some(s) => s.to_string(),
                None => "none".to_string(),
            }
        );
        println!(
            "  feasibility: {} (gamma_max {}, predicted speedup \
             {:.2}x at P={}, chunk overhead {:.1} syms)",
            d.feasibility.name(),
            report.gamma_max,
            d.predicted_speedup,
            report.processors,
            d.chunk_overhead
        );
    }
    if let Some(f) = &report.fuse {
        println!(
            "fuse: {} component(s) {:?} -> product |Q| in \
             [{}, {}], {} combined class(es), budget {} -> {}",
            f.components,
            f.component_states,
            f.certain_min,
            f.upper_bound,
            f.combined_classes,
            f.budget,
            if f.predicted_overflow {
                "predicted overflow (patternset skips the fuse attempt)"
            } else {
                "may fit"
            }
        );
        if let Some(d) = report.literals_disjoint {
            println!(
                "fuse: required literals pairwise disjoint: {d} \
                 (disjoint sets rarely co-fire the fused accept check)"
            );
        }
    }
    println!(
        "proto: {} state(s), {} transition(s), {} arrival kind(s) -> {}",
        report.proto.states,
        report.proto.transitions,
        report.proto.arrivals,
        if report.proto.ok() { "ok" } else { "UNSAFE" }
    );
    for problem in &report.proto.problems {
        println!("  proto problem: {problem}");
    }
    println!(
        "analyzed {} pattern(s): {} hazardous",
        report.patterns.len(),
        report.hazardous()
    );

    if let Some(path) = get(&fl, "json") {
        std::fs::write(path, render_analysis_json(&report))?;
        println!("wrote analysis record to {path}");
    }
    Ok(())
}

/// One request line of the serve stream: `KIND \t PATTERN \t INPUT`.
/// KIND: regex | regex-exact | prosite.  INPUT: literal text, `@path`
/// (read bytes from a file), or `gen:N` (N seeded random ASCII bytes).
fn parse_request_line(
    line: &str,
    lineno: usize,
) -> anyhow::Result<(Pattern, Vec<u8>)> {
    let mut parts = line.splitn(3, '\t');
    let (kind, pat, input) =
        match (parts.next(), parts.next(), parts.next()) {
            (Some(k), Some(p), Some(i)) => (k, p, i),
            _ => anyhow::bail!(
                "line {lineno}: expected KIND<TAB>PATTERN<TAB>INPUT"
            ),
        };
    let pattern = match kind {
        "regex" => Pattern::Regex(pat.to_string()),
        "regex-exact" => Pattern::RegexExact(pat.to_string()),
        "prosite" => Pattern::Prosite(pat.to_string()),
        other => anyhow::bail!(
            "line {lineno}: unknown kind {other:?} \
             (expected regex|regex-exact|prosite)"
        ),
    };
    let bytes = if let Some(path) = input.strip_prefix('@') {
        std::fs::read(path)?
    } else if let Some(n) = input.strip_prefix("gen:") {
        let n: usize = n.parse()?;
        InputGen::new(0x5E1D ^ lineno as u64).ascii_text(n)
    } else {
        input.as_bytes().to_vec()
    };
    Ok((pattern, bytes))
}

fn cmd_serve(args: &[String]) -> anyhow::Result<()> {
    let fl = flags(args)?;
    let defaults = ServeConfig::default();
    let workers: usize = get(&fl, "workers").unwrap_or("4").parse()?;
    let cache: usize = get(&fl, "cache").unwrap_or("64").parse()?;
    let max_batch: usize = get(&fl, "batch").unwrap_or("64").parse()?;
    let recalibrate: u64 =
        get(&fl, "recalibrate").unwrap_or("4096").parse()?;
    let max_queue: usize = get(&fl, "max-queue").unwrap_or("0").parse()?;
    let admission = Admission::parse(get(&fl, "admission").unwrap_or("block"))?;
    let priority = PriorityPolicy::parse(get(&fl, "priority").unwrap_or("size"))?;
    let age_limit: u64 = match get(&fl, "age-limit") {
        Some(v) => v.parse()?,
        None => defaults.age_limit,
    };
    let probe_max_bytes: usize = match get(&fl, "probe-bytes") {
        Some(v) => v.parse()?,
        None => defaults.probe_max_bytes,
    };
    let source = get(&fl, "requests").unwrap_or("-");

    let text = if source == "-" {
        let mut buf = String::new();
        std::io::Read::read_to_string(&mut std::io::stdin(), &mut buf)?;
        buf
    } else {
        std::fs::read_to_string(source)?
    };

    let server = Server::start(ServeConfig {
        workers,
        cache_patterns: cache,
        max_batch,
        recalibrate_every: recalibrate,
        max_queue,
        admission,
        priority,
        age_limit,
        probe_max_bytes,
        ..defaults
    })?;
    let t = server.thresholds();
    println!(
        "serving: {workers} worker(s), cache {cache} pattern(s), \
         queue {} ({admission:?} admission, {priority:?} priority); \
         calibrated {} sym/us -> seq<{} cloud>={}",
        if max_queue == 0 {
            "unbounded".to_string()
        } else {
            format!("<= {max_queue}")
        },
        t.calibrated_rate
            .map(|r| format!("{r:.0}"))
            .unwrap_or_else(|| "off".to_string()),
        t.seq_max_n,
        t.cloud_min_n
    );

    // submit everything up front (the async part), then stream results
    // back in line order; a malformed line is reported in place and must
    // never discard the other requests' results
    let mut tickets = Vec::new();
    let mut bad_lines = 0usize;
    for (idx, line) in text.lines().enumerate() {
        let lineno = idx + 1;
        let line = line.trim_end_matches('\r');
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        match parse_request_line(line, lineno) {
            Ok((pattern, input)) => {
                let n = input.len();
                tickets.push((lineno, n, server.submit(pattern, input)));
            }
            Err(e) => {
                bad_lines += 1;
                eprintln!("line {lineno}: bad request: {e:#}");
            }
        }
    }

    for (lineno, n, ticket) in tickets {
        match ticket.wait() {
            Ok(out) => println!(
                "line {lineno}: accepted={} via {} (n={n}, makespan={})",
                out.accepted, out.engine, out.makespan
            ),
            Err(e) => println!("line {lineno}: error: {e}"),
        }
    }
    if bad_lines > 0 {
        eprintln!("{bad_lines} malformed request line(s) skipped");
    }

    let stats = server.shutdown();
    println!(
        "served {} ok / {} failed / {} rejected in {} batch(es) \
         ({:.2} requests/batch, {} coalesced, peak depth {})",
        stats.served,
        stats.failed,
        stats.rejected,
        stats.batches,
        stats.requests_per_batch(),
        stats.coalesced,
        stats.max_queue_depth
    );
    println!(
        "queue wait: probes {} taken, mean {:.0} us, max {} us; \
         scans {} taken, mean {:.0} us, max {} us",
        stats.probe_wait.taken,
        stats.probe_wait.mean_us(),
        stats.probe_wait.max_us,
        stats.scan_wait.taken,
        stats.scan_wait.mean_us(),
        stats.scan_wait.max_us
    );
    println!(
        "cache: {} compile(s), {} hit(s), {} outcome hit(s), \
         {} eviction(s); {} recalibration(s)",
        stats.compiles,
        stats.cache_hits,
        stats.outcome_hits,
        stats.evictions,
        stats.recalibrations
    );
    Ok(())
}

/// One `bench` workload: a compiled DFA plus a realistic premapped
/// symbol stream.
struct BenchWorkload {
    name: &'static str,
    dfa: Dfa,
    syms: Vec<u32>,
}

fn kernel_workloads(quick: bool) -> Vec<BenchWorkload> {
    let n = if quick { 200_000 } else { 2_000_000 };
    let mut gen = InputGen::new(0xBE4C);
    let pcre = compile_search("(ab|cd)+e").expect("static pattern");
    let pcre_syms = pcre.map_input(&gen.ascii_text(n));
    let prosite =
        compile_prosite("C-x(2)-C-x(3)-[LIVMFYWC]-x(4)-H-x(3,5)-H.")
            .expect("static signature");
    let prosite_syms = prosite.map_input(&gen.protein(n));
    // dense random table large enough to stress the cache hierarchy
    // (the regime where width compaction pays)
    let dense = workload::dense_frontier_dfa(1024, 32, 0xDE45E);
    let dense_syms = gen.uniform_syms(&dense, n);
    let sink = compile_exact("abcde").expect("static pattern");
    let sink_syms = sink.map_input(&gen.ascii_text(n));
    vec![
        BenchWorkload { name: "pcre-small", dfa: pcre, syms: pcre_syms },
        BenchWorkload {
            name: "prosite-sig",
            dfa: prosite,
            syms: prosite_syms,
        },
        BenchWorkload { name: "dense-1024q", dfa: dense, syms: dense_syms },
        BenchWorkload { name: "exact-sink", dfa: sink, syms: sink_syms },
    ]
}

/// The `kernels` suite: per-width scalar and 8-wide interleaved
/// Listing-1 tiers on every workload, plus collapse-on/off speculative
/// runs on the workloads where chains actually converge.
fn bench_kernels(quick: bool, records: &mut Vec<BenchRecord>) {
    let (warmup, reps) = if quick { (1, 2) } else { (1, 5) };
    let procs = if quick { 4 } else { 8 };
    let mut table = Table::new(
        "kernel tiers (syms/sec; see BENCH json for full records)",
        &["workload", "kernel", "width", "table B", "Msyms/s"],
    );
    for w in kernel_workloads(quick) {
        let n = w.syms.len();
        let max_off =
            (w.dfa.num_states - 1) as u64 * w.dfa.num_symbols as u64;
        for width in [Width::U8, Width::U16, Width::U32] {
            if !width.holds(max_off) {
                continue;
            }
            let flat = FlatDfa::from_dfa_with_width(&w.dfa, width);
            let vs = flat.validate(&w.syms);
            let secs =
                time_median(warmup, reps, || flat.run_valid(flat.start_off, vs));
            push_kernel_record(
                records,
                &mut table,
                w.name,
                &format!("seq_{}", width.name()),
                &flat,
                n,
                reps,
                secs,
                n as f64 / secs.max(1e-12),
            );
            // 8 interleaved chains from 8 (possibly repeated) states
            let mut starts = [flat.start_off; 8];
            for (i, s) in starts.iter_mut().enumerate() {
                *s = flat.offset_of(i as u32 % w.dfa.num_states);
            }
            let secs = time_median(warmup, reps, || {
                flat.run_valid_x8(starts, vs)
            });
            push_kernel_record(
                records,
                &mut table,
                w.name,
                &format!("x8_{}", width.name()),
                &flat,
                n,
                reps,
                secs,
                8.0 * n as f64 / secs.max(1e-12),
            );
        }
        // collapse ablation on the structured workloads: exact-sink is
        // the high-gamma case (no lookahead, all-|Q| speculation),
        // prosite-sig the realistic lookahead case
        if w.name == "exact-sink" || w.name == "prosite-sig" {
            let r = if w.name == "exact-sink" { 0 } else { 4 };
            for (kernel, every) in
                [("spec_nocollapse", 0usize), ("spec_collapse", 256)]
            {
                let plan = MatchPlan::new(&w.dfa)
                    .processors(procs)
                    .lookahead(r)
                    .collapse_every(every);
                // the stats run doubles as the warmup
                let (_, out) = time_once(|| plan.run_syms(&w.syms));
                let secs = time_median(0, reps, || plan.run_syms(&w.syms));
                let matched: u64 = out
                    .work
                    .iter()
                    .map(|wk| wk.syms_matched as u64)
                    .sum();
                records.push(BenchRecord {
                    suite: "kernels".to_string(),
                    workload: w.name.to_string(),
                    kernel: kernel.to_string(),
                    width: None,
                    table_bytes: None,
                    n_syms: n,
                    reps,
                    secs_per_iter: secs,
                    syms_per_sec: n as f64 / secs.max(1e-12),
                    syms_matched: Some(matched),
                    collapses: Some(out.collapses() as u64),
                });
                table.row(vec![
                    w.name.to_string(),
                    kernel.to_string(),
                    "-".to_string(),
                    "-".to_string(),
                    format!("{:.1}", n as f64 / secs.max(1e-12) / 1e6),
                ]);
            }
        }
    }
    table.print();
}

#[allow(clippy::too_many_arguments)]
fn push_kernel_record(
    records: &mut Vec<BenchRecord>,
    table: &mut Table,
    workload: &str,
    kernel: &str,
    flat: &FlatDfa,
    n: usize,
    reps: usize,
    secs: f64,
    syms_per_sec: f64,
) {
    records.push(BenchRecord {
        suite: "kernels".to_string(),
        workload: workload.to_string(),
        kernel: kernel.to_string(),
        width: Some(flat.width().name().to_string()),
        table_bytes: Some(flat.table_bytes()),
        n_syms: n,
        reps,
        secs_per_iter: secs,
        syms_per_sec,
        syms_matched: None,
        collapses: None,
    });
    table.row(vec![
        workload.to_string(),
        kernel.to_string(),
        flat.width().name().to_string(),
        flat.table_bytes().to_string(),
        format!("{:.1}", syms_per_sec / 1e6),
    ]);
}

/// The `engines` suite: every engine through the facade on a PCRE-like
/// and a PROSITE workload (collapse on, the serving default).
fn bench_engines(quick: bool, records: &mut Vec<BenchRecord>) {
    let reps = if quick { 2 } else { 5 };
    let n = if quick { 100_000 } else { 1_000_000 };
    let mut gen = InputGen::new(0xBE4E);
    let workloads: Vec<(&str, Pattern, Vec<u8>)> = vec![
        (
            "pcre-text",
            Pattern::Regex("(ab|cd)+e".to_string()),
            gen.ascii_text(n),
        ),
        (
            "prosite-protein",
            Pattern::Prosite("C-x(2)-C-x(3)-[LIVMFYWC].".to_string()),
            gen.protein(n),
        ),
    ];
    let engines: Vec<(&str, Engine)> = vec![
        ("seq", Engine::Sequential),
        ("spec", Engine::speculative()),
        ("simd", Engine::simd()),
        ("shard", Engine::Shard { nodes: 2 }),
        ("cloud", Engine::Cloud { nodes: 4 }),
        ("holub", Engine::HolubStekr),
    ];
    let mut table = Table::new(
        "engines (syms/sec through the facade)",
        &["workload", "engine", "Msyms/s", "makespan", "overhead"],
    );
    for (wname, pattern, input) in &workloads {
        for (ename, engine) in &engines {
            let policy = ExecPolicy {
                processors: if quick { 4 } else { 8 },
                ..ExecPolicy::default()
            };
            let cm = match CompiledMatcher::compile(
                pattern,
                engine.clone(),
                policy,
            ) {
                Ok(cm) => cm,
                Err(e) => {
                    eprintln!("bench: skip {ename} on {wname}: {e:#}");
                    continue;
                }
            };
            let syms = cm.dfa().map_input(input);
            // the stats run doubles as the warmup
            let (_, first) = time_once(|| cm.run_syms(&syms));
            let out = match first {
                Ok(out) => out,
                Err(e) => {
                    eprintln!("bench: {ename} failed on {wname}: {e:#}");
                    continue;
                }
            };
            let secs = time_median(0, reps, || cm.run_syms(&syms));
            let sps = syms.len() as f64 / secs.max(1e-12);
            records.push(BenchRecord {
                suite: "engines".to_string(),
                workload: wname.to_string(),
                kernel: ename.to_string(),
                width: None,
                table_bytes: None,
                n_syms: syms.len(),
                reps,
                secs_per_iter: secs,
                syms_per_sec: sps,
                syms_matched: Some((out.n + out.overhead_syms) as u64),
                collapses: None,
            });
            table.row(vec![
                wname.to_string(),
                ename.to_string(),
                format!("{:.1}", sps / 1e6),
                out.makespan.to_string(),
                out.overhead_syms.to_string(),
            ]);
        }
    }
    table.print();
}

/// The `serve` suite: client-observed ticket latency under a mixed load
/// of corpus scans and small probes, size-aware priority vs FIFO.  One
/// worker, two corpus scans submitted first, then N 64 B probes: FIFO
/// convoys every probe behind both scans; size-aware scheduling takes
/// the queued probes first (aging still finishes the scans).
fn bench_serve(quick: bool, records: &mut Vec<BenchRecord>) {
    let probes: usize = if quick { 200 } else { 1000 };
    let probe_n = 64usize;
    let scan_n: usize = if quick { 1 << 20 } else { 8 << 20 };
    let mut table = Table::new(
        "serve latency (1 worker, 2 scans + N probes)",
        &["mode", "probe p50 ms", "probe p99 ms", "scan max ms", "MB/s"],
    );
    for (mode, priority) in [
        ("size", PriorityPolicy::SizeAware),
        ("fifo", PriorityPolicy::Fifo),
    ] {
        let server = Server::start(ServeConfig {
            workers: 1,
            profile_runs: 1,
            profile_sample_syms: 1 << 14,
            recalibrate_every: 0,
            calibrate_on_start: false,
            cache_outcomes: 0,
            engine: Engine::Sequential,
            priority,
            // one request per batch (the two scans must not coalesce)
            // and a huge aging bound: the finite pre-submitted flood
            // cannot starve anything, and the two modes differ purely
            // by scheduling order
            max_batch: 1,
            age_limit: 1 << 30,
            ..ServeConfig::default()
        })
        .expect("serve bench server");
        let mut gen = InputGen::new(0x5E7E);
        // uppercase literal: InputGen::ascii_text emits lowercase only,
        // so the scan DFA never accepts and must walk the full corpus
        let scan_pat = Pattern::Regex("ZQZQZQ".to_string());
        let probe_pat = Pattern::Regex("(ab|cd)+e".to_string());
        let scan_inputs: Vec<Vec<u8>> =
            (0..2).map(|_| gen.ascii_text(scan_n)).collect();
        let probe_inputs: Vec<Vec<u8>> =
            (0..probes).map(|_| gen.ascii_text(probe_n)).collect();
        let t0 = std::time::Instant::now();
        let scan_tickets: Vec<_> = scan_inputs
            .into_iter()
            .map(|inp| server.submit(scan_pat.clone(), inp))
            .collect();
        let probe_tickets: Vec<_> = probe_inputs
            .into_iter()
            .map(|inp| server.submit(probe_pat.clone(), inp))
            .collect();
        // resolution order approximates completion: tickets resolved
        // while we were blocked on an earlier one read back-to-back
        let mut probe_done: Vec<f64> = probe_tickets
            .into_iter()
            .map(|t| {
                t.wait().expect("probe serves");
                t0.elapsed().as_secs_f64()
            })
            .collect();
        let scan_done: Vec<f64> = scan_tickets
            .into_iter()
            .map(|t| {
                t.wait().expect("scan serves");
                t0.elapsed().as_secs_f64()
            })
            .collect();
        let wall = t0.elapsed().as_secs_f64();
        let _ = server.shutdown();
        probe_done.sort_by(|a, b| a.total_cmp(b));
        let p50 = percentile(&probe_done, 0.50);
        let p99 = percentile(&probe_done, 0.99);
        let scan_max = scan_done.iter().fold(0.0_f64, |a, &b| a.max(b));
        let total_bytes = 2 * scan_n + probes * probe_n;
        let sps = total_bytes as f64 / wall.max(1e-12);
        for (kernel, secs) in [
            ("probe_wait_p50", p50),
            ("probe_wait_p99", p99),
            ("scan_wait_max", scan_max),
        ] {
            records.push(BenchRecord {
                suite: "serve".to_string(),
                workload: format!("{mode}-2scan-{probes}probe"),
                kernel: kernel.to_string(),
                width: None,
                table_bytes: None,
                n_syms: total_bytes,
                reps: probes,
                secs_per_iter: secs,
                syms_per_sec: sps,
                syms_matched: None,
                collapses: None,
            });
        }
        table.row(vec![
            mode.to_string(),
            format!("{:.2}", p50 * 1e3),
            format!("{:.2}", p99 * 1e3),
            format!("{:.2}", scan_max * 1e3),
            format!("{:.1}", sps / (1 << 20) as f64),
        ]);
    }
    table.print();
}

/// The `patternset` suite: k patterns answered over one input — the
/// fused single-pass set matcher (with and without the literal
/// prefilter) against the k-pass ablation of k independent sequential
/// matchers.  Same job on every row (k verdicts over the same bytes),
/// so `secs_per_iter` is directly comparable.
fn bench_patternset(quick: bool, records: &mut Vec<BenchRecord>) {
    let reps = if quick { 2 } else { 5 };
    let n = if quick { 200_000 } else { 2_000_000 };
    let procs = if quick { 4 } else { 8 };
    let mut gen = InputGen::new(0xBE4F);
    let pcre: Vec<Pattern> = pcre_suite_cached()
        .iter()
        .take(6)
        .map(|p| Pattern::Regex(p.pattern.clone()))
        .collect();
    let prosite: Vec<Pattern> = prosite_suite_cached()
        .iter()
        .take(4)
        .map(|p| Pattern::Prosite(p.pattern.clone()))
        .collect();
    let sets: Vec<(&str, Vec<Pattern>, Vec<u8>)> = vec![
        ("pcre-set", pcre, gen.ascii_text(n)),
        ("prosite-set", prosite, gen.protein(n)),
    ];
    let mut table = Table::new(
        "patternset (fused single pass vs k sequential passes)",
        &["workload", "kernel", "k", "fused", "spilled", "Msyms/s"],
    );
    let policy = ExecPolicy { processors: procs, ..ExecPolicy::default() };
    for (wname, patterns, input) in &sets {
        let k = patterns.len();
        let set = PatternSet::from_patterns(patterns.clone());
        for (kernel, prefilter) in
            [("fused_single_pass", true), ("fused_noprefilter", false)]
        {
            let config = SetConfig {
                engine: Engine::Sequential,
                policy: policy.clone(),
                prefilter,
                ..SetConfig::default()
            };
            let csm = match CompiledSetMatcher::compile(&set, config) {
                Ok(csm) => csm,
                Err(e) => {
                    eprintln!("bench: skip {kernel} on {wname}: {e:#}");
                    continue;
                }
            };
            // the verdict run doubles as the warmup
            let (_, first) = time_once(|| csm.run_bytes(input));
            if let Err(e) = first {
                eprintln!("bench: {kernel} failed on {wname}: {e:#}");
                continue;
            }
            let secs = time_median(0, reps, || csm.run_bytes(input));
            let sps = input.len() as f64 / secs.max(1e-12);
            records.push(BenchRecord {
                suite: "patternset".to_string(),
                workload: wname.to_string(),
                kernel: kernel.to_string(),
                width: None,
                table_bytes: None,
                n_syms: input.len(),
                reps,
                secs_per_iter: secs,
                syms_per_sec: sps,
                syms_matched: None,
                collapses: None,
            });
            table.row(vec![
                wname.to_string(),
                kernel.to_string(),
                k.to_string(),
                csm.fused_patterns().to_string(),
                csm.spilled_patterns().to_string(),
                format!("{:.1}", sps / 1e6),
            ]);
        }
        // the ablation: k independent compiled matchers, one pass each
        let cms: Vec<CompiledMatcher> = patterns
            .iter()
            .filter_map(|p| {
                CompiledMatcher::compile(
                    p,
                    Engine::Sequential,
                    policy.clone(),
                )
                .ok()
            })
            .collect();
        if cms.is_empty() {
            continue;
        }
        let secs = time_median(1, reps, || {
            cms.iter()
                .map(|cm| {
                    cm.run_bytes(input).map(|o| o.accepted).unwrap_or(false)
                })
                .filter(|&a| a)
                .count()
        });
        let sps = input.len() as f64 / secs.max(1e-12);
        records.push(BenchRecord {
            suite: "patternset".to_string(),
            workload: wname.to_string(),
            kernel: "kpass_sequential".to_string(),
            width: None,
            table_bytes: None,
            n_syms: input.len(),
            reps,
            secs_per_iter: secs,
            syms_per_sec: sps,
            syms_matched: None,
            collapses: None,
        });
        table.row(vec![
            wname.to_string(),
            "kpass_sequential".to_string(),
            k.to_string(),
            "-".to_string(),
            "-".to_string(),
            format!("{:.1}", sps / 1e6),
        ]);
    }
    table.print();
}

/// The `stream` suite: segment-streamed matching (`engine::stream`)
/// against the one-shot matcher over the same bytes.  The streamed
/// rows carry the checkpoint wire size in `table_bytes`, so the
/// trajectory records both the throughput cost of segmentation and
/// the constant state a preempted or migrated scan has to carry.
fn bench_stream(quick: bool, records: &mut Vec<BenchRecord>) {
    let reps = if quick { 2 } else { 5 };
    let n = if quick { 200_000 } else { 2_000_000 };
    let mut gen = InputGen::new(0xBE50);
    let workloads: Vec<(&str, Pattern, Vec<u8>)> = vec![
        (
            "pcre-text",
            Pattern::Regex("(ab|cd)+e".to_string()),
            gen.ascii_text(n),
        ),
        (
            "prosite-protein",
            Pattern::Prosite("C-x(2)-C-x(3)-[LIVMFYWC].".to_string()),
            gen.protein(n),
        ),
    ];
    let mut table = Table::new(
        "stream (segment-streamed vs one-shot)",
        &["workload", "kernel", "segment B", "ckpt B", "Msyms/s"],
    );
    for (wname, pattern, input) in &workloads {
        let cm = match CompiledMatcher::compile(
            pattern,
            Engine::Sequential,
            ExecPolicy::default(),
        ) {
            Ok(cm) => cm,
            Err(e) => {
                eprintln!("bench: skip stream on {wname}: {e:#}");
                continue;
            }
        };
        // the one-shot yardstick (the verdict run doubles as warmup)
        let (_, first) = time_once(|| cm.run_bytes(input));
        if let Err(e) = first {
            eprintln!("bench: stream one-shot failed on {wname}: {e:#}");
            continue;
        }
        let secs = time_median(0, reps, || cm.run_bytes(input));
        let sps = input.len() as f64 / secs.max(1e-12);
        records.push(BenchRecord {
            suite: "stream".to_string(),
            workload: wname.to_string(),
            kernel: "one_shot".to_string(),
            width: None,
            table_bytes: None,
            n_syms: input.len(),
            reps,
            secs_per_iter: secs,
            syms_per_sec: sps,
            syms_matched: None,
            collapses: None,
        });
        table.row(vec![
            wname.to_string(),
            "one_shot".to_string(),
            "-".to_string(),
            "-".to_string(),
            format!("{:.1}", sps / 1e6),
        ]);
        for seg in [4usize << 10, 64 << 10] {
            let run = || {
                let mut sm = StreamMatcher::new(&cm);
                for chunk in input.chunks(seg) {
                    sm.feed(chunk);
                }
                sm.finish().accepted
            };
            let _ = time_once(run); // warmup
            let secs = time_median(0, reps, run);
            let sps = input.len() as f64 / secs.max(1e-12);
            // checkpoint wire size at mid-stream (it is
            // segment-size-independent: L-vector + counters)
            let mut sm = StreamMatcher::new(&cm);
            sm.feed(&input[..input.len() / 2]);
            let ckpt_bytes = sm.checkpoint().to_bytes().len();
            let kernel = format!("stream_seg{}k", seg >> 10);
            records.push(BenchRecord {
                suite: "stream".to_string(),
                workload: wname.to_string(),
                kernel: kernel.clone(),
                width: None,
                table_bytes: Some(ckpt_bytes),
                n_syms: input.len(),
                reps,
                secs_per_iter: secs,
                syms_per_sec: sps,
                syms_matched: None,
                collapses: None,
            });
            table.row(vec![
                wname.to_string(),
                kernel,
                seg.to_string(),
                ckpt_bytes.to_string(),
                format!("{:.1}", sps / 1e6),
            ]);
        }
    }
    table.print();
}

/// The `adversarial` suite: (1) one-shot engine throughput on the
/// pathological automata — permutation (γ = 1 at every lookahead
/// depth, speculation's structural worst case; `Auto` must dodge it),
/// dense-frontier and sink-heavy — and (2) client-observed ticket
/// latency for a bursty Zipfian heavy-tail trace replayed through the
/// server with the PR 5 bounds active.
fn bench_adversarial(quick: bool, records: &mut Vec<BenchRecord>) {
    let seed = 0xADE5_2026u64;

    // part 1: one-shot throughput vs automaton structure
    let n: usize = if quick { 1 << 16 } else { 1 << 20 };
    let (warmup, iters) = if quick { (1, 3) } else { (2, 7) };
    let mut table = Table::new(
        "adversarial automata (throughput vs structure)",
        &["case", "gamma", "engine", "Msym/s"],
    );
    let policy = ExecPolicy {
        processors: 4,
        lookahead: 2,
        ..ExecPolicy::default()
    };
    let cases: Vec<(&str, Dfa)> = vec![
        ("perm-q64", workload::permutation_dfa(64, 8, seed)),
        ("perm-q256", workload::permutation_dfa(256, 16, seed ^ 1)),
        ("dense-q512", workload::dense_frontier_dfa(512, 16, seed ^ 2)),
        ("sink-q32", workload::sink_heavy_dfa(30, 8, seed ^ 3).0),
    ];
    for (name, dfa) in cases {
        let gamma = DfaProps::analyze(&dfa, policy.lookahead.max(1)).gamma;
        let table_bytes =
            dfa.num_states as usize * dfa.num_symbols as usize * 4;
        let mut gen = InputGen::new(seed ^ 4);
        let syms = gen.uniform_syms(&dfa, n);
        for (ename, engine) in [
            ("seq", Engine::Sequential),
            ("spec", Engine::Speculative { adaptive: false }),
            ("auto", Engine::Auto),
        ] {
            let m = match CompiledMatcher::from_dfa(
                dfa.clone(),
                engine,
                policy.clone(),
            ) {
                Ok(m) => m,
                Err(e) => {
                    eprintln!("  {name}/{ename}: skipped ({e:#})");
                    continue;
                }
            };
            let secs = time_median(warmup, iters, || {
                m.run_syms(&syms).expect("adversarial bench run")
            });
            let sps = n as f64 / secs.max(1e-12);
            records.push(BenchRecord {
                suite: "adversarial".to_string(),
                workload: name.to_string(),
                kernel: format!("oneshot_{ename}"),
                width: None,
                table_bytes: Some(table_bytes),
                n_syms: n,
                reps: iters,
                secs_per_iter: secs,
                syms_per_sec: sps,
                syms_matched: None,
                collapses: None,
            });
            table.row(vec![
                name.to_string(),
                format!("{gamma:.3}"),
                ename.to_string(),
                format!("{:.1}", sps / 1e6),
            ]);
        }
    }
    table.print();

    // part 2: bursty Zipfian heavy-tail trace through the server —
    // the same generator tests/adversarial.rs asserts the bounds on,
    // here timed from the client side of the ticket
    let requests: usize = if quick { 200 } else { 1000 };
    let probe_max = 1 << 12;
    let pool = workload::pathological_corpus(seed);
    let events = workload::trace(
        &workload::TraceConfig {
            requests,
            pool: pool.len(),
            skew: 1.1,
            probe_max_bytes: probe_max,
            burst: 16,
            gap_us: 200,
        },
        seed ^ 5,
    );
    let mut rng = Rng::new(seed ^ 6);
    let jobs: Vec<(usize, Vec<u8>)> = events
        .iter()
        .map(|ev| {
            let i = ev.pattern % pool.len();
            let alphabet = &pool[i].alphabet;
            let input: Vec<u8> = (0..ev.len)
                .map(|_| alphabet[rng.usize_below(alphabet.len())])
                .collect();
            (i, input)
        })
        .collect();
    let total_bytes: usize = jobs.iter().map(|(_, b)| b.len()).sum();
    let server = Server::start(ServeConfig {
        workers: 2,
        max_queue: 64,
        admission: Admission::Block,
        priority: PriorityPolicy::SizeAware,
        probe_max_bytes: probe_max,
        age_limit: 4,
        calibrate_on_start: false,
        profile_runs: 1,
        profile_sample_syms: 1 << 14,
        recalibrate_every: 0,
        ..ServeConfig::default()
    })
    .expect("adversarial bench server");
    let t0 = std::time::Instant::now();
    let tickets: Vec<_> = jobs
        .iter()
        .map(|(i, input)| server.submit(pool[*i].pattern.clone(), input.clone()))
        .collect();
    let mut done: Vec<f64> = tickets
        .into_iter()
        .map(|t| {
            t.wait().expect("adversarial trace request serves");
            t0.elapsed().as_secs_f64()
        })
        .collect();
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.shutdown();
    done.sort_by(|a, b| a.total_cmp(b));
    let p50 = percentile(&done, 0.50);
    let p99 = percentile(&done, 0.99);
    let sps = total_bytes as f64 / wall.max(1e-12);
    for (kernel, secs) in
        [("trace_wait_p50", p50), ("trace_wait_p99", p99)]
    {
        records.push(BenchRecord {
            suite: "adversarial".to_string(),
            workload: format!("zipf-trace-{requests}req"),
            kernel: kernel.to_string(),
            width: None,
            table_bytes: None,
            n_syms: total_bytes,
            reps: requests,
            secs_per_iter: secs,
            syms_per_sec: sps,
            syms_matched: None,
            collapses: None,
        });
    }
    let mut t2 = Table::new(
        "adversarial trace (bursty zipfian, heavy-tail sizes)",
        &["requests", "p50 ms", "p99 ms", "max bypass streak", "MB/s"],
    );
    t2.row(vec![
        requests.to_string(),
        format!("{:.2}", p50 * 1e3),
        format!("{:.2}", p99 * 1e3),
        stats.max_bypass_streak.to_string(),
        format!("{:.1}", sps / (1 << 20) as f64),
    ]);
    t2.print();
}

/// The `cluster` suite: real multi-process matching over the framed
/// socket protocol vs the in-process one-shot yardstick, plus one
/// faulted serve timing the full kill → failover → checkpoint-resume
/// path.  Worker processes are this same binary (`specdfa worker`).
fn bench_cluster(quick: bool, records: &mut Vec<BenchRecord>) {
    let reps = if quick { 2 } else { 4 };
    let n: usize = if quick { 1 << 19 } else { 4 << 20 };
    let pattern = Pattern::Regex("ZQZQZQ".to_string());
    let input = InputGen::new(0xC1A5).ascii_text(n);
    let cm = CompiledMatcher::compile(
        &pattern,
        Engine::Sequential,
        ExecPolicy::default(),
    )
    .expect("static pattern");
    let expect = cm.run_bytes(&input).expect("local yardstick").accepted;
    let mut table = Table::new(
        "cluster (multi-process vs local one-shot)",
        &["kernel", "chunks", "failovers", "MB/s"],
    );
    let mut push = |records: &mut Vec<BenchRecord>,
                    kernel: &str,
                    reps: usize,
                    secs: f64,
                    chunks: usize,
                    failovers: u64| {
        let sps = n as f64 / secs.max(1e-12);
        records.push(BenchRecord {
            suite: "cluster".to_string(),
            workload: "ascii-text".to_string(),
            kernel: kernel.to_string(),
            width: None,
            table_bytes: None,
            n_syms: n,
            reps,
            secs_per_iter: secs,
            syms_per_sec: sps,
            syms_matched: None,
            collapses: None,
        });
        table.row(vec![
            kernel.to_string(),
            chunks.to_string(),
            failovers.to_string(),
            format!("{:.1}", sps / (1 << 20) as f64),
        ]);
    };

    // yardstick: the same verdict computed in-process
    let secs = time_median(1, reps, || {
        cm.run_bytes(&input).expect("local yardstick").accepted
    });
    push(records, "local_oneshot", reps, secs, 1, 0);

    let quick_proc = |fault: Option<String>| ProcConfig {
        workers: 2,
        min_chunk_bytes: 1 << 12,
        fault_spec: fault,
        ..ProcConfig::default()
    };

    // healthy two-worker cluster
    match ProcCluster::start(quick_proc(None)) {
        Ok(cluster) => {
            let run = || {
                cluster
                    .match_bytes(&pattern, &input)
                    .expect("cluster serve")
            };
            let out = run(); // warmup (compiles the pattern on workers)
            assert_eq!(out.accepted, expect, "failure-freedom violated");
            let chunks = match &out.detail {
                specdfa::engine::Detail::Cluster(p) => p.chunks,
                _ => 1,
            };
            let secs = time_median(0, reps, || run().accepted);
            let stats = cluster.shutdown();
            push(records, "cluster_w2", reps, secs, chunks, stats.failovers);
        }
        Err(e) => eprintln!("bench: skip cluster_w2: {e:#}"),
    }

    // worker 1 killed mid-chunk: one serve paying the whole
    // detect → retry → resume-from-checkpoint path
    let kill = format!("w1:kill@{}", n / 8);
    match ProcCluster::start(quick_proc(Some(kill))) {
        Ok(cluster) => {
            let (secs, out) =
                time_once(|| cluster.match_bytes(&pattern, &input));
            let out = out.expect("faulted serve still answers");
            assert_eq!(out.accepted, expect, "failure-freedom violated");
            let chunks = match &out.detail {
                specdfa::engine::Detail::Cluster(p) => p.chunks,
                _ => 1,
            };
            let stats = cluster.shutdown();
            push(records, "cluster_w2_kill", 1, secs, chunks, stats.failovers);
        }
        Err(e) => eprintln!("bench: skip cluster_w2_kill: {e:#}"),
    }
    table.print();
}

/// `specdfa bench`: reproducible kernel-tier, engine and serve-latency
/// benchmarks with machine-readable JSON output (the repo's
/// `BENCH_*.json` trajectory).
fn cmd_bench(args: &[String]) -> anyhow::Result<()> {
    let fl = flags(args)?;
    let suite = get(&fl, "suite").unwrap_or("kernels");
    let quick = has_flag(&fl, "quick");
    let mut records: Vec<BenchRecord> = Vec::new();
    match suite {
        "kernels" => bench_kernels(quick, &mut records),
        "engines" => bench_engines(quick, &mut records),
        "serve" => bench_serve(quick, &mut records),
        "patternset" => bench_patternset(quick, &mut records),
        "stream" => bench_stream(quick, &mut records),
        "adversarial" => bench_adversarial(quick, &mut records),
        "cluster" => bench_cluster(quick, &mut records),
        "all" => {
            bench_kernels(quick, &mut records);
            bench_engines(quick, &mut records);
            bench_serve(quick, &mut records);
            bench_patternset(quick, &mut records);
            bench_stream(quick, &mut records);
            bench_adversarial(quick, &mut records);
            bench_cluster(quick, &mut records);
        }
        other => anyhow::bail!(
            "unknown suite {other:?} \
             (expected kernels|engines|serve|patternset|stream|\
              adversarial|cluster|all)"
        ),
    }
    if let Some(path) = get(&fl, "json") {
        let rate = experiments::calibrate::host_syms_per_us();
        let doc = render_bench_json(
            suite,
            quick,
            Some(rate),
            &format!(
                "specdfa bench --suite {suite}{} on this host",
                if quick { " --quick" } else { "" }
            ),
            &records,
        );
        std::fs::write(path, doc)?;
        println!("wrote {} record(s) to {path}", records.len());
    }
    Ok(())
}

fn cmd_experiment(args: &[String]) -> anyhow::Result<()> {
    let name = args
        .first()
        .ok_or_else(|| anyhow::anyhow!("experiment name required"))?;
    let names: Vec<&str> = if name == "all" {
        experiments::ALL.to_vec()
    } else {
        vec![name.as_str()]
    };
    for n in names {
        let tables = experiments::run(n)
            .ok_or_else(|| anyhow::anyhow!("unknown experiment {n:?}"))?;
        for t in tables {
            t.print();
        }
    }
    Ok(())
}

fn cmd_suite(args: &[String]) -> anyhow::Result<()> {
    let which = args.first().map(String::as_str).unwrap_or("pcre");
    let suite = match which {
        "pcre" => pcre_suite_cached(),
        "prosite" => prosite_suite_cached(),
        _ => anyhow::bail!("suite must be pcre or prosite"),
    };
    let mut t = Table::new(
        &format!("{which} suite"),
        &["name", "|Q|", "|Sigma|", "I_max,1", "I_max,4", "gamma4"],
    );
    for p in suite {
        let la = Lookahead::analyze(&p.dfa, 4);
        t.row(vec![
            p.name.clone(),
            p.q().to_string(),
            p.dfa.num_symbols.to_string(),
            la.i_max_by_r[0].to_string(),
            la.i_max.to_string(),
            format!("{:.3}", la.i_max as f64 / p.q() as f64),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_profile() -> anyhow::Result<()> {
    let rate = experiments::calibrate::host_syms_per_us();
    println!(
        "host sequential matching rate: {rate:.1} symbols/us \
         ({:.2} ns/symbol, {:.1} MB/s at 1 byte/symbol)",
        1000.0 / rate,
        rate * 1e6 / (1 << 20) as f64
    );
    Ok(())
}

fn cmd_grail(args: &[String]) -> anyhow::Result<()> {
    let path = args
        .first()
        .ok_or_else(|| anyhow::anyhow!("grail file required"))?;
    let text = std::fs::read_to_string(path)?;
    let dfa = grail::from_grail(&text)?;
    let fl = flags(&args[1..])?;
    let n: usize = get(&fl, "gen").unwrap_or("1000000").parse()?;
    let syms = InputGen::new(1).uniform_syms(&dfa, n);
    let out = MatchPlan::new(&dfa).processors(8).lookahead(2).run_syms(&syms);
    println!(
        "grail DFA |Q|={} |Sigma|={}: match={} final={}",
        dfa.num_states, dfa.num_symbols, out.accepted, out.final_state
    );
    Ok(())
}

fn cmd_simd(args: &[String]) -> anyhow::Result<()> {
    let fl = flags(args)?;
    let dfa = compile_from_flags(&fl)?;
    let variant = get(&fl, "variant").unwrap_or("lane8_main");
    let r: usize = get(&fl, "lookahead").unwrap_or("1").parse()?;
    let n: usize = get(&fl, "gen").unwrap_or("65536").parse()?;
    let vu = Arc::new(VectorUnit::load(VectorUnit::default_dir(), variant)?);
    println!("vector unit: {} on {} ({} lanes, t={})",
             vu.name, vu.platform(), vu.spec.lanes, vu.spec.t);
    let syms = InputGen::new(0x51D).uniform_syms(&dfa, n);
    let m = SimdMatcher::new(&dfa, &vu)?.lookahead(r);
    let out = m.run_syms(&syms)?;
    let seq = SequentialMatcher::new(&dfa).run_syms(&syms);
    anyhow::ensure!(out.final_state == seq.final_state,
                    "vector unit disagrees with scalar matcher");
    println!(
        "match={} lanes={} slots={} passes={} pjrt_calls={} \
         chunk-speedup={:.2}x instr-speedup={:.2}x wall={:.1}ms",
        out.accepted, vu.spec.lanes, out.lane_slots, out.passes,
        out.pjrt_calls, out.chunk_speedup(), out.instr_speedup(),
        out.wall_s * 1e3
    );
    Ok(())
}

/// `specdfa cluster`: spawn a real multi-process cluster (workers are
/// this same binary re-invoked as `specdfa worker`), run a differential
/// batch against the sequential yardstick, and print the fault-tolerance
/// telemetry.  `--fault-plan` injects deterministic failures
/// (`w1:kill@65536`, `w0:trunc=result`, …) — the verdicts must still
/// match, which is the whole point.
fn cmd_cluster(args: &[String]) -> anyhow::Result<()> {
    let fl = flags(args)?;
    let workers: usize = get(&fl, "workers").unwrap_or("2").parse()?;
    let pattern =
        Pattern::Regex(get(&fl, "regex").unwrap_or("(ab|cd)+e").to_string());
    let n: usize = get(&fl, "n").unwrap_or("4000000").parse()?;
    let requests: usize = get(&fl, "requests").unwrap_or("4").parse()?;
    let transport = if has_flag(&fl, "tcp") {
        Transport::Tcp
    } else {
        Transport::default_for_host()
    };
    let config = ProcConfig {
        workers,
        transport,
        min_chunk_bytes: 1 << 12,
        fault_spec: get(&fl, "fault-plan").map(str::to_string),
        ..ProcConfig::default()
    };
    let cluster = ProcCluster::start(config)?;
    println!(
        "cluster: {} of {workers} worker(s) attached ({transport:?})",
        cluster.live_workers()
    );

    let cm = CompiledMatcher::compile(
        &pattern,
        Engine::Sequential,
        ExecPolicy::default(),
    )?;
    let mut gen = InputGen::new(0xC15);
    let mut mismatches = 0usize;
    for i in 0..requests {
        let input = gen.ascii_text(n);
        let out = cluster.match_bytes(&pattern, &input)?;
        let seq = cm.run_bytes(&input)?;
        if out.accepted != seq.accepted {
            mismatches += 1;
        }
        let detail = match &out.detail {
            specdfa::engine::Detail::Cluster(p) => format!(
                "{} chunk(s), {} retry(s), {} failover(s), \
                 {} B resumed",
                p.chunks, p.retries, p.failovers, p.resumed_bytes
            ),
            _ => "served locally".to_string(),
        };
        println!(
            "request {i}: accepted={} via {} (n={n}; {detail}) \
             seq={} -> {}",
            out.accepted,
            out.engine,
            seq.accepted,
            if out.accepted == seq.accepted { "OK" } else { "MISMATCH" }
        );
    }

    let stats = cluster.shutdown();
    let mut t = Table::new("cluster telemetry", &["counter", "value"]);
    for (k, v) in [
        ("serves", stats.serves),
        ("cluster serves", stats.cluster_serves),
        ("degraded to local", stats.degraded),
        ("small served locally", stats.local_small),
        ("retries", stats.retries),
        ("failovers", stats.failovers),
        ("worker deaths", stats.worker_deaths),
        ("resumed serves", stats.resumed_serves),
        ("resumed bytes", stats.resumed_bytes),
        ("heartbeats", stats.heartbeats),
        ("heartbeat failures", stats.heartbeat_failures),
        ("bytes", stats.bytes),
    ] {
        t.row(vec![k.to_string(), v.to_string()]);
    }
    t.row(vec![
        "live workers at end".to_string(),
        stats.live_workers.to_string(),
    ]);
    t.print();
    anyhow::ensure!(
        mismatches == 0,
        "{mismatches} verdict(s) diverged from sequential — \
         failure-freedom violated"
    );
    Ok(())
}

/// `specdfa worker`: one cluster worker process.  Spawned by
/// [`cmd_cluster`] / `ProcCluster::start`, not meant for interactive
/// use; speaks the framed protocol on the socket given by `--connect`.
fn cmd_worker(args: &[String]) -> anyhow::Result<()> {
    let fl = flags(args)?;
    let addr = get(&fl, "connect")
        .ok_or_else(|| anyhow::anyhow!("worker needs --connect ADDR"))?;
    let id: u32 = get(&fl, "id").unwrap_or("0").parse()?;
    let fault = match get(&fl, "fault") {
        Some(spec) => FaultPlan::parse(spec)?,
        None => FaultPlan::default(),
    };
    let defaults = WorkerConfig::default();
    let profile_runs: usize = match get(&fl, "profile-runs") {
        Some(v) => v.parse()?,
        None => defaults.profile_runs,
    };
    let profile_sample_syms: usize = match get(&fl, "profile-syms") {
        Some(v) => v.parse()?,
        None => defaults.profile_sample_syms,
    };
    run_worker(WorkerConfig {
        addr: addr.to_string(),
        id,
        fault,
        profile_runs,
        profile_sample_syms,
    })
}

fn cmd_cloud(args: &[String]) -> anyhow::Result<()> {
    let fl = flags(args)?;
    let dfa = compile_from_flags(&fl)?;
    let nodes: usize = get(&fl, "nodes").unwrap_or("20").parse()?;
    let r: usize = get(&fl, "lookahead").unwrap_or("4").parse()?;
    let n: usize = get(&fl, "gen").unwrap_or("8000000").parse()?;
    let syms = InputGen::new(0xC1D).uniform_syms(&dfa, n);
    let out = CloudMatcher::new(&dfa, ClusterSpec::homogeneous(nodes))
        .lookahead(r)
        .base_rate(experiments::calibrate::host_syms_per_us())
        .run_syms(&syms);
    println!(
        "cloud: {} nodes ({} cores): match={} speedup={:.1}x comm={:.2}% \
         balance-cv={:.4}",
        nodes,
        ClusterSpec::homogeneous(nodes).total_workers(),
        out.accepted,
        out.speedup(),
        out.comm_ratio() * 100.0,
        out.balance_cv()
    );
    Ok(())
}
