//! Regex abstract syntax tree over byte sets.
//!
//! A deliberately small core: every surface construct (classes, `.`,
//! escapes, `*`/`+`/`?`/`{m,n}`, alternation, grouping, PROSITE elements)
//! desugars into these five node kinds.

use crate::automata::byteset::ByteSet;

/// Regex syntax tree node.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Ast {
    /// Matches nothing (the empty language).
    Empty,
    /// Matches the empty string.
    Epsilon,
    /// Matches one byte from the set.
    Class(ByteSet),
    /// Sequence.
    Concat(Vec<Ast>),
    /// Union.
    Alt(Vec<Ast>),
    /// node{min, max}; max=None means unbounded. Covers * + ? {m} {m,} {m,n}.
    Repeat { node: Box<Ast>, min: u32, max: Option<u32> },
}

impl Ast {
    /// Concatenation of single-byte classes spelling `s`.
    pub fn literal(s: &[u8]) -> Ast {
        Ast::Concat(s.iter().map(|&b| Ast::Class(ByteSet::single(b))).collect())
    }

    /// `node*`
    pub fn star(node: Ast) -> Ast {
        Ast::Repeat { node: Box::new(node), min: 0, max: None }
    }

    /// `node+`
    pub fn plus(node: Ast) -> Ast {
        Ast::Repeat { node: Box::new(node), min: 1, max: None }
    }

    /// `node?`
    pub fn opt(node: Ast) -> Ast {
        Ast::Repeat { node: Box::new(node), min: 0, max: Some(1) }
    }

    /// `.*self.*` over the given universe — "input contains a match"
    /// (search semantics; how grep/ScanProsite patterns are interpreted).
    pub fn surrounded(self, universe: ByteSet) -> Ast {
        Ast::Concat(vec![
            Ast::star(Ast::Class(universe)),
            self,
            Ast::star(Ast::Class(universe)),
        ])
    }

    /// Rough node count (used to cap pathological test inputs).
    pub fn size(&self) -> usize {
        match self {
            Ast::Empty | Ast::Epsilon | Ast::Class(_) => 1,
            Ast::Concat(v) | Ast::Alt(v) => {
                1 + v.iter().map(|a| a.size()).sum::<usize>()
            }
            Ast::Repeat { node, min, max } => {
                // repeats expand during Thompson construction
                let copies = max.unwrap_or(*min + 1).max(1) as usize;
                1 + node.size() * copies
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn helpers_shape() {
        let l = Ast::literal(b"ab");
        match &l {
            Ast::Concat(v) => assert_eq!(v.len(), 2),
            _ => panic!(),
        }
        assert!(matches!(Ast::star(l.clone()),
                         Ast::Repeat { min: 0, max: None, .. }));
        assert!(matches!(Ast::plus(l.clone()),
                         Ast::Repeat { min: 1, max: None, .. }));
        assert!(matches!(Ast::opt(l),
                         Ast::Repeat { min: 0, max: Some(1), .. }));
    }

    #[test]
    fn size_accounts_repeats() {
        let a = Ast::Class(ByteSet::single(b'a'));
        let r = Ast::Repeat { node: Box::new(a), min: 0, max: Some(10) };
        assert!(r.size() > 10);
    }
}
