//! Pattern -> minimal DFA compile pipeline (the paper's Grail+ toolchain):
//! parse -> Thompson NFA -> subset construction -> Hopcroft minimization.
//!
//! Two membership semantics:
//!  * `compile_exact`   — L(A) = L(pattern): whole-input match.
//!  * `compile_search`  — L(A) = Σ* pattern Σ*: "input contains a match",
//!    which is what ScanProsite/grep compute and what the paper's
//!    membership test runs on protein sequences.  Finals are absorbing, so
//!    Algorithm 1's early exit (lines 4–5) is sound.

use anyhow::Result;

use super::ast::Ast;
use super::parser;
use super::prosite;
use crate::automata::byteset::ByteSet;
use crate::automata::minimize::minimize;
use crate::automata::nfa::Nfa;
use crate::automata::subset::determinize;
use crate::automata::Dfa;

/// A compiled pattern: the minimal DFA plus provenance.
#[derive(Clone, Debug)]
pub struct CompiledPattern {
    /// benchmark name (suite id)
    pub name: String,
    /// source pattern text
    pub pattern: String,
    /// minimal search DFA
    pub dfa: Dfa,
}

fn build(ast: &Ast) -> Dfa {
    minimize(&determinize(&Nfa::from_ast(ast)))
}

/// Compile a PCRE-style regex with whole-input semantics (anchors at both
/// ends implied; explicit `^`/`$` are no-ops here).
pub fn compile_exact(pattern: &str) -> Result<Dfa> {
    let parsed = parser::parse(pattern)?;
    Ok(build(&parsed.ast))
}

/// Compile a PCRE-style regex with search ("contains") semantics: the DFA
/// accepts any input containing a substring matching the pattern.  `^`/`$`
/// anchors suppress the corresponding Σ* wrap.
pub fn compile_search(pattern: &str) -> Result<Dfa> {
    let parsed = parser::parse(pattern)?;
    let universe = ByteSet::ALL;
    let mut parts = Vec::new();
    if !parsed.anchored_start {
        parts.push(Ast::star(Ast::Class(universe)));
    }
    parts.push(parsed.ast);
    if !parsed.anchored_end {
        parts.push(Ast::star(Ast::Class(universe)));
    }
    Ok(build(&Ast::Concat(parts)))
}

/// Compile a PROSITE pattern with ScanProsite semantics: match anywhere in
/// the sequence unless `<`/`>` anchored.  Alphabet is the amino-acid set.
pub fn compile_prosite(pattern: &str) -> Result<Dfa> {
    let parsed = prosite::parse(pattern)?;
    let universe = prosite::amino_set();
    let mut parts = Vec::new();
    if !parsed.anchored_start {
        parts.push(Ast::star(Ast::Class(universe)));
    }
    parts.push(parsed.ast);
    if !parsed.anchored_end {
        parts.push(Ast::star(Ast::Class(universe)));
    }
    Ok(build(&Ast::Concat(parts)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn exact_vs_search_semantics() {
        let exact = compile_exact("ab+c").unwrap();
        let search = compile_search("ab+c").unwrap();
        assert!(exact.accepts_bytes(b"abbc"));
        assert!(!exact.accepts_bytes(b"xabbcx"));
        assert!(search.accepts_bytes(b"xabbcx"));
        assert!(search.accepts_bytes(b"abbc"));
        assert!(!search.accepts_bytes(b"abx"));
    }

    #[test]
    fn search_finals_absorbing() {
        let dfa = compile_search("abc").unwrap();
        // after a match, any continuation still accepts
        assert!(dfa.accepts_bytes(b"abc"));
        assert!(dfa.accepts_bytes(b"abc!!!!"));
        // minimal search DFA has a single absorbing accept state
        let q = dfa.run_bytes(dfa.start, b"abc");
        for s in 0..dfa.num_symbols {
            assert_eq!(dfa.step(q, s), q);
        }
    }

    #[test]
    fn anchored_search() {
        let dfa = compile_search("^abc").unwrap();
        assert!(dfa.accepts_bytes(b"abcxxx"));
        assert!(!dfa.accepts_bytes(b"xabc"));
        let dfa = compile_search("abc$").unwrap();
        assert!(dfa.accepts_bytes(b"xxabc"));
        assert!(!dfa.accepts_bytes(b"abcx"));
    }

    #[test]
    fn prosite_scan_semantics() {
        let dfa = compile_prosite("R-G-D.").unwrap();
        assert!(dfa.accepts_bytes(b"MKRGDAC"));
        assert!(!dfa.accepts_bytes(b"MKRGEAC"));
        let dfa = compile_prosite("<M-A.").unwrap();
        assert!(dfa.accepts_bytes(b"MACDEF"));
        assert!(!dfa.accepts_bytes(b"AMACDE"));
    }

    #[test]
    fn minimal_dfa_is_deterministic_complete() {
        let dfa = compile_search("([ab]c){2,3}|d+").unwrap();
        assert_eq!(dfa.table.len(),
                   (dfa.num_states * dfa.num_symbols) as usize);
        assert!(dfa.table.iter().all(|&t| t < dfa.num_states));
    }

    #[test]
    fn prop_exact_compile_agrees_with_nfa() {
        let patterns = [
            "a(b|c)*d", "x{2,5}y", r"\d+-\d+", "(ab|ba)+", "[a-f]{3}",
            "q?w?e?r?t?y?", "(a|b)(a|b)(a|b)",
        ];
        prop::check("compiled DFA == NFA simulation", 30, |rng| {
            let pat = patterns[rng.usize_below(patterns.len())];
            let parsed = parser::parse(pat).unwrap();
            let nfa = Nfa::from_ast(&parsed.ast);
            let dfa = compile_exact(pat).unwrap();
            for _ in 0..20 {
                let len = rng.below(10) as usize;
                let s: Vec<u8> = (0..len)
                    .map(|_| b"abcdxy0123-"[rng.usize_below(11)])
                    .collect();
                assert_eq!(nfa.accepts(&s), dfa.accepts_bytes(&s),
                           "pat={pat} s={s:?}");
            }
        });
    }
}
