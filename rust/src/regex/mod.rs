//! Regular-expression frontends: a PCRE-style parser, a PROSITE protein
//! pattern parser, and the compile pipeline regex -> NFA -> DFA -> minimal
//! DFA (the paper's Grail+ toolchain, §5).

pub mod ast;
pub mod compile;
pub mod parser;
pub mod prosite;

pub use ast::Ast;
pub use compile::{compile_exact, compile_search, CompiledPattern};
