//! PROSITE protein pattern parser (the PA lines of the PROSITE database).
//!
//! Syntax (https://prosite.expasy.org — §"PA line"):
//!   * elements separated by `-`; the pattern ends with `.`
//!   * `x` — any amino acid; `[ACD]` — one of; `{ACD}` — none of
//!   * repetition: `e(3)` exactly, `e(2,4)` between
//!   * `<` anchors at the N-terminus, `>` at the C-terminus
//!
//! Example (PS00029, leucine zipper):
//!   `L-x(6)-L-x(6)-L-x(6)-L.`
//!
//! Patterns compile to ASTs over the 20-letter amino-acid alphabet (plus
//! the wildcard letters B, Z, X which PROSITE sequences may contain).

use anyhow::{bail, Result};

use super::ast::Ast;
use crate::automata::byteset::ByteSet;

/// The 20 standard amino acids.
pub const AMINO_ACIDS: &[u8; 20] = b"ACDEFGHIKLMNPQRSTVWY";
/// Sequence alphabet: amino acids + ambiguity codes seen in SwissProt.
pub const SEQUENCE_ALPHABET: &[u8; 23] = b"ACDEFGHIKLMNPQRSTVWYBZX";

/// ByteSet of the sequence alphabet (amino acids + ambiguity codes).
pub fn amino_set() -> ByteSet {
    ByteSet::from_bytes(SEQUENCE_ALPHABET)
}

/// Parse result: AST plus terminus-anchor flags.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ParsedProsite {
    /// the signature body
    pub ast: Ast,
    /// `<` present: match must start at the sequence N-terminus
    pub anchored_start: bool,
    /// `>` present: match must end at the C-terminus
    pub anchored_end: bool,
}

/// Parse a PROSITE PA-line signature into [`ParsedProsite`].
pub fn parse(pattern: &str) -> Result<ParsedProsite> {
    let mut text = pattern.trim();
    if let Some(stripped) = text.strip_suffix('.') {
        text = stripped;
    }
    let mut anchored_start = false;
    let mut anchored_end = false;
    if let Some(stripped) = text.strip_prefix('<') {
        anchored_start = true;
        text = stripped;
    }
    if let Some(stripped) = text.strip_suffix('>') {
        anchored_end = true;
        text = stripped;
    }
    if text.is_empty() {
        bail!("empty PROSITE pattern");
    }

    let mut parts = Vec::new();
    for element in text.split('-') {
        parts.push(parse_element(element.trim())?);
    }
    Ok(ParsedProsite {
        ast: if parts.len() == 1 {
            parts.pop().unwrap()
        } else {
            Ast::Concat(parts)
        },
        anchored_start,
        anchored_end,
    })
}

fn parse_element(e: &str) -> Result<Ast> {
    if e.is_empty() {
        bail!("empty pattern element");
    }
    let b = e.as_bytes();
    let (core, rest) = parse_core(b)?;
    let (min, max) = parse_counts(rest)?;
    Ok(if (min, max) == (1, Some(1)) {
        core
    } else {
        Ast::Repeat { node: Box::new(core), min, max }
    })
}

/// Parse the residue part; return it plus the remaining repetition suffix.
fn parse_core(b: &[u8]) -> Result<(Ast, &[u8])> {
    match b[0] {
        b'x' | b'X' => Ok((Ast::Class(amino_set()), &b[1..])),
        b'[' => {
            let Some(end) = b.iter().position(|&c| c == b']') else {
                bail!("unterminated [ in PROSITE element");
            };
            let set = residue_set(&b[1..end])?;
            Ok((Ast::Class(set), &b[end + 1..]))
        }
        b'{' => {
            let Some(end) = b.iter().position(|&c| c == b'}') else {
                bail!("unterminated {{ in PROSITE element");
            };
            let excluded = residue_set(&b[1..end])?;
            // complement within the sequence alphabet, not all bytes
            let mut set = amino_set();
            for byte in excluded.iter() {
                set = {
                    let mut t = set;
                    t.0[(byte >> 6) as usize] &= !(1u64 << (byte & 63));
                    t
                };
            }
            Ok((Ast::Class(set), &b[end + 1..]))
        }
        c if c.is_ascii_uppercase() => {
            Ok((Ast::Class(ByteSet::single(c)), &b[1..]))
        }
        c => bail!("bad PROSITE element start {:?}", c as char),
    }
}

fn residue_set(inner: &[u8]) -> Result<ByteSet> {
    if inner.is_empty() {
        bail!("empty residue set");
    }
    let mut set = ByteSet::EMPTY;
    for &c in inner {
        // PROSITE uses '>' inside sets in rare C-terminal patterns like
        // [G>]; treat '>' as "end of sequence possible" — approximated by
        // ignoring it (the set keeps its other members).
        if c == b'>' {
            continue;
        }
        if !c.is_ascii_uppercase() {
            bail!("bad residue {:?}", c as char);
        }
        set.insert(c);
    }
    if set.is_empty() {
        bail!("residue set had only '>'");
    }
    Ok(set)
}

fn parse_counts(rest: &[u8]) -> Result<(u32, Option<u32>)> {
    if rest.is_empty() {
        return Ok((1, Some(1)));
    }
    if rest[0] != b'(' || *rest.last().unwrap() != b')' {
        bail!("bad repetition suffix {:?}",
              String::from_utf8_lossy(rest));
    }
    let inner = std::str::from_utf8(&rest[1..rest.len() - 1])?;
    let parse_one = |s: &str| -> Result<u32> {
        let v: u32 = s.trim().parse()?;
        if v > 2000 {
            bail!("repetition {v} too large");
        }
        Ok(v)
    };
    match inner.split_once(',') {
        None => {
            let n = parse_one(inner)?;
            Ok((n, Some(n)))
        }
        Some((lo, hi)) => {
            let lo = parse_one(lo)?;
            let hi = parse_one(hi)?;
            if hi < lo {
                bail!("reversed repetition ({lo},{hi})");
            }
            Ok((lo, Some(hi)))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::automata::nfa::Nfa;

    fn accepts(pat: &str, seq: &[u8]) -> bool {
        let p = parse(pat).unwrap();
        Nfa::from_ast(&p.ast).accepts(seq)
    }

    #[test]
    fn simple_residues() {
        // PS00016 cell attachment RGD
        assert!(accepts("R-G-D.", b"RGD"));
        assert!(!accepts("R-G-D.", b"RGE"));
    }

    #[test]
    fn wildcards_and_counts() {
        // leucine zipper
        let zip = "L-x(6)-L-x(6)-L-x(6)-L.";
        assert!(accepts(zip, b"LAAAAAALCCCCCCLDDDDDDL"));
        assert!(!accepts(zip, b"LAAAAAALCCCCCCLDDDDDL")); // one x short
    }

    #[test]
    fn ranges() {
        let p = "A-x(2,4)-C.";
        assert!(!accepts(p, b"AxC"[..3].as_ref()));
        assert!(accepts(p, b"AGGC"));
        assert!(accepts(p, b"AGGGC"));
        assert!(accepts(p, b"AGGGGC"));
        assert!(!accepts(p, b"AGGGGGC"));
        assert!(!accepts(p, b"AGC"));
    }

    #[test]
    fn sets_and_exclusions() {
        assert!(accepts("[AC]-B.", b"AB"));
        assert!(accepts("[AC]-B.", b"CB"));
        assert!(!accepts("[AC]-B.", b"DB"));
        assert!(accepts("{AC}-B.", b"DB"));
        assert!(!accepts("{AC}-B.", b"AB"));
    }

    #[test]
    fn set_repetition() {
        assert!(accepts("[LIVM](2)-K.", b"LVK"));
        assert!(!accepts("[LIVM](2)-K.", b"LAK"));
    }

    #[test]
    fn anchors_flagged() {
        let p = parse("<A-x-B.").unwrap();
        assert!(p.anchored_start && !p.anchored_end);
        let p = parse("A-x-B>.").unwrap();
        assert!(!p.anchored_start && p.anchored_end);
    }

    #[test]
    fn real_patterns_parse() {
        // a few real PROSITE signatures
        for pat in [
            "C-x-[DN]-x(4)-[FY]-x-C-x-C.",                 // PS00010 ASX
            "[RK](2)-x-[ST].",                             // PS00004-like
            "N-{P}-[ST]-{P}.",                             // PS00001 N-glyc
            "[GSTNE]-[GSTQCR]-[FYWLSP]-H-[LIVMFYW].",      // PS00028-like
            "C-x(2,4)-C-x(3)-[LIVMFYWC]-x(8)-H-x(3,5)-H.", // zinc finger C2H2
            "W-x(9,11)-[VFY]-[FYW]-x(6,7)-[GSTNE].",
        ] {
            parse(pat).unwrap_or_else(|e| panic!("{pat}: {e}"));
        }
    }

    #[test]
    fn n_glyc_semantics() {
        let p = "N-{P}-[ST]-{P}.";
        assert!(accepts(p, b"NASA"));
        assert!(accepts(p, b"NGTG"));
        assert!(!accepts(p, b"NPSA")); // P excluded at position 2
        assert!(!accepts(p, b"NASP")); // P excluded at position 4
        assert!(!accepts(p, b"NAAA")); // needs S or T at position 3
    }

    #[test]
    fn errors() {
        assert!(parse("").is_err());
        assert!(parse("a-b.").is_err()); // lowercase non-x
        assert!(parse("[.").is_err());
        assert!(parse("A-x(4,2).").is_err());
        assert!(parse("A-()").is_err());
    }
}
