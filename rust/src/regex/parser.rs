//! PCRE-style regex parser (the subset the PCRE benchmark patterns use).
//!
//! Grammar:
//! ```text
//! alt    := concat ('|' concat)*
//! concat := repeat*
//! repeat := atom ( '*' | '+' | '?' | '{m}' | '{m,}' | '{m,n}' )* '?'?  (lazy marker ignored)
//! atom   := '(' alt ')' | '[' class ']' | '.' | escape | literal-byte
//! class  := '^'? (byte | byte '-' byte | class-escape)+
//! escape := \d \D \w \W \s \S \n \r \t \f \0 \xHH or \<punct>
//! ```
//!
//! Anchors `^`/`$` are accepted at the pattern edges and simply mark the
//! pattern as edge-anchored (membership compilation handles wrapping —
//! see compile.rs).  DFA membership semantics make interior anchors
//! meaningless; they are rejected.

use anyhow::{bail, Result};

use super::ast::Ast;
use crate::automata::byteset::ByteSet;

/// Parse result: AST plus edge-anchor flags.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ParsedRegex {
    /// the pattern body
    pub ast: Ast,
    /// pattern started with '^'
    pub anchored_start: bool,
    /// pattern ended with '$'
    pub anchored_end: bool,
}

/// Parse a PCRE-style pattern into [`ParsedRegex`].
pub fn parse(pattern: &str) -> Result<ParsedRegex> {
    let bytes = pattern.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    let anchored_start = p.eat(b'^');
    let ast = p.parse_alt()?;
    let anchored_end = if p.peek() == Some(b'$') {
        p.i += 1;
        true
    } else {
        false
    };
    if p.i != p.b.len() {
        bail!("trailing input at byte {} in {pattern:?}", p.i);
    }
    Ok(ParsedRegex { ast, anchored_start, anchored_end })
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> bool {
        if self.peek() == Some(c) {
            self.i += 1;
            true
        } else {
            false
        }
    }

    fn parse_alt(&mut self) -> Result<Ast> {
        let mut alts = vec![self.parse_concat()?];
        while self.eat(b'|') {
            alts.push(self.parse_concat()?);
        }
        Ok(if alts.len() == 1 { alts.pop().unwrap() } else { Ast::Alt(alts) })
    }

    fn parse_concat(&mut self) -> Result<Ast> {
        let mut parts = Vec::new();
        while let Some(c) = self.peek() {
            if c == b'|' || c == b')' || c == b'$' {
                break;
            }
            parts.push(self.parse_repeat()?);
        }
        Ok(match parts.len() {
            0 => Ast::Epsilon,
            1 => parts.pop().unwrap(),
            _ => Ast::Concat(parts),
        })
    }

    fn parse_repeat(&mut self) -> Result<Ast> {
        let mut node = self.parse_atom()?;
        loop {
            match self.peek() {
                Some(b'*') => {
                    self.i += 1;
                    node = Ast::star(node);
                }
                Some(b'+') => {
                    self.i += 1;
                    node = Ast::plus(node);
                }
                Some(b'?') => {
                    self.i += 1;
                    node = Ast::opt(node);
                }
                Some(b'{') => {
                    let save = self.i;
                    match self.parse_bounds() {
                        Ok((min, max)) => {
                            if let Some(m) = max {
                                if m < min {
                                    bail!("bad repeat bounds {{{min},{m}}}");
                                }
                            }
                            node = Ast::Repeat {
                                node: Box::new(node),
                                min,
                                max,
                            };
                        }
                        Err(_) => {
                            // PCRE treats an unparsable '{' as a literal
                            self.i = save;
                            break;
                        }
                    }
                }
                _ => break,
            }
            // lazy quantifier marker: semantics-free for DFA membership
            if self.peek() == Some(b'?') {
                self.i += 1;
            }
        }
        Ok(node)
    }

    fn parse_bounds(&mut self) -> Result<(u32, Option<u32>)> {
        assert!(self.eat(b'{'));
        let min = self.parse_int()?;
        let out = if self.eat(b',') {
            if self.peek() == Some(b'}') {
                (min, None)
            } else {
                (min, Some(self.parse_int()?))
            }
        } else {
            (min, Some(min))
        };
        if !self.eat(b'}') {
            bail!("expected }}");
        }
        Ok(out)
    }

    fn parse_int(&mut self) -> Result<u32> {
        let start = self.i;
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.i == start {
            bail!("expected integer");
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        let v: u32 = s.parse()?;
        if v > 1000 {
            bail!("repeat bound {v} too large");
        }
        Ok(v)
    }

    fn parse_atom(&mut self) -> Result<Ast> {
        match self.peek() {
            None => bail!("unexpected end of pattern"),
            Some(b'(') => {
                self.i += 1;
                // non-capturing group markers (?: are accepted
                if self.peek() == Some(b'?') {
                    self.i += 1;
                    if !self.eat(b':') {
                        bail!("unsupported (?...) construct");
                    }
                }
                let inner = self.parse_alt()?;
                if !self.eat(b')') {
                    bail!("unbalanced (");
                }
                Ok(inner)
            }
            Some(b'[') => {
                self.i += 1;
                let set = self.parse_class()?;
                Ok(Ast::Class(set))
            }
            Some(b'.') => {
                self.i += 1;
                // '.' = any byte except newline (PCRE default)
                let mut s = ByteSet::ALL;
                s = {
                    let mut t = s;
                    t.0[(b'\n' >> 6) as usize] &= !(1u64 << (b'\n' & 63));
                    t
                };
                Ok(Ast::Class(s))
            }
            Some(b'\\') => {
                self.i += 1;
                let set = self.parse_escape()?;
                Ok(Ast::Class(set))
            }
            // '{' that failed to parse as bounds falls through to a
            // literal (PCRE behaviour), so it is NOT in this reject list.
            Some(c @ (b'*' | b'+' | b'?' | b')')) => {
                bail!("dangling metacharacter {:?}", c as char)
            }
            Some(c) => {
                self.i += 1;
                Ok(Ast::Class(ByteSet::single(c)))
            }
        }
    }

    fn parse_escape(&mut self) -> Result<ByteSet> {
        let Some(c) = self.peek() else { bail!("dangling backslash") };
        self.i += 1;
        Ok(match c {
            b'd' => ByteSet::range(b'0', b'9'),
            b'D' => ByteSet::range(b'0', b'9').negate(),
            b'w' => word_set(),
            b'W' => word_set().negate(),
            b's' => ByteSet::from_bytes(b" \t\n\r\x0b\x0c"),
            b'S' => ByteSet::from_bytes(b" \t\n\r\x0b\x0c").negate(),
            b'n' => ByteSet::single(b'\n'),
            b'r' => ByteSet::single(b'\r'),
            b't' => ByteSet::single(b'\t'),
            b'f' => ByteSet::single(0x0c),
            b'0' => ByteSet::single(0),
            b'x' => {
                let hi = self.hex_digit()?;
                let lo = self.hex_digit()?;
                ByteSet::single(hi * 16 + lo)
            }
            // punctuation escapes: \. \* \( etc.
            c if !c.is_ascii_alphanumeric() => ByteSet::single(c),
            c => bail!("unsupported escape \\{}", c as char),
        })
    }

    fn hex_digit(&mut self) -> Result<u8> {
        let Some(c) = self.peek() else { bail!("bad \\x escape") };
        self.i += 1;
        match c {
            b'0'..=b'9' => Ok(c - b'0'),
            b'a'..=b'f' => Ok(c - b'a' + 10),
            b'A'..=b'F' => Ok(c - b'A' + 10),
            _ => bail!("bad hex digit {:?}", c as char),
        }
    }

    fn parse_class(&mut self) -> Result<ByteSet> {
        let negate = self.eat(b'^');
        let mut set = ByteSet::EMPTY;
        let mut first = true;
        loop {
            let Some(c) = self.peek() else { bail!("unterminated [") };
            if c == b']' && !first {
                self.i += 1;
                break;
            }
            first = false;
            let lo = if c == b'\\' {
                self.i += 1;
                let esc = self.parse_escape()?;
                if esc.len() > 1 {
                    // class escape like \d inside []
                    set = set.union(&esc);
                    continue;
                }
                esc.first().unwrap()
            } else {
                self.i += 1;
                c
            };
            // range?
            if self.peek() == Some(b'-')
                && self.b.get(self.i + 1).map_or(false, |&n| n != b']')
            {
                self.i += 1; // '-'
                let hc = self.peek().unwrap();
                let hi = if hc == b'\\' {
                    self.i += 1;
                    let esc = self.parse_escape()?;
                    if esc.len() != 1 {
                        bail!("bad range endpoint");
                    }
                    esc.first().unwrap()
                } else {
                    self.i += 1;
                    hc
                };
                if hi < lo {
                    bail!("reversed range {}-{}", lo as char, hi as char);
                }
                set = set.union(&ByteSet::range(lo, hi));
            } else {
                set.insert(lo);
            }
        }
        Ok(if negate { set.negate() } else { set })
    }
}

fn word_set() -> ByteSet {
    ByteSet::range(b'a', b'z')
        .union(&ByteSet::range(b'A', b'Z'))
        .union(&ByteSet::range(b'0', b'9'))
        .union(&ByteSet::single(b'_'))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::automata::nfa::Nfa;

    fn accepts(pat: &str, input: &[u8]) -> bool {
        let parsed = parse(pat).unwrap();
        Nfa::from_ast(&parsed.ast).accepts(input)
    }

    #[test]
    fn literals_and_alternation() {
        assert!(accepts("abc", b"abc"));
        assert!(!accepts("abc", b"abd"));
        assert!(accepts("cat|dog", b"dog"));
        assert!(!accepts("cat|dog", b"cow"));
    }

    #[test]
    fn quantifiers() {
        assert!(accepts("a*", b""));
        assert!(accepts("a*", b"aaaa"));
        assert!(accepts("a+b", b"aab"));
        assert!(!accepts("a+b", b"b"));
        assert!(accepts("colou?r", b"color"));
        assert!(accepts("colou?r", b"colour"));
        assert!(accepts("a{2,3}", b"aa"));
        assert!(accepts("a{2,3}", b"aaa"));
        assert!(!accepts("a{2,3}", b"a"));
        assert!(!accepts("a{2,3}", b"aaaa"));
        assert!(accepts("a{3}", b"aaa"));
        assert!(accepts("a{2,}", b"aaaaaa"));
        assert!(!accepts("a{2,}", b"a"));
    }

    #[test]
    fn classes() {
        assert!(accepts("[abc]+", b"cab"));
        assert!(!accepts("[abc]+", b"cad"));
        assert!(accepts("[a-z0-9]+", b"hello42"));
        assert!(accepts("[^aeiou]", b"x"));
        assert!(!accepts("[^aeiou]", b"a"));
        assert!(accepts("[-a]", b"-")); // literal '-' at edge
        assert!(accepts("[]a]", b"]")); // ']' first is literal
    }

    #[test]
    fn escapes() {
        assert!(accepts(r"\d{3}", b"123"));
        assert!(!accepts(r"\d{3}", b"12a"));
        assert!(accepts(r"\w+", b"az_9"));
        assert!(accepts(r"\s", b" "));
        assert!(accepts(r"\.", b"."));
        assert!(!accepts(r"\.", b"a"));
        assert!(accepts(r"\x41", b"A"));
        assert!(accepts(r"[\d_]+", b"1_2"));
    }

    #[test]
    fn dot_excludes_newline() {
        assert!(accepts(".", b"x"));
        assert!(!accepts(".", b"\n"));
    }

    #[test]
    fn groups_nested() {
        assert!(accepts("(ab)+c", b"ababc"));
        assert!(accepts("(a(b|c)){2}", b"abac"));
        assert!(accepts("(?:ab|cd)*", b"abcdab"));
    }

    #[test]
    fn anchors_recorded() {
        let p = parse("^abc$").unwrap();
        assert!(p.anchored_start && p.anchored_end);
        let p = parse("abc").unwrap();
        assert!(!p.anchored_start && !p.anchored_end);
    }

    #[test]
    fn errors() {
        assert!(parse("(a").is_err());
        assert!(parse("a)").is_err());
        assert!(parse("[a").is_err());
        assert!(parse("*a").is_err());
        assert!(parse(r"\").is_err());
        assert!(parse("a{3,2}").is_err());
        assert!(parse("[z-a]").is_err());
    }

    #[test]
    fn brace_literal_fallback() {
        // PCRE treats '{' not starting a valid bound as a literal
        assert!(accepts("a{x", b"a{x"));
    }

    #[test]
    fn lazy_markers_ignored() {
        assert!(accepts("a+?b", b"aab"));
        assert!(accepts("a*?", b"aa"));
        assert!(accepts("a{1,2}?b", b"ab"));
    }
}
