//! The prior speculative parallel DFA algorithm of Holub & Štekr [19],
//! reproduced as the paper's comparator (Fig. 11).
//!
//! Differences from the paper's method (§4.1/§7):
//!  * the input is split into |P| *uniform* chunks (no work-balancing
//!    between the first and subsequent chunks), and
//!  * every chunk except the first is matched for *all* |Q| states (no
//!    structural reduction).
//!
//! Per-processor work is therefore ~ (n/|P|)·|Q| symbols, so the speedup
//! is O(|P|/|Q|) — a speed-down whenever |Q| > |P| (the paper observed
//! −390× for a 788-state DFA).

use crate::automata::{Dfa, FlatDfa};
use crate::speculative::lvector::LVector;
use crate::speculative::merge::{self, MergeStats, MergeStrategy};

/// Result of one Holub–Štekr run.
#[derive(Clone, Debug)]
pub struct HolubStekrOutcome {
    /// delta*(q0, input)
    pub final_state: u32,
    /// membership verdict
    pub accepted: bool,
    /// per-processor symbols matched (chunk_len × states matched)
    pub work: Vec<usize>,
    /// merge op counts
    pub merge_stats: MergeStats,
}

impl HolubStekrOutcome {
    /// Max symbols matched by any worker.
    pub fn makespan_syms(&self) -> usize {
        self.work.iter().copied().max().unwrap_or(0)
    }
}

/// The [19] comparator: uniform chunks × all |Q| states.
pub struct HolubStekr {
    dfa: Dfa,
    flat: FlatDfa,
    processors: usize,
}

impl HolubStekr {
    /// Build over `processors` uniform workers.
    pub fn new(dfa: &Dfa, processors: usize) -> Self {
        assert!(processors >= 1);
        HolubStekr {
            dfa: dfa.clone(),
            flat: FlatDfa::from_dfa(dfa),
            processors,
        }
    }

    /// The compiled DFA.
    pub fn dfa(&self) -> &Dfa {
        &self.dfa
    }

    /// Match pre-mapped dense symbols.
    pub fn run_syms(&self, syms: &[u32]) -> HolubStekrOutcome {
        let n = syms.len();
        let p = self.processors;
        let q = self.dfa.num_states as usize;
        // uniform chunking
        let bounds: Vec<(usize, usize)> = (0..p)
            .map(|i| (n * i / p, n * (i + 1) / p))
            .collect();

        let all_states: Vec<u32> = (0..q as u32).collect();
        let mut lvecs: Vec<LVector> = Vec::with_capacity(p);
        let mut work = Vec::with_capacity(p);
        let mut slots: Vec<Option<(LVector, usize)>> = vec![None; p];
        std::thread::scope(|scope| {
            let flat = &self.flat;
            let dfa = &self.dfa;
            let all_states = &all_states;
            for (i, (slot, &(s, e))) in
                slots.iter_mut().zip(&bounds).enumerate()
            {
                scope.spawn(move || {
                    // validate once per chunk, then the shared 8-wide
                    // width-compacted kernel; [19] has no structural
                    // reduction, so collapsing stays off (interval 0)
                    let chunk = flat.validate(&syms[s..e]);
                    let mut lv = LVector::identity(q);
                    if i == 0 {
                        crate::speculative::chunk::match_chunk_states(
                            flat,
                            &mut lv,
                            &[dfa.start],
                            chunk,
                            0,
                        );
                        *slot = Some((lv, chunk.len()));
                    } else {
                        crate::speculative::chunk::match_chunk_states(
                            flat,
                            &mut lv,
                            all_states,
                            chunk,
                            0,
                        );
                        *slot = Some((lv, chunk.len() * q));
                    }
                });
            }
        });
        for slot in slots {
            let (lv, w) = slot.unwrap();
            lvecs.push(lv);
            work.push(w);
        }

        let (final_state, merge_stats) =
            merge::merge(&lvecs, self.dfa.start, MergeStrategy::Sequential);
        HolubStekrOutcome {
            final_state,
            accepted: self.dfa.accepting[final_state as usize],
            work,
            merge_stats,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::sequential::SequentialMatcher;
    use crate::speculative::lookahead::tests::random_dfa;
    use crate::speculative::matcher::MatchPlan;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn prop_correct_but_slower() {
        prop::check("Holub-Stekr correct; work >= ours", 30, |rng| {
            let dfa = random_dfa(rng);
            let len = rng.range_usize(0, 400);
            let syms: Vec<u32> = (0..len)
                .map(|_| rng.below(dfa.num_symbols as u64) as u32)
                .collect();
            let p = rng.range_usize(1, 8);
            let hs = HolubStekr::new(&dfa, p).run_syms(&syms);
            let seq = SequentialMatcher::new(&dfa).run_syms(&syms);
            assert_eq!(hs.final_state, seq.final_state);
            assert_eq!(hs.accepted, seq.accepted);
            // our balanced partition never does more per-processor work
            let ours = MatchPlan::new(&dfa).processors(p).run_syms(&syms);
            assert!(
                ours.makespan_syms() <= hs.makespan_syms() + dfa.num_states as usize,
                "ours {} vs hs {}",
                ours.makespan_syms(),
                hs.makespan_syms()
            );
        });
    }

    #[test]
    fn speeddown_when_q_exceeds_p() {
        // |Q| = 20-ish, P = 4: per-proc work ~ n·|Q|/|P| >> n
        let mut rng = Rng::new(11);
        let dfa = random_dfa(&mut rng);
        let n = 40_000;
        let syms: Vec<u32> = (0..n)
            .map(|_| rng.below(dfa.num_symbols as u64) as u32)
            .collect();
        let hs = HolubStekr::new(&dfa, 4).run_syms(&syms);
        if dfa.num_states > 8 {
            assert!(
                hs.makespan_syms() > n,
                "expected speed-down work: {} states {}",
                hs.makespan_syms(),
                dfa.num_states
            );
        }
    }
}
