//! Sequential DFA matching: Algorithm 1 over the flattened SBase table.
//!
//! This is the paper's Listing 1: "two add operations, one comparison, one
//! indexed load and one conditional jump" per input symbol.  It is the
//! yardstick for every speedup measurement, and the inner loop reused by
//! the speculative matcher for per-chunk matching.

use crate::automata::{Dfa, FlatDfa};

/// Result of a sequential run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SeqOutcome {
    /// delta*(q0, input)
    pub final_state: u32,
    /// final_state in F
    pub accepted: bool,
    /// symbols actually consumed (< input length iff early exit fired)
    pub consumed: usize,
}

/// Listing-1 sequential matcher over the flattened table.
#[derive(Clone, Debug)]
pub struct SequentialMatcher {
    flat: FlatDfa,
}

impl SequentialMatcher {
    /// Build (and flatten) from a compiled DFA.
    pub fn new(dfa: &Dfa) -> Self {
        SequentialMatcher { flat: FlatDfa::from_dfa(dfa) }
    }

    /// The flattened table (shared with per-chunk matching loops).
    pub fn flat(&self) -> &FlatDfa {
        &self.flat
    }

    /// Plain Listing-1 run over pre-mapped dense symbols: no early exit,
    /// computes delta*(start, syms).  This is the hot loop.
    #[inline]
    pub fn run_syms(&self, syms: &[u32]) -> SeqOutcome {
        let off = self.flat.run_syms(self.flat.start_off, syms);
        SeqOutcome {
            final_state: self.flat.state_of(off),
            accepted: self.flat.is_accepting_off(off),
            consumed: syms.len(),
        }
    }

    /// Run over raw bytes (IBase class mapping fused into the loop).
    #[inline]
    pub fn run_bytes(&self, bytes: &[u8]) -> SeqOutcome {
        let off = self.flat.run_bytes(self.flat.start_off, bytes);
        SeqOutcome {
            final_state: self.flat.state_of(off),
            accepted: self.flat.is_accepting_off(off),
            consumed: bytes.len(),
        }
    }

    /// Algorithm 1 with the early exits: return on reaching a final state
    /// (line 4–5; sound for absorbing-final search DFAs) and on reaching
    /// the sink (§3: "it is unnecessary to process the remaining input
    /// characters once the error state has been reached").
    pub fn run_early_exit(&self, bytes: &[u8]) -> SeqOutcome {
        let flat = &self.flat;
        if flat.is_accepting_off(flat.start_off) {
            return SeqOutcome {
                final_state: flat.state_of(flat.start_off),
                accepted: true,
                consumed: 0,
            };
        }
        let (off, consumed) = flat.run_bytes_until(flat.start_off, bytes);
        SeqOutcome {
            final_state: flat.state_of(off),
            accepted: flat.is_accepting_off(off),
            consumed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::regex::compile::{compile_search, compile_exact};
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn matches_dfa_run() {
        let dfa = compile_search("ab+c").unwrap();
        let m = SequentialMatcher::new(&dfa);
        for input in [&b"xxabbbczz"[..], b"abc", b"", b"nope"] {
            let out = m.run_bytes(input);
            assert_eq!(out.accepted, dfa.accepts_bytes(input));
            assert_eq!(out.final_state, dfa.run_bytes(dfa.start, input));
        }
    }

    #[test]
    fn early_exit_agrees_on_search_dfas() {
        let dfa = compile_search("needle").unwrap();
        let m = SequentialMatcher::new(&dfa);
        let mut input = vec![b'x'; 10_000];
        input.extend_from_slice(b"needle");
        input.extend(vec![b'y'; 10_000]);
        let full = m.run_bytes(&input);
        let fast = m.run_early_exit(&input);
        assert!(full.accepted && fast.accepted);
        assert!(fast.consumed < input.len());
        assert_eq!(fast.consumed, 10_006);
    }

    #[test]
    fn early_exit_sink_shortcut() {
        // exact-match DFA sinks on first mismatch
        let dfa = compile_exact("abc").unwrap();
        let m = SequentialMatcher::new(&dfa);
        let mut input = vec![b'z'; 1000];
        input[0] = b'a';
        let fast = m.run_early_exit(&input);
        assert!(!fast.accepted);
        assert!(fast.consumed <= 2);
    }

    #[test]
    fn prop_syms_equals_bytes() {
        prop::check("run_syms == run_bytes", 20, |rng: &mut Rng| {
            let dfa = compile_search("(ab|cd)+e?").unwrap();
            let m = SequentialMatcher::new(&dfa);
            let len = rng.below(200) as usize;
            let bytes: Vec<u8> =
                (0..len).map(|_| b"abcdex"[rng.usize_below(6)]).collect();
            let syms = dfa.map_input(&bytes);
            assert_eq!(m.run_syms(&syms), m.run_bytes(&bytes));
        });
    }
}
