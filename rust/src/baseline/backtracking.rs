//! Perl-style backtracking regex engine — the ScanProsite stand-in for
//! Fig. 12(a).
//!
//! ScanProsite [14,39] is implemented in Perl, whose regex engine performs
//! recursive backtracking and, for an unanchored pattern, re-scans from
//! every input position.  This engine reproduces exactly that execution
//! model (same asymptotic class, same per-position restart behaviour), so
//! the speedup ratios of Fig. 12 are driven by the same mechanism as in
//! the paper: per-byte interpretive overhead × positions × backtracking.
//!
//! A fuel counter guards against the exponential blowup cases so the
//! benchmark harness can cap runtimes; `None` = ran out of fuel.
//! Every construction path clamps the budget to [`MAX_FUEL`], so a
//! ReDoS-shaped pattern (`(a|a)*b`, `(a+)+b`, …) terminates with a
//! budget error in bounded time instead of hanging the caller — there
//! is no unbounded configuration anymore.

use crate::regex::ast::Ast;

/// Hard ceiling on the step budget.  2³⁰ recursive `match_node` calls
/// is seconds of wall-clock on any host this runs on — far above every
/// legitimate polynomial workload in the repo (the Fig. 12 corpora
/// spend ~10⁸ steps) and far below the 2⁶⁴-shaped blowups the
/// adversarial ReDoS corpus produces.  [`Backtracker::new`] and
/// [`Backtracker::with_fuel`] both clamp to it.
pub const MAX_FUEL: u64 = 1 << 30;

/// Recursive backtracking matcher over a pattern AST.
pub struct Backtracker<'a> {
    ast: &'a Ast,
    fuel: u64,
}

/// Result + work metric of one backtracking run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BacktrackStats {
    /// recursive match() invocations — the work metric
    pub steps: u64,
    /// whether a match was found
    pub matched: bool,
}

impl<'a> Backtracker<'a> {
    /// Engine with the default budget ([`MAX_FUEL`]).  There is
    /// deliberately no unbounded constructor: pre-cap, this was
    /// `fuel = u64::MAX`, and one `(a|a)*b`-shaped pattern reaching it
    /// through any call path would hang CI forever.
    pub fn new(ast: &'a Ast) -> Self {
        Backtracker { ast, fuel: MAX_FUEL }
    }

    /// Engine with a step budget; exceeding it aborts with `None`.
    /// Budgets above [`MAX_FUEL`] are clamped — the cap is a hard
    /// guarantee, not a default.
    pub fn with_fuel(ast: &'a Ast, fuel: u64) -> Self {
        Backtracker { ast, fuel: fuel.min(MAX_FUEL) }
    }

    /// The effective step budget (post-clamp).
    pub fn budget(&self) -> u64 {
        self.fuel
    }

    /// Whole-input match (anchored at both ends).
    pub fn is_match(&self, input: &[u8]) -> Option<BacktrackStats> {
        let mut steps = 0u64;
        let ok = match_node(
            self.ast,
            input,
            0,
            &mut steps,
            self.fuel,
            &mut |pos, steps_ref| {
                let _ = steps_ref;
                pos == input.len()
            },
        )?;
        Some(BacktrackStats { steps, matched: ok })
    }

    /// Match starting exactly at `start`, any suffix allowed (one step of
    /// the Perl scan loop).
    pub fn search_at(
        &self,
        input: &[u8],
        start: usize,
    ) -> Option<BacktrackStats> {
        let mut steps = 0u64;
        let ok = match_node(
            self.ast,
            input,
            start,
            &mut steps,
            self.fuel,
            &mut |_pos, _| true,
        )?;
        Some(BacktrackStats { steps, matched: ok })
    }

    /// Unanchored search: try to match at every start position, first
    /// match wins (the Perl `/pattern/` scan loop).
    pub fn search(&self, input: &[u8]) -> Option<BacktrackStats> {
        let mut total_steps = 0u64;
        for start in 0..=input.len() {
            let mut steps = 0u64;
            let ok = match_node(
                self.ast,
                input,
                start,
                &mut steps,
                self.fuel.saturating_sub(total_steps),
                &mut |_pos, _| true, // any suffix completes a search match
            )?;
            total_steps += steps;
            if ok {
                return Some(BacktrackStats {
                    steps: total_steps,
                    matched: true,
                });
            }
        }
        Some(BacktrackStats { steps: total_steps, matched: false })
    }
}

/// CPS backtracking matcher: `k(pos)` is the continuation deciding whether
/// the rest of the input completes the match.
fn match_node(
    ast: &Ast,
    input: &[u8],
    pos: usize,
    steps: &mut u64,
    fuel: u64,
    k: &mut dyn FnMut(usize, &mut u64) -> bool,
) -> Option<bool> {
    *steps += 1;
    if *steps > fuel {
        return None; // out of fuel: caller treats as "too slow"
    }
    match ast {
        Ast::Empty => Some(false),
        Ast::Epsilon => Some(k(pos, steps)),
        Ast::Class(set) => {
            if pos < input.len() && set.contains(input[pos]) {
                Some(k(pos + 1, steps))
            } else {
                Some(false)
            }
        }
        Ast::Concat(parts) => match_seq(parts, input, pos, steps, fuel, k),
        Ast::Alt(alts) => {
            for a in alts {
                if match_node(a, input, pos, steps, fuel, k)? {
                    return Some(true);
                }
            }
            Some(false)
        }
        Ast::Repeat { node, min, max } => {
            match_repeat(node, *min, *max, input, pos, steps, fuel, k)
        }
    }
}

fn match_seq(
    parts: &[Ast],
    input: &[u8],
    pos: usize,
    steps: &mut u64,
    fuel: u64,
    k: &mut dyn FnMut(usize, &mut u64) -> bool,
) -> Option<bool> {
    match parts.split_first() {
        None => Some(k(pos, steps)),
        Some((head, rest)) => {
            // propagate fuel exhaustion through the continuation via a flag
            let mut exhausted = false;
            let out = match_node(head, input, pos, steps, fuel, &mut |p, st| {
                match match_seq(rest, input, p, st, fuel, k) {
                    Some(b) => b,
                    None => {
                        exhausted = true;
                        true // unwind quickly
                    }
                }
            })?;
            if exhausted {
                None
            } else {
                Some(out)
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn match_repeat(
    node: &Ast,
    min: u32,
    max: Option<u32>,
    input: &[u8],
    pos: usize,
    steps: &mut u64,
    fuel: u64,
    k: &mut dyn FnMut(usize, &mut u64) -> bool,
) -> Option<bool> {
    // greedy: try to consume as many copies as possible, backtracking down
    fn go(
        node: &Ast,
        taken: u32,
        min: u32,
        max: Option<u32>,
        input: &[u8],
        pos: usize,
        steps: &mut u64,
        fuel: u64,
        k: &mut dyn FnMut(usize, &mut u64) -> bool,
    ) -> Option<bool> {
        let can_take_more = max.map_or(true, |m| taken < m);
        if can_take_more {
            let mut exhausted = false;
            let advanced =
                match_node(node, input, pos, steps, fuel, &mut |p, st| {
                    if p == pos {
                        return false; // null-width loop guard
                    }
                    match go(node, taken + 1, min, max, input, p, st, fuel, k)
                    {
                        Some(b) => b,
                        None => {
                            exhausted = true;
                            true
                        }
                    }
                })?;
            if exhausted {
                return None;
            }
            if advanced {
                return Some(true);
            }
        }
        if taken >= min {
            Some(k(pos, steps))
        } else {
            Some(false)
        }
    }
    go(node, 0, min, max, input, pos, steps, fuel, k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::regex::compile::{compile_exact, compile_search};
    use crate::regex::parser;
    use crate::util::prop;

    fn bt_match(pat: &str, input: &[u8]) -> bool {
        let p = parser::parse(pat).unwrap();
        Backtracker::new(&p.ast).is_match(input).unwrap().matched
    }

    fn bt_search(pat: &str, input: &[u8]) -> bool {
        let p = parser::parse(pat).unwrap();
        Backtracker::new(&p.ast).search(input).unwrap().matched
    }

    #[test]
    fn basic_semantics() {
        assert!(bt_match("a*bc*", b"aaabccc"));
        assert!(!bt_match("a*bc*", b"aaacccb"));
        assert!(bt_match("(ab|cd)+", b"abcdab"));
        assert!(!bt_match("(ab|cd)+", b""));
        assert!(bt_match("x{2,3}", b"xxx"));
        assert!(!bt_match("x{2,3}", b"xxxx"));
    }

    #[test]
    fn search_vs_match() {
        assert!(bt_search("needle", b"hay needle hay"));
        assert!(!bt_match("needle", b"hay needle hay"));
        assert!(!bt_search("needle", b"haystack"));
    }

    #[test]
    fn prop_agrees_with_dfa() {
        let pats = ["a(b|c)*d", "x{1,3}y?", "(ab)+|(ba)+", "[abc]{2}d"];
        prop::check("backtracker == DFA", 30, |rng| {
            let pat = pats[rng.usize_below(pats.len())];
            let len = rng.below(12) as usize;
            let s: Vec<u8> =
                (0..len).map(|_| b"abcdxy"[rng.usize_below(6)]).collect();
            let dfa_exact = compile_exact(pat).unwrap();
            assert_eq!(bt_match(pat, &s), dfa_exact.accepts_bytes(&s),
                       "match {pat} {s:?}");
            let dfa_search = compile_search(pat).unwrap();
            assert_eq!(bt_search(pat, &s), dfa_search.accepts_bytes(&s),
                       "search {pat} {s:?}");
        });
    }

    #[test]
    fn null_width_star_terminates() {
        assert!(bt_match("(a*)*b", b"aaab"));
        assert!(!bt_match("(a*)*b", b"aaac"));
    }

    #[test]
    fn fuel_exhaustion_detected() {
        // classic catastrophic backtracking: (a+)+b vs aaaa...c
        let p = parser::parse("(a+)+b").unwrap();
        let input = vec![b'a'; 28];
        let bt = Backtracker::with_fuel(&p.ast, 100_000);
        assert!(bt.is_match(&input).is_none(), "should run out of fuel");
    }

    #[test]
    fn redos_alternation_is_budget_capped_by_default() {
        // regression: `(a|a)*b` doubles the search tree per `a`, so on
        // a 64-`a` input an unbounded run needs ~2^64 steps — the
        // pre-fix `Backtracker::new` (fuel = u64::MAX) would hang here
        // for centuries.  The hard cap turns it into a budget error.
        let p = parser::parse("(a|a)*b").unwrap();
        let bt = Backtracker::new(&p.ast);
        assert_eq!(bt.budget(), MAX_FUEL, "default budget must be capped");
        // behavioral check at a small explicit budget: the blowup is
        // detected and reported as None, not a hang or a wrong verdict
        let input = vec![b'a'; 64];
        let small = Backtracker::with_fuel(&p.ast, 200_000);
        assert!(
            small.is_match(&input).is_none(),
            "exponential alternation must exhaust the budget"
        );
        // explicit budgets cannot opt back out of the cap
        let huge = Backtracker::with_fuel(&p.ast, u64::MAX);
        assert_eq!(huge.budget(), MAX_FUEL, "u64::MAX must clamp");
    }

    #[test]
    fn capped_budget_still_answers_polynomial_patterns() {
        // the cap must be invisible to legitimate workloads: a linear
        // pattern completes far under MAX_FUEL (repeat count kept small
        // — the CPS matcher's stack depth grows with each iteration)
        let p = parser::parse("(ab|cd)+e").unwrap();
        let mut input = Vec::new();
        for _ in 0..300 {
            input.extend_from_slice(b"ab");
        }
        input.push(b'e');
        let stats = Backtracker::new(&p.ast).is_match(&input).unwrap();
        assert!(stats.matched);
        assert!(stats.steps < MAX_FUEL / 2, "steps={}", stats.steps);
    }

    #[test]
    fn steps_grow_with_positions() {
        // unanchored search on a non-matching input is Θ(n·cost(pattern))
        let p = parser::parse("abc").unwrap();
        let bt = Backtracker::new(&p.ast);
        let short = bt.search(&vec![b'z'; 100]).unwrap().steps;
        let long = bt.search(&vec![b'z'; 1000]).unwrap().steps;
        assert!(long > short * 5, "short={short} long={long}");
    }
}
