//! grep-style matcher — the UNIX grep stand-in for Fig. 12(b).
//!
//! GNU grep builds a DFA and uses Boyer–Moore on a required literal to
//! skip input [17]; it is much faster than Perl but still pays per-line /
//! per-candidate verification overhead.  This engine reproduces that
//! architecture honestly:
//!
//!  * extract a mandatory literal factor from the AST (if any),
//!  * Boyer–Moore–Horspool scan for candidate positions,
//!  * verify candidates with a bounded backtracking match,
//!  * fall back to a per-position NFA (Thompson) simulation when the
//!    pattern has no usable literal.
//!
//! The point of the comparison (as in the paper) is architectural: a
//! per-candidate engine does strictly more work per byte than the paper's
//! single-pass table loop, and cannot be parallelized by chunking without
//! the speculation machinery.

use crate::automata::byteset::ByteSet;
use crate::baseline::backtracking::Backtracker;
use crate::regex::ast::Ast;

/// Literal-prefilter engine over a pattern AST.
pub struct GrepLike<'a> {
    ast: &'a Ast,
    literal: Option<Vec<u8>>,
}

/// Result + work metric of one grep-like search.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GrepStats {
    /// whether a match was found
    pub matched: bool,
    /// bytes inspected by the BMH scan + verifier steps (work metric)
    pub work: u64,
    /// BMH candidate positions verified
    pub candidates: u64,
}

impl<'a> GrepLike<'a> {
    /// Build the engine, extracting the required literal if any.
    pub fn new(ast: &'a Ast) -> Self {
        GrepLike { ast, literal: required_literal(ast) }
    }

    /// The mandatory literal factor the BMH scan uses, if one exists.
    pub fn required_literal(&self) -> Option<&[u8]> {
        self.literal.as_deref()
    }

    /// Does `input` contain a match of the pattern?
    pub fn search(&self, input: &[u8]) -> GrepStats {
        match &self.literal {
            Some(lit) if !lit.is_empty() => {
                self.search_with_literal(input, lit)
            }
            _ => self.search_nfa(input),
        }
    }

    fn search_with_literal(&self, input: &[u8], lit: &[u8]) -> GrepStats {
        let mut work = 0u64;
        let mut candidates = 0u64;
        let mut from = 0usize;
        while let Some(hit) = bmh_find(&mut work, input, lit, from) {
            candidates += 1;
            // verify: some match must straddle this literal occurrence;
            // try all starts up to the literal hit (bounded by pattern
            // reach, approximated by scanning backwards a window)
            let bt = Backtracker::with_fuel(self.ast, 1_000_000);
            let lo = hit.saturating_sub(4096);
            for start in lo..=hit {
                if let Some(stats) = suffix_match(&bt, input, start) {
                    work += stats.0;
                    if stats.1 {
                        return GrepStats { matched: true, work, candidates };
                    }
                } else {
                    break; // fuel exceeded — stop verifying this candidate
                }
            }
            from = hit + 1;
        }
        GrepStats { matched: false, work, candidates }
    }

    fn search_nfa(&self, input: &[u8]) -> GrepStats {
        // Thompson simulation restarted at every position (grep's slow
        // path for literal-free patterns on short inputs)
        use crate::automata::nfa::Nfa;
        let nfa = Nfa::from_ast(self.ast);
        let mut work = 0u64;
        for start in 0..=input.len() {
            let mut cur = nfa.eps_closure(&[nfa.start]);
            if cur.contains(&nfa.accept) {
                return GrepStats { matched: true, work, candidates: 0 };
            }
            for &b in &input[start..] {
                work += cur.len() as u64;
                let mut nxt: Vec<u32> = Vec::new();
                for &s in &cur {
                    for &(set, t) in &nfa.trans[s as usize] {
                        if set.contains(b) && !nxt.contains(&t) {
                            nxt.push(t);
                        }
                    }
                }
                cur = nfa.eps_closure(&nxt);
                if cur.contains(&nfa.accept) {
                    return GrepStats { matched: true, work, candidates: 0 };
                }
                if cur.is_empty() {
                    break;
                }
            }
        }
        GrepStats { matched: false, work, candidates: 0 }
    }
}

/// Match the pattern starting exactly at `start` with any suffix allowed.
/// Returns (steps, matched), or None on fuel exhaustion.
fn suffix_match(
    bt: &Backtracker,
    input: &[u8],
    start: usize,
) -> Option<(u64, bool)> {
    let st = bt.search_at(input, start)?;
    Some((st.steps, st.matched))
}

/// Boyer–Moore–Horspool: find `needle` in `haystack[from..]`, counting
/// inspected bytes into `work`.
fn bmh_find(
    work: &mut u64,
    haystack: &[u8],
    needle: &[u8],
    from: usize,
) -> Option<usize> {
    let n = haystack.len();
    let m = needle.len();
    if m == 0 || from + m > n {
        return None;
    }
    // bad-character shift table
    let mut shift = [m; 256];
    for (i, &b) in needle[..m - 1].iter().enumerate() {
        shift[b as usize] = m - 1 - i;
    }
    let mut pos = from;
    while pos + m <= n {
        let last = haystack[pos + m - 1];
        *work += 1;
        if last == needle[m - 1] {
            let mut i = m - 1;
            while i > 0 && haystack[pos + i - 1] == needle[i - 1] {
                *work += 1;
                i -= 1;
            }
            if i == 0 {
                return Some(pos);
            }
        }
        pos += shift[last as usize];
    }
    None
}

/// Extract a mandatory literal factor: a byte string every match must
/// contain.  Conservative (None when unsure).
pub fn required_literal(ast: &Ast) -> Option<Vec<u8>> {
    fn singleton(set: &ByteSet) -> Option<u8> {
        if set.len() == 1 { set.first() } else { None }
    }
    fn walk(ast: &Ast) -> Option<Vec<u8>> {
        match ast {
            Ast::Class(set) => singleton(set).map(|b| vec![b]),
            Ast::Concat(parts) => {
                // longest run of singleton classes anywhere in the concat
                let mut best: Vec<u8> = Vec::new();
                let mut cur: Vec<u8> = Vec::new();
                for p in parts {
                    match p {
                        Ast::Class(set) => {
                            if let Some(b) = singleton(set) {
                                cur.push(b);
                                continue;
                            }
                            if cur.len() > best.len() {
                                best = std::mem::take(&mut cur);
                            } else {
                                cur.clear();
                            }
                        }
                        Ast::Repeat { node, min, max }
                            if *min >= 1 && *max == Some(*min) =>
                        {
                            // exact repeat: node^min is fully mandatory and
                            // contiguous on both sides
                            if let Some(lit) = walk(node) {
                                for _ in 0..*min {
                                    cur.extend_from_slice(&lit);
                                }
                                continue;
                            }
                            if cur.len() > best.len() {
                                best = std::mem::take(&mut cur);
                            } else {
                                cur.clear();
                            }
                        }
                        Ast::Repeat { node, min, .. } if *min >= 1 => {
                            // variable repeat: the first copy is contiguous
                            // with the prefix, but nothing after it is
                            if let Some(mut lit) = walk(node) {
                                cur.append(&mut lit);
                            }
                            if cur.len() > best.len() {
                                best = std::mem::take(&mut cur);
                            } else {
                                cur.clear();
                            }
                        }
                        _ => {
                            if cur.len() > best.len() {
                                best = std::mem::take(&mut cur);
                            } else {
                                cur.clear();
                            }
                        }
                    }
                }
                if cur.len() > best.len() {
                    best = cur;
                }
                if best.is_empty() { None } else { Some(best) }
            }
            Ast::Repeat { node, min, .. } if *min >= 1 => walk(node),
            _ => None,
        }
    }
    walk(ast).filter(|l| !l.is_empty())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::regex::compile::compile_search;
    use crate::regex::parser;
    use crate::util::prop;

    fn grep(pat: &str, input: &[u8]) -> bool {
        let p = parser::parse(pat).unwrap();
        GrepLike::new(&p.ast).search(input).matched
    }

    #[test]
    fn literal_extraction() {
        let p = parser::parse("xa+needle[0-9]?").unwrap();
        let lit = required_literal(&p.ast).unwrap();
        assert_eq!(lit, b"needle".to_vec());
        let p = parser::parse("(a|b)c*").unwrap();
        assert!(required_literal(&p.ast).is_none());
    }

    #[test]
    fn bmh_finds_all() {
        let mut w = 0;
        assert_eq!(bmh_find(&mut w, b"hello world", b"world", 0), Some(6));
        assert_eq!(bmh_find(&mut w, b"aaaa", b"aa", 1), Some(1));
        assert_eq!(bmh_find(&mut w, b"abc", b"d", 0), None);
        assert_eq!(bmh_find(&mut w, b"abc", b"abcd", 0), None);
    }

    #[test]
    fn search_semantics() {
        assert!(grep("needle", b"hay needle hay"));
        assert!(!grep("needle", b"haystack"));
        assert!(grep("a+b", b"xxaaabyy"));
        assert!(grep("(a|b)+", b"zzzazz")); // NFA fallback path
        assert!(!grep("(a|b)+c", b"zzz"));
    }

    #[test]
    fn prop_agrees_with_dfa_search() {
        let pats = ["abc", "a+b", "ne{2}dle", "(cat|dog)s?", "[0-9]+x"];
        prop::check("greplike == DFA search", 30, |rng| {
            let pat = pats[rng.usize_below(pats.len())];
            let len = rng.below(60) as usize;
            let s: Vec<u8> = (0..len)
                .map(|_| b"abcdnes togx0123 "[rng.usize_below(17)])
                .collect();
            let dfa = compile_search(pat).unwrap();
            assert_eq!(grep(pat, &s), dfa.accepts_bytes(&s),
                       "pat={pat} s={:?}", String::from_utf8_lossy(&s));
        });
    }
}
