//! Baseline matchers the paper compares against:
//!
//! * [`sequential`] — the efficient C-style sequential matcher of
//!   Listing 1, the yardstick every speedup in §6 is measured against.
//! * [`holub_stekr`] — the prior speculative parallel algorithm [19]
//!   (uniform chunks, all |Q| states matched per chunk), reproduced for
//!   Fig. 11.
//! * [`backtracking`] — a Perl-style backtracking engine standing in for
//!   ScanProsite (Fig. 12a).
//! * [`greplike`] — a grep-style engine (per-position DFA scan with a
//!   memchr-style literal prefilter) standing in for UNIX grep (Fig. 12b).

pub mod backtracking;
pub mod greplike;
pub mod holub_stekr;
pub mod sequential;

pub use sequential::SequentialMatcher;
