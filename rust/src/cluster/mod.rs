//! Cloud computing environments: the simulated EC2 substitute and a
//! real multi-process cluster.
//!
//! The paper's cloud experiments (§5.2, §6.2) measure quantities that are
//! functions of (a) per-core matching capacity, (b) message latency
//! distributions, and (c) the merge topology.  All three are modelled in
//! [`cloud`]/[`network`]/[`node`] with the paper's own measured
//! parameters:
//!
//!  * inter-node L-vector transfer: mean 362 µs, σ = 3.6 %
//!  * intra-node L-vector transfer: mean 2.68 µs, σ = 0.14 %
//!  * cc2.8xlarge : m2.4xlarge capacity ratio 1.41
//!  * hypervisor preemption: without the leave-one-core-idle rule, one
//!    worker per node may run an order of magnitude slower
//!
//! In the simulated path, matching is executed for real (results are
//! bit-identical to the sequential matcher — failure-freedom is
//! preserved); only the *timing* of the parallel execution is simulated,
//! since the build host exposes a single physical core (see DESIGN.md
//! §Substitutions).
//!
//! The [`proc`] module replaces the timing model with actual deployment:
//! `specdfa worker` processes speak the length-framed [`proto`] protocol
//! over Unix/TCP sockets, and a [`ProcCluster`] frontend partitions,
//! retries, fails over between them, and — under total cluster loss —
//! degrades to an in-process match.  [`fault`] makes every failure mode
//! deterministically injectable.

// Cluster code runs unattended across process boundaries: a panic in
// the frontend kills live requests, so `unwrap`/`expect` are banned in
// non-test code (clippy.toml `disallowed-methods`).
#![deny(clippy::disallowed_methods)]

pub mod cloud;
pub mod fault;
pub mod network;
pub mod node;
pub mod proc;
pub mod proto;

pub use cloud::{CloudMatcher, CloudOutcome};
pub use fault::FaultPlan;
pub use network::LatencyModel;
pub use node::{ClusterSpec, InstanceType, NodeSpec};
pub use proc::{ClusterStats, ProcCluster, ProcConfig, ProcOutcome};
