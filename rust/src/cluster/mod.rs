//! Simulated cloud computing environment — the EC2 substitute.
//!
//! The paper's cloud experiments (§5.2, §6.2) measure quantities that are
//! functions of (a) per-core matching capacity, (b) message latency
//! distributions, and (c) the merge topology.  All three are modelled here
//! with the paper's own measured parameters:
//!
//!  * inter-node L-vector transfer: mean 362 µs, σ = 3.6 %
//!  * intra-node L-vector transfer: mean 2.68 µs, σ = 0.14 %
//!  * cc2.8xlarge : m2.4xlarge capacity ratio 1.41
//!  * hypervisor preemption: without the leave-one-core-idle rule, one
//!    worker per node may run an order of magnitude slower
//!
//! Matching itself is executed for real (results are bit-identical to the
//! sequential matcher — failure-freedom is preserved); only the *timing*
//! of the parallel execution is simulated, since the build host exposes a
//! single physical core (see DESIGN.md §Substitutions).

pub mod cloud;
pub mod network;
pub mod node;

pub use cloud::{CloudMatcher, CloudOutcome};
pub use network::LatencyModel;
pub use node::{ClusterSpec, InstanceType, NodeSpec};
