//! Node and cluster specifications (Table 2's instance types).

/// EC2 instance families used in the paper (Table 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InstanceType {
    /// cc2.8xlarge: 2× Xeon E5-2670, 16 cores, 88 CUs — "Fast" in Table 3
    Cc28xlarge,
    /// m2.4xlarge: 2× Xeon X5550, 8 cores, 26 CUs — "Slow" in Table 3
    M24xlarge,
    /// custom capacity (heterogeneous clusters beyond the paper's two)
    Custom,
}

/// One cluster node.
#[derive(Clone, Debug)]
pub struct NodeSpec {
    /// EC2 instance family
    pub instance: InstanceType,
    /// physical cores of the instance
    pub cores: usize,
    /// per-core matching capacity relative to the m2.4xlarge baseline;
    /// the paper measured cc2.8xlarge/m2.4xlarge = 1.41
    pub capacity: f64,
}

impl NodeSpec {
    /// The paper's "Fast" instance (Table 2).
    pub fn cc2_8xlarge() -> NodeSpec {
        NodeSpec { instance: InstanceType::Cc28xlarge, cores: 16, capacity: 1.41 }
    }

    /// The paper's "Slow" instance (Table 2).
    pub fn m2_4xlarge() -> NodeSpec {
        NodeSpec { instance: InstanceType::M24xlarge, cores: 8, capacity: 1.0 }
    }

    /// An arbitrary node shape for heterogeneous clusters.
    pub fn custom(cores: usize, capacity: f64) -> NodeSpec {
        assert!(cores >= 1 && capacity > 0.0);
        NodeSpec { instance: InstanceType::Custom, cores, capacity }
    }
}

/// A cluster: a list of nodes plus the allocation policy.
#[derive(Clone, Debug)]
pub struct ClusterSpec {
    /// the cluster's nodes, in worker order
    pub nodes: Vec<NodeSpec>,
    /// §5.2: leave one core per node unallocated to dodge hypervisor
    /// preemption (the paper's finding; default true)
    pub leave_one_core_idle: bool,
    /// probability that a node suffers a preempted (10× slower) worker
    /// when all cores are allocated
    pub preemption_prob: f64,
    /// capacity jitter across cluster invocations (§6.2: "capacities of
    /// cluster nodes could change slightly across cluster invocations")
    pub capacity_jitter: f64,
}

impl ClusterSpec {
    /// A cluster over the given nodes with the paper's default policy.
    pub fn new(nodes: Vec<NodeSpec>) -> ClusterSpec {
        assert!(!nodes.is_empty());
        ClusterSpec {
            nodes,
            leave_one_core_idle: true,
            preemption_prob: 0.9,
            capacity_jitter: 0.02,
        }
    }

    /// The paper's main cloud setup: `n` cc2.8xlarge instances.
    pub fn homogeneous(n: usize) -> ClusterSpec {
        ClusterSpec::new(vec![NodeSpec::cc2_8xlarge(); n])
    }

    /// Table 3 mixes: `fast` cc2.8xlarge + `slow` m2.4xlarge instances.
    pub fn fast_slow(fast: usize, slow: usize) -> ClusterSpec {
        let mut nodes = vec![NodeSpec::cc2_8xlarge(); fast];
        nodes.extend(vec![NodeSpec::m2_4xlarge(); slow]);
        ClusterSpec::new(nodes)
    }

    /// Allocate every core (drops the §5.2 leave-one-idle rule).
    pub fn allocate_all_cores(mut self) -> Self {
        self.leave_one_core_idle = false;
        self
    }

    /// Worker slots: (node_id, per-core capacity) per allocated core.
    pub fn workers(&self) -> Vec<(usize, f64)> {
        let mut out = Vec::new();
        for (id, node) in self.nodes.iter().enumerate() {
            let cores = if self.leave_one_core_idle {
                node.cores.saturating_sub(1).max(1)
            } else {
                node.cores
            };
            for _ in 0..cores {
                out.push((id, node.capacity));
            }
        }
        out
    }

    /// Total allocated worker slots across the cluster.
    pub fn total_workers(&self) -> usize {
        self.workers().len()
    }

    /// Cores per node actually allocated (|C| of Fig. 9) — assumes a
    /// homogeneous-core cluster layout for the 2-tier merge grouping.
    pub fn cores_per_node(&self) -> usize {
        let node = &self.nodes[0];
        if self.leave_one_core_idle {
            node.cores.saturating_sub(1).max(1)
        } else {
            node.cores
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_cluster_300_cores() {
        // §6.2: 20 cc2.8xlarge × 15 allocated cores = 300
        let c = ClusterSpec::homogeneous(20);
        assert_eq!(c.total_workers(), 300);
        assert_eq!(c.cores_per_node(), 15);
    }

    #[test]
    fn all_cores_allocation() {
        let c = ClusterSpec::homogeneous(2).allocate_all_cores();
        assert_eq!(c.total_workers(), 32);
    }

    #[test]
    fn fast_slow_mix() {
        // Table 3 row "4 fast, 1 slow"
        let c = ClusterSpec::fast_slow(4, 1);
        assert_eq!(c.nodes.len(), 5);
        let w = c.workers();
        assert_eq!(w.len(), 4 * 15 + 7);
        assert!(w.iter().filter(|(_, cap)| *cap > 1.0).count() == 60);
    }

    #[test]
    fn capacity_ratio_paper_measured() {
        let fast = NodeSpec::cc2_8xlarge();
        let slow = NodeSpec::m2_4xlarge();
        assert!((fast.capacity / slow.capacity - 1.41).abs() < 1e-12);
    }
}
