//! The `SDPF` frame protocol spoken between a [`super::proc::ProcCluster`]
//! frontend and its `specdfa worker` processes.
//!
//! Everything on the wire is a length-framed message with a fixed
//! 11-byte header, versioned exactly like the `SDCK` checkpoint frame
//! ([`crate::engine::stream::Checkpoint`]) it transports:
//!
//! ```text
//!   +------+---------+------+--------------+-----------------+
//!   | SDPF | version | kind | payload_len  |  payload bytes  |
//!   | 4 B  | u16 LE  | u8   |   u32 LE     |  (payload_len)  |
//!   +------+---------+------+--------------+-----------------+
//! ```
//!
//! The conversation is strictly request/response from the frontend's
//! point of view, with one exception: while serving a `Match`, the
//! worker *streams* [`Frame::Checkpoint`] progress frames before the
//! final [`Frame::Result`] — those checkpoints are the failover
//! currency (a survivor resumes a dead worker's chunk from the last
//! one received, instead of rescanning).
//!
//! Decoding is paranoid by design: bad magic, unknown version, unknown
//! kind, truncated payloads, oversized payloads and trailing garbage
//! are all hard errors, so a corrupted or maliciously short write never
//! silently changes a verdict — it surfaces as a transport failure that
//! the frontend's retry/failover machinery handles.

use std::io::{Read, Write};

use anyhow::{bail, Result};

use crate::engine::Pattern;

/// Frame magic: `SDPF` ("SpecDFA Process Frame").
pub const MAGIC: [u8; 4] = *b"SDPF";
/// Current protocol version; bumped on any wire-layout change.
pub const VERSION: u16 = 1;
/// Header size in bytes: magic + version + kind + payload length.
pub const HEADER_BYTES: usize = 4 + 2 + 1 + 4;
/// Hard ceiling on a single frame payload (64 MiB): anything larger is
/// rejected before allocation, so a corrupted length field cannot OOM
/// the peer.
pub const MAX_PAYLOAD: usize = 64 << 20;

/// Frame kind discriminant — the `kind` byte of the header.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FrameKind {
    /// Worker → frontend, once after connecting: identity + capacity.
    Hello,
    /// Frontend → worker: compile a pattern under an id.
    Compile,
    /// Worker → frontend: the pattern compiled.
    CompileOk,
    /// Frontend → worker: match a chunk (optionally resuming).
    Match,
    /// Worker → frontend: streamed mid-chunk progress checkpoint.
    Checkpoint,
    /// Worker → frontend: final checkpoint for a finished chunk.
    Result,
    /// Either direction: liveness probe (nonce echoed back).
    Heartbeat,
    /// Worker → frontend: a request failed.
    Error,
    /// Frontend → worker: exit cleanly.
    Shutdown,
}

impl FrameKind {
    /// Wire discriminant.
    pub fn code(self) -> u8 {
        match self {
            FrameKind::Hello => 1,
            FrameKind::Compile => 2,
            FrameKind::CompileOk => 3,
            FrameKind::Match => 4,
            FrameKind::Checkpoint => 5,
            FrameKind::Result => 6,
            FrameKind::Heartbeat => 7,
            FrameKind::Error => 8,
            FrameKind::Shutdown => 9,
        }
    }

    /// Decode a wire discriminant.
    pub fn from_code(code: u8) -> Result<FrameKind> {
        Ok(match code {
            1 => FrameKind::Hello,
            2 => FrameKind::Compile,
            3 => FrameKind::CompileOk,
            4 => FrameKind::Match,
            5 => FrameKind::Checkpoint,
            6 => FrameKind::Result,
            7 => FrameKind::Heartbeat,
            8 => FrameKind::Error,
            9 => FrameKind::Shutdown,
            other => bail!("unknown SDPF frame kind {other}"),
        })
    }

    /// Stable lowercase name (fault-plan spec vocabulary).
    pub fn name(self) -> &'static str {
        match self {
            FrameKind::Hello => "hello",
            FrameKind::Compile => "compile",
            FrameKind::CompileOk => "compileok",
            FrameKind::Match => "match",
            FrameKind::Checkpoint => "checkpoint",
            FrameKind::Result => "result",
            FrameKind::Heartbeat => "heartbeat",
            FrameKind::Error => "error",
            FrameKind::Shutdown => "shutdown",
        }
    }

    /// Parse a lowercase name ([`FrameKind::name`] vocabulary).
    pub fn parse(name: &str) -> Result<FrameKind> {
        Ok(match name {
            "hello" => FrameKind::Hello,
            "compile" => FrameKind::Compile,
            "compileok" => FrameKind::CompileOk,
            "match" => FrameKind::Match,
            "checkpoint" => FrameKind::Checkpoint,
            "result" => FrameKind::Result,
            "heartbeat" => FrameKind::Heartbeat,
            "error" => FrameKind::Error,
            "shutdown" => FrameKind::Shutdown,
            other => bail!("unknown SDPF frame name {other:?}"),
        })
    }
}

/// One protocol message (header kind + decoded payload).
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// Worker attach: which worker this connection is, and its measured
    /// §4.1 matching capacity in symbols per microsecond.
    Hello {
        /// worker index (the `--id` the frontend spawned it with)
        worker: u32,
        /// median matching rate measured in-process at startup
        rate_syms_per_us: f64,
    },
    /// Compile `pattern` and remember it as `pattern_id`.
    Compile {
        /// frontend-assigned id future `Match` frames reference
        pattern_id: u32,
        /// the pattern to compile
        pattern: Pattern,
    },
    /// `Compile` succeeded.
    CompileOk {
        /// echoed pattern id
        pattern_id: u32,
        /// |Q| of the compiled minimal DFA (sanity telemetry)
        states: u32,
    },
    /// Match a chunk of input against a compiled pattern.
    Match {
        /// frontend-assigned request id echoed in every reply frame
        req_id: u64,
        /// which compiled pattern to run
        pattern_id: u32,
        /// stream a [`Frame::Checkpoint`] after every this many bytes
        checkpoint_every: u64,
        /// resume from this serialized [`crate::engine::Checkpoint`]
        /// (`SDCK` bytes) instead of starting fresh — the failover path
        resume: Option<Vec<u8>>,
        /// the chunk bytes to match
        data: Vec<u8>,
    },
    /// Streamed mid-chunk progress (serialized `SDCK` checkpoint).
    Checkpoint {
        /// echoed request id
        req_id: u64,
        /// serialized [`crate::engine::Checkpoint`]
        ckpt: Vec<u8>,
    },
    /// Final answer for a chunk: the fully-folded checkpoint whose
    /// L-vector covers every byte of the chunk.
    Result {
        /// echoed request id
        req_id: u64,
        /// serialized [`crate::engine::Checkpoint`]
        ckpt: Vec<u8>,
    },
    /// Liveness probe; the peer echoes the nonce back.
    Heartbeat {
        /// opaque nonce the reply must echo
        nonce: u64,
    },
    /// A request failed on the worker.
    Error {
        /// request id the failure belongs to (0 = connection-level)
        req_id: u64,
        /// human-readable failure description
        message: String,
    },
    /// Clean shutdown request; the worker exits after reading it.
    Shutdown,
}

impl Frame {
    /// This frame's [`FrameKind`].
    pub fn kind(&self) -> FrameKind {
        match self {
            Frame::Hello { .. } => FrameKind::Hello,
            Frame::Compile { .. } => FrameKind::Compile,
            Frame::CompileOk { .. } => FrameKind::CompileOk,
            Frame::Match { .. } => FrameKind::Match,
            Frame::Checkpoint { .. } => FrameKind::Checkpoint,
            Frame::Result { .. } => FrameKind::Result,
            Frame::Heartbeat { .. } => FrameKind::Heartbeat,
            Frame::Error { .. } => FrameKind::Error,
            Frame::Shutdown => FrameKind::Shutdown,
        }
    }

    /// Encode header + payload into a fresh byte vector.
    pub fn encode(&self) -> Vec<u8> {
        let payload = self.encode_payload();
        let mut out = Vec::with_capacity(HEADER_BYTES + payload.len());
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.push(self.kind().code());
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&payload);
        out
    }

    fn encode_payload(&self) -> Vec<u8> {
        let mut p = Vec::new();
        match self {
            Frame::Hello { worker, rate_syms_per_us } => {
                p.extend_from_slice(&worker.to_le_bytes());
                p.extend_from_slice(&rate_syms_per_us.to_bits().to_le_bytes());
            }
            Frame::Compile { pattern_id, pattern } => {
                p.extend_from_slice(&pattern_id.to_le_bytes());
                encode_pattern(&mut p, pattern);
            }
            Frame::CompileOk { pattern_id, states } => {
                p.extend_from_slice(&pattern_id.to_le_bytes());
                p.extend_from_slice(&states.to_le_bytes());
            }
            Frame::Match {
                req_id,
                pattern_id,
                checkpoint_every,
                resume,
                data,
            } => {
                p.extend_from_slice(&req_id.to_le_bytes());
                p.extend_from_slice(&pattern_id.to_le_bytes());
                p.extend_from_slice(&checkpoint_every.to_le_bytes());
                let resume = resume.as_deref().unwrap_or(&[]);
                p.extend_from_slice(&(resume.len() as u64).to_le_bytes());
                p.extend_from_slice(resume);
                p.extend_from_slice(&(data.len() as u64).to_le_bytes());
                p.extend_from_slice(data);
            }
            Frame::Checkpoint { req_id, ckpt }
            | Frame::Result { req_id, ckpt } => {
                p.extend_from_slice(&req_id.to_le_bytes());
                p.extend_from_slice(&(ckpt.len() as u64).to_le_bytes());
                p.extend_from_slice(ckpt);
            }
            Frame::Heartbeat { nonce } => {
                p.extend_from_slice(&nonce.to_le_bytes());
            }
            Frame::Error { req_id, message } => {
                p.extend_from_slice(&req_id.to_le_bytes());
                let bytes = message.as_bytes();
                p.extend_from_slice(&(bytes.len() as u64).to_le_bytes());
                p.extend_from_slice(bytes);
            }
            Frame::Shutdown => {}
        }
        p
    }

    /// Decode a payload for `kind`; the whole payload must be consumed.
    pub fn decode(kind: FrameKind, payload: &[u8]) -> Result<Frame> {
        let mut c = Cursor { buf: payload, pos: 0 };
        let frame = match kind {
            FrameKind::Hello => Frame::Hello {
                worker: c.u32()?,
                rate_syms_per_us: f64::from_bits(c.u64()?),
            },
            FrameKind::Compile => Frame::Compile {
                pattern_id: c.u32()?,
                pattern: decode_pattern(&mut c)?,
            },
            FrameKind::CompileOk => Frame::CompileOk {
                pattern_id: c.u32()?,
                states: c.u32()?,
            },
            FrameKind::Match => {
                let req_id = c.u64()?;
                let pattern_id = c.u32()?;
                let checkpoint_every = c.u64()?;
                let resume_len = c.u64()? as usize;
                let resume = c.take(resume_len)?.to_vec();
                let data_len = c.u64()? as usize;
                let data = c.take(data_len)?.to_vec();
                Frame::Match {
                    req_id,
                    pattern_id,
                    checkpoint_every,
                    resume: if resume.is_empty() { None } else { Some(resume) },
                    data,
                }
            }
            FrameKind::Checkpoint | FrameKind::Result => {
                let req_id = c.u64()?;
                let len = c.u64()? as usize;
                let ckpt = c.take(len)?.to_vec();
                if kind == FrameKind::Checkpoint {
                    Frame::Checkpoint { req_id, ckpt }
                } else {
                    Frame::Result { req_id, ckpt }
                }
            }
            FrameKind::Heartbeat => Frame::Heartbeat { nonce: c.u64()? },
            FrameKind::Error => {
                let req_id = c.u64()?;
                let len = c.u64()? as usize;
                let bytes = c.take(len)?.to_vec();
                Frame::Error {
                    req_id,
                    message: String::from_utf8_lossy(&bytes).into_owned(),
                }
            }
            FrameKind::Shutdown => Frame::Shutdown,
        };
        if c.pos != payload.len() {
            bail!(
                "SDPF {} frame has {} trailing payload bytes",
                kind.name(),
                payload.len() - c.pos
            );
        }
        Ok(frame)
    }
}

/// Write one frame to a stream (single `write_all` of the encoding, so
/// a frame is either fully queued to the transport or not at all — the
/// only partial writes on the wire are deliberately injected faults).
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> std::io::Result<()> {
    w.write_all(&frame.encode())?;
    w.flush()
}

/// Read one frame from a stream, validating magic, version and payload
/// bounds before allocating.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Frame> {
    let mut header = [0u8; HEADER_BYTES];
    r.read_exact(&mut header)?;
    if header[..4] != MAGIC {
        bail!("bad SDPF magic {:?}", &header[..4]);
    }
    let version = u16::from_le_bytes([header[4], header[5]]);
    if version != VERSION {
        bail!("unsupported SDPF version {version} (want {VERSION})");
    }
    let kind = FrameKind::from_code(header[6])?;
    let len = u32::from_le_bytes([header[7], header[8], header[9], header[10]])
        as usize;
    if len > MAX_PAYLOAD {
        bail!("SDPF payload length {len} exceeds cap {MAX_PAYLOAD}");
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Frame::decode(kind, &payload)
}

fn encode_pattern(out: &mut Vec<u8>, pattern: &Pattern) {
    let (tag, text): (u8, &str) = match pattern {
        Pattern::Regex(t) => (0, t),
        Pattern::RegexExact(t) => (1, t),
        Pattern::Prosite(t) => (2, t),
        Pattern::Grail(t) => (3, t),
    };
    out.push(tag);
    let bytes = text.as_bytes();
    out.extend_from_slice(&(bytes.len() as u64).to_le_bytes());
    out.extend_from_slice(bytes);
}

fn decode_pattern(c: &mut Cursor<'_>) -> Result<Pattern> {
    let tag = c.take(1)?[0];
    let len = c.u64()? as usize;
    let text = String::from_utf8(c.take(len)?.to_vec())?;
    Ok(match tag {
        0 => Pattern::Regex(text),
        1 => Pattern::RegexExact(text),
        2 => Pattern::Prosite(text),
        3 => Pattern::Grail(text),
        other => bail!("unknown pattern tag {other}"),
    })
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() - self.pos < n {
            bail!(
                "truncated SDPF payload: wanted {n} bytes at offset {}, \
                 have {}",
                self.pos,
                self.buf.len() - self.pos
            );
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // unwrap in tests is a test failure
mod tests {
    use super::*;

    fn roundtrip(frame: Frame) {
        let bytes = frame.encode();
        let mut r = &bytes[..];
        let back = read_frame(&mut r).unwrap();
        assert_eq!(back, frame);
        assert!(r.is_empty(), "reader must consume the whole frame");
    }

    #[test]
    fn every_frame_kind_roundtrips() {
        roundtrip(Frame::Hello { worker: 3, rate_syms_per_us: 417.25 });
        roundtrip(Frame::Compile {
            pattern_id: 9,
            pattern: Pattern::Regex("(ab|cd)+e".into()),
        });
        roundtrip(Frame::Compile {
            pattern_id: 10,
            pattern: Pattern::Grail("(START) |- 0\n0 -| (FINAL)\n".into()),
        });
        roundtrip(Frame::CompileOk { pattern_id: 9, states: 6 });
        roundtrip(Frame::Match {
            req_id: 77,
            pattern_id: 9,
            checkpoint_every: 65536,
            resume: None,
            data: b"abcdabcde".to_vec(),
        });
        roundtrip(Frame::Match {
            req_id: 78,
            pattern_id: 9,
            checkpoint_every: 4096,
            resume: Some(vec![1, 2, 3, 4]),
            data: vec![0xAB; 100],
        });
        roundtrip(Frame::Checkpoint { req_id: 77, ckpt: vec![5; 40] });
        roundtrip(Frame::Result { req_id: 77, ckpt: vec![6; 40] });
        roundtrip(Frame::Heartbeat { nonce: 0xDEADBEEF });
        roundtrip(Frame::Error { req_id: 1, message: "boom".into() });
        roundtrip(Frame::Shutdown);
    }

    #[test]
    fn corrupt_headers_are_rejected() {
        let good = Frame::Heartbeat { nonce: 42 }.encode();
        // bad magic
        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(read_frame(&mut &bad[..]).is_err());
        // bad version
        let mut bad = good.clone();
        bad[4] = 0xFF;
        assert!(read_frame(&mut &bad[..]).is_err());
        // unknown kind
        let mut bad = good.clone();
        bad[6] = 0x7F;
        assert!(read_frame(&mut &bad[..]).is_err());
        // oversized payload length
        let mut bad = good.clone();
        bad[7..11].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(read_frame(&mut &bad[..]).is_err());
    }

    #[test]
    fn truncation_at_every_byte_is_rejected() {
        let full = Frame::Result { req_id: 5, ckpt: vec![7; 16] }.encode();
        for cut in 0..full.len() {
            let mut r = &full[..cut];
            assert!(
                read_frame(&mut r).is_err(),
                "truncation at byte {cut} must fail"
            );
        }
    }

    #[test]
    fn trailing_payload_bytes_are_rejected() {
        let mut bytes = Frame::Heartbeat { nonce: 1 }.encode();
        // grow the declared payload by one garbage byte
        let len = u32::from_le_bytes(bytes[7..11].try_into().unwrap()) + 1;
        bytes[7..11].copy_from_slice(&len.to_le_bytes());
        bytes.push(0xEE);
        assert!(read_frame(&mut &bytes[..]).is_err());
    }

    #[test]
    fn frame_kind_names_roundtrip() {
        for kind in [
            FrameKind::Hello,
            FrameKind::Compile,
            FrameKind::CompileOk,
            FrameKind::Match,
            FrameKind::Checkpoint,
            FrameKind::Result,
            FrameKind::Heartbeat,
            FrameKind::Error,
            FrameKind::Shutdown,
        ] {
            assert_eq!(FrameKind::parse(kind.name()).unwrap(), kind);
            assert_eq!(FrameKind::from_code(kind.code()).unwrap(), kind);
        }
        assert!(FrameKind::parse("warp").is_err());
        assert!(FrameKind::from_code(0).is_err());
    }
}
